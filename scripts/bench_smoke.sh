#!/usr/bin/env bash
# Bench smoke: run the z-sampling bench on a reduced matrix (small
# thread count, minimal benchkit sampling) and validate the
# BENCH_z_sampling.json it emits — well-formed JSON, the expected cases
# (exact SIMD×pin matrix plus the Pólya-urn fast-path cells), and the
# exact-vs-PPU throughput columns. Minutes of wall clock, not a perf
# run: CI uses it (non-gating) to catch bench bit-rot and schema drift,
# never to publish numbers.
#
# Runs anywhere with a rust toolchain: `bash scripts/bench_smoke.sh`.
set -euo pipefail

ROOT="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"

export BENCH_THREADS="${BENCH_THREADS:-2}"
export BENCHKIT_SAMPLES="${BENCHKIT_SAMPLES:-3}"
export BENCHKIT_BATCH_MS="${BENCHKIT_BATCH_MS:-50}"

cargo bench --bench z_sampling --manifest-path "$ROOT/rust/Cargo.toml"

# Bench binaries run with CWD = the package root, so the JSON lands
# next to the manifest.
JSON="$ROOT/rust/BENCH_z_sampling.json"
if [ ! -f "$JSON" ]; then
  echo "bench did not write $JSON" >&2
  exit 1
fi

if command -v python3 >/dev/null 2>&1; then
  python3 - "$JSON" "$BENCH_THREADS" <<'EOF'
import json
import sys

path, threads = sys.argv[1], sys.argv[2]
with open(path) as f:
    doc = json.load(f)

assert doc["group"] == "z_sampling", doc.get("group")
cases = {c["name"]: c for c in doc["cases"]}
want_cases = [
    "pc_doubly_sparse_iteration",
    f"pc_t{threads}_simd_off_pin_off",
    f"pc_t{threads}_simd_on_pin_on",
    f"pc_t{threads}_ppu_simd_off",
    f"pc_t{threads}_ppu_simd_on",
    "dense_enumeration_iteration_10pct",
]
for name in want_cases:
    assert name in cases, f"missing case {name}: have {sorted(cases)}"
    case = cases[name]
    for key in ("median_s", "mean_s", "sd_s", "min_s", "items_per_s"):
        assert key in case, f"{name}: missing {key}"
    assert case["median_s"] > 0, f"{name}: non-positive median"
    assert case["items_per_s"] > 0, f"{name}: non-positive throughput"

counters = doc["counters"]
for key in (
    "exact_tokens_per_s",
    "ppu_tokens_per_s",
    "speedup_ppu_vs_exact",
    f"pc_t{threads}_ppu_simd_off/counter/ppu_tokens",
    f"pc_t{threads}_ppu_simd_off/ppu_doc_accept_rate",
    f"pc_t{threads}_ppu_simd_off/ppu_word_accept_rate",
):
    assert key in counters, f"missing counter {key}"
    assert counters[key] > 0, f"non-positive counter {key}"
print(
    f"schema OK: {len(cases)} cases; "
    f"exact {counters['exact_tokens_per_s']:.0f} tok/s, "
    f"ppu {counters['ppu_tokens_per_s']:.0f} tok/s "
    f"({counters['speedup_ppu_vs_exact']:.2f}x)"
)
EOF
else
  # Shell fallback: the load-bearing names plus balanced braces.
  for pat in '"group": "z_sampling"' \
             '"name": "pc_doubly_sparse_iteration"' \
             "\"name\": \"pc_t${BENCH_THREADS}_ppu_simd_off\"" \
             '"exact_tokens_per_s"' \
             '"ppu_tokens_per_s"' \
             '"speedup_ppu_vs_exact"'; do
    grep -qF "$pat" "$JSON" || { echo "missing $pat in $JSON" >&2; exit 1; }
  done
  opens="$(grep -o '[{[]' "$JSON" | wc -l)"
  closes="$(grep -o '[]}]' "$JSON" | wc -l)"
  if [ "$opens" -ne "$closes" ]; then
    echo "unbalanced braces/brackets in $JSON" >&2
    exit 1
  fi
  echo "schema OK (shell fallback): $JSON"
fi

echo "bench smoke: OK"
