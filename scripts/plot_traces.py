#!/usr/bin/env python
"""Render the Figure-1 panels from the experiment CSV traces.

Usage: python scripts/plot_traces.py [results_dir] [out.png]

Reads the traces written by `repro exp all` and draws the paper's
Fig-1 layout: log-likelihood and active-topic traces for the
PC-vs-direct-assignment comparison (per-iteration axis), the
PC-vs-subcluster comparison (real-time axis), the PubMed-scale run,
and the per-iteration-cost panel (Fig 1i) from the bench CSV.
Offline-only convenience — no part of the pipeline depends on it.
"""

import csv
import pathlib
import sys

import matplotlib

matplotlib.use("Agg")
import matplotlib.pyplot as plt


def read_trace(path):
    rows = []
    with open(path) as f:
        for row in csv.DictReader(f):
            rows.append({k: float(v) for k, v in row.items()})
    return rows


def maybe(ax, results, name, x_key, y_key, label, **kw):
    path = results / f"{name}.csv"
    if not path.exists():
        ax.set_title(f"{name} (missing)", fontsize=8)
        return
    rows = read_trace(path)
    ax.plot([r[x_key] for r in rows], [r[y_key] for r in rows], label=label, **kw)


def main():
    results = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else "results")
    out = sys.argv[2] if len(sys.argv) > 2 else str(results / "fig1.png")
    fig, axes = plt.subplots(3, 3, figsize=(14, 10))
    panels = [
        # (axis, corpus tag, x key, title)
        (axes[0][0], "fig1_ap", "iteration", "AP log-lik (a)"),
        (axes[0][1], "fig1_ap", "iteration", "AP active topics (b)"),
        (axes[0][2], "fig1_cgcbib", "iteration", "CGCBIB log-lik (d)"),
    ]
    for ax, tag, xk, title in panels:
        yk = "active_topics" if "topics" in title else "log_likelihood"
        maybe(ax, results, f"{tag}_pc", xk, yk, "partially collapsed")
        maybe(ax, results, f"{tag}_da", xk, yk, "direct assignment")
        ax.set_title(title, fontsize=9)
        ax.legend(fontsize=7)
    # NeurIPS real-time panels (g, h)
    for ax, yk, title in [
        (axes[1][0], "active_topics", "NeurIPS active topics vs time (g)"),
        (axes[1][1], "log_likelihood", "NeurIPS log-lik vs time (h)"),
    ]:
        maybe(ax, results, "fig1_neurips_pc", "elapsed_secs", yk, "partially collapsed")
        maybe(ax, results, "fig1_neurips_ssm", "elapsed_secs", yk, "subcluster split-merge")
        ax.set_title(title, fontsize=9)
        ax.legend(fontsize=7)
    # Per-iteration cost (i) from the bench CSV
    ax = axes[1][2]
    bench = results / "bench_fig1i.csv"
    if bench.exists():
        rows = read_trace(bench)
        ax.plot([r["iter"] for r in rows], [r["pc_secs"] for r in rows], label="PC")
        ax.plot([r["iter"] for r in rows], [r["ssm_secs"] for r in rows], label="SSM")
        ax.set_yscale("log")
        ax.legend(fontsize=7)
    ax.set_title("seconds per iteration (i)", fontsize=9)
    # PubMed panels (j, k)
    for ax, yk, title in [
        (axes[2][0], "log_likelihood", "PubMed log-lik (j)"),
        (axes[2][1], "active_topics", "PubMed active topics (k)"),
    ]:
        maybe(ax, results, "fig1_pubmed_pc", "iteration", yk, "partially collapsed")
        ax.set_title(title, fontsize=9)
    # tokens-per-topic (c)
    ax = axes[2][2]
    for tag, label in [("ap_pc", "PC"), ("ap_da", "DA")]:
        path = results / f"fig1_tokens_per_topic_{tag}.csv"
        if path.exists():
            rows = read_trace(path)
            ax.plot([r["rank"] for r in rows], [r["tokens"] for r in rows], label=label)
    ax.set_xscale("log")
    ax.set_yscale("log")
    ax.set_title("AP tokens per topic (c)", fontsize=9)
    ax.legend(fontsize=7)
    fig.tight_layout()
    fig.savefig(out, dpi=120)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
