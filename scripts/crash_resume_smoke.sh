#!/usr/bin/env bash
# Crash/resume smoke test for the `repro` binary:
#
#   1. start a training run with periodic durable checkpoints,
#   2. kill -9 it once at least two checkpoints have landed,
#   3. fake the debris of a mid-save crash (tear the newest checkpoint,
#      drop an atomic-write temp partial),
#   4. rerun with --resume and require it to pick a surviving snapshot
#      (never "starting fresh"), sweep the partial, and finish.
#
# Runs anywhere with a rust toolchain: `bash scripts/crash_resume_smoke.sh`.
# Set PACKED_ONLY=1 for the out-of-core leg: both runs train with
# --packed-only and z spilled to a file-backed store, so the kill lands
# while z lives on disk and the resume must rebuild straight into the
# packed layout (no nested state on either side of the crash).
set -euo pipefail

ROOT="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
OUT="$(mktemp -d "${TMPDIR:-/tmp}/hdp_crash_smoke.XXXXXX")"
trap 'rm -rf "$OUT"' EXIT
CKDIR="$OUT/checkpoints"

cargo build --release --manifest-path "$ROOT/rust/Cargo.toml"
REPRO="$ROOT/rust/target/release/repro"

MODE_FLAGS=()
if [ "${PACKED_ONLY:-0}" = "1" ]; then
  MODE_FLAGS=(--packed-only --z-file "$OUT/z.bin")
  echo "packed-only leg: z file-backed at $OUT/z.bin"
fi

ITERS=600
"$REPRO" train --corpus small --sampler pc --iterations "$ITERS" \
  --k-max 200 --eval-every 200 --threads 2 --seed 7 \
  --checkpoint-every 5 --out-dir "$OUT" "${MODE_FLAGS[@]+"${MODE_FLAGS[@]}"}" \
  >"$OUT/first.log" 2>&1 &
PID=$!

ckpt_count() { ls "$CKDIR"/ckpt-*.ckpt 2>/dev/null | wc -l; }

# Wait for two durable checkpoints (so tearing the newest still leaves
# one to resume from), then kill -9 mid-run.
for _ in $(seq 1 600); do
  if [ "$(ckpt_count)" -ge 2 ]; then break; fi
  if ! kill -0 "$PID" 2>/dev/null; then
    echo "training exited before writing two checkpoints:" >&2
    cat "$OUT/first.log" >&2
    exit 1
  fi
  sleep 0.1
done
if [ "$(ckpt_count)" -lt 2 ]; then
  echo "timed out waiting for checkpoints" >&2
  exit 1
fi
kill -9 "$PID" 2>/dev/null || true
wait "$PID" 2>/dev/null || true
echo "killed training with checkpoints: $(ls "$CKDIR")"

# Crash debris: tear the newest checkpoint and drop a temp partial.
NEWEST="$(ls "$CKDIR"/ckpt-*.ckpt | sort | tail -n 1)"
SIZE="$(wc -c <"$NEWEST")"
head -c "$((SIZE / 2))" "$NEWEST" >"$NEWEST.torn"
mv "$NEWEST.torn" "$NEWEST"
PARTIAL="$CKDIR/.ckpt-9999999999.ckpt.1-0.tmp"
printf partial >"$PARTIAL"

# Resume: must discard the torn file, pick the previous snapshot, and
# run the chain to completion.
"$REPRO" train --corpus small --sampler pc --iterations "$ITERS" \
  --k-max 200 --eval-every 200 --threads 2 --seed 7 \
  --checkpoint-every 5 --out-dir "$OUT" --resume \
  "${MODE_FLAGS[@]+"${MODE_FLAGS[@]}"}" | tee "$OUT/resume.log"

if [ "${PACKED_ONLY:-0}" = "1" ] \
  && ! grep -q 'packed-only: z store `file`' "$OUT/resume.log"; then
  echo "packed-only resume did not land in the file-backed z store" >&2
  exit 1
fi
if ! grep -q "resuming from" "$OUT/resume.log"; then
  echo "expected to resume from a checkpoint, not start fresh" >&2
  exit 1
fi
if [ -e "$PARTIAL" ]; then
  echo "temp partial was not swept by the resume scan" >&2
  exit 1
fi
echo "crash/resume smoke: OK"
