#!/usr/bin/env bash
# Low-memory smoke test for packed-only training:
#
#   1. calibrate: run `repro train --packed-only --z-file` (z spilled to
#      disk, tokens in the flat arena, no nested corpus or z ever
#      materialized) and record its peak virtual memory from
#      /proc/<pid>/status VmPeak,
#   2. re-run the SAME packed-only configuration under `ulimit -v` set
#      to that peak plus a small allocator margin — it must complete,
#   3. run the resident (nested-corpus construction) configuration
#      under the SAME budget — it must die on allocation failure,
#      because its nested z + construction transient sit well above the
#      packed-only footprint.
#
# This is the executable form of the residency claim: the packed-arena
# sampler state fits where the nested representation does not, and the
# chains are bit-identical anyway (tests/statistical.rs).
#
# Runs anywhere with a rust toolchain: `bash scripts/low_mem_smoke.sh`.
set -euo pipefail

ROOT="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
OUT="$(mktemp -d "${TMPDIR:-/tmp}/hdp_low_mem_smoke.XXXXXX")"
trap 'rm -rf "$OUT"' EXIT
export HDP_CACHE_DIR="$OUT/cache"

cargo build --release --manifest-path "$ROOT/rust/Cargo.toml"
REPRO="$ROOT/rust/target/release/repro"

# The pubmed synthetic analog (~41k docs) is the largest registered
# corpus — big enough that sampler-state bytes dominate the process
# baseline. eval-every exceeds iterations so the run never materializes
# any diagnostic state beyond the training path itself.
COMMON=(--corpus pubmed --sampler pc --iterations 5 --k-max 100
  --eval-every 1000 --threads 1 --seed 7 --out-dir "$OUT")

# Run a command in the background and poll its VmPeak (a kernel
# high-water mark, monotone — the last read before exit is the max).
peak_vm_kb() {
  "$@" >/dev/null 2>&1 &
  local pid=$! peak=0 v
  while kill -0 "$pid" 2>/dev/null; do
    v="$(awk '/^VmPeak:/ {print $2}' "/proc/$pid/status" 2>/dev/null || true)"
    if [ -n "${v:-}" ] && [ "$v" -gt "$peak" ]; then peak=$v; fi
    sleep 0.02
  done
  wait "$pid"
  echo "$peak"
}

# Warm the corpus cache outside any limit (generation cost is identical
# for both modes and not what this test measures).
"$REPRO" corpus --name pubmed --seed 7 >/dev/null

echo "calibrating packed-only peak VM..."
PACKED_PEAK_KB="$(peak_vm_kb "$REPRO" train "${COMMON[@]}" \
  --packed-only --z-file "$OUT/z.bin")" \
  || { echo "calibration run failed" >&2; exit 1; }
if [ "$PACKED_PEAK_KB" -le 0 ]; then
  echo "could not sample VmPeak (run too fast?); not a pass" >&2
  exit 1
fi
BUDGET_KB=$((PACKED_PEAK_KB + 8192))
echo "packed-only peak ${PACKED_PEAK_KB} KB -> budget ${BUDGET_KB} KB"

# Packed-only under the budget: must complete.
if ! (
  ulimit -v "$BUDGET_KB"
  exec "$REPRO" train "${COMMON[@]}" --packed-only --z-file "$OUT/z2.bin"
) >"$OUT/packed.log" 2>&1; then
  echo "packed-only run died under its own budget:" >&2
  tail -n 20 "$OUT/packed.log" >&2
  exit 1
fi
grep -q 'packed-only: z store `file`' "$OUT/packed.log"
echo "packed-only + FileZ completed under ${BUDGET_KB} KB"

# Resident under the same budget: must OOM (nested z + the nested
# construction transient exceed the packed-only footprint by far more
# than the margin).
if (
  ulimit -v "$BUDGET_KB"
  exec "$REPRO" train "${COMMON[@]}"
) >"$OUT/resident.log" 2>&1; then
  echo "resident run unexpectedly fit in the packed-only budget" >&2
  tail -n 20 "$OUT/resident.log" >&2
  exit 1
fi
echo "resident run OOMed under the same budget (expected)"
echo "low-mem smoke: OK"
