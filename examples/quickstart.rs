//! Quickstart: train the paper's sparse parallel HDP sampler on a
//! small synthetic corpus and print the discovered topics.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use hdp_sparse::config::{HdpConfig, RunConfig};
use hdp_sparse::coordinator::{train, LoopOptions};
use hdp_sparse::corpus::registry;
use hdp_sparse::diagnostics::topics;
use hdp_sparse::hdp::pc::PcSampler;
use hdp_sparse::hdp::Trainer;
use hdp_sparse::metrics::TraceWriter;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // 1. A corpus. `registry::load` returns the cached synthetic analog
    //    (or real UCI data when HDP_CORPUS_DIR provides it).
    let corpus = Arc::new(registry::load("small", 2020)?);
    println!("corpus: {}", corpus.summary());

    // 2. The model: paper hyperparameters, truncation K* = 200.
    let cfg = HdpConfig { alpha: 0.1, beta: 0.01, gamma: 1.0, k_max: 200, init_topics: 1 };
    let mut sampler = PcSampler::new(corpus.clone(), cfg, 2, 42)?;

    // 3. Train. The coordinator streams a CSV trace; stdout shows the
    //    log-likelihood and active-topic trajectory.
    let run = RunConfig {
        iterations: 300,
        threads: 2,
        seed: 42,
        eval_every: 50,
        time_budget_secs: 0,
        ..Default::default()
    };
    let mut trace = TraceWriter::in_memory();
    let summary = train(
        &mut sampler,
        &run,
        &mut trace,
        &LoopOptions { verbose: true, eval_first: true, ..Default::default() },
    )?;
    println!(
        "\ntrained {} iterations in {:.1}s ({:.0} tokens/s)",
        summary.iterations, summary.elapsed_secs, summary.tokens_per_sec
    );

    // 4. Inspect the topics.
    let rows = sampler.topic_word_rows();
    let tops = topics::top_words(&rows, &corpus, 8, 50);
    println!("\ntop topics (of {} active):", tops.len());
    for t in tops.iter().take(10) {
        println!(
            "  topic {:>3} ({:>6} tokens): {}",
            t.topic,
            t.tokens,
            t.top_words.join(" ")
        );
    }
    // 5. Phase timing breakdown (where the iteration time goes).
    println!("\nphase timers:\n{}", sampler.timers.summary());
    Ok(())
}
