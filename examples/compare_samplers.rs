//! Sampler comparison on one corpus — the Fig-1 experiment in example
//! form: partially collapsed (Algorithm 2) vs direct assignment vs
//! subcluster split-merge vs fixed-K Pólya-urn LDA, under a shared
//! wall-clock budget.
//!
//! ```text
//! cargo run --release --example compare_samplers [-- budget_secs]
//! ```

use hdp_sparse::config::{HdpConfig, RunConfig};
use hdp_sparse::coordinator::{train, LoopOptions};
use hdp_sparse::corpus::registry;
use hdp_sparse::hdp::{
    da::DaSampler, pc::PcSampler, pclda::PcLdaSampler, ssm::SsmSampler, Trainer,
};
use hdp_sparse::metrics::TraceWriter;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let budget: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(15);
    let corpus = Arc::new(registry::load("small", 2020)?);
    println!("corpus: {} | budget {}s per sampler\n", corpus.summary(), budget);
    let cfg = HdpConfig { alpha: 0.1, beta: 0.01, gamma: 1.0, k_max: 200, init_topics: 1 };
    let run = RunConfig {
        iterations: usize::MAX / 2,
        threads: 2,
        seed: 7,
        eval_every: 20,
        time_budget_secs: budget,
        ..Default::default()
    };
    let mut trainers: Vec<Box<dyn Trainer>> = vec![
        Box::new(PcSampler::new(corpus.clone(), cfg, 2, 7)?),
        Box::new(DaSampler::new(corpus.clone(), cfg, 7)?),
        Box::new(SsmSampler::new(corpus.clone(), cfg, 7)?),
        Box::new(PcLdaSampler::new(corpus.clone(), 50, cfg.alpha, cfg.beta, 2, 7)?),
    ];
    println!(
        "{:<8} {:>9} {:>14} {:>8} {:>12}",
        "sampler", "iters", "final_ll", "topics", "iters/sec"
    );
    for t in trainers.iter_mut() {
        let mut trace = TraceWriter::in_memory();
        let summary = train(t.as_mut(), &run, &mut trace, &LoopOptions::default())?;
        println!(
            "{:<8} {:>9} {:>14.1} {:>8} {:>12.2}",
            t.name(),
            summary.iterations,
            summary.final_log_likelihood,
            summary.final_active_topics,
            summary.iterations as f64 / summary.elapsed_secs
        );
    }
    println!(
        "\npaper shape (Fig 1): the partially collapsed sampler completes the\n\
         most iterations per second and stabilizes its topic count fastest;\n\
         direct assignment mixes to a slightly better optimum per iteration\n\
         but is sequential; subcluster split-merge grows topics one at a time."
    );
    Ok(())
}
