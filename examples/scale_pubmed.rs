//! **End-to-end driver** (DESIGN.md §"End-to-end validation"): the
//! PubMed-scale experiment on a real (synthetic-analog) workload,
//! exercising every layer of the stack in one run:
//!
//! 1. corpus substrate — generate/load the `pubmed` analog
//!    (~41k docs, ~3.9M tokens, V=60k; 1/200 scale of the paper's);
//! 2. L3 sampler — Algorithm 2 with the paper's hyperparameters
//!    (α=0.1, β=0.01, γ=1, K*=1000), multi-threaded, trace logged;
//! 3. runtime — the AOT-compiled (jax→pallas→HLO) loglik artifact is
//!    executed via PJRT every evaluation and cross-checked against the
//!    rust-native sparse value;
//! 4. diagnostics — Fig-1(j,k)-style trace + Fig-2-style topic table,
//!    and the Table-2 throughput extrapolation to the paper's full
//!    768M-token corpus.
//!
//! ```text
//! cargo run --release --example scale_pubmed [-- iterations]
//! ```

use hdp_sparse::config::HdpConfig;
use hdp_sparse::corpus::registry;
use hdp_sparse::diagnostics::topics;
use hdp_sparse::hdp::pc::PcSampler;
use hdp_sparse::hdp::Trainer;
use hdp_sparse::metrics::{IterRecord, TraceWriter};
use std::sync::Arc;
use std::time::Instant;

/// XLA cross-check: dense tiled loglik == rust-native sparse value.
/// Compiled only with the off-by-default `xla` feature; skipped
/// gracefully when the AOT artifacts are absent.
#[cfg(feature = "xla")]
fn xla_cross_check(
    sampler: &PcSampler,
    beta: f64,
    vocab: usize,
    threads: usize,
) -> anyhow::Result<()> {
    use hdp_sparse::hdp::pc::phi::sample_phi;
    use hdp_sparse::rng::Pcg64;
    use hdp_sparse::runtime::{phi_loglik_sparse, Engine};
    let engine_dir = Engine::default_dir();
    if !engine_dir.join("manifest.txt").exists() {
        println!("note: no artifacts/ — XLA cross-check disabled (run `make artifacts`)");
        return Ok(());
    }
    let mut engine = Engine::load(&engine_dir)?;
    let root = Pcg64::new(1);
    let phi = sample_phi(&root, sampler.n(), beta, vocab, threads);
    let t0 = Instant::now();
    let dense = engine.loglik(sampler.n(), &phi)?;
    let xla_time = t0.elapsed();
    let sparse = phi_loglik_sparse(sampler.n(), &phi);
    let rel = (dense - sparse).abs() / sparse.abs().max(1.0);
    println!(
        "\nXLA cross-check: sparse {sparse:.1} vs PJRT-tiled {dense:.1} (rel {rel:.2e}, {xla_time:?})"
    );
    anyhow::ensure!(rel < 1e-4, "XLA/native mismatch");
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn xla_cross_check(
    _sampler: &PcSampler,
    _beta: f64,
    _vocab: usize,
    _threads: usize,
) -> anyhow::Result<()> {
    println!("note: built without the `xla` feature — cross-check skipped");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let iterations: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);
    let threads = 2usize;
    println!("loading pubmed analog (first run generates + caches it)...");
    let corpus = Arc::new(registry::load("pubmed", 2020)?);
    println!("corpus: {}", corpus.summary());
    let paper = registry::find("pubmed").unwrap().paper.unwrap();

    let cfg = HdpConfig { alpha: 0.1, beta: 0.01, gamma: 1.0, k_max: 1000, init_topics: 1 };
    let mut sampler = PcSampler::new(corpus.clone(), cfg, threads, 2020)?;

    std::fs::create_dir_all("results")?;
    let mut trace = TraceWriter::to_file(std::path::Path::new(
        "results/scale_pubmed_trace.csv",
    ))?;
    let start = Instant::now();
    for it in 1..=iterations {
        let t0 = Instant::now();
        sampler.step()?;
        let iter_secs = t0.elapsed().as_secs_f64();
        if it % 5 == 0 || it == iterations || it == 1 {
            let d = sampler.diagnostics();
            println!(
                "iter {it:>4}: ll {:>15.1}  topics {:>4}  flag {}  {:.2}s/iter  work/token {:.2}",
                d.log_likelihood,
                d.active_topics,
                d.flag_topic_tokens,
                iter_secs,
                sampler.mean_sparse_work()
            );
            trace.push(IterRecord {
                iteration: it,
                elapsed_secs: start.elapsed().as_secs_f64(),
                iter_secs,
                log_likelihood: d.log_likelihood,
                active_topics: d.active_topics,
                flag_topic_tokens: d.flag_topic_tokens,
                total_tokens: d.total_tokens,
            })?;
        }
    }
    let elapsed = start.elapsed().as_secs_f64();
    let tput = corpus.num_tokens() as f64 * iterations as f64 / elapsed;

    xla_cross_check(&sampler, cfg.beta, corpus.vocab_size(), threads)?;

    // Fig-2-style topic table.
    let rows = sampler.topic_word_rows();
    let tops = topics::top_words(&rows, &corpus, 8, 1000);
    println!("\ntop topics (Fig-2 style):");
    for t in tops.iter().take(8) {
        println!("  n_k={:>9}  {}", t.tokens, t.top_words.join(" "));
    }

    // Table-2 extrapolation.
    let per_thread = tput / threads as f64;
    let paper_total = paper.tokens as f64 * paper.iterations as f64;
    let extrap_h = paper_total / (per_thread * paper.threads as f64) / 3600.0;
    println!(
        "\nthroughput: {:.2}M tokens/s on {threads} threads ({:.2}M/thread)",
        tput / 1e6,
        per_thread / 1e6
    );
    println!(
        "extrapolated full-PubMed run ({} iters, {} threads): {extrap_h:.1} h — paper reports {:.1} h",
        paper.iterations, paper.threads, paper.runtime_hours
    );
    println!("\nphase timers:\n{}", sampler.timers.summary());
    println!("trace -> results/scale_pubmed_trace.csv");
    Ok(())
}
