//! Ground-truth recovery: generate a corpus from the HDP generative
//! model itself, train Algorithm 2, and measure how well the planted
//! topics are recovered (greedy cosine matching) — the strongest
//! correctness evidence available for an unsupervised model.
//!
//! ```text
//! cargo run --release --example topic_recovery
//! ```

use hdp_sparse::config::HdpConfig;
use hdp_sparse::corpus::synthetic::HdpCorpusSpec;
use hdp_sparse::hdp::pc::PcSampler;
use hdp_sparse::hdp::Trainer;
use std::sync::Arc;

fn cosine(a: &[f64], b: &[f64]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|x| x * x).sum::<f64>().sqrt();
    dot / (na * nb).max(1e-12)
}

fn main() -> anyhow::Result<()> {
    let spec = HdpCorpusSpec {
        vocab: 1000,
        topics: 12,
        gamma: 4.0,
        alpha: 0.6,
        topic_beta: 0.01,
        docs: 600,
        mean_doc_len: 80.0,
        len_sigma: 0.4,
        min_doc_len: 20,
    };
    println!("generating HDP corpus: {} planted topics ...", spec.topics);
    let (corpus, truth) = spec.generate(123);
    let corpus = Arc::new(corpus);
    println!("corpus: {}", corpus.summary());

    let cfg = HdpConfig { alpha: 0.3, beta: 0.02, gamma: 1.0, k_max: 100, init_topics: 1 };
    let mut s = PcSampler::new(corpus.clone(), cfg, 2, 9)?;
    let iters = 500;
    for it in 1..=iters {
        s.step()?;
        if it % 100 == 0 {
            let d = s.diagnostics();
            println!("iter {it:>4}: ll {:.1}, {} active topics", d.log_likelihood, d.active_topics);
        }
    }

    // Learned topic distributions.
    let rows = s.topic_word_rows();
    let mut learned: Vec<(usize, u64, Vec<f64>)> = Vec::new();
    for (k, row) in rows.iter().enumerate() {
        let total: u64 = row.iter().map(|&(_, c)| c as u64).sum();
        if total < 100 {
            continue;
        }
        let mut dense = vec![0.0f64; corpus.vocab_size()];
        for &(v, c) in row {
            dense[v as usize] = c as f64 / total as f64;
        }
        learned.push((k, total, dense));
    }
    // Planted topic sizes.
    let mut planted_tokens = vec![0u64; truth.phi.len()];
    for zd in &truth.z {
        for &k in zd {
            planted_tokens[k as usize] += 1;
        }
    }
    println!("\n{:<10} {:>10} {:>10} {:>8}", "planted", "tokens", "best_cos", "matched");
    let mut matched = 0usize;
    let mut considered = 0usize;
    for (k, phi_k) in truth.phi.iter().enumerate() {
        if planted_tokens[k] < 300 {
            continue;
        }
        considered += 1;
        let best = learned
            .iter()
            .map(|(_, _, l)| cosine(l, phi_k))
            .fold(0.0f64, f64::max);
        let ok = best > 0.8;
        matched += ok as usize;
        println!(
            "topic {k:<4} {:>10} {best:>10.3} {:>8}",
            planted_tokens[k],
            if ok { "yes" } else { "NO" }
        );
    }
    println!(
        "\nrecovered {matched}/{considered} sizable planted topics; sampler found {} active topics (planted {})",
        s.diagnostics().active_topics,
        spec.topics
    );
    anyhow::ensure!(matched * 10 >= considered * 7, "recovery below 70%");
    println!("recovery OK");
    Ok(())
}
