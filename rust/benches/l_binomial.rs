//! Bench: the `l` step — binomial trick (§2.6) vs explicit Bernoulli
//! sequences (eq. 26–27). The claim: trick cost is constant in the
//! number of documents D, explicit cost is linear in total counts.

mod common;

use hdp_sparse::benchkit::Bench;
use hdp_sparse::hdp::pc::lstep::{sample_l_explicit, sample_l_topic};
use hdp_sparse::rng::Pcg64;
use hdp_sparse::sparse::DocCountHist;

fn main() {
    let mut bench = Bench::new("l_binomial");
    for &docs in &[1_000usize, 10_000, 100_000] {
        // Per-document topic counts with a realistic geometric-ish tail.
        let mut rng = Pcg64::new(docs as u64);
        let counts: Vec<u32> = (0..docs)
            .map(|_| {
                let u = rng.f64();
                (1.0 + (-8.0 * u.ln()).min(60.0)) as u32
            })
            .collect();
        let mut hist = DocCountHist::new(1);
        for &c in &counts {
            hist.record_doc(&[(0, c)]);
        }
        hist.finish();
        let (alpha, psi_k) = (0.1, 0.02);
        let mut r1 = Pcg64::new(1);
        bench.run(&format!("binomial_trick_D{docs}"), Some(docs as f64), || {
            sample_l_topic(&mut r1, &hist, 0, psi_k, alpha)
        });
        let mut r2 = Pcg64::new(2);
        bench.run(&format!("explicit_bernoulli_D{docs}"), Some(docs as f64), || {
            sample_l_explicit(&mut r2, &counts, psi_k, alpha)
        });
    }
    bench.write_csv(std::path::Path::new("results/bench_l_binomial.csv")).ok();
}
