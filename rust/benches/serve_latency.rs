//! Bench: frozen-snapshot serving latency.
//!
//! Trains a PC-HDP model on the shared bench corpus, freezes a
//! [`ModelSnapshot`], and reports per-request inference latency
//! (p50/p99) at 1, 8, and 32 concurrent client streams, plus a
//! pool-batched dispatch and an 8-stream run under continuous
//! hot-swapping — the serving layer's headline numbers.

mod common;

use hdp_sparse::benchkit::fmt_time;
use hdp_sparse::hdp::pc::PcSampler;
use hdp_sparse::hdp::Trainer;
use hdp_sparse::serve::{InferMode, InferRequest, ModelSnapshot, Server};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// Nearest-rank percentile of an ascending-sorted sample.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() - 1) as f64 * q).round() as usize]
}

/// Serve every request once across `streams` client threads (thread t
/// takes indices t, t+streams, ...). Returns (sorted latencies, wall
/// seconds, total tokens scored).
fn run_streams(
    server: &Server,
    reqs: &[InferRequest],
    streams: usize,
) -> (Vec<f64>, f64, u64) {
    let t0 = Instant::now();
    let mut lat: Vec<f64> = Vec::with_capacity(reqs.len());
    let mut scored = 0u64;
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..streams)
            .map(|t| {
                scope.spawn(move || {
                    let mut lats = Vec::new();
                    let mut tok = 0u64;
                    let mut i = t;
                    while i < reqs.len() {
                        let q0 = Instant::now();
                        let r = server.serve_one(&reqs[i]);
                        lats.push(q0.elapsed().as_secs_f64());
                        tok += r.tokens_scored;
                        i += streams;
                    }
                    (lats, tok)
                })
            })
            .collect();
        for h in handles {
            let (l, t) = h.join().unwrap();
            lat.extend(l);
            scored += t;
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (lat, wall, scored)
}

fn row(case: &str, lat: &[f64], wall: f64, n: usize) {
    println!(
        "{:>28} {:>12} {:>12} {:>10.0}",
        case,
        fmt_time(percentile(lat, 0.50)),
        fmt_time(percentile(lat, 0.99)),
        n as f64 / wall
    );
}

fn main() {
    let corpus = common::bench_corpus();
    let cfg = common::paper_cfg(200);
    let threads = 4usize;
    let mut s = PcSampler::new(corpus.clone(), cfg, threads, 2024).unwrap();
    for _ in 0..30 {
        s.step().unwrap();
    }
    let pool = s.pool_handle();

    let num_requests = 512usize;
    let reqs: Vec<InferRequest> = (0..num_requests)
        .map(|i| InferRequest {
            id: i as u64,
            tokens: corpus.docs[i % corpus.num_docs()].clone(),
            seed: 7,
            passes: 3,
            mode: InferMode::Mixture,
        })
        .collect();

    let server = Server::new(pool, ModelSnapshot::from_pc(&s, 1));
    {
        let snap = server.snapshot();
        println!(
            "serve_latency: {} requests on {} ({} threads)",
            reqs.len(),
            snap.describe(),
            threads
        );
    }
    println!(
        "{:>28} {:>12} {:>12} {:>10}",
        "case", "p50", "p99", "req/s"
    );

    let mut total_scored = 0u64;
    for &streams in &[1usize, 8, 32] {
        let (lat, wall, scored) = run_streams(&server, &reqs, streams);
        total_scored += scored;
        row(&format!("inline_{streams}_streams"), &lat, wall, reqs.len());
    }

    // One pool dispatch, one task per request (batch-level latency
    // only — individual requests share the pool's slots).
    let t0 = Instant::now();
    let batch = server.serve_batch(&reqs);
    let wall = t0.elapsed().as_secs_f64();
    total_scored += batch.iter().map(|r| r.tokens_scored).sum::<u64>();
    println!(
        "{:>28} {:>12} {:>12} {:>10.0}",
        "pool_batch",
        "-",
        fmt_time(wall),
        batch.len() as f64 / wall
    );

    // 8 streams served while a writer hot-swaps pre-frozen snapshots:
    // the publish path must not dent tail latency.
    let snaps: Vec<ModelSnapshot> =
        (0..16u64).map(|i| ModelSnapshot::from_pc(&s, 100 + i)).collect();
    let stop = AtomicBool::new(false);
    let t0 = Instant::now();
    let mut lat: Vec<f64> = Vec::new();
    let mut served = 0usize;
    std::thread::scope(|scope| {
        let writer = {
            let server = &server;
            let stop = &stop;
            scope.spawn(move || {
                for snap in snaps {
                    server.publish(snap);
                    std::thread::sleep(Duration::from_millis(5));
                }
                stop.store(true, Ordering::Release);
            })
        };
        let handles: Vec<_> = (0..8usize)
            .map(|t| {
                let server = &server;
                let reqs = &reqs;
                let stop = &stop;
                scope.spawn(move || {
                    let mut lats = Vec::new();
                    let mut tok = 0u64;
                    let mut i = t;
                    while !stop.load(Ordering::Acquire) {
                        let q0 = Instant::now();
                        let r = server.serve_one(&reqs[i % reqs.len()]);
                        lats.push(q0.elapsed().as_secs_f64());
                        tok += r.tokens_scored;
                        i += 8;
                    }
                    (lats, tok)
                })
            })
            .collect();
        writer.join().unwrap();
        for h in handles {
            let (l, t) = h.join().unwrap();
            served += l.len();
            total_scored += t;
            lat.extend(l);
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    row("hot_swap_8_streams", &lat, wall, served);
    println!(
        "final generation {}, {} tokens scored overall",
        server.generation(),
        total_scored
    );
}
