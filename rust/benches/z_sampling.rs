//! Bench: the doubly sparse z sweep (the hot path of Algorithm 2) vs a
//! dense-enumeration sweep — the core ablation behind eq. (29) and the
//! headline throughput of Table 2.

mod common;

use hdp_sparse::benchkit::Bench;
use hdp_sparse::hdp::pc::PcSampler;
use hdp_sparse::hdp::{exact::ExactSampler, Trainer};

fn main() {
    let corpus = common::bench_corpus();
    let tokens = corpus.num_tokens() as f64;
    let mut bench = Bench::new("z_sampling");

    // Warm the PC sampler into a structured state first so the bench
    // measures the equilibrium sparsity pattern, not the init.
    let mut pc = PcSampler::new(corpus.clone(), common::paper_cfg(500), 1, 1).unwrap();
    for _ in 0..20 {
        pc.step().unwrap();
    }
    bench.run("pc_doubly_sparse_iteration", Some(tokens), || {
        pc.step().unwrap();
    });
    println!(
        "  mean per-token sparse work (eq.29 min-term): {:.2}; active topics {}",
        pc.mean_sparse_work(),
        pc.diagnostics().active_topics
    );

    // Dense oracle at matched truncation on a slice of the corpus
    // (dense is O(N·K*); run it on a 10% subsample and scale).
    let sub = std::sync::Arc::new(hdp_sparse::corpus::Corpus {
        docs: corpus.docs[..corpus.docs.len() / 10].to_vec(),
        vocab: corpus.vocab.clone(),
    });
    let sub_tokens = sub.num_tokens() as f64;
    let mut dense = ExactSampler::new(sub, common::paper_cfg(500), 1).unwrap();
    for _ in 0..2 {
        dense.step().unwrap();
    }
    bench.run("dense_enumeration_iteration_10pct", Some(sub_tokens), || {
        dense.step().unwrap();
    });

    bench
        .write_csv(std::path::Path::new("results/bench_z_sampling.csv"))
        .ok();
}
