//! Bench: the doubly sparse z sweep (the hot path of Algorithm 2) vs a
//! dense-enumeration sweep — the core ablation behind eq. (29) and the
//! headline throughput of Table 2 — plus the SIMD-kernel × core-pinning
//! matrix for the multi-threaded sweep.
//!
//! Writes `BENCH_z_sampling.json` (per-case timing/throughput plus each
//! cell's phase seconds and kernel counters) next to the CSV.

mod common;

use hdp_sparse::benchkit::Bench;
use hdp_sparse::hdp::pc::PcSampler;
use hdp_sparse::hdp::{exact::ExactSampler, Trainer};
use hdp_sparse::metrics::PhaseTimers;

fn main() {
    let corpus = common::bench_corpus();
    let tokens = corpus.num_tokens() as f64;
    let mut bench = Bench::new("z_sampling");
    let mut counters: Vec<(String, f64)> = Vec::new();

    // Warm the PC sampler into a structured state first so the bench
    // measures the equilibrium sparsity pattern, not the init.
    let mut pc = PcSampler::new(corpus.clone(), common::paper_cfg(500), 1, 1).unwrap();
    for _ in 0..20 {
        pc.step().unwrap();
    }
    bench.run("pc_doubly_sparse_iteration", Some(tokens), || {
        pc.step().unwrap();
    });
    println!(
        "  mean per-token sparse work (eq.29 min-term): {:.2}; active topics {}",
        pc.mean_sparse_work(),
        pc.diagnostics().active_topics
    );
    counters.push(("mean_sparse_work".into(), pc.mean_sparse_work()));

    // SIMD × pinning matrix at the acceptance thread count. The chain
    // is bit-identical across cells (kernels are element-exact and
    // pinning only moves threads), so the cells measure pure schedule
    // and kernel cost.
    let threads: usize = std::env::var("BENCH_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    for (simd, pin) in [(false, false), (true, false), (false, true), (true, true)] {
        let cell = format!(
            "pc_t{threads}_simd_{}_pin_{}",
            if simd { "on" } else { "off" },
            if pin { "on" } else { "off" }
        );
        let mut s = PcSampler::new(corpus.clone(), common::paper_cfg(500), threads, 1).unwrap();
        s.set_simd(simd);
        let pinned = s.set_pinning(pin);
        if pin && !pinned {
            println!("  note: pinning unavailable (EPERM or no affinity); {cell} runs unpinned");
        }
        for _ in 0..10 {
            s.step().unwrap();
        }
        let steps0 = s.iterations_done();
        s.timers = PhaseTimers::new();
        bench.run(&cell, Some(tokens), || s.step().unwrap());
        let steps = (s.iterations_done() - steps0) as f64;
        counters.push((format!("{cell}/steps"), steps));
        counters.push((format!("{cell}/simd_accelerated"), f64::from(s.simd_active() as u8)));
        counters.push((format!("{cell}/pinned"), f64::from(pinned as u8)));
        for (phase, secs, _) in s.timers.rows() {
            counters.push((format!("{cell}/phase_s/{phase}"), secs));
        }
        for (name, count) in s.timers.counter_rows() {
            counters.push((format!("{cell}/counter/{name}"), count as f64));
        }
        if simd && pin {
            println!("  kernel tier in simd+pin cell: {}", s.kernel_tier());
        }
        s.set_pinning(false);
    }
    let median = |results: &[hdp_sparse::benchkit::CaseResult], name: &str| {
        results.iter().find(|c| c.name == name).map(|c| c.median()).unwrap_or(f64::NAN)
    };
    let base = median(bench.results(), &format!("pc_t{threads}_simd_off_pin_off"));
    let best = median(bench.results(), &format!("pc_t{threads}_simd_on_pin_on"));
    counters.push(("speedup_simd_pin_vs_scalar".into(), base / best));
    println!("  simd+pin speedup over scalar unpinned at t{threads}: {:.2}x", base / best);

    // Pólya-urn MH fast path vs the exact kernel at the same thread
    // count, scalar and SIMD tiers. The PPU chain is a different
    // (approximate) kernel, so it warms its own sampler; the exact
    // reference is the scalar unpinned matrix cell above. Per-phase
    // seconds ride along in the JSON so the z-only comparison is
    // recoverable next to the whole-iteration tokens/s columns.
    for simd in [false, true] {
        let cell = format!("pc_t{threads}_ppu_simd_{}", if simd { "on" } else { "off" });
        let mut s = PcSampler::new(corpus.clone(), common::paper_cfg(500), threads, 1).unwrap();
        s.set_ppu(true);
        s.set_simd(simd);
        for _ in 0..10 {
            s.step().unwrap();
        }
        let steps0 = s.iterations_done();
        s.timers = PhaseTimers::new();
        bench.run(&cell, Some(tokens), || s.step().unwrap());
        let steps = (s.iterations_done() - steps0) as f64;
        counters.push((format!("{cell}/steps"), steps));
        let swept = s.timers.counter("ppu_tokens") as f64;
        counters.push((format!("{cell}/counter/ppu_tokens"), swept));
        counters.push((
            format!("{cell}/ppu_doc_accept_rate"),
            s.timers.counter("ppu_doc_accepts") as f64 / swept.max(1.0),
        ));
        counters.push((
            format!("{cell}/ppu_word_accept_rate"),
            s.timers.counter("ppu_word_accepts") as f64 / swept.max(1.0),
        ));
        for (phase, secs, _) in s.timers.rows() {
            counters.push((format!("{cell}/phase_s/{phase}"), secs));
        }
    }
    let exact_s = median(bench.results(), &format!("pc_t{threads}_simd_off_pin_off"));
    let ppu_s = median(bench.results(), &format!("pc_t{threads}_ppu_simd_off"));
    counters.push(("exact_tokens_per_s".into(), tokens / exact_s));
    counters.push(("ppu_tokens_per_s".into(), tokens / ppu_s));
    counters.push(("speedup_ppu_vs_exact".into(), exact_s / ppu_s));
    println!(
        "  iteration tokens/s at t{threads}: exact {:.0}, ppu {:.0} ({:.2}x)",
        tokens / exact_s,
        tokens / ppu_s,
        exact_s / ppu_s
    );

    // Dense oracle at matched truncation on a slice of the corpus
    // (dense is O(N·K*); run it on a 10% subsample and scale).
    let sub = std::sync::Arc::new(hdp_sparse::corpus::Corpus {
        docs: corpus.docs[..corpus.docs.len() / 10].to_vec(),
        vocab: corpus.vocab.clone(),
    });
    let sub_tokens = sub.num_tokens() as f64;
    let mut dense = ExactSampler::new(sub, common::paper_cfg(500), 1).unwrap();
    for _ in 0..2 {
        dense.step().unwrap();
    }
    bench.run("dense_enumeration_iteration_10pct", Some(sub_tokens), || {
        dense.step().unwrap();
    });

    bench
        .write_csv(std::path::Path::new("results/bench_z_sampling.csv"))
        .ok();
    let refs: Vec<(&str, f64)> = counters.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    bench
        .write_json(std::path::Path::new("BENCH_z_sampling.json"), &refs)
        .ok();
}
