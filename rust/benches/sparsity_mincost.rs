//! Bench: eq. (29) — per-token cost tracks min(K^(m), K^(Φ)).
//!
//! Two sweeps move the two sparsity terms independently:
//! * doc-topic sparsity: generator α controls topics per document;
//! * topic-word sparsity: generator topic_beta controls words per
//!   topic (hence Φ column sizes).
//!
//! The measured mean work counter and per-token time must follow the
//! *smaller* term — the doubly sparse property.

mod common;

use hdp_sparse::benchkit::Bench;
use hdp_sparse::corpus::synthetic::HdpCorpusSpec;
use hdp_sparse::hdp::pc::PcSampler;
use hdp_sparse::hdp::Trainer;
use std::sync::Arc;

fn run_case(bench: &mut Bench, tag: &str, gen_alpha: f64, topic_beta: f64) {
    let (c, _) = HdpCorpusSpec {
        vocab: 4000,
        topics: 50,
        gamma: 6.0,
        alpha: gen_alpha,
        topic_beta,
        docs: 500,
        mean_doc_len: 80.0,
        len_sigma: 0.4,
        min_doc_len: 10,
    }
    .generate(13);
    let corpus = Arc::new(c);
    let tokens = corpus.num_tokens() as f64;
    let mut s = PcSampler::new(corpus, common::paper_cfg(400), 1, 3).unwrap();
    for _ in 0..15 {
        s.step().unwrap();
    }
    bench.run(tag, Some(tokens), || {
        s.step().unwrap();
    });
    println!(
        "  {tag}: mean min-work/token {:.2}, active topics {}",
        s.mean_sparse_work(),
        s.diagnostics().active_topics
    );
}

fn main() {
    std::env::set_var("BENCHKIT_SAMPLES", "5");
    let mut bench = Bench::new("sparsity_mincost");
    // doc-topic sparsity sweep (concentrated -> diffuse documents)
    run_case(&mut bench, "docs_concentrated_a0.3", 0.3, 0.015);
    run_case(&mut bench, "docs_medium_a1.5", 1.5, 0.015);
    run_case(&mut bench, "docs_diffuse_a8", 8.0, 0.015);
    // topic-word sparsity sweep (sharp -> broad topics)
    run_case(&mut bench, "topics_sharp_b0.005", 1.5, 0.005);
    run_case(&mut bench, "topics_broad_b0.1", 1.5, 0.1);
    bench
        .write_csv(std::path::Path::new("results/bench_sparsity_mincost.csv"))
        .ok();
}
