//! Bench: resident vs streamed vs fully out-of-core z sweeps over the
//! packed corpus arena.
//!
//! The streamed path exists for corpora whose tokens + z do not fit in
//! RAM (PubMed: 768M tokens ≈ 3 GB arena + 3 GB z). This bench
//! measures what bounding residency costs on an in-RAM corpus where
//! the comparison is honest:
//!
//! * `resident_packed` — the default sweep over the packed arena
//!   (everything hot);
//! * `streamed_nested_b*` — block-streamed sweep (per-slot z block
//!   buffers) over the resident nested assignments, two block sizes;
//! * `ooc_file_b*` — tokens *and* z served from disk
//!   ([`PackedCorpusFile`] + [`FileZ`]), the true out-of-core shape;
//! * `*_pf` — the same sweeps with the double-buffered block
//!   prefetcher on (next block's I/O submitted as a front-queued async
//!   pool job while the current block sweeps), the inline-vs-prefetch
//!   comparison; per-sweep hit/stall counts are printed alongside.
//!
//! Peak hot-z bytes per case come from the per-slot block buffers
//! ([`ShardScratch::stream_buf_bytes`]); steady-state allocation
//! behavior shows up in benchkit's `allocs/call` column (the scratch
//! counters) — a warm streamed sweep must not grow its buffers.

use hdp_sparse::benchkit::Bench;
use hdp_sparse::corpus::io::{write_packed, PackedCorpusFile};
use hdp_sparse::corpus::synthetic::HdpCorpusSpec;
use hdp_sparse::hdp::pc::zstep::{FileZ, NestedZ, ShardScratch, WordTables, ZSweep};
use hdp_sparse::hdp::pc::phi::sample_phi;
use hdp_sparse::par::{Schedule, Sharding, WorkerPool};
use hdp_sparse::rng::Pcg64;
use hdp_sparse::sparse::{DocTopics, TopicWordAcc, TopicWordRows};

const THREADS: usize = 4;
const K_MAX: usize = 48;
const ALPHA: f64 = 0.4;
const BETA: f64 = 0.03;

fn main() {
    let mut bench = Bench::new("stream_ingest");

    let (corpus, _) = HdpCorpusSpec {
        vocab: 3000,
        topics: 30,
        gamma: 4.0,
        alpha: 0.8,
        topic_beta: 0.02,
        docs: 2000,
        mean_doc_len: 60.0,
        len_sigma: 0.5,
        min_doc_len: 10,
    }
    .generate(2027);
    let packed = corpus.to_packed();
    let tokens = packed.num_tokens() as f64;
    let plan = Sharding::weighted(&corpus.doc_weights(), THREADS);
    let pool = std::sync::Arc::new(WorkerPool::new(THREADS));
    let root = Pcg64::new(41);
    let psi: Vec<f64> = vec![1.0 / K_MAX as f64; K_MAX];

    // Frozen chain state (the bench sweeps the same posterior state
    // repeatedly; iteration advances so draws differ but cost doesn't).
    let mut rng = Pcg64::new(7);
    let z0: Vec<Vec<u32>> = corpus
        .docs
        .iter()
        .map(|d| d.iter().map(|_| rng.below(16) as u32).collect())
        .collect();
    let m0: Vec<DocTopics> =
        z0.iter().map(|zd| zd.iter().copied().collect()).collect();
    let mut acc = TopicWordAcc::with_capacity(1 << 16);
    for (doc, zd) in corpus.docs.iter().zip(&z0) {
        for (&v, &k) in doc.iter().zip(zd) {
            acc.add(k, v, 1);
        }
    }
    let n = TopicWordRows::merge_from(K_MAX, &mut [acc]);
    let phi = sample_phi(&root, &n, BETA, corpus.vocab_size(), &*pool);
    let tables = WordTables::build(&phi, &psi, ALPHA, &*pool);

    let iter = std::cell::Cell::new(0u64);
    let sweep_iter = || {
        iter.set(iter.get() + 1);
        ZSweep {
            phi: &phi,
            psi: &psi,
            tables: &tables,
            alpha: ALPHA,
            k_max: K_MAX,
            kernels: Default::default(),
            seed_root: &root,
            iteration: iter.get(),
            ppu: None,
        }
    };

    let fresh_scratch =
        || -> Vec<ShardScratch> { (0..pool.slots()).map(|_| ShardScratch::new(K_MAX)).collect() };
    let peak_bytes =
        |scratch: &[ShardScratch]| scratch.iter().map(|s| s.stream_buf_bytes()).sum::<usize>();

    // --- resident reference -----------------------------------------
    let (mut z, mut m) = (z0.clone(), m0.clone());
    let mut scratch = fresh_scratch();
    bench.run("resident_packed", Some(tokens), || {
        let sweep = sweep_iter();
        sweep.run_with_scratch_sched(
            &packed,
            &mut z,
            &mut m,
            &plan,
            &*pool,
            &mut scratch,
            Schedule::Steal,
        );
    });
    println!("    resident hot-z buffer bytes: {}", peak_bytes(&scratch));

    let hit_stall = |scratch: &[ShardScratch]| {
        let h: u64 = scratch.iter().map(|s| s.out.prefetch_hits).sum();
        let st: u64 = scratch.iter().map(|s| s.out.prefetch_stalls).sum();
        (h, st)
    };

    // --- streamed over resident storage -----------------------------
    for block_docs in [16usize, 256] {
        let blocks = plan.refine(block_docs);
        let (mut z, mut m) = (z0.clone(), m0.clone());
        let mut scratch = fresh_scratch();
        bench.run(&format!("streamed_nested_b{block_docs}"), Some(tokens), || {
            let sweep = sweep_iter();
            sweep.run_streamed(
                &packed,
                &NestedZ::new(&mut z),
                &mut m,
                &blocks,
                &*pool,
                &mut scratch,
                Schedule::Steal,
            );
        });
        println!(
            "    streamed b{block_docs} hot-z buffer bytes: {} ({} blocks, {:.2}% of arena)",
            peak_bytes(&scratch),
            blocks.len(),
            100.0 * peak_bytes(&scratch) as f64 / (4.0 * tokens),
        );

        // Prefetched twin: double-buffered async block loads.
        let (mut z, mut m) = (z0.clone(), m0.clone());
        let mut scratch = fresh_scratch();
        bench.run(&format!("streamed_nested_b{block_docs}_pf"), Some(tokens), || {
            let sweep = sweep_iter();
            sweep.run_streamed_prefetched(
                &packed,
                &NestedZ::new(&mut z),
                &mut m,
                &blocks,
                &pool,
                &mut scratch,
            );
        });
        let (h, st) = hit_stall(&scratch);
        println!(
            "    streamed b{block_docs}_pf hot bytes: {} (last sweep: {h} hits / {st} stalls)",
            peak_bytes(&scratch),
        );
    }

    // --- fully out of core: tokens and z from disk -------------------
    let dir = std::env::temp_dir().join("hdp_stream_ingest_bench");
    let cpath = dir.join("corpus.hdpp");
    write_packed(&packed, &cpath).expect("write packed corpus");
    let cfile = PackedCorpusFile::open(&cpath).expect("open packed corpus");
    for block_docs in [64usize, 512] {
        let blocks = plan.refine(block_docs);
        let zfile =
            FileZ::from_nested(&dir.join(format!("z_b{block_docs}.bin")), &z0).expect("z file");
        let mut m = m0.clone();
        let mut scratch = fresh_scratch();
        bench.run(&format!("ooc_file_b{block_docs}"), Some(tokens), || {
            let sweep = sweep_iter();
            sweep.run_streamed(
                &cfile,
                &zfile,
                &mut m,
                &blocks,
                &*pool,
                &mut scratch,
                Schedule::Steal,
            );
        });
        println!(
            "    ooc b{block_docs} hot bytes (z + tokens): {} ({:.2}% of arena+z)",
            peak_bytes(&scratch),
            100.0 * peak_bytes(&scratch) as f64 / (8.0 * tokens),
        );

        // Prefetched twin: where the overlap actually pays — both the
        // token and z loads of block t+1 run while block t sweeps.
        let zfile = FileZ::from_nested(&dir.join(format!("z_b{block_docs}_pf.bin")), &z0)
            .expect("z file");
        let mut m = m0.clone();
        let mut scratch = fresh_scratch();
        bench.run(&format!("ooc_file_b{block_docs}_pf"), Some(tokens), || {
            let sweep = sweep_iter();
            sweep.run_streamed_prefetched(&cfile, &zfile, &mut m, &blocks, &pool, &mut scratch);
        });
        zfile.sync().expect("z file sync");
        let (h, st) = hit_stall(&scratch);
        println!(
            "    ooc b{block_docs}_pf hot bytes: {} (last sweep: {h} hits / {st} stalls)",
            peak_bytes(&scratch),
        );
    }

    // --- verdict -----------------------------------------------------
    let median = |name: &str| {
        bench
            .results()
            .iter()
            .find(|c| c.name == name)
            .map(|c| c.median())
            .unwrap_or(f64::NAN)
    };
    let res = median("resident_packed");
    let stream = median("streamed_nested_b256");
    let ooc = median("ooc_file_b512");
    println!(
        "\nper-sweep wall: resident {:.3} ms, streamed {:.3} ms ({:+.1}%), out-of-core {:.3} ms ({:+.1}%)",
        res * 1e3,
        stream * 1e3,
        100.0 * (stream - res) / res,
        ooc * 1e3,
        100.0 * (ooc - res) / res,
    );
    // Inline vs prefetched, per block size.
    for (inline, pf) in [
        ("streamed_nested_b16", "streamed_nested_b16_pf"),
        ("streamed_nested_b256", "streamed_nested_b256_pf"),
        ("ooc_file_b64", "ooc_file_b64_pf"),
        ("ooc_file_b512", "ooc_file_b512_pf"),
    ] {
        let (a, b) = (median(inline), median(pf));
        println!(
            "prefetch: {inline} {:.3} ms -> {pf} {:.3} ms ({:+.1}%)",
            a * 1e3,
            b * 1e3,
            100.0 * (b - a) / a,
        );
    }

    // --- resident-memory cells ---------------------------------------
    // Same accounting as `PcSampler::resident_state_bytes`: token
    // storage + z storage, per-`Vec` headers included for the nested
    // layout. The packed-only file cell keeps only the two offset
    // tables resident (tokens and z both on disk).
    let nested_corpus_bytes: u64 =
        corpus.docs.iter().map(|d| 4 * d.len() as u64 + 24).sum::<u64>() + 24;
    let nested_z_bytes: u64 =
        z0.iter().map(|zd| 4 * zd.len() as u64 + 24).sum::<u64>() + 24;
    let resident_nested = nested_corpus_bytes + nested_z_bytes;
    let arena = packed.arena_bytes();
    let packed_only_arena = arena + 4 * packed.num_tokens() + 24;
    let offsets_resident = 8 * (packed.num_docs() as u64 + 1) + 24;
    let packed_only_filez = 2 * offsets_resident;
    let reduction = |cell: u64| 100.0 * (1.0 - cell as f64 / resident_nested as f64);
    println!(
        "\nresident bytes: nested {} | packed-only arena {} ({:.1}% less) | packed-only filez {} ({:.1}% less)",
        resident_nested,
        packed_only_arena,
        reduction(packed_only_arena),
        packed_only_filez,
        reduction(packed_only_filez),
    );

    bench
        .write_json(
            std::path::Path::new("BENCH_stream_ingest.json"),
            &[
                ("resident_bytes_nested", resident_nested as f64),
                ("resident_bytes_packed_only_arena", packed_only_arena as f64),
                ("resident_bytes_packed_only_filez", packed_only_filez as f64),
                ("arena_bytes", arena as f64),
                ("filez_reduction_vs_nested_pct", reduction(packed_only_filez)),
            ],
        )
        .ok();
    bench
        .write_csv(std::path::Path::new("results/bench_stream_ingest.csv"))
        .ok();
    std::fs::remove_dir_all(&dir).ok();
}
