//! Bench: parallel-phase overheads — shard-local n accumulation +
//! merge cost vs shard count, and the weighted-sharding planner. The
//! merge is the serialization point of the data-parallel z phase; it
//! must stay a small fraction of sweep cost.

mod common;

use hdp_sparse::benchkit::Bench;
use hdp_sparse::par::Sharding;
use hdp_sparse::rng::Pcg64;
use hdp_sparse::sparse::{TopicWordAcc, TopicWordRows};

fn main() {
    let mut bench = Bench::new("shard_merge");
    let tokens = 200_000usize;
    let topics = 400u64;
    let vocab = 5000u64;
    for &shards in &[1usize, 4, 16] {
        // Pre-generate the token stream once.
        let mut rng = Pcg64::new(shards as u64);
        let stream: Vec<(u32, u32)> = (0..tokens)
            .map(|_| (rng.below(topics) as u32, rng.below(vocab) as u32))
            .collect();
        bench.run(
            &format!("accumulate_and_merge_s{shards}"),
            Some(tokens as f64),
            || {
                let mut accs: Vec<TopicWordAcc> = (0..shards)
                    .map(|_| TopicWordAcc::with_capacity(tokens / shards + 16))
                    .collect();
                for (i, &(k, v)) in stream.iter().enumerate() {
                    accs[i % shards].add(k, v, 1);
                }
                TopicWordRows::merge_from(topics as usize, &mut accs)
            },
        );
    }
    // Sharding planners.
    let mut rng = Pcg64::new(77);
    let weights: Vec<u64> = (0..100_000).map(|_| 10 + rng.below(300)).collect();
    bench.run("sharding_even_100k", Some(100_000.0), || {
        Sharding::even(weights.len(), 16)
    });
    bench.run("sharding_weighted_100k", Some(100_000.0), || {
        Sharding::weighted(&weights, 16)
    });
    bench.write_csv(std::path::Path::new("results/bench_shard_merge.csv")).ok();
}
