//! Bench: Table 2 — end-to-end per-iteration runtime of Algorithm 2 on
//! each corpus analog, with the measured tokens/s that EXPERIMENTS.md
//! extrapolates to the paper's full workloads.

mod common;

use hdp_sparse::benchkit::Bench;
use hdp_sparse::corpus::registry;
use hdp_sparse::hdp::pc::PcSampler;
use hdp_sparse::hdp::Trainer;
use std::sync::Arc;

fn main() {
    std::env::set_var("BENCHKIT_SAMPLES", "5");
    let mut bench = Bench::new("table2_runtime");
    for (name, warm) in [("ap", 15usize), ("cgcbib", 15), ("neurips", 5), ("pubmed", 3)] {
        let corpus = Arc::new(registry::load(name, 2020).expect("corpus"));
        let tokens = corpus.num_tokens() as f64;
        let k_max = if name == "pubmed" { 1000 } else { 500 };
        let mut s =
            PcSampler::new(corpus, common::paper_cfg(k_max), 1, 2020).unwrap();
        for _ in 0..warm {
            s.step().unwrap();
        }
        bench.run(&format!("pc_iteration_{name}"), Some(tokens), || {
            s.step().unwrap();
        });
        println!(
            "  {name}: active topics {}, phi nnz {}, timers:\n{}",
            s.diagnostics().active_topics,
            s.phi_nnz,
            s.timers.summary()
        );
    }
    bench.write_csv(std::path::Path::new("results/bench_table2.csv")).ok();
}
