//! Bench: Fig 1(i) — per-iteration wall-clock as the topic count
//! grows: flat for the partially collapsed sampler, increasing for the
//! subcluster split-merge baseline.

mod common;

use hdp_sparse::benchkit::fmt_time;
use hdp_sparse::corpus::synthetic::HdpCorpusSpec;
use hdp_sparse::hdp::{pc::PcSampler, ssm::SsmSampler, Trainer};
use std::sync::Arc;
use std::time::Instant;

fn main() {
    // Smaller corpus than common::bench_corpus so SSM's dense sweep
    // completes enough iterations to show its slope.
    let (c, _) = HdpCorpusSpec {
        vocab: 3000,
        topics: 40,
        gamma: 5.0,
        alpha: 0.8,
        topic_beta: 0.015,
        docs: 400,
        mean_doc_len: 80.0,
        len_sigma: 0.4,
        min_doc_len: 10,
    }
    .generate(7);
    let corpus = Arc::new(c);
    println!("== bench group: fig1_traces (per-iteration cost vs topic growth) ==");
    println!("{:>6} {:>14} {:>8}   {:>14} {:>8}", "iter", "pc_time", "pc_K", "ssm_time", "ssm_K");
    let mut pc = PcSampler::new(corpus.clone(), common::paper_cfg(500), 1, 5).unwrap();
    let mut ssm = SsmSampler::new(corpus, common::paper_cfg(500), 5).unwrap();
    let mut rows = Vec::new();
    for it in 1..=30 {
        let t0 = Instant::now();
        pc.step().unwrap();
        let pc_t = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        ssm.step().unwrap();
        let ssm_t = t0.elapsed().as_secs_f64();
        let pk = pc.diagnostics().active_topics;
        let sk = ssm.active_topics();
        if it % 3 == 0 {
            println!(
                "{it:>6} {:>14} {pk:>8}   {:>14} {sk:>8}",
                fmt_time(pc_t),
                fmt_time(ssm_t)
            );
        }
        rows.push((it, pc_t, pk, ssm_t, sk));
    }
    // Paper-shape summary: SSM slope vs PC slope across the run.
    let slope = |f: &dyn Fn(&(usize, f64, usize, f64, usize)) -> f64| {
        let first: f64 = rows[..5].iter().map(f).sum::<f64>() / 5.0;
        let last: f64 = rows[rows.len() - 5..].iter().map(f).sum::<f64>() / 5.0;
        last / first.max(1e-12)
    };
    println!(
        "\ncost growth (last5/first5): PC {:.2}x, SSM {:.2}x — paper Fig 1(i): PC flat, SSM grows",
        slope(&|r| r.1),
        slope(&|r| r.3)
    );
    // CSV
    std::fs::create_dir_all("results").ok();
    let mut csv = String::from("iter,pc_secs,pc_topics,ssm_secs,ssm_topics\n");
    for (it, a, b, c, d) in rows {
        csv.push_str(&format!("{it},{a:.6},{b},{c:.6},{d}\n"));
    }
    std::fs::write("results/bench_fig1i.csv", csv).ok();
}
