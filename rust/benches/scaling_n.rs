//! Bench: §2.8 — under Heaps-law vocabulary growth, per-token
//! iteration cost stays (near-)constant as the corpus grows; total
//! cost is linear in N. Uses the Zipf generator so the observed
//! vocabulary actually follows Heaps' law.

mod common;

use hdp_sparse::benchkit::Bench;
use hdp_sparse::corpus::synthetic::ZipfCorpusSpec;
use hdp_sparse::hdp::pc::PcSampler;
use hdp_sparse::hdp::Trainer;
use std::sync::Arc;

fn main() {
    std::env::set_var("BENCHKIT_SAMPLES", "5");
    let mut bench = Bench::new("scaling_n");
    for &docs in &[250usize, 1000, 4000] {
        let corpus = Arc::new(
            ZipfCorpusSpec {
                vocab: 60_000,
                exponent: 1.05,
                docs,
                mean_doc_len: 90.0,
                len_sigma: 0.4,
                min_doc_len: 10,
            }
            .generate(17),
        );
        let tokens = corpus.num_tokens() as f64;
        let observed_v = corpus.observed_vocab();
        let mut s = PcSampler::new(corpus, common::paper_cfg(400), 1, 4).unwrap();
        for _ in 0..10 {
            s.step().unwrap();
        }
        bench.run(&format!("pc_iteration_D{docs}"), Some(tokens), || {
            s.step().unwrap();
        });
        println!(
            "  D={docs}: N={tokens:.0}, observed V={observed_v} (Heaps), topics {}, work/token {:.2}",
            s.diagnostics().active_topics,
            s.mean_sparse_work()
        );
    }
    bench.write_csv(std::path::Path::new("results/bench_scaling_n.csv")).ok();
}
