//! Shared bench fixtures.

use hdp_sparse::config::HdpConfig;
use hdp_sparse::corpus::synthetic::HdpCorpusSpec;
use hdp_sparse::corpus::Corpus;
use std::sync::Arc;

/// A mid-size structured corpus (~120k tokens) usable by every bench
/// without multi-minute setup.
pub fn bench_corpus() -> Arc<Corpus> {
    let (c, _) = HdpCorpusSpec {
        vocab: 5000,
        topics: 60,
        gamma: 6.0,
        alpha: 0.8,
        topic_beta: 0.015,
        docs: 1200,
        mean_doc_len: 100.0,
        len_sigma: 0.5,
        min_doc_len: 10,
    }
    .generate(2024);
    Arc::new(c)
}

/// Paper hyperparameters with a given truncation.
pub fn paper_cfg(k_max: usize) -> HdpConfig {
    HdpConfig { alpha: 0.1, beta: 0.01, gamma: 1.0, k_max, init_topics: 1 }
}
