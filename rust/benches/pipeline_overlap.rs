//! Bench: barriered vs pipelined sampler iteration.
//!
//! The pipelined `PcSampler::step` submits Φ for iteration t+1 to the
//! worker pool right after the z merge of iteration t and runs the
//! serial l/Ψ tail concurrently, joining the prebuilt Φ at the start of
//! the next step. The chain is bit-identical; only the schedule
//! changes. This bench measures what that buys per iteration at
//! 1/2/4/8 threads on a synthetic corpus, and reports each mode's
//! `PhaseTimers` overlap (sum-of-phases vs critical-path wall) so the
//! hidden Φ work is visible, not just the wall-time delta. At the top
//! thread count it also runs the pipelined sampler with SIMD kernels
//! and core pinning on, the full fast-path configuration.
//!
//! Writes `BENCH_pipeline_overlap.json` with per-case throughput plus
//! per-mode phase seconds and prefetch/overlap counters.

use hdp_sparse::benchkit::Bench;
use hdp_sparse::config::HdpConfig;
use hdp_sparse::corpus::synthetic::HdpCorpusSpec;
use hdp_sparse::hdp::pc::PcSampler;
use hdp_sparse::hdp::Trainer;
use hdp_sparse::metrics::PhaseTimers;

const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 8];
const WARMUP_STEPS: usize = 3;

/// Append one sampler's phase seconds and counters under `cell/…`.
fn record(counters: &mut Vec<(String, f64)>, cell: &str, timers: &PhaseTimers, iters: f64) {
    counters.push((format!("{cell}/steps"), iters));
    for (phase, secs, _) in timers.rows() {
        counters.push((format!("{cell}/phase_s/{phase}"), secs));
    }
    counters.push((format!("{cell}/overlap_s"), timers.overlap_seconds()));
    for (name, count) in timers.counter_rows() {
        counters.push((format!("{cell}/counter/{name}"), count as f64));
    }
}

fn main() {
    let mut bench = Bench::new("pipeline_overlap");
    let mut counters: Vec<(String, f64)> = Vec::new();

    // Mid-size corpus: enough Φ/alias work per iteration for overlap to
    // matter, small enough for quick bench turnaround.
    let (corpus, _) = HdpCorpusSpec {
        vocab: 2000,
        topics: 24,
        gamma: 4.0,
        alpha: 0.8,
        topic_beta: 0.02,
        docs: 600,
        mean_doc_len: 60.0,
        len_sigma: 0.4,
        min_doc_len: 10,
    }
    .generate(2026);
    let corpus = std::sync::Arc::new(corpus);
    let tokens = corpus.num_tokens() as f64;
    let cfg = HdpConfig { alpha: 0.3, beta: 0.02, gamma: 1.0, k_max: 96, init_topics: 1 };

    let mut report: Vec<(usize, f64, f64, f64)> = Vec::new();
    for threads in THREAD_COUNTS {
        let mut barriered = PcSampler::new(corpus.clone(), cfg, threads, 7).unwrap();
        barriered.set_pipelined(false);
        let mut pipelined = PcSampler::new(corpus.clone(), cfg, threads, 7).unwrap();
        assert!(pipelined.pipelined());
        for _ in 0..WARMUP_STEPS {
            barriered.step().unwrap();
            pipelined.step().unwrap();
        }
        barriered.timers = PhaseTimers::new();
        pipelined.timers = PhaseTimers::new();
        bench.run(&format!("barriered_t{threads}"), Some(tokens), || {
            barriered.step().unwrap()
        });
        bench.run(&format!("pipelined_t{threads}"), Some(tokens), || {
            pipelined.step().unwrap()
        });
        let wall = pipelined.timers.seconds(PhaseTimers::CRITICAL_PATH);
        let overlap = pipelined.timers.overlap_seconds();
        // Timers were reset after warm-up, so only the benched steps count.
        let iters = (pipelined.iterations_done() - WARMUP_STEPS) as f64;
        record(
            &mut counters,
            &format!("barriered_t{threads}"),
            &barriered.timers,
            (barriered.iterations_done() - WARMUP_STEPS) as f64,
        );
        record(&mut counters, &format!("pipelined_t{threads}"), &pipelined.timers, iters);
        report.push((threads, wall / iters.max(1.0), overlap / iters.max(1.0), {
            let median = |name: &str| {
                bench
                    .results()
                    .iter()
                    .find(|c| c.name == name)
                    .map(|c| c.median())
                    .unwrap_or(f64::NAN)
            };
            median(&format!("barriered_t{threads}"))
                / median(&format!("pipelined_t{threads}"))
        }));
    }

    // Full fast path: pipelined + SIMD kernels + pinned workers at the
    // top thread count. Bit-identical chain; schedule/kernels only.
    let top = *THREAD_COUNTS.last().unwrap();
    let mut fast = PcSampler::new(corpus.clone(), cfg, top, 7).unwrap();
    fast.set_simd(true);
    let pinned = fast.set_pinning(true);
    for _ in 0..WARMUP_STEPS {
        fast.step().unwrap();
    }
    let steps0 = fast.iterations_done();
    fast.timers = PhaseTimers::new();
    let cell = format!("pipelined_simd_pin_t{top}");
    bench.run(&cell, Some(tokens), || fast.step().unwrap());
    record(&mut counters, &cell, &fast.timers, (fast.iterations_done() - steps0) as f64);
    counters.push((format!("{cell}/simd_accelerated"), f64::from(fast.simd_active() as u8)));
    counters.push((format!("{cell}/pinned"), f64::from(pinned as u8)));
    let median = |name: &str| {
        bench.results().iter().find(|c| c.name == name).map(|c| c.median()).unwrap_or(f64::NAN)
    };
    let fast_speedup = median(&format!("barriered_t{top}")) / median(&cell);
    counters.push(("speedup_fastpath_vs_barriered".into(), fast_speedup));
    println!(
        "  simd+pin pipelined vs barriered at t{top}: {fast_speedup:.2}x (tier {})",
        fast.kernel_tier()
    );
    fast.set_pinning(false);

    println!("\nthreads  wall/iter  overlap/iter  barriered/pipelined");
    let mut pass = true;
    for (threads, wall, overlap, speedup) in &report {
        println!(
            "{threads:>7}  {:>8.3}ms  {:>10.3}ms  {speedup:>18.2}x",
            wall * 1e3,
            overlap * 1e3
        );
        if *threads >= 4 {
            if *speedup <= 1.0 {
                pass = false;
            }
            if *overlap <= 0.0 {
                pass = false;
            }
        }
    }
    if pass {
        println!("PASS: pipelined wall/iter below barriered with nonzero overlap at ≥4 threads");
    } else {
        println!("WARN: pipelining did not pay off on this machine/corpus");
    }

    bench
        .write_csv(std::path::Path::new("results/bench_pipeline_overlap.csv"))
        .ok();
    let refs: Vec<(&str, f64)> = counters.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    bench
        .write_json(std::path::Path::new("BENCH_pipeline_overlap.json"), &refs)
        .ok();
}
