//! Bench: barriered vs pipelined sampler iteration.
//!
//! The pipelined `PcSampler::step` submits Φ for iteration t+1 to the
//! worker pool right after the z merge of iteration t and runs the
//! serial l/Ψ tail concurrently, joining the prebuilt Φ at the start of
//! the next step. The chain is bit-identical; only the schedule
//! changes. This bench measures what that buys per iteration at
//! 1/2/4/8 threads on a synthetic corpus, and reports each mode's
//! `PhaseTimers` overlap (sum-of-phases vs critical-path wall) so the
//! hidden Φ work is visible, not just the wall-time delta.

use hdp_sparse::benchkit::Bench;
use hdp_sparse::config::HdpConfig;
use hdp_sparse::corpus::synthetic::HdpCorpusSpec;
use hdp_sparse::hdp::pc::PcSampler;
use hdp_sparse::hdp::Trainer;
use hdp_sparse::metrics::PhaseTimers;

const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 8];
const WARMUP_STEPS: usize = 3;

fn main() {
    let mut bench = Bench::new("pipeline_overlap");

    // Mid-size corpus: enough Φ/alias work per iteration for overlap to
    // matter, small enough for quick bench turnaround.
    let (corpus, _) = HdpCorpusSpec {
        vocab: 2000,
        topics: 24,
        gamma: 4.0,
        alpha: 0.8,
        topic_beta: 0.02,
        docs: 600,
        mean_doc_len: 60.0,
        len_sigma: 0.4,
        min_doc_len: 10,
    }
    .generate(2026);
    let corpus = std::sync::Arc::new(corpus);
    let tokens = corpus.num_tokens() as f64;
    let cfg = HdpConfig { alpha: 0.3, beta: 0.02, gamma: 1.0, k_max: 96, init_topics: 1 };

    let mut report: Vec<(usize, f64, f64, f64)> = Vec::new();
    for threads in THREAD_COUNTS {
        let mut barriered = PcSampler::new(corpus.clone(), cfg, threads, 7).unwrap();
        barriered.set_pipelined(false);
        let mut pipelined = PcSampler::new(corpus.clone(), cfg, threads, 7).unwrap();
        assert!(pipelined.pipelined());
        for _ in 0..WARMUP_STEPS {
            barriered.step().unwrap();
            pipelined.step().unwrap();
        }
        barriered.timers = PhaseTimers::new();
        pipelined.timers = PhaseTimers::new();
        bench.run(&format!("barriered_t{threads}"), Some(tokens), || {
            barriered.step().unwrap()
        });
        bench.run(&format!("pipelined_t{threads}"), Some(tokens), || {
            pipelined.step().unwrap()
        });
        let wall = pipelined.timers.seconds(PhaseTimers::CRITICAL_PATH);
        let overlap = pipelined.timers.overlap_seconds();
        // Timers were reset after warm-up, so only the benched steps count.
        let iters = (pipelined.iterations_done() - WARMUP_STEPS) as f64;
        report.push((threads, wall / iters.max(1.0), overlap / iters.max(1.0), {
            let median = |name: &str| {
                bench
                    .results()
                    .iter()
                    .find(|c| c.name == name)
                    .map(|c| c.median())
                    .unwrap_or(f64::NAN)
            };
            median(&format!("barriered_t{threads}"))
                / median(&format!("pipelined_t{threads}"))
        }));
    }

    println!("\nthreads  wall/iter  overlap/iter  barriered/pipelined");
    let mut pass = true;
    for (threads, wall, overlap, speedup) in &report {
        println!(
            "{threads:>7}  {:>8.3}ms  {:>10.3}ms  {speedup:>18.2}x",
            wall * 1e3,
            overlap * 1e3
        );
        if *threads >= 4 {
            if *speedup <= 1.0 {
                pass = false;
            }
            if *overlap <= 0.0 {
                pass = false;
            }
        }
    }
    if pass {
        println!("PASS: pipelined wall/iter below barriered with nonzero overlap at ≥4 threads");
    } else {
        println!("WARN: pipelining did not pay off on this machine/corpus");
    }

    bench
        .write_csv(std::path::Path::new("results/bench_pipeline_overlap.csv"))
        .ok();
}
