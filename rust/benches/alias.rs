//! Bench: Walker alias tables — build cost and O(1) draws vs linear
//! categorical scan (the §2.5 bucket-(a) design choice), with a
//! scalar-vs-SIMD build comparison at each table size.
//!
//! Writes `BENCH_alias.json` with per-case throughput and the
//! per-size simd build speedups.

mod common;

use hdp_sparse::alias::AliasTable;
use hdp_sparse::benchkit::Bench;
use hdp_sparse::rng::{dist, Pcg64};
use hdp_sparse::simd::Kernels;

fn main() {
    let mut bench = Bench::new("alias");
    let mut counters: Vec<(String, f64)> = Vec::new();
    let kern = Kernels::auto();
    counters.push(("simd_accelerated".into(), f64::from(kern.is_accelerated() as u8)));
    println!("  kernel tier: {}", kern.name());
    for &k in &[16usize, 256, 4096] {
        let mut rng = Pcg64::new(k as u64);
        let weights: Vec<f64> = (0..k).map(|_| rng.f64() + 1e-3).collect();
        bench.run(&format!("build_k{k}"), Some(k as f64), || {
            AliasTable::new(&weights)
        });
        bench.run(&format!("build_simd_k{k}"), Some(k as f64), || {
            AliasTable::new_with(&weights, &kern)
        });
        let table = AliasTable::new(&weights);
        let mut r1 = Pcg64::new(1);
        bench.run(&format!("alias_draw_k{k}"), Some(1.0), || {
            table.sample(&mut r1)
        });
        let mut r2 = Pcg64::new(2);
        bench.run(&format!("linear_scan_draw_k{k}"), Some(1.0), || {
            dist::categorical(&mut r2, &weights)
        });
        // Amortized: build + N draws for the per-iteration reuse count a
        // word type sees on AP (~50 tokens/word/iteration).
        let mut r3 = Pcg64::new(3);
        bench.run(&format!("build_plus_50_draws_k{k}"), Some(50.0), || {
            let t = AliasTable::new(&weights);
            let mut acc = 0usize;
            for _ in 0..50 {
                acc += t.sample(&mut r3);
            }
            acc
        });
        let median = |name: &str| {
            bench.results().iter().find(|c| c.name == name).map(|c| c.median()).unwrap_or(f64::NAN)
        };
        counters.push((
            format!("build_simd_speedup_k{k}"),
            median(&format!("build_k{k}")) / median(&format!("build_simd_k{k}")),
        ));
    }
    bench.write_csv(std::path::Path::new("results/bench_alias.csv")).ok();
    let refs: Vec<(&str, f64)> = counters.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    bench.write_json(std::path::Path::new("BENCH_alias.json"), &refs).ok();
}
