//! Bench: persistent worker pool vs per-phase scoped thread spawning.
//!
//! On small corpora an Algorithm-2 iteration is fractions of a
//! millisecond, so the four parallel phases' thread spawn/join latency
//! (the seed substrate) dominates. The pool amortizes worker creation
//! across the whole chain and reuses per-slot shard scratch, so pooled
//! per-iteration overhead must come in strictly below the scoped
//! strategy exactly where it matters most.
//!
//! Two views:
//! * `*_noop_phase_x4` — raw dispatch cost of four empty phases
//!   (pure substrate overhead, no sampler work);
//! * `*_phase_cycle` — a faithful Φ → alias → z → merge → l iteration
//!   over a frozen small-corpus state, scoped vs pooled.

use hdp_sparse::benchkit::Bench;
use hdp_sparse::corpus::synthetic::HdpCorpusSpec;
use hdp_sparse::hdp::pc::zstep::{ShardScratch, WordTables, ZSweep};
use hdp_sparse::hdp::pc::{lstep, phi::sample_phi};
use hdp_sparse::par::{self, Sharding, WorkerPool};
use hdp_sparse::rng::Pcg64;
use hdp_sparse::sparse::{DocCountHist, DocTopics, TopicWordAcc, TopicWordRows};

const THREADS: usize = 4;
const K_MAX: usize = 64;
const ALPHA: f64 = 0.3;
const BETA: f64 = 0.05;

struct ChainState {
    z: Vec<Vec<u32>>,
    m: Vec<DocTopics>,
    n: TopicWordRows,
    iter: u64,
}

fn init_state(corpus: &hdp_sparse::corpus::Corpus) -> ChainState {
    let mut rng = Pcg64::new(17);
    let z: Vec<Vec<u32>> = corpus
        .docs
        .iter()
        .map(|d| d.iter().map(|_| rng.below(8) as u32).collect())
        .collect();
    let m: Vec<DocTopics> =
        z.iter().map(|zd| zd.iter().copied().collect()).collect();
    let mut acc = TopicWordAcc::with_capacity(4096);
    for (doc, zd) in corpus.docs.iter().zip(&z) {
        for (&v, &k) in doc.iter().zip(zd) {
            acc.add(k, v, 1);
        }
    }
    let n = TopicWordRows::merge_from(K_MAX, &mut [acc]);
    ChainState { z, m, n, iter: 0 }
}

fn main() {
    let mut bench = Bench::new("pool_overhead");

    // Small corpus: the regime where per-phase spawn latency dominates.
    let (corpus, _) = HdpCorpusSpec {
        vocab: 500,
        topics: 8,
        gamma: 2.0,
        alpha: 0.8,
        topic_beta: 0.03,
        docs: 240,
        mean_doc_len: 18.0,
        len_sigma: 0.4,
        min_doc_len: 5,
    }
    .generate(2026);
    let tokens = corpus.num_tokens() as f64;
    let plan = Sharding::weighted(&corpus.doc_weights(), THREADS);
    let root = Pcg64::new(99);
    // Uniform Ψ is fine for a frozen-state substrate bench.
    let psi: Vec<f64> = vec![1.0 / (K_MAX as f64); K_MAX];

    let pool = WorkerPool::new(THREADS);

    // --- raw dispatch: four empty phases per call -------------------
    bench.run("scoped_noop_phase_x4", Some(4.0), || {
        for _ in 0..4 {
            par::exec_for(THREADS, THREADS, |i| {
                std::hint::black_box(i);
            });
        }
    });
    bench.run("pooled_noop_phase_x4", Some(4.0), || {
        for _ in 0..4 {
            par::exec_for(&pool, THREADS, |i| {
                std::hint::black_box(i);
            });
        }
    });

    // --- faithful phase cycle: Φ → alias → z → merge → l ------------
    let mut scoped = init_state(&corpus);
    bench.run("scoped_phase_cycle", Some(tokens), || {
        scoped.iter += 1;
        let phi = sample_phi(
            &root.stream(scoped.iter ^ 0x0f1),
            &scoped.n,
            BETA,
            corpus.vocab_size(),
            THREADS,
        );
        let tables = WordTables::build(&phi, &psi, ALPHA, THREADS);
        let sweep = ZSweep {
            phi: &phi,
            psi: &psi,
            tables: &tables,
            alpha: ALPHA,
            k_max: K_MAX,
            kernels: Default::default(),
            seed_root: &root,
            iteration: scoped.iter,
            ppu: None,
        };
        let results = sweep.run(&corpus.docs, &mut scoped.z, &mut scoped.m, &plan);
        let mut accs = Vec::with_capacity(results.len());
        let mut hists = Vec::with_capacity(results.len());
        for r in results {
            accs.push(r.n_acc);
            hists.push(r.hist);
        }
        scoped.n = TopicWordRows::merge_from(K_MAX, &mut accs);
        let hist = DocCountHist::merge(K_MAX, hists);
        let l = lstep::sample_l(&root.stream(scoped.iter ^ 0x77), &hist, &psi, ALPHA, THREADS);
        std::hint::black_box(l);
    });

    let mut pooled = init_state(&corpus);
    let mut scratch: Vec<ShardScratch> = (0..pool.slots().max(plan.len()))
        .map(|_| ShardScratch::new(K_MAX))
        .collect();
    bench.run("pooled_phase_cycle", Some(tokens), || {
        pooled.iter += 1;
        let phi = sample_phi(
            &root.stream(pooled.iter ^ 0x0f1),
            &pooled.n,
            BETA,
            corpus.vocab_size(),
            &pool,
        );
        let tables = WordTables::build(&phi, &psi, ALPHA, &pool);
        let sweep = ZSweep {
            phi: &phi,
            psi: &psi,
            tables: &tables,
            alpha: ALPHA,
            k_max: K_MAX,
            kernels: Default::default(),
            seed_root: &root,
            iteration: pooled.iter,
            ppu: None,
        };
        sweep.run_with_scratch(
            &corpus.docs,
            &mut pooled.z,
            &mut pooled.m,
            &plan,
            &pool,
            &mut scratch,
        );
        pooled.n = TopicWordRows::merge_from_iter(
            K_MAX,
            scratch.iter_mut().map(|s| &mut s.out.n_acc),
        );
        let hist =
            DocCountHist::merge_mut(K_MAX, scratch.iter_mut().map(|s| &mut s.out.hist));
        let l = lstep::sample_l(&root.stream(pooled.iter ^ 0x77), &hist, &psi, ALPHA, &pool);
        std::hint::black_box(l);
    });

    // --- verdict ----------------------------------------------------
    let median = |name: &str| {
        bench
            .results()
            .iter()
            .find(|c| c.name == name)
            .map(|c| c.median())
            .unwrap_or(f64::NAN)
    };
    let noop_ratio = median("scoped_noop_phase_x4") / median("pooled_noop_phase_x4");
    let cycle_scoped = median("scoped_phase_cycle");
    let cycle_pooled = median("pooled_phase_cycle");
    println!(
        "\nnoop dispatch: pooled is {noop_ratio:.1}x cheaper than scoped spawning"
    );
    println!(
        "phase cycle:   scoped {:.3} ms vs pooled {:.3} ms per iteration ({:+.1}% change)",
        cycle_scoped * 1e3,
        cycle_pooled * 1e3,
        100.0 * (cycle_pooled - cycle_scoped) / cycle_scoped,
    );
    if cycle_pooled < cycle_scoped {
        println!("PASS: pooled per-iteration overhead is strictly below per-phase spawning");
    } else {
        println!("WARN: pooled did not beat scoped on this machine/corpus");
    }

    bench
        .write_csv(std::path::Path::new("results/bench_pool_overhead.csv"))
        .ok();
}
