//! Bench: the PJRT runtime — artifact execute latency for each
//! compiled kernel plus the tile-staging cost, i.e. the price of one
//! XLA-evaluated diagnostic pass (off the per-token hot path).

mod common;

use hdp_sparse::benchkit::Bench;
use hdp_sparse::hdp::pc::phi::sample_phi;
use hdp_sparse::rng::Pcg64;
use hdp_sparse::runtime::{phi_loglik_sparse, Engine};
use hdp_sparse::sparse::{TopicWordAcc, TopicWordRows};

fn main() {
    let dir = Engine::default_dir();
    if !dir.join("manifest.txt").exists() {
        println!("SKIP runtime_xla: no artifacts (run `make artifacts`)");
        return;
    }
    let mut engine = Engine::load(&dir).expect("engine");
    let mut bench = Bench::new("runtime_xla");
    let (tk, tv) = engine.loglik_tile_shape();

    // Raw tile execute.
    let mut rng = Pcg64::new(1);
    let n: Vec<f32> = (0..tk * tv)
        .map(|_| if rng.bernoulli(0.05) { rng.below(20) as f32 } else { 0.0 })
        .collect();
    let phi: Vec<f32> =
        n.iter().map(|&c| if c > 0.0 { 0.01 } else { 0.0 }).collect();
    bench.run("loglik_tile_execute", Some((tk * tv) as f64), || {
        engine.loglik_tile_raw(&n, &phi).unwrap()
    });

    // Full-state tiled loglik vs rust-native sparse.
    let corpus = common::bench_corpus();
    let mut acc = TopicWordAcc::with_capacity(corpus.num_tokens() as usize);
    let mut r = Pcg64::new(2);
    for doc in &corpus.docs {
        for &v in doc {
            acc.add(r.below(128) as u32, v, 1);
        }
    }
    let nrows = TopicWordRows::merge_from(512, &mut [acc]);
    let root = Pcg64::new(3);
    let phim = sample_phi(&root, &nrows, 0.01, corpus.vocab_size(), 1usize);
    let nnz = nrows.total() as f64;
    bench.run("engine_loglik_full_state", Some(nnz), || {
        engine.loglik(&nrows, &phim).unwrap()
    });
    bench.run("sparse_loglik_full_state", Some(nnz), || {
        phi_loglik_sparse(&nrows, &phim)
    });

    // zscore + psi artifacts.
    if let Some((b, k)) = engine.zscore_shape() {
        let phi_cols = vec![0.01f32; b * k];
        let m_rows = vec![0.0f32; b * k];
        let psi = vec![1.0 / k as f32; k];
        bench.run("zscore_execute", Some(b as f64), || {
            engine.zscore(&phi_cols, &m_rows, &psi, 0.1).unwrap()
        });
    }
    let sticks = vec![0.5f32; 1024];
    bench.run("psi_stick_execute", Some(1024.0), || {
        engine.psi_stick(&sticks).unwrap()
    });
    bench.write_csv(std::path::Path::new("results/bench_runtime_xla.csv")).ok();
}
