//! Bench: the Φ step — sparse Poisson Pólya urn (β-splitting) vs dense
//! PPU vs exact Dirichlet rows. The §2.5 design claim: sparse PPU cost
//! is `O(nnz + βV)` per topic, independent of the dense row size.

mod common;

use hdp_sparse::benchkit::Bench;
use hdp_sparse::hdp::pc::phi::{sample_ppu_row, sample_ppu_row_dense};
use hdp_sparse::rng::{dist, Pcg64};

fn main() {
    let mut bench = Bench::new("phi_ppu");
    let vocab = 50_000usize;
    let beta = 0.01;
    // Typical topic row: 500 nonzero words out of 50k.
    let mut rng = Pcg64::new(3);
    let mut row: Vec<(u32, u32)> = (0..500)
        .map(|_| (rng.below(vocab as u64) as u32, 1 + rng.below(30) as u32))
        .collect();
    row.sort_unstable_by_key(|&(v, _)| v);
    row.dedup_by(|a, b| {
        if a.0 == b.0 {
            b.1 += a.1;
            true
        } else {
            false
        }
    });
    let nnz = row.len() as f64;

    let mut r1 = Pcg64::new(10);
    bench.run("sparse_ppu_row_50k_vocab", Some(nnz), || {
        sample_ppu_row(&mut r1, &row, beta, vocab)
    });
    let mut r2 = Pcg64::new(11);
    bench.run("dense_ppu_row_50k_vocab", Some(nnz), || {
        sample_ppu_row_dense(&mut r2, &row, beta, vocab)
    });
    // Exact Dirichlet row (the Algorithm-1 oracle's step).
    let mut alpha_buf = vec![beta; vocab];
    for &(v, c) in &row {
        alpha_buf[v as usize] += c as f64;
    }
    let mut out = vec![0.0f64; vocab];
    let mut r3 = Pcg64::new(12);
    bench.run("exact_dirichlet_row_50k_vocab", Some(nnz), || {
        dist::dirichlet_into(&mut r3, &alpha_buf, &mut out);
    });

    // Scaling in vocab at fixed nnz: sparse should be ~flat per βV unit.
    for &v in &[10_000usize, 100_000] {
        let mut r = Pcg64::new(20 + v as u64);
        bench.run(&format!("sparse_ppu_row_vocab_{v}"), Some(nnz), || {
            sample_ppu_row(&mut r, &row, beta, v)
        });
    }
    bench.write_csv(std::path::Path::new("results/bench_phi_ppu.csv")).ok();
}
