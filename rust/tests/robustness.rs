//! Crash-safety integration tests over the public API: checksummed
//! loaders fail closed on any corruption, periodic checkpoints make a
//! training run resumable, and a chain resumed from a durable snapshot
//! is bit-identical to the uninterrupted one.
//!
//! Deterministic fault *injection* (torn writes, transient EIO) lives
//! in `tests/fault_matrix.rs` behind the `failpoints` feature; this
//! suite needs no feature — it corrupts files the honest way, with
//! `std::fs`.

use hdp_sparse::config::{HdpConfig, RunConfig};
use hdp_sparse::coordinator::{train, LoopOptions};
use hdp_sparse::corpus::io::{write_packed, PackedCorpusFile};
use hdp_sparse::corpus::synthetic::HdpCorpusSpec;
use hdp_sparse::corpus::Corpus;
use hdp_sparse::hdp::checkpoint::{latest_valid, periodic_name, Checkpoint};
use hdp_sparse::hdp::pc::PcSampler;
use hdp_sparse::hdp::Trainer;
use hdp_sparse::metrics::TraceWriter;
use hdp_sparse::par::{exec_map, WorkerPool};
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn corpus(seed: u64) -> Arc<Corpus> {
    let (c, _) = HdpCorpusSpec {
        vocab: 120,
        topics: 3,
        gamma: 1.0,
        alpha: 1.0,
        topic_beta: 0.05,
        docs: 24,
        mean_doc_len: 16.0,
        len_sigma: 0.3,
        min_doc_len: 6,
    }
    .generate(seed);
    Arc::new(c)
}

fn cfg() -> HdpConfig {
    HdpConfig { alpha: 0.5, beta: 0.05, gamma: 1.0, k_max: 24, init_topics: 1 }
}

fn run_config(iterations: usize, checkpoint_every: usize) -> RunConfig {
    RunConfig {
        iterations,
        threads: 1,
        seed: 23,
        eval_every: 4,
        time_budget_secs: 0,
        checkpoint_every,
    }
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Every strict prefix and every single-byte flip of `bytes` written
/// to `victim` must make `load` return `Err` — never panic, never a
/// silently wrong value.
fn assert_fails_closed(
    bytes: &[u8],
    victim: &Path,
    load: &dyn Fn(&Path) -> bool,
    what: &str,
) {
    for cut in 0..bytes.len() {
        std::fs::write(victim, &bytes[..cut]).unwrap();
        assert!(!load(victim), "{what}: prefix of {cut} bytes accepted");
    }
    for i in 0..bytes.len() {
        let mut bad = bytes.to_vec();
        bad[i] ^= 0x40;
        std::fs::write(victim, &bad).unwrap();
        assert!(!load(victim), "{what}: flip at byte {i} accepted");
    }
    let mut ext = bytes.to_vec();
    ext.push(0);
    std::fs::write(victim, &ext).unwrap();
    assert!(!load(victim), "{what}: extended file accepted");
}

#[test]
fn trained_checkpoint_rejects_every_truncation_and_bit_flip() {
    let c = corpus(41);
    let mut s = PcSampler::new(c, cfg(), 1, 11).unwrap();
    for _ in 0..5 {
        s.step().unwrap();
    }
    let dir = fresh_dir("hdp_robust_ckpt_sweep");
    let good = dir.join("model.ckpt");
    let ckpt = s.checkpoint();
    ckpt.save(&good).unwrap();
    assert_eq!(Checkpoint::load(&good).unwrap(), ckpt);
    let bytes = std::fs::read(&good).unwrap();
    let victim = dir.join("victim.ckpt");
    assert_fails_closed(
        &bytes,
        &victim,
        &|p| Checkpoint::load(p).is_ok(),
        "checkpoint",
    );
    // The original, untouched file still loads after the sweep.
    assert_eq!(Checkpoint::load(&good).unwrap(), ckpt);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn packed_corpus_rejects_every_truncation_and_bit_flip() {
    let c = Corpus {
        docs: vec![vec![0, 0, 2, 1], vec![1], vec![], vec![2, 1, 0]],
        vocab: vec!["alpha".into(), "beta".into(), "gamma".into()],
    };
    let dir = fresh_dir("hdp_robust_packed_sweep");
    let good = dir.join("c.hdpp");
    write_packed(&c.to_packed(), &good).unwrap();
    let f = PackedCorpusFile::open(&good).unwrap();
    assert_eq!(f.num_docs(), 4);
    assert_eq!(f.num_tokens(), 8);
    let bytes = std::fs::read(&good).unwrap();
    let victim = dir.join("victim.hdpp");
    assert_fails_closed(
        &bytes,
        &victim,
        &|p| PackedCorpusFile::open(p).is_ok(),
        "packed corpus",
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_chain_from_disk_is_bit_identical() {
    let c = corpus(91);
    let cfg = cfg();
    // The uninterrupted reference chain: 10 steps.
    let mut full = PcSampler::new(c.clone(), cfg, 2, 17).unwrap();
    for _ in 0..10 {
        full.step().unwrap();
    }
    // The interrupted chain: 6 steps, durable snapshot, then a resume
    // that round-trips through the on-disk format.
    let mut first = PcSampler::new(c.clone(), cfg, 2, 17).unwrap();
    for _ in 0..6 {
        first.step().unwrap();
    }
    let dir = fresh_dir("hdp_robust_resume_chain");
    let path = dir.join("mid.ckpt");
    first.checkpoint().save(&path).unwrap();
    drop(first);
    let loaded = Checkpoint::load(&path).unwrap();
    assert_eq!(loaded.iteration, 6);
    let mut resumed = PcSampler::resume_chain(c, cfg, 2, 17, &loaded).unwrap();
    assert_eq!(Trainer::iterations_done(&resumed), 6);
    for _ in 0..4 {
        resumed.step().unwrap();
    }
    // Recovery is bit-identical, not merely statistically equivalent.
    assert_eq!(resumed.z_nested(), full.z_nested());
    assert_eq!(resumed.psi(), full.psi());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn coordinator_periodic_checkpoints_survive_crash_debris_and_resume() {
    let c = corpus(92);
    let cfg = cfg();
    // Uninterrupted reference: 10 iterations through the coordinator.
    let mut full = PcSampler::new(c.clone(), cfg, 1, 23).unwrap();
    let mut trace = TraceWriter::in_memory();
    train(&mut full, &run_config(10, 0), &mut trace, &LoopOptions::default())
        .unwrap();
    // Interrupted run: stop after 6, checkpointing every 2 iterations.
    let dir = fresh_dir("hdp_robust_coord");
    let ckdir = dir.join("checkpoints");
    let mut first = PcSampler::new(c.clone(), cfg, 1, 23).unwrap();
    let opts = LoopOptions {
        checkpoint_dir: Some(ckdir.clone()),
        ..Default::default()
    };
    let mut trace = TraceWriter::in_memory();
    let summary =
        train(&mut first, &run_config(6, 2), &mut trace, &opts).unwrap();
    assert_eq!(summary.iterations, 6);
    assert_eq!(summary.checkpoints_written, 3);
    assert_eq!(summary.checkpoints_failed, 0);
    for it in [2u64, 4, 6] {
        assert!(ckdir.join(periodic_name(it)).is_file(), "missing ckpt {it}");
    }
    drop(first);
    // Fake the debris a mid-save crash leaves behind: a torn "newer"
    // checkpoint and an atomic-write temp partial.
    let good = std::fs::read(ckdir.join(periodic_name(6))).unwrap();
    std::fs::write(ckdir.join(periodic_name(8)), &good[..good.len() / 2]).unwrap();
    let partial = ckdir.join(".ckpt-0000000009.ckpt.321-0.tmp");
    std::fs::write(&partial, b"partial").unwrap();
    // Recovery: the scan skips the torn file, sweeps the partial, and
    // lands on the newest valid snapshot.
    let (path, ckpt) = latest_valid(&ckdir).unwrap().unwrap();
    assert_eq!(
        path.file_name().unwrap().to_str().unwrap(),
        periodic_name(6),
        "latest_valid picked the torn checkpoint"
    );
    assert_eq!(ckpt.iteration, 6);
    assert!(!partial.exists(), "temp partial not swept");
    // Resume the chain and finish the run: the coordinator continues
    // at iteration 7 and the result matches the uninterrupted chain
    // exactly.
    let mut resumed = PcSampler::resume_chain(c, cfg, 1, 23, &ckpt).unwrap();
    let mut trace = TraceWriter::in_memory();
    let summary = train(
        &mut resumed,
        &run_config(10, 0),
        &mut trace,
        &LoopOptions::default(),
    )
    .unwrap();
    assert_eq!(summary.iterations, 10);
    assert_eq!(
        trace.records().first().map(|r| r.iteration),
        Some(8),
        "resumed trace must start past the snapshot (evals at 8, 10)"
    );
    assert_eq!(resumed.z_nested(), full.z_nested());
    assert_eq!(resumed.psi(), full.psi());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resuming_a_finished_chain_is_a_no_op() {
    let c = corpus(93);
    let mut s = PcSampler::new(c, cfg(), 1, 5).unwrap();
    let mut trace = TraceWriter::in_memory();
    train(&mut s, &run_config(4, 0), &mut trace, &LoopOptions::default())
        .unwrap();
    let before = s.z_nested();
    // Asking for 4 iterations when 4 are done must run zero steps and
    // still produce a meaningful summary.
    let mut trace = TraceWriter::in_memory();
    let summary =
        train(&mut s, &run_config(4, 0), &mut trace, &LoopOptions::default())
            .unwrap();
    assert_eq!(summary.iterations, 4);
    assert!(summary.final_log_likelihood.is_finite());
    assert!(trace.records().is_empty());
    assert_eq!(s.z_nested(), before);
}

#[test]
fn worker_pool_panic_keeps_message_and_attribution_and_pool_survives() {
    let pool = WorkerPool::new(2);
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        exec_map(&pool, 16, |i| {
            if i == 7 {
                panic!("robustness-boom");
            }
            i
        })
    }))
    .expect_err("panic must propagate to the dispatching thread");
    let msg = err
        .downcast_ref::<String>()
        .expect("enriched payload is a String");
    assert!(msg.contains("robustness-boom"), "original message lost: {msg}");
    assert!(msg.contains("worker pool task"), "no attribution: {msg}");
    // The pool is still fully usable after a panicked job.
    let v = exec_map(&pool, 4, |i| i * 2);
    assert_eq!(v, vec![0, 2, 4, 6]);
}
