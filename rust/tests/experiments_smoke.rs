//! Smoke tests for the experiment drivers: each figure/table driver
//! runs end-to-end at a tiny scale and produces its output files with
//! plausible contents. (Full-scale results live in EXPERIMENTS.md.)

use hdp_sparse::experiments::{self, ExpContext};
use hdp_sparse::metrics::IterRecord;

fn ctx(tag: &str) -> ExpContext {
    let out_dir = std::env::temp_dir().join(format!("hdp_exp_smoke_{tag}"));
    std::fs::create_dir_all(&out_dir).unwrap();
    ExpContext { out_dir, scale: 0.05, threads: 1, seed: 4, verbose: false }
}

fn read_trace(path: &std::path::Path) -> Vec<IterRecord> {
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
        panic!("missing trace {}: {e}", path.display());
    });
    text.lines()
        .skip(1)
        .map(|l| IterRecord::from_csv_row(l).unwrap())
        .collect()
}

#[test]
fn table2_produces_all_rows() {
    // Use the tiny/small corpora path indirectly: table2 runs the four
    // paper corpora at scale; keep scale tiny so this finishes fast.
    let ctx = ctx("table2");
    // pubmed analog generation is the slow part (~40k docs) — still
    // fine at this scale; cache makes reruns cheap.
    experiments::table2::run(&ctx).unwrap();
    let report = std::fs::read_to_string(ctx.out_dir.join("table2.txt")).unwrap();
    for corpus in ["ap", "cgcbib", "neurips", "pubmed"] {
        assert!(report.contains(corpus), "table2 missing {corpus}");
    }
    for corpus in ["ap", "cgcbib", "neurips", "pubmed"] {
        let trace = read_trace(&ctx.out_dir.join(format!("table2_{corpus}.csv")));
        assert!(!trace.is_empty());
        assert!(trace.iter().all(|r| r.total_tokens > 0));
    }
    std::fs::remove_dir_all(&ctx.out_dir).ok();
}

#[test]
fn fig1_small_produces_traces_and_histograms() {
    let ctx = ctx("fig1small");
    experiments::fig1::run_small(&ctx).unwrap();
    for tag in ["fig1_ap_pc", "fig1_ap_da", "fig1_cgcbib_pc", "fig1_cgcbib_da"] {
        let trace = read_trace(&ctx.out_dir.join(format!("{tag}.csv")));
        assert!(trace.len() >= 2, "{tag}");
        // log-likelihoods finite and tokens conserved within a run
        let t0 = trace[0].total_tokens;
        assert!(trace.iter().all(|r| r.total_tokens == t0));
        assert!(trace.iter().all(|r| r.log_likelihood.is_finite()));
    }
    for tag in ["ap_pc", "ap_da", "cgcbib_pc", "cgcbib_da"] {
        let hist = std::fs::read_to_string(
            ctx.out_dir.join(format!("fig1_tokens_per_topic_{tag}.csv")),
        )
        .unwrap();
        assert!(hist.lines().count() >= 2, "{tag} histogram");
    }
    assert!(ctx.out_dir.join("fig1_small_report.txt").exists());
    std::fs::remove_dir_all(&ctx.out_dir).ok();
}

#[test]
fn fig1_neurips_budgeted_comparison() {
    let ctx = ctx("fig1neurips");
    experiments::fig1::run_neurips(&ctx).unwrap();
    let pc = read_trace(&ctx.out_dir.join("fig1_neurips_pc.csv"));
    let ssm = read_trace(&ctx.out_dir.join("fig1_neurips_ssm.csv"));
    assert!(!pc.is_empty() && !ssm.is_empty());
    // Paper shape (Fig 1g–i): under the same wall-clock budget the
    // doubly sparse PC sampler completes (far) more iterations than
    // the dense subcluster split-merge sampler.
    let pc_iters = pc.last().unwrap().iteration;
    let ssm_iters = ssm.last().unwrap().iteration;
    assert!(
        pc_iters > ssm_iters,
        "PC should out-iterate SSM: {pc_iters} vs {ssm_iters}"
    );
    std::fs::remove_dir_all(&ctx.out_dir).ok();
}

#[test]
fn topics_quantile_tables() {
    let ctx = ctx("topics");
    experiments::topics_exp::run(&ctx, "tiny", false).unwrap();
    let text =
        std::fs::read_to_string(ctx.out_dir.join("topics_tiny_quantiles.txt")).unwrap();
    assert!(text.contains("quantile 100%"));
    assert!(text.contains("UMass coherence"));
    experiments::topics_exp::run(&ctx, "tiny", true).unwrap();
    let all = std::fs::read_to_string(ctx.out_dir.join("topics_tiny_all.txt")).unwrap();
    assert!(all.contains("n_k="));
    std::fs::remove_dir_all(&ctx.out_dir).ok();
}
