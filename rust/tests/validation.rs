//! Numerical-validation integration tests (DESIGN.md §5): each of the
//! paper's mathematical claims is checked against a brute-force or
//! closed-form reference.

use hdp_sparse::config::HdpConfig;
use hdp_sparse::corpus::synthetic::HdpCorpusSpec;
use hdp_sparse::hdp::pc::{lstep, phi as ppu, psi, zstep};
use hdp_sparse::rng::{dist, Pcg64};
use hdp_sparse::sparse::{DocCountHist, DocTopics, PhiMatrix, TopicWordAcc, TopicWordRows};

/// Proposition 1: the FGEM stick-breaking posterior's full expectation
/// vector matches closed-form generalized-Dirichlet moments, and the
/// empirical covariance structure is consistent (variance check on a
/// non-trivial l).
#[test]
fn proposition1_moments() {
    let l = [120u64, 40, 7, 0, 2, 0];
    let gamma = 1.0;
    let want = psi::psi_posterior_mean(&l, gamma);
    let mut rng = Pcg64::new(42);
    let mut acc = vec![0.0f64; l.len()];
    let mut acc2 = vec![0.0f64; l.len()];
    let reps = 60_000;
    let mut buf = vec![0.0f64; l.len()];
    for _ in 0..reps {
        psi::sample_psi(&mut rng, &l, gamma, &mut buf);
        for i in 0..l.len() {
            acc[i] += buf[i];
            acc2[i] += buf[i] * buf[i];
        }
    }
    for i in 0..l.len() {
        let mean = acc[i] / reps as f64;
        assert!(
            (mean - want[i]).abs() < 0.005,
            "E[Ψ_{i}]: {mean} vs {}",
            want[i]
        );
        let var = acc2[i] / reps as f64 - mean * mean;
        assert!(var >= 0.0 && var < 0.05, "Var[Ψ_{i}] sane: {var}");
    }
    // ς_0 marginal: Beta(1 + l_0, γ + Σ_{i>0} l_i) ⇒ Ψ_0 = ς_0 exactly.
    let a = 1.0 + l[0] as f64;
    let b = gamma + l[1..].iter().sum::<u64>() as f64;
    let var0_want = a * b / ((a + b) * (a + b) * (a + b + 1.0));
    let var0 = acc2[0] / reps as f64 - (acc[0] / reps as f64).powi(2);
    assert!(
        (var0 - var0_want).abs() < 0.2 * var0_want,
        "Var[Ψ_0] {var0} vs {var0_want}"
    );
}

/// §2.6: the binomial-trick l sampler and the explicit eq. (26)–(27)
/// Bernoulli-sequence sampler produce the same distribution (χ² over
/// the support on a small configuration).
#[test]
fn binomial_trick_chi2_vs_explicit() {
    let counts = [3u32, 2, 4];
    let mut hist = DocCountHist::new(1);
    for &c in &counts {
        hist.record_doc(&[(0, c)]);
    }
    hist.finish();
    let (alpha, psi_k) = (0.9, 0.35);
    let reps = 60_000usize;
    let max_l = counts.iter().map(|&c| c as usize).sum::<usize>() + 1;
    let mut h_trick = vec![0usize; max_l];
    let mut h_explicit = vec![0usize; max_l];
    let mut rng = Pcg64::new(7);
    for _ in 0..reps {
        h_trick[lstep::sample_l_topic(&mut rng, &hist, 0, psi_k, alpha) as usize] += 1;
        h_explicit
            [lstep::sample_l_explicit(&mut rng, &counts, psi_k, alpha) as usize] += 1;
    }
    // two-sample χ² over bins with enough mass
    let mut chi2 = 0.0;
    let mut dof = 0usize;
    for i in 0..max_l {
        let (a, b) = (h_trick[i] as f64, h_explicit[i] as f64);
        if a + b < 20.0 {
            continue;
        }
        chi2 += (a - b) * (a - b) / (a + b);
        dof += 1;
    }
    // 99.9% for <=10 dof is < 30
    assert!(chi2 < 30.0, "chi2 {chi2} over {dof} bins");
}

/// §2.5: PPU row normalization approximates the Dirichlet posterior
/// mean for moderately large counts, and the sparse β-splitting scheme
/// is distributionally identical to dense PPU (KS-style max deviation
/// on per-word means, already unit-tested; here the full-row joint is
/// checked through the PhiMatrix path).
#[test]
fn ppu_phi_matrix_mean_matches_dirichlet() {
    let mut acc = TopicWordAcc::with_capacity(64);
    // one topic with known counts
    for (v, c) in [(0u32, 60u32), (1, 30), (2, 10)] {
        acc.add(0, v, c);
    }
    let n = TopicWordRows::merge_from(1, &mut [acc]);
    let beta = 0.5;
    let vocab = 20usize;
    let reps = 20_000;
    let mut mean = vec![0.0f64; vocab];
    for rep in 0..reps {
        let root = Pcg64::new(1000 + rep as u64);
        let phi = ppu::sample_phi(&root, &n, beta, vocab, 1usize);
        for (v, m) in mean.iter_mut().enumerate() {
            *m += phi.get(0, v as u32);
        }
    }
    let denom = vocab as f64 * beta + 100.0;
    for (v, m) in mean.iter_mut().enumerate() {
        *m /= reps as f64;
        let count = match v {
            0 => 60.0,
            1 => 30.0,
            2 => 10.0,
            _ => 0.0,
        };
        let want = (beta + count) / denom;
        assert!(
            (*m - want).abs() < 0.02 * want.max(0.05),
            "E[φ_{v}] {m} vs {want}"
        );
    }
}

/// eq. (24): per-token sparse draw distribution equals the dense
/// enumeration, verified through a χ² on repeated single-token sweeps
/// over a frozen state (complements the unit test with a bigger state
/// and the alias path exercised through both buckets).
#[test]
fn z_draw_chi2_vs_dense_enumeration() {
    // Frozen state: K=8 topics, V=30 words.
    let count_rows: Vec<Vec<(u32, u32)>> = vec![
        vec![(0, 4), (5, 2), (7, 1)],
        vec![(1, 3), (5, 5)],
        vec![(2, 2)],
        vec![(5, 1), (6, 4)],
        vec![],
        vec![(5, 3), (9, 2)],
        vec![(3, 1), (5, 1)],
        vec![(4, 2)],
    ];
    let phi = PhiMatrix::from_count_rows(30, &count_rows);
    let psi = [0.25, 0.2, 0.15, 0.12, 0.1, 0.08, 0.06, 0.04];
    let alpha = 0.8;
    let tables = zstep::WordTables::build(&phi, &psi, alpha, 1usize);
    let doc = vec![5u32, 5, 5]; // word 5 appears in many topics
    let docs = vec![doc];
    let reps = 40_000;
    let mut counts = vec![0usize; 8];
    for rep in 0..reps {
        let root = Pcg64::new(3_000_000 + rep as u64);
        let sweep = zstep::ZSweep {
            phi: &phi,
            psi: &psi,
            tables: &tables,
            alpha,
            k_max: 8,
            seed_root: &root,
            iteration: 1,
            kernels: Default::default(),
            ppu: None,
        };
        let mut z = vec![vec![1u32, 3, 5]];
        let mut m: Vec<DocTopics> = vec![z[0].iter().copied().collect()];
        let plan = hdp_sparse::par::Sharding::even(1, 1);
        sweep.run(&docs, &mut z, &mut m, &plan);
        counts[z[0][0] as usize] += 1;
    }
    // dense conditional for token 0 at its draw: m^{-0} = {3:1, 5:1}
    let mut weights = vec![0.0f64; 8];
    for k in 0..8u32 {
        let m = match k {
            3 => 1.0,
            5 => 1.0,
            _ => 0.0,
        };
        weights[k as usize] = phi.get(k, 5) * (alpha * psi[k as usize] + m);
    }
    let total: f64 = weights.iter().sum();
    let mut chi2 = 0.0;
    for k in 0..8 {
        let e = reps as f64 * weights[k] / total;
        if e < 5.0 {
            assert!(counts[k] <= 30, "k={k} should be ~never drawn");
            continue;
        }
        chi2 += (counts[k] as f64 - e).powi(2) / e;
    }
    assert!(chi2 < 30.0, "chi2 {chi2}; counts {counts:?}");
}

/// Heaps-law complexity audit (§2.8 / eq. 29): mean per-token work
/// min(K^m, K^Φ) stays far below the active topic count and roughly
/// flat as the corpus grows.
#[test]
fn per_token_work_stays_sublinear_in_topics() {
    use hdp_sparse::hdp::pc::PcSampler;
    use hdp_sparse::hdp::Trainer;
    let mut works = Vec::new();
    let mut topic_counts = Vec::new();
    for &docs in &[100usize, 400] {
        let (c, _) = HdpCorpusSpec {
            vocab: 2000,
            topics: 30,
            gamma: 5.0,
            alpha: 0.8,
            topic_beta: 0.01,
            docs,
            mean_doc_len: 60.0,
            len_sigma: 0.4,
            min_doc_len: 10,
        }
        .generate(33);
        let cfg =
            HdpConfig { alpha: 0.1, beta: 0.01, gamma: 1.0, k_max: 200, init_topics: 1 };
        let mut s = PcSampler::new(std::sync::Arc::new(c), cfg, 1, 9).unwrap();
        for _ in 0..30 {
            s.step().unwrap();
        }
        let d = s.diagnostics();
        works.push(s.mean_sparse_work());
        topic_counts.push(d.active_topics as f64);
    }
    for (w, k) in works.iter().zip(&topic_counts) {
        assert!(
            *w < 0.5 * k,
            "mean work {w:.1} should be well below active topics {k:.0}"
        );
        assert!(*w >= 1.0, "work counter should be meaningful: {w}");
    }
    // Roughly flat in corpus size: within 2.5x of each other.
    let ratio = works[1] / works[0];
    assert!(
        (0.4..2.5).contains(&ratio),
        "per-token work should not scale with corpus size: {works:?}"
    );
}

/// Distribution samplers under extreme parameters stay in-range (the
/// failure-injection sweep of DESIGN.md §5.4).
#[test]
fn distribution_samplers_extreme_params() {
    let mut rng = Pcg64::new(99);
    for _ in 0..2000 {
        let g = dist::gamma(&mut rng, 1e-3);
        assert!(g.is_finite() && g >= 0.0);
        let b = dist::beta(&mut rng, 1e-3, 1e3);
        assert!((0.0..=1.0).contains(&b));
        let p = dist::poisson(&mut rng, 1e4);
        assert!(p < 200_000);
        let bi = dist::binomial(&mut rng, 1_000_000, 1e-7);
        assert!(bi < 1000);
        let bi2 = dist::binomial(&mut rng, 3, 0.999_999);
        assert!(bi2 <= 3);
    }
}
