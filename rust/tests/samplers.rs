//! Cross-sampler integration tests: the four samplers run on the same
//! corpora and must agree on the model-level invariants, and the
//! sparse Algorithm-2 implementation must be statistically
//! indistinguishable from the dense Algorithm-1 oracle.

use hdp_sparse::config::HdpConfig;
use hdp_sparse::corpus::synthetic::HdpCorpusSpec;
use hdp_sparse::corpus::Corpus;
use hdp_sparse::hdp::{
    da::DaSampler, exact::ExactSampler, pc::PcSampler, pclda::PcLdaSampler,
    ssm::SsmSampler, Trainer,
};
use std::sync::Arc;

fn corpus(seed: u64) -> Arc<Corpus> {
    let (c, _) = HdpCorpusSpec {
        vocab: 250,
        topics: 5,
        gamma: 2.0,
        alpha: 1.0,
        topic_beta: 0.04,
        docs: 80,
        mean_doc_len: 35.0,
        len_sigma: 0.4,
        min_doc_len: 10,
    }
    .generate(seed);
    Arc::new(c)
}

fn cfg() -> HdpConfig {
    HdpConfig { alpha: 0.5, beta: 0.05, gamma: 1.0, k_max: 60, init_topics: 1 }
}

fn check_invariants(t: &dyn Trainer, expected_tokens: u64) {
    let d = t.diagnostics();
    assert_eq!(d.total_tokens, expected_tokens, "{}: token conservation", t.name());
    assert!(d.log_likelihood.is_finite(), "{}: finite ll", t.name());
    assert!(d.active_topics >= 1, "{}", t.name());
    assert_eq!(
        d.tokens_per_topic.iter().sum::<u64>(),
        expected_tokens,
        "{}: tokens_per_topic partition",
        t.name()
    );
    // descending
    assert!(
        d.tokens_per_topic.windows(2).all(|w| w[0] >= w[1]),
        "{}: sorted histogram",
        t.name()
    );
    // topic_word_rows consistent with assignments
    let rows = t.topic_word_rows();
    let total_n: u64 = rows
        .iter()
        .flat_map(|r| r.iter().map(|&(_, c)| c as u64))
        .sum();
    assert_eq!(total_n, expected_tokens, "{}: n totals", t.name());
    // rebuild n from z and compare exactly (through the view API — the
    // packed-only samplers have no nested state to borrow)
    let mut rebuilt = std::collections::HashMap::new();
    let docs = t.docs();
    let z = t.z_view();
    for d in 0..docs.num_docs() {
        for (&v, k) in docs.doc(d).iter().zip(z.doc(d).iter().copied()) {
            *rebuilt.entry((k, v)).or_insert(0u32) += 1;
        }
    }
    for (k, row) in rows.iter().enumerate() {
        for &(v, c) in row {
            assert_eq!(
                rebuilt.get(&(k as u32, v)).copied().unwrap_or(0),
                c,
                "{}: n[{k}][{v}]",
                t.name()
            );
        }
    }
}

#[test]
fn all_samplers_preserve_invariants() {
    let c = corpus(1);
    let tokens = c.num_tokens();
    let mut trainers: Vec<Box<dyn Trainer>> = vec![
        Box::new(PcSampler::new(c.clone(), cfg(), 2, 7).unwrap()),
        Box::new(DaSampler::new(c.clone(), cfg(), 7).unwrap()),
        Box::new(SsmSampler::new(c.clone(), cfg(), 7).unwrap()),
        Box::new(PcLdaSampler::new(c.clone(), 12, 0.5, 0.05, 2, 7).unwrap()),
        Box::new(ExactSampler::new(c.clone(), cfg(), 7).unwrap()),
    ];
    for t in trainers.iter_mut() {
        for _ in 0..6 {
            t.step().unwrap();
        }
        check_invariants(t.as_ref(), tokens);
        assert!(t.iterations_done() == 6);
    }
}

/// The sparse PC sampler and the dense exact oracle sample from the
/// same conditionals (PPU vs Dirichlet aside): their equilibrium
/// summary statistics must land in the same region. This is the
/// statistical-equivalence check of DESIGN.md §5.3.
#[test]
fn pc_matches_exact_oracle_statistically() {
    let (c, _truth) = HdpCorpusSpec {
        vocab: 120,
        topics: 4,
        gamma: 1.5,
        alpha: 1.5,
        topic_beta: 0.05,
        docs: 60,
        mean_doc_len: 30.0,
        len_sigma: 0.3,
        min_doc_len: 10,
    }
    .generate(77);
    let c = Arc::new(c);
    let cfg = HdpConfig { alpha: 0.5, beta: 0.1, gamma: 1.0, k_max: 24, init_topics: 1 };
    let mut pc = PcSampler::new(c.clone(), cfg, 1, 3).unwrap();
    let mut exact = ExactSampler::new(c.clone(), cfg, 3).unwrap();
    // Burn both chains to their stationary region.
    for _ in 0..250 {
        pc.step().unwrap();
        exact.step().unwrap();
    }
    let mut pc_lls = Vec::new();
    let mut ex_lls = Vec::new();
    let mut pc_topics = Vec::new();
    let mut ex_topics = Vec::new();
    for _ in 0..60 {
        pc.step().unwrap();
        exact.step().unwrap();
        let dp = pc.diagnostics();
        let de = exact.diagnostics();
        pc_lls.push(dp.log_likelihood);
        ex_lls.push(de.log_likelihood);
        pc_topics.push(dp.active_topics as f64);
        ex_topics.push(de.active_topics as f64);
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let (mp, me) = (mean(&pc_lls), mean(&ex_lls));
    let rel = (mp - me).abs() / me.abs();
    assert!(
        rel < 0.05,
        "stationary log-lik region: pc {mp:.1} vs exact {me:.1} (rel {rel:.3})"
    );
    let (tp, te) = (mean(&pc_topics), mean(&ex_topics));
    assert!(
        (tp - te).abs() < 10.0,
        "stationary topic counts: pc {tp:.1} vs exact {te:.1}"
    );
}

/// Recovery: on a strongly structured corpus the PC sampler must find
/// learned topics matching the planted ones by cosine similarity.
#[test]
fn pc_recovers_planted_topics() {
    let (c, truth) = HdpCorpusSpec {
        vocab: 400,
        topics: 6,
        gamma: 3.0,
        alpha: 0.5, // concentrated docs
        topic_beta: 0.01,
        docs: 200,
        mean_doc_len: 60.0,
        len_sigma: 0.3,
        min_doc_len: 20,
    }
    .generate(91);
    let c = Arc::new(c);
    let cfg = HdpConfig { alpha: 0.3, beta: 0.02, gamma: 1.0, k_max: 64, init_topics: 1 };
    let mut pc = PcSampler::new(c.clone(), cfg, 2, 5).unwrap();
    for _ in 0..400 {
        pc.step().unwrap();
    }
    let rows = pc.topic_word_rows();
    // learned topic distributions (significant topics only)
    let mut learned: Vec<Vec<f64>> = Vec::new();
    for row in &rows {
        let total: u64 = row.iter().map(|&(_, c)| c as u64).sum();
        if total < 200 {
            continue;
        }
        let mut dense = vec![0.0f64; c.vocab_size()];
        for &(v, cnt) in row {
            dense[v as usize] = cnt as f64 / total as f64;
        }
        learned.push(dense);
    }
    let cosine = |a: &[f64], b: &[f64]| {
        let dot: f64 = a.iter().zip(b).map(|(x, y)| x * y).sum();
        let na: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
        let nb: f64 = b.iter().map(|x| x * x).sum::<f64>().sqrt();
        dot / (na * nb).max(1e-12)
    };
    let mut planted_tokens = vec![0u64; truth.phi.len()];
    for zd in &truth.z {
        for &k in zd {
            planted_tokens[k as usize] += 1;
        }
    }
    let mut matched = 0usize;
    let mut sizable = 0usize;
    for (k, phi_k) in truth.phi.iter().enumerate() {
        if planted_tokens[k] < 500 {
            continue; // too small to be recoverable
        }
        sizable += 1;
        let best = learned.iter().map(|l| cosine(l, phi_k)).fold(0.0f64, f64::max);
        if best > 0.8 {
            matched += 1;
        }
    }
    assert!(sizable >= 3, "test corpus should have sizable topics");
    assert!(
        matched * 10 >= sizable * 8,
        "recovered {matched}/{sizable} sizable planted topics"
    );
}

/// Chains are reproducible end-to-end: same seed → identical traces,
/// different seed → different traces.
#[test]
fn chains_reproducible_per_seed() {
    let c = corpus(5);
    let run_chain = |seed: u64| {
        let mut s = PcSampler::new(c.clone(), cfg(), 2, seed).unwrap();
        for _ in 0..5 {
            s.step().unwrap();
        }
        s.diagnostics().log_likelihood
    };
    assert_eq!(run_chain(11).to_bits(), run_chain(11).to_bits());
    assert_ne!(run_chain(11).to_bits(), run_chain(12).to_bits());
}
