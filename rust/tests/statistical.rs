//! Statistical regression nets for the sampler math.
//!
//! * A cheap Geweke-style agreement check: the sparse, pooled
//!   [`PcSampler`] and the dense [`ExactSampler`] oracle sample (PPU
//!   approximation aside) the same posterior, so their post-burn-in
//!   summary statistics — active-topic count and joint log-likelihood
//!   — must agree across seeds within a generous tolerance. A broken
//!   conditional (or a pool/scratch bug that corrupts a phase) moves
//!   these means far outside the band.
//! * χ² goodness-of-fit for the Walker alias tables against their
//!   target distributions with a fixed seed and ~100k draws.

use hdp_sparse::alias::{AliasTable, SparseAlias};
use hdp_sparse::config::HdpConfig;
use hdp_sparse::corpus::synthetic::HdpCorpusSpec;
use hdp_sparse::hdp::{exact::ExactSampler, pc::PcSampler, Trainer};
use hdp_sparse::rng::Pcg64;
use std::sync::Arc;

fn mean(v: &[f64]) -> f64 {
    v.iter().sum::<f64>() / v.len().max(1) as f64
}

#[test]
fn pc_and_exact_agree_across_seeds() {
    let (c, _) = HdpCorpusSpec {
        vocab: 100,
        topics: 3,
        gamma: 1.5,
        alpha: 1.5,
        topic_beta: 0.05,
        docs: 40,
        mean_doc_len: 25.0,
        len_sigma: 0.3,
        min_doc_len: 8,
    }
    .generate(2020);
    let c = Arc::new(c);
    let cfg = HdpConfig { alpha: 0.5, beta: 0.1, gamma: 1.0, k_max: 16, init_topics: 1 };
    let (burn, keep) = (200usize, 40usize);

    let mut pc_lls = Vec::new();
    let mut ex_lls = Vec::new();
    let mut pc_topics = Vec::new();
    let mut ex_topics = Vec::new();
    for seed in [11u64, 12, 13] {
        // Pooled sparse sampler (2 threads: exercises the pool path).
        let mut pc = PcSampler::new(c.clone(), cfg, 2, seed).unwrap();
        let mut exact = ExactSampler::new(c.clone(), cfg, seed).unwrap();
        for _ in 0..burn {
            pc.step().unwrap();
            exact.step().unwrap();
        }
        for _ in 0..keep {
            pc.step().unwrap();
            exact.step().unwrap();
            let dp = pc.diagnostics();
            let de = exact.diagnostics();
            pc_lls.push(dp.log_likelihood);
            ex_lls.push(de.log_likelihood);
            pc_topics.push(dp.active_topics as f64);
            ex_topics.push(de.active_topics as f64);
        }
    }
    let (mp, me) = (mean(&pc_lls), mean(&ex_lls));
    let rel = (mp - me).abs() / me.abs();
    assert!(
        rel < 0.05,
        "stationary joint log-lik: pc {mp:.1} vs exact {me:.1} (rel {rel:.3})"
    );
    let (tp, te) = (mean(&pc_topics), mean(&ex_topics));
    assert!(
        (tp - te).abs() < 8.0,
        "stationary active-topic count: pc {tp:.1} vs exact {te:.1}"
    );
}

/// χ² of `draws` samples from `table` against `weights`; returns
/// (statistic, degrees of freedom over bins with expected count ≥ 5).
fn chi2_alias(table: &AliasTable, weights: &[f64], draws: usize, seed: u64) -> (f64, usize) {
    let mut rng = Pcg64::new(seed);
    let mut counts = vec![0u64; weights.len()];
    for _ in 0..draws {
        counts[table.sample(&mut rng)] += 1;
    }
    let total: f64 = weights.iter().sum();
    let mut chi2 = 0.0;
    let mut dof = 0usize;
    for (c, w) in counts.iter().zip(weights) {
        let e = draws as f64 * w / total;
        if e < 5.0 {
            // Rare outcomes: bound them instead of pooling into χ².
            assert!((*c as f64) < 10.0 + 10.0 * e, "rare outcome overdrawn: {c} vs e={e:.2}");
            continue;
        }
        chi2 += (*c as f64 - e).powi(2) / e;
        dof += 1;
    }
    (chi2, dof)
}

#[test]
fn alias_table_chi_square_goodness_of_fit() {
    // Mixed-magnitude weights spanning 5 orders, fixed seed, 100k
    // draws. Acceptance at mean + 5σ of the χ² distribution — loose
    // enough to be deterministic-stable, tight enough to catch a
    // mis-built table (off-by-one alias slot, unscaled probability).
    let mut weights: Vec<f64> = (1..=40)
        .map(|i| match i % 4 {
            0 => 10.0,
            1 => 1.0,
            2 => 0.1,
            _ => 0.37 * i as f64,
        })
        .collect();
    weights[7] = 0.0; // zero-mass outcome must never be drawn
    let table = AliasTable::new(&weights);
    let (chi2, dof) = chi2_alias(&table, &weights, 100_000, 0xa11a5);
    assert!(dof >= 20, "enough populated bins: {dof}");
    let bound = dof as f64 + 5.0 * (2.0 * dof as f64).sqrt();
    assert!(chi2 < bound, "chi2 {chi2:.1} over {dof} dof (bound {bound:.1})");

    // Zero-weight outcome check rides along.
    let mut rng = Pcg64::new(3);
    for _ in 0..50_000 {
        assert_ne!(table.sample(&mut rng), 7, "zero-weight outcome drawn");
    }
}

#[test]
fn sparse_alias_chi_square_on_support() {
    // SparseAlias over a scattered topic support — the exact shape the
    // bucket-(a) word tables use.
    let support: Vec<u32> = vec![3, 17, 64, 999, 1024, 4095];
    let weights = [0.05, 1.0, 2.5, 0.3, 4.0, 0.15];
    let sa = SparseAlias::new(support.clone(), &weights);
    let mut rng = Pcg64::new(0x5a11a5);
    let draws = 120_000usize;
    let mut counts = std::collections::HashMap::new();
    for _ in 0..draws {
        *counts.entry(sa.sample(&mut rng)).or_insert(0u64) += 1;
    }
    // Every drawn id must be in the support.
    assert!(counts.keys().all(|k| support.contains(k)));
    let total: f64 = weights.iter().sum();
    let mut chi2 = 0.0;
    for (id, w) in support.iter().zip(&weights) {
        let e = draws as f64 * w / total;
        let c = counts.get(id).copied().unwrap_or(0) as f64;
        chi2 += (c - e).powi(2) / e;
    }
    // 5 dof: mean 5, sd sqrt(10); allow 5σ.
    assert!(chi2 < 5.0 + 5.0 * 10.0f64.sqrt(), "chi2 {chi2:.1}");
}
