//! Statistical regression nets for the sampler math.
//!
//! * A cheap Geweke-style agreement check: the sparse, pooled
//!   [`PcSampler`] and the dense [`ExactSampler`] oracle sample (PPU
//!   approximation aside) the same posterior, so their post-burn-in
//!   summary statistics — active-topic count and joint log-likelihood
//!   — must agree across seeds within a generous tolerance. A broken
//!   conditional (or a pool/scratch bug that corrupts a phase) moves
//!   these means far outside the band.
//! * χ² goodness-of-fit for the Walker alias tables against their
//!   target distributions with a fixed seed and ~100k draws.

use hdp_sparse::alias::{AliasTable, SparseAlias};
use hdp_sparse::config::HdpConfig;
use hdp_sparse::corpus::synthetic::HdpCorpusSpec;
use hdp_sparse::corpus::Corpus;
use hdp_sparse::hdp::{exact::ExactSampler, pc::PcSampler, Trainer};
use hdp_sparse::par::Sharding;
use hdp_sparse::rng::Pcg64;
use std::sync::Arc;

fn mean(v: &[f64]) -> f64 {
    v.iter().sum::<f64>() / v.len().max(1) as f64
}

#[test]
fn pc_and_exact_agree_across_seeds() {
    let (c, _) = HdpCorpusSpec {
        vocab: 100,
        topics: 3,
        gamma: 1.5,
        alpha: 1.5,
        topic_beta: 0.05,
        docs: 40,
        mean_doc_len: 25.0,
        len_sigma: 0.3,
        min_doc_len: 8,
    }
    .generate(2020);
    let c = Arc::new(c);
    let cfg = HdpConfig { alpha: 0.5, beta: 0.1, gamma: 1.0, k_max: 16, init_topics: 1 };
    let (burn, keep) = (200usize, 40usize);

    let mut pc_lls = Vec::new();
    let mut ex_lls = Vec::new();
    let mut pc_topics = Vec::new();
    let mut ex_topics = Vec::new();
    for seed in [11u64, 12, 13] {
        // Pooled sparse sampler (2 threads: exercises the pool path).
        let mut pc = PcSampler::new(c.clone(), cfg, 2, seed).unwrap();
        let mut exact = ExactSampler::new(c.clone(), cfg, seed).unwrap();
        for _ in 0..burn {
            pc.step().unwrap();
            exact.step().unwrap();
        }
        for _ in 0..keep {
            pc.step().unwrap();
            exact.step().unwrap();
            let dp = pc.diagnostics();
            let de = exact.diagnostics();
            pc_lls.push(dp.log_likelihood);
            ex_lls.push(de.log_likelihood);
            pc_topics.push(dp.active_topics as f64);
            ex_topics.push(de.active_topics as f64);
        }
    }
    let (mp, me) = (mean(&pc_lls), mean(&ex_lls));
    let rel = (mp - me).abs() / me.abs();
    assert!(
        rel < 0.05,
        "stationary joint log-lik: pc {mp:.1} vs exact {me:.1} (rel {rel:.3})"
    );
    let (tp, te) = (mean(&pc_topics), mean(&ex_topics));
    assert!(
        (tp - te).abs() < 8.0,
        "stationary active-topic count: pc {tp:.1} vs exact {te:.1}"
    );
}

/// Streamed-vs-resident axis of the invariance matrix: streaming the z
/// phase through document blocks (out-of-core machinery: block plan,
/// per-slot hot z buffers, load/store round trips) must leave the
/// chain — z, l, and Ψ — bit-identical to the resident reference for
/// every block size {1 doc, uneven, whole corpus} × thread count
/// {1, 2, 7} × pipelining {off, on} × prefetch {off, on} (the
/// double-buffered async block loader), and must never materialize
/// more than the blocks-in-flight bound of hot z. With prefetch on,
/// every block of every sweep must be accounted exactly once in the
/// `prefetch_hits`/`prefetch_stalls` counters.
///
/// The SIMD-kernel and core-pinning axes ride along: the vectorized
/// kernels are element-exact against the scalar path and pinning only
/// moves threads, so simd {off, on} × pinning {off, on} must also
/// leave the chain bit-identical (exercised on representative cells;
/// the full blocks matrix stays on the scalar unpinned path).
#[test]
fn streamed_and_resident_chains_are_bit_identical() {
    let (c, _) = HdpCorpusSpec {
        vocab: 180,
        topics: 5,
        gamma: 2.0,
        alpha: 1.2,
        topic_beta: 0.05,
        docs: 58,
        mean_doc_len: 26.0,
        len_sigma: 0.4,
        min_doc_len: 6,
    }
    .generate(4040);
    let c = Arc::new(c);
    let cfg = HdpConfig { alpha: 0.5, beta: 0.05, gamma: 1.0, k_max: 24, init_topics: 1 };
    let steps = 4usize;

    #[derive(Clone, Copy, Debug)]
    enum Blocks {
        Resident,
        /// Refine the (weighted → uneven) doc plan to ≤ `docs` docs
        /// per block; `prefetch` turns on the double-buffered async
        /// block loader.
        Stream { docs: usize, prefetch: bool },
    }

    let run = |threads: usize, pipelined: bool, blocks: Blocks, simd: bool, pin: bool| {
        let mut s = PcSampler::new(c.clone(), cfg, threads, 616).unwrap();
        s.set_pipelined(pipelined);
        s.set_simd(simd);
        // Best-effort: degrades to unpinned when the kernel denies
        // affinity (EPERM under some sandboxes) — chain is unaffected
        // either way, which is exactly what this test certifies.
        let _ = s.set_pinning(pin);
        // A token-weighted plan gives uneven shards, hence uneven
        // blocks after refinement.
        s.set_doc_plan(Sharding::weighted(&c.doc_weights(), threads));
        if let Blocks::Stream { docs, prefetch } = blocks {
            s.set_streaming(Some(docs));
            s.set_stream_prefetch(prefetch);
            assert_eq!(s.stream_prefetch(), prefetch);
        }
        for _ in 0..steps {
            s.step().unwrap();
        }
        let hot = s.stream_buf_bytes();
        if let Blocks::Stream { prefetch, .. } = blocks {
            // Residency: hot z is bounded by slots × the largest block
            // (×2 for z+token buffers, ×2 buffer pairs when
            // prefetching, ×2 allocator slack), and the resident
            // corpus arena is never duplicated into buffers.
            let weights = c.doc_weights();
            let max_block: u64 = s
                .stream_block_plan()
                .unwrap()
                .shards()
                .iter()
                .map(|b| weights[b.start..b.end].iter().sum())
                .max()
                .unwrap();
            let pairs = if prefetch { 2 } else { 1 };
            let bound = threads * pairs * 2 * 2 * 4 * max_block as usize;
            assert!(
                hot <= bound,
                "threads={threads} blocks={blocks:?}: hot z {hot} B > bound {bound} B"
            );
            // Prefetch accounting: every block of every sweep is a hit
            // xor a stall; with prefetch off the counters stay silent.
            let accounted = s.timers.counter("prefetch_hits")
                + s.timers.counter("prefetch_stalls");
            let want = if prefetch {
                (steps * s.stream_block_plan().unwrap().len()) as u64
            } else {
                0
            };
            assert_eq!(accounted, want, "threads={threads} blocks={blocks:?}");
        } else {
            assert_eq!(hot, 0, "resident sweep must not touch block buffers");
        }
        let out = (s.z_nested(), s.l().to_vec(), s.psi().to_vec());
        s.set_pinning(false);
        out
    };

    let (z_ref, l_ref, psi_ref) = run(1, false, Blocks::Resident, false, false);
    for &threads in &[1usize, 2, 7] {
        for &pipelined in &[false, true] {
            for &blocks in &[
                Blocks::Resident,
                // one document per block
                Blocks::Stream { docs: 1, prefetch: false },
                Blocks::Stream { docs: 1, prefetch: true },
                // uneven blocks (weighted plan tails)
                Blocks::Stream { docs: 5, prefetch: false },
                Blocks::Stream { docs: 5, prefetch: true },
                // whole-corpus blocks (= shards)
                Blocks::Stream { docs: usize::MAX, prefetch: false },
                Blocks::Stream { docs: usize::MAX, prefetch: true },
            ] {
                let (z, l, psi) = run(threads, pipelined, blocks, false, false);
                let tag = format!("threads={threads} pipelined={pipelined} blocks={blocks:?}");
                assert_eq!(z, z_ref, "z diverged: {tag}");
                assert_eq!(l, l_ref, "l diverged: {tag}");
                assert_eq!(psi, psi_ref, "psi diverged: {tag}");
            }
        }
    }

    // simd × pinning cells on a pooled pipelined sampler, resident and
    // streamed+prefetched. (With the crate built without the `simd`
    // feature the on-cells dispatch to scalar and this degenerates to a
    // re-run of the baseline — still a valid, if weaker, check.)
    for &simd in &[false, true] {
        for &pin in &[false, true] {
            for &blocks in &[
                Blocks::Resident,
                Blocks::Stream { docs: 5, prefetch: true },
            ] {
                let (z, l, psi) = run(2, true, blocks, simd, pin);
                let tag = format!("simd={simd} pin={pin} blocks={blocks:?}");
                assert_eq!(z, z_ref, "z diverged: {tag}");
                assert_eq!(l, l_ref, "l diverged: {tag}");
                assert_eq!(psi, psi_ref, "psi diverged: {tag}");
            }
        }
    }
}

/// The streamed path serves PubMed-scale ingest from the packed
/// on-disk format; the chain must survive a full out-of-core round
/// trip of the *corpus* too (write → reopen → sweep from file blocks),
/// not just in-RAM block streaming. Sampler-level coverage of the
/// file-backed z store lives in `zstep`'s unit tests.
#[test]
fn packed_corpus_file_roundtrip_preserves_docs() {
    let (c, _) = HdpCorpusSpec {
        vocab: 150,
        topics: 4,
        gamma: 1.5,
        alpha: 1.0,
        topic_beta: 0.05,
        docs: 30,
        mean_doc_len: 20.0,
        len_sigma: 0.3,
        min_doc_len: 5,
    }
    .generate(777);
    let packed = c.to_packed();
    let dir = std::env::temp_dir().join("hdp_statistical_packed");
    let path = dir.join("c.hdpp");
    hdp_sparse::corpus::io::write_packed(&packed, &path).unwrap();
    let reread = hdp_sparse::corpus::io::read_packed(&path).unwrap();
    let nested: Corpus = reread.to_nested();
    assert_eq!(nested.docs, c.docs);
    assert_eq!(nested.vocab, c.vocab);
    std::fs::remove_dir_all(&dir).ok();
}

/// The packed-only cells of the invariance matrix: chains with no
/// nested corpus or z resident — z in the flat arena
/// ([`PcSampler::from_packed`]) or spilled to the file-backed store,
/// token blocks from the resident arena or from the `.hdpp` file
/// opened with positioned reads (pread) or the mmap binding — must be
/// bit-identical to the nested-resident reference, across threads ×
/// pipelining × streaming/prefetch. Layout is a pure representation
/// choice; the chain never sees it.
#[test]
fn packed_only_chains_match_resident_across_mmap_and_pread() {
    use hdp_sparse::corpus::io::{write_packed, PackedCorpusFile};
    let (c, _) = HdpCorpusSpec {
        vocab: 180,
        topics: 5,
        gamma: 2.0,
        alpha: 1.2,
        topic_beta: 0.05,
        docs: 58,
        mean_doc_len: 26.0,
        len_sigma: 0.4,
        min_doc_len: 6,
    }
    .generate(4040);
    let c = Arc::new(c);
    let cfg = HdpConfig { alpha: 0.5, beta: 0.05, gamma: 1.0, k_max: 24, init_topics: 1 };
    let steps = 4usize;
    let packed = Arc::new(c.to_packed());
    let dir = std::env::temp_dir().join("hdp_statistical_packed_only");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let cpath = dir.join("c.hdpp");
    write_packed(&packed, &cpath).unwrap();
    // What the nested layouts would have kept resident — every
    // packed-only cell must sit strictly below it.
    let nested_corpus_bytes: u64 =
        c.docs.iter().map(|d| 4 * d.len() as u64 + 24).sum::<u64>() + 24;
    let nested_state_bytes = 2 * nested_corpus_bytes;

    // Nested-resident reference chain (same seed, same config).
    let (z_ref, l_ref, psi_ref) = {
        let mut s = PcSampler::new(c.clone(), cfg, 2, 616).unwrap();
        assert_eq!(s.z_mode(), "nested");
        for _ in 0..steps {
            s.step().unwrap();
        }
        (s.z_nested(), s.l().to_vec(), s.psi().to_vec())
    };

    #[derive(Clone, Copy, Debug)]
    enum Tok {
        Resident,
        Pread,
        Mmap,
    }
    let mut cell = 0usize;
    for &threads in &[1usize, 3] {
        for &pipelined in &[false, true] {
            for &zfile in &[false, true] {
                for &tok in &[Tok::Resident, Tok::Pread, Tok::Mmap] {
                    for &stream in &[None, Some(5usize)] {
                        cell += 1;
                        let mut s =
                            PcSampler::from_packed(packed.clone(), cfg, threads, 616)
                                .unwrap();
                        assert_eq!(s.z_mode(), "arena");
                        s.set_pipelined(pipelined);
                        if zfile {
                            s.move_z_to_file(&dir.join(format!("z{cell}.bin")))
                                .unwrap();
                            assert_eq!(s.z_mode(), "file");
                        }
                        match tok {
                            Tok::Resident => {}
                            Tok::Pread => {
                                let f = PackedCorpusFile::open(&cpath).unwrap();
                                assert!(!f.mmap_active(), "open() must not map");
                                s.set_token_file(Some(Arc::new(f)));
                            }
                            Tok::Mmap => {
                                // On non-linux (or a failed map) this
                                // silently falls back to pread — the
                                // chain must not care either way.
                                let f = PackedCorpusFile::open_mmap(&cpath).unwrap();
                                s.set_token_file(Some(Arc::new(f)));
                            }
                        }
                        if let Some(docs) = stream {
                            s.set_streaming(Some(docs));
                            s.set_stream_prefetch(true);
                        }
                        for _ in 0..steps {
                            s.step().unwrap();
                        }
                        let tag = format!(
                            "threads={threads} pipelined={pipelined} zfile={zfile} tok={tok:?} stream={stream:?}"
                        );
                        assert_eq!(s.z_nested(), z_ref, "z diverged: {tag}");
                        assert_eq!(s.l(), &l_ref[..], "l diverged: {tag}");
                        assert_eq!(s.psi(), &psi_ref[..], "psi diverged: {tag}");
                        // The tentpole residency claim: the z store
                        // never inflated back to nested, and the cell's
                        // resident state sits below what nested
                        // corpus + nested z would have held.
                        assert_eq!(s.z_mode(), if zfile { "file" } else { "arena" });
                        assert!(
                            s.resident_state_bytes() < nested_state_bytes,
                            "{tag}: resident {} B >= nested {} B",
                            s.resident_state_bytes(),
                            nested_state_bytes
                        );
                    }
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// χ² of `draws` samples from `table` against `weights`; returns
/// (statistic, degrees of freedom over bins with expected count ≥ 5).
fn chi2_alias(table: &AliasTable, weights: &[f64], draws: usize, seed: u64) -> (f64, usize) {
    let mut rng = Pcg64::new(seed);
    let mut counts = vec![0u64; weights.len()];
    for _ in 0..draws {
        counts[table.sample(&mut rng)] += 1;
    }
    let total: f64 = weights.iter().sum();
    let mut chi2 = 0.0;
    let mut dof = 0usize;
    for (c, w) in counts.iter().zip(weights) {
        let e = draws as f64 * w / total;
        if e < 5.0 {
            // Rare outcomes: bound them instead of pooling into χ².
            assert!((*c as f64) < 10.0 + 10.0 * e, "rare outcome overdrawn: {c} vs e={e:.2}");
            continue;
        }
        chi2 += (*c as f64 - e).powi(2) / e;
        dof += 1;
    }
    (chi2, dof)
}

#[test]
fn alias_table_chi_square_goodness_of_fit() {
    // Mixed-magnitude weights spanning 5 orders, fixed seed, 100k
    // draws. Acceptance at mean + 5σ of the χ² distribution — loose
    // enough to be deterministic-stable, tight enough to catch a
    // mis-built table (off-by-one alias slot, unscaled probability).
    let mut weights: Vec<f64> = (1..=40)
        .map(|i| match i % 4 {
            0 => 10.0,
            1 => 1.0,
            2 => 0.1,
            _ => 0.37 * i as f64,
        })
        .collect();
    weights[7] = 0.0; // zero-mass outcome must never be drawn
    let table = AliasTable::new(&weights);
    let (chi2, dof) = chi2_alias(&table, &weights, 100_000, 0xa11a5);
    assert!(dof >= 20, "enough populated bins: {dof}");
    let bound = dof as f64 + 5.0 * (2.0 * dof as f64).sqrt();
    assert!(chi2 < bound, "chi2 {chi2:.1} over {dof} dof (bound {bound:.1})");

    // Zero-weight outcome check rides along.
    let mut rng = Pcg64::new(3);
    for _ in 0..50_000 {
        assert_ne!(table.sample(&mut rng), 7, "zero-weight outcome drawn");
    }
}

#[test]
fn sparse_alias_chi_square_on_support() {
    // SparseAlias over a scattered topic support — the exact shape the
    // bucket-(a) word tables use.
    let support: Vec<u32> = vec![3, 17, 64, 999, 1024, 4095];
    let weights = [0.05, 1.0, 2.5, 0.3, 4.0, 0.15];
    let sa = SparseAlias::new(support.clone(), &weights);
    let mut rng = Pcg64::new(0x5a11a5);
    let draws = 120_000usize;
    let mut counts = std::collections::HashMap::new();
    for _ in 0..draws {
        *counts.entry(sa.sample(&mut rng)).or_insert(0u64) += 1;
    }
    // Every drawn id must be in the support.
    assert!(counts.keys().all(|k| support.contains(k)));
    let total: f64 = weights.iter().sum();
    let mut chi2 = 0.0;
    for (id, w) in support.iter().zip(&weights) {
        let e = draws as f64 * w / total;
        let c = counts.get(id).copied().unwrap_or(0) as f64;
        chi2 += (c - e).powi(2) / e;
    }
    // 5 dof: mean 5, sd sqrt(10); allow 5σ.
    assert!(chi2 < 5.0 + 5.0 * 10.0f64.sqrt(), "chi2 {chi2:.1}");
}

/// Fixture shared by the serving-agreement tests: a trained PC-HDP
/// model plus its corpus and config.
fn serving_fixture() -> (Arc<Corpus>, HdpConfig, PcSampler) {
    let (c, _) = HdpCorpusSpec {
        vocab: 200,
        topics: 4,
        gamma: 2.0,
        alpha: 0.8,
        topic_beta: 0.05,
        docs: 70,
        mean_doc_len: 28.0,
        len_sigma: 0.3,
        min_doc_len: 10,
    }
    .generate(909);
    let c = Arc::new(c);
    let cfg = HdpConfig { alpha: 0.3, beta: 0.05, gamma: 1.0, k_max: 14, init_topics: 1 };
    let mut s = PcSampler::new(c.clone(), cfg, 2, 31).unwrap();
    for _ in 0..20 {
        s.step().unwrap();
    }
    (c, cfg, s)
}

/// Completion-mode requests through the [`Server`] agree *bit-for-bit*
/// with `document_completion` run directly against the same frozen
/// snapshot: same derived seed → identical per-document log-likelihood
/// accumulation, scored and skipped counts, and perplexity bits.
#[test]
fn server_matches_document_completion() {
    use hdp_sparse::diagnostics::heldout;
    use hdp_sparse::serve::{
        request_seed, InferMode, InferRequest, ModelSnapshot, Server,
    };
    let (c, _cfg, s) = serving_fixture();
    let server = Server::new(s.pool_handle(), ModelSnapshot::from_pc(&s, 55));
    let snap = server.snapshot();
    let (_, test) = heldout::train_test_split(c.num_docs(), 0.4, 21);
    let passes = 3usize;
    let base_seed = 4242u64;
    let reqs: Vec<InferRequest> = test
        .iter()
        .map(|&d| InferRequest {
            id: d as u64,
            tokens: c.docs[d].clone(),
            seed: base_seed,
            passes,
            mode: InferMode::Completion,
        })
        .collect();
    let responses = server.serve_batch(&reqs);
    assert_eq!(responses.len(), test.len());
    let mut agree = 0usize;
    for (resp, &d) in responses.iter().zip(&test) {
        // The server's RNG stream is pinned to (seed, id, generation);
        // reconstruct it and run the heldout evaluator on just this
        // document against the same frozen (Φ̂, Ψ).
        let derived = request_seed(base_seed, d as u64, resp.generation);
        let direct = heldout::document_completion(
            &*c,
            &[d],
            snap.phi(),
            snap.psi(),
            snap.alpha(),
            passes,
            derived,
        );
        assert_eq!(resp.tokens_scored, direct.tokens, "doc {d}: scored");
        assert_eq!(resp.tokens_skipped, direct.skipped, "doc {d}: skipped");
        // Mirror `document_completion`'s empty-set contract: zero
        // scored tokens has no defined perplexity (NaN), never a
        // silently "perfect" exp(0) = 1.0.
        let resp_ppx = if resp.tokens_scored == 0 {
            f64::NAN
        } else {
            (-resp.log_likelihood / resp.tokens_scored as f64).exp()
        };
        assert_eq!(
            resp_ppx.to_bits(),
            direct.perplexity.to_bits(),
            "doc {d}: perplexity bits"
        );
        if resp.tokens_scored > 0 {
            agree += 1;
        }
    }
    assert!(agree > test.len() / 2, "most held-out docs must score");
}

/// The dense fold-in scan and the alias-table two-bucket fold-in
/// ([`InferMode::Mixture`] vs [`InferMode::SparseMixture`]) implement
/// the *same* per-token conditional, so pooled topic-assignment counts
/// over many seeded runs must agree: small L1 distance between the
/// pooled distributions and a χ²-style two-sample statistic far below
/// the gross-mismatch regime. (They consume randomness differently, so
/// agreement is distributional, not bitwise.)
#[test]
fn sparse_and_dense_fold_in_agree() {
    use hdp_sparse::serve::{InferMode, InferRequest, ModelSnapshot};
    let (c, _cfg, s) = serving_fixture();
    let snap = ModelSnapshot::from_pc(&s, 66);
    let k = snap.k_max();
    let docs = [0usize, 3, 7, 11];
    let runs_per_doc = 100u64;
    let mut dense = vec![0u64; k];
    let mut sparse = vec![0u64; k];
    for (pool, mode) in [
        (&mut dense, InferMode::Mixture),
        (&mut sparse, InferMode::SparseMixture),
    ] {
        for &d in &docs {
            for r in 0..runs_per_doc {
                let resp = snap.infer(&InferRequest {
                    id: (d as u64) << 32 | r,
                    tokens: c.docs[d].clone(),
                    seed: 777 + r,
                    passes: 5,
                    mode,
                });
                for &(kk, cnt) in &resp.topic_counts {
                    pool[kk as usize] += cnt as u64;
                }
            }
        }
    }
    let (da, db) = (
        dense.iter().sum::<u64>() as f64,
        sparse.iter().sum::<u64>() as f64,
    );
    // Both modes fold in every token of every run, so the pooled
    // totals are identical by construction.
    assert_eq!(da, db, "pooled token totals");
    let mut l1 = 0.0f64;
    let mut chi2 = 0.0f64;
    let mut df = 0usize;
    for (&a, &b) in dense.iter().zip(&sparse) {
        l1 += (a as f64 / da - b as f64 / db).abs();
        if a + b > 0 {
            let (af, bf) = (a as f64, b as f64);
            chi2 += (af - bf).powi(2) / (af + bf);
            df += 1;
        }
    }
    // Within-document token assignments are correlated, so these
    // bounds are deliberately loose: a broken conditional (wrong
    // bucket split, unnormalized weights, mis-indexed alias column)
    // lands orders of magnitude outside them.
    assert!(l1 < 0.25, "pooled L1 {l1:.3} (dense {dense:?} sparse {sparse:?})");
    let bound = 200.0 * (df as f64 + 1.0);
    assert!(chi2 < bound, "chi2 {chi2:.1} over {df} topics (bound {bound:.0})");
}

/// The Pólya-urn MH z sweep (`PcSampler::set_ppu`) is a different —
/// but still valid — MCMC kernel for the same per-token conditional,
/// so its *stationary* behaviour must agree with the exact chain
/// across seeds even though the trajectories diverge: joint
/// log-likelihood and active-topic means within tolerance, held-out
/// document-completion perplexity within a relative band, and pooled
/// sorted topic-size profiles close in L1/χ².
#[test]
fn ppu_and_exact_chains_agree_across_seeds() {
    use hdp_sparse::diagnostics::heldout;
    use hdp_sparse::serve::ModelSnapshot;
    let (c, _) = HdpCorpusSpec {
        vocab: 100,
        topics: 3,
        gamma: 1.5,
        alpha: 1.5,
        topic_beta: 0.05,
        docs: 40,
        mean_doc_len: 25.0,
        len_sigma: 0.3,
        min_doc_len: 8,
    }
    .generate(2021);
    let c = Arc::new(c);
    let cfg = HdpConfig { alpha: 0.5, beta: 0.1, gamma: 1.0, k_max: 16, init_topics: 1 };
    let (burn, keep) = (200usize, 40usize);
    let (_, test) = heldout::train_test_split(c.num_docs(), 0.3, 5150);

    let mut lls = [Vec::new(), Vec::new()];
    let mut topics = [Vec::new(), Vec::new()];
    let mut ppx = [Vec::new(), Vec::new()];
    // Pooled (over seeds) sorted topic-size profiles, one per kernel:
    // topic identities aren't aligned across chains, the *profile* is
    // the comparable statistic.
    let mut profiles = [vec![0u64; cfg.k_max], vec![0u64; cfg.k_max]];
    for seed in [21u64, 22, 23] {
        for (which, use_ppu) in [(0usize, false), (1usize, true)] {
            let mut s = PcSampler::new(c.clone(), cfg, 2, seed).unwrap();
            s.set_ppu(use_ppu);
            assert_eq!(s.ppu(), use_ppu);
            for _ in 0..burn {
                s.step().unwrap();
            }
            for _ in 0..keep {
                s.step().unwrap();
                let d = s.diagnostics();
                lls[which].push(d.log_likelihood);
                topics[which].push(d.active_topics as f64);
            }
            if use_ppu {
                // The fast path must actually have run (and its MH
                // moves must both fire), not silently fall back to
                // the exact kernel.
                assert!(s.timers.counter("ppu_tokens") > 0, "seed {seed}: ppu ran");
                assert!(
                    s.timers.counter("ppu_doc_accepts") > 0
                        && s.timers.counter("ppu_word_accepts") > 0,
                    "seed {seed}: both MH proposals must accept sometimes"
                );
            } else {
                assert_eq!(s.timers.counter("ppu_tokens"), 0);
            }
            // Held-out document-completion perplexity against the
            // frozen final state.
            let snap = ModelSnapshot::from_pc(&s, 77);
            let r = heldout::document_completion(
                &*c,
                &test,
                snap.phi(),
                snap.psi(),
                snap.alpha(),
                3,
                9090,
            );
            assert!(r.tokens > 0, "held-out split must score tokens");
            ppx[which].push(r.perplexity);
            let mut sizes = vec![0u64; cfg.k_max];
            for zd in s.z_nested() {
                for k in zd {
                    sizes[k as usize] += 1;
                }
            }
            sizes.sort_unstable_by(|a, b| b.cmp(a));
            for (p, sz) in profiles[which].iter_mut().zip(&sizes) {
                *p += sz;
            }
        }
    }
    let (me, mp) = (mean(&lls[0]), mean(&lls[1]));
    let rel = (mp - me).abs() / me.abs();
    assert!(rel < 0.05, "stationary joint log-lik: exact {me:.1} vs ppu {mp:.1} (rel {rel:.3})");
    let (te, tp) = (mean(&topics[0]), mean(&topics[1]));
    assert!((tp - te).abs() < 8.0, "stationary active-topic count: exact {te:.1} vs ppu {tp:.1}");
    let (pe, pp) = (mean(&ppx[0]), mean(&ppx[1]));
    let prel = (pp - pe).abs() / pe;
    assert!(
        prel < 0.15,
        "held-out doc-completion perplexity: exact {pe:.1} vs ppu {pp:.1} (rel {prel:.3})"
    );
    // Pooled profile agreement: L1 over the normalized sorted
    // topic-size distributions + a two-sample χ²-style statistic.
    let se = profiles[0].iter().sum::<u64>() as f64;
    let sp = profiles[1].iter().sum::<u64>() as f64;
    assert_eq!(se, sp, "both chains assign every token every sweep");
    let mut l1 = 0.0f64;
    let mut chi2 = 0.0f64;
    let mut df = 0usize;
    for (&a, &b) in profiles[0].iter().zip(&profiles[1]) {
        l1 += (a as f64 / se - b as f64 / sp).abs();
        if a + b > 0 {
            let (af, bf) = (a as f64, b as f64);
            chi2 += (af - bf).powi(2) / (af + bf);
            df += 1;
        }
    }
    assert!(
        l1 < 0.25,
        "pooled topic-size L1 {l1:.3} (exact {:?} ppu {:?})",
        profiles[0],
        profiles[1]
    );
    let bound = 200.0 * (df as f64 + 1.0);
    assert!(chi2 < bound, "profile chi2 {chi2:.1} over {df} bins (bound {bound:.0})");
}

/// The PPU chain diverges from the exact chain, but it must be just as
/// *deterministic*: for a fixed seed the z/l/Ψ state after any number
/// of sweeps is bit-identical across thread counts, pipelining,
/// streaming (with and without prefetch), and the SIMD kernel tiers —
/// all randomness flows through the same per-(iteration, doc) streams.
/// It must also differ from the exact chain (the fast path actually
/// engaged).
#[test]
fn ppu_chain_is_bit_identical_across_drivers() {
    let (c, _) = HdpCorpusSpec {
        vocab: 180,
        topics: 5,
        gamma: 2.0,
        alpha: 1.2,
        topic_beta: 0.05,
        docs: 58,
        mean_doc_len: 26.0,
        len_sigma: 0.4,
        min_doc_len: 6,
    }
    .generate(4141);
    let c = Arc::new(c);
    let cfg = HdpConfig { alpha: 0.5, beta: 0.05, gamma: 1.0, k_max: 24, init_topics: 1 };
    let steps = 4usize;

    #[derive(Clone, Copy, Debug)]
    enum Blocks {
        Resident,
        Stream { docs: usize, prefetch: bool },
    }

    let run = |ppu: bool, threads: usize, pipelined: bool, blocks: Blocks, simd: bool| {
        let mut s = PcSampler::new(c.clone(), cfg, threads, 616).unwrap();
        s.set_ppu(ppu);
        s.set_pipelined(pipelined);
        s.set_simd(simd);
        s.set_doc_plan(Sharding::weighted(&c.doc_weights(), threads));
        if let Blocks::Stream { docs, prefetch } = blocks {
            s.set_streaming(Some(docs));
            s.set_stream_prefetch(prefetch);
        }
        for _ in 0..steps {
            s.step().unwrap();
        }
        (s.z_nested(), s.l().to_vec(), s.psi().to_vec())
    };

    let (z_ref, l_ref, psi_ref) = run(true, 1, false, Blocks::Resident, false);
    let (z_exact, ..) = run(false, 1, false, Blocks::Resident, false);
    assert_ne!(z_ref, z_exact, "ppu chain must actually diverge from the exact kernel");
    for &threads in &[1usize, 2, 7] {
        for &pipelined in &[false, true] {
            for &blocks in &[
                Blocks::Resident,
                Blocks::Stream { docs: 1, prefetch: false },
                Blocks::Stream { docs: 5, prefetch: true },
                Blocks::Stream { docs: usize::MAX, prefetch: false },
            ] {
                let (z, l, psi) = run(true, threads, pipelined, blocks, false);
                let tag = format!("threads={threads} pipelined={pipelined} blocks={blocks:?}");
                assert_eq!(z, z_ref, "ppu z diverged: {tag}");
                assert_eq!(l, l_ref, "ppu l diverged: {tag}");
                assert_eq!(psi, psi_ref, "ppu psi diverged: {tag}");
            }
        }
    }
    // SIMD axis (dispatches to scalar without the `simd` feature —
    // still a valid, if weaker, re-run of a matrix cell).
    for &blocks in &[Blocks::Resident, Blocks::Stream { docs: 5, prefetch: true }] {
        let (z, l, psi) = run(true, 2, true, blocks, true);
        assert_eq!(z, z_ref, "ppu z diverged under simd: {blocks:?}");
        assert_eq!(l, l_ref, "ppu l diverged under simd: {blocks:?}");
        assert_eq!(psi, psi_ref, "ppu psi diverged under simd: {blocks:?}");
    }
}
