//! Integration tests for the PJRT runtime: load the AOT artifacts
//! produced by `make artifacts`, execute them, and cross-check numbers
//! against rust-native computations and python-derived golden values.
//!
//! These tests are skipped (with a visible message) when artifacts are
//! missing, so `cargo test` works before the python step; `make test`
//! always builds artifacts first.

use hdp_sparse::corpus::synthetic::HdpCorpusSpec;
use hdp_sparse::hdp::pc::phi::sample_phi;
use hdp_sparse::rng::Pcg64;
use hdp_sparse::runtime::{phi_loglik_sparse, Engine};
use hdp_sparse::sparse::{TopicWordAcc, TopicWordRows};

fn engine() -> Option<Engine> {
    let dir = Engine::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Engine::load(&dir).expect("engine load"))
}

#[test]
fn loads_all_artifacts() {
    let Some(e) = engine() else { return };
    let mut names = e.artifact_names();
    names.sort();
    assert_eq!(names, vec!["loglik_tile", "psi_stick", "zscore_tile"]);
    let (tk, tv) = e.loglik_tile_shape();
    assert!(tk >= 128 && tv >= 512);
}

#[test]
fn loglik_tile_matches_python_golden() {
    // Mirror of python/tests/test_aot.py::test_loglik_golden — the
    // same deterministic stripe pattern must evaluate to the same
    // closed-form value through the compiled artifact.
    let Some(e) = engine() else { return };
    let (tk, tv) = e.loglik_tile_shape();
    let mut n = vec![0.0f32; tk * tv];
    let mut phi = vec![0.0f32; tk * tv];
    let mut want = 0.0f64;
    for i in 0..tk {
        let v = (i * 7) % tv;
        let c = (i % 5 + 1) as f32;
        n[i * tv + v] = c;
        phi[i * tv + v] = 0.25;
        phi[i * tv + (i * 11 + 1) % tv] += 0.75;
        want += c as f64 * 0.25f64.ln();
    }
    let got = e.loglik_tile_raw(&n, &phi).unwrap() as f64;
    assert!(
        (got - want).abs() < 1e-2 * want.abs().max(1.0),
        "{got} vs {want}"
    );
}

#[test]
fn engine_loglik_matches_sparse_reference() {
    // Random sparse model state: the tiled XLA path and the rust-native
    // sparse path must agree to f32 tolerance.
    let Some(mut e) = engine() else { return };
    let (corpus, _) = HdpCorpusSpec {
        vocab: 1500, // forces multiple V tiles
        topics: 10,
        gamma: 3.0,
        alpha: 1.0,
        topic_beta: 0.03,
        docs: 150,
        mean_doc_len: 60.0,
        len_sigma: 0.4,
        min_doc_len: 10,
    }
    .generate(17);
    let k_max = 300; // forces multiple K tiles
    let mut rng = Pcg64::new(5);
    let mut acc = TopicWordAcc::with_capacity(4096);
    for doc in &corpus.docs {
        for &v in doc {
            acc.add(rng.below(24) as u32, v, 1);
        }
    }
    let n = TopicWordRows::merge_from(k_max, &mut [acc]);
    let root = Pcg64::new(9);
    let phi = sample_phi(&root, &n, 0.01, 1500, 1usize);
    let sparse = phi_loglik_sparse(&n, &phi);
    let dense = e.loglik(&n, &phi).unwrap();
    let rel = (sparse - dense).abs() / sparse.abs().max(1.0);
    assert!(rel < 1e-4, "sparse {sparse} vs xla {dense} (rel {rel})");
}

#[test]
fn zscore_matches_rust_dense_enumeration() {
    let Some(e) = engine() else { return };
    let Some((b, k)) = e.zscore_shape() else {
        panic!("zscore artifact missing")
    };
    let mut rng = Pcg64::new(11);
    let mut phi_cols = vec![0.0f32; b * k];
    let mut m_rows = vec![0.0f32; b * k];
    let mut psi = vec![0.0f32; k];
    for p in psi.iter_mut() {
        *p = rng.f64() as f32;
    }
    let psum: f32 = psi.iter().sum();
    psi.iter_mut().for_each(|p| *p /= psum);
    for x in phi_cols.iter_mut() {
        if rng.bernoulli(0.2) {
            *x = rng.f64() as f32;
        }
    }
    for x in m_rows.iter_mut() {
        if rng.bernoulli(0.1) {
            *x = rng.below(5) as f32;
        }
    }
    let alpha = 0.8f32;
    let got = e.zscore(&phi_cols, &m_rows, &psi, alpha).unwrap();
    assert_eq!(got.len(), b * k);
    for t in 0..b {
        let row = &phi_cols[t * k..(t + 1) * k];
        let mrow = &m_rows[t * k..(t + 1) * k];
        let want: Vec<f64> = row
            .iter()
            .zip(mrow)
            .zip(&psi)
            .map(|((&p, &m), &s)| p as f64 * (alpha as f64 * s as f64 + m as f64))
            .collect();
        let tot: f64 = want.iter().sum();
        for i in 0..k {
            let w = if tot > 0.0 { want[i] / tot } else { 0.0 };
            let g = got[t * k + i] as f64;
            assert!(
                (g - w).abs() < 1e-4,
                "token {t} topic {i}: {g} vs {w}"
            );
        }
        // normalized
        let s: f32 = got[t * k..(t + 1) * k].iter().sum();
        assert!(s == 0.0 || (s - 1.0).abs() < 1e-3, "row {t} sum {s}");
    }
}

#[test]
fn psi_stick_matches_rust() {
    let Some(e) = engine() else { return };
    let klen = 1024usize;
    let mut sticks = vec![0.0f32; klen];
    let mut rng = Pcg64::new(3);
    for s in sticks.iter_mut() {
        *s = rng.f64() as f32 * 0.5;
    }
    sticks[klen - 1] = 1.0;
    let got = e.psi_stick(&sticks).unwrap();
    // rust reference
    let mut remaining = 1.0f64;
    let mut sum = 0.0f64;
    for (i, &s) in sticks.iter().enumerate() {
        let want = remaining * s as f64;
        assert!(
            (got[i] as f64 - want).abs() < 1e-5,
            "component {i}: {} vs {want}",
            got[i]
        );
        remaining *= 1.0 - s as f64;
        sum += want;
    }
    assert!((sum - 1.0).abs() < 1e-4);
    assert!((got.iter().map(|&x| x as f64).sum::<f64>() - 1.0).abs() < 1e-3);
}
