//! Deterministic fault-injection matrix (`--features failpoints`):
//! torn-write sweeps over the atomic checkpoint pipeline, transient
//! injected EIO healed bit-identically by the positioned-I/O retries,
//! and crash-during-checkpoint runs whose chains stay bit-identical.
//!
//! Every test takes [`fault::serial_guard`] — the failpoint registry
//! is process-global — and starts from [`fault::reset`].

use hdp_sparse::config::{HdpConfig, RunConfig};
use hdp_sparse::coordinator::{train, LoopOptions};
use hdp_sparse::corpus::io::{write_packed, PackedCorpusFile};
use hdp_sparse::corpus::synthetic::HdpCorpusSpec;
use hdp_sparse::corpus::Corpus;
use hdp_sparse::durable;
use hdp_sparse::fault::{self, FaultSpec};
use hdp_sparse::hdp::checkpoint::{latest_valid, Checkpoint};
use hdp_sparse::hdp::pc::PcSampler;
use hdp_sparse::hdp::Trainer;
use hdp_sparse::metrics::TraceWriter;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn sample_ckpt(iteration: u64) -> Checkpoint {
    Checkpoint::from_nested_z(
        iteration,
        "pc-hdp",
        vec![0.5, 0.25, 0.25],
        &[vec![0, 1, 1, 2], vec![], vec![2, 0]],
    )
}

fn assert_no_tmp_debris(dir: &Path) {
    for entry in std::fs::read_dir(dir).unwrap() {
        let name = entry.unwrap().file_name();
        let name = name.to_string_lossy().to_string();
        assert!(!durable::is_tmp_partial(&name), "temp debris left: {name}");
    }
}

/// The tentpole sweep: tear the checkpoint byte stream at **every**
/// offset. Each attempt must fail with `Err`, leave the previous
/// checkpoint at the target path bit-for-bit loadable, and clean up
/// its temp file. Tearing exactly at the end (nothing actually cut)
/// must succeed.
#[test]
fn torn_checkpoint_write_at_every_offset_fails_closed() {
    let _g = fault::serial_guard();
    fault::reset();
    let dir = fresh_dir("hdp_fault_torn_sweep");
    let path = dir.join("model.ckpt");
    let old = sample_ckpt(3);
    old.save(&path).unwrap();
    let new = sample_ckpt(9);
    // Fault-free sibling save tells us the exact byte length to sweep.
    let reference = dir.join("reference.ckpt");
    new.save(&reference).unwrap();
    let n = std::fs::metadata(&reference).unwrap().len();
    for cut in 0..n {
        fault::arm("ckpt.write", FaultSpec::torn(cut));
        let res = new.save(&path);
        assert!(res.is_err(), "save survived a tear at byte {cut}/{n}");
        assert!(
            fault::triggered("ckpt.write") >= 1,
            "tear at {cut} never fired"
        );
        fault::disarm("ckpt.write");
        let loaded = Checkpoint::load(&path)
            .unwrap_or_else(|e| panic!("old checkpoint lost after tear at {cut}: {e:#}"));
        assert_eq!(loaded, old, "target mutated by failed save (tear at {cut})");
        assert_no_tmp_debris(&dir);
    }
    // A "tear" past the last byte lets everything through.
    fault::arm("ckpt.write", FaultSpec::torn(n));
    new.save(&path).unwrap();
    fault::reset();
    assert_eq!(Checkpoint::load(&path).unwrap(), new);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sync_rename_and_dirsync_faults_fail_closed() {
    let _g = fault::serial_guard();
    fault::reset();
    let dir = fresh_dir("hdp_fault_pipeline_sites");
    let path = dir.join("model.ckpt");
    let old = sample_ckpt(3);
    old.save(&path).unwrap();
    let new = sample_ckpt(9);
    // Before the rename the old file must be untouched.
    for site in ["ckpt.write", "ckpt.sync", "ckpt.rename"] {
        fault::arm(site, FaultSpec::error());
        assert!(new.save(&path).is_err(), "{site}: save did not fail");
        // `>= 1`, not `== 1`: the buffered writer's drop may retry the
        // flush and trip a persistent write fault a second time.
        assert!(fault::triggered(site) >= 1, "{site}: did not fire");
        fault::disarm(site);
        assert_eq!(Checkpoint::load(&path).unwrap(), old, "{site} corrupted target");
        assert_no_tmp_debris(&dir);
    }
    // The dirsync site sits after the rename: the save still reports
    // `Err` (durability of the rename is unconfirmed) but the target
    // already holds the complete new checkpoint — never a torn one.
    fault::arm("ckpt.dirsync", FaultSpec::error());
    assert!(new.save(&path).is_err());
    fault::reset();
    assert_eq!(Checkpoint::load(&path).unwrap(), new);
    assert_no_tmp_debris(&dir);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn packed_corpus_torn_write_and_transient_read_faults() {
    let _g = fault::serial_guard();
    fault::reset();
    let c = Corpus {
        docs: vec![vec![0, 0, 2, 1], vec![1], vec![], vec![2, 1, 0]],
        vocab: vec!["alpha".into(), "beta".into(), "gamma".into()],
    };
    let dir = fresh_dir("hdp_fault_packed");
    let path = dir.join("c.hdpp");
    // A torn packed write fails closed and leaves nothing behind.
    fault::arm("packed.write", FaultSpec::torn(25));
    assert!(write_packed(&c.to_packed(), &path).is_err());
    fault::disarm("packed.write");
    assert!(!path.exists(), "torn write published a file");
    assert_no_tmp_debris(&dir);
    write_packed(&c.to_packed(), &path).unwrap();
    let f = PackedCorpusFile::open(&path).unwrap();
    let mut reference = Vec::new();
    f.read_block(0, f.num_docs(), &mut reference).unwrap();
    assert_eq!(reference.len() as u64, f.num_tokens());
    // Two consecutive injected EIOs on the positioned read: the retry
    // loop heals them and the bytes are bit-identical.
    fault::arm("corpus.pread", FaultSpec::error_after(0, 2));
    let mut healed = Vec::new();
    f.read_block(0, f.num_docs(), &mut healed).unwrap();
    assert!(fault::triggered("corpus.pread") >= 2);
    fault::disarm("corpus.pread");
    assert_eq!(healed, reference);
    // A persistent fault exhausts the retries and surfaces as `Err` —
    // no panic, and the handle stays usable afterwards.
    fault::arm("corpus.pread", FaultSpec::error());
    let mut buf = Vec::new();
    assert!(f.read_block(0, f.num_docs(), &mut buf).is_err());
    fault::disarm("corpus.pread");
    let mut after = Vec::new();
    f.read_block(0, f.num_docs(), &mut after).unwrap();
    assert_eq!(after, reference);
    fault::reset();
    std::fs::remove_dir_all(&dir).ok();
}

/// Seeded random faults: whatever the outcome (healed read or clean
/// `Err`), the caller never sees wrong bytes.
#[test]
fn random_read_faults_never_yield_wrong_data() {
    let _g = fault::serial_guard();
    fault::reset();
    let c = Corpus {
        docs: vec![vec![0, 1, 2, 2, 1, 0], vec![2, 2], vec![0]],
        vocab: vec!["a".into(), "b".into(), "c".into()],
    };
    let dir = fresh_dir("hdp_fault_random_soak");
    let path = dir.join("c.hdpp");
    write_packed(&c.to_packed(), &path).unwrap();
    let f = PackedCorpusFile::open(&path).unwrap();
    let mut reference = Vec::new();
    f.read_block(0, f.num_docs(), &mut reference).unwrap();
    let mut healed = 0u32;
    for seed in 0u64..16 {
        fault::arm("corpus.pread", FaultSpec::random_error(0.4, seed));
        let mut buf = Vec::new();
        match f.read_block(0, f.num_docs(), &mut buf) {
            Ok(()) => {
                assert_eq!(buf, reference, "seed {seed}: wrong data served");
                healed += 1;
            }
            Err(_) => {} // fail-closed is an acceptable outcome
        }
        fault::disarm("corpus.pread");
    }
    // With p = 0.4 and 4 attempts per read, most seeds must heal; a
    // zero count would mean the retry loop is not actually retrying.
    assert!(healed > 0, "no seed ever healed through retries");
    fault::reset();
    std::fs::remove_dir_all(&dir).ok();
}

fn train_corpus(seed: u64) -> Arc<Corpus> {
    let (c, _) = HdpCorpusSpec {
        vocab: 120,
        topics: 3,
        gamma: 1.0,
        alpha: 1.0,
        topic_beta: 0.05,
        docs: 24,
        mean_doc_len: 16.0,
        len_sigma: 0.3,
        min_doc_len: 6,
    }
    .generate(seed);
    Arc::new(c)
}

/// A periodic checkpoint that dies mid-save costs durability, never
/// the chain: training continues, the failure is counted, and the
/// final state — plus a crash-resume from the last checkpoint that
/// *did* land — is bit-identical to the fault-free run.
#[test]
fn failed_checkpoint_never_perturbs_the_chain_and_resume_matches() {
    let _g = fault::serial_guard();
    fault::reset();
    let c = train_corpus(31);
    let cfg = HdpConfig { alpha: 0.5, beta: 0.05, gamma: 1.0, k_max: 24, init_topics: 1 };
    let run = |iterations: usize, checkpoint_every: usize| RunConfig {
        iterations,
        threads: 1,
        seed: 7,
        eval_every: 5,
        time_budget_secs: 0,
        checkpoint_every,
    };
    // Fault-free reference: 10 iterations, no checkpoints.
    let mut full = PcSampler::new(c.clone(), cfg, 1, 7).unwrap();
    let mut trace = TraceWriter::in_memory();
    train(&mut full, &run(10, 0), &mut trace, &LoopOptions::default()).unwrap();
    // Checkpointing run: every 2 iterations (5 attempts), with the
    // SECOND attempt's data sync injected to fail.
    let dir = fresh_dir("hdp_fault_ckpt_chain");
    let ckdir = dir.join("checkpoints");
    let mut chain = PcSampler::new(c.clone(), cfg, 1, 7).unwrap();
    let opts = LoopOptions {
        checkpoint_dir: Some(ckdir.clone()),
        ..Default::default()
    };
    fault::arm("ckpt.sync", FaultSpec::error_after(1, 1));
    let mut trace = TraceWriter::in_memory();
    let summary = train(&mut chain, &run(10, 2), &mut trace, &opts).unwrap();
    assert_eq!(fault::triggered("ckpt.sync"), 1);
    fault::reset();
    assert_eq!(summary.iterations, 10);
    assert_eq!(summary.checkpoints_written, 4);
    assert_eq!(summary.checkpoints_failed, 1);
    // The injected save failure changed nothing about the chain.
    assert_eq!(chain.z_nested(), full.z_nested());
    assert_eq!(chain.psi(), full.psi());
    // The iteration-4 checkpoint is the injected casualty; the scan
    // still finds the final one and a resume of the *truncated* chain
    // reconverges bit-identically: rerun to 6, resume from the ckpt-6
    // snapshot, finish to 10.
    let (_, ckpt) = latest_valid(&ckdir).unwrap().unwrap();
    assert_eq!(ckpt.iteration, 10);
    assert!(!ckdir.join(hdp_sparse::hdp::checkpoint::periodic_name(4)).exists());
    let mid = Checkpoint::load(&ckdir.join(
        hdp_sparse::hdp::checkpoint::periodic_name(6),
    ))
    .unwrap();
    let mut resumed = PcSampler::resume_chain(c, cfg, 1, 7, &mid).unwrap();
    let mut trace = TraceWriter::in_memory();
    let summary = train(
        &mut resumed,
        &run(10, 0),
        &mut trace,
        &LoopOptions::default(),
    )
    .unwrap();
    assert_eq!(summary.iterations, 10);
    assert_eq!(resumed.z_nested(), full.z_nested());
    assert_eq!(resumed.psi(), full.psi());
    std::fs::remove_dir_all(&dir).ok();
}
