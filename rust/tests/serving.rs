//! Serving-layer lockdown: hot-swap safety under concurrency, bitwise
//! reproducibility, checkpoint/live snapshot equivalence, and the
//! guarantee that serving never perturbs the training chain.

use hdp_sparse::config::HdpConfig;
use hdp_sparse::corpus::synthetic::HdpCorpusSpec;
use hdp_sparse::corpus::Corpus;
use hdp_sparse::hdp::checkpoint::Checkpoint;
use hdp_sparse::hdp::pc::PcSampler;
use hdp_sparse::hdp::pclda::PcLdaSampler;
use hdp_sparse::hdp::Trainer;
use hdp_sparse::serve::{
    InferMode, InferRequest, InferResponse, ModelSnapshot, Server,
};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn corpus() -> Arc<Corpus> {
    let (c, _) = HdpCorpusSpec {
        vocab: 180,
        topics: 4,
        gamma: 2.0,
        alpha: 0.8,
        topic_beta: 0.05,
        docs: 60,
        mean_doc_len: 25.0,
        len_sigma: 0.3,
        min_doc_len: 8,
    }
    .generate(37);
    Arc::new(c)
}

fn cfg() -> HdpConfig {
    HdpConfig { alpha: 0.3, beta: 0.05, gamma: 1.0, k_max: 14, init_topics: 1 }
}

fn trained(corpus: &Arc<Corpus>, threads: usize, seed: u64) -> PcSampler {
    let mut s = PcSampler::new(corpus.clone(), cfg(), threads, seed).unwrap();
    for _ in 0..15 {
        s.step().unwrap();
    }
    s
}

fn requests(corpus: &Corpus, n: usize, mode: InferMode) -> Vec<InferRequest> {
    (0..n)
        .map(|i| InferRequest {
            id: i as u64,
            tokens: corpus.docs[i % corpus.num_docs()].clone(),
            seed: 5000 + (i as u64 % 7),
            passes: 3,
            mode,
        })
        .collect()
}

/// Full bitwise equality of two responses.
fn assert_same(a: &InferResponse, b: &InferResponse, ctx: &str) {
    assert_eq!(a.id, b.id, "{ctx}: id");
    assert_eq!(a.generation, b.generation, "{ctx}: generation");
    assert_eq!(a.topic_counts, b.topic_counts, "{ctx}: topic_counts");
    assert_eq!(a.theta.len(), b.theta.len(), "{ctx}: theta len");
    for ((ka, ta), (kb, tb)) in a.theta.iter().zip(&b.theta) {
        assert_eq!(ka, kb, "{ctx}: theta topic");
        assert_eq!(ta.to_bits(), tb.to_bits(), "{ctx}: theta value");
    }
    assert_eq!(
        a.log_likelihood.to_bits(),
        b.log_likelihood.to_bits(),
        "{ctx}: log_likelihood"
    );
    assert_eq!(a.tokens_scored, b.tokens_scored, "{ctx}: scored");
    assert_eq!(a.tokens_skipped, b.tokens_skipped, "{ctx}: skipped");
}

/// 8 reader threads hammer `serve_one` while a writer hot-swaps 30
/// snapshots. Afterwards every recorded response must replay
/// bit-identically on the exact published snapshot its generation
/// names — no torn reads, exact attribution.
#[test]
fn hot_swap_stress_attributes_every_response() {
    let c = corpus();
    let s = trained(&c, 2, 11);
    let reqs = requests(&c, 48, InferMode::Mixture);
    // Pre-freeze everything on the main thread; the writer only
    // publishes (distinct phi seeds -> distinct models).
    let pending: Vec<ModelSnapshot> =
        (0..30u64).map(|i| ModelSnapshot::from_pc(&s, 200 + i)).collect();
    let server = Server::new(s.pool_handle(), ModelSnapshot::from_pc(&s, 199));
    let stop = AtomicBool::new(false);
    let readers = 8usize;

    let mut published: Vec<Arc<ModelSnapshot>> = vec![server.snapshot()];
    let mut recorded: Vec<(usize, InferResponse)> = Vec::new();
    std::thread::scope(|scope| {
        let writer = {
            let server = &server;
            let stop = &stop;
            scope.spawn(move || {
                let mut seen = Vec::new();
                for snap in pending {
                    server.publish(snap);
                    // Single writer: this load returns exactly the
                    // snapshot just published.
                    seen.push(server.snapshot());
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                stop.store(true, Ordering::Release);
                seen
            })
        };
        let handles: Vec<_> = (0..readers)
            .map(|t| {
                let server = &server;
                let reqs = &reqs;
                let stop = &stop;
                scope.spawn(move || {
                    let mut out = Vec::new();
                    let mut i = t;
                    while !stop.load(Ordering::Acquire) {
                        let idx = i % reqs.len();
                        out.push((idx, server.serve_one(&reqs[idx])));
                        i += 1;
                    }
                    out
                })
            })
            .collect();
        published.extend(writer.join().unwrap());
        for h in handles {
            recorded.extend(h.join().unwrap());
        }
    });

    assert_eq!(published.len(), 31);
    let by_gen: HashMap<u64, &Arc<ModelSnapshot>> =
        published.iter().map(|p| (p.generation(), p)).collect();
    assert_eq!(by_gen.len(), 31, "generations are unique");
    let mut gens_seen = std::collections::HashSet::new();
    assert!(!recorded.is_empty());
    for (idx, resp) in &recorded {
        let snap = by_gen
            .get(&resp.generation)
            .unwrap_or_else(|| panic!("unpublished generation {}", resp.generation));
        let replay = snap.infer(&reqs[*idx]);
        assert_same(resp, &replay, "replay");
        gens_seen.insert(resp.generation);
    }
    assert!(
        gens_seen.len() >= 2,
        "stress run observed only {} generation(s)",
        gens_seen.len()
    );
}

/// Identical (request, snapshot, seed) triples reproduce bit-for-bit;
/// changing any leg of the triple changes the draw.
#[test]
fn identical_triples_reproduce_bitwise() {
    let c = corpus();
    let s = trained(&c, 1, 13);
    let server = Server::new(s.pool_handle(), ModelSnapshot::from_pc(&s, 300));
    for mode in
        [InferMode::Mixture, InferMode::SparseMixture, InferMode::Completion]
    {
        let reqs = requests(&c, 8, mode);
        let mut any_diff = false;
        for req in &reqs {
            let a = server.serve_one(req);
            let b = server.serve_one(req);
            assert_same(&a, &b, "same triple");
            let mut other_seed = req.clone();
            other_seed.seed ^= 1;
            let d = server.serve_one(&other_seed);
            any_diff |= a.topic_counts != d.topic_counts
                || a.log_likelihood.to_bits() != d.log_likelihood.to_bits();
        }
        assert!(any_diff, "{mode:?}: flipping the seed never redrew");
    }
    // New generation, same request: attributed differently AND redrawn.
    let req = &requests(&c, 1, InferMode::Mixture)[0];
    let a = server.serve_one(req);
    server.publish(ModelSnapshot::from_pc(&s, 300));
    let e = server.serve_one(req);
    assert_eq!(e.generation, 2);
    assert_ne!(a.generation, e.generation);
}

/// Concurrent batched clients: each batch is answered by exactly one
/// generation and matches direct inference on that snapshot.
#[test]
fn concurrent_batches_are_single_generation() {
    let c = corpus();
    let s = trained(&c, 3, 17);
    let server = Server::new(s.pool_handle(), ModelSnapshot::from_pc(&s, 400));
    let reqs = requests(&c, 40, InferMode::Completion);
    let batches: Vec<Vec<InferResponse>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let server = &server;
                let reqs = &reqs;
                scope.spawn(move || server.serve_batch(reqs))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let snap = server.snapshot();
    for batch in &batches {
        assert_eq!(batch.len(), reqs.len());
        for (resp, req) in batch.iter().zip(&reqs) {
            assert_eq!(resp.generation, 1, "single snapshot per batch");
            assert_same(resp, &snap.infer(req), "batch vs direct");
        }
    }
}

/// Checkpoint round trips (v2 packed and legacy v1) freeze to
/// snapshots whose predictions are bit-identical to freezing straight
/// off the live sampler.
#[test]
fn checkpoint_freeze_matches_live() {
    let c = corpus();
    let s = trained(&c, 2, 19);
    let hp = cfg();
    let live = ModelSnapshot::from_pc(&s, 500);
    let ckpt = s.checkpoint();

    let dir = std::env::temp_dir().join(format!(
        "hdp_serving_ckpt_{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let p2 = dir.join("m.ckpt2");
    let p1 = dir.join("m.ckpt1");
    ckpt.save(&p2).unwrap();
    ckpt.save_v1(&p1).unwrap();
    let r2 = Checkpoint::load(&p2).unwrap();
    let r1 = Checkpoint::load(&p1).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(ckpt, r2);
    assert_eq!(ckpt, r1);

    let from_v2 =
        ModelSnapshot::from_checkpoint(&r2, &c, hp.alpha, hp.beta, 500, 2usize)
            .unwrap();
    let from_v1 =
        ModelSnapshot::from_checkpoint(&r1, &c, hp.alpha, hp.beta, 500, 1usize)
            .unwrap();
    let reqs = requests(&c, 20, InferMode::Completion);
    for req in &reqs {
        let a = live.infer(req);
        assert_same(&a, &from_v2.infer(req), "live vs v2 roundtrip");
        assert_same(&a, &from_v1.infer(req), "live vs v1 roundtrip");
    }

    // Same story for the fixed-K LDA sampler via a hand-built
    // checkpoint (uniform psi is what its checkpoints carry).
    let k = 12usize;
    let mut lda = PcLdaSampler::new(c.clone(), k, 0.3, 0.05, 2, 21).unwrap();
    for _ in 0..10 {
        lda.step().unwrap();
    }
    let lda_live = ModelSnapshot::from_pclda(&lda, 600);
    let lda_ckpt = Checkpoint::from_nested_z(
        lda.iterations_done() as u64,
        "pclda",
        lda.psi().to_vec(),
        lda.assignments(),
    );
    let lda_rebuilt = ModelSnapshot::from_checkpoint(
        &lda_ckpt,
        &c,
        lda.alpha(),
        lda.beta(),
        600,
        2usize,
    )
    .unwrap();
    for req in &requests(&c, 10, InferMode::Mixture) {
        assert_same(
            &lda_live.infer(req),
            &lda_rebuilt.infer(req),
            "pclda live vs checkpoint",
        );
    }
}

/// Requests carrying vocabulary ids the model never observed (empty Φ
/// columns — routine in production traffic) must be answered in every
/// mode, not panic: the zero-mass column draw has a defined fallback.
/// A panicking request used to take down a worker-pool slot, so the
/// pool must still serve normal batches afterwards.
#[test]
fn unseen_vocabulary_ids_are_served_not_panicked() {
    let base = corpus();
    // Extend the vocabulary without emitting the new ids in any
    // document: ids 180..=183 have empty Φ columns after training.
    let mut ext = (*base).clone();
    for i in 0..4 {
        ext.vocab.push(format!("unseen{i}"));
    }
    let c = Arc::new(ext);
    let s = trained(&c, 2, 29);
    let server = Server::new(s.pool_handle(), ModelSnapshot::from_pc(&s, 800));
    let unseen: Vec<u32> = (180..184).collect();
    for mode in
        [InferMode::Mixture, InferMode::SparseMixture, InferMode::Completion]
    {
        // One request of nothing but unseen ids, one mixing them into
        // a real document.
        let mut mixed = c.docs[0].clone();
        mixed.extend(&unseen);
        let reqs = vec![
            InferRequest { id: 1, tokens: unseen.clone(), seed: 902, passes: 3, mode },
            InferRequest { id: 2, tokens: mixed, seed: 903, passes: 3, mode },
        ];
        let resps = server.serve_batch(&reqs);
        assert_eq!(resps.len(), 2);
        assert_eq!(resps[0].tokens_scored, 0, "{mode:?}: nothing scorable");
        assert!(resps[0].tokens_skipped > 0, "{mode:?}: unseen ids skipped");
        assert!(resps[1].tokens_scored > 0, "{mode:?}: real tokens still score");
        // And reproducible, like any other request.
        assert_same(&resps[1], &server.serve_one(&reqs[1]), "unseen-mixed replay");
    }
    // The pool survived: a normal batch still runs end to end.
    let reqs = requests(&c, 8, InferMode::SparseMixture);
    assert_eq!(server.serve_batch(&reqs).len(), 8);
}

/// Interleaving serving with training must leave the training chain
/// bit-identical to an undisturbed twin: request RNG streams are
/// derived per (request, generation), never borrowed from the chain.
#[test]
fn serving_never_perturbs_training() {
    let c = corpus();
    let mut a = PcSampler::new(c.clone(), cfg(), 2, 23).unwrap();
    let mut b = PcSampler::new(c.clone(), cfg(), 2, 23).unwrap();
    for _ in 0..8 {
        a.step().unwrap();
        b.step().unwrap();
    }
    let server = Server::new(a.pool_handle(), ModelSnapshot::from_pc(&a, 700));
    let reqs = requests(&c, 16, InferMode::Mixture);
    for round in 0..4 {
        // Serve between `a`'s steps (on `a`'s own pool), publish a
        // fresh freeze each round; `b` just trains.
        for req in &reqs {
            server.serve_one(req);
        }
        server.serve_batch(&reqs);
        server.publish(ModelSnapshot::from_pc(&a, 700 + round));
        a.step().unwrap();
        b.step().unwrap();
    }
    assert_eq!(a.psi().len(), b.psi().len());
    for (x, y) in a.psi().iter().zip(b.psi()) {
        assert_eq!(x.to_bits(), y.to_bits(), "psi diverged");
    }
    assert_eq!(a.z_nested(), b.z_nested(), "z diverged");
    for k in 0..cfg().k_max {
        assert_eq!(a.n().row(k), b.n().row(k), "n row {k} diverged");
    }
}
