//! Configuration system.
//!
//! A small TOML-subset parser ([`ConfigMap::parse`]) plus the typed
//! configuration structs consumed by the trainers and experiment
//! drivers. Supported syntax: `[section]` headers, `key = value` with
//! string / integer / float / boolean / flat string-or-number arrays,
//! `#` comments, blank lines. That covers every config this project
//! ships; nested tables are intentionally out of scope.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    List(Vec<Value>),
}

impl Value {
    /// As f64 (ints coerce).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// As i64.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// As str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse error with line information.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Sectioned key-value configuration. Keys in the preamble (before any
/// `[section]`) live in the `""` section.
#[derive(Clone, Debug, Default)]
pub struct ConfigMap {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl ConfigMap {
    /// Parse from TOML-subset text.
    pub fn parse(text: &str) -> Result<Self, ParseError> {
        let mut map = ConfigMap::default();
        let mut section = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| ParseError {
                    line: lineno,
                    message: "unterminated section header".into(),
                })?;
                section = name.trim().to_string();
                map.sections.entry(section.clone()).or_default();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| ParseError {
                line: lineno,
                message: format!("expected `key = value`, got `{line}`"),
            })?;
            let key = line[..eq].trim().to_string();
            if key.is_empty() {
                return Err(ParseError { line: lineno, message: "empty key".into() });
            }
            let value = parse_value(line[eq + 1..].trim()).map_err(|m| ParseError {
                line: lineno,
                message: m,
            })?;
            map.sections.entry(section.clone()).or_default().insert(key, value);
        }
        Ok(map)
    }

    /// Load and parse a file.
    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Self::parse(&text)?)
    }

    /// Get a value.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    /// Typed getters with defaults.
    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_f64).unwrap_or(default)
    }

    /// Integer (usize) getter with default.
    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key)
            .and_then(Value::as_i64)
            .map(|i| i.max(0) as usize)
            .unwrap_or(default)
    }

    /// u64 getter with default.
    pub fn u64_or(&self, section: &str, key: &str, default: u64) -> u64 {
        self.get(section, key)
            .and_then(Value::as_i64)
            .map(|i| i.max(0) as u64)
            .unwrap_or(default)
    }

    /// String getter with default.
    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }

    /// Bool getter with default.
    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// Set a value programmatically (used by CLI overrides).
    pub fn set(&mut self, section: &str, key: &str, value: Value) {
        self.sections
            .entry(section.to_string())
            .or_default()
            .insert(key.to_string(), value);
    }

    /// Section names.
    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::List(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value `{s}`"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    // split on commas not inside quotes (flat arrays only)
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

/// HDP model hyperparameters (paper §3: α=0.1, β=0.01, γ=1, K*=1000).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HdpConfig {
    /// Document-level DP concentration α.
    pub alpha: f64,
    /// Symmetric Dirichlet topic-word prior β.
    pub beta: f64,
    /// GEM concentration γ for the global topic distribution Ψ.
    pub gamma: f64,
    /// Truncation level K* (flag topic index; §2.4).
    pub k_max: usize,
    /// Number of topics assigned at initialization (paper follows
    /// Teh et al. 2006 and starts from a single topic).
    pub init_topics: usize,
}

impl Default for HdpConfig {
    fn default() -> Self {
        Self { alpha: 0.1, beta: 0.01, gamma: 1.0, k_max: 1000, init_topics: 1 }
    }
}

impl HdpConfig {
    /// Read from the `[model]` section, falling back to paper defaults.
    pub fn from_map(map: &ConfigMap) -> Self {
        let d = Self::default();
        Self {
            alpha: map.f64_or("model", "alpha", d.alpha),
            beta: map.f64_or("model", "beta", d.beta),
            gamma: map.f64_or("model", "gamma", d.gamma),
            k_max: map.usize_or("model", "k_max", d.k_max),
            init_topics: map.usize_or("model", "init_topics", d.init_topics),
        }
    }

    /// Validate ranges.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.alpha > 0.0, "alpha must be > 0");
        anyhow::ensure!(self.beta > 0.0, "beta must be > 0");
        anyhow::ensure!(self.gamma > 0.0, "gamma must be > 0");
        anyhow::ensure!(self.k_max >= 2, "k_max must be >= 2");
        anyhow::ensure!(
            self.init_topics >= 1 && self.init_topics < self.k_max,
            "init_topics must be in [1, k_max)"
        );
        Ok(())
    }
}

/// Run-control parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    /// Gibbs iterations.
    pub iterations: usize,
    /// Worker threads for the parallel phases.
    pub threads: usize,
    /// RNG seed (chains are reproducible per seed and shard-invariant).
    pub seed: u64,
    /// Evaluate diagnostics every this many iterations.
    pub eval_every: usize,
    /// Optional wall-clock budget in seconds (0 = unlimited); used by
    /// the Fig-1(g–i) fixed-budget comparison.
    pub time_budget_secs: u64,
    /// Write a durable checkpoint every this many iterations (0 = off;
    /// the training loop also needs a checkpoint directory).
    pub checkpoint_every: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            iterations: 100,
            threads: 1,
            seed: 2020,
            eval_every: 10,
            time_budget_secs: 0,
            checkpoint_every: 0,
        }
    }
}

impl RunConfig {
    /// Read from the `[run]` section.
    pub fn from_map(map: &ConfigMap) -> Self {
        let d = Self::default();
        Self {
            iterations: map.usize_or("run", "iterations", d.iterations),
            threads: map.usize_or("run", "threads", d.threads).max(1),
            seed: map.u64_or("run", "seed", d.seed),
            eval_every: map.usize_or("run", "eval_every", d.eval_every).max(1),
            time_budget_secs: map.u64_or("run", "time_budget_secs", 0),
            checkpoint_every: map.usize_or("run", "checkpoint_every", 0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
title = "ap reproduction"

[model]
alpha = 0.1
beta = 0.01
gamma = 1 # integer coerces
k_max = 1000

[run]
iterations = 100_000
threads = 8
trace = true
corpora = ["ap", "cgcbib"]
ratio = 2.5
"#;

    #[test]
    fn parses_sections_and_types() {
        let map = ConfigMap::parse(SAMPLE).unwrap();
        assert_eq!(map.get("", "title").unwrap().as_str().unwrap(), "ap reproduction");
        assert_eq!(map.f64_or("model", "alpha", 0.0), 0.1);
        assert_eq!(map.f64_or("model", "gamma", 0.0), 1.0);
        assert_eq!(map.usize_or("run", "iterations", 0), 100_000);
        assert!(map.bool_or("run", "trace", false));
        assert_eq!(map.f64_or("run", "ratio", 0.0), 2.5);
        match map.get("run", "corpora").unwrap() {
            Value::List(items) => {
                assert_eq!(items.len(), 2);
                assert_eq!(items[0].as_str().unwrap(), "ap");
            }
            other => panic!("expected list, got {other:?}"),
        }
    }

    #[test]
    fn defaults_when_missing() {
        let map = ConfigMap::parse("").unwrap();
        let hdp = HdpConfig::from_map(&map);
        assert_eq!(hdp, HdpConfig::default());
        let run = RunConfig::from_map(&map);
        assert_eq!(run, RunConfig::default());
    }

    #[test]
    fn typed_configs_from_map() {
        let map = ConfigMap::parse(SAMPLE).unwrap();
        let hdp = HdpConfig::from_map(&map);
        assert_eq!(hdp.alpha, 0.1);
        assert_eq!(hdp.k_max, 1000);
        hdp.validate().unwrap();
        let run = RunConfig::from_map(&map);
        assert_eq!(run.threads, 8);
    }

    #[test]
    fn rejects_bad_syntax() {
        assert!(ConfigMap::parse("[unterminated").is_err());
        assert!(ConfigMap::parse("novalue").is_err());
        assert!(ConfigMap::parse("x = ").is_err());
        assert!(ConfigMap::parse("x = \"open").is_err());
    }

    #[test]
    fn comments_and_strings_interact() {
        let map = ConfigMap::parse("s = \"a # not comment\" # real comment").unwrap();
        assert_eq!(map.get("", "s").unwrap().as_str().unwrap(), "a # not comment");
    }

    #[test]
    fn validate_catches_bad_hparams() {
        let mut c = HdpConfig::default();
        c.alpha = 0.0;
        assert!(c.validate().is_err());
        let mut c = HdpConfig::default();
        c.init_topics = c.k_max;
        assert!(c.validate().is_err());
    }

    #[test]
    fn set_overrides() {
        let mut map = ConfigMap::parse(SAMPLE).unwrap();
        map.set("model", "alpha", Value::Float(0.5));
        assert_eq!(map.f64_or("model", "alpha", 0.0), 0.5);
    }
}
