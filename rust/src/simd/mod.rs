//! Vendored SIMD kernels for the z/Φ/alias hot loops — no crates, just
//! `core::arch` intrinsics behind a runtime-dispatched function table.
//!
//! # The dispatch ladder
//!
//! [`Kernels::auto`] resolves, once, to the widest tier the running CPU
//! supports and the build enables:
//!
//! 1. **AVX2** (x86_64, `simd` feature, `avx2` detected at runtime):
//!    256-bit lanes, hardware gathers for the bucket-(b) dense scan and
//!    the bucket-(a) `Ψ` weight build.
//! 2. **SSE2** (x86_64, `simd` feature): 128-bit lanes for the f64
//!    elementwise/compare kernels; gathers fall back to scalar.
//! 3. **Scalar** (everything else, and always when the `simd` cargo
//!    feature is off): plain loops, bit-for-bit the pre-SIMD code.
//!
//! The table is a struct of plain `fn` pointers, so call sites pay one
//! predictable indirect call per *kernel invocation* (amortized over a
//! whole column/row/table), never per element, and the sampler can
//! carry a `Kernels` by value ([`Kernels`] is `Copy`).
//!
//! # Bit-exactness policy
//!
//! Chains must stay reproducible, so every kernel that can influence
//! the sampler chain is **bit-exact** with respect to its scalar
//! version:
//!
//! * integer and compare kernels ([`Kernels::partition_lt1`],
//!   [`Kernels::find_first_gt`], [`Kernels::compact_nonzero_u32`])
//!   evaluate the identical per-element predicate and preserve first
//!   match/order semantics — results are bit-identical;
//! * elementwise float kernels ([`Kernels::scale_f64`],
//!   [`Kernels::gather_mul_u32`], [`Kernels::gather_mul_f64`]) perform
//!   the same IEEE-754 operation on the same operands per element — no
//!   reassociation — so they too are bit-identical;
//! * the one reassociating reduction, [`Kernels::sum_f64`], uses
//!   multi-lane accumulators and may differ from left-to-right
//!   summation by ≈ 1 ulp per accumulation step (relative error
//!   `O(n·ε)`, tiny in practice for the nonnegative weight vectors it
//!   sees). It is therefore only used where the result cannot change
//!   the chain: the `total > 0` degeneracy *test* in the alias build
//!   (nonnegative terms sum to exactly 0.0 in any order, and a positive
//!   sum stays positive under any reassociation) and bench/diagnostic
//!   aggregation. Chain-visible totals (e.g. the stored alias mass)
//!   keep the scalar left-to-right sum.
//!
//! Net effect: with the `simd` feature off the binary contains only the
//! scalar loops (bit-exactness runs); with it on, chains are *still*
//! bit-identical by construction, and the property tests in this module
//! enforce it per kernel.
//!
//! # Adding a kernel
//!
//! 1. Write the scalar version as a plain `fn` here and add a field to
//!    [`Kernels`] (plus the [`Kernels::scalar`] entry).
//! 2. Add the x86_64 implementations in `x86.rs`: a private
//!    `#[target_feature(enable = "...")] unsafe fn` body plus a safe
//!    wrapper, and register the wrapper in `x86::avx2()` /
//!    `x86::sse2()` (reuse the scalar `fn` for tiers that lack the
//!    needed instructions).
//! 3. State the kernel's exactness class (bit-identical vs documented
//!    tolerance) in its doc comment, and extend the scalar-vs-auto
//!    property tests below accordingly.

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86;

/// Runtime-dispatched kernel table. Obtain via [`Kernels::scalar`] (the
/// reference implementations) or [`Kernels::auto`] (the widest
/// supported tier); see the module docs for the dispatch ladder and the
/// bit-exactness policy of each field.
#[derive(Clone, Copy, Debug)]
pub struct Kernels {
    name: &'static str,
    /// Multi-lane f64 reduction: `Σ xs`. The only reassociating kernel
    /// — see the module's bit-exactness policy for where it may be
    /// used. Scalar tier is exact left-to-right summation.
    pub sum_f64: fn(&[f64]) -> f64,
    /// In-place elementwise scale: `xs[i] *= c`. Bit-identical across
    /// tiers (same IEEE multiply per element).
    pub scale_f64: fn(&mut [f64], f64),
    /// Bucket-(b) dense scan: `out[i] = probs[i] * counts[idx[i]] as
    /// f64` for `i < idx.len()`, growing `out` as needed (the tail
    /// beyond `idx.len()` is left stale — callers slice). Bit-identical
    /// across tiers. Panics if any index is out of range; count values
    /// must be `< 2^31` (they are per-document token counts).
    pub gather_mul_u32: fn(&[u32], &[f64], &[u32], &mut Vec<f64>),
    /// Bucket-(a) weight build: `out[i] = (probs[i] * scale) *
    /// src[idx[i]]` for `i < idx.len()`, growing `out` as needed (stale
    /// tail, as above). Bit-identical across tiers. Panics if any index
    /// is out of range.
    pub gather_mul_f64: fn(&[u32], &[f64], f64, &[f64], &mut Vec<f64>),
    /// Vose partition: clears then fills `small`/`large` with the
    /// indices `i` where `xs[i] < 1.0` / `!(xs[i] < 1.0)`, in order.
    /// Compare kernel — bit-identical across tiers.
    pub partition_lt1: fn(&[f64], &mut Vec<u32>, &mut Vec<u32>),
    /// First index `i` with `xs[i] > t`, or `xs.len()` when none (the
    /// cumulative-weight search). Compare kernel — bit-identical across
    /// tiers (NaN compares false, as in the scalar loop).
    pub find_first_gt: fn(&[f64], f64) -> usize,
    /// Clears then fills `out` with `(i, xs[i])` for every `xs[i] > 0`,
    /// in order (the dense Φ-row compaction). Integer kernel —
    /// bit-identical across tiers.
    pub compact_nonzero_u32: fn(&[u32], &mut Vec<(u32, u32)>),
}

impl Kernels {
    /// The scalar reference tier: plain loops, bit-for-bit the pre-SIMD
    /// hot-path code. Always available; the tier every other tier is
    /// tested against.
    pub const fn scalar() -> Self {
        Self {
            name: "scalar",
            sum_f64: sum_f64_scalar,
            scale_f64: scale_f64_scalar,
            gather_mul_u32: gather_mul_u32_scalar,
            gather_mul_f64: gather_mul_f64_scalar,
            partition_lt1: partition_lt1_scalar,
            find_first_gt: find_first_gt_scalar,
            compact_nonzero_u32: compact_nonzero_u32_scalar,
        }
    }

    /// The widest tier this build + CPU supports (see the module docs'
    /// dispatch ladder). With the `simd` cargo feature off this is
    /// always [`Kernels::scalar`] — the bit-exactness build.
    pub fn auto() -> Self {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        {
            if std::arch::is_x86_64_feature_detected!("avx2") {
                return x86::avx2();
            }
            if std::arch::is_x86_64_feature_detected!("sse2") {
                return x86::sse2();
            }
        }
        Self::scalar()
    }

    /// Tier name: `"scalar"`, `"sse2"`, or `"avx2"`.
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// True when any non-scalar tier resolved (i.e. SIMD is compiled
    /// in, enabled, and supported by this CPU).
    pub fn is_accelerated(&self) -> bool {
        self.name != "scalar"
    }

    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    fn named(name: &'static str) -> Self {
        Self { name, ..Self::scalar() }
    }
}

impl Default for Kernels {
    fn default() -> Self {
        Self::scalar()
    }
}

/// Grow `out` to at least `n` elements without touching the prefix (new
/// space is zeroed only once; reuse across calls never re-zeroes the
/// used length — the kernels overwrite `[..n]` and callers ignore the
/// stale tail).
#[inline]
pub(crate) fn ensure_f64_buf(out: &mut Vec<f64>, n: usize) {
    if out.len() < n {
        out.resize(n, 0.0);
    }
}

fn sum_f64_scalar(xs: &[f64]) -> f64 {
    xs.iter().sum()
}

fn scale_f64_scalar(xs: &mut [f64], c: f64) {
    for x in xs.iter_mut() {
        *x *= c;
    }
}

fn gather_mul_u32_scalar(idx: &[u32], probs: &[f64], counts: &[u32], out: &mut Vec<f64>) {
    assert_eq!(idx.len(), probs.len());
    ensure_f64_buf(out, idx.len());
    let out = &mut out[..idx.len()];
    for ((o, &k), &p) in out.iter_mut().zip(idx).zip(probs) {
        *o = p * counts[k as usize] as f64;
    }
}

fn gather_mul_f64_scalar(
    idx: &[u32],
    probs: &[f64],
    scale: f64,
    src: &[f64],
    out: &mut Vec<f64>,
) {
    assert_eq!(idx.len(), probs.len());
    ensure_f64_buf(out, idx.len());
    let out = &mut out[..idx.len()];
    for ((o, &k), &p) in out.iter_mut().zip(idx).zip(probs) {
        *o = p * scale * src[k as usize];
    }
}

fn partition_lt1_scalar(xs: &[f64], small: &mut Vec<u32>, large: &mut Vec<u32>) {
    small.clear();
    large.clear();
    for (i, &x) in xs.iter().enumerate() {
        if x < 1.0 {
            small.push(i as u32);
        } else {
            large.push(i as u32);
        }
    }
}

fn find_first_gt_scalar(xs: &[f64], t: f64) -> usize {
    xs.iter().position(|&x| x > t).unwrap_or(xs.len())
}

fn compact_nonzero_u32_scalar(xs: &[u32], out: &mut Vec<(u32, u32)>) {
    out.clear();
    for (i, &c) in xs.iter().enumerate() {
        if c > 0 {
            out.push((i as u32, c));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    /// Drive every length class a lane loop can mishandle: empty,
    /// sub-lane, exact multiples of both lane widths, and ragged tails.
    const LENS: &[usize] = &[0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 64, 100, 257];

    fn rand_f64s(rng: &mut Pcg64, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.f64() * 3.0).collect()
    }

    /// scalar-vs-auto: the reassociating f64 reduction must agree
    /// within the documented `O(n·ε)` bound (≈ 1 ulp per accumulation
    /// step); on the scalar tier it is bit-identical by definition.
    #[test]
    fn sum_f64_within_documented_tolerance() {
        let auto = Kernels::auto();
        let mut rng = Pcg64::new(11);
        for &n in LENS {
            let xs = rand_f64s(&mut rng, n);
            let a = (Kernels::scalar().sum_f64)(&xs);
            let b = (auto.sum_f64)(&xs);
            let tol = 2.0 * (n.max(1) as f64) * f64::EPSILON * a.abs().max(1.0);
            assert!((a - b).abs() <= tol, "n={n}: {a} vs {b} (tol {tol})");
            if !auto.is_accelerated() {
                assert_eq!(a.to_bits(), b.to_bits(), "n={n}");
            }
        }
        // All-zero input sums to exactly 0.0 in every tier — the
        // property the alias degeneracy check relies on.
        assert_eq!((auto.sum_f64)(&[0.0; 13]).to_bits(), 0.0f64.to_bits());
        assert_eq!((auto.sum_f64)(&[]).to_bits(), 0.0f64.to_bits());
    }

    /// scalar-vs-auto: elementwise kernels are bit-identical.
    #[test]
    fn scale_f64_bit_identical() {
        let auto = Kernels::auto();
        let mut rng = Pcg64::new(12);
        for &n in LENS {
            let xs = rand_f64s(&mut rng, n);
            let c = 0.1 + rng.f64();
            let mut a = xs.clone();
            let mut b = xs.clone();
            (Kernels::scalar().scale_f64)(&mut a, c);
            (auto.scale_f64)(&mut b, c);
            let a_bits: Vec<u64> = a.iter().map(|x| x.to_bits()).collect();
            let b_bits: Vec<u64> = b.iter().map(|x| x.to_bits()).collect();
            assert_eq!(a_bits, b_bits, "n={n} tier={}", auto.name());
        }
    }

    /// scalar-vs-auto: the gather kernels are bit-identical (same IEEE
    /// multiply per element, gathers change only how operands load).
    #[test]
    fn gather_kernels_bit_identical() {
        let auto = Kernels::auto();
        let mut rng = Pcg64::new(13);
        for &n in LENS {
            let k_max = 40usize;
            let idx: Vec<u32> = (0..n).map(|_| rng.below(k_max as u64) as u32).collect();
            let probs = rand_f64s(&mut rng, n);
            let counts: Vec<u32> =
                (0..k_max).map(|_| rng.below(1000) as u32).collect();
            let src = rand_f64s(&mut rng, k_max);
            let scale = 0.5 + rng.f64();

            let (mut a, mut b) = (vec![7.0; 3], vec![7.0; 3]);
            (Kernels::scalar().gather_mul_u32)(&idx, &probs, &counts, &mut a);
            (auto.gather_mul_u32)(&idx, &probs, &counts, &mut b);
            let a_bits: Vec<u64> = a[..n].iter().map(|x| x.to_bits()).collect();
            let b_bits: Vec<u64> = b[..n].iter().map(|x| x.to_bits()).collect();
            assert_eq!(a_bits, b_bits, "gather_mul_u32 n={n}");

            let (mut a, mut b) = (Vec::new(), Vec::new());
            (Kernels::scalar().gather_mul_f64)(&idx, &probs, scale, &src, &mut a);
            (auto.gather_mul_f64)(&idx, &probs, scale, &src, &mut b);
            let a_bits: Vec<u64> = a[..n].iter().map(|x| x.to_bits()).collect();
            let b_bits: Vec<u64> = b[..n].iter().map(|x| x.to_bits()).collect();
            assert_eq!(a_bits, b_bits, "gather_mul_f64 n={n}");
        }
    }

    /// Reused gather output buffers keep their stale tail (no
    /// re-zeroing beyond the used length) while the prefix is exact.
    #[test]
    fn gather_reuses_buffer_without_rezeroing() {
        let auto = Kernels::auto();
        let mut out = vec![0.0; 8];
        (auto.gather_mul_u32)(&[0, 1], &[2.0, 3.0], &[5, 7], &mut out);
        assert_eq!(&out[..2], &[10.0, 21.0]);
        assert_eq!(out.len(), 8, "shrinking would force re-zeroing later");
        let cap = out.capacity();
        (auto.gather_mul_u32)(&[1], &[1.0], &[5, 7], &mut out);
        assert_eq!(out[0], 7.0);
        assert_eq!(out.capacity(), cap, "reuse must not reallocate");
    }

    #[test]
    #[should_panic]
    fn gather_rejects_out_of_range_index() {
        let auto = Kernels::auto();
        let mut out = Vec::new();
        (auto.gather_mul_u32)(&[3], &[1.0], &[1, 2, 3], &mut out);
    }

    /// scalar-vs-auto: compare/integer kernels are bit-identical.
    #[test]
    fn partition_lt1_bit_identical() {
        let auto = Kernels::auto();
        let mut rng = Pcg64::new(14);
        for &n in LENS {
            // Cluster around 1.0 so both branches are exercised, and
            // include the boundary value itself.
            let mut xs: Vec<f64> = (0..n).map(|_| 0.5 + rng.f64()).collect();
            if n > 2 {
                xs[n / 2] = 1.0;
            }
            let (mut s1, mut l1) = (vec![9u32], vec![9u32]);
            let (mut s2, mut l2) = (Vec::new(), Vec::new());
            (Kernels::scalar().partition_lt1)(&xs, &mut s1, &mut l1);
            (auto.partition_lt1)(&xs, &mut s2, &mut l2);
            assert_eq!(s1, s2, "small n={n}");
            assert_eq!(l1, l2, "large n={n}");
            assert_eq!(s1.len() + l1.len(), n);
        }
    }

    #[test]
    fn find_first_gt_bit_identical() {
        let auto = Kernels::auto();
        let mut rng = Pcg64::new(15);
        for &n in LENS {
            // Cumulative (nondecreasing) inputs, like the partials scan.
            let mut cum = 0.0f64;
            let xs: Vec<f64> = (0..n)
                .map(|_| {
                    cum += rng.f64();
                    cum
                })
                .collect();
            for trial in 0..20 {
                let t = match trial {
                    0 => -1.0,        // first element wins
                    1 => cum + 1.0,   // no element wins -> len
                    _ => rng.f64() * cum.max(1.0),
                };
                let a = (Kernels::scalar().find_first_gt)(&xs, t);
                let b = (auto.find_first_gt)(&xs, t);
                assert_eq!(a, b, "n={n} t={t}");
                assert!(a <= n);
            }
        }
        // Exact-boundary semantics: strictly greater, not >=.
        assert_eq!((auto.find_first_gt)(&[1.0, 2.0], 1.0), 1);
        assert_eq!((auto.find_first_gt)(&[1.0, 2.0], 2.0), 2);
        // NaN threshold / elements compare false everywhere.
        assert_eq!((auto.find_first_gt)(&[1.0, 2.0], f64::NAN), 2);
        assert_eq!((auto.find_first_gt)(&[f64::NAN, 2.0], 1.0), 1);
    }

    #[test]
    fn compact_nonzero_bit_identical() {
        let auto = Kernels::auto();
        let mut rng = Pcg64::new(16);
        for &n in LENS {
            // Mostly zeros, like an integer Φ row.
            let xs: Vec<u32> = (0..n)
                .map(|_| if rng.below(4) == 0 { rng.below(50) as u32 + 1 } else { 0 })
                .collect();
            let mut a = vec![(1u32, 1u32)];
            let mut b = Vec::new();
            (Kernels::scalar().compact_nonzero_u32)(&xs, &mut a);
            (auto.compact_nonzero_u32)(&xs, &mut b);
            assert_eq!(a, b, "n={n}");
            assert!(a.iter().all(|&(i, c)| c > 0 && xs[i as usize] == c));
        }
    }

    #[test]
    fn tier_reporting_is_consistent() {
        let scalar = Kernels::scalar();
        assert_eq!(scalar.name(), "scalar");
        assert!(!scalar.is_accelerated());
        assert!(!Kernels::default().is_accelerated());
        let auto = Kernels::auto();
        if cfg!(not(feature = "simd")) {
            assert_eq!(
                auto.name(),
                "scalar",
                "simd feature off must resolve to the scalar tier"
            );
        }
        assert_eq!(auto.is_accelerated(), auto.name() != "scalar");
    }
}
