//! x86_64 kernel tiers (AVX2 and SSE2) for the [`super::Kernels`]
//! table. Compiled only with the `simd` cargo feature on x86_64; the
//! constructors here are called by [`super::Kernels::auto`] **after**
//! runtime feature detection, which is what makes the safe wrappers
//! sound (see the SAFETY notes on each).
//!
//! Every `#[target_feature]` body keeps the scalar operation order per
//! element (see the module bit-exactness policy): lanes change how
//! operands load and store, never which IEEE operation combines them —
//! except `sum_f64`, the documented multi-accumulator reduction.

use super::{ensure_f64_buf, Kernels};
use core::arch::x86_64::*;

/// AVX2 tier. Caller contract: `avx2` was detected at runtime.
pub(super) fn avx2() -> Kernels {
    debug_assert!(std::arch::is_x86_64_feature_detected!("avx2"));
    Kernels {
        sum_f64: sum_f64_avx2,
        scale_f64: scale_f64_avx2,
        gather_mul_u32: gather_mul_u32_avx2,
        gather_mul_f64: gather_mul_f64_avx2,
        partition_lt1: partition_lt1_avx2,
        find_first_gt: find_first_gt_avx2,
        compact_nonzero_u32: compact_nonzero_u32_avx2,
        ..Kernels::named("avx2")
    }
}

/// SSE2 tier: 128-bit f64 kernels; the gather/compact kernels (which
/// need AVX2 instructions to beat scalar) stay scalar. Caller contract:
/// `sse2` was detected at runtime (guaranteed on x86_64, but the ladder
/// checks anyway).
pub(super) fn sse2() -> Kernels {
    debug_assert!(std::arch::is_x86_64_feature_detected!("sse2"));
    Kernels {
        sum_f64: sum_f64_sse2,
        scale_f64: scale_f64_sse2,
        partition_lt1: partition_lt1_sse2,
        find_first_gt: find_first_gt_sse2,
        ..Kernels::named("sse2")
    }
}

// ---------------------------------------------------------------- AVX2

fn sum_f64_avx2(xs: &[f64]) -> f64 {
    // SAFETY: table constructed only after `avx2` runtime detection.
    unsafe { sum_f64_avx2_impl(xs) }
}

#[target_feature(enable = "avx2")]
unsafe fn sum_f64_avx2_impl(xs: &[f64]) -> f64 {
    let n = xs.len();
    let p = xs.as_ptr();
    let mut acc = _mm256_setzero_pd();
    let mut i = 0usize;
    while i + 4 <= n {
        acc = _mm256_add_pd(acc, _mm256_loadu_pd(p.add(i)));
        i += 4;
    }
    let lo = _mm256_castpd256_pd128(acc);
    let hi = _mm256_extractf128_pd::<1>(acc);
    let s2 = _mm_add_pd(lo, hi);
    let s1 = _mm_add_pd(s2, _mm_unpackhi_pd(s2, s2));
    let mut s = _mm_cvtsd_f64(s1);
    while i < n {
        s += *p.add(i);
        i += 1;
    }
    s
}

fn scale_f64_avx2(xs: &mut [f64], c: f64) {
    // SAFETY: table constructed only after `avx2` runtime detection.
    unsafe { scale_f64_avx2_impl(xs, c) }
}

#[target_feature(enable = "avx2")]
unsafe fn scale_f64_avx2_impl(xs: &mut [f64], c: f64) {
    let n = xs.len();
    let p = xs.as_mut_ptr();
    let cv = _mm256_set1_pd(c);
    let mut i = 0usize;
    while i + 4 <= n {
        _mm256_storeu_pd(p.add(i), _mm256_mul_pd(_mm256_loadu_pd(p.add(i)), cv));
        i += 4;
    }
    while i < n {
        *p.add(i) *= c;
        i += 1;
    }
}

/// Max over `idx` (0 for an empty slice) — the one-pass range check
/// that makes the safe gather wrappers sound.
#[target_feature(enable = "avx2")]
unsafe fn max_u32_avx2(idx: &[u32]) -> u32 {
    let n = idx.len();
    let p = idx.as_ptr();
    let mut maxv = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 8 <= n {
        maxv = _mm256_max_epu32(maxv, _mm256_loadu_si256(p.add(i) as *const __m256i));
        i += 8;
    }
    let mut tmp = [0u32; 8];
    _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, maxv);
    let mut mx = tmp.iter().copied().max().unwrap_or(0);
    while i < n {
        mx = mx.max(*p.add(i));
        i += 1;
    }
    mx
}

fn gather_mul_u32_avx2(idx: &[u32], probs: &[f64], counts: &[u32], out: &mut Vec<f64>) {
    assert_eq!(idx.len(), probs.len());
    // i32 gather offsets: the table itself must sit below 2^31 entries.
    assert!(counts.len() < (1usize << 31));
    ensure_f64_buf(out, idx.len());
    // SAFETY: table constructed only after `avx2` runtime detection;
    // the impl validates every index before gathering.
    unsafe { gather_mul_u32_avx2_impl(idx, probs, counts, &mut out[..idx.len()]) }
}

#[target_feature(enable = "avx2")]
unsafe fn gather_mul_u32_avx2_impl(idx: &[u32], probs: &[f64], counts: &[u32], out: &mut [f64]) {
    let n = idx.len();
    if n == 0 {
        return;
    }
    assert!(
        (max_u32_avx2(idx) as usize) < counts.len(),
        "gather index out of range"
    );
    let ip = idx.as_ptr();
    let pp = probs.as_ptr();
    let op = out.as_mut_ptr();
    let base = counts.as_ptr() as *const i32;
    let mut i = 0usize;
    while i + 8 <= n {
        let iv = _mm256_loadu_si256(ip.add(i) as *const __m256i);
        // 8 × u32 counts; values are per-document token counts < 2^31,
        // so the signed i32 → f64 conversion below is exact.
        let cv = _mm256_i32gather_epi32::<4>(base, iv);
        let flo = _mm256_cvtepi32_pd(_mm256_castsi256_si128(cv));
        let fhi = _mm256_cvtepi32_pd(_mm256_extracti128_si256::<1>(cv));
        let r0 = _mm256_mul_pd(_mm256_loadu_pd(pp.add(i)), flo);
        let r1 = _mm256_mul_pd(_mm256_loadu_pd(pp.add(i + 4)), fhi);
        _mm256_storeu_pd(op.add(i), r0);
        _mm256_storeu_pd(op.add(i + 4), r1);
        i += 8;
    }
    while i < n {
        let k = *ip.add(i) as usize;
        *op.add(i) = *pp.add(i) * *counts.get_unchecked(k) as f64;
        i += 1;
    }
}

fn gather_mul_f64_avx2(idx: &[u32], probs: &[f64], scale: f64, src: &[f64], out: &mut Vec<f64>) {
    assert_eq!(idx.len(), probs.len());
    assert!(src.len() < (1usize << 31));
    ensure_f64_buf(out, idx.len());
    // SAFETY: table constructed only after `avx2` runtime detection;
    // the impl validates every index before gathering.
    unsafe { gather_mul_f64_avx2_impl(idx, probs, scale, src, &mut out[..idx.len()]) }
}

#[target_feature(enable = "avx2")]
unsafe fn gather_mul_f64_avx2_impl(
    idx: &[u32],
    probs: &[f64],
    scale: f64,
    src: &[f64],
    out: &mut [f64],
) {
    let n = idx.len();
    if n == 0 {
        return;
    }
    assert!(
        (max_u32_avx2(idx) as usize) < src.len(),
        "gather index out of range"
    );
    let ip = idx.as_ptr();
    let pp = probs.as_ptr();
    let op = out.as_mut_ptr();
    let sv = _mm256_set1_pd(scale);
    let mut i = 0usize;
    while i + 4 <= n {
        let iv = _mm_loadu_si128(ip.add(i) as *const __m128i);
        let g = _mm256_i32gather_pd::<8>(src.as_ptr(), iv);
        let pv = _mm256_mul_pd(_mm256_loadu_pd(pp.add(i)), sv);
        _mm256_storeu_pd(op.add(i), _mm256_mul_pd(pv, g));
        i += 4;
    }
    while i < n {
        let k = *ip.add(i) as usize;
        *op.add(i) = *pp.add(i) * scale * *src.get_unchecked(k);
        i += 1;
    }
}

fn partition_lt1_avx2(xs: &[f64], small: &mut Vec<u32>, large: &mut Vec<u32>) {
    small.clear();
    large.clear();
    small.reserve(xs.len());
    large.reserve(xs.len());
    // SAFETY: table constructed only after `avx2` runtime detection.
    unsafe { partition_lt1_avx2_impl(xs, small, large) }
}

#[target_feature(enable = "avx2")]
unsafe fn partition_lt1_avx2_impl(xs: &[f64], small: &mut Vec<u32>, large: &mut Vec<u32>) {
    let n = xs.len();
    let p = xs.as_ptr();
    let one = _mm256_set1_pd(1.0);
    let mut i = 0usize;
    while i + 4 <= n {
        let m = _mm256_movemask_pd(_mm256_cmp_pd::<_CMP_LT_OQ>(_mm256_loadu_pd(p.add(i)), one))
            as u32;
        for j in 0..4u32 {
            let at = i as u32 + j;
            if m & (1 << j) != 0 {
                small.push(at);
            } else {
                large.push(at);
            }
        }
        i += 4;
    }
    while i < n {
        if *p.add(i) < 1.0 {
            small.push(i as u32);
        } else {
            large.push(i as u32);
        }
        i += 1;
    }
}

fn find_first_gt_avx2(xs: &[f64], t: f64) -> usize {
    // SAFETY: table constructed only after `avx2` runtime detection.
    unsafe { find_first_gt_avx2_impl(xs, t) }
}

#[target_feature(enable = "avx2")]
unsafe fn find_first_gt_avx2_impl(xs: &[f64], t: f64) -> usize {
    let n = xs.len();
    let p = xs.as_ptr();
    let tv = _mm256_set1_pd(t);
    let mut i = 0usize;
    while i + 4 <= n {
        let m = _mm256_movemask_pd(_mm256_cmp_pd::<_CMP_GT_OQ>(_mm256_loadu_pd(p.add(i)), tv));
        if m != 0 {
            return i + m.trailing_zeros() as usize;
        }
        i += 4;
    }
    while i < n {
        if *p.add(i) > t {
            return i;
        }
        i += 1;
    }
    n
}

fn compact_nonzero_u32_avx2(xs: &[u32], out: &mut Vec<(u32, u32)>) {
    out.clear();
    // SAFETY: table constructed only after `avx2` runtime detection.
    unsafe { compact_nonzero_u32_avx2_impl(xs, out) }
}

#[target_feature(enable = "avx2")]
unsafe fn compact_nonzero_u32_avx2_impl(xs: &[u32], out: &mut Vec<(u32, u32)>) {
    let n = xs.len();
    let p = xs.as_ptr();
    let zero = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 8 <= n {
        let v = _mm256_loadu_si256(p.add(i) as *const __m256i);
        // movemask bit j = sign bit of lane j of the all-ones compare
        // result, i.e. "lane j is zero".
        let zmask = _mm256_movemask_ps(_mm256_castsi256_ps(_mm256_cmpeq_epi32(v, zero))) as u32;
        let nz = !zmask & 0xff;
        if nz != 0 {
            let mut tmp = [0u32; 8];
            _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, v);
            for j in 0..8usize {
                if nz & (1 << j) != 0 {
                    out.push(((i + j) as u32, tmp[j]));
                }
            }
        }
        i += 8;
    }
    while i < n {
        let c = *p.add(i);
        if c > 0 {
            out.push((i as u32, c));
        }
        i += 1;
    }
}

// ---------------------------------------------------------------- SSE2

fn sum_f64_sse2(xs: &[f64]) -> f64 {
    // SAFETY: table constructed only after `sse2` runtime detection.
    unsafe { sum_f64_sse2_impl(xs) }
}

#[target_feature(enable = "sse2")]
unsafe fn sum_f64_sse2_impl(xs: &[f64]) -> f64 {
    let n = xs.len();
    let p = xs.as_ptr();
    let mut acc = _mm_setzero_pd();
    let mut i = 0usize;
    while i + 2 <= n {
        acc = _mm_add_pd(acc, _mm_loadu_pd(p.add(i)));
        i += 2;
    }
    let s1 = _mm_add_pd(acc, _mm_unpackhi_pd(acc, acc));
    let mut s = _mm_cvtsd_f64(s1);
    while i < n {
        s += *p.add(i);
        i += 1;
    }
    s
}

fn scale_f64_sse2(xs: &mut [f64], c: f64) {
    // SAFETY: table constructed only after `sse2` runtime detection.
    unsafe { scale_f64_sse2_impl(xs, c) }
}

#[target_feature(enable = "sse2")]
unsafe fn scale_f64_sse2_impl(xs: &mut [f64], c: f64) {
    let n = xs.len();
    let p = xs.as_mut_ptr();
    let cv = _mm_set1_pd(c);
    let mut i = 0usize;
    while i + 2 <= n {
        _mm_storeu_pd(p.add(i), _mm_mul_pd(_mm_loadu_pd(p.add(i)), cv));
        i += 2;
    }
    while i < n {
        *p.add(i) *= c;
        i += 1;
    }
}

fn partition_lt1_sse2(xs: &[f64], small: &mut Vec<u32>, large: &mut Vec<u32>) {
    small.clear();
    large.clear();
    small.reserve(xs.len());
    large.reserve(xs.len());
    // SAFETY: table constructed only after `sse2` runtime detection.
    unsafe { partition_lt1_sse2_impl(xs, small, large) }
}

#[target_feature(enable = "sse2")]
unsafe fn partition_lt1_sse2_impl(xs: &[f64], small: &mut Vec<u32>, large: &mut Vec<u32>) {
    let n = xs.len();
    let p = xs.as_ptr();
    let one = _mm_set1_pd(1.0);
    let mut i = 0usize;
    while i + 2 <= n {
        let m = _mm_movemask_pd(_mm_cmplt_pd(_mm_loadu_pd(p.add(i)), one)) as u32;
        for j in 0..2u32 {
            let at = i as u32 + j;
            if m & (1 << j) != 0 {
                small.push(at);
            } else {
                large.push(at);
            }
        }
        i += 2;
    }
    while i < n {
        if *p.add(i) < 1.0 {
            small.push(i as u32);
        } else {
            large.push(i as u32);
        }
        i += 1;
    }
}

fn find_first_gt_sse2(xs: &[f64], t: f64) -> usize {
    // SAFETY: table constructed only after `sse2` runtime detection.
    unsafe { find_first_gt_sse2_impl(xs, t) }
}

#[target_feature(enable = "sse2")]
unsafe fn find_first_gt_sse2_impl(xs: &[f64], t: f64) -> usize {
    let n = xs.len();
    let p = xs.as_ptr();
    let tv = _mm_set1_pd(t);
    let mut i = 0usize;
    while i + 2 <= n {
        let m = _mm_movemask_pd(_mm_cmpgt_pd(_mm_loadu_pd(p.add(i)), tv));
        if m != 0 {
            return i + m.trailing_zeros() as usize;
        }
        i += 2;
    }
    while i < n {
        if *p.add(i) > t {
            return i;
        }
        i += 1;
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every tier this CPU supports must agree bit-for-bit with scalar
    /// on the compare/elementwise kernels (the module-level tests cover
    /// `auto()`; this pins the tiers individually, so an AVX2 machine
    /// still exercises the SSE2 code).
    #[test]
    fn each_supported_tier_matches_scalar() {
        let mut tiers = Vec::new();
        if std::arch::is_x86_64_feature_detected!("avx2") {
            tiers.push(avx2());
        }
        if std::arch::is_x86_64_feature_detected!("sse2") {
            tiers.push(sse2());
        }
        let scalar = Kernels::scalar();
        let xs: Vec<f64> = (0..37).map(|i| 0.03 * i as f64).collect();
        for tier in tiers {
            for t in [-1.0, 0.0, 0.5, 0.09, 1.07, 100.0] {
                assert_eq!(
                    (tier.find_first_gt)(&xs, t),
                    (scalar.find_first_gt)(&xs, t),
                    "tier={} t={t}",
                    tier.name()
                );
            }
            let (mut s1, mut l1) = (Vec::new(), Vec::new());
            let (mut s2, mut l2) = (Vec::new(), Vec::new());
            (scalar.partition_lt1)(&xs, &mut s1, &mut l1);
            (tier.partition_lt1)(&xs, &mut s2, &mut l2);
            assert_eq!((s1, l1), (s2, l2), "tier={}", tier.name());
            let (mut a, mut b) = (xs.clone(), xs.clone());
            (scalar.scale_f64)(&mut a, 1.7);
            (tier.scale_f64)(&mut b, 1.7);
            assert_eq!(a, b, "tier={}", tier.name());
        }
    }
}
