//! Special functions needed by the rejection samplers and the
//! log-likelihood diagnostics: `ln Γ(x)`, log-factorial, and digamma.
//!
//! `ln_gamma` uses the Lanczos approximation (g = 7, n = 9 coefficients,
//! |relative error| < 2e-10 over the positive reals), which is accurate
//! enough for every consumer in this crate (PTRS/BTRS acceptance tests
//! and marginal-likelihood traces).

/// Lanczos coefficients for g = 7.
const LANCZOS_G: f64 = 7.0;
const LANCZOS: [f64; 9] = [
    0.999_999_999_999_809_93,
    676.520_368_121_885_1,
    -1_259.139_216_722_402_8,
    771.323_428_777_653_13,
    -176.615_029_162_140_6,
    12.507_343_278_686_905,
    -0.138_571_095_265_720_12,
    9.984_369_578_019_571_6e-6,
    1.505_632_735_149_311_6e-7,
];

/// Natural log of the Gamma function for `x > 0`.
pub fn ln_gamma(x: f64) -> f64 {
    debug_assert!(x > 0.0, "ln_gamma domain: x > 0, got {x}");
    if x < 0.5 {
        // Reflection: Γ(x)Γ(1−x) = π / sin(πx)
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut acc = LANCZOS[0];
    for (i, &c) in LANCZOS.iter().enumerate().skip(1) {
        acc += c / (x + i as f64);
    }
    let t = x + LANCZOS_G + 0.5;
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + acc.ln()
}

/// Size of the precomputed `ln n!` table. Factorials up to this bound are
/// looked up; larger ones fall through to `ln_gamma`.
pub const LN_FACT_TABLE: usize = 1024;

/// `ln(n!)` with a small-n lookup table (built lazily per thread would
/// complicate the API; a process-wide `OnceLock` table is enough).
pub fn ln_factorial(n: u64) -> f64 {
    use std::sync::OnceLock;
    static TABLE: OnceLock<Vec<f64>> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = vec![0.0f64; LN_FACT_TABLE];
        for i in 2..LN_FACT_TABLE {
            t[i] = t[i - 1] + (i as f64).ln();
        }
        t
    });
    if (n as usize) < LN_FACT_TABLE {
        table[n as usize]
    } else {
        ln_gamma(n as f64 + 1.0)
    }
}

/// `ln B(a, b)` — log Beta function.
pub fn ln_beta(a: f64, b: f64) -> f64 {
    ln_gamma(a) + ln_gamma(b) - ln_gamma(a + b)
}

/// Digamma ψ(x) via the asymptotic series with upward recurrence.
/// Used by hyperparameter diagnostics.
pub fn digamma(x: f64) -> f64 {
    debug_assert!(x > 0.0);
    let mut x = x;
    let mut acc = 0.0;
    while x < 6.0 {
        acc -= 1.0 / x;
        x += 1.0;
    }
    let inv = 1.0 / x;
    let inv2 = inv * inv;
    acc + x.ln() - 0.5 * inv
        - inv2
            * (1.0 / 12.0
                - inv2 * (1.0 / 120.0 - inv2 * (1.0 / 252.0 - inv2 * (1.0 / 240.0))))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_matches_known_values() {
        // Γ(1) = Γ(2) = 1, Γ(5) = 24, Γ(0.5) = sqrt(pi)
        assert!((ln_gamma(1.0)).abs() < 1e-12);
        assert!((ln_gamma(2.0)).abs() < 1e-12);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-10);
        assert!((ln_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-10);
        // Recurrence Γ(x+1) = xΓ(x) at a non-integer point
        let x = 3.7;
        assert!((ln_gamma(x + 1.0) - (x.ln() + ln_gamma(x))).abs() < 1e-10);
    }

    #[test]
    fn ln_factorial_consistent() {
        assert_eq!(ln_factorial(0), 0.0);
        assert_eq!(ln_factorial(1), 0.0);
        assert!((ln_factorial(5) - 120f64.ln()).abs() < 1e-10);
        // across the table boundary
        let big = (LN_FACT_TABLE + 5) as u64;
        assert!((ln_factorial(big) - ln_gamma(big as f64 + 1.0)).abs() < 1e-8);
        // table vs ln_gamma agreement inside the table
        assert!((ln_factorial(1000) - ln_gamma(1001.0)).abs() < 1e-7);
    }

    #[test]
    fn digamma_matches_known_values() {
        const EULER: f64 = 0.577_215_664_901_532_9;
        assert!((digamma(1.0) + EULER).abs() < 1e-9);
        // ψ(x+1) = ψ(x) + 1/x
        let x = 2.3;
        assert!((digamma(x + 1.0) - digamma(x) - 1.0 / x).abs() < 1e-9);
    }

    #[test]
    fn ln_beta_symmetry() {
        assert!((ln_beta(2.0, 3.0) - ln_beta(3.0, 2.0)).abs() < 1e-12);
        // B(1,1) = 1
        assert!(ln_beta(1.0, 1.0).abs() < 1e-12);
        // B(2,3) = 1/12
        assert!((ln_beta(2.0, 3.0) - (1.0f64 / 12.0).ln()).abs() < 1e-10);
    }
}
