//! Non-uniform distribution samplers built on [`Pcg64`].
//!
//! Every Gibbs step of the HDP sampler reduces to draws from this
//! module:
//!
//! * `Ψ` stick-breaking — [`beta`] (via [`gamma`]);
//! * `Φ` Poisson Pólya urn — [`poisson`] (inversion + PTRS);
//! * `l` binomial trick — [`binomial`] (BINV inversion + BTRS);
//! * exact `Φ` Gibbs step — [`dirichlet`];
//! * `z` indicators — categorical draws ([`categorical`] for the dense
//!   fallback; the alias tables in [`crate::alias`] for the fast path).
//!
//! Rejection samplers follow Hörmann's transformed-rejection family
//! (BTRS for binomial, PTRS for Poisson) and Marsaglia–Tsang for Gamma;
//! all are exact (not approximations) up to floating point.

use super::special::ln_factorial;
use super::Pcg64;

/// Standard normal via the Marsaglia polar method.
pub fn std_normal(rng: &mut Pcg64) -> f64 {
    loop {
        let u = 2.0 * rng.f64() - 1.0;
        let v = 2.0 * rng.f64() - 1.0;
        let s = u * u + v * v;
        if s > 0.0 && s < 1.0 {
            return u * ((-2.0 * s.ln()) / s).sqrt();
        }
    }
}

/// Gamma(shape, 1) via Marsaglia–Tsang (2000); `shape > 0`.
///
/// For `shape < 1` uses the boost `Γ(a) = Γ(a+1)·U^{1/a}` (Johnk-style
/// correction), which is exact.
pub fn gamma(rng: &mut Pcg64, shape: f64) -> f64 {
    debug_assert!(shape > 0.0, "gamma shape must be > 0, got {shape}");
    if shape < 1.0 {
        // Boost: draw Gamma(shape+1) and scale by U^(1/shape).
        let g = gamma(rng, shape + 1.0);
        let u = rng.f64_open();
        return g * u.powf(1.0 / shape);
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (3.0 * d.sqrt());
    loop {
        let x = std_normal(rng);
        let t = 1.0 + c * x;
        if t <= 0.0 {
            continue;
        }
        let v = t * t * t;
        let u = rng.f64_open();
        let x2 = x * x;
        if u < 1.0 - 0.0331 * x2 * x2 {
            return d * v;
        }
        if u.ln() < 0.5 * x2 + d * (1.0 - v + v.ln()) {
            return d * v;
        }
    }
}

/// Gamma(shape, scale).
#[inline]
pub fn gamma_scaled(rng: &mut Pcg64, shape: f64, scale: f64) -> f64 {
    gamma(rng, shape) * scale
}

/// Beta(a, b) via two Gamma draws. Exact for all `a, b > 0`.
pub fn beta(rng: &mut Pcg64, a: f64, b: f64) -> f64 {
    debug_assert!(a > 0.0 && b > 0.0);
    let x = gamma(rng, a);
    let y = gamma(rng, b);
    let s = x + y;
    if s <= 0.0 {
        // Underflow corner (a, b both tiny): fall back to the Bernoulli
        // limit of the Beta distribution.
        return if rng.bernoulli(a / (a + b)) { 1.0 } else { 0.0 };
    }
    x / s
}

/// Threshold on `n·min(p,1−p)` below which binomial sampling uses BINV
/// inversion; above it, BTRS transformed rejection.
const BINV_CUTOFF: f64 = 10.0;

/// Binomial(n, p) — exact.
///
/// * small `n·min(p,1−p)`: BINV sequential inversion (Kachitvichyanukul
///   & Schmeiser 1988), O(n·p) expected;
/// * otherwise: BTRS transformed rejection (Hörmann 1993), O(1)
///   expected.
///
/// This is the hot call of the `l` "binomial trick" step (eq. 28 of the
/// paper): one draw per (topic, per-document-count-level) pair.
pub fn binomial(rng: &mut Pcg64, n: u64, p: f64) -> u64 {
    debug_assert!((0.0..=1.0).contains(&p), "binomial p in [0,1], got {p}");
    if n == 0 || p <= 0.0 {
        return 0;
    }
    if p >= 1.0 {
        return n;
    }
    // Work with q = min(p, 1-p), flip at the end.
    let flipped = p > 0.5;
    let q = if flipped { 1.0 - p } else { p };
    let k = if (n as f64) * q < BINV_CUTOFF {
        binomial_binv(rng, n, q)
    } else {
        binomial_btrs(rng, n, q)
    };
    if flipped {
        n - k
    } else {
        k
    }
}

/// BINV: CDF inversion by sequential search from 0. Requires `p <= 0.5`
/// and moderate `n·p` (expected work ~ n·p).
fn binomial_binv(rng: &mut Pcg64, n: u64, p: f64) -> u64 {
    let q = 1.0 - p;
    let s = p / q;
    let a = (n + 1) as f64 * s;
    let mut r = q.powf(n as f64);
    if r <= 0.0 {
        // q^n underflowed (large n, p near 0.5 shouldn't reach here, but
        // guard anyway): fall back to summing Bernoullis in blocks.
        let mut k = 0;
        for _ in 0..n {
            if rng.bernoulli(p) {
                k += 1;
            }
        }
        return k;
    }
    let mut u = rng.f64();
    let mut x = 0u64;
    loop {
        if u < r {
            return x;
        }
        u -= r;
        x += 1;
        if x > n {
            // numerical tail leak: retry
            u = rng.f64();
            x = 0;
            r = q.powf(n as f64);
            continue;
        }
        r *= a / x as f64 - s;
    }
}

/// BTRS: transformed rejection with squeeze (Hörmann 1993), `p <= 0.5`,
/// `n·p >= 10`.
fn binomial_btrs(rng: &mut Pcg64, n: u64, p: f64) -> u64 {
    let nf = n as f64;
    let q = 1.0 - p;
    let spq = (nf * p * q).sqrt();
    let b = 1.15 + 2.53 * spq;
    let a = -0.0873 + 0.0248 * b + 0.01 * p;
    let c = nf * p + 0.5;
    let v_r = 0.92 - 4.2 / b;
    let alpha = (2.83 + 5.1 / b) * spq;
    let lpq = (p / q).ln();
    let m = ((nf + 1.0) * p).floor();
    let h = ln_factorial(m as u64) + ln_factorial(n - m as u64);
    loop {
        let u = rng.f64() - 0.5;
        let mut v = rng.f64();
        let us = 0.5 - u.abs();
        let kf = ((2.0 * a / us + b) * u + c).floor();
        if kf < 0.0 || kf > nf {
            continue;
        }
        let k = kf as u64;
        if us >= 0.07 && v <= v_r {
            return k;
        }
        v = (v * alpha / (a / (us * us) + b)).ln();
        let accept =
            h - ln_factorial(k) - ln_factorial(n - k) + (kf - m) * lpq;
        if v <= accept {
            return k;
        }
    }
}

/// Threshold below which Poisson sampling uses multiplication/inversion.
const POISSON_INV_CUTOFF: f64 = 10.0;

/// Poisson(λ) — exact.
///
/// * `λ < 10`: inversion by sequential search (O(λ) expected);
/// * `λ ≥ 10`: PTRS transformed rejection (Hörmann 1993), O(1) expected.
///
/// This is the hot call of the Poisson Pólya urn `Φ` step: one draw per
/// nonzero of the topic-word statistic `n` plus one per β-process point.
pub fn poisson(rng: &mut Pcg64, lambda: f64) -> u64 {
    debug_assert!(lambda >= 0.0);
    if lambda == 0.0 {
        return 0;
    }
    if lambda < POISSON_INV_CUTOFF {
        poisson_inversion(rng, lambda)
    } else {
        poisson_ptrs(rng, lambda)
    }
}

fn poisson_inversion(rng: &mut Pcg64, lambda: f64) -> u64 {
    let mut x = 0u64;
    let mut p = (-lambda).exp();
    let mut s = p;
    let u = rng.f64();
    while u > s {
        x += 1;
        p *= lambda / x as f64;
        s += p;
        if x > 10_000 {
            break; // numerically impossible tail
        }
    }
    x
}

/// PTRS transformed rejection for λ ≥ 10.
fn poisson_ptrs(rng: &mut Pcg64, lambda: f64) -> u64 {
    let slam = lambda.sqrt();
    let loglam = lambda.ln();
    let b = 0.931 + 2.53 * slam;
    let a = -0.059 + 0.02483 * b;
    let inv_alpha = 1.1239 + 1.1328 / (b - 3.4);
    let v_r = 0.9277 - 3.6224 / (b - 2.0);
    loop {
        let u = rng.f64() - 0.5;
        let v = rng.f64();
        let us = 0.5 - u.abs();
        let kf = ((2.0 * a / us + b) * u + lambda + 0.43).floor();
        if us >= 0.07 && v <= v_r {
            return kf as u64;
        }
        if kf < 0.0 || (us < 0.013 && v > us) {
            continue;
        }
        let k = kf as u64;
        if (v * inv_alpha / (a / (us * us) + b)).ln()
            <= kf * loglam - lambda - ln_factorial(k)
        {
            return k;
        }
    }
}

/// Dirichlet(α) sample written into `out` (same length as `alpha`).
/// Exact via normalized Gammas. Used by the *exact* (non-PPU) Φ step
/// and by the synthetic-corpus generators.
pub fn dirichlet_into(rng: &mut Pcg64, alpha: &[f64], out: &mut [f64]) {
    debug_assert_eq!(alpha.len(), out.len());
    let mut sum = 0.0;
    for (o, &a) in out.iter_mut().zip(alpha) {
        let g = gamma(rng, a);
        *o = g;
        sum += g;
    }
    if sum <= 0.0 {
        // All gammas underflowed (all alphas tiny): put mass on one
        // coordinate chosen ∝ alpha — the correct limiting behaviour.
        let tot: f64 = alpha.iter().sum();
        let mut u = rng.f64() * tot;
        out.iter_mut().for_each(|o| *o = 0.0);
        for (o, &a) in out.iter_mut().zip(alpha) {
            u -= a;
            if u <= 0.0 {
                *o = 1.0;
                return;
            }
        }
        *out.last_mut().unwrap() = 1.0;
        return;
    }
    for o in out.iter_mut() {
        *o /= sum;
    }
}

/// Symmetric Dirichlet(β, …, β) of dimension `dim`.
pub fn symmetric_dirichlet(rng: &mut Pcg64, beta: f64, dim: usize) -> Vec<f64> {
    let alpha = vec![beta; dim];
    let mut out = vec![0.0; dim];
    dirichlet_into(rng, &alpha, &mut out);
    out
}

/// Categorical draw from (unnormalized) nonnegative weights by linear
/// scan. O(k). The alias table ([`crate::alias`]) replaces this on hot
/// paths; this is the reference/fallback.
pub fn categorical(rng: &mut Pcg64, weights: &[f64]) -> usize {
    let total: f64 = weights.iter().sum();
    debug_assert!(total > 0.0, "categorical needs positive total mass");
    let mut u = rng.f64() * total;
    for (i, &w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return i;
        }
    }
    weights.len() - 1
}

/// Draw from a discrete distribution given cumulative weights
/// (`cum[i] = w_0 + … + w_i`). O(log k) binary search.
pub fn categorical_cum(rng: &mut Pcg64, cum: &[f64]) -> usize {
    let total = *cum.last().expect("nonempty");
    let u = rng.f64() * total;
    match cum.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
        Ok(i) => i + 1,
        Err(i) => i,
    }
    .min(cum.len() - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        (mean, var)
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(1);
        let xs: Vec<f64> = (0..200_000).map(|_| std_normal(&mut rng)).collect();
        let (m, v) = moments(&xs);
        assert!(m.abs() < 0.01, "mean={m}");
        assert!((v - 1.0).abs() < 0.02, "var={v}");
    }

    #[test]
    fn gamma_moments_large_and_small_shape() {
        let mut rng = Pcg64::new(2);
        for &shape in &[0.1, 0.5, 1.0, 2.5, 10.0] {
            let xs: Vec<f64> = (0..100_000).map(|_| gamma(&mut rng, shape)).collect();
            let (m, v) = moments(&xs);
            assert!(
                (m - shape).abs() < 0.06 * shape.max(0.3),
                "shape {shape}: mean {m}"
            );
            assert!(
                (v - shape).abs() < 0.12 * shape.max(0.5),
                "shape {shape}: var {v}"
            );
            assert!(xs.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn beta_moments() {
        let mut rng = Pcg64::new(3);
        for &(a, b) in &[(1.0, 1.0), (2.0, 5.0), (0.5, 0.5), (1.0, 9.0)] {
            let xs: Vec<f64> = (0..100_000).map(|_| beta(&mut rng, a, b)).collect();
            let (m, v) = moments(&xs);
            let want_m = a / (a + b);
            let want_v = a * b / ((a + b) * (a + b) * (a + b + 1.0));
            assert!((m - want_m).abs() < 0.005, "Beta({a},{b}) mean {m} vs {want_m}");
            assert!((v - want_v).abs() < 0.005, "Beta({a},{b}) var {v} vs {want_v}");
        }
    }

    #[test]
    fn binomial_moments_small_and_large() {
        let mut rng = Pcg64::new(4);
        // (n, p) pairs covering BINV, BTRS, and the p>0.5 flip.
        for &(n, p) in &[(20u64, 0.1), (1000, 0.3), (1000, 0.9), (50, 0.5), (7, 0.99)] {
            let xs: Vec<f64> =
                (0..60_000).map(|_| binomial(&mut rng, n, p) as f64).collect();
            let (m, v) = moments(&xs);
            let want_m = n as f64 * p;
            let want_v = n as f64 * p * (1.0 - p);
            assert!(
                (m - want_m).abs() < 4.0 * (want_v / 60_000.0).sqrt() + 0.02,
                "Bin({n},{p}) mean {m} vs {want_m}"
            );
            assert!(
                (v - want_v).abs() < 0.05 * want_v.max(1.0),
                "Bin({n},{p}) var {v} vs {want_v}"
            );
            assert!(xs.iter().all(|&x| x <= n as f64));
        }
    }

    #[test]
    fn binomial_edge_cases() {
        let mut rng = Pcg64::new(5);
        assert_eq!(binomial(&mut rng, 0, 0.5), 0);
        assert_eq!(binomial(&mut rng, 10, 0.0), 0);
        assert_eq!(binomial(&mut rng, 10, 1.0), 10);
    }

    #[test]
    fn binomial_exact_pmf_chi2() {
        // χ² against the exact Bin(8, 0.3) pmf.
        let mut rng = Pcg64::new(6);
        let (n, p) = (8u64, 0.3);
        let trials = 80_000usize;
        let mut counts = [0usize; 9];
        for _ in 0..trials {
            counts[binomial(&mut rng, n, p) as usize] += 1;
        }
        let mut chi2 = 0.0;
        for k in 0..=8u64 {
            let lp = ln_factorial(n) - ln_factorial(k) - ln_factorial(n - k)
                + k as f64 * p.ln()
                + (n - k) as f64 * (1.0 - p).ln();
            let e = trials as f64 * lp.exp();
            let o = counts[k as usize] as f64;
            chi2 += (o - e) * (o - e) / e.max(1e-9);
        }
        // 8 dof, 99.9th percentile ≈ 26.1
        assert!(chi2 < 26.1, "chi2={chi2}");
    }

    #[test]
    fn poisson_moments_small_and_large() {
        let mut rng = Pcg64::new(7);
        for &lam in &[0.1, 1.0, 5.0, 9.99, 10.0, 40.0, 500.0] {
            let xs: Vec<f64> =
                (0..60_000).map(|_| poisson(&mut rng, lam) as f64).collect();
            let (m, v) = moments(&xs);
            assert!(
                (m - lam).abs() < 4.0 * (lam / 60_000.0).sqrt() + 0.02 * lam.max(0.1),
                "Pois({lam}) mean {m}"
            );
            assert!((v - lam).abs() < 0.06 * lam.max(1.0), "Pois({lam}) var {v}");
        }
        assert_eq!(poisson(&mut rng, 0.0), 0);
    }

    #[test]
    fn poisson_exact_pmf_chi2() {
        let mut rng = Pcg64::new(8);
        let lam = 3.5f64;
        let trials = 80_000usize;
        let kmax = 14usize;
        let mut counts = vec![0usize; kmax + 2];
        for _ in 0..trials {
            let k = poisson(&mut rng, lam) as usize;
            counts[k.min(kmax + 1)] += 1;
        }
        let mut chi2 = 0.0;
        let mut tail = trials as f64;
        for k in 0..=kmax {
            let lp = k as f64 * lam.ln() - lam - ln_factorial(k as u64);
            let e = trials as f64 * lp.exp();
            tail -= e;
            let o = counts[k] as f64;
            chi2 += (o - e) * (o - e) / e.max(1e-9);
        }
        let o = counts[kmax + 1] as f64;
        chi2 += (o - tail) * (o - tail) / tail.max(1e-9);
        // 15 dof, 99.9th percentile ≈ 37.7
        assert!(chi2 < 37.7, "chi2={chi2}");
    }

    #[test]
    fn dirichlet_means_and_simplex() {
        let mut rng = Pcg64::new(9);
        let alpha = [1.0, 2.0, 7.0];
        let mut acc = [0.0f64; 3];
        let reps = 40_000;
        for _ in 0..reps {
            let mut out = [0.0; 3];
            dirichlet_into(&mut rng, &alpha, &mut out);
            let s: f64 = out.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            for i in 0..3 {
                acc[i] += out[i];
            }
        }
        let tot: f64 = alpha.iter().sum();
        for i in 0..3 {
            let want = alpha[i] / tot;
            let got = acc[i] / reps as f64;
            assert!((got - want).abs() < 0.01, "dim {i}: {got} vs {want}");
        }
    }

    #[test]
    fn categorical_matches_weights() {
        let mut rng = Pcg64::new(10);
        let w = [0.1, 0.0, 0.4, 0.5];
        let mut counts = [0usize; 4];
        let n = 100_000;
        for _ in 0..n {
            counts[categorical(&mut rng, &w)] += 1;
        }
        assert_eq!(counts[1], 0);
        for i in [0usize, 2, 3] {
            let got = counts[i] as f64 / n as f64;
            assert!((got - w[i]).abs() < 0.01, "{i}: {got}");
        }
        // cumulative variant agrees
        let cum = [0.1, 0.1, 0.5, 1.0];
        let mut counts2 = [0usize; 4];
        for _ in 0..n {
            counts2[categorical_cum(&mut rng, &cum)] += 1;
        }
        assert_eq!(counts2[1], 0);
        for i in [0usize, 2, 3] {
            let got = counts2[i] as f64 / n as f64;
            assert!((got - w[i]).abs() < 0.01, "cum {i}: {got}");
        }
    }
}
