//! Pseudo-random number generation.
//!
//! The sampler needs (i) a fast, high-quality core generator, (ii)
//! *independent streams* so that every document / topic / shard can be
//! given its own deterministic generator (this is what makes parallel
//! runs reproducible and shard-count invariant), and (iii) a set of
//! non-uniform distribution samplers (Gamma, Beta, Binomial, Poisson,
//! Dirichlet, …) that the HDP Gibbs steps are built from.
//!
//! No external crates are available in this environment, so the whole
//! stack is implemented here from scratch:
//!
//! * [`Pcg64`] — PCG-XSL-RR 128/64 (O'Neill 2014). 128-bit LCG state,
//!   64-bit output, distinct odd increments give independent streams.
//! * [`SplitMix64`] — tiny seeding generator used to expand user seeds
//!   into full PCG states and to hash stream ids.
//! * [`dist`] — the distribution samplers.
//! * [`special`] — `ln_gamma` and log-factorial machinery used by the
//!   rejection samplers.

pub mod dist;
pub mod special;

/// SplitMix64 (Steele et al. 2014). Used only for seeding/stream hashing;
/// passes through every 64-bit value exactly once per period.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a seeding generator from an arbitrary 64-bit seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

/// PCG-XSL-RR 128/64: a 128-bit linear congruential generator with an
/// xorshift-low + random-rotate output function. Period 2^128 per
/// stream; 2^127 distinct streams selected by the (odd) increment.
///
/// This is the generator used for *all* sampling in the crate. Every
/// logical actor (document, topic row, shard) derives its own stream
/// via [`Pcg64::stream`], which makes chains bit-reproducible under any
/// shard layout.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128, // odd
}

impl Pcg64 {
    /// Seed from a 64-bit seed (expanded through SplitMix64) on the
    /// default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0)
    }

    /// Seed from a 64-bit seed on stream `stream`. Streams with
    /// different ids are statistically independent sequences.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next_u64();
        let s1 = sm.next_u64();
        let mut sm2 = SplitMix64::new(stream ^ 0xDA3E_39CB_94B9_5BDB);
        let i0 = sm2.next_u64();
        let i1 = sm2.next_u64();
        let state = ((s0 as u128) << 64) | s1 as u128;
        let inc = ((((i0 as u128) << 64) | i1 as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_add(state);
        rng.step();
        rng
    }

    /// Derive a child generator for stream `id`, deterministically from
    /// this generator's current state *without* advancing it in a way
    /// that depends on `id`. Children of distinct ids are independent.
    pub fn stream(&self, id: u64) -> Pcg64 {
        // Hash the current increment + id into a fresh (seed, stream).
        let mut sm = SplitMix64::new((self.inc >> 1) as u64 ^ id.rotate_left(17));
        let seed = sm.next_u64() ^ (self.state as u64);
        Pcg64::with_stream(seed, id)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let s = self.state;
        let xored = ((s >> 64) as u64) ^ (s as u64);
        let rot = (s >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Next 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `(0, 1]` — safe as an argument to `ln`.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift with
    /// rejection (unbiased).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Bernoulli draw with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(43);
        let eq = (0..100).filter(|_| a.next_u64() == c.next_u64()).count();
        assert!(eq < 3, "different seeds should disagree");
    }

    #[test]
    fn streams_are_distinct_and_deterministic() {
        let root = Pcg64::new(7);
        let mut s1 = root.stream(1);
        let mut s1b = root.stream(1);
        let mut s2 = root.stream(2);
        for _ in 0..64 {
            assert_eq!(s1.next_u64(), s1b.next_u64());
        }
        let mut same = 0;
        let mut s1c = root.stream(1);
        for _ in 0..64 {
            if s1c.next_u64() == s2.next_u64() {
                same += 1;
            }
        }
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval_and_uniform() {
        let mut rng = Pcg64::new(1);
        let n = 100_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var={var}");
    }

    #[test]
    fn below_is_unbiased() {
        let mut rng = Pcg64::new(3);
        let bound = 7u64;
        let mut counts = [0usize; 7];
        let n = 140_000;
        for _ in 0..n {
            counts[rng.below(bound) as usize] += 1;
        }
        let expect = n as f64 / bound as f64;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expect).abs() < 5.0 * expect.sqrt(),
                "bucket {i}: {c} vs {expect}"
            );
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut rng = Pcg64::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn f64_open_never_zero() {
        let mut rng = Pcg64::new(11);
        for _ in 0..10_000 {
            assert!(rng.f64_open() > 0.0);
        }
    }
}
