//! The `Φ` Gibbs step via the Poisson Pólya urn (§2.5, eq. 21).
//!
//! `φ_{k,v} ∝ Pois(β + n_{k,v})`, sampled sparsely by splitting the
//! rate: the `β` part is a Poisson process with total rate `β·V` whose
//! points land on uniform word ids; the `n` part iterates the nonzeros
//! of the topic's row. Expected cost per topic is `β·V + nnz(n_k)`
//! draws, independent of the dense row size.
//!
//! The resulting integer rows are normalized into a [`PhiMatrix`].
//! Because the draws are integers, most of `Φ` is *exactly* zero — the
//! topic-word sparsity the z step exploits.

use crate::par;
use crate::rng::{dist, Pcg64};
use crate::simd::Kernels;
use crate::sparse::{PhiMatrix, TopicWordRows};

/// Sample one PPU row: integer counts `ϕ_{k,v} ~ Pois(β + n_{k,v})`,
/// returned as sorted `(word, count)` with zeros omitted.
pub fn sample_ppu_row(
    rng: &mut Pcg64,
    n_row: &[(u32, u32)],
    beta: f64,
    vocab: usize,
) -> Vec<(u32, u32)> {
    // β part: B ~ Pois(β·V) points at uniform word ids.
    let b_total = dist::poisson(rng, beta * vocab as f64);
    let mut beta_points: Vec<u32> =
        (0..b_total).map(|_| rng.below(vocab as u64) as u32).collect();
    beta_points.sort_unstable();
    // n part: Pois(n_{k,v}) at each nonzero (already sorted by word).
    let mut out: Vec<(u32, u32)> = Vec::with_capacity(n_row.len() + b_total as usize);
    let mut bi = 0usize;
    for &(v, c) in n_row {
        // flush β points before v
        while bi < beta_points.len() && beta_points[bi] < v {
            push_count(&mut out, beta_points[bi], 1);
            bi += 1;
        }
        let mut draw = dist::poisson(rng, c as f64);
        while bi < beta_points.len() && beta_points[bi] == v {
            draw += 1;
            bi += 1;
        }
        if draw > 0 {
            push_count(&mut out, v, draw as u32);
        }
    }
    while bi < beta_points.len() {
        push_count(&mut out, beta_points[bi], 1);
        bi += 1;
    }
    out
}

#[inline]
fn push_count(out: &mut Vec<(u32, u32)>, v: u32, c: u32) {
    if let Some(last) = out.last_mut() {
        if last.0 == v {
            last.1 += c;
            return;
        }
    }
    out.push((v, c));
}

/// Dense exact reference for tests: `ϕ_{k,v} ~ Pois(β + n_{k,v})` for
/// every `v` (O(V) draws).
pub fn sample_ppu_row_dense(
    rng: &mut Pcg64,
    n_row: &[(u32, u32)],
    beta: f64,
    vocab: usize,
) -> Vec<(u32, u32)> {
    sample_ppu_row_dense_with(rng, n_row, beta, vocab, &Kernels::scalar())
}

/// [`sample_ppu_row_dense`] with an explicit kernel set: the Poisson
/// draws are inherently serial (RNG stream), but the nonzero
/// compaction of the dense row runs through
/// `kernels.compact_nonzero_u32` — an order-preserving integer kernel,
/// so the output is bit-identical across tiers.
pub fn sample_ppu_row_dense_with(
    rng: &mut Pcg64,
    n_row: &[(u32, u32)],
    beta: f64,
    vocab: usize,
    kernels: &Kernels,
) -> Vec<(u32, u32)> {
    let mut dense = vec![0u32; vocab];
    let mut idx = 0usize;
    for v in 0..vocab as u32 {
        let c = if idx < n_row.len() && n_row[idx].0 == v {
            let c = n_row[idx].1;
            idx += 1;
            c
        } else {
            0
        };
        dense[v as usize] = dist::poisson(rng, beta + c as f64) as u32;
    }
    let mut out = Vec::new();
    (kernels.compact_nonzero_u32)(&dense, &mut out);
    out
}

/// Sample the whole `Φ` in parallel over topics (one RNG stream per
/// topic — shard-layout invariant) and assemble the [`PhiMatrix`].
/// Runs on any executor: a `threads: usize` scoped strategy or a
/// persistent [`&WorkerPool`](crate::par::WorkerPool).
pub fn sample_phi(
    root: &Pcg64,
    n: &TopicWordRows,
    beta: f64,
    vocab: usize,
    exec: impl par::Executor,
) -> PhiMatrix {
    sample_phi_with(root, n, beta, vocab, exec, &Kernels::scalar())
}

/// [`sample_phi`] with an explicit kernel set: the row draws are
/// serial per topic (RNG streams), the normalization into the
/// [`PhiMatrix`] runs through the kernels (bit-identical across tiers;
/// see [`PhiMatrix::from_count_rows_with`]).
pub fn sample_phi_with(
    root: &Pcg64,
    n: &TopicWordRows,
    beta: f64,
    vocab: usize,
    exec: impl par::Executor,
    kernels: &Kernels,
) -> PhiMatrix {
    let k_max = n.num_topics();
    let rows: Vec<Vec<(u32, u32)>> = par::exec_map(exec, k_max, |k| {
        let mut rng = root.stream(0x9900_0000 | k as u64);
        sample_ppu_row(&mut rng, n.row(k), beta, vocab)
    });
    PhiMatrix::from_count_rows_with(vocab, &rows, kernels)
}

/// An in-flight asynchronous `Φ` sampling job (the pipelined sampler's
/// front for iteration t+1). [`PhiJob::join`] assembles the
/// [`PhiMatrix`] exactly like [`sample_phi`] would have.
pub struct PhiJob {
    rows: crate::par::MapJob<Vec<(u32, u32)>>,
    vocab: usize,
    /// Kernel set for the join-time normalization (bit-identical across
    /// tiers, so the async/blocking equivalence is unaffected).
    kernels: Kernels,
    /// Nanoseconds of worker CPU time spent sampling rows, accumulated
    /// across tasks — lets the sampler attribute overlapped Φ work to
    /// its `phi` phase timer even though it ran off the critical path.
    nanos: std::sync::Arc<std::sync::atomic::AtomicU64>,
}

impl PhiJob {
    /// Block until every row is sampled and assemble the matrix,
    /// returning it together with the total worker CPU time spent in
    /// row sampling.
    pub fn join(self) -> (PhiMatrix, std::time::Duration) {
        let rows = self.rows.join();
        let spent = std::time::Duration::from_nanos(
            self.nanos.load(std::sync::atomic::Ordering::Relaxed),
        );
        (PhiMatrix::from_count_rows_with(self.vocab, &rows, &self.kernels), spent)
    }
}

/// Submit `Φ` sampling asynchronously on the pool: the rows cook on the
/// workers while the caller runs the serial merge/l/Ψ/diagnostics tail
/// of the current iteration. The RNG stream layout is identical to
/// [`sample_phi`] (`root` must already be the per-iteration phase
/// stream), so a joined [`PhiJob`] is bit-identical to the blocking
/// call — only *when* the draws happen differs.
pub fn submit_phi(
    pool: &std::sync::Arc<crate::par::WorkerPool>,
    root: Pcg64,
    n: std::sync::Arc<TopicWordRows>,
    beta: f64,
    vocab: usize,
) -> PhiJob {
    submit_phi_with(pool, root, n, beta, vocab, Kernels::scalar())
}

/// [`submit_phi`] with an explicit kernel set for the join-time
/// normalization.
pub fn submit_phi_with(
    pool: &std::sync::Arc<crate::par::WorkerPool>,
    root: Pcg64,
    n: std::sync::Arc<TopicWordRows>,
    beta: f64,
    vocab: usize,
    kernels: Kernels,
) -> PhiJob {
    use std::sync::atomic::{AtomicU64, Ordering};
    let k_max = n.num_topics();
    let nanos = std::sync::Arc::new(AtomicU64::new(0));
    let nanos_task = std::sync::Arc::clone(&nanos);
    let rows = crate::par::WorkerPool::submit_map(pool, k_max, move |k| {
        let t0 = std::time::Instant::now();
        let mut rng = root.stream(0x9900_0000 | k as u64);
        let row = sample_ppu_row(&mut rng, n.row(k), beta, vocab);
        nanos_task.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        row
    });
    PhiJob { rows, vocab, kernels, nanos }
}

/// Double-buffer slot for the pipelined samplers: holds the `Φ` job
/// submitted for a future iteration and resolves it at the next step's
/// start. Owns the per-sampler phase-stream tag, so both the async and
/// the synchronous fallback path derive the *same* RNG streams — the
/// pipeline stays bit-identical to the barriered loop by construction.
pub struct PhiPipeline {
    /// `(iteration, job)` — the iteration whose step will consume it.
    pending: Option<(u64, PhiJob)>,
    /// XOR tag of the per-iteration Φ phase stream (PC: `0x0f1`,
    /// PcLDA: `0x1f1`).
    stream_tag: u64,
    /// Kernel set used by both the async and the synchronous path (the
    /// Φ draws themselves are serial; only the normalization runs
    /// through it — bit-identical across tiers).
    kernels: Kernels,
}

impl PhiPipeline {
    /// Empty pipeline with the sampler's phase-stream tag.
    pub fn new(stream_tag: u64) -> Self {
        Self { pending: None, stream_tag, kernels: Kernels::scalar() }
    }

    /// Switch the kernel set used for future `Φ` assemblies. A job
    /// already in flight keeps the set it was submitted with — both
    /// produce the same bits, so the swap point is unobservable.
    pub fn set_kernels(&mut self, kernels: Kernels) {
        self.kernels = kernels;
    }

    /// Produce `Φ` for iteration `iter`: join the prebuilt job when one
    /// is pending for exactly this iteration, otherwise sample
    /// synchronously on the pool. Returns the matrix plus the
    /// overlapped worker CPU time (`Some` only on the join path — the
    /// caller attributes it to its `phi` timer).
    pub fn resolve(
        &mut self,
        iter: u64,
        root: &Pcg64,
        n: &std::sync::Arc<TopicWordRows>,
        beta: f64,
        vocab: usize,
        pool: &std::sync::Arc<crate::par::WorkerPool>,
    ) -> (PhiMatrix, Option<std::time::Duration>) {
        match self.pending.take() {
            Some((for_iter, job)) if for_iter == iter => {
                let (phi, spent) = job.join();
                (phi, Some(spent))
            }
            stale => {
                // None, or a job for a different iteration (defensive —
                // nothing currently produces one): join-discard and
                // sample in place from the same streams.
                drop(stale);
                let phase_root = self.phase_root(iter, root);
                (
                    sample_phi_with(
                        &phase_root,
                        n,
                        beta,
                        vocab,
                        &**pool,
                        &self.kernels,
                    ),
                    None,
                )
            }
        }
    }

    /// Submit `Φ` for iteration `next_iter` on the workers (call right
    /// after the merge finalizes `n`).
    pub fn submit_next(
        &mut self,
        next_iter: u64,
        root: &Pcg64,
        n: &std::sync::Arc<TopicWordRows>,
        beta: f64,
        vocab: usize,
        pool: &std::sync::Arc<crate::par::WorkerPool>,
    ) {
        let phase_root = self.phase_root(next_iter, root);
        self.pending = Some((
            next_iter,
            submit_phi_with(
                pool,
                phase_root,
                std::sync::Arc::clone(n),
                beta,
                vocab,
                self.kernels,
            ),
        ));
    }

    /// Join and discard any in-flight job (leaving pipelined mode).
    pub fn clear(&mut self) {
        self.pending = None;
    }

    fn phase_root(&self, iter: u64, root: &Pcg64) -> Pcg64 {
        root.stream(iter.wrapping_mul(0x9e37) ^ self.stream_tag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_matches_dense_in_moments() {
        // Same (β, n) configuration sampled both ways; compare per-word
        // mean counts. They are draws from the SAME distribution, so
        // means must agree.
        let n_row = vec![(3u32, 5u32), (10, 1), (50, 20)];
        let (beta, vocab) = (0.05, 100usize);
        let reps = 20_000;
        let mut rng = Pcg64::new(1);
        let mut mean_sparse = vec![0.0f64; vocab];
        let mut mean_dense = vec![0.0f64; vocab];
        for _ in 0..reps {
            for (v, c) in sample_ppu_row(&mut rng, &n_row, beta, vocab) {
                mean_sparse[v as usize] += c as f64;
            }
            for (v, c) in sample_ppu_row_dense(&mut rng, &n_row, beta, vocab) {
                mean_dense[v as usize] += c as f64;
            }
        }
        for v in 0..vocab {
            let a = mean_sparse[v] / reps as f64;
            let b = mean_dense[v] / reps as f64;
            let expect = beta
                + n_row
                    .iter()
                    .find(|&&(w, _)| w as usize == v)
                    .map(|&(_, c)| c as f64)
                    .unwrap_or(0.0);
            assert!((a - expect).abs() < 0.15 * expect.max(0.3), "v={v}: {a} vs {expect}");
            assert!((b - expect).abs() < 0.15 * expect.max(0.3), "v={v}: {b} vs {expect}");
        }
    }

    #[test]
    fn rows_sorted_no_duplicates() {
        let mut rng = Pcg64::new(2);
        let n_row = vec![(0u32, 3u32), (1, 1), (99, 2)];
        for _ in 0..200 {
            let row = sample_ppu_row(&mut rng, &n_row, 0.1, 100);
            assert!(row.windows(2).all(|w| w[0].0 < w[1].0), "{row:?}");
            assert!(row.iter().all(|&(v, c)| c > 0 && v < 100));
        }
    }

    #[test]
    fn empty_row_gets_only_beta_points() {
        let mut rng = Pcg64::new(3);
        let (beta, vocab) = (0.01, 1000usize);
        let mut total = 0u64;
        let reps = 5000;
        for _ in 0..reps {
            let row = sample_ppu_row(&mut rng, &[], beta, vocab);
            total += row.iter().map(|&(_, c)| c as u64).sum::<u64>();
        }
        // E[total per row] = β·V = 10
        let mean = total as f64 / reps as f64;
        assert!((mean - 10.0).abs() < 0.5, "mean β mass {mean}");
    }

    #[test]
    fn ppu_approximates_dirichlet_mean() {
        // For moderately large counts, E[φ_kv] ≈ (β + n_kv)/(Vβ + n_k).
        let n_row = vec![(0u32, 40u32), (1, 60)];
        let (beta, vocab) = (0.5, 10usize);
        let mut rng = Pcg64::new(4);
        let reps = 30_000;
        let mut mean0 = 0.0f64;
        for _ in 0..reps {
            let row = sample_ppu_row(&mut rng, &n_row, beta, vocab);
            let total: u32 = row.iter().map(|&(_, c)| c).sum();
            if total == 0 {
                continue;
            }
            let c0 = row.iter().find(|&&(v, _)| v == 0).map(|&(_, c)| c).unwrap_or(0);
            mean0 += c0 as f64 / total as f64;
        }
        mean0 /= reps as f64;
        let want = (beta + 40.0) / (vocab as f64 * beta + 100.0);
        assert!((mean0 - want).abs() < 0.01, "{mean0} vs {want}");
    }

    /// The kernel-compacted dense row must equal the scalar one bit for
    /// bit, whatever tier `auto()` resolves to (same RNG stream — the
    /// draws are identical, only the compaction differs).
    #[test]
    fn dense_row_kernel_compaction_identical() {
        let n_row = vec![(2u32, 4u32), (7, 9), (40, 1)];
        for seed in 0..8 {
            let mut r1 = Pcg64::new(21 + seed);
            let mut r2 = Pcg64::new(21 + seed);
            let a = sample_ppu_row_dense(&mut r1, &n_row, 0.2, 64);
            let b =
                sample_ppu_row_dense_with(&mut r2, &n_row, 0.2, 64, &Kernels::auto());
            assert_eq!(a, b, "seed {seed}");
        }
    }

    #[test]
    fn phi_matrix_parallel_deterministic() {
        use crate::sparse::TopicWordAcc;
        let mut acc = TopicWordAcc::with_capacity(64);
        let mut rng = Pcg64::new(5);
        for _ in 0..2000 {
            acc.add(rng.below(8) as u32, rng.below(50) as u32, 1);
        }
        let n = TopicWordRows::merge_from(8, &mut [acc]);
        let root = Pcg64::new(7);
        let phi1 = sample_phi(&root, &n, 0.1, 50, 1usize);
        let phi4 = sample_phi(&root, &n, 0.1, 50, 4usize);
        assert_eq!(phi1.nnz(), phi4.nnz());
        for k in 0..8 {
            assert_eq!(phi1.row(k), phi4.row(k), "topic {k}");
        }
    }

    #[test]
    fn async_phi_matches_blocking_phi() {
        use crate::par::WorkerPool;
        use crate::sparse::TopicWordAcc;
        use std::sync::Arc;
        let mut acc = TopicWordAcc::with_capacity(64);
        let mut rng = Pcg64::new(9);
        for _ in 0..3000 {
            acc.add(rng.below(10) as u32, rng.below(80) as u32, 1);
        }
        let n = Arc::new(TopicWordRows::merge_from(10, &mut [acc]));
        let root = Pcg64::new(13);
        for threads in [1usize, 3] {
            let pool = Arc::new(WorkerPool::new(threads));
            let blocking = sample_phi(&root, &n, 0.05, 80, &*pool);
            let job = submit_phi(&pool, root.clone(), Arc::clone(&n), 0.05, 80);
            let (async_phi, spent) = job.join();
            assert_eq!(async_phi.nnz(), blocking.nnz(), "threads={threads}");
            for k in 0..10 {
                assert_eq!(async_phi.row(k), blocking.row(k), "threads={threads} k={k}");
            }
            assert!(spent >= std::time::Duration::ZERO);
        }
    }
}
