//! The `Φ` Gibbs step via the Poisson Pólya urn (§2.5, eq. 21).
//!
//! `φ_{k,v} ∝ Pois(β + n_{k,v})`, sampled sparsely by splitting the
//! rate: the `β` part is a Poisson process with total rate `β·V` whose
//! points land on uniform word ids; the `n` part iterates the nonzeros
//! of the topic's row. Expected cost per topic is `β·V + nnz(n_k)`
//! draws, independent of the dense row size.
//!
//! The resulting integer rows are normalized into a [`PhiMatrix`].
//! Because the draws are integers, most of `Φ` is *exactly* zero — the
//! topic-word sparsity the z step exploits.

use crate::par;
use crate::rng::{dist, Pcg64};
use crate::sparse::{PhiMatrix, TopicWordRows};

/// Sample one PPU row: integer counts `ϕ_{k,v} ~ Pois(β + n_{k,v})`,
/// returned as sorted `(word, count)` with zeros omitted.
pub fn sample_ppu_row(
    rng: &mut Pcg64,
    n_row: &[(u32, u32)],
    beta: f64,
    vocab: usize,
) -> Vec<(u32, u32)> {
    // β part: B ~ Pois(β·V) points at uniform word ids.
    let b_total = dist::poisson(rng, beta * vocab as f64);
    let mut beta_points: Vec<u32> =
        (0..b_total).map(|_| rng.below(vocab as u64) as u32).collect();
    beta_points.sort_unstable();
    // n part: Pois(n_{k,v}) at each nonzero (already sorted by word).
    let mut out: Vec<(u32, u32)> = Vec::with_capacity(n_row.len() + b_total as usize);
    let mut bi = 0usize;
    for &(v, c) in n_row {
        // flush β points before v
        while bi < beta_points.len() && beta_points[bi] < v {
            push_count(&mut out, beta_points[bi], 1);
            bi += 1;
        }
        let mut draw = dist::poisson(rng, c as f64);
        while bi < beta_points.len() && beta_points[bi] == v {
            draw += 1;
            bi += 1;
        }
        if draw > 0 {
            push_count(&mut out, v, draw as u32);
        }
    }
    while bi < beta_points.len() {
        push_count(&mut out, beta_points[bi], 1);
        bi += 1;
    }
    out
}

#[inline]
fn push_count(out: &mut Vec<(u32, u32)>, v: u32, c: u32) {
    if let Some(last) = out.last_mut() {
        if last.0 == v {
            last.1 += c;
            return;
        }
    }
    out.push((v, c));
}

/// Dense exact reference for tests: `ϕ_{k,v} ~ Pois(β + n_{k,v})` for
/// every `v` (O(V) draws).
pub fn sample_ppu_row_dense(
    rng: &mut Pcg64,
    n_row: &[(u32, u32)],
    beta: f64,
    vocab: usize,
) -> Vec<(u32, u32)> {
    let mut dense = vec![0u32; vocab];
    let mut idx = 0usize;
    for v in 0..vocab as u32 {
        let c = if idx < n_row.len() && n_row[idx].0 == v {
            let c = n_row[idx].1;
            idx += 1;
            c
        } else {
            0
        };
        dense[v as usize] = dist::poisson(rng, beta + c as f64) as u32;
    }
    dense
        .into_iter()
        .enumerate()
        .filter(|&(_, c)| c > 0)
        .map(|(v, c)| (v as u32, c))
        .collect()
}

/// Sample the whole `Φ` in parallel over topics (one RNG stream per
/// topic — shard-layout invariant) and assemble the [`PhiMatrix`].
/// Runs on any executor: a `threads: usize` scoped strategy or a
/// persistent [`&WorkerPool`](crate::par::WorkerPool).
pub fn sample_phi(
    root: &Pcg64,
    n: &TopicWordRows,
    beta: f64,
    vocab: usize,
    exec: impl par::Executor,
) -> PhiMatrix {
    let k_max = n.num_topics();
    let rows: Vec<Vec<(u32, u32)>> = par::exec_map(exec, k_max, |k| {
        let mut rng = root.stream(0x9900_0000 | k as u64);
        sample_ppu_row(&mut rng, n.row(k), beta, vocab)
    });
    PhiMatrix::from_count_rows(vocab, &rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_matches_dense_in_moments() {
        // Same (β, n) configuration sampled both ways; compare per-word
        // mean counts. They are draws from the SAME distribution, so
        // means must agree.
        let n_row = vec![(3u32, 5u32), (10, 1), (50, 20)];
        let (beta, vocab) = (0.05, 100usize);
        let reps = 20_000;
        let mut rng = Pcg64::new(1);
        let mut mean_sparse = vec![0.0f64; vocab];
        let mut mean_dense = vec![0.0f64; vocab];
        for _ in 0..reps {
            for (v, c) in sample_ppu_row(&mut rng, &n_row, beta, vocab) {
                mean_sparse[v as usize] += c as f64;
            }
            for (v, c) in sample_ppu_row_dense(&mut rng, &n_row, beta, vocab) {
                mean_dense[v as usize] += c as f64;
            }
        }
        for v in 0..vocab {
            let a = mean_sparse[v] / reps as f64;
            let b = mean_dense[v] / reps as f64;
            let expect = beta
                + n_row
                    .iter()
                    .find(|&&(w, _)| w as usize == v)
                    .map(|&(_, c)| c as f64)
                    .unwrap_or(0.0);
            assert!((a - expect).abs() < 0.15 * expect.max(0.3), "v={v}: {a} vs {expect}");
            assert!((b - expect).abs() < 0.15 * expect.max(0.3), "v={v}: {b} vs {expect}");
        }
    }

    #[test]
    fn rows_sorted_no_duplicates() {
        let mut rng = Pcg64::new(2);
        let n_row = vec![(0u32, 3u32), (1, 1), (99, 2)];
        for _ in 0..200 {
            let row = sample_ppu_row(&mut rng, &n_row, 0.1, 100);
            assert!(row.windows(2).all(|w| w[0].0 < w[1].0), "{row:?}");
            assert!(row.iter().all(|&(v, c)| c > 0 && v < 100));
        }
    }

    #[test]
    fn empty_row_gets_only_beta_points() {
        let mut rng = Pcg64::new(3);
        let (beta, vocab) = (0.01, 1000usize);
        let mut total = 0u64;
        let reps = 5000;
        for _ in 0..reps {
            let row = sample_ppu_row(&mut rng, &[], beta, vocab);
            total += row.iter().map(|&(_, c)| c as u64).sum::<u64>();
        }
        // E[total per row] = β·V = 10
        let mean = total as f64 / reps as f64;
        assert!((mean - 10.0).abs() < 0.5, "mean β mass {mean}");
    }

    #[test]
    fn ppu_approximates_dirichlet_mean() {
        // For moderately large counts, E[φ_kv] ≈ (β + n_kv)/(Vβ + n_k).
        let n_row = vec![(0u32, 40u32), (1, 60)];
        let (beta, vocab) = (0.5, 10usize);
        let mut rng = Pcg64::new(4);
        let reps = 30_000;
        let mut mean0 = 0.0f64;
        for _ in 0..reps {
            let row = sample_ppu_row(&mut rng, &n_row, beta, vocab);
            let total: u32 = row.iter().map(|&(_, c)| c).sum();
            if total == 0 {
                continue;
            }
            let c0 = row.iter().find(|&&(v, _)| v == 0).map(|&(_, c)| c).unwrap_or(0);
            mean0 += c0 as f64 / total as f64;
        }
        mean0 /= reps as f64;
        let want = (beta + 40.0) / (vocab as f64 * beta + 100.0);
        assert!((mean0 - want).abs() < 0.01, "{mean0} vs {want}");
    }

    #[test]
    fn phi_matrix_parallel_deterministic() {
        use crate::sparse::TopicWordAcc;
        let mut acc = TopicWordAcc::with_capacity(64);
        let mut rng = Pcg64::new(5);
        for _ in 0..2000 {
            acc.add(rng.below(8) as u32, rng.below(50) as u32, 1);
        }
        let n = TopicWordRows::merge_from(8, &mut [acc]);
        let root = Pcg64::new(7);
        let phi1 = sample_phi(&root, &n, 0.1, 50, 1usize);
        let phi4 = sample_phi(&root, &n, 0.1, 50, 4usize);
        assert_eq!(phi1.nnz(), phi4.nnz());
        for k in 0..8 {
            assert_eq!(phi1.row(k), phi4.row(k), "topic {k}");
        }
    }
}
