//! **Algorithm 2** — the doubly sparse, data-parallel, partially
//! collapsed Gibbs sampler for the HDP topic model (the paper's
//! contribution).
//!
//! Per iteration:
//!
//! 1. `Φ` ~ Poisson Pólya urn, parallel over topics ([`phi`]);
//! 2. per-word alias tables over bucket (a) ([`zstep::WordTables`]);
//! 3. `z` resampled in parallel over documents, doubly sparse
//!    ([`zstep`]); topic-word stats `n` and the `d` histogram are
//!    accumulated shard-locally and merged;
//! 4. `l` via the binomial trick, parallel over topics ([`lstep`]);
//! 5. `Ψ` from the FGEM stick-breaking posterior ([`psi`]).
//!
//! All randomness flows through per-(phase, iteration, actor) RNG
//! streams, so a chain is bit-reproducible for a given seed regardless
//! of thread count or shard layout.

pub mod lstep;
pub mod phi;
pub mod psi;
pub mod zstep;

use crate::config::HdpConfig;
use crate::corpus::Corpus;
use crate::diagnostics::loglik;
use crate::metrics::PhaseTimers;
use crate::par::{self, Sharding, WorkerPool};
use crate::rng::Pcg64;
use crate::sparse::{DocCountHist, TopicWordAcc, TopicWordRows};

use super::state::Assignments;
use super::{DiagSnapshot, Trainer};

/// The Algorithm-2 sampler.
pub struct PcSampler {
    corpus: std::sync::Arc<Corpus>,
    cfg: HdpConfig,
    threads: usize,
    root: Pcg64,
    assign: Assignments,
    /// Global topic distribution over `k_max` topics (last = flag K*).
    psi: Vec<f64>,
    /// Topic-word statistic, rebuilt each iteration.
    n: TopicWordRows,
    /// Latest `l` draw (diagnostic).
    l: Vec<u64>,
    iteration: usize,
    /// Per-phase timing (z / phi / alias / merge / l / psi).
    pub timers: PhaseTimers,
    /// Tokens whose conditional had zero mass in the last sweep.
    pub zero_mass_tokens: u64,
    /// Tokens on the flag topic after the last sweep.
    pub flag_tokens: u64,
    /// Σ min-sparsity work over tokens in the last sweep (eq. 29).
    pub sparse_work: u64,
    /// nnz(Φ) of the last iteration (alias/bucket-a cost driver).
    pub phi_nnz: usize,
    doc_plan: Sharding,
    /// Persistent fork-join pool: created once, reused by every phase
    /// of every iteration (no per-phase thread spawns).
    pool: WorkerPool,
    /// Per-pool-slot z-phase scratch, cleared and reused each sweep.
    scratch: Vec<zstep::ShardScratch>,
}

impl PcSampler {
    /// Create with single-topic initialization (paper §3).
    pub fn new(corpus: std::sync::Arc<Corpus>, cfg: HdpConfig, threads: usize, seed: u64) -> anyhow::Result<Self> {
        cfg.validate()?;
        let assign = Assignments::single_topic(&corpus);
        Self::with_assignments(corpus, cfg, threads, seed, assign)
    }

    /// Create from explicit initial assignments (tests, warm starts).
    pub fn with_assignments(
        corpus: std::sync::Arc<Corpus>,
        cfg: HdpConfig,
        threads: usize,
        seed: u64,
        assign: Assignments,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        let root = Pcg64::with_stream(seed, 0x8d9);
        // n from the initial assignments.
        let mut acc = TopicWordAcc::with_capacity(corpus.num_tokens() as usize / 2 + 16);
        for (doc, zd) in corpus.docs.iter().zip(&assign.z) {
            for (&v, &k) in doc.iter().zip(zd) {
                acc.add(k, v, 1);
            }
        }
        let n = TopicWordRows::merge_from(cfg.k_max, &mut [acc]);
        // Initial Ψ: condition on l implied by "every document drew its
        // topics from Ψ at least once".
        let mut hist = DocCountHist::new(cfg.k_max);
        for m in &assign.m {
            hist.record_doc(m.entries());
        }
        hist.finish();
        let mut l = vec![0u64; cfg.k_max];
        for k in 0..cfg.k_max {
            l[k] = hist.docs_with_at_least(k, 1) as u64;
        }
        let mut psi = vec![0.0; cfg.k_max];
        let mut rng = root.stream(0x7051);
        psi::sample_psi(&mut rng, &l, cfg.gamma, &mut psi);
        let doc_plan = Sharding::weighted(&corpus.doc_weights(), threads);
        let pool = WorkerPool::new(threads);
        // One scratch per pool slot — the pool's slot bound is
        // independent of the shard plan, so no resizing on plan swaps.
        let scratch = (0..pool.slots())
            .map(|_| zstep::ShardScratch::new(cfg.k_max))
            .collect();
        Ok(Self {
            corpus,
            cfg,
            threads,
            root,
            assign,
            psi,
            n,
            l,
            iteration: 0,
            timers: PhaseTimers::new(),
            zero_mass_tokens: 0,
            flag_tokens: 0,
            sparse_work: 0,
            phi_nnz: 0,
            doc_plan,
            pool,
            scratch,
        })
    }

    /// Current global topic distribution `Ψ`.
    pub fn psi(&self) -> &[f64] {
        &self.psi
    }

    /// Overwrite `Ψ` (checkpoint resume). Length must be `k_max`.
    pub fn set_psi(&mut self, psi: &[f64]) {
        assert_eq!(psi.len(), self.cfg.k_max);
        self.psi.copy_from_slice(psi);
    }

    /// Current topic-word statistic.
    pub fn n(&self) -> &TopicWordRows {
        &self.n
    }

    /// Latest `l` vector.
    pub fn l(&self) -> &[u64] {
        &self.l
    }

    /// Model configuration.
    pub fn config(&self) -> &HdpConfig {
        &self.cfg
    }

    /// Thread count used by the parallel phases.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The sampler's persistent worker pool.
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// Replace the document shard plan (tests and tuning: the chain is
    /// bit-identical under any plan that covers `0..D` contiguously).
    pub fn set_doc_plan(&mut self, plan: Sharding) {
        let mut next = 0usize;
        for s in plan.shards() {
            assert_eq!(s.start, next, "plan must be contiguous from 0");
            next = s.end;
        }
        assert_eq!(next, self.corpus.num_docs(), "plan must cover all documents");
        self.doc_plan = plan;
    }

    /// Mean per-token sparse work of the last iteration (eq. 29 audit).
    pub fn mean_sparse_work(&self) -> f64 {
        self.sparse_work as f64 / self.corpus.num_tokens().max(1) as f64
    }
}

impl Trainer for PcSampler {
    fn name(&self) -> &'static str {
        "pc-hdp"
    }

    fn step(&mut self) -> anyhow::Result<()> {
        use std::time::Instant;
        let iter = self.iteration as u64 + 1;
        let vocab = self.corpus.vocab_size();
        let root = self.root.clone();
        let spawns0 = par::stats::thread_spawns();
        let jobs0 = self.pool.jobs_run();
        let allocs0 = par::stats::scratch_allocs();
        // 1. Φ ~ PPU(n + β), parallel over topics.
        let t0 = Instant::now();
        let phi = phi::sample_phi(
            &root.stream(iter.wrapping_mul(0x9e37) ^ 0x0f1),
            &self.n,
            self.cfg.beta,
            vocab,
            &self.pool,
        );
        self.timers.add("phi", t0.elapsed());
        self.phi_nnz = phi.nnz();
        // 2. Bucket-(a) alias tables, parallel over word types.
        let t0 = Instant::now();
        let tables =
            zstep::WordTables::build(&phi, &self.psi, self.cfg.alpha, &self.pool);
        self.timers.add("alias", t0.elapsed());
        // 3. z sweep, parallel over document shards, accumulating into
        // the persistent per-slot scratch.
        let sweep = zstep::ZSweep {
            phi: &phi,
            psi: &self.psi,
            tables: &tables,
            alpha: self.cfg.alpha,
            k_max: self.cfg.k_max,
            seed_root: &root,
            iteration: iter,
        };
        let t0 = Instant::now();
        sweep.run_with_scratch(
            &self.corpus.docs,
            &mut self.assign.z,
            &mut self.assign.m,
            &self.doc_plan,
            &self.pool,
            &mut self.scratch,
        );
        self.timers.add("z", t0.elapsed());
        // 4. Merge the slot outputs (draining the scratch in place so
        // its allocations survive into the next sweep).
        let t0 = Instant::now();
        self.zero_mass_tokens = 0;
        self.flag_tokens = 0;
        self.sparse_work = 0;
        for s in &self.scratch {
            self.zero_mass_tokens += s.out.zero_mass_tokens;
            self.flag_tokens += s.out.flag_tokens;
            self.sparse_work += s.out.sparse_work;
        }
        self.n = TopicWordRows::merge_from_iter(
            self.cfg.k_max,
            self.scratch.iter_mut().map(|s| &mut s.out.n_acc),
        );
        let hist = DocCountHist::merge_mut(
            self.cfg.k_max,
            self.scratch.iter_mut().map(|s| &mut s.out.hist),
        );
        self.timers.add("merge", t0.elapsed());
        // 5. l via the binomial trick, parallel over topics.
        let t0 = Instant::now();
        let l_root = root.stream(iter.wrapping_mul(0x51ed) ^ 0x77);
        self.l = lstep::sample_l(&l_root, &hist, &self.psi, self.cfg.alpha, &self.pool);
        self.timers.add("l", t0.elapsed());
        // 6. Ψ | l.
        let t0 = Instant::now();
        let mut psi_rng = root.stream(iter.wrapping_mul(0xabcd) ^ 0x7051);
        psi::sample_psi(&mut psi_rng, &self.l, self.cfg.gamma, &mut self.psi);
        self.timers.add("psi", t0.elapsed());
        self.timers.incr("thread_spawns", par::stats::thread_spawns() - spawns0);
        self.timers.incr("pool_jobs", self.pool.jobs_run() - jobs0);
        self.timers.incr("scratch_allocs", par::stats::scratch_allocs() - allocs0);
        self.iteration += 1;
        Ok(())
    }

    fn diagnostics(&self) -> DiagSnapshot {
        let rows: Vec<Vec<(u32, u32)>> =
            (0..self.cfg.k_max).map(|k| self.n.row(k).to_vec()).collect();
        let ll = loglik::joint_loglik(
            &rows,
            &self.assign.z,
            &self.psi,
            self.cfg.alpha,
            self.cfg.beta,
            self.corpus.vocab_size(),
            &self.pool,
        );
        let mut tokens_per_topic: Vec<u64> =
            self.n.row_totals().iter().copied().filter(|&t| t > 0).collect();
        tokens_per_topic.sort_unstable_by(|a, b| b.cmp(a));
        DiagSnapshot {
            log_likelihood: ll,
            active_topics: self.n.active_topics(),
            flag_topic_tokens: self.flag_tokens,
            total_tokens: self.n.total(),
            tokens_per_topic,
        }
    }

    fn assignments(&self) -> &[Vec<u32>] {
        &self.assign.z
    }

    fn topic_word_rows(&self) -> Vec<Vec<(u32, u32)>> {
        (0..self.cfg.k_max).map(|k| self.n.row(k).to_vec()).collect()
    }

    fn corpus(&self) -> &Corpus {
        &self.corpus
    }

    fn iterations_done(&self) -> usize {
        self.iteration
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::HdpCorpusSpec;

    fn tiny_corpus(seed: u64) -> std::sync::Arc<Corpus> {
        let (c, _) = HdpCorpusSpec {
            vocab: 200,
            topics: 5,
            gamma: 2.0,
            alpha: 1.0,
            topic_beta: 0.05,
            docs: 60,
            mean_doc_len: 30.0,
            len_sigma: 0.3,
            min_doc_len: 8,
        }
        .generate(seed);
        std::sync::Arc::new(c)
    }

    fn cfg() -> HdpConfig {
        HdpConfig { alpha: 0.5, beta: 0.05, gamma: 1.0, k_max: 40, init_topics: 1 }
    }

    #[test]
    fn runs_and_conserves_tokens() {
        let corpus = tiny_corpus(1);
        let total = corpus.num_tokens();
        let mut s = PcSampler::new(corpus.clone(), cfg(), 2, 42).unwrap();
        for _ in 0..5 {
            s.step().unwrap();
            assert_eq!(s.n().total(), total, "token conservation");
            s.assign.check_consistency(&corpus).unwrap();
            let psum: f64 = s.psi().iter().sum();
            assert!((psum - 1.0).abs() < 1e-9);
        }
        let d = s.diagnostics();
        assert_eq!(d.total_tokens, total);
        assert!(d.active_topics >= 1);
        assert!(d.log_likelihood.is_finite());
    }

    #[test]
    fn grows_topics_from_single_init() {
        let corpus = tiny_corpus(2);
        let mut s = PcSampler::new(corpus, cfg(), 1, 7).unwrap();
        for _ in 0..30 {
            s.step().unwrap();
        }
        let d = s.diagnostics();
        assert!(
            d.active_topics > 1,
            "sampler should create topics (got {})",
            d.active_topics
        );
        // And not blow up to the truncation.
        assert!(d.active_topics < 40);
    }

    #[test]
    fn loglik_improves_from_init() {
        let corpus = tiny_corpus(3);
        let mut s = PcSampler::new(corpus, cfg(), 2, 11).unwrap();
        // Baseline: the single-topic INITIAL state (before any step).
        // Burn-in on this corpus takes ~200 sweeps (the transient
        // fragments first, then consolidates — the paper runs 100k
        // sweeps on AP); after it the joint must beat the init.
        let init = s.diagnostics().log_likelihood;
        for _ in 0..250 {
            s.step().unwrap();
        }
        let last = s.diagnostics().log_likelihood;
        assert!(
            last > init,
            "log-likelihood should improve over the init: {init} -> {last}"
        );
    }

    #[test]
    fn chain_reproducible_and_thread_invariant() {
        // Full matrix: threads × document-plan family. Every pooled
        // chain must be bit-identical to the single-threaded reference
        // after 4 sweeps — z, l, and Ψ.
        let corpus = tiny_corpus(4);
        let run = |threads: usize, weighted: bool| {
            let mut s = PcSampler::new(corpus.clone(), cfg(), threads, 99).unwrap();
            let plan = if weighted {
                Sharding::weighted(&corpus.doc_weights(), threads)
            } else {
                Sharding::even(corpus.num_docs(), threads)
            };
            s.set_doc_plan(plan);
            for _ in 0..4 {
                s.step().unwrap();
            }
            (s.assignments().to_vec(), s.l().to_vec(), s.psi().to_vec())
        };
        let (z_ref, l_ref, psi_ref) = run(1, false);
        for &threads in &[1usize, 2, 3, 7] {
            for &weighted in &[false, true] {
                let (z, l, psi) = run(threads, weighted);
                let tag = format!("threads={threads} weighted={weighted}");
                assert_eq!(z, z_ref, "z diverged: {tag}");
                assert_eq!(l, l_ref, "l diverged: {tag}");
                assert_eq!(psi, psi_ref, "psi diverged: {tag}");
            }
        }
    }

    #[test]
    fn pool_reuses_workers_across_iterations() {
        // Every parallel phase must run as a job on the persistent
        // pool: 4 jobs per iteration (Φ, alias, z, l), no per-phase
        // pools or scoped fallbacks.
        let corpus = tiny_corpus(6);
        let mut s = PcSampler::new(corpus, cfg(), 4, 5).unwrap();
        assert_eq!(s.pool().slots(), 4);
        s.step().unwrap(); // warm-up (scratch growth happens here)
        let jobs0 = s.pool().jobs_run();
        for _ in 0..3 {
            s.step().unwrap();
        }
        assert_eq!(s.pool().jobs_run() - jobs0, 12, "4 pool jobs per iteration");
        assert!(s.timers.counter("pool_jobs") >= 16);
    }

    #[test]
    fn flag_topic_unused_with_large_truncation() {
        let corpus = tiny_corpus(5);
        let mut s = PcSampler::new(corpus, cfg(), 2, 1).unwrap();
        for _ in 0..10 {
            s.step().unwrap();
            assert_eq!(
                s.flag_tokens, 0,
                "no tokens should reach the flag topic at K*=40"
            );
        }
    }
}
