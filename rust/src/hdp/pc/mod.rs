//! **Algorithm 2** — the doubly sparse, data-parallel, partially
//! collapsed Gibbs sampler for the HDP topic model (the paper's
//! contribution).
//!
//! Per iteration:
//!
//! 1. `Φ` ~ Poisson Pólya urn, parallel over topics ([`phi`]);
//! 2. per-word alias tables over bucket (a) ([`zstep::WordTables`]);
//! 3. `z` resampled in parallel over documents, doubly sparse
//!    ([`zstep`]); topic-word stats `n` and the `d` histogram are
//!    accumulated shard-locally and merged;
//! 4. `l` via the binomial trick, parallel over topics ([`lstep`]);
//! 5. `Ψ` from the FGEM stick-breaking posterior ([`psi`]).
//!
//! # The phase pipeline
//!
//! The paper presents the iteration as phase-barriered, but its
//! dependency graph is looser:
//!
//! ```text
//!   n_t ──────────► Φ_{t+1} ──┐
//!   Ψ_t ──────────────────────┴─► tables_{t+1} ─► z_{t+1} ─► n_{t+1}
//!   hist_t ─► l_t ─► Ψ_t                          (merge)
//! ```
//!
//! `Φ_{t+1}` depends *only* on the merged `n_t`, which is final the
//! moment the z-sweep outputs merge — everything after the merge
//! (l, Ψ, diagnostics, checkpointing) is independent of it. So in
//! pipelined mode (the default) [`PcSampler::step`] submits `Φ_{t+1}`
//! asynchronously on the worker pool right after the merge, runs the
//! serial `l`/`Ψ` tail inline on the calling thread, and joins the
//! prebuilt `Φ` at the start of the *next* step — exactly where the
//! barriered loop would have sampled it, so the chain is bit-identical
//! (all randomness flows through per-(phase, iteration, actor) RNG
//! streams; pipelining changes only *when* draws are computed, never
//! *what* they condition on). Any between-step work — the
//! coordinator's diagnostics pass, checkpoint writes — overlaps with
//! `Φ_{t+1}` for free.
//!
//! The alias tables also depend on `Ψ_t`, which is only final after the
//! tail, so they are built (in place, buffers recycled) at the start of
//! the next step, again exactly where the barriered loop builds them.
//!
//! All randomness flows through per-(phase, iteration, actor) RNG
//! streams, so a chain is bit-reproducible for a given seed regardless
//! of thread count, shard layout, scheduling mode, or pipelining.

pub mod lstep;
pub mod phi;
pub mod psi;
pub mod zstep;

use crate::config::HdpConfig;
use crate::corpus::io::PackedCorpusFile;
use crate::corpus::{Corpus, PackedCorpus};
use crate::diagnostics::loglik;
use crate::metrics::PhaseTimers;
use crate::par::{self, Schedule, Sharding, WorkerPool};
use crate::rng::Pcg64;
use crate::simd::Kernels;
use crate::sparse::{DocCountHist, DocTopics, MergeScratch, TopicWordAcc, TopicWordRows};
use std::borrow::Cow;
use std::sync::Arc;

use super::state::Assignments;
use super::{DiagSnapshot, Trainer, ZView};

/// Where a [`PcSampler`]'s topic assignments live. The chain is
/// **bit-identical** under every layout (per-document RNG streams) —
/// this is purely a residency choice.
pub(crate) enum SamplerZ {
    /// Per-document vectors — the layout [`PcSampler::new`] /
    /// [`PcSampler::with_assignments`] start in (+24 B/doc of `Vec`
    /// headers next to the packed token arena).
    Nested(Vec<Vec<u32>>),
    /// One flat arena over the packed corpus's CSR doc offsets — the
    /// packed-only layout ([`PcSampler::from_packed`]): z costs exactly
    /// 4 B/token and no per-document allocation exists.
    Arena(Vec<u32>),
    /// File-backed arena ([`zstep::FileZ`]) — fully out-of-core: only
    /// the `(D + 1)` offsets stay resident.
    File(zstep::FileZ),
}

/// The Algorithm-2 sampler.
pub struct PcSampler {
    /// The packed CSR corpus: **the only corpus representation the
    /// sampler holds**. Every sweep reads its token arena (contiguous
    /// per-document slices; contiguous blocks for the streamed path)
    /// and the `Trainer` API serves document/vocab views straight from
    /// it — no nested `Corpus` twin.
    packed: Arc<PackedCorpus>,
    cfg: HdpConfig,
    threads: usize,
    root: Pcg64,
    /// Topic assignments, in whichever layout ([`SamplerZ`]) this
    /// sampler was built with.
    z: SamplerZ,
    /// Per-document sparse topic counts `m` (always resident — they
    /// gate every doc's conditional and are `O(topics-per-doc)`).
    m: Vec<DocTopics>,
    /// Optional out-of-core token source: when set, packed-only sweeps
    /// read token blocks from the file (mmap or positioned reads)
    /// instead of the resident arena.
    token_file: Option<Arc<PackedCorpusFile>>,
    /// Global topic distribution over `k_max` topics (last = flag K*).
    psi: Vec<f64>,
    /// Topic-word statistic, rebuilt each iteration. Shared with the
    /// in-flight Φ job in pipelined mode (Φ_{t+1} reads n_t while the
    /// main thread runs the tail), hence the `Arc`.
    n: Arc<TopicWordRows>,
    /// Latest `l` draw (diagnostic).
    l: Vec<u64>,
    iteration: usize,
    /// Per-phase timing (z / phi / alias / merge / l / psi, plus
    /// `critical_path` = per-step wall; in pipelined mode `phi` is the
    /// overlapped worker CPU time and `phi_join` the join stall).
    pub timers: PhaseTimers,
    /// Tokens whose conditional had zero mass in the last sweep.
    pub zero_mass_tokens: u64,
    /// Tokens on the flag topic after the last sweep.
    pub flag_tokens: u64,
    /// Σ min-sparsity work over tokens in the last sweep (eq. 29).
    pub sparse_work: u64,
    /// nnz(Φ) of the last iteration (alias/bucket-a cost driver).
    pub phi_nnz: usize,
    doc_plan: Sharding,
    /// Persistent fork-join pool: created once, reused by every phase
    /// of every iteration (no per-phase thread spawns). `Arc` so async
    /// Φ jobs can hold the pool across the step boundary.
    pool: Arc<WorkerPool>,
    /// Per-pool-slot z-phase scratch, cleared and reused each sweep.
    scratch: Vec<zstep::ShardScratch>,
    /// Bucket-(a) alias tables, rebuilt in place every iteration.
    tables: zstep::WordTables,
    tables_scratch: zstep::WordTablesScratch,
    /// Reusable buckets for the pool-parallel `n` merge.
    merge_scratch: MergeScratch,
    /// Overlap Φ_{t+1} with the merge/l/Ψ/diagnostics tail of t.
    pipelined: bool,
    /// Hand shard `i` to pool slot `i % slots` every z sweep.
    slot_affine: bool,
    /// Streamed z: max documents per block (None = resident sweep).
    stream_block_docs: Option<usize>,
    /// Block plan derived from `doc_plan.refine(stream_block_docs)`.
    block_plan: Option<Sharding>,
    /// Streamed z: double-buffered block prefetch (next block's I/O
    /// overlaps the current block's sweep).
    stream_prefetch: bool,
    /// Double-buffer slot for the in-flight Φ job.
    phi_pipe: phi::PhiPipeline,
    /// Kernel set for the hot loops (scalar unless
    /// [`PcSampler::set_simd`] engaged an accelerated tier). Chains are
    /// bit-identical under every tier.
    kernels: Kernels,
    /// Whether worker core pinning is engaged (resolved, not
    /// requested: false when the OS denied `sched_setaffinity`).
    pinning: bool,
    /// Run the z sweep with the Pólya-urn MH fast path instead of the
    /// exact doubly-sparse kernel (see [`zstep`]'s module docs).
    ppu: bool,
}

impl PcSampler {
    /// Create with single-topic initialization (paper §3).
    pub fn new(corpus: Arc<Corpus>, cfg: HdpConfig, threads: usize, seed: u64) -> anyhow::Result<Self> {
        cfg.validate()?;
        let assign = Assignments::single_topic(&corpus);
        Self::with_assignments(corpus, cfg, threads, seed, assign)
    }

    /// Create from explicit initial assignments (tests, warm starts).
    /// The nested corpus is packed and dropped on the way in — the
    /// sampler itself never holds it.
    pub fn with_assignments(
        corpus: Arc<Corpus>,
        cfg: HdpConfig,
        threads: usize,
        seed: u64,
        assign: Assignments,
    ) -> anyhow::Result<Self> {
        let packed = Arc::new(corpus.to_packed());
        drop(corpus);
        let Assignments { z, m } = assign;
        Self::init(packed, SamplerZ::Nested(z), m, cfg, threads, seed)
    }

    /// **Packed-only** construction with single-topic initialization:
    /// z lives in a flat arena ([`SamplerZ::Arena`]) for the whole run
    /// and no nested `Corpus` or nested z is ever materialized. The
    /// chain is bit-identical to [`PcSampler::new`] on the nested form
    /// of the same corpus.
    pub fn from_packed(
        packed: Arc<PackedCorpus>,
        cfg: HdpConfig,
        threads: usize,
        seed: u64,
    ) -> anyhow::Result<Self> {
        let z = vec![0u32; packed.num_tokens() as usize];
        let m = (0..packed.num_docs())
            .map(|d| {
                let mut md = DocTopics::with_capacity(4);
                for _ in 0..packed.doc_len(d) {
                    md.inc(0);
                }
                md
            })
            .collect();
        Self::init(packed, SamplerZ::Arena(z), m, cfg, threads, seed)
    }

    /// Packed-only construction from an explicit flat z arena in the
    /// corpus's CSR layout (checkpoint resume: v2 stores exactly this
    /// shape, so resume never inflates nested state). `m` is rebuilt
    /// from the arena.
    pub fn from_packed_with_z(
        packed: Arc<PackedCorpus>,
        cfg: HdpConfig,
        threads: usize,
        seed: u64,
        z: Vec<u32>,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            z.len() as u64 == packed.num_tokens(),
            "z arena len {} != corpus tokens {}",
            z.len(),
            packed.num_tokens()
        );
        let m = packed
            .doc_offsets()
            .windows(2)
            .map(|w| z[w[0] as usize..w[1] as usize].iter().copied().collect::<DocTopics>())
            .collect();
        Self::init(packed, SamplerZ::Arena(z), m, cfg, threads, seed)
    }

    /// Shared constructor: every layout funnels through here, so the
    /// initial `n`/`l`/`Ψ` (and all downstream randomness) are
    /// layout-independent.
    fn init(
        packed: Arc<PackedCorpus>,
        z: SamplerZ,
        m: Vec<DocTopics>,
        cfg: HdpConfig,
        threads: usize,
        seed: u64,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        let root = Pcg64::with_stream(seed, 0x8d9);
        // n from the initial assignments — token order is document
        // order under every layout, so the accumulation sequence (and
        // hence `n`) is bit-identical across layouts.
        let mut acc = TopicWordAcc::with_capacity(packed.num_tokens() as usize / 2 + 16);
        match &z {
            SamplerZ::Nested(zs) => {
                for (d, zd) in zs.iter().enumerate() {
                    for (&v, &k) in packed.doc(d).iter().zip(zd) {
                        acc.add(k, v, 1);
                    }
                }
            }
            SamplerZ::Arena(flat) => {
                for (&v, &k) in packed.tokens().iter().zip(flat) {
                    acc.add(k, v, 1);
                }
            }
            SamplerZ::File(f) => {
                let flat = f.to_flat()?;
                for (&v, &k) in packed.tokens().iter().zip(&flat) {
                    acc.add(k, v, 1);
                }
            }
        }
        let n = Arc::new(TopicWordRows::merge_from(cfg.k_max, &mut [acc]));
        // Initial Ψ: condition on l implied by "every document drew its
        // topics from Ψ at least once".
        let mut hist = DocCountHist::new(cfg.k_max);
        for md in &m {
            hist.record_doc(md.entries());
        }
        hist.finish();
        let mut l = vec![0u64; cfg.k_max];
        for k in 0..cfg.k_max {
            l[k] = hist.docs_with_at_least(k, 1) as u64;
        }
        let mut psi = vec![0.0; cfg.k_max];
        let mut rng = root.stream(0x7051);
        psi::sample_psi(&mut rng, &l, cfg.gamma, &mut psi);
        let weights = packed.doc_weights();
        let doc_plan = Sharding::weighted(&weights, threads);
        let pool = Arc::new(WorkerPool::new(threads));
        // One scratch per pool slot — the pool's slot bound is
        // independent of the shard plan, so no resizing on plan swaps.
        // The accumulator hint comes from the plan's affine stripe
        // (tokens-per-slot with 25% headroom, see `plan_pair_hint`):
        // a slot records at most one distinct (topic, word) pair per
        // token it processes, so under balanced (or slot-affine)
        // sharding the table never regrows after construction.
        let pair_hint = zstep::plan_pair_hint(&doc_plan, &weights, pool.slots());
        let scratch = (0..pool.slots())
            .map(|_| zstep::ShardScratch::with_pair_hint(cfg.k_max, pair_hint))
            .collect();
        Ok(Self {
            packed,
            cfg,
            threads,
            root,
            z,
            m,
            token_file: None,
            psi,
            n,
            l,
            iteration: 0,
            timers: PhaseTimers::new(),
            zero_mass_tokens: 0,
            flag_tokens: 0,
            sparse_work: 0,
            phi_nnz: 0,
            doc_plan,
            pool,
            scratch,
            tables: zstep::WordTables::empty(),
            tables_scratch: zstep::WordTablesScratch::new(),
            merge_scratch: MergeScratch::new(),
            pipelined: true,
            slot_affine: false,
            stream_block_docs: None,
            block_plan: None,
            stream_prefetch: false,
            phi_pipe: phi::PhiPipeline::new(0x0f1),
            kernels: Kernels::scalar(),
            pinning: false,
            ppu: false,
        })
    }

    /// Current global topic distribution `Ψ`.
    pub fn psi(&self) -> &[f64] {
        &self.psi
    }

    /// Overwrite `Ψ` (checkpoint resume). Length must be `k_max`. Safe
    /// at any step boundary: an in-flight Φ job never reads `Ψ` (the
    /// alias tables are built from the fresh `Ψ` at the next step).
    pub fn set_psi(&mut self, psi: &[f64]) {
        assert_eq!(psi.len(), self.cfg.k_max);
        self.psi.copy_from_slice(psi);
    }

    /// Set the iteration counter to `iteration` completed steps —
    /// checkpoint resume. Subsequent steps draw from the per-iteration
    /// RNG streams `iteration + 1, iteration + 2, …` of the
    /// construction seed, so a resumed chain continues **bit-identical**
    /// to the uninterrupted one.
    pub fn set_resume_point(&mut self, iteration: u64) {
        self.iteration = iteration as usize;
    }

    /// Current topic-word statistic.
    pub fn n(&self) -> &TopicWordRows {
        &self.n
    }

    /// Latest `l` vector.
    pub fn l(&self) -> &[u64] {
        &self.l
    }

    /// Model configuration.
    pub fn config(&self) -> &HdpConfig {
        &self.cfg
    }

    /// Thread count used by the parallel phases.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The sampler's persistent worker pool.
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// An owning handle to the sampler's pool, for components that
    /// outlive a borrow of the sampler — e.g. a [`crate::serve::Server`]
    /// answering queries on the training pool between (never during)
    /// steps.
    pub fn pool_handle(&self) -> Arc<WorkerPool> {
        self.pool.clone()
    }

    /// Enable/disable the phase pipeline (default on). Disabling joins
    /// and discards any in-flight Φ job; the chain is bit-identical
    /// either way, so this is purely a scheduling choice.
    pub fn set_pipelined(&mut self, pipelined: bool) {
        self.pipelined = pipelined;
        if !pipelined {
            self.phi_pipe.clear(); // join → discard
        }
    }

    /// Whether the phase pipeline is enabled.
    pub fn pipelined(&self) -> bool {
        self.pipelined
    }

    /// Enable/disable slot-affine z scheduling (default off): shard `i`
    /// runs on pool slot `i % slots` every sweep, keeping each slot's
    /// `z`/`m` shard hot in one worker's cache. Chains are bit-identical
    /// under either schedule.
    pub fn set_slot_affine(&mut self, slot_affine: bool) {
        self.slot_affine = slot_affine;
    }

    /// Whether slot-affine z scheduling is enabled.
    pub fn slot_affine(&self) -> bool {
        self.slot_affine
    }

    /// Engage (or drop) the SIMD kernel set for the z/Φ/alias hot
    /// loops. `true` resolves the widest tier this build + CPU
    /// supports ([`Kernels::auto`]); with the `simd` cargo feature off
    /// that is still the scalar set. Chains are **bit-identical**
    /// under every tier (see [`crate::simd`]), so this may be flipped
    /// mid-chain.
    pub fn set_simd(&mut self, on: bool) {
        self.kernels = if on { Kernels::auto() } else { Kernels::scalar() };
        self.phi_pipe.set_kernels(self.kernels);
    }

    /// Whether an accelerated (non-scalar) kernel tier is active.
    pub fn simd_active(&self) -> bool {
        self.kernels.is_accelerated()
    }

    /// Name of the active kernel tier (`"scalar"`, `"sse2"`,
    /// `"avx2"`).
    pub fn kernel_tier(&self) -> &'static str {
        self.kernels.name()
    }

    /// Request (or release) worker core pinning: each pool worker is
    /// pinned to one CPU of the process affinity mask (slot-major, so
    /// the [`Schedule::SlotAffine`] z schedule lines shards up with
    /// cores), and the per-slot z scratch is reallocated **on the
    /// pinned workers** so first-touch places its pages on the
    /// worker's NUMA node. Returns the resolved state: `false` when
    /// the OS denied `sched_setaffinity` (containers) — the sampler
    /// degrades gracefully and keeps running unpinned. Chains are
    /// bit-identical with pinning on or off.
    pub fn set_pinning(&mut self, on: bool) -> bool {
        self.pinning = self.pool.set_pinning(on);
        if self.pinning {
            self.first_touch_scratch();
        }
        self.pinning
    }

    /// Whether worker core pinning is engaged (resolved, not
    /// requested).
    pub fn pinning(&self) -> bool {
        self.pinning
    }

    /// Enable/disable the Pólya-urn MH z sweep (default off — the
    /// exact doubly-sparse kernel). The PPU chain targets the same
    /// conditionals but takes a different (still valid, still
    /// deterministic-per-seed) trajectory, so flipping this changes
    /// the chain — unlike every other knob on this sampler it is
    /// **not** bit-identical to the default. See [`zstep`]'s module
    /// docs for the approximation and its validation.
    pub fn set_ppu(&mut self, on: bool) {
        self.ppu = on;
    }

    /// Whether the Pólya-urn fast path is engaged.
    pub fn ppu(&self) -> bool {
        self.ppu
    }

    /// Reallocate the per-slot z scratch inside a slot-affine pool job
    /// so each slot's buffers are first-touched (and their pages
    /// placed) on the worker that will use them every sweep.
    fn first_touch_scratch(&mut self) {
        let slots = self.pool.slots();
        let plan = self.block_plan.as_ref().unwrap_or(&self.doc_plan);
        let weights = self.packed.doc_weights();
        let pair_hint = zstep::plan_pair_hint(plan, &weights, slots);
        let k_max = self.cfg.k_max;
        let slot_plan = Sharding::even(slots, slots);
        // Pool slot_bound == slots (one unit scratch per slot).
        let mut unit: Vec<()> = vec![(); slots];
        self.scratch = par::exec_shards_with_sched(
            &*self.pool,
            &slot_plan,
            &mut unit,
            Schedule::SlotAffine,
            |_, _, _| zstep::ShardScratch::with_pair_hint(k_max, pair_hint),
        );
    }

    /// The packed CSR arena the sweeps run on.
    pub fn packed(&self) -> &PackedCorpus {
        &self.packed
    }

    /// Replace the document shard plan (tests and tuning: the chain is
    /// bit-identical under any plan that covers `0..D` contiguously).
    /// The streamed block plan, if any, is re-derived from the new
    /// plan.
    pub fn set_doc_plan(&mut self, plan: Sharding) {
        let mut next = 0usize;
        for s in plan.shards() {
            assert_eq!(s.start, next, "plan must be contiguous from 0");
            next = s.end;
        }
        assert_eq!(next, self.packed.num_docs(), "plan must cover all documents");
        self.doc_plan = plan;
        self.rebuild_stream_state();
    }

    /// Enable/disable the streamed (out-of-core-shaped) z sweep:
    /// `Some(b)` refines the document shard plan into blocks of at
    /// most `b` documents and sweeps them through per-slot block
    /// buffers, so hot per-token state is `slots × max_block` instead
    /// of the whole corpus; `None` restores the resident sweep. Chains
    /// are **bit-identical** under every setting (per-document RNG
    /// streams), so this is purely a residency/scheduling choice and
    /// may be flipped mid-chain.
    pub fn set_streaming(&mut self, block_docs: Option<usize>) {
        self.stream_block_docs = block_docs.map(|b| b.max(1));
        self.rebuild_stream_state();
    }

    /// Streamed-mode block size (documents), if streaming is enabled.
    pub fn streaming(&self) -> Option<usize> {
        self.stream_block_docs
    }

    /// The prefetch knob of [`PcSampler::set_streaming`]: when on (and
    /// streaming is enabled), block `t+1`'s token/z loads run as an
    /// async front-queued pool job while block `t` sweeps, double
    /// buffered per slot ([`zstep::ZSweep::run_streamed_prefetched`]).
    /// Per-sweep hit/stall counts surface through the
    /// [`PhaseTimers::PREFETCH_HITS`] / [`PhaseTimers::PREFETCH_STALLS`]
    /// counters. Chains are **bit-identical** with the knob on or off.
    pub fn set_stream_prefetch(&mut self, prefetch: bool) {
        self.stream_prefetch = prefetch;
    }

    /// Whether streamed sweeps prefetch the next block.
    pub fn stream_prefetch(&self) -> bool {
        self.stream_prefetch
    }

    /// The active streamed block plan, if streaming is enabled.
    pub fn stream_block_plan(&self) -> Option<&Sharding> {
        self.block_plan.as_ref()
    }

    /// Bytes currently held by the per-slot streamed block buffers
    /// (0 for resident sweeps) — the hot-z residency the streaming
    /// tests bound.
    pub fn stream_buf_bytes(&self) -> usize {
        self.scratch.iter().map(|s| s.stream_buf_bytes()).sum()
    }

    /// Re-derive the block plan and re-size the per-slot accumulators
    /// from the plan actually in effect (config-time only — sweeps
    /// never resize).
    fn rebuild_stream_state(&mut self) {
        self.block_plan = self.stream_block_docs.map(|b| self.doc_plan.refine(b));
        if self.pinning {
            // Keep the first-touch placement: rebuild on the pinned
            // workers, not the caller.
            self.first_touch_scratch();
            return;
        }
        let plan = self.block_plan.as_ref().unwrap_or(&self.doc_plan);
        let weights = self.packed.doc_weights();
        let pair_hint = zstep::plan_pair_hint(plan, &weights, self.pool.slots());
        self.scratch = (0..self.pool.slots())
            .map(|_| zstep::ShardScratch::with_pair_hint(self.cfg.k_max, pair_hint))
            .collect();
    }

    /// Mean per-token sparse work of the last iteration (eq. 29 audit).
    pub fn mean_sparse_work(&self) -> f64 {
        self.sparse_work as f64 / self.packed.num_tokens().max(1) as f64
    }

    /// Which z layout is active: `"nested"`, `"arena"`, or `"file"`.
    pub fn z_mode(&self) -> &'static str {
        match &self.z {
            SamplerZ::Nested(_) => "nested",
            SamplerZ::Arena(_) => "arena",
            SamplerZ::File(_) => "file",
        }
    }

    /// Move the z store into a file-backed arena at `path`
    /// ([`SamplerZ::File`]) — the fully out-of-core mode: only the
    /// `(D + 1)` offsets stay resident. Safe at any step boundary; the
    /// chain continues bit-identical.
    pub fn move_z_to_file(&mut self, path: &std::path::Path) -> anyhow::Result<()> {
        let offsets = self.packed.doc_offsets();
        let f = match &self.z {
            SamplerZ::Nested(zs) => {
                let mut flat = Vec::with_capacity(self.packed.num_tokens() as usize);
                for zd in zs {
                    flat.extend_from_slice(zd);
                }
                zstep::FileZ::from_flat(path, &flat, offsets)?
            }
            SamplerZ::Arena(flat) => zstep::FileZ::from_flat(path, flat, offsets)?,
            SamplerZ::File(old) => zstep::FileZ::from_flat(path, &old.to_flat()?, offsets)?,
        };
        self.z = SamplerZ::File(f);
        Ok(())
    }

    /// Flush a file-backed z store to stable storage (`fdatasync`) —
    /// the checkpoint-boundary durability point. No-op for resident
    /// layouts.
    pub fn sync_z_store(&self) {
        if let SamplerZ::File(f) = &self.z {
            f.sync().expect("z store sync");
        }
    }

    /// Attach (or detach) an out-of-core token source: packed-only
    /// sweeps then read token blocks from the file — zero-copy when it
    /// is mmap-backed, positioned reads otherwise — instead of the
    /// resident arena. The file must describe the same corpus
    /// (identical doc offsets). Nested-layout resident sweeps ignore
    /// it. Chains are bit-identical with or without a token file.
    pub fn set_token_file(&mut self, file: Option<Arc<PackedCorpusFile>>) {
        if let Some(f) = &file {
            assert_eq!(
                f.doc_offsets(),
                self.packed.doc_offsets(),
                "token file / corpus layout mismatch"
            );
        }
        self.token_file = file;
    }

    /// Whether an out-of-core token source is attached.
    pub fn token_file_active(&self) -> bool {
        self.token_file.is_some()
    }

    /// Bytes held by the packed token arena + CSR offsets.
    pub fn arena_bytes(&self) -> u64 {
        self.packed.arena_bytes()
    }

    /// Resident bytes of the z store: per-document `Vec` headers
    /// included for the nested layout; the file layout holds only the
    /// `(D + 1)` offsets.
    pub fn z_bytes(&self) -> u64 {
        match &self.z {
            SamplerZ::Nested(zs) => {
                zs.iter().map(|zd| 4 * zd.len() as u64 + 24).sum::<u64>() + 24
            }
            SamplerZ::Arena(flat) => 4 * flat.len() as u64 + 24,
            SamplerZ::File(f) => 8 * f.offsets().len() as u64 + 24,
        }
    }

    /// Resident sampler-state bytes: token arena + z store. Per-slot
    /// scratch and stream buffers are accounted separately
    /// ([`PcSampler::stream_buf_bytes`]).
    pub fn resident_state_bytes(&self) -> u64 {
        self.arena_bytes() + self.z_bytes()
    }

    /// Nested copy of the assignments (tests and reporting — the
    /// packed-only training path never calls this).
    pub fn z_nested(&self) -> Vec<Vec<u32>> {
        Trainer::z_view(self).to_nested()
    }

    /// Check the z/m/corpus consistency invariant (tests / debug).
    pub fn check_consistency(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.m.len() == self.packed.num_docs(), "m/doc count mismatch");
        let view = Trainer::z_view(self);
        anyhow::ensure!(
            view.num_docs() == self.packed.num_docs(),
            "z/doc count mismatch"
        );
        for d in 0..self.packed.num_docs() {
            let zd = view.doc(d);
            anyhow::ensure!(
                zd.len() == self.packed.doc_len(d),
                "doc {d}: token count mismatch"
            );
            let rebuilt: DocTopics = zd.iter().copied().collect();
            let md = &self.m[d];
            anyhow::ensure!(rebuilt.total() == md.total(), "doc {d}: m total mismatch");
            for (k, c) in rebuilt.iter() {
                anyhow::ensure!(
                    md.get(k) == c,
                    "doc {d}: m[{k}] = {} but z implies {c}",
                    md.get(k)
                );
            }
        }
        Ok(())
    }
}

/// One streamed z sweep over an arbitrary token source — the shared
/// dispatch of the packed-only (arena/file) layouts, which always run
/// the streaming machinery (over the document plan when no block plan
/// is set; bit-identical either way).
#[allow(clippy::too_many_arguments)]
fn run_packed_sweep<S: zstep::ZStore + ?Sized>(
    sweep: &zstep::ZSweep<'_>,
    token_file: Option<&PackedCorpusFile>,
    packed: &PackedCorpus,
    store: &S,
    m: &mut [DocTopics],
    blocks: &Sharding,
    prefetch: bool,
    pool: &Arc<WorkerPool>,
    scratch: &mut [zstep::ShardScratch],
    schedule: Schedule,
) {
    match token_file {
        Some(tf) if prefetch => {
            sweep.run_streamed_prefetched(tf, store, m, blocks, pool, scratch)
        }
        Some(tf) => sweep.run_streamed(tf, store, m, blocks, &**pool, scratch, schedule),
        None if prefetch => {
            sweep.run_streamed_prefetched(packed, store, m, blocks, pool, scratch)
        }
        None => sweep.run_streamed(packed, store, m, blocks, &**pool, scratch, schedule),
    }
}

impl Trainer for PcSampler {
    fn name(&self) -> &'static str {
        "pc-hdp"
    }

    fn try_set_ppu(&mut self, on: bool) -> bool {
        self.set_ppu(on);
        true
    }

    fn step(&mut self) -> anyhow::Result<()> {
        use std::time::Instant;
        let step_t0 = Instant::now();
        let iter = self.iteration as u64 + 1;
        let vocab = self.packed.vocab_size();
        let root = self.root.clone();
        let spawns0 = par::stats::thread_spawns();
        let jobs0 = self.pool.jobs_run();
        let allocs0 = par::stats::scratch_allocs();
        // 1. Φ ~ PPU(n + β), parallel over topics: join the job the
        // previous step submitted (it cooked on the workers during that
        // step's l/Ψ tail and any between-step diagnostics), or sample
        // synchronously (first iteration / sequential mode). Both paths
        // draw from identical RNG streams.
        let t0 = Instant::now();
        let (phi, overlapped) =
            self.phi_pipe.resolve(iter, &root, &self.n, self.cfg.beta, vocab, &self.pool);
        match overlapped {
            Some(sampling) => {
                self.timers.add("phi", sampling);
                self.timers.add("phi_join", t0.elapsed());
            }
            None => self.timers.add("phi", t0.elapsed()),
        }
        self.phi_nnz = phi.nnz();
        // 2. Bucket-(a) alias tables over (Φ_t, Ψ_{t-1}), rebuilt in
        // place (buffers recycled across iterations).
        let t0 = Instant::now();
        self.tables.build_into_with(
            &phi,
            &self.psi,
            self.cfg.alpha,
            &*self.pool,
            &mut self.tables_scratch,
            &self.kernels,
        );
        self.timers.add("alias", t0.elapsed());
        if self.kernels.is_accelerated() {
            self.timers.incr(PhaseTimers::KERNEL_ALIAS_ELEMS, phi.nnz() as u64);
            self.timers.incr(PhaseTimers::KERNEL_PHI_ELEMS, phi.nnz() as u64);
        }
        // 3. z sweep, parallel over document shards, accumulating into
        // the persistent per-slot scratch. PPU mode additionally needs
        // the dense Ψ alias for the doc proposal's global side — built
        // inline (O(k_max), trivially cheap next to the sweep; keeping
        // it off the pool preserves the per-iteration job accounting).
        let psi_alias = self
            .ppu
            .then(|| crate::alias::AliasTable::new_with(&self.psi, &self.kernels));
        let sweep = zstep::ZSweep {
            phi: &phi,
            psi: &self.psi,
            tables: &self.tables,
            alpha: self.cfg.alpha,
            k_max: self.cfg.k_max,
            seed_root: &root,
            iteration: iter,
            kernels: self.kernels,
            ppu: psi_alias.as_ref(),
        };
        let schedule =
            if self.slot_affine { Schedule::SlotAffine } else { Schedule::Steal };
        let t0 = Instant::now();
        match &mut self.z {
            SamplerZ::Nested(zs) => match &self.block_plan {
                // Streamed + prefetched: block t+1's I/O cooks on the
                // pool while block t sweeps. Bit-identical to every
                // other form (per-document RNG streams).
                Some(blocks) if self.stream_prefetch => sweep.run_streamed_prefetched(
                    &*self.packed,
                    &zstep::NestedZ::new(zs),
                    &mut self.m,
                    blocks,
                    &self.pool,
                    &mut self.scratch,
                ),
                // Streamed: block-refined plan, per-slot hot z buffers
                // over the resident assignments. Bit-identical to the
                // resident sweep (per-document RNG streams).
                Some(blocks) => sweep.run_streamed(
                    &*self.packed,
                    &zstep::NestedZ::new(zs),
                    &mut self.m,
                    blocks,
                    &*self.pool,
                    &mut self.scratch,
                    schedule,
                ),
                None => sweep.run_with_scratch_sched(
                    &*self.packed,
                    zs,
                    &mut self.m,
                    &self.doc_plan,
                    &*self.pool,
                    &mut self.scratch,
                    schedule,
                ),
            },
            // Packed-only layouts always run the streaming machinery —
            // over the block plan when streaming is on, otherwise over
            // the document plan itself (its shards are contiguous and
            // cover 0..D, so it is a valid block plan). Bit-identical
            // to the resident nested sweep.
            SamplerZ::Arena(flat) => run_packed_sweep(
                &sweep,
                self.token_file.as_deref(),
                &self.packed,
                &zstep::ArenaZ::new(flat, self.packed.doc_offsets()),
                &mut self.m,
                self.block_plan.as_ref().unwrap_or(&self.doc_plan),
                self.stream_prefetch,
                &self.pool,
                &mut self.scratch,
                schedule,
            ),
            SamplerZ::File(f) => run_packed_sweep(
                &sweep,
                self.token_file.as_deref(),
                &self.packed,
                f,
                &mut self.m,
                self.block_plan.as_ref().unwrap_or(&self.doc_plan),
                self.stream_prefetch,
                &self.pool,
                &mut self.scratch,
                schedule,
            ),
        }
        self.timers.add("z", t0.elapsed());
        // 4. Merge the slot outputs (draining the scratch in place so
        // its allocations survive into the next sweep). The n merge is
        // pool-parallel — it gates Φ_{t+1}, so it sits on the critical
        // path.
        let t0 = Instant::now();
        self.zero_mass_tokens = 0;
        self.flag_tokens = 0;
        self.sparse_work = 0;
        let (mut pf_hits, mut pf_stalls, mut pf_failures) = (0u64, 0u64, 0u64);
        let (mut kern_gather, mut kern_scan) = (0u64, 0u64);
        let (mut ppu_tokens, mut ppu_doc, mut ppu_word) = (0u64, 0u64, 0u64);
        for s in &self.scratch {
            self.zero_mass_tokens += s.out.zero_mass_tokens;
            self.flag_tokens += s.out.flag_tokens;
            self.sparse_work += s.out.sparse_work;
            pf_hits += s.out.prefetch_hits;
            pf_stalls += s.out.prefetch_stalls;
            pf_failures += s.out.prefetch_failures;
            kern_gather += s.out.kern_gather_elems;
            kern_scan += s.out.kern_scan_tokens;
            ppu_tokens += s.out.ppu_tokens;
            ppu_doc += s.out.ppu_doc_accepts;
            ppu_word += s.out.ppu_word_accepts;
        }
        if ppu_tokens > 0 {
            self.timers.incr(PhaseTimers::PPU_TOKENS, ppu_tokens);
            self.timers.incr(PhaseTimers::PPU_DOC_ACCEPTS, ppu_doc);
            self.timers.incr(PhaseTimers::PPU_WORD_ACCEPTS, ppu_word);
        }
        if pf_hits + pf_stalls > 0 {
            self.timers.incr(PhaseTimers::PREFETCH_HITS, pf_hits);
            self.timers.incr(PhaseTimers::PREFETCH_STALLS, pf_stalls);
        }
        if pf_failures > 0 {
            self.timers.incr(PhaseTimers::PREFETCH_FAILURES, pf_failures);
        }
        if kern_gather + kern_scan > 0 {
            self.timers.incr(PhaseTimers::KERNEL_GATHER_ELEMS, kern_gather);
            self.timers.incr(PhaseTimers::KERNEL_SCAN_TOKENS, kern_scan);
        }
        self.n = Arc::new(TopicWordRows::merge_par(
            self.cfg.k_max,
            self.scratch.iter_mut().map(|s| &mut s.out.n_acc),
            &*self.pool,
            &mut self.merge_scratch,
        ));
        let hist = DocCountHist::merge_mut(
            self.cfg.k_max,
            self.scratch.iter_mut().map(|s| &mut s.out.hist),
        );
        self.timers.add("merge", t0.elapsed());
        // 5. Pipeline front: n_t is final, so Φ_{t+1} can start now —
        // submit it to the workers and keep the tail on this thread.
        if self.pipelined {
            self.phi_pipe
                .submit_next(iter + 1, &root, &self.n, self.cfg.beta, vocab, &self.pool);
        }
        // 6. l via the binomial trick. In pipelined mode it runs inline
        // on this thread (the workers are busy with Φ_{t+1}); the
        // per-topic RNG streams make the result identical either way.
        let t0 = Instant::now();
        let l_root = root.stream(iter.wrapping_mul(0x51ed) ^ 0x77);
        self.l = if self.pipelined {
            lstep::sample_l(&l_root, &hist, &self.psi, self.cfg.alpha, 1usize)
        } else {
            lstep::sample_l(&l_root, &hist, &self.psi, self.cfg.alpha, &*self.pool)
        };
        self.timers.add("l", t0.elapsed());
        // 7. Ψ | l.
        let t0 = Instant::now();
        let mut psi_rng = root.stream(iter.wrapping_mul(0xabcd) ^ 0x7051);
        psi::sample_psi(&mut psi_rng, &self.l, self.cfg.gamma, &mut self.psi);
        self.timers.add("psi", t0.elapsed());
        self.timers.add("critical_path", step_t0.elapsed());
        self.timers.incr("thread_spawns", par::stats::thread_spawns() - spawns0);
        self.timers.incr("pool_jobs", self.pool.jobs_run() - jobs0);
        self.timers.incr("scratch_allocs", par::stats::scratch_allocs() - allocs0);
        // Residency gauges (set, not accumulated — the z store can
        // change layout mid-run via `move_z_to_file`).
        self.timers.set(PhaseTimers::RESIDENT_BYTES, self.resident_state_bytes());
        self.timers.set(PhaseTimers::ARENA_BYTES, self.arena_bytes());
        self.timers.set(PhaseTimers::Z_BYTES, self.z_bytes());
        self.iteration += 1;
        Ok(())
    }

    fn diagnostics(&self) -> DiagSnapshot {
        let rows: Vec<Vec<(u32, u32)>> =
            (0..self.cfg.k_max).map(|k| self.n.row(k).to_vec()).collect();
        // word + CRP terms, scored in the z store's own layout —
        // `crp_loglik_packed` is bit-identical to the nested
        // `crp_loglik` (same sharding plan, same accumulation order).
        let wl = loglik::word_loglik(&rows, self.cfg.beta, self.packed.vocab_size());
        let crp = match &self.z {
            SamplerZ::Nested(zs) => {
                loglik::crp_loglik(zs, &self.psi, self.cfg.alpha, &*self.pool)
            }
            SamplerZ::Arena(flat) => loglik::crp_loglik_packed(
                flat,
                self.packed.doc_offsets(),
                &self.psi,
                self.cfg.alpha,
                &*self.pool,
            ),
            SamplerZ::File(f) => loglik::crp_loglik_packed(
                &f.to_flat().expect("z store read"),
                f.offsets(),
                &self.psi,
                self.cfg.alpha,
                &*self.pool,
            ),
        };
        let ll = wl + crp;
        let mut tokens_per_topic: Vec<u64> =
            self.n.row_totals().iter().copied().filter(|&t| t > 0).collect();
        tokens_per_topic.sort_unstable_by(|a, b| b.cmp(a));
        DiagSnapshot {
            log_likelihood: ll,
            active_topics: self.n.active_topics(),
            flag_topic_tokens: self.flag_tokens,
            total_tokens: self.n.total(),
            tokens_per_topic,
        }
    }

    fn z_view(&self) -> ZView<'_> {
        match &self.z {
            SamplerZ::Nested(zs) => ZView::Nested(zs),
            SamplerZ::Arena(flat) => ZView::Packed {
                z: Cow::Borrowed(flat),
                offsets: Cow::Borrowed(self.packed.doc_offsets()),
            },
            SamplerZ::File(f) => ZView::Packed {
                z: Cow::Owned(f.to_flat().expect("z store read")),
                offsets: Cow::Borrowed(f.offsets()),
            },
        }
    }

    fn topic_word_rows(&self) -> Vec<Vec<(u32, u32)>> {
        (0..self.cfg.k_max).map(|k| self.n.row(k).to_vec()).collect()
    }

    fn docs(&self) -> &dyn crate::corpus::CorpusView {
        &*self.packed
    }

    fn iterations_done(&self) -> usize {
        self.iteration
    }

    fn checkpoint(&self) -> crate::hdp::checkpoint::Checkpoint {
        // The inherent snapshot records the learned `Ψ` (the trait
        // default would fabricate a uniform one).
        PcSampler::checkpoint(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::HdpCorpusSpec;

    fn tiny_corpus(seed: u64) -> Arc<Corpus> {
        let (c, _) = HdpCorpusSpec {
            vocab: 200,
            topics: 5,
            gamma: 2.0,
            alpha: 1.0,
            topic_beta: 0.05,
            docs: 60,
            mean_doc_len: 30.0,
            len_sigma: 0.3,
            min_doc_len: 8,
        }
        .generate(seed);
        Arc::new(c)
    }

    fn cfg() -> HdpConfig {
        HdpConfig { alpha: 0.5, beta: 0.05, gamma: 1.0, k_max: 40, init_topics: 1 }
    }

    #[test]
    fn runs_and_conserves_tokens() {
        let corpus = tiny_corpus(1);
        let total = corpus.num_tokens();
        let mut s = PcSampler::new(corpus.clone(), cfg(), 2, 42).unwrap();
        for _ in 0..5 {
            s.step().unwrap();
            assert_eq!(s.n().total(), total, "token conservation");
            s.check_consistency().unwrap();
            let psum: f64 = s.psi().iter().sum();
            assert!((psum - 1.0).abs() < 1e-9);
        }
        let d = s.diagnostics();
        assert_eq!(d.total_tokens, total);
        assert!(d.active_topics >= 1);
        assert!(d.log_likelihood.is_finite());
    }

    #[test]
    fn grows_topics_from_single_init() {
        let corpus = tiny_corpus(2);
        let mut s = PcSampler::new(corpus, cfg(), 1, 7).unwrap();
        for _ in 0..30 {
            s.step().unwrap();
        }
        let d = s.diagnostics();
        assert!(
            d.active_topics > 1,
            "sampler should create topics (got {})",
            d.active_topics
        );
        // And not blow up to the truncation.
        assert!(d.active_topics < 40);
    }

    #[test]
    fn loglik_improves_from_init() {
        let corpus = tiny_corpus(3);
        let mut s = PcSampler::new(corpus, cfg(), 2, 11).unwrap();
        // Baseline: the single-topic INITIAL state (before any step).
        // Burn-in on this corpus takes ~200 sweeps (the transient
        // fragments first, then consolidates — the paper runs 100k
        // sweeps on AP); after it the joint must beat the init.
        let init = s.diagnostics().log_likelihood;
        for _ in 0..250 {
            s.step().unwrap();
        }
        let last = s.diagnostics().log_likelihood;
        assert!(
            last > init,
            "log-likelihood should improve over the init: {init} -> {last}"
        );
    }

    #[test]
    fn chain_reproducible_and_thread_invariant() {
        // Full matrix: threads × document-plan family × pipelining ×
        // z schedule. Every chain must be bit-identical to the
        // single-threaded sequential reference after 4 sweeps — z, l,
        // and Ψ.
        let corpus = tiny_corpus(4);
        let run = |threads: usize, weighted: bool, pipelined: bool, affine: bool| {
            let mut s = PcSampler::new(corpus.clone(), cfg(), threads, 99).unwrap();
            s.set_pipelined(pipelined);
            s.set_slot_affine(affine);
            let plan = if weighted {
                Sharding::weighted(&corpus.doc_weights(), threads)
            } else {
                Sharding::even(corpus.num_docs(), threads)
            };
            s.set_doc_plan(plan);
            for _ in 0..4 {
                s.step().unwrap();
            }
            (s.z_nested(), s.l().to_vec(), s.psi().to_vec())
        };
        let (z_ref, l_ref, psi_ref) = run(1, false, false, false);
        for &threads in &[1usize, 2, 3, 7] {
            for &weighted in &[false, true] {
                for &pipelined in &[false, true] {
                    for &affine in &[false, true] {
                        let (z, l, psi) = run(threads, weighted, pipelined, affine);
                        let tag = format!(
                            "threads={threads} weighted={weighted} \
                             pipelined={pipelined} affine={affine}"
                        );
                        assert_eq!(z, z_ref, "z diverged: {tag}");
                        assert_eq!(l, l_ref, "l diverged: {tag}");
                        assert_eq!(psi, psi_ref, "psi diverged: {tag}");
                    }
                }
            }
        }
    }

    #[test]
    fn pipelined_matches_sequential_including_loglik() {
        // Dedicated pipelined-vs-barriered bit-identity: run the same
        // seeded chain both ways, interleaving diagnostics (which
        // overlap the async Φ job in pipelined mode), and require
        // identical z, l, Ψ, and bit-equal log-likelihood each sweep.
        let corpus = tiny_corpus(8);
        for threads in [2usize, 3] {
            let mut seq = PcSampler::new(corpus.clone(), cfg(), threads, 31).unwrap();
            seq.set_pipelined(false);
            let mut pip = PcSampler::new(corpus.clone(), cfg(), threads, 31).unwrap();
            assert!(pip.pipelined());
            for it in 0..6 {
                seq.step().unwrap();
                pip.step().unwrap();
                let (ds, dp) = (seq.diagnostics(), pip.diagnostics());
                assert_eq!(
                    dp.log_likelihood.to_bits(),
                    ds.log_likelihood.to_bits(),
                    "threads={threads} iter={it}"
                );
                assert_eq!(pip.z_nested(), seq.z_nested(), "iter={it}");
                assert_eq!(pip.l(), seq.l(), "iter={it}");
                assert_eq!(pip.psi(), seq.psi(), "iter={it}");
            }
        }
    }

    #[test]
    fn toggling_pipeline_mid_chain_is_transparent() {
        // Switching modes between steps must not perturb the chain: the
        // pending Φ job is discarded and resampled from the same
        // streams.
        let corpus = tiny_corpus(9);
        let mut a = PcSampler::new(corpus.clone(), cfg(), 3, 17).unwrap();
        let mut b = PcSampler::new(corpus, cfg(), 3, 17).unwrap();
        b.set_pipelined(false);
        for it in 0..6 {
            a.set_pipelined(it % 2 == 0); // flip every step
            a.step().unwrap();
            b.step().unwrap();
            assert_eq!(a.z_nested(), b.z_nested(), "iter={it}");
            assert_eq!(a.psi(), b.psi(), "iter={it}");
        }
    }

    #[test]
    fn pool_reuses_workers_across_iterations() {
        // Every parallel phase must run as a job on the persistent
        // pool, with no per-phase thread spawns. Pipelined steady
        // state: alias + z + merge(drain) + merge(combine) + async Φ
        // submit = 5 jobs per iteration (l runs inline; Φ for t+1 was
        // submitted by step t).
        let corpus = tiny_corpus(6);
        let mut s = PcSampler::new(corpus.clone(), cfg(), 4, 5).unwrap();
        assert_eq!(s.pool().slots(), 4);
        s.step().unwrap(); // warm-up (scratch growth + sync Φ happen here)
        let jobs0 = s.pool().jobs_run();
        for _ in 0..3 {
            s.step().unwrap();
        }
        assert_eq!(s.pool().jobs_run() - jobs0, 15, "5 pool jobs per iteration");
        assert!(s.timers.counter("pool_jobs") >= 20);
        // Sequential mode: Φ + alias + z + merge×2 + l = 6 blocking
        // jobs per iteration.
        let mut s = PcSampler::new(corpus, cfg(), 4, 5).unwrap();
        s.set_pipelined(false);
        s.step().unwrap();
        let jobs0 = s.pool().jobs_run();
        for _ in 0..3 {
            s.step().unwrap();
        }
        assert_eq!(s.pool().jobs_run() - jobs0, 18, "6 pool jobs per iteration");
    }

    #[test]
    fn warm_iterations_do_not_grow_scratch() {
        // After a couple of warm-up sweeps every reusable buffer must
        // have reached its steady-state size. (The global
        // scratch_allocs counter can't be asserted here — tests run
        // concurrently — so check the structures directly: the
        // per-slot accumulators must never regrow thanks to the
        // tokens-per-slot pair hint, which slot-affine scheduling makes
        // a deterministic bound.)
        let corpus = tiny_corpus(7);
        let mut s = PcSampler::new(corpus.clone(), cfg(), 3, 23).unwrap();
        s.set_slot_affine(true);
        for _ in 0..3 {
            s.step().unwrap();
        }
        let caps: Vec<usize> =
            s.scratch.iter().map(|sc| sc.out.n_acc.capacity()).collect();
        for _ in 0..5 {
            s.step().unwrap();
        }
        let caps_after: Vec<usize> =
            s.scratch.iter().map(|sc| sc.out.n_acc.capacity()).collect();
        assert_eq!(caps_after, caps, "steady-state sweeps must not regrow n_acc");
        // Pool-accounting of the accumulator sizing: the pre-size must
        // come from the plan in effect, not whole-corpus totals. The
        // open-addressing table doubles, so capacity(hint) < 2·hint —
        // assert both the resident plan hint and, after enabling
        // 1-doc-block streaming, the refined-plan hint bound it.
        let weights = corpus.doc_weights();
        let hint =
            zstep::plan_pair_hint(&s.doc_plan, &weights, s.pool.slots());
        for sc in &s.scratch {
            assert!(
                sc.out.n_acc.capacity() < 2 * hint.max(64),
                "slot accumulator ({}) over-allocated vs plan hint {hint}",
                sc.out.n_acc.capacity()
            );
        }
        s.set_streaming(Some(1));
        let blocks = s.stream_block_plan().unwrap().clone();
        let hint_blocks = zstep::plan_pair_hint(&blocks, &weights, s.pool.slots());
        for _ in 0..2 {
            s.step().unwrap();
        }
        for sc in &s.scratch {
            assert!(
                sc.out.n_acc.capacity() < 2 * hint_blocks.max(64),
                "streamed slot accumulator ({}) over-allocated vs block-plan hint {hint_blocks}",
                sc.out.n_acc.capacity()
            );
        }
    }

    #[test]
    fn streamed_chain_matches_resident() {
        // Sampler-level streamed-vs-resident bit-identity, including a
        // mid-chain flip into (and out of) streaming — the full matrix
        // lives in tests/statistical.rs.
        let corpus = tiny_corpus(10);
        let mut resident = PcSampler::new(corpus.clone(), cfg(), 3, 55).unwrap();
        let mut streamed = PcSampler::new(corpus.clone(), cfg(), 3, 55).unwrap();
        streamed.set_streaming(Some(3));
        assert_eq!(streamed.streaming(), Some(3));
        let mut prefetched = PcSampler::new(corpus.clone(), cfg(), 3, 55).unwrap();
        prefetched.set_streaming(Some(3));
        prefetched.set_stream_prefetch(true);
        for it in 0..3 {
            resident.step().unwrap();
            streamed.step().unwrap();
            prefetched.step().unwrap();
            assert_eq!(streamed.z_nested(), resident.z_nested(), "iter={it}");
            assert_eq!(streamed.l(), resident.l(), "iter={it}");
            assert_eq!(streamed.psi(), resident.psi(), "iter={it}");
            assert_eq!(
                prefetched.z_nested(),
                resident.z_nested(),
                "prefetched iter={it}"
            );
            assert_eq!(prefetched.psi(), resident.psi(), "prefetched iter={it}");
        }
        // Every prefetched block was accounted a hit xor a stall.
        let accounted = prefetched.timers.counter("prefetch_hits")
            + prefetched.timers.counter("prefetch_stalls");
        assert_eq!(
            accounted,
            3 * prefetched.stream_block_plan().unwrap().len() as u64
        );
        // Hot streamed z is bounded by slots × max block, far below
        // the corpus arena.
        let weights = corpus.doc_weights();
        let max_block: u64 = streamed
            .stream_block_plan()
            .unwrap()
            .shards()
            .iter()
            .map(|b| weights[b.start..b.end].iter().sum())
            .max()
            .unwrap();
        let bound = 2 * 2 * 4 * max_block as usize * streamed.pool.slots();
        assert!(
            streamed.stream_buf_bytes() <= bound,
            "hot z {} exceeds blocks-in-flight bound {bound}",
            streamed.stream_buf_bytes()
        );
        assert!(
            (streamed.stream_buf_bytes() as u64) < corpus.num_tokens() * 4,
            "streamed sweep materialized corpus-scale z"
        );
        // Flip back to resident mid-chain: still bit-identical, and the
        // chain state is already in place (NestedZ streams through it).
        streamed.set_streaming(None);
        for it in 0..2 {
            resident.step().unwrap();
            streamed.step().unwrap();
            assert_eq!(streamed.z_nested(), resident.z_nested(), "post-flip iter={it}");
            assert_eq!(streamed.psi(), resident.psi(), "post-flip iter={it}");
        }
        s_consistency(&streamed, &corpus);
    }

    fn s_consistency(s: &PcSampler, corpus: &Arc<Corpus>) {
        s.check_consistency().unwrap();
        assert_eq!(s.n().total(), corpus.num_tokens());
    }

    #[test]
    fn simd_and_pinning_chains_bit_identical() {
        // Sampler-level kernel/pinning invariance: every cell of
        // simd {off,on} × pinning {off,on} must be bit-identical to
        // the scalar unpinned reference (the full matrix against the
        // sequential reference lives in tests/statistical.rs).
        // Pinning may degrade to off when the OS denies
        // sched_setaffinity — that is exactly the graceful path the
        // test covers.
        let corpus = tiny_corpus(11);
        let run = |simd: bool, pin: bool| {
            let mut s = PcSampler::new(corpus.clone(), cfg(), 3, 77).unwrap();
            s.set_simd(simd);
            assert_eq!(s.simd_active(), s.kernel_tier() != "scalar");
            if pin {
                let engaged = s.set_pinning(true);
                assert_eq!(engaged, s.pinning());
            }
            for _ in 0..4 {
                s.step().unwrap();
            }
            if simd && s.simd_active() {
                // Accelerated tiers must actually be exercised and
                // accounted.
                assert!(
                    s.timers.counter(PhaseTimers::KERNEL_ALIAS_ELEMS) > 0,
                    "alias kernel counter untouched"
                );
            }
            if !simd {
                assert_eq!(s.timers.counter(PhaseTimers::KERNEL_GATHER_ELEMS), 0);
                assert_eq!(s.timers.counter(PhaseTimers::KERNEL_SCAN_TOKENS), 0);
            }
            let _ = s.set_pinning(false);
            (s.z_nested(), s.l().to_vec(), s.psi().to_vec())
        };
        let reference = run(false, false);
        for &(simd, pin) in &[(true, false), (false, true), (true, true)] {
            assert_eq!(run(simd, pin), reference, "simd={simd} pin={pin}");
        }
    }

    #[test]
    fn packed_only_chain_matches_nested() {
        // Arena- and file-backed packed-only samplers (no nested
        // corpus, no nested z — ISSUE 10's tentpole) must be
        // bit-identical to the nested reference, diagnostics included,
        // and must actually retire the duplicated residency.
        let corpus = tiny_corpus(12);
        let packed = Arc::new(corpus.to_packed());
        let mut nested = PcSampler::new(corpus.clone(), cfg(), 3, 33).unwrap();
        assert_eq!(nested.z_mode(), "nested");
        let mut arena = PcSampler::from_packed(packed.clone(), cfg(), 3, 33).unwrap();
        assert_eq!(arena.z_mode(), "arena");
        let dir = std::env::temp_dir().join("hdp_pc_packed_only_test");
        let mut filed = PcSampler::from_packed(packed.clone(), cfg(), 3, 33).unwrap();
        filed.move_z_to_file(&dir.join("z.bin")).unwrap();
        assert_eq!(filed.z_mode(), "file");
        for it in 0..4 {
            nested.step().unwrap();
            arena.step().unwrap();
            filed.step().unwrap();
            assert_eq!(arena.z_nested(), nested.z_nested(), "arena iter={it}");
            assert_eq!(filed.z_nested(), nested.z_nested(), "file iter={it}");
            assert_eq!(arena.l(), nested.l(), "arena iter={it}");
            assert_eq!(arena.psi(), nested.psi(), "arena iter={it}");
            assert_eq!(filed.psi(), nested.psi(), "file iter={it}");
            let (dn, da, df) =
                (nested.diagnostics(), arena.diagnostics(), filed.diagnostics());
            assert_eq!(
                da.log_likelihood.to_bits(),
                dn.log_likelihood.to_bits(),
                "arena loglik iter={it}"
            );
            assert_eq!(
                df.log_likelihood.to_bits(),
                dn.log_likelihood.to_bits(),
                "file loglik iter={it}"
            );
        }
        arena.check_consistency().unwrap();
        filed.check_consistency().unwrap();
        // The arena layout retires the nested-z duplication; the file
        // layout retires the resident z arena too.
        assert!(arena.resident_state_bytes() < nested.resident_state_bytes());
        assert!(filed.resident_state_bytes() < arena.resident_state_bytes());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn packed_only_streaming_and_prefetch_match() {
        // The packed-only layouts compose with the streaming/prefetch
        // knobs: every combination stays on the reference chain.
        let corpus = tiny_corpus(13);
        let packed = Arc::new(corpus.to_packed());
        let mut reference = PcSampler::new(corpus.clone(), cfg(), 2, 44).unwrap();
        let mut streamed = PcSampler::from_packed(packed.clone(), cfg(), 2, 44).unwrap();
        streamed.set_streaming(Some(4));
        let mut prefetched = PcSampler::from_packed(packed, cfg(), 2, 44).unwrap();
        prefetched.set_streaming(Some(4));
        prefetched.set_stream_prefetch(true);
        for it in 0..3 {
            reference.step().unwrap();
            streamed.step().unwrap();
            prefetched.step().unwrap();
            assert_eq!(streamed.z_nested(), reference.z_nested(), "iter={it}");
            assert_eq!(prefetched.z_nested(), reference.z_nested(), "pf iter={it}");
            assert_eq!(streamed.psi(), reference.psi(), "iter={it}");
            assert_eq!(prefetched.psi(), reference.psi(), "pf iter={it}");
        }
        streamed.check_consistency().unwrap();
        prefetched.check_consistency().unwrap();
    }

    #[test]
    fn flag_topic_unused_with_large_truncation() {
        let corpus = tiny_corpus(5);
        let mut s = PcSampler::new(corpus, cfg(), 2, 1).unwrap();
        for _ in 0..10 {
            s.step().unwrap();
            assert_eq!(
                s.flag_tokens, 0,
                "no tokens should reach the flag topic at K*=40"
            );
        }
    }
}
