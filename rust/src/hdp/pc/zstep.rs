//! The doubly sparse `z` Gibbs step (§2.5, eq. 22–24).
//!
//! The full conditional `P(z_{i,d} = k) ∝ φ_{k,v}·α·Ψ_k + φ_{k,v}·m^{-i}_{d,k}`
//! splits into:
//!
//! * **bucket (a)** `φ_{k,v}·α·Ψ_k` — document-independent: one Walker
//!   alias table per word type, built once per iteration over the
//!   nonzero support of the `Φ` column ([`WordTables`]);
//! * **bucket (b)** `φ_{k,v}·m^{-i}_{d,k}` — evaluated per token by
//!   iterating the sparser of `m_d` (with binary-search `φ` lookups)
//!   and the `Φ` column (with O(1) dense-scratch `m` lookups) — the
//!   `O(min(K^{(m)}_d, K^{(Φ)}_v))` bound of eq. 29.
//!
//! `Φ` and `Ψ` are fixed during the phase (partially collapsed), so the
//! alias tables are exact and documents are embarrassingly parallel.
//! Each document owns an RNG stream keyed by (iteration, doc id): the
//! chain is bit-identical under any shard layout or thread count.
//!
//! # Pólya-urn approximate fast path (`ZSweep::ppu`)
//!
//! Opt-in alternative z kernel (Terenin, Magnusson, Jonsson & Draper,
//! *Pólya Urn LDA*): instead of materializing the exact per-token
//! bucket-(b) partial sums, each token takes two
//! Metropolis–Hastings sub-steps with cheap *cycled proposals* against
//! the same target `π(k) ∝ φ_{k,v}·(α·Ψ_k + m^{-i}_{d,k})`:
//!
//! * **doc proposal** `q_d(k) ∝ m_{d,k} + α·Ψ_k` — drawn in O(1) by
//!   the Pólya-urn trick: with probability `len_d / (len_d + α·|Ψ|)`
//!   read the assignment of a uniformly random token of the document
//!   (the document's own z vector *is* the urn — no per-doc table
//!   build), else draw from a per-iteration dense `Ψ` alias table;
//! * **word proposal** `q_w(k) ∝ φ_{k,v}·α·Ψ_k` — the existing
//!   bucket-(a) per-word alias table, also O(1). Topic birth flows
//!   through this proposal (the β-noise support of the sampled `Φ`).
//!
//! Each proposal is accepted with the standard MH ratio
//! `min(1, π(k')q(k)/π(k)q(k'))`, so the sweep is a *valid* MCMC
//! kernel for the *exact* conditional — the approximation is in
//! mixing (a token may keep a stale topic for an iteration), not in
//! the stationary distribution. Per-token cost drops from
//! `O(min(K^m_d, K^Φ_v))` to O(1) draws plus at most two binary
//! searches for `φ` lookups.
//!
//! **Deviation from the exact sweep:** the drawn topics differ
//! per-token (different RNG consumption, MH rejections), so a PPU
//! chain is *not* bit-comparable to the exact chain. It is still
//! fully deterministic for a fixed seed — all randomness flows
//! through the same per-(iteration, doc) streams — so PPU chains are
//! bit-identical across thread counts, schedules, streaming,
//! prefetch, pipelining, and SIMD tiers, exactly like exact chains.
//!
//! **Validation:** `tests/statistical.rs` holds PPU to the exact
//! chain's stationary behaviour — joint log-likelihood and active
//! topic counts within tolerance across seeds, held-out
//! document-completion perplexity within a relative band, and pooled
//! χ²/L1 agreement of the recovered topic-size profiles — plus the
//! bit-identity invariance matrix *within* the PPU chain. The
//! speed side is the exact-vs-PPU tokens/sec columns in
//! `benches/z_sampling.rs` (`BENCH_z_sampling.json`).

use crate::alias::SparseAlias;
use crate::corpus::io::{PackedCorpusFile, PositionedFile};
use crate::corpus::{DocAccess, PackedCorpus};
use crate::par::pool::SendPtr;
use crate::par::{self, Executor, JobHandle, Schedule, Shard, Sharding, WorkerPool};
use crate::rng::Pcg64;
use crate::simd::Kernels;
use crate::sparse::{DocCountHist, DocTopics, PhiMatrix, TopicWordAcc};
use std::marker::PhantomData;
use std::sync::Arc;

/// Reusable per-executor-slot buffers for [`WordTables::build_into`]:
/// the bucket-(a) weight vector for the word currently being processed
/// by that slot. Growth is counted via
/// [`crate::par::stats::note_scratch_alloc`].
#[derive(Debug, Default)]
pub struct WordTablesScratch {
    weights: Vec<Vec<f64>>,
}

impl WordTablesScratch {
    /// Empty scratch; per-slot buffers are grown on first use.
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, slots: usize) {
        if self.weights.len() < slots {
            crate::par::stats::note_scratch_alloc();
            self.weights.resize_with(slots, Vec::new);
        }
    }
}

/// Per-word-type bucket-(a) alias tables and totals.
pub struct WordTables {
    /// `tables[v]` — alias over `{k : φ_{k,v} > 0}` with weights
    /// `φ_{k,v}·α·Ψ_k`; `None` for words with an empty `Φ` column.
    tables: Vec<Option<SparseAlias>>,
    /// Dense per-word totals `Q_v` — the per-token hot load (§Perf:
    /// one predictable array read instead of an Option + pointer
    /// chase per token).
    masses: Vec<f64>,
}

impl WordTables {
    /// Empty table set, ready for [`WordTables::build_into`]. The
    /// samplers keep one of these per chain and rebuild it in place
    /// every iteration so the `tables`/`masses` vectors (and the
    /// per-slot weight buffers) survive across sweeps.
    pub fn empty() -> Self {
        Self { tables: Vec::new(), masses: Vec::new() }
    }

    /// Build all tables in parallel over word types on any executor
    /// (a `threads: usize` scoped strategy or a
    /// [`&WorkerPool`](crate::par::WorkerPool)). One-shot convenience
    /// over [`WordTables::build_into`].
    pub fn build<E: par::Executor + Copy>(
        phi: &PhiMatrix,
        psi: &[f64],
        alpha: f64,
        exec: E,
    ) -> Self {
        let mut out = Self::empty();
        let mut scratch = WordTablesScratch::new();
        out.build_into(phi, psi, alpha, exec, &mut scratch);
        out
    }

    /// Rebuild the tables in place, recycling the `tables`/`masses`
    /// vectors and the per-slot weight buffers across iterations
    /// instead of reallocating them each time. The result is identical
    /// to [`WordTables::build`] (same per-word weight order, same
    /// float summation order).
    pub fn build_into<E: par::Executor + Copy>(
        &mut self,
        phi: &PhiMatrix,
        psi: &[f64],
        alpha: f64,
        exec: E,
        scratch: &mut WordTablesScratch,
    ) {
        self.build_into_with(phi, psi, alpha, exec, scratch, &Kernels::scalar())
    }

    /// [`WordTables::build_into`] with an explicit kernel set. With an
    /// accelerated tier the per-word weight vector `φ_{k,v}·α·Ψ_k` is
    /// built by a SIMD gather and the alias construction runs through
    /// the kernel table; the result is **bit-identical** to the scalar
    /// build (the gather keeps the scalar per-element operation order,
    /// the table total is the same left-to-right sum inside
    /// [`SparseAlias`], and the reassociated `sum_f64` is used only
    /// for the zero-mass degeneracy check, where any summation order
    /// of nonnegative terms agrees on `> 0`).
    pub fn build_into_with<E: par::Executor + Copy>(
        &mut self,
        phi: &PhiMatrix,
        psi: &[f64],
        alpha: f64,
        exec: E,
        scratch: &mut WordTablesScratch,
        kernels: &Kernels,
    ) {
        let vocab = phi.vocab();
        if self.tables.len() != vocab {
            crate::par::stats::note_scratch_alloc();
            self.tables.clear();
            self.tables.resize_with(vocab, || None);
            self.masses.clear();
            self.masses.resize(vocab, 0.0);
        }
        if vocab == 0 {
            return;
        }
        let plan = Sharding::even(vocab, exec.slots());
        scratch.ensure(exec.slot_bound(plan.len()));
        let tbase = crate::par::pool::SendPtr(self.tables.as_mut_ptr());
        let mbase = crate::par::pool::SendPtr(self.masses.as_mut_ptr());
        par::exec_shards_with(exec, &plan, &mut scratch.weights, |weights, _i, shard| {
            for v in shard.start..shard.end {
                let (topics, probs) = phi.col(v as u32);
                // SAFETY: shards cover disjoint word ranges, so index
                // `v` is owned by this task.
                let slot_t = unsafe { &mut *tbase.0.add(v) };
                let slot_m = unsafe { &mut *mbase.0.add(v) };
                let total;
                if kernels.is_accelerated() {
                    // Gathered build: w[i] = probs[i]·α·Ψ[topics[i]],
                    // same left-associated multiply per element as the
                    // scalar loop. `total` only gates the degeneracy
                    // branch below, so the reassociated SIMD sum is
                    // fine: nonnegative terms agree on `> 0` in any
                    // summation order.
                    (kernels.gather_mul_f64)(topics, probs, alpha, psi, weights);
                    weights.truncate(topics.len());
                    total = (kernels.sum_f64)(weights);
                } else {
                    weights.clear();
                    let mut t = 0.0f64;
                    for (&k, &p) in topics.iter().zip(probs) {
                        let w = p * alpha * psi[k as usize];
                        weights.push(w);
                        t += w;
                    }
                    total = t;
                }
                if topics.is_empty() || total <= 0.0 {
                    *slot_t = None;
                    *slot_m = 0.0;
                } else {
                    let alias =
                        SparseAlias::new_with(topics.to_vec(), weights, kernels);
                    *slot_m = alias.total();
                    *slot_t = Some(alias);
                }
            }
        });
    }

    /// Bucket-(a) total mass `Q_v = α·Σ_k φ_{k,v}Ψ_k`.
    #[inline]
    pub fn mass(&self, v: u32) -> f64 {
        self.masses[v as usize]
    }

    /// Draw a topic from bucket (a) for word `v`, or `None` when the
    /// word's column is empty / zero-mass (vocabulary id never observed
    /// under this `Φ`, or all its support topics have `Ψ_k = 0`).
    ///
    /// Callers on the z hot path and the serving fold-in reach this
    /// through a float edge: with `q_a = 0` and `s_b > 0`,
    /// `rng.f64() * s_b` can round up to exactly `s_b`, sending the
    /// draw to bucket (a) even though it has no mass. A zero-mass
    /// column must yield a *defined* fallback (the last bucket-(b)
    /// partial / the old assignment), never a panic — a serving
    /// request hitting an unseen vocabulary id must not take down a
    /// pool slot.
    #[inline]
    pub fn try_sample(&self, v: u32, rng: &mut Pcg64) -> Option<u32> {
        self.tables[v as usize].as_ref().map(|t| t.sample(rng))
    }
}

/// Shard-local outputs of the z phase.
pub struct ZShardResult {
    /// Topic-word counts accumulated from the new assignments.
    pub n_acc: TopicWordAcc,
    /// Per-topic document-count histogram (feeds the l step).
    pub hist: DocCountHist,
    /// Tokens whose conditional had zero mass (word vanished from every
    /// topic under the integer `Φ`): assignment kept, counted here.
    pub zero_mass_tokens: u64,
    /// Tokens assigned to the flag topic `K* − 1` (§2.4 check).
    pub flag_tokens: u64,
    /// Work counter: Σ min(K^m, K^Φ) over tokens (eq. 29 audit).
    pub sparse_work: u64,
    /// Prefetched streamed sweeps: blocks whose token/z loads were
    /// already complete when the sweep joined them (the overlap won).
    pub prefetch_hits: u64,
    /// Prefetched streamed sweeps: blocks the sweep had to wait for
    /// (or load inline — each slot stripe's cold first block counts
    /// here). `hits + stalls` equals the blocks this slot swept.
    pub prefetch_stalls: u64,
    /// Prefetched streamed sweeps: async loads that died (panicked
    /// after exhausting their I/O retries); the sweep discarded the
    /// back buffers and reloaded the block inline. Each failure is
    /// also counted as a stall.
    pub prefetch_failures: u64,
    /// Elements fed through the SIMD gather kernel in the dense
    /// bucket-(b) branch (0 when the sweep runs the scalar kernel set).
    pub kern_gather_elems: u64,
    /// Tokens whose bucket-(b) selection scan used the SIMD
    /// `find_first_gt` kernel (0 under the scalar kernel set).
    pub kern_scan_tokens: u64,
    /// Tokens resampled by the Pólya-urn MH fast path (0 for exact
    /// sweeps).
    pub ppu_tokens: u64,
    /// PPU doc-proposal MH moves accepted (urn / `Ψ`-alias side).
    pub ppu_doc_accepts: u64,
    /// PPU word-proposal MH moves accepted (bucket-(a) alias side).
    pub ppu_word_accepts: u64,
}

impl ZShardResult {
    /// Empty result for a `k_max`-topic model with a default `n_acc`
    /// capacity. Prefer [`ZShardResult::with_pair_hint`] when the
    /// caller knows the expected pair count — this default forces the
    /// accumulator to regrow during the first sweeps on any real shard.
    pub fn new(k_max: usize) -> Self {
        Self::with_pair_hint(k_max, 1 << 10)
    }

    /// Empty result whose `n_acc` is pre-sized for ~`pair_hint`
    /// distinct `(topic, word)` pairs (the samplers pass a
    /// tokens-per-slot estimate so warm sweeps never regrow the table).
    pub fn with_pair_hint(k_max: usize, pair_hint: usize) -> Self {
        Self {
            n_acc: TopicWordAcc::with_capacity(pair_hint.max(64)),
            hist: DocCountHist::new(k_max),
            zero_mass_tokens: 0,
            flag_tokens: 0,
            sparse_work: 0,
            prefetch_hits: 0,
            prefetch_stalls: 0,
            prefetch_failures: 0,
            kern_gather_elems: 0,
            kern_scan_tokens: 0,
            ppu_tokens: 0,
            ppu_doc_accepts: 0,
            ppu_word_accepts: 0,
        }
    }

    /// Zero the counters and empty the accumulators, keeping every
    /// allocation for the next sweep.
    fn reset(&mut self, k_max: usize) {
        self.n_acc.clear();
        self.hist.reset(k_max);
        self.zero_mass_tokens = 0;
        self.flag_tokens = 0;
        self.sparse_work = 0;
        self.prefetch_hits = 0;
        self.prefetch_stalls = 0;
        self.prefetch_failures = 0;
        self.kern_gather_elems = 0;
        self.kern_scan_tokens = 0;
        self.ppu_tokens = 0;
        self.ppu_doc_accepts = 0;
        self.ppu_word_accepts = 0;
    }
}

/// Reusable per-worker scratch.
pub struct ZScratch {
    /// Dense `m_{d,k}` lookup (K*), maintained only for the current doc.
    mdense: Vec<u32>,
    /// Topics that have appeared in the current document (may contain
    /// stale zero-count entries — iteration skips them; this makes the
    /// per-token add/remove O(1) instead of the O(K_d) list scans a
    /// `DocTopics` would cost; §Perf iteration 1).
    entries: Vec<u32>,
    /// Membership mark for `entries` (reset via `entries` at doc end).
    in_list: Vec<bool>,
    /// bucket-(b) partial topics (parallel to `partial_cums`). Sized to
    /// `k_max` once; per token only the first `used` entries are live —
    /// the stale tail is never read and never re-zeroed.
    partial_ks: Vec<u32>,
    /// bucket-(b) cumulative weights (parallel to `partial_ks`).
    partial_cums: Vec<f64>,
    /// Gathered `φ_{k,v}·m_{d,k}` weights for the dense bucket-(b)
    /// branch under an accelerated kernel set (unused in scalar mode).
    dense_w: Vec<f64>,
}

impl ZScratch {
    /// Scratch for `k_max` topics.
    pub fn new(k_max: usize) -> Self {
        crate::par::stats::note_scratch_alloc();
        Self {
            mdense: vec![0; k_max],
            entries: Vec::with_capacity(64),
            in_list: vec![false; k_max],
            partial_ks: vec![0; k_max],
            partial_cums: vec![0.0; k_max],
            dense_w: Vec::new(),
        }
    }

    /// Grow the dense workspaces to cover `k_max` topics if needed
    /// (new space is zeroed/false, matching the between-docs
    /// invariant) and drop any stale entries.
    fn ensure(&mut self, k_max: usize) {
        if self.mdense.len() < k_max {
            crate::par::stats::note_scratch_alloc();
            self.mdense.resize(k_max, 0);
            self.in_list.resize(k_max, false);
        }
        if self.partial_ks.len() < k_max {
            crate::par::stats::note_scratch_alloc();
            self.partial_ks.resize(k_max, 0);
            self.partial_cums.resize(k_max, 0.0);
        }
        self.entries.clear();
    }
}

/// One executor slot's persistent z-phase state: the dense probability
/// workspaces ([`ZScratch`]) plus the shard-local sweep outputs
/// ([`ZShardResult`]), all reused — cleared, not reallocated — across
/// sweeps. The sampler owns one per pool slot.
///
/// The streamed sweep additionally parks its per-slot **block
/// buffers** here: the hot copies of the current block's `z` (and, for
/// non-resident token sources, its tokens). They are the only
/// per-token state a streamed slot keeps, so total hot z is bounded by
/// `slots × max_block_tokens` — the "blocks in flight" residency bound
/// — instead of the corpus size.
pub struct ShardScratch {
    /// Sweep outputs accumulated by this slot (possibly over several
    /// shards when the pool has fewer slots than the plan has shards).
    pub out: ZShardResult,
    scratch: ZScratch,
    /// Streamed mode: the current block's assignments.
    z_buf: Vec<u32>,
    /// Streamed mode: the current block's tokens (unused — left empty —
    /// when the token source is memory-resident).
    tok_buf: Vec<u32>,
    /// Prefetched streamed mode: the **back** buffer pair the async
    /// load of the slot's next block fills while the front pair
    /// sweeps; swapped at join. Empty for non-prefetched sweeps.
    z_buf2: Vec<u32>,
    tok_buf2: Vec<u32>,
}

impl ShardScratch {
    /// Fresh scratch for a `k_max`-topic model (default `n_acc` size;
    /// see [`ShardScratch::with_pair_hint`]).
    pub fn new(k_max: usize) -> Self {
        Self::with_pair_hint(k_max, 1 << 10)
    }

    /// Fresh scratch whose accumulator is pre-sized for ~`pair_hint`
    /// distinct `(topic, word)` pairs — the samplers pass their
    /// plan-derived tokens-per-slot estimate here (see
    /// [`plan_pair_hint`]).
    pub fn with_pair_hint(k_max: usize, pair_hint: usize) -> Self {
        Self {
            out: ZShardResult::with_pair_hint(k_max, pair_hint),
            scratch: ZScratch::new(k_max),
            z_buf: Vec::new(),
            tok_buf: Vec::new(),
            z_buf2: Vec::new(),
            tok_buf2: Vec::new(),
        }
    }

    /// Bytes currently held by this slot's streamed block buffers
    /// (z + tokens, both double-buffer pairs). Stays 0 for resident
    /// sweeps; bounded by the largest block a slot has seen for
    /// streamed ones (×2 with prefetch on) — the number the residency
    /// tests and `benches/stream_ingest.rs` assert on.
    pub fn stream_buf_bytes(&self) -> usize {
        (self.z_buf.capacity()
            + self.tok_buf.capacity()
            + self.z_buf2.capacity()
            + self.tok_buf2.capacity())
            * std::mem::size_of::<u32>()
    }
}

/// Per-slot accumulator pre-size derived from the plan actually swept:
/// the [`Sharding::max_stripe_weight`] tokens-per-slot bound plus 25%
/// headroom, capped. A slot records at most one distinct
/// `(topic, word)` pair per token it processes, so under slot-affine
/// (or balanced stolen) scheduling the accumulator never regrows after
/// construction — and, unlike the old whole-corpus `N / slots`
/// estimate, a block-refined streamed plan is sized from its own
/// stripe, not from totals that assume every slot sees `1/slots` of
/// the corpus.
pub fn plan_pair_hint(plan: &Sharding, doc_weights: &[u64], slots: usize) -> usize {
    let per_slot = plan.max_stripe_weight(doc_weights, slots) as usize;
    (per_slot + per_slot / 4 + 32).min(1 << 22)
}

/// Parameters of one z sweep.
pub struct ZSweep<'a> {
    pub phi: &'a PhiMatrix,
    pub psi: &'a [f64],
    pub tables: &'a WordTables,
    pub alpha: f64,
    pub k_max: usize,
    /// Root RNG; per-document streams derive from it and the iteration.
    pub seed_root: &'a Pcg64,
    pub iteration: u64,
    /// Kernel set for the per-token hot loops. [`Kernels::scalar`] is
    /// the reference path; an accelerated set changes *how* the same
    /// arithmetic is evaluated, never *what* — the chain is
    /// bit-identical either way (see [`crate::simd`]'s policy).
    pub kernels: Kernels,
    /// `Some` engages the Pólya-urn MH fast path (see the module
    /// docs): the per-iteration dense `Ψ` alias backing the global
    /// side of the doc proposal. `None` runs the exact doubly-sparse
    /// kernel. The two modes produce *different* (both valid) chains.
    pub ppu: Option<&'a crate::alias::AliasTable>,
}

impl<'a> ZSweep<'a> {
    /// Resample one document in place: `doc` tokens, `zd` assignments,
    /// `md` sparse counts; accumulates into the shard result.
    pub fn resample_doc(
        &self,
        doc_id: usize,
        doc: &[u32],
        zd: &mut [u32],
        md: &mut DocTopics,
        scratch: &mut ZScratch,
        out: &mut ZShardResult,
    ) {
        if let Some(psi_alias) = self.ppu {
            return self.resample_doc_ppu(doc_id, doc, zd, md, scratch, out, psi_alias);
        }
        let mut rng = self
            .seed_root
            .stream(self.iteration.rotate_left(32) ^ 0x2000_0000)
            .stream(doc_id as u64);
        let accel = self.kernels.is_accelerated();
        // Hoist the per-token bounds checks: every topic id this doc
        // touches is < k_max, so slice the dense workspaces to exactly
        // k_max once per document instead of checking against the
        // (possibly larger, never-shrunk) Vec lengths per token. The
        // partials buffers are written by index up to `used` ≤ k_max and
        // never re-zeroed — the stale tail is dead by construction.
        let ZScratch { mdense, entries, in_list, partial_ks, partial_cums, dense_w } =
            scratch;
        let mdense = &mut mdense[..self.k_max];
        let in_list = &mut in_list[..self.k_max];
        let partial_ks = &mut partial_ks[..self.k_max];
        let partial_cums = &mut partial_cums[..self.k_max];
        // Load the per-doc scratch from md (touch only its entries).
        // `live` tracks the current nnz of m_d for the min-sparsity
        // branch; `entries` may keep stale zero-count topics (skipped
        // during iteration, compacted at doc end).
        let mut live = md.nnz();
        for (k, c) in md.iter() {
            mdense[k as usize] = c;
            in_list[k as usize] = true;
            entries.push(k);
        }
        for (&v, z) in doc.iter().zip(zd.iter_mut()) {
            let kold = *z;
            // Remove the token (the −i in m^{-i}) — O(1).
            let cold = &mut mdense[kold as usize];
            *cold -= 1;
            if *cold == 0 {
                live -= 1;
            }
            // Bucket (b): iterate the sparser side.
            let (col_topics, col_probs) = self.phi.col(v);
            let mut used = 0usize;
            let mut s_b = 0.0f64;
            if live <= col_topics.len() {
                out.sparse_work += live as u64;
                for &k in entries.iter() {
                    let c = mdense[k as usize];
                    if c == 0 {
                        continue; // stale entry
                    }
                    // manual binary search over the hoisted column
                    if let Ok(idx) = col_topics.binary_search(&k) {
                        s_b += col_probs[idx] * c as f64;
                        partial_ks[used] = k;
                        partial_cums[used] = s_b;
                        used += 1;
                    }
                }
            } else {
                out.sparse_work += col_topics.len() as u64;
                if accel {
                    // Gathered dense branch: w[i] = φ_{k_i,v}·m_{d,k_i}
                    // with the scalar's exact per-element multiply, then
                    // a serial cumulative compaction. `w > 0.0` keeps a
                    // superset-equivalent partials list vs the scalar
                    // `c > 0` test: a zero-weight partial adds +0.0 to
                    // `s_b` (bit-identical cumsum) and can never be the
                    // first cum > u, so dropping it never changes the
                    // drawn topic.
                    (self.kernels.gather_mul_u32)(
                        col_topics, col_probs, mdense, dense_w,
                    );
                    out.kern_gather_elems += col_topics.len() as u64;
                    for (i, &w) in dense_w[..col_topics.len()].iter().enumerate() {
                        if w > 0.0 {
                            s_b += w;
                            partial_ks[used] = col_topics[i];
                            partial_cums[used] = s_b;
                            used += 1;
                        }
                    }
                } else {
                    for (&k, &p) in col_topics.iter().zip(col_probs) {
                        let c = mdense[k as usize];
                        if c > 0 {
                            s_b += p * c as f64;
                            partial_ks[used] = k;
                            partial_cums[used] = s_b;
                            used += 1;
                        }
                    }
                }
            }
            let q_a = self.tables.mass(v);
            let total = q_a + s_b;
            let knew = if total <= 0.0 {
                // Word v currently absent from every topic's integer Φ:
                // conditional is degenerate; keep the old assignment
                // (it re-enters n, so Φ regains the word next sweep).
                out.zero_mass_tokens += 1;
                kold
            } else {
                let u = rng.f64() * total;
                if u < s_b {
                    let pick = if accel {
                        // SIMD scan for the first cumulative > u; `u <
                        // s_b = partial_cums[used-1]` guarantees a hit,
                        // the `min` only guards the float-edge where it
                        // would not.
                        out.kern_scan_tokens += 1;
                        (self.kernels.find_first_gt)(&partial_cums[..used], u)
                            .min(used - 1)
                    } else {
                        // walk the partials (short vector, linear is
                        // fastest)
                        let mut pick = used - 1;
                        for (idx, &cum) in partial_cums[..used].iter().enumerate()
                        {
                            if u < cum {
                                pick = idx;
                                break;
                            }
                        }
                        pick
                    };
                    partial_ks[pick]
                } else {
                    // `u ≥ s_b` can hold with `q_a = 0` on a float
                    // edge (`rng.f64()·s_b` rounding up to `s_b`), in
                    // which case the word has no bucket-(a) table —
                    // fall back to the last bucket-(b) partial (the
                    // draw the un-rounded `u` would have produced;
                    // `total > 0 ∧ q_a = 0 ⇒ used ≥ 1`).
                    self.tables
                        .try_sample(v, &mut rng)
                        .unwrap_or_else(|| partial_ks[used - 1])
                }
            };
            *z = knew;
            // Add the token — O(1) amortized.
            let cnew = &mut mdense[knew as usize];
            if *cnew == 0 {
                live += 1;
                if !in_list[knew as usize] {
                    in_list[knew as usize] = true;
                    entries.push(knew);
                }
            }
            *cnew += 1;
            out.n_acc.add(knew, v, 1);
            if knew as usize == self.k_max - 1 {
                out.flag_tokens += 1;
            }
        }
        // Compact the scratch back into md and reset it.
        md.clear();
        for &k in entries.iter() {
            let c = mdense[k as usize];
            if c > 0 {
                md.set(k, c);
            }
            mdense[k as usize] = 0;
            in_list[k as usize] = false;
        }
        entries.clear();
        out.hist.record_doc(md.entries());
    }

    /// Pólya-urn MH resample of one document (see the module docs):
    /// two cycled-proposal MH sub-steps per token against the exact
    /// conditional `π(k) ∝ φ_{k,v}·(α·Ψ_k + m^{-i}_{d,k})` — a doc
    /// proposal drawn from the document's own `z` vector (the urn)
    /// or the dense `Ψ` alias, then a word proposal from the
    /// bucket-(a) table. O(1) draws + ≤ 2 binary `φ` lookups per
    /// token instead of the exact partial-sum walk.
    #[allow(clippy::too_many_arguments)]
    fn resample_doc_ppu(
        &self,
        doc_id: usize,
        doc: &[u32],
        zd: &mut [u32],
        md: &mut DocTopics,
        scratch: &mut ZScratch,
        out: &mut ZShardResult,
        psi_alias: &crate::alias::AliasTable,
    ) {
        let mut rng = self
            .seed_root
            .stream(self.iteration.rotate_left(32) ^ 0x2000_0000)
            .stream(doc_id as u64);
        let ZScratch { mdense, entries, in_list, .. } = scratch;
        let mdense = &mut mdense[..self.k_max];
        let in_list = &mut in_list[..self.k_max];
        for (k, c) in md.iter() {
            mdense[k as usize] = c;
            in_list[k as usize] = true;
            entries.push(k);
        }
        let len_d = doc.len() as f64;
        // Global side of the doc proposal: mass α·|Ψ| (the alias holds
        // the raw Ψ weights, which need not sum to exactly 1).
        let psi_mass = self.alpha * psi_alias.total();
        let alpha = self.alpha;
        for i in 0..doc.len() {
            let v = doc[i];
            let kold = zd[i] as usize;
            // Remove the token (the −i in m^{-i}) — O(1).
            mdense[kold] -= 1;
            let q_a = self.tables.mass(v);
            let knew = if q_a <= 0.0 {
                // Word v absent from every topic's integer Φ: π ≡ 0,
                // the conditional is degenerate — keep the old
                // assignment (same contract as the exact kernel).
                out.zero_mass_tokens += 1;
                kold
            } else {
                out.ppu_tokens += 1;
                let (col_topics, col_probs) = self.phi.col(v);
                let phi_at = |k: u32| match col_topics.binary_search(&k) {
                    Ok(ix) => col_probs[ix],
                    Err(_) => 0.0,
                };
                let mut cur = kold;
                let mut phi_cur = phi_at(kold as u32);
                out.sparse_work += 1;
                // MH sub-step 1 — doc proposal q_d(k) ∝ m_k + α·Ψ_k.
                // The urn: `mdense` excludes the current token but
                // `zd[i]` still holds `kold`, so a uniformly random
                // zd entry is distributed exactly ∝ mdense + e_kold.
                let u = rng.f64() * (len_d + psi_mass);
                let kprop = if u < len_d {
                    zd[u as usize] as usize
                } else {
                    psi_alias.sample(&mut rng)
                };
                if kprop != cur {
                    let phi_prop = phi_at(kprop as u32);
                    out.sparse_work += 1;
                    let pi_prop = phi_prop
                        * (alpha * self.psi[kprop] + mdense[kprop] as f64);
                    let pi_cur = phi_cur * (alpha * self.psi[cur] + mdense[cur] as f64);
                    // Proposal masses match the urn (current token
                    // included): +1 on the old topic.
                    let q_cur = mdense[cur] as f64
                        + (cur == kold) as u64 as f64
                        + alpha * self.psi[cur];
                    let q_prop = mdense[kprop] as f64
                        + (kprop == kold) as u64 as f64
                        + alpha * self.psi[kprop];
                    // A proposed topic always has q_prop > 0, so the
                    // cross-multiplied test is exact; π(cur) = 0 means
                    // the chain cannot stay put — accept any π > 0.
                    let accept = if pi_cur <= 0.0 {
                        pi_prop > 0.0
                    } else {
                        rng.f64() * (pi_cur * q_prop) < pi_prop * q_cur
                    };
                    if accept {
                        cur = kprop;
                        phi_cur = phi_prop;
                        out.ppu_doc_accepts += 1;
                    }
                }
                // MH sub-step 2 — word proposal q_w(k) ∝ φ_{k,v}·α·Ψ_k
                // (the bucket-(a) alias; q_a > 0 ⇒ the table exists,
                // the defensive fallback keeps `cur`). The φ factors
                // cancel in the ratio; a drawn topic always has
                // φ·Ψ > 0, so π(cur) = 0 accepts unconditionally.
                if let Some(kw) = self.tables.try_sample(v, &mut rng) {
                    let kw = kw as usize;
                    if kw != cur {
                        let pi_cur = phi_cur
                            * (alpha * self.psi[cur] + mdense[cur] as f64);
                        let accept = if pi_cur <= 0.0 {
                            true
                        } else {
                            let num = (alpha * self.psi[kw]
                                + mdense[kw] as f64)
                                * (alpha * self.psi[cur]);
                            let den = (alpha * self.psi[cur]
                                + mdense[cur] as f64)
                                * (alpha * self.psi[kw]);
                            rng.f64() * den < num
                        };
                        if accept {
                            cur = kw;
                            out.ppu_word_accepts += 1;
                        }
                    }
                }
                cur
            };
            zd[i] = knew as u32;
            // Add the token back — O(1) amortized.
            let cnew = &mut mdense[knew];
            if *cnew == 0 && !in_list[knew] {
                in_list[knew] = true;
                entries.push(knew as u32);
            }
            *cnew += 1;
            out.n_acc.add(knew as u32, v, 1);
            if knew == self.k_max - 1 {
                out.flag_tokens += 1;
            }
        }
        // Compact the scratch back into md and reset it.
        md.clear();
        for &k in entries.iter() {
            let c = mdense[k as usize];
            if c > 0 {
                md.set(k, c);
            }
            mdense[k as usize] = 0;
            in_list[k as usize] = false;
        }
        entries.clear();
        out.hist.record_doc(md.entries());
    }

    /// Run the sweep over all documents with the given shard plan,
    /// mutating `z`/`m` in place and returning the per-shard results.
    /// `docs` is any [`DocAccess`] source — the nested `Vec<Vec<u32>>`
    /// document list or a [`PackedCorpus`] arena.
    ///
    /// One-shot form: allocates fresh per-shard scratch and runs on
    /// scoped threads (one per shard). The samplers use
    /// [`ZSweep::run_with_scratch`] with a persistent pool instead.
    pub fn run<D: DocAccess + ?Sized>(
        &self,
        docs: &D,
        z: &mut [Vec<u32>],
        m: &mut [DocTopics],
        plan: &Sharding,
    ) -> Vec<ZShardResult> {
        if plan.is_empty() {
            return Vec::new();
        }
        let mut scratch: Vec<ShardScratch> =
            (0..plan.len()).map(|_| ShardScratch::new(self.k_max)).collect();
        // With the scoped executor, slot == shard index, so each
        // ShardScratch.out is exactly one shard's result.
        self.run_with_scratch(docs, z, m, plan, plan.len(), &mut scratch);
        scratch.into_iter().map(|s| s.out).collect()
    }

    /// Run the sweep on `exec`, accumulating outputs into the per-slot
    /// `scratch` (reset here, reused across calls — no per-sweep
    /// allocation). The chain is bit-identical to [`ZSweep::run`] for
    /// the same plan because every document owns its RNG stream; only
    /// the grouping of outputs across `scratch` slots differs, and the
    /// shard merges are order-independent.
    pub fn run_with_scratch<D: DocAccess + ?Sized>(
        &self,
        docs: &D,
        z: &mut [Vec<u32>],
        m: &mut [DocTopics],
        plan: &Sharding,
        exec: impl par::Executor,
        scratch: &mut [ShardScratch],
    ) {
        self.run_with_scratch_sched(docs, z, m, plan, exec, scratch, par::Schedule::Steal)
    }

    /// [`ZSweep::run_with_scratch`] with an explicit [`par::Schedule`].
    /// Under [`par::Schedule::SlotAffine`] shard `i` is handed to pool
    /// slot `i % slots` every sweep, so a slot re-touches the same
    /// `z`/`m` shard each iteration (cache/NUMA affinity); the chain is
    /// bit-identical under either schedule because per-document RNG
    /// streams make placement irrelevant.
    #[allow(clippy::too_many_arguments)]
    pub fn run_with_scratch_sched<D: DocAccess + ?Sized>(
        &self,
        docs: &D,
        z: &mut [Vec<u32>],
        m: &mut [DocTopics],
        plan: &Sharding,
        exec: impl par::Executor,
        scratch: &mut [ShardScratch],
        schedule: par::Schedule,
    ) {
        if plan.is_empty() {
            return;
        }
        for s in scratch.iter_mut() {
            s.out.reset(self.k_max);
            s.scratch.ensure(self.k_max);
        }
        // Split z and m into per-shard mutable slices.
        let mut z_parts: Vec<&mut [Vec<u32>]> = Vec::with_capacity(plan.len());
        let mut m_parts: Vec<&mut [DocTopics]> = Vec::with_capacity(plan.len());
        {
            let mut z_rest = z;
            let mut m_rest = m;
            let mut offset = 0usize;
            for shard in plan.shards() {
                let (zl, zr) = z_rest.split_at_mut(shard.end - offset);
                let (ml, mr) = m_rest.split_at_mut(shard.end - offset);
                z_parts.push(zl);
                m_parts.push(ml);
                z_rest = zr;
                m_rest = mr;
                offset = shard.end;
            }
        }
        // Interior mutability across shards: each task owns its part.
        let work: Vec<(usize, &mut [Vec<u32>], &mut [DocTopics])> = plan
            .shards()
            .iter()
            .zip(z_parts.into_iter().zip(m_parts))
            .map(|(s, (zp, mp))| (s.start, zp, mp))
            .collect();
        let work = std::sync::Mutex::new(
            work.into_iter().map(Some).collect::<Vec<_>>(),
        );
        par::exec_shards_with_sched(exec, plan, scratch, schedule, |slot, shard_idx, shard| {
            let (start, zp, mp) = {
                let mut guard = work.lock().unwrap();
                guard[shard_idx].take().expect("shard taken once")
            };
            debug_assert_eq!(start, shard.start);
            let ShardScratch { out, scratch: zs, .. } = slot;
            for (off, (zd, md)) in zp.iter_mut().zip(mp.iter_mut()).enumerate() {
                let d = shard.start + off;
                self.resample_doc(d, docs.doc(d), zd, md, zs, out);
            }
        });
    }

    /// Run the sweep **streamed**: documents arrive as contiguous
    /// blocks — `blocks` must cover `0..D` contiguously, normally a
    /// [`Sharding::refine`] refinement of the document shard plan — and
    /// each executor slot materializes only its *current* block's `z`
    /// (and, for out-of-core token sources, tokens) in its
    /// [`ShardScratch`] block buffers. Hot per-token state is therefore
    /// bounded by `slots × max_block_tokens`, never by the corpus.
    ///
    /// `tokens` is a [`TokenBlocks`] source ([`PackedCorpus`] serves
    /// arena slices zero-copy; [`PackedCorpusFile`] reads blocks from
    /// disk) and `z` a [`ZStore`] ([`NestedZ`] over the samplers'
    /// resident assignments, [`ArenaZ`] over a packed arena, [`FileZ`]
    /// fully out of core). The per-document sparse statistic `m` stays
    /// resident: it is `O(K_d)` per document — offsets-scale, not
    /// token-scale.
    ///
    /// The chain is **bit-identical** to the resident
    /// [`ZSweep::run_with_scratch_sched`] for any block size, thread
    /// count, schedule, or store: every document owns its RNG stream
    /// keyed by `(iteration, doc id)`, and block boundaries only decide
    /// *where* a document's resample runs.
    #[allow(clippy::too_many_arguments)]
    pub fn run_streamed<T, S>(
        &self,
        tokens: &T,
        z: &S,
        m: &mut [DocTopics],
        blocks: &Sharding,
        exec: impl par::Executor,
        scratch: &mut [ShardScratch],
        schedule: par::Schedule,
    ) where
        T: TokenBlocks + ?Sized,
        S: ZStore + ?Sized,
    {
        if blocks.is_empty() {
            return;
        }
        let offsets = tokens.doc_offsets();
        assert_stream_invariants(offsets, m.len(), blocks);
        for s in scratch.iter_mut() {
            s.out.reset(self.k_max);
            s.scratch.ensure(self.k_max);
        }
        // Disjoint per-block doc ranges: each task owns its documents'
        // `m` entries.
        let mbase = SendPtr(m.as_mut_ptr());
        par::exec_shards_with_sched(exec, blocks, scratch, schedule, |slot, _bi, block| {
            let ShardScratch { out, scratch: zs, z_buf, tok_buf, .. } = slot;
            let ntok = (offsets[block.end] - offsets[block.start]) as usize;
            z.load(block, ntok, z_buf);
            // Real (release-mode) asserts: a short block would silently
            // corrupt the `pos`-based slicing below, and the check is
            // O(1) per block — noise next to the sweep.
            assert_eq!(z_buf.len(), ntok, "z store returned a short block");
            tokens.with_block(block, tok_buf, &mut |toks| {
                assert_eq!(toks.len(), ntok, "token source returned a short block");
                let mut pos = 0usize;
                for d in block.start..block.end {
                    let len = (offsets[d + 1] - offsets[d]) as usize;
                    // SAFETY: blocks cover disjoint document ranges, so
                    // `m[d]` is touched by exactly one task.
                    let md = unsafe { &mut *mbase.0.add(d) };
                    self.resample_doc(
                        d,
                        &toks[pos..pos + len],
                        &mut z_buf[pos..pos + len],
                        md,
                        zs,
                        out,
                    );
                    pos += len;
                }
            });
            z.store(block, z_buf);
        });
    }

    /// [`ZSweep::run_streamed`] with a **double-buffered block
    /// prefetcher**: while a slot sweeps block *t* of its stripe, the
    /// token + z loads of block *t + slots* run as a front-queued
    /// async pool job ([`WorkerPool::submit_unowned`]) filling the
    /// slot's back buffer pair, so by the time the slot gets there the
    /// data is (usually) already resident — disk latency overlaps
    /// other slots' compute instead of extending the critical path.
    ///
    /// Blocks are placed on the deterministic [`Schedule::SlotAffine`]
    /// stripe map (block `i` → slot `i mod slots`), which is what
    /// makes "this slot's next block" well defined; the chain is
    /// **bit-identical** to every other sweep form regardless of
    /// placement (per-document RNG streams). Per-sweep accounting
    /// lands in [`ZShardResult::prefetch_hits`] /
    /// [`ZShardResult::prefetch_stalls`].
    pub fn run_streamed_prefetched<T, S>(
        &self,
        tokens: &T,
        z: &S,
        m: &mut [DocTopics],
        blocks: &Sharding,
        pool: &Arc<WorkerPool>,
        scratch: &mut [ShardScratch],
    ) where
        T: TokenBlocks + ?Sized,
        S: ZStore + ?Sized,
    {
        if blocks.is_empty() {
            return;
        }
        let offsets = tokens.doc_offsets();
        assert_stream_invariants(offsets, m.len(), blocks);
        for s in scratch.iter_mut() {
            s.out.reset(self.k_max);
            s.scratch.ensure(self.k_max);
        }
        let nslots = pool.slots();
        assert!(
            scratch.len() >= nslots,
            "scratch slots {} must cover the pool's {nslots} slots",
            scratch.len()
        );
        let shards = blocks.shards();
        let nblocks = shards.len();
        let resident_tokens = tokens.resident();
        let mbase = SendPtr(m.as_mut_ptr());
        let sbase = SendPtr(scratch.as_mut_ptr());
        // One in-flight prefetch per slot: the async load job plus the
        // closure it runs, kept alive here (outliving every task) until
        // the join — the pool borrows the closure unowned.
        let mut pending: Vec<Option<PendingLoad<'_>>> = (0..nslots).map(|_| None).collect();
        let pbase = SendPtr(pending.as_mut_ptr());
        let task = |slot: usize, bi: usize| {
            let block = shards[bi];
            // SAFETY: the Executor slot contract — no two concurrent
            // tasks share `slot` — makes this slot's prefetch cell
            // exclusively ours for the task's duration.
            let pend = unsafe { &mut *pbase.0.add(slot) };
            let ntok = (offsets[block.end] - offsets[block.start]) as usize;
            // 1. Join the load submitted while the stripe's previous
            // block swept — BEFORE touching the slot scratch: until
            // the join, that job is still writing the back buffer pair
            // through its own pointers, and creating a whole-struct
            // `&mut ShardScratch` while a foreign write is in flight
            // would violate the aliasing rules even though the fields
            // are disjoint.
            let prefetched = pend.take();
            let was_hit = prefetched.as_ref().map(|(h, _)| h.is_done());
            let mut load_ok = true;
            if let Some((mut h, _load)) = prefetched {
                // Quiet join: we own `slot` (the plain `wait` would
                // take the dispatch gate the enclosing blocking sweep
                // dispatch holds), and a dead load must not sink the
                // sweep — we fall back to an inline reload instead.
                load_ok = h.wait_as_quiet(slot);
            }
            // SAFETY: slot contract as above; the only other writer
            // (the prefetch load) has been joined, so this slot's
            // scratch is quiescent and exclusively ours.
            let slot_scratch = unsafe { &mut *sbase.0.add(slot) };
            // 2. Materialize block `bi`: the prefetched data sits in
            // the back pair (swap it to the front), or load inline on
            // the stripe's cold first block — or on a failed prefetch,
            // whose back pair is discarded unswapped (possibly torn).
            match was_hit {
                Some(hit) if load_ok => {
                    if hit {
                        slot_scratch.out.prefetch_hits += 1;
                    } else {
                        slot_scratch.out.prefetch_stalls += 1;
                    }
                    std::mem::swap(&mut slot_scratch.z_buf, &mut slot_scratch.z_buf2);
                    std::mem::swap(&mut slot_scratch.tok_buf, &mut slot_scratch.tok_buf2);
                }
                degraded => {
                    if degraded.is_some() {
                        slot_scratch.out.prefetch_failures += 1;
                    }
                    slot_scratch.out.prefetch_stalls += 1;
                    z.load(block, ntok, &mut slot_scratch.z_buf);
                    if !resident_tokens {
                        tokens.read_block_into(block, &mut slot_scratch.tok_buf);
                    }
                }
            }
            // 3. Submit the load of this stripe's next block into the
            // (now free) back pair before sweeping — the overlap
            // window. Front-queued: whichever participant finishes a
            // block first performs it between bulk tasks.
            let nb = bi + nslots;
            if nb < nblocks {
                let nblock = shards[nb];
                let nntok = (offsets[nblock.end] - offsets[nblock.start]) as usize;
                let zdst = SendPtr(std::ptr::addr_of_mut!(slot_scratch.z_buf2));
                let tdst = SendPtr(std::ptr::addr_of_mut!(slot_scratch.tok_buf2));
                let load: Box<dyn Fn(usize, usize) + Send + Sync + '_> =
                    Box::new(move |_s, _t| {
                        // Injectable crash site: with the `failpoints`
                        // feature an armed "prefetch.load" fault
                        // retries, then panics — the quiet join above
                        // turns that into an inline-reload degrade.
                        crate::fault::check_or_die("prefetch.load");
                        // SAFETY: this slot's back pair is untouched by
                        // the sweep until the next stripe task joins
                        // this job (or the drain below does).
                        let zb = unsafe { &mut *zdst.0 };
                        z.load(nblock, nntok, zb);
                        if !resident_tokens {
                            let tb = unsafe { &mut *tdst.0 };
                            tokens.read_block_into(nblock, tb);
                        }
                    });
                // SAFETY: the closure lives in `pending[slot]` (whose
                // heap address is stable across the move below) until
                // the job is joined — by the next stripe task's
                // `wait_as` or by the post-dispatch drain.
                let h = unsafe {
                    WorkerPool::submit_unowned(pool, 1, Schedule::Steal, true, &*load)
                };
                *pend = Some((h, load));
            }
            // 4. Sweep the front pair, then write the block back
            // (positioned, lock-free on unix).
            let ShardScratch { out, scratch: zs, z_buf, tok_buf, .. } = slot_scratch;
            assert_eq!(z_buf.len(), ntok, "z store returned a short block");
            let mut sweep_block = |toks: &[u32]| {
                assert_eq!(toks.len(), ntok, "token source returned a short block");
                let mut pos = 0usize;
                for d in block.start..block.end {
                    let len = (offsets[d + 1] - offsets[d]) as usize;
                    // SAFETY: blocks cover disjoint document ranges, so
                    // `m[d]` is touched by exactly one task.
                    let md = unsafe { &mut *mbase.0.add(d) };
                    self.resample_doc(
                        d,
                        &toks[pos..pos + len],
                        &mut z_buf[pos..pos + len],
                        md,
                        zs,
                        out,
                    );
                    pos += len;
                }
            };
            if resident_tokens {
                tokens.with_block(block, tok_buf, &mut sweep_block);
            } else {
                sweep_block(tok_buf);
            }
            z.store(block, z_buf);
        };
        let exec: &WorkerPool = pool;
        exec.run_tasks_scheduled(nblocks, Schedule::SlotAffine, &task);
        // On a panic-free run every handle was consumed by its stripe
        // successor; drain any leftovers (we are outside the dispatch
        // now, so the gate-taking join is safe). Quietly: a dead load
        // here prefetched data no task will ever read, and the sweep
        // itself completed — nothing to re-raise.
        for p in pending.iter_mut() {
            if let Some((mut h, _load)) = p.take() {
                h.wait_quiet();
            }
        }
    }
}

/// An in-flight prefetch: the async load job plus the closure it runs,
/// kept alive by the sweep until the join (the pool borrows it
/// unowned).
type PendingLoad<'a> = (JobHandle, Box<dyn Fn(usize, usize) + Send + Sync + 'a>);

/// Release-mode invariants shared by the streamed sweep forms: the
/// per-block raw-pointer writes are sound only under these, and the
/// checks are O(D + blocks) once per sweep — noise next to the sweep.
fn assert_stream_invariants(offsets: &[u64], m_len: usize, blocks: &Sharding) {
    assert_eq!(offsets.len(), m_len + 1, "offsets must cover m");
    assert!(
        {
            let mut next = 0usize;
            blocks.shards().iter().all(|b| {
                let ok = b.start == next;
                next = b.end;
                ok
            }) && next + 1 == offsets.len()
        },
        "blocks must cover 0..D contiguously"
    );
}

/// Clear `buf` and make room for `n` values, counting real growth via
/// the substrate scratch-alloc counter. `reserve_exact` keeps the
/// steady-state capacity at the largest block seen instead of the
/// doubling growth a plain `reserve` would leave behind.
fn ensure_u32_buf(buf: &mut Vec<u32>, n: usize) {
    buf.clear();
    if buf.capacity() < n {
        crate::par::stats::note_scratch_alloc();
        buf.reserve_exact(n);
    }
}

/// Read-only source of packed token blocks for the streamed z sweep.
///
/// Implementors keep `doc_offsets` resident (8 bytes/document) and
/// serve the tokens of a contiguous document block either in place
/// (memory-resident arenas) or through the caller's per-slot buffer
/// (out-of-core files).
pub trait TokenBlocks: Sync {
    /// Document offsets into the token arena (length `D + 1`).
    fn doc_offsets(&self) -> &[u64];

    /// Call `f` with the packed tokens of documents
    /// `[docs.start, docs.end)`. `buf` is the calling slot's reusable
    /// scratch; resident sources ignore it and pass an arena slice.
    fn with_block(&self, docs: Shard, buf: &mut Vec<u32>, f: &mut dyn FnMut(&[u32]));

    /// True when blocks are served zero-copy from resident memory.
    /// The streamed prefetcher skips token I/O for resident sources;
    /// out-of-core sources return false and must implement
    /// [`TokenBlocks::read_block_into`].
    fn resident(&self) -> bool {
        true
    }

    /// Materialize the block's tokens into `buf` (cleared first) — the
    /// prefetch path, which needs owned data it can load ahead of time
    /// on another thread. Only called when [`TokenBlocks::resident`]
    /// is false.
    fn read_block_into(&self, _docs: Shard, _buf: &mut Vec<u32>) {
        unreachable!("read_block_into is only called on non-resident token sources")
    }
}

impl TokenBlocks for PackedCorpus {
    fn doc_offsets(&self) -> &[u64] {
        PackedCorpus::doc_offsets(self)
    }

    fn with_block(&self, docs: Shard, _buf: &mut Vec<u32>, f: &mut dyn FnMut(&[u32])) {
        f(&self.tokens()[self.token_range(docs.start, docs.end)])
    }
}

impl TokenBlocks for PackedCorpusFile {
    fn doc_offsets(&self) -> &[u64] {
        PackedCorpusFile::doc_offsets(self)
    }

    fn with_block(&self, docs: Shard, buf: &mut Vec<u32>, f: &mut dyn FnMut(&[u32])) {
        // A memory-mapped file serves the block zero-copy straight
        // from the mapping (same bytes pread would return — the chain
        // is identical either way).
        if let Some(tokens) = self.mapped_tokens() {
            let t0 = self.doc_offsets()[docs.start] as usize;
            let t1 = self.doc_offsets()[docs.end] as usize;
            f(&tokens[t0..t1]);
            return;
        }
        self.read_block_into(docs, buf);
        f(buf)
    }

    fn resident(&self) -> bool {
        // Mapped files behave like resident arenas: the prefetcher
        // must not double-buffer what the page cache already serves
        // in place.
        self.mmap_active()
    }

    fn read_block_into(&self, docs: Shard, buf: &mut Vec<u32>) {
        let ntok =
            (self.doc_offsets()[docs.end] - self.doc_offsets()[docs.start]) as usize;
        ensure_u32_buf(buf, ntok);
        // I/O mid-sweep has no recovery path that preserves the chain;
        // fail loudly (the sweep is re-runnable from the last
        // checkpoint).
        self.read_block(docs.start, docs.end, buf).expect("corpus block read");
    }
}

/// Mutable store of packed z blocks for the streamed z sweep.
///
/// The sweep calls [`ZStore::load`] / [`ZStore::store`] once per block
/// with **disjoint** contiguous document ranges; implementations may
/// therefore hand out overlapping-free interior mutability without
/// locking — resident stores through raw pointers, the out-of-core
/// [`FileZ`] through positioned reads/writes on disjoint byte ranges.
pub trait ZStore: Sync {
    /// Copy the assignments of documents `[docs.start, docs.end)`
    /// (`ntokens` total, packed in document order) into `buf`.
    fn load(&self, docs: Shard, ntokens: usize, buf: &mut Vec<u32>);

    /// Write the mutated block back.
    fn store(&self, docs: Shard, buf: &[u32]);
}

/// [`ZStore`] view over the samplers' resident nested assignments:
/// streaming machinery, resident storage. This is what lets a sampler
/// flip between resident and streamed sweeps mid-chain with no data
/// migration (and what the equivalence tests pin).
pub struct NestedZ<'a> {
    base: SendPtr<Vec<u32>>,
    len: usize,
    _borrow: PhantomData<&'a mut [Vec<u32>]>,
}

impl<'a> NestedZ<'a> {
    /// Wrap the nested assignments for block streaming.
    pub fn new(z: &'a mut [Vec<u32>]) -> Self {
        Self { base: SendPtr(z.as_mut_ptr()), len: z.len(), _borrow: PhantomData }
    }
}

impl ZStore for NestedZ<'_> {
    fn load(&self, docs: Shard, ntokens: usize, buf: &mut Vec<u32>) {
        assert!(docs.end <= self.len, "z block {docs:?} out of range");
        ensure_u32_buf(buf, ntokens);
        for d in docs.start..docs.end {
            // SAFETY: the sweep hands out disjoint doc ranges.
            let zd = unsafe { &*self.base.0.add(d) };
            buf.extend_from_slice(zd);
        }
    }

    fn store(&self, docs: Shard, buf: &[u32]) {
        let mut pos = 0usize;
        for d in docs.start..docs.end {
            // SAFETY: as above — this range belongs to one task.
            let zd = unsafe { &mut *self.base.0.add(d) };
            zd.copy_from_slice(&buf[pos..pos + zd.len()]);
            pos += zd.len();
        }
    }
}

/// [`ZStore`] over a packed resident z arena aligned with the corpus
/// `doc_offsets` (z stored exactly like the token arena).
pub struct ArenaZ<'a> {
    base: SendPtr<u32>,
    offsets: &'a [u64],
    len: usize,
    _borrow: PhantomData<&'a mut [u32]>,
}

impl<'a> ArenaZ<'a> {
    /// Wrap a flat z arena; `offsets` is the corpus `doc_offsets`
    /// (length `D + 1`) and `z.len()` must equal the token count.
    pub fn new(z: &'a mut [u32], offsets: &'a [u64]) -> Self {
        assert_eq!(z.len() as u64, *offsets.last().expect("offsets non-empty"));
        Self { base: SendPtr(z.as_mut_ptr()), offsets, len: z.len(), _borrow: PhantomData }
    }

    /// Arena range of a doc block, bounds-checked against the wrapped
    /// slice (release-mode: the raw slices below rely on it). The
    /// caller's `ntokens` claim must equal the offsets span exactly —
    /// a wrong hint would read/write a misaligned arena range that the
    /// `start + ntokens` bound alone cannot catch.
    fn range(&self, docs: Shard, ntokens: usize) -> usize {
        let start = self.offsets[docs.start] as usize;
        let span = (self.offsets[docs.end] - self.offsets[docs.start]) as usize;
        assert_eq!(
            span, ntokens,
            "z block {docs:?}: caller claims {ntokens} tokens, offsets span {span}"
        );
        assert!(start + ntokens <= self.len, "z block {docs:?} out of range");
        start
    }
}

impl ZStore for ArenaZ<'_> {
    fn load(&self, docs: Shard, ntokens: usize, buf: &mut Vec<u32>) {
        ensure_u32_buf(buf, ntokens);
        let start = self.range(docs, ntokens);
        // SAFETY: disjoint doc ranges map to disjoint arena ranges
        // (offsets are monotone), bounds-checked in `range`.
        let src = unsafe { std::slice::from_raw_parts(self.base.0.add(start), ntokens) };
        buf.extend_from_slice(src);
    }

    fn store(&self, docs: Shard, buf: &[u32]) {
        let start = self.range(docs, buf.len());
        // SAFETY: as above.
        let dst =
            unsafe { std::slice::from_raw_parts_mut(self.base.0.add(start), buf.len()) };
        dst.copy_from_slice(buf);
    }
}

/// Fully out-of-core [`ZStore`]: the z arena lives in a file (raw
/// little-endian u32s at the corpus token offsets), blocks are read
/// and written with **positioned** I/O ([`PositionedFile`]) — on unix,
/// concurrent slots serving disjoint blocks never touch a lock or a
/// shared cursor. Combined with [`PackedCorpusFile`] this makes the
/// whole z phase's RAM footprint `O(D)` offsets + `O(slots × block)`
/// buffers.
///
/// Durability: [`FileZ::store`] only hands blocks to the OS page
/// cache; [`FileZ::sync`] (`fdatasync`) is the durability point,
/// called once at the checkpoint boundary instead of per block.
pub struct FileZ {
    file: PositionedFile,
    offsets: Vec<u64>,
}

impl FileZ {
    /// Create (truncating) at `path`, initialized from nested
    /// assignments; `offsets` are derived from the document lengths.
    pub fn from_nested(path: &std::path::Path, z: &[Vec<u32>]) -> anyhow::Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        let mut offsets = Vec::with_capacity(z.len() + 1);
        let mut off = 0u64;
        offsets.push(0);
        {
            let mut w = std::io::BufWriter::new(&file);
            for zd in z {
                off += zd.len() as u64;
                offsets.push(off);
                crate::corpus::io::write_u32s(&mut w, zd)?;
            }
            use std::io::Write;
            w.flush()?;
        }
        Ok(Self {
            file: PositionedFile::new(file, ("filez.pread", "filez.pwrite")),
            offsets,
        })
    }

    /// The document offsets (length `D + 1`).
    pub fn offsets(&self) -> &[u64] {
        &self.offsets
    }

    /// Flush every stored block to stable storage (`fdatasync`) — the
    /// checkpoint-boundary durability point. Block stores only reach
    /// the page cache; paying one sync per checkpoint instead of one
    /// per block keeps I/O off the sweep's critical path.
    pub fn sync(&self) -> anyhow::Result<()> {
        Ok(self.file.sync_data()?)
    }

    /// Read the whole store back as nested assignments (tests and
    /// checkpointing).
    pub fn to_nested(&self) -> anyhow::Result<Vec<Vec<u32>>> {
        let flat = self.to_flat()?;
        Ok(self
            .offsets
            .windows(2)
            .map(|w| flat[w[0] as usize..w[1] as usize].to_vec())
            .collect())
    }

    /// Read the whole store back as one flat arena in document order —
    /// the packed-only checkpoint/diagnostics read, pairs with
    /// [`FileZ::offsets`].
    pub fn to_flat(&self) -> anyhow::Result<Vec<u32>> {
        let mut flat = Vec::new();
        self.file
            .read_u32s_at(0, *self.offsets.last().unwrap() as usize, &mut flat)?;
        Ok(flat)
    }

    /// Create (truncating) at `path` from a flat arena + CSR offsets —
    /// the packed-only spill path: no nested `Vec<Vec<u32>>` is ever
    /// built.
    pub fn from_flat(
        path: &std::path::Path,
        z: &[u32],
        offsets: &[u64],
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            !offsets.is_empty() && offsets[0] == 0,
            "z offsets must start at 0"
        );
        anyhow::ensure!(
            *offsets.last().unwrap() as usize == z.len(),
            "z offsets end {} != arena len {}",
            offsets.last().unwrap(),
            z.len()
        );
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        {
            let mut w = std::io::BufWriter::new(&file);
            crate::corpus::io::write_u32s(&mut w, z)?;
            use std::io::Write;
            w.flush()?;
        }
        Ok(Self {
            file: PositionedFile::new(file, ("filez.pread", "filez.pwrite")),
            offsets: offsets.to_vec(),
        })
    }
}

impl ZStore for FileZ {
    fn load(&self, docs: Shard, ntokens: usize, buf: &mut Vec<u32>) {
        ensure_u32_buf(buf, ntokens);
        self.file
            .read_u32s_at(self.offsets[docs.start] * 4, ntokens, buf)
            .expect("z block read");
    }

    fn store(&self, docs: Shard, buf: &[u32]) {
        // Positioned write straight to the page cache — no lock, no
        // per-block flush (durability is FileZ::sync's job at the
        // checkpoint boundary).
        self.file
            .write_u32s_at(self.offsets[docs.start] * 4, buf)
            .expect("z block write");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::TopicWordRows;

    /// Dense reference: enumerate P(z=k) ∝ φ_{k,v}(αΨ_k + m_k) exactly.
    fn dense_conditional(
        phi: &PhiMatrix,
        psi: &[f64],
        alpha: f64,
        v: u32,
        mdense: &[u32],
    ) -> Vec<f64> {
        let k_max = psi.len();
        let mut w = vec![0.0f64; k_max];
        for k in 0..k_max {
            let p = phi.get(k as u32, v);
            w[k] = p * (alpha * psi[k] + mdense[k] as f64);
        }
        let s: f64 = w.iter().sum();
        if s > 0.0 {
            w.iter_mut().for_each(|x| *x /= s);
        }
        w
    }

    fn small_phi() -> PhiMatrix {
        // K=4, V=3
        PhiMatrix::from_count_rows(
            3,
            &[
                vec![(0, 5), (1, 5)],
                vec![(1, 2), (2, 8)],
                vec![(0, 1)],
                vec![], // dead topic
            ],
        )
    }

    #[test]
    fn word_tables_mass_matches_sum() {
        let phi = small_phi();
        let psi = [0.4, 0.3, 0.2, 0.1];
        let alpha = 0.7;
        let t = WordTables::build(&phi, &psi, alpha, 2usize);
        for v in 0..3u32 {
            let want: f64 = (0..4)
                .map(|k| phi.get(k as u32, v) * alpha * psi[k])
                .sum();
            assert!((t.mass(v) - want).abs() < 1e-12, "v={v}");
        }
    }

    #[test]
    fn word_tables_draw_distribution() {
        let phi = small_phi();
        let psi = [0.4, 0.3, 0.2, 0.1];
        let alpha = 1.0;
        let t = WordTables::build(&phi, &psi, alpha, 1usize);
        let mut rng = Pcg64::new(1);
        let mut counts = [0usize; 4];
        let reps = 200_000;
        for _ in 0..reps {
            counts[t.sample(1, &mut rng) as usize] += 1;
        }
        // weights at v=1: k0: .5*.4, k1: .2*.3 -> normalized
        let w0 = 0.5 * 0.4;
        let w1 = 0.2 * 0.3;
        let p0 = w0 / (w0 + w1);
        let got = counts[0] as f64 / reps as f64;
        assert!((got - p0).abs() < 0.01, "{got} vs {p0}");
        assert_eq!(counts[2], 0, "φ_{{2,1}} = 0");
        assert_eq!(counts[3], 0);
    }

    #[test]
    fn sweep_token_distribution_matches_dense_enumeration() {
        // Freeze Φ, Ψ, and one document with a single token; resampling
        // that token repeatedly must match the dense conditional.
        let phi = small_phi();
        let psi = [0.4, 0.3, 0.2, 0.1];
        let alpha = 0.9;
        let tables = WordTables::build(&phi, &psi, alpha, 1usize);
        // document: tokens [1, 1, 0], assignments start at [0, 1, 0]
        let doc = vec![1u32, 1, 0];
        let docs = vec![doc.clone()];
        let mut counts = vec![[0usize; 4]; 3];
        let reps = 60_000;
        for rep in 0..reps {
            let root = Pcg64::new(500 + rep as u64);
            let sweep = ZSweep {
                phi: &phi,
                psi: &psi,
                tables: &tables,
                alpha,
                k_max: 4,
                seed_root: &root,
                iteration: 3,
                kernels: Kernels::scalar(),
                ppu: None,
            };
            let mut z = vec![vec![0u32, 1, 0]];
            let mut m: Vec<DocTopics> =
                vec![z[0].iter().copied().collect()];
            let plan = Sharding::even(1, 1);
            sweep.run(&docs, &mut z, &mut m, &plan);
            for (i, &k) in z[0].iter().enumerate() {
                counts[i][k as usize] += 1;
            }
        }
        // Check the FIRST token's distribution analytically: at its
        // draw, m^{-i} = {0:1, 1:1} (the other two tokens unchanged).
        let mdense = [1u32, 1, 0, 0];
        let want = dense_conditional(&phi, &psi, alpha, 1, &mdense);
        for k in 0..4 {
            let got = counts[0][k] as f64 / reps as f64;
            assert!(
                (got - want[k]).abs() < 0.015,
                "token0 k={k}: {got} vs {}",
                want[k]
            );
        }
    }

    #[test]
    fn sweep_shard_invariant() {
        // Same corpus, same seed, different shard counts → identical z.
        use crate::corpus::synthetic::HdpCorpusSpec;
        let (corpus, _) = HdpCorpusSpec {
            vocab: 120,
            topics: 5,
            gamma: 2.0,
            alpha: 1.0,
            topic_beta: 0.1,
            docs: 40,
            mean_doc_len: 25.0,
            len_sigma: 0.3,
            min_doc_len: 5,
        }
        .generate(8);
        // Build some non-trivial state.
        let mut acc = TopicWordAcc::with_capacity(256);
        let mut rng = Pcg64::new(3);
        let mut z: Vec<Vec<u32>> = corpus
            .docs
            .iter()
            .map(|d| d.iter().map(|_| rng.below(6) as u32).collect())
            .collect();
        for (doc, zd) in corpus.docs.iter().zip(&z) {
            for (&v, &k) in doc.iter().zip(zd) {
                acc.add(k, v, 1);
            }
        }
        let n = TopicWordRows::merge_from(8, &mut [acc]);
        let root = Pcg64::new(77);
        let phi = super::super::phi::sample_phi(&root, &n, 0.05, 120, 1usize);
        let psi = [0.3, 0.2, 0.15, 0.1, 0.1, 0.05, 0.05, 0.05];
        let tables = WordTables::build(&phi, &psi, 0.5, 1usize);
        let sweep = ZSweep {
            phi: &phi,
            psi: &psi,
            tables: &tables,
            alpha: 0.5,
            k_max: 8,
            seed_root: &root,
            iteration: 1,
            kernels: Kernels::scalar(),
            ppu: None,
        };
        let mut m: Vec<DocTopics> =
            z.iter().map(|zd| zd.iter().copied().collect()).collect();
        let mut z1 = z.clone();
        let mut m1 = m.clone();
        sweep.run(&corpus.docs, &mut z1, &mut m1, &Sharding::even(40, 1));
        sweep.run(&corpus.docs, &mut z, &mut m, &Sharding::even(40, 7));
        assert_eq!(z, z1, "chains must not depend on shard layout");
    }

    #[test]
    fn pooled_sweep_matches_scoped_sweep() {
        // Same frozen state swept twice: scoped one-shot `run` vs
        // `run_with_scratch` on a persistent pool (with slot count ≠
        // shard count, twice in a row to exercise scratch reuse). The
        // chain (z, m) must be bit-identical and the merged statistics
        // equal.
        use crate::corpus::synthetic::HdpCorpusSpec;
        use crate::par::WorkerPool;
        let (corpus, _) = HdpCorpusSpec {
            vocab: 150,
            topics: 5,
            gamma: 2.0,
            alpha: 1.0,
            topic_beta: 0.1,
            docs: 50,
            mean_doc_len: 25.0,
            len_sigma: 0.3,
            min_doc_len: 5,
        }
        .generate(12);
        let mut acc = TopicWordAcc::with_capacity(256);
        let mut rng = Pcg64::new(4);
        let z0: Vec<Vec<u32>> = corpus
            .docs
            .iter()
            .map(|d| d.iter().map(|_| rng.below(6) as u32).collect())
            .collect();
        for (doc, zd) in corpus.docs.iter().zip(&z0) {
            for (&v, &k) in doc.iter().zip(zd) {
                acc.add(k, v, 1);
            }
        }
        let n = TopicWordRows::merge_from(8, &mut [acc]);
        let root = Pcg64::new(31);
        let phi = super::super::phi::sample_phi(&root, &n, 0.05, 150, 1usize);
        let psi = [0.3, 0.2, 0.15, 0.1, 0.1, 0.05, 0.05, 0.05];
        let tables = WordTables::build(&phi, &psi, 0.5, 1usize);
        let m0: Vec<DocTopics> =
            z0.iter().map(|zd| zd.iter().copied().collect()).collect();
        let plan = Sharding::even(50, 5);
        let pool = WorkerPool::new(3); // fewer slots than shards
        let mut scratch: Vec<ShardScratch> =
            (0..plan.len().max(pool.slots())).map(|_| ShardScratch::new(8)).collect();
        for iteration in 1..=2u64 {
            let sweep = ZSweep {
                phi: &phi,
                psi: &psi,
                tables: &tables,
                alpha: 0.5,
                k_max: 8,
                seed_root: &root,
                iteration,
                kernels: Kernels::scalar(),
                ppu: None,
            };
            let (mut z_scoped, mut m_scoped) = (z0.clone(), m0.clone());
            let results =
                sweep.run(&corpus.docs, &mut z_scoped, &mut m_scoped, &plan);
            let (mut z_pooled, mut m_pooled) = (z0.clone(), m0.clone());
            sweep.run_with_scratch(
                &corpus.docs,
                &mut z_pooled,
                &mut m_pooled,
                &plan,
                &pool,
                &mut scratch,
            );
            assert_eq!(z_pooled, z_scoped, "iteration {iteration}");
            for (md, ms) in m_pooled.iter().zip(&m_scoped) {
                assert_eq!(md.total(), ms.total());
            }
            // Merged statistics agree regardless of slot grouping.
            let mut accs: Vec<TopicWordAcc> =
                results.into_iter().map(|r| r.n_acc).collect();
            let n_scoped = TopicWordRows::merge_from(8, &mut accs);
            let n_pooled = TopicWordRows::merge_from_iter(
                8,
                scratch.iter_mut().map(|s| &mut s.out.n_acc),
            );
            for k in 0..8 {
                assert_eq!(n_pooled.row(k), n_scoped.row(k), "topic {k}");
            }
        }
    }

    #[test]
    fn build_into_reuses_buffers_and_matches_build() {
        use crate::par::WorkerPool;
        let phi = small_phi();
        let psi = [0.4, 0.3, 0.2, 0.1];
        let alpha = 0.7;
        let pool = WorkerPool::new(2);
        let fresh = WordTables::build(&phi, &psi, alpha, &pool);
        let mut reused = WordTables::empty();
        let mut scratch = WordTablesScratch::new();
        reused.build_into(&phi, &psi, alpha, &pool, &mut scratch);
        let tables_ptr = reused.tables.as_ptr();
        let masses_ptr = reused.masses.as_ptr();
        // Rebuild with different Ψ, then with the original again: the
        // recycled vectors must not be reallocated (the global alloc
        // counter can't be asserted here — tests run concurrently).
        let psi2 = [0.1, 0.2, 0.3, 0.4];
        reused.build_into(&phi, &psi2, alpha, &pool, &mut scratch);
        reused.build_into(&phi, &psi, alpha, &pool, &mut scratch);
        assert_eq!(reused.tables.as_ptr(), tables_ptr, "tables vec must be reused");
        assert_eq!(reused.masses.as_ptr(), masses_ptr, "masses vec must be reused");
        assert_eq!(scratch.weights.len(), pool.slots());
        for v in 0..3u32 {
            assert_eq!(reused.mass(v).to_bits(), fresh.mass(v).to_bits(), "v={v}");
        }
        // Draw-level agreement on a live column.
        let mut r1 = Pcg64::new(7);
        let mut r2 = Pcg64::new(7);
        for _ in 0..200 {
            assert_eq!(reused.sample(1, &mut r1), fresh.sample(1, &mut r2));
        }
    }

    /// Frozen sweep state shared by the streaming tests.
    struct Frozen {
        corpus: crate::corpus::Corpus,
        phi: PhiMatrix,
        psi: [f64; 8],
        z0: Vec<Vec<u32>>,
        m0: Vec<DocTopics>,
    }

    fn frozen_state(seed: u64) -> Frozen {
        use crate::corpus::synthetic::HdpCorpusSpec;
        let (corpus, _) = HdpCorpusSpec {
            vocab: 130,
            topics: 5,
            gamma: 2.0,
            alpha: 1.0,
            topic_beta: 0.1,
            docs: 47,
            mean_doc_len: 24.0,
            len_sigma: 0.3,
            min_doc_len: 5,
        }
        .generate(seed);
        let mut acc = TopicWordAcc::with_capacity(256);
        let mut rng = Pcg64::new(seed ^ 0xf00);
        let z0: Vec<Vec<u32>> = corpus
            .docs
            .iter()
            .map(|d| d.iter().map(|_| rng.below(6) as u32).collect())
            .collect();
        for (doc, zd) in corpus.docs.iter().zip(&z0) {
            for (&v, &k) in doc.iter().zip(zd) {
                acc.add(k, v, 1);
            }
        }
        let n = TopicWordRows::merge_from(8, &mut [acc]);
        let root = Pcg64::new(seed ^ 0xbeef);
        let phi = super::super::phi::sample_phi(&root, &n, 0.05, 130, 1usize);
        let m0: Vec<DocTopics> =
            z0.iter().map(|zd| zd.iter().copied().collect()).collect();
        Frozen { corpus, phi, psi: [0.3, 0.2, 0.15, 0.1, 0.1, 0.05, 0.05, 0.05], z0, m0 }
    }

    fn frozen_sweep<'a>(f: &'a Frozen, tables: &'a WordTables, root: &'a Pcg64) -> ZSweep<'a> {
        ZSweep {
            phi: &f.phi,
            psi: &f.psi,
            tables,
            alpha: 0.5,
            k_max: 8,
            seed_root: root,
            iteration: 1,
            kernels: Kernels::scalar(),
            ppu: None,
        }
    }

    #[test]
    fn streamed_sweep_matches_resident_for_every_store() {
        // One frozen state swept five ways — resident, streamed over
        // nested z, streamed over a packed z arena, and fully
        // out-of-core (packed corpus file + z file) — with 1-doc and
        // uneven blocks. All chains must be bit-identical and the
        // merged statistics equal.
        use crate::par::{Schedule, WorkerPool};
        let f = frozen_state(31);
        let root = Pcg64::new(77);
        let tables = WordTables::build(&f.phi, &f.psi, 0.5, 1usize);
        let sweep = frozen_sweep(&f, &tables, &root);
        let packed = f.corpus.to_packed();
        let d = f.corpus.num_docs();
        let plan = Sharding::weighted(&f.corpus.doc_weights(), 3);
        let pool = Arc::new(WorkerPool::new(3));

        // Reference: resident sweep.
        let (mut z_ref, mut m_ref) = (f.z0.clone(), f.m0.clone());
        let mut scratch: Vec<ShardScratch> =
            (0..pool.slots()).map(|_| ShardScratch::new(8)).collect();
        sweep.run_with_scratch_sched(
            &packed,
            &mut z_ref,
            &mut m_ref,
            &plan,
            &*pool,
            &mut scratch,
            Schedule::Steal,
        );
        let n_ref = TopicWordRows::merge_from_iter(
            8,
            scratch.iter_mut().map(|s| &mut s.out.n_acc),
        );

        let check = |z: &[Vec<u32>], m: &[DocTopics], n: &TopicWordRows, tag: &str| {
            assert_eq!(z, &z_ref[..], "{tag}: z diverged");
            for (d, (ma, mb)) in m.iter().zip(&m_ref).enumerate() {
                assert_eq!(ma.total(), mb.total(), "{tag}: m total, doc {d}");
                for (k, c) in ma.iter() {
                    assert_eq!(mb.get(k), c, "{tag}: m[{d}][{k}]");
                }
            }
            for k in 0..8 {
                assert_eq!(n.row(k), n_ref.row(k), "{tag}: topic {k}");
            }
        };

        for block_docs in [1usize, 5, usize::MAX] {
            let blocks = plan.refine(block_docs);
            for schedule in [Schedule::Steal, Schedule::SlotAffine] {
                let tag = format!("blocks={block_docs} schedule={schedule:?}");
                // Streamed over the nested resident z.
                let (mut z, mut m) = (f.z0.clone(), f.m0.clone());
                let mut scratch: Vec<ShardScratch> =
                    (0..pool.slots()).map(|_| ShardScratch::new(8)).collect();
                sweep.run_streamed(
                    &packed,
                    &NestedZ::new(&mut z),
                    &mut m,
                    &blocks,
                    &*pool,
                    &mut scratch,
                    schedule,
                );
                let n = TopicWordRows::merge_from_iter(
                    8,
                    scratch.iter_mut().map(|s| &mut s.out.n_acc),
                );
                check(&z, &m, &n, &format!("nested {tag}"));

                // Streamed over a packed z arena.
                let mut z_arena: Vec<u32> =
                    f.z0.iter().flat_map(|zd| zd.iter().copied()).collect();
                let mut m = f.m0.clone();
                let mut scratch: Vec<ShardScratch> =
                    (0..pool.slots()).map(|_| ShardScratch::new(8)).collect();
                sweep.run_streamed(
                    &packed,
                    &ArenaZ::new(&mut z_arena, packed.doc_offsets()),
                    &mut m,
                    &blocks,
                    &*pool,
                    &mut scratch,
                    schedule,
                );
                let n = TopicWordRows::merge_from_iter(
                    8,
                    scratch.iter_mut().map(|s| &mut s.out.n_acc),
                );
                let z: Vec<Vec<u32>> = packed
                    .doc_offsets()
                    .windows(2)
                    .map(|w| z_arena[w[0] as usize..w[1] as usize].to_vec())
                    .collect();
                check(&z, &m, &n, &format!("arena {tag}"));
            }

            // Prefetched double-buffered sweep (nested + arena): the
            // async block loads must leave the chain bit-identical,
            // and every block must be accounted a hit xor a stall.
            let tag = format!("blocks={block_docs} prefetched");
            let (mut z, mut m) = (f.z0.clone(), f.m0.clone());
            let mut scratch: Vec<ShardScratch> =
                (0..pool.slots()).map(|_| ShardScratch::new(8)).collect();
            sweep.run_streamed_prefetched(
                &packed,
                &NestedZ::new(&mut z),
                &mut m,
                &blocks,
                &pool,
                &mut scratch,
            );
            let n = TopicWordRows::merge_from_iter(
                8,
                scratch.iter_mut().map(|s| &mut s.out.n_acc),
            );
            check(&z, &m, &n, &format!("nested {tag}"));
            let accounted: u64 = scratch
                .iter()
                .map(|s| s.out.prefetch_hits + s.out.prefetch_stalls)
                .sum();
            assert_eq!(accounted, blocks.len() as u64, "{tag}: block accounting");

            let mut z_arena: Vec<u32> =
                f.z0.iter().flat_map(|zd| zd.iter().copied()).collect();
            let mut m = f.m0.clone();
            let mut scratch: Vec<ShardScratch> =
                (0..pool.slots()).map(|_| ShardScratch::new(8)).collect();
            sweep.run_streamed_prefetched(
                &packed,
                &ArenaZ::new(&mut z_arena, packed.doc_offsets()),
                &mut m,
                &blocks,
                &pool,
                &mut scratch,
            );
            let n = TopicWordRows::merge_from_iter(
                8,
                scratch.iter_mut().map(|s| &mut s.out.n_acc),
            );
            let z: Vec<Vec<u32>> = packed
                .doc_offsets()
                .windows(2)
                .map(|w| z_arena[w[0] as usize..w[1] as usize].to_vec())
                .collect();
            check(&z, &m, &n, &format!("arena {tag}"));
        }

        // Fully out of core: tokens and z both file-backed.
        let dir = std::env::temp_dir().join("hdp_zstep_ooc_test");
        let cpath = dir.join("corpus.hdpp");
        crate::corpus::io::write_packed(&packed, &cpath).unwrap();
        let cfile = PackedCorpusFile::open(&cpath).unwrap();
        let zfile = FileZ::from_nested(&dir.join("z.bin"), &f.z0).unwrap();
        let blocks = plan.refine(4);
        let mut m = f.m0.clone();
        let mut scratch: Vec<ShardScratch> =
            (0..pool.slots()).map(|_| ShardScratch::new(8)).collect();
        sweep.run_streamed(
            &cfile,
            &zfile,
            &mut m,
            &blocks,
            &*pool,
            &mut scratch,
            Schedule::Steal,
        );
        let n = TopicWordRows::merge_from_iter(
            8,
            scratch.iter_mut().map(|s| &mut s.out.n_acc),
        );
        let z = zfile.to_nested().unwrap();
        check(&z, &m, &n, "out-of-core");

        // Out of core *with* the prefetcher: tokens and z both loaded
        // ahead by async jobs, synced at the end — still bit-identical.
        let zfile2 = FileZ::from_nested(&dir.join("z_pf.bin"), &f.z0).unwrap();
        let mut m = f.m0.clone();
        let mut scratch: Vec<ShardScratch> =
            (0..pool.slots()).map(|_| ShardScratch::new(8)).collect();
        sweep.run_streamed_prefetched(&cfile, &zfile2, &mut m, &blocks, &pool, &mut scratch);
        zfile2.sync().unwrap();
        let n = TopicWordRows::merge_from_iter(
            8,
            scratch.iter_mut().map(|s| &mut s.out.n_acc),
        );
        let z = zfile2.to_nested().unwrap();
        check(&z, &m, &n, "out-of-core prefetched");
        let accounted: u64 = scratch
            .iter()
            .map(|s| s.out.prefetch_hits + s.out.prefetch_stalls)
            .sum();
        assert_eq!(accounted, blocks.len() as u64, "ooc prefetch accounting");
        // Residency: per-slot hot state is bounded by the largest
        // block, not the corpus (×2 buffer pairs for the prefetched
        // double buffer, ×2 slack for allocator rounding).
        let weights = f.corpus.doc_weights();
        let max_block: u64 = blocks
            .shards()
            .iter()
            .map(|b| weights[b.start..b.end].iter().sum())
            .max()
            .unwrap();
        let bound = 2 * 2 * 2 * 4 * max_block as usize; // (z + tok) × 2 pairs
        for (i, s) in scratch.iter().enumerate() {
            assert!(
                s.stream_buf_bytes() <= bound,
                "slot {i} holds {} bytes (> {bound})",
                s.stream_buf_bytes()
            );
        }
        assert_eq!(d, z.len());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resident_sweep_ignores_block_buffers() {
        // The resident path must never touch the streamed block
        // buffers: their capacity stays zero.
        let f = frozen_state(32);
        let root = Pcg64::new(5);
        let tables = WordTables::build(&f.phi, &f.psi, 0.5, 1usize);
        let sweep = frozen_sweep(&f, &tables, &root);
        let plan = Sharding::even(f.corpus.num_docs(), 3);
        let pool = crate::par::WorkerPool::new(2);
        let mut scratch: Vec<ShardScratch> =
            (0..pool.slots()).map(|_| ShardScratch::new(8)).collect();
        let (mut z, mut m) = (f.z0.clone(), f.m0.clone());
        sweep.run_with_scratch(&f.corpus, &mut z, &mut m, &plan, &pool, &mut scratch);
        for s in &scratch {
            assert_eq!(s.stream_buf_bytes(), 0);
        }
    }

    #[test]
    #[should_panic(expected = "offsets span")]
    fn arena_z_rejects_a_wrong_token_hint() {
        // A caller claiming the wrong token count for a block must hit
        // the offsets-span equality assert, not silently read a
        // misaligned arena range.
        let offsets = [0u64, 3, 5, 9];
        let mut arena = vec![0u32; 9];
        let z = ArenaZ::new(&mut arena, &offsets);
        let mut buf = Vec::new();
        // Block [1, 3) spans 6 tokens; claim 4.
        z.load(Shard { start: 1, end: 3 }, 4, &mut buf);
    }

    #[test]
    fn filez_concurrent_disjoint_blocks_and_sync() {
        // Post-pread/pwrite contract: many threads loading and storing
        // DISJOINT blocks of one FileZ concurrently must round-trip
        // every value exactly (no lock, no shared cursor). Each thread
        // owns a stride of 1-doc blocks: it re-reads and rewrites them
        // for several rounds, then stamps a distinct final pattern that
        // must read back exactly.
        let docs: Vec<Vec<u32>> = (0..48u32)
            .map(|d| (0..(d % 5 + 1)).map(|i| d * 1000 + i).collect())
            .collect();
        let dir = std::env::temp_dir().join("hdp_zstep_filez_conc");
        let zfile = FileZ::from_nested(&dir.join("z.bin"), &docs).unwrap();
        std::thread::scope(|scope| {
            for t in 0..8usize {
                let zfile = &zfile;
                let docs = &docs;
                scope.spawn(move || {
                    let mut buf = Vec::new();
                    for _round in 0..30 {
                        for d in (t..docs.len()).step_by(8) {
                            let block = Shard { start: d, end: d + 1 };
                            zfile.load(block, docs[d].len(), &mut buf);
                            assert_eq!(&buf[..], &docs[d][..], "thread {t} doc {d}");
                            // Rewrite the same values (idempotent, so
                            // racing rounds of this thread are fine;
                            // other threads never touch doc d).
                            zfile.store(block, &buf);
                        }
                    }
                    // Last word: a distinct per-doc pattern.
                    for d in (t..docs.len()).step_by(8) {
                        let block = Shard { start: d, end: d + 1 };
                        let new: Vec<u32> =
                            docs[d].iter().map(|&x| x ^ 0xdead_beef).collect();
                        zfile.store(block, &new);
                    }
                });
            }
        });
        zfile.sync().unwrap();
        let back = zfile.to_nested().unwrap();
        for (d, zd) in back.iter().enumerate() {
            let want: Vec<u32> = docs[d].iter().map(|&x| x ^ 0xdead_beef).collect();
            assert_eq!(zd, &want, "doc {d}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn plan_pair_hint_tracks_the_plan_not_the_corpus() {
        // Even plan over 1000 docs of weight 10: stripe of 4 slots is
        // a quarter of the corpus, so the hint must be ~N/4 + headroom,
        // far below whole-corpus totals.
        let weights = vec![10u64; 1000];
        let plan = Sharding::even(1000, 8);
        let hint = plan_pair_hint(&plan, &weights, 4);
        assert!(hint >= 2500, "hint {hint} below the stripe bound");
        assert!(hint < 5000, "hint {hint} should not approach corpus totals");
        // A block-refined plan keeps the same stripe mass, so the hint
        // stays plan-scale after refinement.
        let refined = plan.refine(7);
        let hint_refined = plan_pair_hint(&refined, &weights, 4);
        assert!(hint_refined < 5000, "refined hint {hint_refined} over-allocates");
        // Single slot sees everything.
        assert!(plan_pair_hint(&plan, &weights, 1) >= 10_000);
        // Cap holds.
        let huge = vec![u32::MAX as u64; 8];
        assert_eq!(plan_pair_hint(&Sharding::even(8, 1), &huge, 1), 1 << 22);
    }

    #[test]
    fn with_pair_hint_presizes_accumulator() {
        let mut r = ZShardResult::with_pair_hint(8, 10_000);
        let cap0 = r.n_acc.capacity();
        assert!(cap0 >= 10_000, "hint must presize the table (got {cap0})");
        for i in 0..10_000u32 {
            r.n_acc.add(i % 8, i / 8, 1);
        }
        // 10k distinct pairs fit without a single regrow.
        assert_eq!(r.n_acc.capacity(), cap0);
        assert_eq!(r.n_acc.nnz(), 10_000);
        // The no-hint default still works but is deliberately small.
        assert!(ZShardResult::new(8).n_acc.capacity() < cap0);
    }

    #[test]
    fn affine_sweep_matches_stealing_sweep() {
        // Same frozen state swept with work stealing and with the
        // slot-affine schedule: the chain (and merged stats) must be
        // bit-identical — placement never changes what is computed.
        use crate::corpus::synthetic::HdpCorpusSpec;
        use crate::par::{Schedule, WorkerPool};
        let (corpus, _) = HdpCorpusSpec {
            vocab: 120,
            topics: 5,
            gamma: 2.0,
            alpha: 1.0,
            topic_beta: 0.1,
            docs: 44,
            mean_doc_len: 22.0,
            len_sigma: 0.3,
            min_doc_len: 5,
        }
        .generate(21);
        let mut acc = TopicWordAcc::with_capacity(256);
        let mut rng = Pcg64::new(6);
        let z0: Vec<Vec<u32>> = corpus
            .docs
            .iter()
            .map(|d| d.iter().map(|_| rng.below(6) as u32).collect())
            .collect();
        for (doc, zd) in corpus.docs.iter().zip(&z0) {
            for (&v, &k) in doc.iter().zip(zd) {
                acc.add(k, v, 1);
            }
        }
        let n = TopicWordRows::merge_from(8, &mut [acc]);
        let root = Pcg64::new(41);
        let phi = super::super::phi::sample_phi(&root, &n, 0.05, 120, 1usize);
        let psi = [0.3, 0.2, 0.15, 0.1, 0.1, 0.05, 0.05, 0.05];
        let tables = WordTables::build(&phi, &psi, 0.5, 1usize);
        let sweep = ZSweep {
            phi: &phi,
            psi: &psi,
            tables: &tables,
            alpha: 0.5,
            k_max: 8,
            seed_root: &root,
            iteration: 1,
            kernels: Kernels::scalar(),
            ppu: None,
        };
        let m0: Vec<DocTopics> =
            z0.iter().map(|zd| zd.iter().copied().collect()).collect();
        let plan = Sharding::even(44, 7);
        let pool = WorkerPool::new(3);
        let run = |schedule: Schedule| {
            let mut scratch: Vec<ShardScratch> =
                (0..pool.slots()).map(|_| ShardScratch::new(8)).collect();
            let (mut z, mut m) = (z0.clone(), m0.clone());
            sweep.run_with_scratch_sched(
                &corpus.docs,
                &mut z,
                &mut m,
                &plan,
                &pool,
                &mut scratch,
                schedule,
            );
            let n = TopicWordRows::merge_from_iter(
                8,
                scratch.iter_mut().map(|s| &mut s.out.n_acc),
            );
            (z, n)
        };
        let (z_steal, n_steal) = run(Schedule::Steal);
        let (z_affine, n_affine) = run(Schedule::SlotAffine);
        assert_eq!(z_affine, z_steal);
        for k in 0..8 {
            assert_eq!(n_affine.row(k), n_steal.row(k), "topic {k}");
        }
    }

    #[test]
    fn sweep_conserves_counts_and_fills_results() {
        use crate::corpus::synthetic::HdpCorpusSpec;
        let (corpus, _) = HdpCorpusSpec {
            vocab: 80,
            topics: 4,
            gamma: 1.0,
            alpha: 1.0,
            topic_beta: 0.1,
            docs: 25,
            mean_doc_len: 30.0,
            len_sigma: 0.3,
            min_doc_len: 5,
        }
        .generate(9);
        let mut z: Vec<Vec<u32>> =
            corpus.docs.iter().map(|d| vec![0u32; d.len()]).collect();
        let mut m: Vec<DocTopics> =
            z.iter().map(|zd| zd.iter().copied().collect()).collect();
        let mut acc = TopicWordAcc::with_capacity(256);
        for (doc, zd) in corpus.docs.iter().zip(&z) {
            for (&v, &k) in doc.iter().zip(zd) {
                acc.add(k, v, 1);
            }
        }
        let n = TopicWordRows::merge_from(6, &mut [acc]);
        let root = Pcg64::new(5);
        let phi = super::super::phi::sample_phi(&root, &n, 0.05, 80, 1usize);
        let psi = [0.4, 0.2, 0.15, 0.1, 0.1, 0.05];
        let tables = WordTables::build(&phi, &psi, 0.6, 1usize);
        let sweep = ZSweep {
            phi: &phi,
            psi: &psi,
            tables: &tables,
            alpha: 0.6,
            k_max: 6,
            seed_root: &root,
            iteration: 2,
            kernels: Kernels::scalar(),
            ppu: None,
        };
        let results =
            sweep.run(&corpus.docs, &mut z, &mut m, &Sharding::even(25, 3));
        // n accumulators hold exactly N tokens.
        let mut total = 0u64;
        for mut r in results {
            total += r
                .n_acc
                .drain_triples()
                .iter()
                .map(|&(_, _, c)| c as u64)
                .sum::<u64>();
        }
        assert_eq!(total, corpus.num_tokens());
        // m consistent with z
        for (zd, md) in z.iter().zip(&m) {
            let rebuilt: DocTopics = zd.iter().copied().collect();
            assert_eq!(rebuilt.total(), md.total());
            for (k, c) in rebuilt.iter() {
                assert_eq!(md.get(k), c);
            }
        }
    }

    /// Whatever tier `auto()` resolves to, a kernel-driven sweep (and
    /// the kernel-built alias tables it draws from) must leave z, m,
    /// and the accumulated n bit-identical to the scalar sweep. The
    /// fixture drives both bucket-(b) branches: single-topic m_d init
    /// (dense columns win) relaxing toward mixed docs over sweeps.
    #[test]
    fn kernel_sweep_is_bit_identical_to_scalar() {
        use crate::corpus::synthetic::HdpCorpusSpec;
        let (corpus, _) = HdpCorpusSpec {
            vocab: 60,
            topics: 4,
            gamma: 1.0,
            alpha: 1.0,
            topic_beta: 0.1,
            docs: 20,
            mean_doc_len: 25.0,
            len_sigma: 0.3,
            min_doc_len: 5,
        }
        .generate(17);
        let mut rng = Pcg64::new(3);
        let z0: Vec<Vec<u32>> = corpus
            .docs
            .iter()
            .map(|d| d.iter().map(|_| rng.below(6) as u32).collect())
            .collect();
        let m0: Vec<DocTopics> =
            z0.iter().map(|zd| zd.iter().copied().collect()).collect();
        let mut acc = TopicWordAcc::with_capacity(256);
        for (doc, zd) in corpus.docs.iter().zip(&z0) {
            for (&v, &k) in doc.iter().zip(zd) {
                acc.add(k, v, 1);
            }
        }
        let n = TopicWordRows::merge_from(6, &mut [acc]);
        let root = Pcg64::new(19);
        let phi = super::super::phi::sample_phi(&root, &n, 0.05, 60, 1usize);
        let psi = [0.35, 0.25, 0.15, 0.1, 0.1, 0.05];
        let run = |kernels: Kernels| {
            let mut tables = WordTables::empty();
            let mut tscratch = WordTablesScratch::new();
            tables.build_into_with(&phi, &psi, 0.5, 1usize, &mut tscratch, &kernels);
            let sweep = ZSweep {
                phi: &phi,
                psi: &psi,
                tables: &tables,
                alpha: 0.5,
                k_max: 6,
                seed_root: &root,
                iteration: 4,
                kernels,
                ppu: None,
            };
            let (mut z, mut m) = (z0.clone(), m0.clone());
            let results =
                sweep.run(&corpus.docs, &mut z, &mut m, &Sharding::even(20, 2));
            let counters: Vec<(u64, u64)> = results
                .iter()
                .map(|r| (r.kern_gather_elems, r.kern_scan_tokens))
                .collect();
            (z, m, counters)
        };
        let (z_s, m_s, c_s) = run(Kernels::scalar());
        let auto = Kernels::auto();
        let (z_a, m_a, c_a) = run(auto);
        assert_eq!(z_a, z_s, "kernel sweep diverged from scalar");
        for (a, b) in m_a.iter().zip(&m_s) {
            assert_eq!(a.entries(), b.entries());
        }
        assert!(c_s.iter().all(|&(g, t)| g == 0 && t == 0), "scalar counted kernels");
        if auto.is_accelerated() {
            let gathered: u64 = c_a.iter().map(|&(g, _)| g).sum();
            assert!(gathered > 0, "accelerated sweep never hit the gather kernel");
        }
    }

    #[cfg(feature = "failpoints")]
    #[test]
    fn transient_filez_read_fault_heals_via_retry() {
        // One injected EIO on a FileZ block read must be absorbed by
        // the positioned-I/O retry policy: the sweep completes and the
        // chain is bit-identical to the fault-free run.
        use crate::fault::FaultSpec;
        use crate::par::{Schedule, WorkerPool};
        let _g = crate::fault::serial_guard();
        crate::fault::reset();
        let f = frozen_state(61);
        let root = Pcg64::new(13);
        let tables = WordTables::build(&f.phi, &f.psi, 0.5, 1usize);
        let sweep = frozen_sweep(&f, &tables, &root);
        let packed = f.corpus.to_packed();
        let blocks = Sharding::weighted(&f.corpus.doc_weights(), 3).refine(4);
        let pool = Arc::new(WorkerPool::new(3));

        // Fault-free reference over the resident nested store.
        let (mut z_ref, mut m_ref) = (f.z0.clone(), f.m0.clone());
        let mut scratch: Vec<ShardScratch> =
            (0..pool.slots()).map(|_| ShardScratch::new(8)).collect();
        sweep.run_streamed(
            &packed,
            &NestedZ::new(&mut z_ref),
            &mut m_ref,
            &blocks,
            &*pool,
            &mut scratch,
            Schedule::Steal,
        );

        let dir = std::env::temp_dir().join("hdp_zstep_fault_transient");
        let zfile = FileZ::from_nested(&dir.join("z.bin"), &f.z0).unwrap();
        crate::fault::arm("filez.pread", FaultSpec::error_after(2, 1));
        let mut m = f.m0.clone();
        let mut scratch: Vec<ShardScratch> =
            (0..pool.slots()).map(|_| ShardScratch::new(8)).collect();
        sweep.run_streamed(
            &packed,
            &zfile,
            &mut m,
            &blocks,
            &*pool,
            &mut scratch,
            Schedule::Steal,
        );
        assert!(crate::fault::triggered("filez.pread") >= 1, "fault never fired");
        crate::fault::reset();
        let z = zfile.to_nested().unwrap();
        assert_eq!(z, z_ref, "retried read must leave the chain bit-identical");
        for (d, (ma, mb)) in m.iter().zip(&m_ref).enumerate() {
            assert_eq!(ma.total(), mb.total(), "m total, doc {d}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[cfg(feature = "failpoints")]
    #[test]
    fn dead_prefetch_degrades_to_inline_reload() {
        // A persistent `prefetch.load` fault kills every async load
        // job (panic after its retries). The sweep must degrade to
        // inline reloads — same chain, failures accounted, pool alive.
        use crate::fault::FaultSpec;
        use crate::par::WorkerPool;
        let _g = crate::fault::serial_guard();
        crate::fault::reset();
        let f = frozen_state(62);
        let root = Pcg64::new(29);
        let tables = WordTables::build(&f.phi, &f.psi, 0.5, 1usize);
        let sweep = frozen_sweep(&f, &tables, &root);
        let packed = f.corpus.to_packed();
        let blocks = Sharding::weighted(&f.corpus.doc_weights(), 3).refine(4);
        let pool = Arc::new(WorkerPool::new(3));

        // Fault-free prefetched reference.
        let (mut z_ref, mut m_ref) = (f.z0.clone(), f.m0.clone());
        let mut scratch: Vec<ShardScratch> =
            (0..pool.slots()).map(|_| ShardScratch::new(8)).collect();
        sweep.run_streamed_prefetched(
            &packed,
            &NestedZ::new(&mut z_ref),
            &mut m_ref,
            &blocks,
            &pool,
            &mut scratch,
        );

        crate::fault::arm("prefetch.load", FaultSpec::error());
        let (mut z, mut m) = (f.z0.clone(), f.m0.clone());
        let mut scratch: Vec<ShardScratch> =
            (0..pool.slots()).map(|_| ShardScratch::new(8)).collect();
        sweep.run_streamed_prefetched(
            &packed,
            &NestedZ::new(&mut z),
            &mut m,
            &blocks,
            &pool,
            &mut scratch,
        );
        crate::fault::reset();
        assert_eq!(z, z_ref, "degraded sweep must stay bit-identical");
        for (d, (ma, mb)) in m.iter().zip(&m_ref).enumerate() {
            assert_eq!(ma.total(), mb.total(), "m total, doc {d}");
        }
        let failures: u64 =
            scratch.iter().map(|s| s.out.prefetch_failures).sum();
        let hits: u64 = scratch.iter().map(|s| s.out.prefetch_hits).sum();
        let stalls: u64 = scratch.iter().map(|s| s.out.prefetch_stalls).sum();
        assert!(failures > 0, "no prefetch job ever died");
        assert!(failures <= stalls, "every failure is also a stall");
        assert_eq!(hits + stalls, blocks.len() as u64, "block accounting");
        // The pool survived its workers' captured panics.
        let out = crate::par::exec_map(&*pool, 8, |i| i);
        assert_eq!(out.len(), 8);
    }

    #[test]
    fn try_sample_is_none_only_for_zero_mass_columns() {
        // `None` marks the two degenerate columns — a vocabulary id
        // never observed under Φ, and a word whose entire support has
        // Ψ_k = 0 — the float-edge / serving fallback cases that used
        // to panic. Live columns always draw from their support.
        // small_phi support: word 0 ∈ {0, 2}, word 1 ∈ {0, 1},
        // word 2 ∈ {1}; extend the vocab so word 3 is never observed.
        let phi = PhiMatrix::from_count_rows(
            4,
            &[vec![(0, 5), (1, 5)], vec![(1, 2), (2, 8)], vec![(0, 1)], vec![]],
        );
        let psi = [0.5, 0.0, 0.3, 0.2];
        let t = WordTables::build(&phi, &psi, 0.8, 1usize);
        let mut rng = Pcg64::new(5);
        assert!(t.try_sample(3, &mut rng).is_none(), "unseen vocabulary id");
        assert_eq!(t.mass(3), 0.0);
        assert!(t.try_sample(2, &mut rng).is_none(), "Ψ of word 2's only topic is 0");
        assert_eq!(t.mass(2), 0.0);
        for _ in 0..200 {
            let k = t.try_sample(0, &mut rng).expect("live column");
            assert!(k == 0 || k == 2, "word 0 support");
            let k = t.try_sample(1, &mut rng).expect("word 1 keeps topic 0");
            assert_eq!(k, 0, "topic 1's Ψ weight is zero, never drawn");
        }
    }

    #[test]
    fn zero_mass_word_keeps_assignment_in_both_kernels() {
        // A word absent from every topic's integer Φ has a degenerate
        // conditional: both the exact and the Pólya-urn kernel must
        // keep the old assignment and count the token — never panic.
        let phi = PhiMatrix::from_count_rows(
            4,
            &[vec![(0, 5), (1, 5)], vec![(1, 2), (2, 8)], vec![(0, 1)], vec![]],
        );
        let psi = [0.4, 0.3, 0.2, 0.1];
        let tables = WordTables::build(&phi, &psi, 0.9, 1usize);
        let root = Pcg64::new(11);
        let psi_alias = crate::alias::AliasTable::new(&psi);
        let docs = vec![vec![3u32, 1, 3]];
        for ppu in [None, Some(&psi_alias)] {
            let sweep = ZSweep {
                phi: &phi,
                psi: &psi,
                tables: &tables,
                alpha: 0.9,
                k_max: 4,
                seed_root: &root,
                iteration: 2,
                kernels: Kernels::scalar(),
                ppu,
            };
            let mut z = vec![vec![2u32, 0, 1]];
            let mut m: Vec<DocTopics> = vec![z[0].iter().copied().collect()];
            let r = sweep.run(&docs, &mut z, &mut m, &Sharding::even(1, 1));
            assert_eq!(z[0][0], 2, "token 0 keeps its topic");
            assert_eq!(z[0][2], 1, "token 2 keeps its topic");
            let zm: u64 = r.iter().map(|s| s.zero_mass_tokens).sum();
            assert_eq!(zm, 2, "both degenerate tokens counted");
        }
    }

    #[test]
    fn ppu_sweep_is_deterministic_and_conserves_tokens() {
        // Determinism (per-document RNG streams) and conservation: a
        // PPU sweep must account every token exactly once — resampled
        // through the MH kernel or kept as degenerate — and rebuild n
        // and m to the same totals as the exact kernel would.
        let f = frozen_state(73);
        let root = Pcg64::new(91);
        let tables = WordTables::build(&f.phi, &f.psi, 0.5, 1usize);
        let psi_alias = crate::alias::AliasTable::new(&f.psi);
        let mut sweep = frozen_sweep(&f, &tables, &root);
        sweep.ppu = Some(&psi_alias);
        let total_tokens: u64 = f.corpus.docs.iter().map(|d| d.len() as u64).sum();
        let run = || {
            let (mut z, mut m) = (f.z0.clone(), f.m0.clone());
            let r = sweep.run(
                &f.corpus.docs,
                &mut z,
                &mut m,
                &Sharding::even(f.corpus.num_docs(), 3),
            );
            (z, m, r)
        };
        let (z1, m1, r1) = run();
        let (z2, _, _) = run();
        assert_eq!(z1, z2, "ppu sweep must be deterministic for a fixed seed");
        let ppu: u64 = r1.iter().map(|s| s.ppu_tokens).sum();
        let zm: u64 = r1.iter().map(|s| s.zero_mass_tokens).sum();
        assert_eq!(ppu + zm, total_tokens, "every token ppu-swept xor degenerate");
        let da: u64 = r1.iter().map(|s| s.ppu_doc_accepts).sum();
        let wa: u64 = r1.iter().map(|s| s.ppu_word_accepts).sum();
        assert!(da > 0 && wa > 0, "both MH proposals must accept sometimes");
        assert!(da <= ppu && wa <= ppu, "at most one accept per sub-step");
        // n conservation: merged topic-word counts hold one entry per
        // token; m mirrors each document's new z.
        let mut accs: Vec<TopicWordAcc> = r1.into_iter().map(|r| r.n_acc).collect();
        let n = TopicWordRows::merge_from(8, &mut accs);
        let total_n: u64 = (0..8).map(|k| n.row_total(k)).sum();
        assert_eq!(total_n, total_tokens);
        for (d, (zd, md)) in z1.iter().zip(&m1).enumerate() {
            assert_eq!(md.total() as usize, zd.len(), "m total, doc {d}");
            let mut dense = [0u64; 8];
            for &k in zd {
                dense[k as usize] += 1;
            }
            for (k, c) in md.iter() {
                assert_eq!(c as u64, dense[k as usize], "m[{k}], doc {d}");
            }
        }
    }
}
