//! The doubly sparse `z` Gibbs step (§2.5, eq. 22–24).
//!
//! The full conditional `P(z_{i,d} = k) ∝ φ_{k,v}·α·Ψ_k + φ_{k,v}·m^{-i}_{d,k}`
//! splits into:
//!
//! * **bucket (a)** `φ_{k,v}·α·Ψ_k` — document-independent: one Walker
//!   alias table per word type, built once per iteration over the
//!   nonzero support of the `Φ` column ([`WordTables`]);
//! * **bucket (b)** `φ_{k,v}·m^{-i}_{d,k}` — evaluated per token by
//!   iterating the sparser of `m_d` (with binary-search `φ` lookups)
//!   and the `Φ` column (with O(1) dense-scratch `m` lookups) — the
//!   `O(min(K^{(m)}_d, K^{(Φ)}_v))` bound of eq. 29.
//!
//! `Φ` and `Ψ` are fixed during the phase (partially collapsed), so the
//! alias tables are exact and documents are embarrassingly parallel.
//! Each document owns an RNG stream keyed by (iteration, doc id): the
//! chain is bit-identical under any shard layout or thread count.

use crate::alias::SparseAlias;
use crate::par::{self, Sharding};
use crate::rng::Pcg64;
use crate::sparse::{DocCountHist, DocTopics, PhiMatrix, TopicWordAcc};

/// Reusable per-executor-slot buffers for [`WordTables::build_into`]:
/// the bucket-(a) weight vector for the word currently being processed
/// by that slot. Growth is counted via
/// [`crate::par::stats::note_scratch_alloc`].
#[derive(Debug, Default)]
pub struct WordTablesScratch {
    weights: Vec<Vec<f64>>,
}

impl WordTablesScratch {
    /// Empty scratch; per-slot buffers are grown on first use.
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, slots: usize) {
        if self.weights.len() < slots {
            crate::par::stats::note_scratch_alloc();
            self.weights.resize_with(slots, Vec::new);
        }
    }
}

/// Per-word-type bucket-(a) alias tables and totals.
pub struct WordTables {
    /// `tables[v]` — alias over `{k : φ_{k,v} > 0}` with weights
    /// `φ_{k,v}·α·Ψ_k`; `None` for words with an empty `Φ` column.
    tables: Vec<Option<SparseAlias>>,
    /// Dense per-word totals `Q_v` — the per-token hot load (§Perf:
    /// one predictable array read instead of an Option + pointer
    /// chase per token).
    masses: Vec<f64>,
}

impl WordTables {
    /// Empty table set, ready for [`WordTables::build_into`]. The
    /// samplers keep one of these per chain and rebuild it in place
    /// every iteration so the `tables`/`masses` vectors (and the
    /// per-slot weight buffers) survive across sweeps.
    pub fn empty() -> Self {
        Self { tables: Vec::new(), masses: Vec::new() }
    }

    /// Build all tables in parallel over word types on any executor
    /// (a `threads: usize` scoped strategy or a
    /// [`&WorkerPool`](crate::par::WorkerPool)). One-shot convenience
    /// over [`WordTables::build_into`].
    pub fn build<E: par::Executor + Copy>(
        phi: &PhiMatrix,
        psi: &[f64],
        alpha: f64,
        exec: E,
    ) -> Self {
        let mut out = Self::empty();
        let mut scratch = WordTablesScratch::new();
        out.build_into(phi, psi, alpha, exec, &mut scratch);
        out
    }

    /// Rebuild the tables in place, recycling the `tables`/`masses`
    /// vectors and the per-slot weight buffers across iterations
    /// instead of reallocating them each time. The result is identical
    /// to [`WordTables::build`] (same per-word weight order, same
    /// float summation order).
    pub fn build_into<E: par::Executor + Copy>(
        &mut self,
        phi: &PhiMatrix,
        psi: &[f64],
        alpha: f64,
        exec: E,
        scratch: &mut WordTablesScratch,
    ) {
        let vocab = phi.vocab();
        if self.tables.len() != vocab {
            crate::par::stats::note_scratch_alloc();
            self.tables.clear();
            self.tables.resize_with(vocab, || None);
            self.masses.clear();
            self.masses.resize(vocab, 0.0);
        }
        if vocab == 0 {
            return;
        }
        let plan = Sharding::even(vocab, exec.slots());
        scratch.ensure(exec.slot_bound(plan.len()));
        let tbase = crate::par::pool::SendPtr(self.tables.as_mut_ptr());
        let mbase = crate::par::pool::SendPtr(self.masses.as_mut_ptr());
        par::exec_shards_with(exec, &plan, &mut scratch.weights, |weights, _i, shard| {
            for v in shard.start..shard.end {
                let (topics, probs) = phi.col(v as u32);
                // SAFETY: shards cover disjoint word ranges, so index
                // `v` is owned by this task.
                let slot_t = unsafe { &mut *tbase.0.add(v) };
                let slot_m = unsafe { &mut *mbase.0.add(v) };
                weights.clear();
                let mut total = 0.0f64;
                for (&k, &p) in topics.iter().zip(probs) {
                    let w = p * alpha * psi[k as usize];
                    weights.push(w);
                    total += w;
                }
                if topics.is_empty() || total <= 0.0 {
                    *slot_t = None;
                    *slot_m = 0.0;
                } else {
                    let alias = SparseAlias::new(topics.to_vec(), weights);
                    *slot_m = alias.total();
                    *slot_t = Some(alias);
                }
            }
        });
    }

    /// Bucket-(a) total mass `Q_v = α·Σ_k φ_{k,v}Ψ_k`.
    #[inline]
    pub fn mass(&self, v: u32) -> f64 {
        self.masses[v as usize]
    }

    /// Draw a topic from bucket (a) for word `v`.
    #[inline]
    pub fn sample(&self, v: u32, rng: &mut Pcg64) -> u32 {
        self.tables[v as usize].as_ref().expect("empty column").sample(rng)
    }
}

/// Shard-local outputs of the z phase.
pub struct ZShardResult {
    /// Topic-word counts accumulated from the new assignments.
    pub n_acc: TopicWordAcc,
    /// Per-topic document-count histogram (feeds the l step).
    pub hist: DocCountHist,
    /// Tokens whose conditional had zero mass (word vanished from every
    /// topic under the integer `Φ`): assignment kept, counted here.
    pub zero_mass_tokens: u64,
    /// Tokens assigned to the flag topic `K* − 1` (§2.4 check).
    pub flag_tokens: u64,
    /// Work counter: Σ min(K^m, K^Φ) over tokens (eq. 29 audit).
    pub sparse_work: u64,
}

impl ZShardResult {
    /// Empty result for a `k_max`-topic model with a default `n_acc`
    /// capacity. Prefer [`ZShardResult::with_pair_hint`] when the
    /// caller knows the expected pair count — this default forces the
    /// accumulator to regrow during the first sweeps on any real shard.
    pub fn new(k_max: usize) -> Self {
        Self::with_pair_hint(k_max, 1 << 10)
    }

    /// Empty result whose `n_acc` is pre-sized for ~`pair_hint`
    /// distinct `(topic, word)` pairs (the samplers pass a
    /// tokens-per-slot estimate so warm sweeps never regrow the table).
    pub fn with_pair_hint(k_max: usize, pair_hint: usize) -> Self {
        Self {
            n_acc: TopicWordAcc::with_capacity(pair_hint.max(64)),
            hist: DocCountHist::new(k_max),
            zero_mass_tokens: 0,
            flag_tokens: 0,
            sparse_work: 0,
        }
    }

    /// Zero the counters and empty the accumulators, keeping every
    /// allocation for the next sweep.
    fn reset(&mut self, k_max: usize) {
        self.n_acc.clear();
        self.hist.reset(k_max);
        self.zero_mass_tokens = 0;
        self.flag_tokens = 0;
        self.sparse_work = 0;
    }
}

/// Reusable per-worker scratch.
pub struct ZScratch {
    /// Dense `m_{d,k}` lookup (K*), maintained only for the current doc.
    mdense: Vec<u32>,
    /// Topics that have appeared in the current document (may contain
    /// stale zero-count entries — iteration skips them; this makes the
    /// per-token add/remove O(1) instead of the O(K_d) list scans a
    /// `DocTopics` would cost; §Perf iteration 1).
    entries: Vec<u32>,
    /// Membership mark for `entries` (reset via `entries` at doc end).
    in_list: Vec<bool>,
    /// bucket-(b) partials `(topic, cumulative weight)`.
    partials: Vec<(u32, f64)>,
}

impl ZScratch {
    /// Scratch for `k_max` topics.
    pub fn new(k_max: usize) -> Self {
        crate::par::stats::note_scratch_alloc();
        Self {
            mdense: vec![0; k_max],
            entries: Vec::with_capacity(64),
            in_list: vec![false; k_max],
            partials: Vec::with_capacity(64),
        }
    }

    /// Grow the dense workspaces to cover `k_max` topics if needed
    /// (new space is zeroed/false, matching the between-docs
    /// invariant) and drop any stale entries.
    fn ensure(&mut self, k_max: usize) {
        if self.mdense.len() < k_max {
            crate::par::stats::note_scratch_alloc();
            self.mdense.resize(k_max, 0);
            self.in_list.resize(k_max, false);
        }
        self.entries.clear();
        self.partials.clear();
    }
}

/// One executor slot's persistent z-phase state: the dense probability
/// workspaces ([`ZScratch`]) plus the shard-local sweep outputs
/// ([`ZShardResult`]), all reused — cleared, not reallocated — across
/// sweeps. The sampler owns one per pool slot.
pub struct ShardScratch {
    /// Sweep outputs accumulated by this slot (possibly over several
    /// shards when the pool has fewer slots than the plan has shards).
    pub out: ZShardResult,
    scratch: ZScratch,
}

impl ShardScratch {
    /// Fresh scratch for a `k_max`-topic model (default `n_acc` size;
    /// see [`ShardScratch::with_pair_hint`]).
    pub fn new(k_max: usize) -> Self {
        Self { out: ZShardResult::new(k_max), scratch: ZScratch::new(k_max) }
    }

    /// Fresh scratch whose accumulator is pre-sized for ~`pair_hint`
    /// distinct `(topic, word)` pairs — the samplers pass their
    /// tokens-per-slot estimate here.
    pub fn with_pair_hint(k_max: usize, pair_hint: usize) -> Self {
        Self {
            out: ZShardResult::with_pair_hint(k_max, pair_hint),
            scratch: ZScratch::new(k_max),
        }
    }
}

/// Parameters of one z sweep.
pub struct ZSweep<'a> {
    pub phi: &'a PhiMatrix,
    pub psi: &'a [f64],
    pub tables: &'a WordTables,
    pub alpha: f64,
    pub k_max: usize,
    /// Root RNG; per-document streams derive from it and the iteration.
    pub seed_root: &'a Pcg64,
    pub iteration: u64,
}

impl<'a> ZSweep<'a> {
    /// Resample one document in place: `doc` tokens, `zd` assignments,
    /// `md` sparse counts; accumulates into the shard result.
    pub fn resample_doc(
        &self,
        doc_id: usize,
        doc: &[u32],
        zd: &mut [u32],
        md: &mut DocTopics,
        scratch: &mut ZScratch,
        out: &mut ZShardResult,
    ) {
        let mut rng = self
            .seed_root
            .stream(self.iteration.rotate_left(32) ^ 0x2000_0000)
            .stream(doc_id as u64);
        // Load the per-doc scratch from md (touch only its entries).
        // `live` tracks the current nnz of m_d for the min-sparsity
        // branch; `entries` may keep stale zero-count topics (skipped
        // during iteration, compacted at doc end).
        let mut live = md.nnz();
        for (k, c) in md.iter() {
            scratch.mdense[k as usize] = c;
            scratch.in_list[k as usize] = true;
            scratch.entries.push(k);
        }
        for (&v, z) in doc.iter().zip(zd.iter_mut()) {
            let kold = *z;
            // Remove the token (the −i in m^{-i}) — O(1).
            let cold = &mut scratch.mdense[kold as usize];
            *cold -= 1;
            if *cold == 0 {
                live -= 1;
            }
            // Bucket (b): iterate the sparser side.
            let (col_topics, col_probs) = self.phi.col(v);
            scratch.partials.clear();
            let mut s_b = 0.0f64;
            if live <= col_topics.len() {
                out.sparse_work += live as u64;
                for &k in scratch.entries.iter() {
                    let c = scratch.mdense[k as usize];
                    if c == 0 {
                        continue; // stale entry
                    }
                    // manual binary search over the hoisted column
                    if let Ok(idx) = col_topics.binary_search(&k) {
                        s_b += col_probs[idx] * c as f64;
                        scratch.partials.push((k, s_b));
                    }
                }
            } else {
                out.sparse_work += col_topics.len() as u64;
                for (&k, &p) in col_topics.iter().zip(col_probs) {
                    let c = scratch.mdense[k as usize];
                    if c > 0 {
                        s_b += p * c as f64;
                        scratch.partials.push((k, s_b));
                    }
                }
            }
            let q_a = self.tables.mass(v);
            let total = q_a + s_b;
            let knew = if total <= 0.0 {
                // Word v currently absent from every topic's integer Φ:
                // conditional is degenerate; keep the old assignment
                // (it re-enters n, so Φ regains the word next sweep).
                out.zero_mass_tokens += 1;
                kold
            } else {
                let u = rng.f64() * total;
                if u < s_b {
                    // walk the partials (short vector, linear is fastest)
                    let mut pick = scratch.partials.len() - 1;
                    for (idx, &(_, cum)) in scratch.partials.iter().enumerate() {
                        if u < cum {
                            pick = idx;
                            break;
                        }
                    }
                    scratch.partials[pick].0
                } else {
                    self.tables.sample(v, &mut rng)
                }
            };
            *z = knew;
            // Add the token — O(1) amortized.
            let cnew = &mut scratch.mdense[knew as usize];
            if *cnew == 0 {
                live += 1;
                if !scratch.in_list[knew as usize] {
                    scratch.in_list[knew as usize] = true;
                    scratch.entries.push(knew);
                }
            }
            *cnew += 1;
            out.n_acc.add(knew, v, 1);
            if knew as usize == self.k_max - 1 {
                out.flag_tokens += 1;
            }
        }
        // Compact the scratch back into md and reset it.
        md.clear();
        for &k in scratch.entries.iter() {
            let c = scratch.mdense[k as usize];
            if c > 0 {
                md.set(k, c);
            }
            scratch.mdense[k as usize] = 0;
            scratch.in_list[k as usize] = false;
        }
        scratch.entries.clear();
        out.hist.record_doc(md.entries());
    }

    /// Run the sweep over all documents with the given shard plan,
    /// mutating `z`/`m` in place and returning the per-shard results.
    ///
    /// One-shot form: allocates fresh per-shard scratch and runs on
    /// scoped threads (one per shard). The samplers use
    /// [`ZSweep::run_with_scratch`] with a persistent pool instead.
    pub fn run(
        &self,
        docs: &[Vec<u32>],
        z: &mut [Vec<u32>],
        m: &mut [DocTopics],
        plan: &Sharding,
    ) -> Vec<ZShardResult> {
        if plan.is_empty() {
            return Vec::new();
        }
        let mut scratch: Vec<ShardScratch> =
            (0..plan.len()).map(|_| ShardScratch::new(self.k_max)).collect();
        // With the scoped executor, slot == shard index, so each
        // ShardScratch.out is exactly one shard's result.
        self.run_with_scratch(docs, z, m, plan, plan.len(), &mut scratch);
        scratch.into_iter().map(|s| s.out).collect()
    }

    /// Run the sweep on `exec`, accumulating outputs into the per-slot
    /// `scratch` (reset here, reused across calls — no per-sweep
    /// allocation). The chain is bit-identical to [`ZSweep::run`] for
    /// the same plan because every document owns its RNG stream; only
    /// the grouping of outputs across `scratch` slots differs, and the
    /// shard merges are order-independent.
    pub fn run_with_scratch(
        &self,
        docs: &[Vec<u32>],
        z: &mut [Vec<u32>],
        m: &mut [DocTopics],
        plan: &Sharding,
        exec: impl par::Executor,
        scratch: &mut [ShardScratch],
    ) {
        self.run_with_scratch_sched(docs, z, m, plan, exec, scratch, par::Schedule::Steal)
    }

    /// [`ZSweep::run_with_scratch`] with an explicit [`par::Schedule`].
    /// Under [`par::Schedule::SlotAffine`] shard `i` is handed to pool
    /// slot `i % slots` every sweep, so a slot re-touches the same
    /// `z`/`m` shard each iteration (cache/NUMA affinity); the chain is
    /// bit-identical under either schedule because per-document RNG
    /// streams make placement irrelevant.
    #[allow(clippy::too_many_arguments)]
    pub fn run_with_scratch_sched(
        &self,
        docs: &[Vec<u32>],
        z: &mut [Vec<u32>],
        m: &mut [DocTopics],
        plan: &Sharding,
        exec: impl par::Executor,
        scratch: &mut [ShardScratch],
        schedule: par::Schedule,
    ) {
        if plan.is_empty() {
            return;
        }
        for s in scratch.iter_mut() {
            s.out.reset(self.k_max);
            s.scratch.ensure(self.k_max);
        }
        // Split z and m into per-shard mutable slices.
        let mut z_parts: Vec<&mut [Vec<u32>]> = Vec::with_capacity(plan.len());
        let mut m_parts: Vec<&mut [DocTopics]> = Vec::with_capacity(plan.len());
        {
            let mut z_rest = z;
            let mut m_rest = m;
            let mut offset = 0usize;
            for shard in plan.shards() {
                let (zl, zr) = z_rest.split_at_mut(shard.end - offset);
                let (ml, mr) = m_rest.split_at_mut(shard.end - offset);
                z_parts.push(zl);
                m_parts.push(ml);
                z_rest = zr;
                m_rest = mr;
                offset = shard.end;
            }
        }
        // Interior mutability across shards: each task owns its part.
        let work: Vec<(usize, &mut [Vec<u32>], &mut [DocTopics])> = plan
            .shards()
            .iter()
            .zip(z_parts.into_iter().zip(m_parts))
            .map(|(s, (zp, mp))| (s.start, zp, mp))
            .collect();
        let work = std::sync::Mutex::new(
            work.into_iter().map(Some).collect::<Vec<_>>(),
        );
        par::exec_shards_with_sched(exec, plan, scratch, schedule, |slot, shard_idx, shard| {
            let (start, zp, mp) = {
                let mut guard = work.lock().unwrap();
                guard[shard_idx].take().expect("shard taken once")
            };
            debug_assert_eq!(start, shard.start);
            let ShardScratch { out, scratch: zs } = slot;
            for (off, (zd, md)) in zp.iter_mut().zip(mp.iter_mut()).enumerate() {
                let d = shard.start + off;
                self.resample_doc(d, &docs[d], zd, md, zs, out);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::TopicWordRows;

    /// Dense reference: enumerate P(z=k) ∝ φ_{k,v}(αΨ_k + m_k) exactly.
    fn dense_conditional(
        phi: &PhiMatrix,
        psi: &[f64],
        alpha: f64,
        v: u32,
        mdense: &[u32],
    ) -> Vec<f64> {
        let k_max = psi.len();
        let mut w = vec![0.0f64; k_max];
        for k in 0..k_max {
            let p = phi.get(k as u32, v);
            w[k] = p * (alpha * psi[k] + mdense[k] as f64);
        }
        let s: f64 = w.iter().sum();
        if s > 0.0 {
            w.iter_mut().for_each(|x| *x /= s);
        }
        w
    }

    fn small_phi() -> PhiMatrix {
        // K=4, V=3
        PhiMatrix::from_count_rows(
            3,
            &[
                vec![(0, 5), (1, 5)],
                vec![(1, 2), (2, 8)],
                vec![(0, 1)],
                vec![], // dead topic
            ],
        )
    }

    #[test]
    fn word_tables_mass_matches_sum() {
        let phi = small_phi();
        let psi = [0.4, 0.3, 0.2, 0.1];
        let alpha = 0.7;
        let t = WordTables::build(&phi, &psi, alpha, 2usize);
        for v in 0..3u32 {
            let want: f64 = (0..4)
                .map(|k| phi.get(k as u32, v) * alpha * psi[k])
                .sum();
            assert!((t.mass(v) - want).abs() < 1e-12, "v={v}");
        }
    }

    #[test]
    fn word_tables_draw_distribution() {
        let phi = small_phi();
        let psi = [0.4, 0.3, 0.2, 0.1];
        let alpha = 1.0;
        let t = WordTables::build(&phi, &psi, alpha, 1usize);
        let mut rng = Pcg64::new(1);
        let mut counts = [0usize; 4];
        let reps = 200_000;
        for _ in 0..reps {
            counts[t.sample(1, &mut rng) as usize] += 1;
        }
        // weights at v=1: k0: .5*.4, k1: .2*.3 -> normalized
        let w0 = 0.5 * 0.4;
        let w1 = 0.2 * 0.3;
        let p0 = w0 / (w0 + w1);
        let got = counts[0] as f64 / reps as f64;
        assert!((got - p0).abs() < 0.01, "{got} vs {p0}");
        assert_eq!(counts[2], 0, "φ_{{2,1}} = 0");
        assert_eq!(counts[3], 0);
    }

    #[test]
    fn sweep_token_distribution_matches_dense_enumeration() {
        // Freeze Φ, Ψ, and one document with a single token; resampling
        // that token repeatedly must match the dense conditional.
        let phi = small_phi();
        let psi = [0.4, 0.3, 0.2, 0.1];
        let alpha = 0.9;
        let tables = WordTables::build(&phi, &psi, alpha, 1usize);
        // document: tokens [1, 1, 0], assignments start at [0, 1, 0]
        let doc = vec![1u32, 1, 0];
        let docs = vec![doc.clone()];
        let mut counts = vec![[0usize; 4]; 3];
        let reps = 60_000;
        for rep in 0..reps {
            let root = Pcg64::new(500 + rep as u64);
            let sweep = ZSweep {
                phi: &phi,
                psi: &psi,
                tables: &tables,
                alpha,
                k_max: 4,
                seed_root: &root,
                iteration: 3,
            };
            let mut z = vec![vec![0u32, 1, 0]];
            let mut m: Vec<DocTopics> =
                vec![z[0].iter().copied().collect()];
            let plan = Sharding::even(1, 1);
            sweep.run(&docs, &mut z, &mut m, &plan);
            for (i, &k) in z[0].iter().enumerate() {
                counts[i][k as usize] += 1;
            }
        }
        // Check the FIRST token's distribution analytically: at its
        // draw, m^{-i} = {0:1, 1:1} (the other two tokens unchanged).
        let mdense = [1u32, 1, 0, 0];
        let want = dense_conditional(&phi, &psi, alpha, 1, &mdense);
        for k in 0..4 {
            let got = counts[0][k] as f64 / reps as f64;
            assert!(
                (got - want[k]).abs() < 0.015,
                "token0 k={k}: {got} vs {}",
                want[k]
            );
        }
    }

    #[test]
    fn sweep_shard_invariant() {
        // Same corpus, same seed, different shard counts → identical z.
        use crate::corpus::synthetic::HdpCorpusSpec;
        let (corpus, _) = HdpCorpusSpec {
            vocab: 120,
            topics: 5,
            gamma: 2.0,
            alpha: 1.0,
            topic_beta: 0.1,
            docs: 40,
            mean_doc_len: 25.0,
            len_sigma: 0.3,
            min_doc_len: 5,
        }
        .generate(8);
        // Build some non-trivial state.
        let mut acc = TopicWordAcc::with_capacity(256);
        let mut rng = Pcg64::new(3);
        let mut z: Vec<Vec<u32>> = corpus
            .docs
            .iter()
            .map(|d| d.iter().map(|_| rng.below(6) as u32).collect())
            .collect();
        for (doc, zd) in corpus.docs.iter().zip(&z) {
            for (&v, &k) in doc.iter().zip(zd) {
                acc.add(k, v, 1);
            }
        }
        let n = TopicWordRows::merge_from(8, &mut [acc]);
        let root = Pcg64::new(77);
        let phi = super::super::phi::sample_phi(&root, &n, 0.05, 120, 1usize);
        let psi = [0.3, 0.2, 0.15, 0.1, 0.1, 0.05, 0.05, 0.05];
        let tables = WordTables::build(&phi, &psi, 0.5, 1usize);
        let sweep = ZSweep {
            phi: &phi,
            psi: &psi,
            tables: &tables,
            alpha: 0.5,
            k_max: 8,
            seed_root: &root,
            iteration: 1,
        };
        let mut m: Vec<DocTopics> =
            z.iter().map(|zd| zd.iter().copied().collect()).collect();
        let mut z1 = z.clone();
        let mut m1 = m.clone();
        sweep.run(&corpus.docs, &mut z1, &mut m1, &Sharding::even(40, 1));
        sweep.run(&corpus.docs, &mut z, &mut m, &Sharding::even(40, 7));
        assert_eq!(z, z1, "chains must not depend on shard layout");
    }

    #[test]
    fn pooled_sweep_matches_scoped_sweep() {
        // Same frozen state swept twice: scoped one-shot `run` vs
        // `run_with_scratch` on a persistent pool (with slot count ≠
        // shard count, twice in a row to exercise scratch reuse). The
        // chain (z, m) must be bit-identical and the merged statistics
        // equal.
        use crate::corpus::synthetic::HdpCorpusSpec;
        use crate::par::WorkerPool;
        let (corpus, _) = HdpCorpusSpec {
            vocab: 150,
            topics: 5,
            gamma: 2.0,
            alpha: 1.0,
            topic_beta: 0.1,
            docs: 50,
            mean_doc_len: 25.0,
            len_sigma: 0.3,
            min_doc_len: 5,
        }
        .generate(12);
        let mut acc = TopicWordAcc::with_capacity(256);
        let mut rng = Pcg64::new(4);
        let z0: Vec<Vec<u32>> = corpus
            .docs
            .iter()
            .map(|d| d.iter().map(|_| rng.below(6) as u32).collect())
            .collect();
        for (doc, zd) in corpus.docs.iter().zip(&z0) {
            for (&v, &k) in doc.iter().zip(zd) {
                acc.add(k, v, 1);
            }
        }
        let n = TopicWordRows::merge_from(8, &mut [acc]);
        let root = Pcg64::new(31);
        let phi = super::super::phi::sample_phi(&root, &n, 0.05, 150, 1usize);
        let psi = [0.3, 0.2, 0.15, 0.1, 0.1, 0.05, 0.05, 0.05];
        let tables = WordTables::build(&phi, &psi, 0.5, 1usize);
        let m0: Vec<DocTopics> =
            z0.iter().map(|zd| zd.iter().copied().collect()).collect();
        let plan = Sharding::even(50, 5);
        let pool = WorkerPool::new(3); // fewer slots than shards
        let mut scratch: Vec<ShardScratch> =
            (0..plan.len().max(pool.slots())).map(|_| ShardScratch::new(8)).collect();
        for iteration in 1..=2u64 {
            let sweep = ZSweep {
                phi: &phi,
                psi: &psi,
                tables: &tables,
                alpha: 0.5,
                k_max: 8,
                seed_root: &root,
                iteration,
            };
            let (mut z_scoped, mut m_scoped) = (z0.clone(), m0.clone());
            let results =
                sweep.run(&corpus.docs, &mut z_scoped, &mut m_scoped, &plan);
            let (mut z_pooled, mut m_pooled) = (z0.clone(), m0.clone());
            sweep.run_with_scratch(
                &corpus.docs,
                &mut z_pooled,
                &mut m_pooled,
                &plan,
                &pool,
                &mut scratch,
            );
            assert_eq!(z_pooled, z_scoped, "iteration {iteration}");
            for (md, ms) in m_pooled.iter().zip(&m_scoped) {
                assert_eq!(md.total(), ms.total());
            }
            // Merged statistics agree regardless of slot grouping.
            let mut accs: Vec<TopicWordAcc> =
                results.into_iter().map(|r| r.n_acc).collect();
            let n_scoped = TopicWordRows::merge_from(8, &mut accs);
            let n_pooled = TopicWordRows::merge_from_iter(
                8,
                scratch.iter_mut().map(|s| &mut s.out.n_acc),
            );
            for k in 0..8 {
                assert_eq!(n_pooled.row(k), n_scoped.row(k), "topic {k}");
            }
        }
    }

    #[test]
    fn build_into_reuses_buffers_and_matches_build() {
        use crate::par::WorkerPool;
        let phi = small_phi();
        let psi = [0.4, 0.3, 0.2, 0.1];
        let alpha = 0.7;
        let pool = WorkerPool::new(2);
        let fresh = WordTables::build(&phi, &psi, alpha, &pool);
        let mut reused = WordTables::empty();
        let mut scratch = WordTablesScratch::new();
        reused.build_into(&phi, &psi, alpha, &pool, &mut scratch);
        let tables_ptr = reused.tables.as_ptr();
        let masses_ptr = reused.masses.as_ptr();
        // Rebuild with different Ψ, then with the original again: the
        // recycled vectors must not be reallocated (the global alloc
        // counter can't be asserted here — tests run concurrently).
        let psi2 = [0.1, 0.2, 0.3, 0.4];
        reused.build_into(&phi, &psi2, alpha, &pool, &mut scratch);
        reused.build_into(&phi, &psi, alpha, &pool, &mut scratch);
        assert_eq!(reused.tables.as_ptr(), tables_ptr, "tables vec must be reused");
        assert_eq!(reused.masses.as_ptr(), masses_ptr, "masses vec must be reused");
        assert_eq!(scratch.weights.len(), pool.slots());
        for v in 0..3u32 {
            assert_eq!(reused.mass(v).to_bits(), fresh.mass(v).to_bits(), "v={v}");
        }
        // Draw-level agreement on a live column.
        let mut r1 = Pcg64::new(7);
        let mut r2 = Pcg64::new(7);
        for _ in 0..200 {
            assert_eq!(reused.sample(1, &mut r1), fresh.sample(1, &mut r2));
        }
    }

    #[test]
    fn with_pair_hint_presizes_accumulator() {
        let mut r = ZShardResult::with_pair_hint(8, 10_000);
        let cap0 = r.n_acc.capacity();
        assert!(cap0 >= 10_000, "hint must presize the table (got {cap0})");
        for i in 0..10_000u32 {
            r.n_acc.add(i % 8, i / 8, 1);
        }
        // 10k distinct pairs fit without a single regrow.
        assert_eq!(r.n_acc.capacity(), cap0);
        assert_eq!(r.n_acc.nnz(), 10_000);
        // The no-hint default still works but is deliberately small.
        assert!(ZShardResult::new(8).n_acc.capacity() < cap0);
    }

    #[test]
    fn affine_sweep_matches_stealing_sweep() {
        // Same frozen state swept with work stealing and with the
        // slot-affine schedule: the chain (and merged stats) must be
        // bit-identical — placement never changes what is computed.
        use crate::corpus::synthetic::HdpCorpusSpec;
        use crate::par::{Schedule, WorkerPool};
        let (corpus, _) = HdpCorpusSpec {
            vocab: 120,
            topics: 5,
            gamma: 2.0,
            alpha: 1.0,
            topic_beta: 0.1,
            docs: 44,
            mean_doc_len: 22.0,
            len_sigma: 0.3,
            min_doc_len: 5,
        }
        .generate(21);
        let mut acc = TopicWordAcc::with_capacity(256);
        let mut rng = Pcg64::new(6);
        let z0: Vec<Vec<u32>> = corpus
            .docs
            .iter()
            .map(|d| d.iter().map(|_| rng.below(6) as u32).collect())
            .collect();
        for (doc, zd) in corpus.docs.iter().zip(&z0) {
            for (&v, &k) in doc.iter().zip(zd) {
                acc.add(k, v, 1);
            }
        }
        let n = TopicWordRows::merge_from(8, &mut [acc]);
        let root = Pcg64::new(41);
        let phi = super::super::phi::sample_phi(&root, &n, 0.05, 120, 1usize);
        let psi = [0.3, 0.2, 0.15, 0.1, 0.1, 0.05, 0.05, 0.05];
        let tables = WordTables::build(&phi, &psi, 0.5, 1usize);
        let sweep = ZSweep {
            phi: &phi,
            psi: &psi,
            tables: &tables,
            alpha: 0.5,
            k_max: 8,
            seed_root: &root,
            iteration: 1,
        };
        let m0: Vec<DocTopics> =
            z0.iter().map(|zd| zd.iter().copied().collect()).collect();
        let plan = Sharding::even(44, 7);
        let pool = WorkerPool::new(3);
        let run = |schedule: Schedule| {
            let mut scratch: Vec<ShardScratch> =
                (0..pool.slots()).map(|_| ShardScratch::new(8)).collect();
            let (mut z, mut m) = (z0.clone(), m0.clone());
            sweep.run_with_scratch_sched(
                &corpus.docs,
                &mut z,
                &mut m,
                &plan,
                &pool,
                &mut scratch,
                schedule,
            );
            let n = TopicWordRows::merge_from_iter(
                8,
                scratch.iter_mut().map(|s| &mut s.out.n_acc),
            );
            (z, n)
        };
        let (z_steal, n_steal) = run(Schedule::Steal);
        let (z_affine, n_affine) = run(Schedule::SlotAffine);
        assert_eq!(z_affine, z_steal);
        for k in 0..8 {
            assert_eq!(n_affine.row(k), n_steal.row(k), "topic {k}");
        }
    }

    #[test]
    fn sweep_conserves_counts_and_fills_results() {
        use crate::corpus::synthetic::HdpCorpusSpec;
        let (corpus, _) = HdpCorpusSpec {
            vocab: 80,
            topics: 4,
            gamma: 1.0,
            alpha: 1.0,
            topic_beta: 0.1,
            docs: 25,
            mean_doc_len: 30.0,
            len_sigma: 0.3,
            min_doc_len: 5,
        }
        .generate(9);
        let mut z: Vec<Vec<u32>> =
            corpus.docs.iter().map(|d| vec![0u32; d.len()]).collect();
        let mut m: Vec<DocTopics> =
            z.iter().map(|zd| zd.iter().copied().collect()).collect();
        let mut acc = TopicWordAcc::with_capacity(256);
        for (doc, zd) in corpus.docs.iter().zip(&z) {
            for (&v, &k) in doc.iter().zip(zd) {
                acc.add(k, v, 1);
            }
        }
        let n = TopicWordRows::merge_from(6, &mut [acc]);
        let root = Pcg64::new(5);
        let phi = super::super::phi::sample_phi(&root, &n, 0.05, 80, 1usize);
        let psi = [0.4, 0.2, 0.15, 0.1, 0.1, 0.05];
        let tables = WordTables::build(&phi, &psi, 0.6, 1usize);
        let sweep = ZSweep {
            phi: &phi,
            psi: &psi,
            tables: &tables,
            alpha: 0.6,
            k_max: 6,
            seed_root: &root,
            iteration: 2,
        };
        let results =
            sweep.run(&corpus.docs, &mut z, &mut m, &Sharding::even(25, 3));
        // n accumulators hold exactly N tokens.
        let mut total = 0u64;
        for mut r in results {
            total += r
                .n_acc
                .drain_triples()
                .iter()
                .map(|&(_, _, c)| c as u64)
                .sum::<u64>();
        }
        assert_eq!(total, corpus.num_tokens());
        // m consistent with z
        for (zd, md) in z.iter().zip(&m) {
            let rebuilt: DocTopics = zd.iter().copied().collect();
            assert_eq!(rebuilt.total(), md.total());
            for (k, c) in rebuilt.iter() {
                assert_eq!(md.get(k), c);
            }
        }
    }
}
