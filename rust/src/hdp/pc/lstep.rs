//! The `l` Gibbs step via the binomial trick (§2.6).
//!
//! Rather than storing the O(N) Bernoulli augmentation `b`, the
//! sufficient statistic is sampled directly:
//!
//! ```text
//! l_k = Σ_{j=1..max_d m_{d,k}}  Bin(D_{k,j},  αΨ_k / (αΨ_k + j − 1))
//! ```
//!
//! where `D_{k,j}` = #documents with `m_{d,k} ≥ j`, read off the sparse
//! [`DocCountHist`]. Cost is constant in `D` and linear in the number
//! of distinct per-document count levels. [`sample_l_explicit`] is the
//! literal eq. (26)–(27) Bernoulli-sequence sampler used to validate
//! the trick distributionally.

use crate::rng::{dist, Pcg64};
use crate::sparse::DocCountHist;

/// Sample `l_k` for one topic from the count histogram.
pub fn sample_l_topic(rng: &mut Pcg64, hist: &DocCountHist, k: usize, psi_k: f64, alpha: f64) -> u64 {
    let a = alpha * psi_k;
    let mut l = 0u64;
    hist.for_runs(k, |j_lo, j_hi, d| {
        for j in j_lo..=j_hi {
            if j == 1 {
                // p = a / (a + 0) = 1: every document's first draw of a
                // topic necessarily came from Ψ.
                l += d as u64;
            } else if a > 0.0 {
                let p = a / (a + (j - 1) as f64);
                l += dist::binomial(rng, d as u64, p);
            }
        }
    });
    l
}

/// Sample the full `l` vector in parallel over topics, using one RNG
/// stream per topic (shard-layout invariant). Runs on any executor: a
/// `threads: usize` scoped strategy or a persistent
/// [`&WorkerPool`](crate::par::WorkerPool).
pub fn sample_l(
    root: &Pcg64,
    hist: &DocCountHist,
    psi: &[f64],
    alpha: f64,
    exec: impl crate::par::Executor,
) -> Vec<u64> {
    let k_max = hist.num_topics();
    assert_eq!(psi.len(), k_max);
    crate::par::exec_map(exec, k_max, |k| {
        if hist.max_count(k) == 0 {
            return 0u64;
        }
        let mut rng = root.stream(0x6c00_0000 | k as u64);
        sample_l_topic(&mut rng, hist, k, psi[k], alpha)
    })
}

/// Literal eq. (26)–(27): for one topic, iterate every document's count
/// `m_{d,k}` and draw the Bernoulli sequence. O(Σ_d m_{d,k}) — the
/// reference the binomial trick is tested against.
pub fn sample_l_explicit(
    rng: &mut Pcg64,
    doc_counts: &[u32],
    psi_k: f64,
    alpha: f64,
) -> u64 {
    let a = alpha * psi_k;
    let mut l = 0u64;
    for &m in doc_counts {
        for j in 1..=m {
            let p = if j == 1 { 1.0 } else { a / (a + (j - 1) as f64) };
            if rng.bernoulli(p) {
                l += 1;
            }
        }
    }
    l
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hist_from_counts(counts: &[u32]) -> DocCountHist {
        let mut h = DocCountHist::new(1);
        for &c in counts {
            if c > 0 {
                h.record_doc(&[(0, c)]);
            }
        }
        h.finish();
        h
    }

    #[test]
    fn at_least_one_per_document() {
        // l_k >= number of documents containing the topic, and
        // l_k <= total tokens of the topic.
        let mut rng = Pcg64::new(1);
        let counts = [3u32, 1, 7, 2];
        let h = hist_from_counts(&counts);
        for _ in 0..200 {
            let l = sample_l_topic(&mut rng, &h, 0, 0.3, 0.5);
            assert!(l >= 4, "l={l}");
            assert!(l <= 13, "l={l}");
        }
    }

    #[test]
    fn trick_matches_explicit_distribution() {
        // Moment comparison of the binomial trick vs the literal
        // Bernoulli-sequence sampler on the same configuration.
        let counts = [5u32, 2, 2, 9, 1, 3];
        let h = hist_from_counts(&counts);
        let (alpha, psi_k) = (1.2, 0.4);
        let reps = 40_000;
        let mut rng = Pcg64::new(2);
        let (mut s1, mut s1sq) = (0.0f64, 0.0f64);
        let (mut s2, mut s2sq) = (0.0f64, 0.0f64);
        for _ in 0..reps {
            let a = sample_l_topic(&mut rng, &h, 0, psi_k, alpha) as f64;
            let b = sample_l_explicit(&mut rng, &counts, psi_k, alpha) as f64;
            s1 += a;
            s1sq += a * a;
            s2 += b;
            s2sq += b * b;
        }
        let m1 = s1 / reps as f64;
        let m2 = s2 / reps as f64;
        let v1 = s1sq / reps as f64 - m1 * m1;
        let v2 = s2sq / reps as f64 - m2 * m2;
        assert!((m1 - m2).abs() < 0.05, "means {m1} vs {m2}");
        assert!((v1 - v2).abs() < 0.15 * v2.max(0.5), "vars {v1} vs {v2}");
    }

    #[test]
    fn exact_mean_small_case() {
        // counts = [2]: l = 1 + Ber(a/(a+1)); E[l] = 1 + a/(a+1).
        let h = hist_from_counts(&[2]);
        let (alpha, psi_k) = (0.8, 0.5);
        let a = alpha * psi_k;
        let want = 1.0 + a / (a + 1.0);
        let mut rng = Pcg64::new(3);
        let reps = 100_000;
        let mean = (0..reps)
            .map(|_| sample_l_topic(&mut rng, &h, 0, psi_k, alpha) as f64)
            .sum::<f64>()
            / reps as f64;
        assert!((mean - want).abs() < 0.01, "{mean} vs {want}");
    }

    #[test]
    fn zero_psi_gives_first_draw_only() {
        // With Ψ_k = 0, every j>1 Bernoulli has p=0: l = #documents.
        let h = hist_from_counts(&[4, 4, 4]);
        let mut rng = Pcg64::new(4);
        let l = sample_l_topic(&mut rng, &h, 0, 0.0, 1.0);
        assert_eq!(l, 3);
    }

    #[test]
    fn parallel_l_deterministic_and_thread_invariant() {
        let mut h = DocCountHist::new(5);
        h.record_doc(&[(0, 2), (3, 7)]);
        h.record_doc(&[(0, 1), (3, 2), (4, 1)]);
        h.finish();
        let psi = [0.2, 0.1, 0.1, 0.5, 0.1];
        let root = Pcg64::new(9);
        let l1 = sample_l(&root, &h, &psi, 0.7, 1usize);
        let l4 = sample_l(&root, &h, &psi, 0.7, 4usize);
        assert_eq!(l1, l4, "per-topic streams make layout irrelevant");
        assert_eq!(l1[1], 0);
        assert_eq!(l1[2], 0);
        assert!(l1[0] >= 2 && l1[0] <= 3);
        assert!(l1[3] >= 2 && l1[3] <= 9);
        assert_eq!(l1[4], 1);
    }
}
