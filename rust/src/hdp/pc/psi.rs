//! The `Ψ` Gibbs step: stick-breaking posterior of Proposition 1 under
//! the FGEM truncation of §2.4.
//!
//! Given the sufficient statistic `l` (how many topic draws came from
//! `Ψ` rather than the urn), `Ψ | l` is generalized-Dirichlet:
//!
//! ```text
//! ς_k ~ Beta(1 + l_k, γ + Σ_{i>k} l_i),   ς_{K*} = 1
//! Ψ_k = ς_k · Π_{i<k} (1 − ς_i)
//! ```

use crate::rng::{dist, Pcg64};

/// Sample `Ψ | l` into `psi` (same length as `l`); the last index is
/// the flag topic `K*` with `ς = 1`, so `Ψ` sums to exactly 1.
pub fn sample_psi(rng: &mut Pcg64, l: &[u64], gamma: f64, psi: &mut [f64]) {
    let k_max = l.len();
    assert_eq!(psi.len(), k_max);
    assert!(k_max >= 1);
    // Suffix sums Σ_{i>k} l_i.
    let mut suffix = vec![0u64; k_max + 1];
    for k in (0..k_max).rev() {
        suffix[k] = suffix[k + 1] + l[k];
    }
    let mut remaining = 1.0f64;
    for k in 0..k_max {
        let s = if k + 1 == k_max {
            1.0 // flag topic: absorb the tail (§2.4)
        } else {
            dist::beta(rng, 1.0 + l[k] as f64, gamma + suffix[k + 1] as f64)
        };
        psi[k] = remaining * s;
        remaining *= 1.0 - s;
    }
}

/// Generalized-Dirichlet `Ψ` step with an *informative* stick prior
/// (the §4 extension): `ς_k ~ Beta(a_k + l_k, b_k + Σ_{i>k} l_i)` with
/// per-stick prior hyperparameters `(a_k, b_k)` instead of the GEM's
/// `(1, γ)`. `sample_psi` is the special case `a_k = 1, b_k = γ`.
pub fn sample_psi_general(
    rng: &mut Pcg64,
    l: &[u64],
    a: &[f64],
    b: &[f64],
    psi: &mut [f64],
) {
    let k_max = l.len();
    assert_eq!(psi.len(), k_max);
    assert_eq!(a.len(), k_max);
    assert_eq!(b.len(), k_max);
    let mut suffix = vec![0u64; k_max + 1];
    for k in (0..k_max).rev() {
        suffix[k] = suffix[k + 1] + l[k];
    }
    let mut remaining = 1.0f64;
    for k in 0..k_max {
        let s = if k + 1 == k_max {
            1.0
        } else {
            dist::beta(rng, a[k] + l[k] as f64, b[k] + suffix[k + 1] as f64)
        };
        psi[k] = remaining * s;
        remaining *= 1.0 - s;
    }
}

/// Posterior mean of `Ψ_k | l` under the same FGEM posterior — used by
/// moment-matching tests and as a deterministic point estimate:
/// `E[ς_k] = (1 + l_k) / (1 + γ + Σ_{i≥k} l_i)` and
/// `E[Ψ_k] = E[ς_k]·Π_{i<k}(1 − E[ς_i])` (independence of the sticks).
pub fn psi_posterior_mean(l: &[u64], gamma: f64) -> Vec<f64> {
    let k_max = l.len();
    let mut suffix = vec![0u64; k_max + 1];
    for k in (0..k_max).rev() {
        suffix[k] = suffix[k + 1] + l[k];
    }
    let mut out = vec![0.0; k_max];
    let mut remaining = 1.0f64;
    for k in 0..k_max {
        let e = if k + 1 == k_max {
            1.0
        } else {
            let a = 1.0 + l[k] as f64;
            let b = gamma + suffix[k + 1] as f64;
            a / (a + b)
        };
        out[k] = remaining * e;
        remaining *= 1.0 - e;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sums_to_one_and_nonnegative() {
        let mut rng = Pcg64::new(1);
        let l = [10u64, 5, 0, 1, 0];
        let mut psi = [0.0; 5];
        for _ in 0..100 {
            sample_psi(&mut rng, &l, 1.0, &mut psi);
            let s: f64 = psi.iter().sum();
            assert!((s - 1.0).abs() < 1e-12, "sum {s}");
            assert!(psi.iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn empirical_mean_matches_posterior_mean() {
        let mut rng = Pcg64::new(2);
        let l = [50u64, 20, 5, 0];
        let gamma = 1.5;
        let want = psi_posterior_mean(&l, gamma);
        let mut acc = [0.0f64; 4];
        let reps = 50_000;
        let mut psi = [0.0; 4];
        for _ in 0..reps {
            sample_psi(&mut rng, &l, gamma, &mut psi);
            for i in 0..4 {
                acc[i] += psi[i];
            }
        }
        for i in 0..4 {
            let got = acc[i] / reps as f64;
            assert!(
                (got - want[i]).abs() < 0.01,
                "component {i}: {got} vs {}",
                want[i]
            );
        }
    }

    #[test]
    fn no_counts_gives_gem_prior_means() {
        // With l = 0, ς_k ~ Beta(1, γ): E[Ψ_k] = (1/(1+γ))(γ/(1+γ))^k.
        let gamma = 2.0;
        let l = [0u64; 6];
        let want = psi_posterior_mean(&l, gamma);
        for k in 0..5 {
            let expect =
                (1.0 / (1.0 + gamma)) * (gamma / (1.0 + gamma)).powi(k as i32);
            assert!((want[k] - expect).abs() < 1e-12, "k={k}");
        }
    }

    #[test]
    fn heavy_count_concentrates_mass() {
        let mut rng = Pcg64::new(3);
        let mut l = vec![0u64; 10];
        l[2] = 100_000;
        let mut psi = vec![0.0; 10];
        sample_psi(&mut rng, &l, 1.0, &mut psi);
        assert!(psi[2] > 0.9, "psi={psi:?}");
    }

    #[test]
    fn general_prior_reduces_to_gem() {
        // With a_k = 1, b_k = γ the general sampler must agree with
        // sample_psi distributionally (same seed ⇒ same draws).
        let l = [10u64, 3, 0, 1];
        let gamma = 1.7;
        let a = vec![1.0; 4];
        let b = vec![gamma; 4];
        let mut r1 = Pcg64::new(5);
        let mut r2 = Pcg64::new(5);
        let mut p1 = [0.0; 4];
        let mut p2 = [0.0; 4];
        sample_psi(&mut r1, &l, gamma, &mut p1);
        sample_psi_general(&mut r2, &l, &a, &b, &mut p2);
        assert_eq!(p1, p2);
    }

    #[test]
    fn informative_prior_shifts_mass() {
        // A prior concentrated on stick 2 must raise E[Ψ_2] vs GEM.
        let l = [0u64; 5];
        let mut a = vec![1.0; 5];
        let b = vec![1.0; 5];
        a[2] = 50.0; // strongly favour stick 2
        let mut rng = Pcg64::new(6);
        let mut acc_gem = 0.0;
        let mut acc_inf = 0.0;
        let mut psi = [0.0; 5];
        for _ in 0..5000 {
            sample_psi(&mut rng, &l, 1.0, &mut psi);
            acc_gem += psi[2];
            sample_psi_general(&mut rng, &l, &a, &b, &mut psi);
            acc_inf += psi[2];
        }
        assert!(acc_inf > 1.5 * acc_gem, "{acc_inf} vs {acc_gem}");
    }

    #[test]
    fn flag_topic_takes_tail() {
        // With all sticks at prior and a tiny K*, the flag topic takes
        // visible mass; the invariant is exact sum-to-one.
        let mut rng = Pcg64::new(4);
        let l = [0u64, 0];
        let mut psi = [0.0; 2];
        sample_psi(&mut rng, &l, 1.0, &mut psi);
        assert!((psi[0] + psi[1] - 1.0).abs() < 1e-15);
        assert!(psi[1] > 0.0);
    }
}
