//! Topic assignments and shared sufficient statistics.

use crate::corpus::Corpus;
use crate::rng::Pcg64;
use crate::sparse::DocTopics;

/// Topic assignments `z` and the per-document statistic `m` they imply.
#[derive(Clone, Debug, Default)]
pub struct Assignments {
    /// `z[d][i]` = topic of token `i` in document `d`.
    pub z: Vec<Vec<u32>>,
    /// `m[d]` = sparse per-document topic counts.
    pub m: Vec<DocTopics>,
}

impl Assignments {
    /// Initialize every token to topic 0 — the paper follows Teh et al.
    /// (2006) and starts from a single topic, letting the sampler grow
    /// the topic count.
    pub fn single_topic(corpus: &Corpus) -> Self {
        let z: Vec<Vec<u32>> = corpus.docs.iter().map(|d| vec![0u32; d.len()]).collect();
        let m = z
            .iter()
            .map(|zd| {
                let mut m = DocTopics::with_capacity(4);
                for _ in 0..zd.len() {
                    m.inc(0);
                }
                m
            })
            .collect();
        Self { z, m }
    }

    /// Initialize tokens uniformly at random over `k` topics (used by
    /// LDA and by robustness tests — the HDP experiments use
    /// [`Assignments::single_topic`]).
    pub fn random(corpus: &Corpus, k: usize, rng: &mut Pcg64) -> Self {
        let mut z = Vec::with_capacity(corpus.num_docs());
        let mut m = Vec::with_capacity(corpus.num_docs());
        for doc in &corpus.docs {
            let zd: Vec<u32> =
                doc.iter().map(|_| rng.below(k as u64) as u32).collect();
            m.push(zd.iter().copied().collect::<DocTopics>());
            z.push(zd);
        }
        Self { z, m }
    }

    /// Total assigned tokens.
    pub fn total_tokens(&self) -> u64 {
        self.m.iter().map(|m| m.total() as u64).sum()
    }

    /// Tokens per topic over `num_topics` rows (the per-topic totals of
    /// the implied `n`).
    pub fn tokens_per_topic(&self, num_topics: usize) -> Vec<u64> {
        let mut out = vec![0u64; num_topics];
        for m in &self.m {
            for (k, c) in m.iter() {
                out[k as usize] += c as u64;
            }
        }
        out
    }

    /// Check the `z`/`m` consistency invariant (tests / debug).
    pub fn check_consistency(&self, corpus: &Corpus) -> anyhow::Result<()> {
        anyhow::ensure!(self.z.len() == corpus.num_docs(), "z/doc count mismatch");
        for (d, (zd, md)) in self.z.iter().zip(&self.m).enumerate() {
            anyhow::ensure!(
                zd.len() == corpus.docs[d].len(),
                "doc {d}: token count mismatch"
            );
            let rebuilt: DocTopics = zd.iter().copied().collect();
            anyhow::ensure!(
                rebuilt.total() == md.total(),
                "doc {d}: m total mismatch"
            );
            for (k, c) in rebuilt.iter() {
                anyhow::ensure!(
                    md.get(k) == c,
                    "doc {d}: m[{k}] = {} but z implies {c}",
                    md.get(k)
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::HdpCorpusSpec;

    fn corpus() -> Corpus {
        Corpus {
            docs: vec![vec![0, 1, 2], vec![1, 1]],
            vocab: vec!["a".into(), "b".into(), "c".into()],
        }
    }

    #[test]
    fn single_topic_init() {
        let c = corpus();
        let a = Assignments::single_topic(&c);
        a.check_consistency(&c).unwrap();
        assert_eq!(a.total_tokens(), 5);
        assert_eq!(a.tokens_per_topic(2), vec![5, 0]);
        assert!(a.z.iter().flatten().all(|&k| k == 0));
    }

    #[test]
    fn random_init_consistent() {
        let spec = HdpCorpusSpec {
            vocab: 100,
            topics: 4,
            gamma: 1.0,
            alpha: 1.0,
            topic_beta: 0.1,
            docs: 30,
            mean_doc_len: 20.0,
            len_sigma: 0.3,
            min_doc_len: 5,
        };
        let (c, _) = spec.generate(5);
        let mut rng = Pcg64::new(1);
        let a = Assignments::random(&c, 7, &mut rng);
        a.check_consistency(&c).unwrap();
        let tpt = a.tokens_per_topic(7);
        assert_eq!(tpt.iter().sum::<u64>(), c.num_tokens());
        assert!(tpt.iter().all(|&t| t > 0), "all 7 topics should be hit");
    }
}
