//! Model checkpointing: persist a trained sampler state and resume or
//! serve from it. The format is a compact little-endian binary holding
//! the assignments `z`, the global distribution `Ψ`, and run metadata;
//! sufficient statistics (`m`, `n`) are rebuilt on load, so the file
//! stays small and version-robust.
//!
//! Since version 2 (`HDPCKPT2`) the assignments are stored in the
//! **packed CSR layout** — `(D+1)` u64 doc offsets followed by the
//! flat `N × u32` z arena — mirroring the packed corpus format
//! ([`crate::corpus::io`]), so a checkpoint's z section can be block-read
//! (or streamed straight into a [`crate::hdp::pc::zstep::FileZ`] store)
//! without parsing per-document records. Version-1 files (per-document
//! length-prefixed vectors) are still read.
//!
//! # Crash-recovery contract
//!
//! Both writers ([`Checkpoint::save`], [`Checkpoint::save_v1`]) go
//! through [`crate::durable::atomic_write`]: temp file in the same
//! directory, data fsync, rename, parent-directory fsync — a crash at
//! *any byte offset* of a save leaves the previous checkpoint at the
//! target path intact, and the only possible debris is a uniquely
//! named `.…tmp` sibling. Every file ends in the 8-byte CRC-32
//! trailer ([`crate::durable`]); [`Checkpoint::load`] verifies it for
//! both format versions and returns `Err` — never a panic or a
//! partial snapshot — on any truncation, extension, or bit flip.
//!
//! Resumable training sits on top: the coordinator saves periodic
//! checkpoints under [`periodic_name`], and [`latest_valid`] scans a
//! directory for the newest one that still loads (deleting temp
//! partials, skipping corrupt files) so a crash between saves falls
//! back to the previous valid snapshot.
//! [`PcSampler::resume_chain`] then restores the sampler with the
//! run's original seed and iteration counter, which makes the
//! recovered chain **bit-identical** to the uninterrupted one (the
//! per-iteration RNG streams are keyed by `(seed, iteration)`).
//!
//! With the `failpoints` feature the save pipeline checks the
//! `ckpt.write` / `ckpt.sync` / `ckpt.rename` / `ckpt.dirsync` sites
//! ([`crate::fault`]); there is no retry anywhere on this path — a
//! failed save surfaces as `Err` with the old file intact.

use crate::corpus::DocAccess;
use crate::hdp::ZView;
use crate::sparse::{DocTopics, TopicWordAcc, TopicWordRows};
use anyhow::{Context, Result};
use std::io::{BufReader, Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 8] = b"HDPCKPT2";
const MAGIC_V1: &[u8; 8] = b"HDPCKPT1";

/// A serializable snapshot of a trained topic-model state.
///
/// The assignments are held **packed** — one flat `z` arena plus
/// `(D+1)` doc offsets, mirroring the on-disk v2 layout — so loading a
/// v2 file is a straight read into the final representation and a
/// packed-only resume ([`crate::hdp::pc::PcSampler::resume_chain_packed`])
/// never inflates nested `Vec<Vec<u32>>` state.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Iterations completed when the snapshot was taken.
    pub iteration: u64,
    /// Sampler name (informational).
    pub sampler: String,
    /// Global topic distribution (length = K* for the PC sampler).
    pub psi: Vec<f64>,
    /// Flat topic assignments, packed in document order.
    pub z: Vec<u32>,
    /// Doc offsets into `z` (length `D + 1`, starting at 0).
    pub z_offsets: Vec<u64>,
}

impl Checkpoint {
    /// Build from any sampler's assignments view (nested views are
    /// packed here, once, at snapshot time).
    pub fn from_z_view(
        iteration: u64,
        sampler: &str,
        psi: Vec<f64>,
        z: &ZView<'_>,
    ) -> Self {
        let (z, z_offsets) = z.to_packed();
        Self { iteration, sampler: sampler.to_string(), psi, z, z_offsets }
    }

    /// Build from nested per-document assignments (tests, the v1
    /// loader, and nested-sampler callers).
    pub fn from_nested_z(
        iteration: u64,
        sampler: &str,
        psi: Vec<f64>,
        z: &[Vec<u32>],
    ) -> Self {
        Self::from_z_view(iteration, sampler, psi, &ZView::Nested(z))
    }

    /// Number of documents covered by the snapshot.
    pub fn num_docs(&self) -> usize {
        self.z_offsets.len().saturating_sub(1)
    }

    /// Assignments of document `d`.
    pub fn doc_z(&self, d: usize) -> &[u32] {
        &self.z[self.z_offsets[d] as usize..self.z_offsets[d + 1] as usize]
    }

    /// The assignments as a borrowed [`ZView`].
    pub fn z_view(&self) -> ZView<'_> {
        ZView::Packed {
            z: std::borrow::Cow::Borrowed(&self.z),
            offsets: std::borrow::Cow::Borrowed(&self.z_offsets),
        }
    }

    /// Nested copy of the assignments (tests and nested-sampler
    /// resume; the packed-only path never calls this).
    pub fn z_nested(&self) -> Vec<Vec<u32>> {
        self.z_view().to_nested()
    }

    /// Write to `path` (parent directories created) — atomically and
    /// with the checksum trailer (module docs). The z section is the
    /// packed CSR layout (offsets + flat arena), written straight from
    /// the in-memory packed form.
    pub fn save(&self, path: &Path) -> Result<()> {
        crate::durable::atomic_write(path, &crate::durable::CKPT_SITES, |f| {
            f.write_all(MAGIC)?;
            write_u64(f, self.iteration)?;
            let name = self.sampler.as_bytes();
            write_u64(f, name.len() as u64)?;
            f.write_all(name)?;
            write_u64(f, self.psi.len() as u64)?;
            for &p in &self.psi {
                f.write_all(&p.to_le_bytes())?;
            }
            write_u64(f, self.num_docs() as u64)?;
            for &off in &self.z_offsets {
                write_u64(f, off)?;
            }
            crate::corpus::io::write_u32s(f, &self.z)?;
            Ok(())
        })
    }

    /// Read from `path` (packed version-2 layout, or the legacy
    /// version-1 per-document layout), verifying the checksum trailer.
    /// Any truncation or corruption yields `Err`, never a panic.
    pub fn load(path: &Path) -> Result<Self> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let file_len = file.metadata()?.len();
        let payload = crate::durable::payload_len(file_len, "checkpoint")
            .with_context(|| path.display().to_string())?;
        // Hash above the buffering so the digest covers exactly the
        // bytes the parser consumes.
        let mut f = crate::durable::HashingReader::new(BufReader::new(file));
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        let v2 = match &magic {
            m if m == MAGIC => true,
            m if m == MAGIC_V1 => false,
            _ => anyhow::bail!("not an hdp checkpoint: {}", path.display()),
        };
        let iteration = read_u64(&mut f)?;
        let name_len = read_u64(&mut f)? as usize;
        anyhow::ensure!(name_len < 1024, "corrupt sampler name");
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let psi_len = read_u64(&mut f)? as usize;
        anyhow::ensure!(
            psi_len as u128 * 8 <= payload as u128,
            "corrupt checkpoint: psi length {psi_len} exceeds file size"
        );
        let mut psi = Vec::with_capacity(psi_len);
        let mut b8 = [0u8; 8];
        for _ in 0..psi_len {
            f.read_exact(&mut b8)?;
            psi.push(f64::from_le_bytes(b8));
        }
        let docs = read_u64(&mut f)? as usize;
        anyhow::ensure!(
            docs as u128 * 8 <= payload as u128,
            "corrupt checkpoint: doc count {docs} exceeds file size"
        );
        let (z, z_offsets) = if v2 {
            // Packed layout: (D+1) offsets then the flat arena — read
            // straight into the final representation, no per-document
            // inflation.
            let mut offsets = Vec::with_capacity(docs + 1);
            for _ in 0..=docs {
                offsets.push(read_u64(&mut f)?);
            }
            anyhow::ensure!(
                offsets.first() == Some(&0)
                    && offsets.windows(2).all(|w| w[0] <= w[1])
                    && *offsets.last().unwrap() as u128 * 4 <= payload as u128,
                "corrupt checkpoint z offsets"
            );
            let mut flat = Vec::new();
            crate::corpus::io::read_u32s_into(
                &mut f,
                *offsets.last().unwrap() as usize,
                &mut flat,
            )?;
            (flat, offsets)
        } else {
            // Legacy per-document layout, packed on the fly.
            let mut flat: Vec<u32> = Vec::new();
            let mut offsets = Vec::with_capacity(docs + 1);
            offsets.push(0u64);
            let mut doc = Vec::new();
            for _ in 0..docs {
                let len = read_u64(&mut f)? as usize;
                anyhow::ensure!(
                    len as u128 * 4 <= payload as u128,
                    "corrupt checkpoint: doc length {len} exceeds file size"
                );
                crate::corpus::io::read_u32s_into(&mut f, len, &mut doc)?;
                flat.extend_from_slice(&doc);
                offsets.push(flat.len() as u64);
            }
            (flat, offsets)
        };
        crate::durable::verify_trailer(&mut f, payload, "checkpoint")
            .with_context(|| path.display().to_string())?;
        Ok(Self {
            iteration,
            sampler: String::from_utf8(name)?,
            psi,
            z,
            z_offsets,
        })
    }

    /// Validate the snapshot against a corpus (doc/token alignment and
    /// topic ids inside `psi`'s range). Accepts any [`DocAccess`]
    /// layout — the packed-only path validates against the arena
    /// without a nested corpus.
    pub fn validate<C: DocAccess + ?Sized>(&self, corpus: &C) -> Result<()> {
        anyhow::ensure!(
            self.num_docs() == corpus.num_docs(),
            "checkpoint docs {} != corpus docs {}",
            self.num_docs(),
            corpus.num_docs()
        );
        let k = self.psi.len() as u32;
        for d in 0..self.num_docs() {
            let zd = self.doc_z(d);
            anyhow::ensure!(
                zd.len() == corpus.doc(d).len(),
                "doc {d}: token count mismatch"
            );
            for &t in zd {
                anyhow::ensure!(t < k, "doc {d}: topic {t} out of range {k}");
            }
        }
        Ok(())
    }

    /// Rebuild the `Assignments` (nested z + m) for resuming a
    /// nested-layout sampler. The packed-only resume path
    /// ([`crate::hdp::pc::PcSampler::resume_chain_packed`]) bypasses
    /// this entirely.
    pub fn to_assignments(&self) -> super::state::Assignments {
        let z: Vec<Vec<u32>> = self.z_nested();
        let m: Vec<DocTopics> =
            z.iter().map(|zd| zd.iter().copied().collect()).collect();
        super::state::Assignments { z, m }
    }

    /// Rebuild the merged topic-word statistic `n` from the stored
    /// assignments against `corpus`' tokens. The result is the
    /// canonical sorted/merged form ([`TopicWordRows::merge_from`]),
    /// value-identical to a live sampler's `n` in the same state —
    /// which is what lets a snapshot frozen from a checkpoint
    /// ([`crate::serve::ModelSnapshot::from_checkpoint`]) predict
    /// bit-identically to one frozen off the live chain.
    pub fn topic_word_rows<C: DocAccess + ?Sized>(
        &self,
        corpus: &C,
    ) -> Result<TopicWordRows> {
        self.validate(corpus)?;
        let k = self.psi.len();
        let mut acc = TopicWordAcc::with_capacity(self.z.len() / 2 + 16);
        for d in 0..self.num_docs() {
            for (&v, &kk) in corpus.doc(d).iter().zip(self.doc_z(d)) {
                acc.add(kk, v, 1);
            }
        }
        Ok(TopicWordRows::merge_from(k, &mut [acc]))
    }

    /// Write the **legacy version-1 layout** (per-document
    /// length-prefixed z vectors) — the format PR ≤ 3 binaries
    /// produced. Kept as a public writer so format-compatibility
    /// tests can mint v1 fixtures; new code should use
    /// [`Checkpoint::save`].
    pub fn save_v1(&self, path: &Path) -> Result<()> {
        crate::durable::atomic_write(path, &crate::durable::CKPT_SITES, |f| {
            f.write_all(MAGIC_V1)?;
            write_u64(f, self.iteration)?;
            let name = self.sampler.as_bytes();
            write_u64(f, name.len() as u64)?;
            f.write_all(name)?;
            write_u64(f, self.psi.len() as u64)?;
            for &p in &self.psi {
                f.write_all(&p.to_le_bytes())?;
            }
            write_u64(f, self.num_docs() as u64)?;
            for d in 0..self.num_docs() {
                let zd = self.doc_z(d);
                write_u64(f, zd.len() as u64)?;
                crate::corpus::io::write_u32s(f, zd)?;
            }
            Ok(())
        })
    }

    /// Snapshot a **file-backed** z store at the checkpoint boundary.
    /// This is where durability for streamed chains lives:
    /// [`crate::hdp::pc::zstep::FileZ::store`] only hands blocks to
    /// the OS page cache, so this syncs the store once
    /// ([`crate::hdp::pc::zstep::FileZ::sync`], `fdatasync`) before
    /// reading the assignments back for the snapshot — one sync per
    /// checkpoint instead of one per block. The read lands directly in
    /// the packed form; no nested vectors are materialized.
    pub fn from_filez(
        iteration: u64,
        sampler: &str,
        psi: &[f64],
        z: &crate::hdp::pc::zstep::FileZ,
    ) -> Result<Self> {
        z.sync()?;
        Ok(Self {
            iteration,
            sampler: sampler.to_string(),
            psi: psi.to_vec(),
            z: z.to_flat()?,
            z_offsets: z.offsets().to_vec(),
        })
    }
}

fn write_u64<W: Write + ?Sized>(f: &mut W, x: u64) -> std::io::Result<()> {
    f.write_all(&x.to_le_bytes())
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// File name of the periodic checkpoint for `iteration`, zero-padded
/// so lexicographic order equals numeric order.
pub fn periodic_name(iteration: u64) -> String {
    format!("ckpt-{iteration:010}.ckpt")
}

/// Parse the iteration back out of a [`periodic_name`]-shaped file
/// name; `None` for anything else in the directory.
fn periodic_iteration(name: &str) -> Option<u64> {
    let digits = name.strip_prefix("ckpt-")?.strip_suffix(".ckpt")?;
    if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// Scan `dir` for the **newest loadable** periodic checkpoint.
///
/// This is the crash-recovery entry point: leftover atomic-write temp
/// partials (only possible if a process died mid-save) are deleted,
/// and any candidate that fails to load — torn, truncated, or
/// bit-flipped; the checksum trailer catches all three — is skipped
/// with a warning so the scan falls back to the previous checkpoint
/// in the chain. Returns `Ok(None)` for a missing or empty directory.
pub fn latest_valid(dir: &Path) -> Result<Option<(PathBuf, Checkpoint)>> {
    if !dir.is_dir() {
        return Ok(None);
    }
    let mut candidates: Vec<(u64, PathBuf)> = Vec::new();
    for entry in
        std::fs::read_dir(dir).with_context(|| format!("scan {}", dir.display()))?
    {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if crate::durable::is_tmp_partial(&name) {
            let _ = std::fs::remove_file(entry.path());
            continue;
        }
        if let Some(it) = periodic_iteration(&name) {
            candidates.push((it, entry.path()));
        }
    }
    candidates.sort_by(|a, b| b.0.cmp(&a.0));
    for (_, path) in candidates {
        match Checkpoint::load(&path) {
            Ok(ckpt) => return Ok(Some((path, ckpt))),
            Err(e) => eprintln!(
                "warning: skipping unloadable checkpoint {}: {e:#}",
                path.display()
            ),
        }
    }
    Ok(None)
}

impl super::pc::PcSampler {
    /// Snapshot the current state. File-backed z stores are synced at
    /// this boundary (their blocks only reach the page cache during
    /// sweeps); the snapshot itself is read through [`ZView`] in the
    /// sampler's own layout — no nested inflation on the packed path.
    pub fn checkpoint(&self) -> Checkpoint {
        self.sync_z_store();
        Checkpoint::from_z_view(
            crate::hdp::Trainer::iterations_done(self) as u64,
            "pc-hdp",
            self.psi().to_vec(),
            &crate::hdp::Trainer::z_view(self),
        )
    }

    /// Resume from a snapshot: rebuilds `m`/`n` and reuses the stored
    /// `Ψ` implicitly through the next `l`/`Ψ` step (the chain is a
    /// valid continuation of the checkpointed posterior state).
    pub fn resume(
        corpus: std::sync::Arc<crate::corpus::Corpus>,
        cfg: crate::config::HdpConfig,
        threads: usize,
        seed: u64,
        ckpt: &Checkpoint,
    ) -> Result<Self> {
        ckpt.validate(&corpus)?;
        anyhow::ensure!(
            ckpt.psi.len() == cfg.k_max,
            "checkpoint K* {} != cfg.k_max {}",
            ckpt.psi.len(),
            cfg.k_max
        );
        let mut s = Self::with_assignments(
            corpus,
            cfg,
            threads,
            seed ^ ckpt.iteration, // fresh stream offset past the old chain
            ckpt.to_assignments(),
        )?;
        s.set_psi(&ckpt.psi);
        Ok(s)
    }

    /// Resume the **same chain** from a checkpoint: reconstruct the
    /// sampler with the run's *original* `seed` and restore the
    /// iteration counter, so the per-iteration RNG streams (keyed by
    /// `(seed, iteration)`) continue exactly where the checkpointed
    /// process left off. Iteration `i + 1` after a crash-resume draws
    /// the same randomness as iteration `i + 1` of the uninterrupted
    /// run — recovery is bit-identical. Use [`PcSampler::resume`]
    /// instead when a *fresh* continuation stream is wanted.
    pub fn resume_chain(
        corpus: std::sync::Arc<crate::corpus::Corpus>,
        cfg: crate::config::HdpConfig,
        threads: usize,
        seed: u64,
        ckpt: &Checkpoint,
    ) -> Result<Self> {
        ckpt.validate(&*corpus)?;
        anyhow::ensure!(
            ckpt.psi.len() == cfg.k_max,
            "checkpoint K* {} != cfg.k_max {}",
            ckpt.psi.len(),
            cfg.k_max
        );
        let mut s =
            Self::with_assignments(corpus, cfg, threads, seed, ckpt.to_assignments())?;
        s.set_psi(&ckpt.psi);
        s.set_resume_point(ckpt.iteration);
        Ok(s)
    }

    /// [`PcSampler::resume_chain`] for the **packed-only** path: the
    /// checkpoint's flat z lands straight in the sampler's arena store
    /// (or, with `z_file`, a file-backed
    /// [`crate::hdp::pc::zstep::FileZ`] store) — no nested corpus and
    /// no nested z are ever materialized. The recovered chain is
    /// bit-identical to the uninterrupted one, and to a nested
    /// [`PcSampler::resume_chain`] of the same checkpoint.
    pub fn resume_chain_packed(
        packed: std::sync::Arc<crate::corpus::PackedCorpus>,
        cfg: crate::config::HdpConfig,
        threads: usize,
        seed: u64,
        ckpt: &Checkpoint,
        z_file: Option<&Path>,
    ) -> Result<Self> {
        ckpt.validate(&*packed)?;
        anyhow::ensure!(
            ckpt.psi.len() == cfg.k_max,
            "checkpoint K* {} != cfg.k_max {}",
            ckpt.psi.len(),
            cfg.k_max
        );
        let mut s =
            Self::from_packed_with_z(packed, cfg, threads, seed, ckpt.z.clone())?;
        if let Some(path) = z_file {
            s.move_z_to_file(path)?;
        }
        s.set_psi(&ckpt.psi);
        s.set_resume_point(ckpt.iteration);
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HdpConfig;
    use crate::corpus::synthetic::HdpCorpusSpec;
    use crate::corpus::Corpus;
    use crate::hdp::pc::PcSampler;
    use crate::hdp::Trainer;
    use std::sync::Arc;

    fn corpus() -> Arc<Corpus> {
        let (c, _) = HdpCorpusSpec {
            vocab: 150,
            topics: 4,
            gamma: 1.0,
            alpha: 1.0,
            topic_beta: 0.05,
            docs: 40,
            mean_doc_len: 25.0,
            len_sigma: 0.3,
            min_doc_len: 8,
        }
        .generate(71);
        Arc::new(c)
    }

    #[test]
    fn roundtrip_exact() {
        let c = corpus();
        let cfg = HdpConfig { k_max: 32, ..Default::default() };
        let mut s = PcSampler::new(c.clone(), cfg, 1, 1).unwrap();
        for _ in 0..8 {
            s.step().unwrap();
        }
        let ckpt = s.checkpoint();
        let path = std::env::temp_dir().join("hdp_ckpt_test/model.ckpt");
        ckpt.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ckpt);
        back.validate(&c).unwrap();
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn resume_continues_training() {
        let c = corpus();
        let cfg = HdpConfig { k_max: 32, ..Default::default() };
        let mut s = PcSampler::new(c.clone(), cfg, 1, 2).unwrap();
        for _ in 0..10 {
            s.step().unwrap();
        }
        let ll_before = s.diagnostics().log_likelihood;
        let ckpt = s.checkpoint();
        let mut resumed = PcSampler::resume(c.clone(), cfg, 2, 99, &ckpt).unwrap();
        // The resumed state reproduces the checkpoint exactly...
        assert_eq!(resumed.psi(), &ckpt.psi[..]);
        assert_eq!(resumed.z_nested(), ckpt.z_nested());
        let d0 = resumed.diagnostics();
        assert!((d0.log_likelihood - ll_before).abs() < 1e-6);
        // ...and keeps training sanely.
        for _ in 0..5 {
            resumed.step().unwrap();
        }
        let d = resumed.diagnostics();
        assert_eq!(d.total_tokens, c.num_tokens());
        assert!(d.log_likelihood.is_finite());
    }

    #[test]
    fn rejects_mismatched_corpus() {
        let c = corpus();
        let cfg = HdpConfig { k_max: 32, ..Default::default() };
        let s = PcSampler::new(c, cfg, 1, 3).unwrap();
        let ckpt = s.checkpoint();
        let (other, _) = HdpCorpusSpec {
            vocab: 150,
            topics: 4,
            gamma: 1.0,
            alpha: 1.0,
            topic_beta: 0.05,
            docs: 10,
            mean_doc_len: 25.0,
            len_sigma: 0.3,
            min_doc_len: 8,
        }
        .generate(72);
        assert!(ckpt.validate(&other).is_err());
    }

    #[test]
    fn from_filez_syncs_and_roundtrips() {
        // Checkpointing a streamed chain: the file-backed z store is
        // synced at the boundary and its contents land in the snapshot
        // exactly (including the empty doc).
        use crate::hdp::pc::zstep::FileZ;
        let z: Vec<Vec<u32>> = vec![vec![0, 1, 1, 2], vec![], vec![2, 0]];
        let dir = std::env::temp_dir().join("hdp_ckpt_filez_test");
        let zfile = FileZ::from_nested(&dir.join("z.bin"), &z).unwrap();
        let ckpt =
            Checkpoint::from_filez(7, "pc-hdp", &[0.5, 0.25, 0.25], &zfile).unwrap();
        // The snapshot lands directly in the packed layout...
        assert_eq!(ckpt.z, vec![0, 1, 1, 2, 2, 0]);
        assert_eq!(ckpt.z_offsets, vec![0, 4, 4, 6]);
        // ...and round-trips to the nested shape (empty doc retained).
        assert_eq!(ckpt.z_nested(), z);
        assert_eq!(ckpt.iteration, 7);
        let path = dir.join("model.ckpt");
        ckpt.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), ckpt);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join("hdp_ckpt_test2/garbage.ckpt");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, b"nope").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    fn sample_ckpt() -> Checkpoint {
        // Includes an empty document — the packed layout must retain
        // it as a zero-length range.
        Checkpoint::from_nested_z(
            12,
            "pc-hdp",
            vec![0.5, 0.25, 0.25],
            &[vec![0, 1, 1, 2], vec![], vec![2, 0]],
        )
    }

    #[test]
    fn save_appends_trailer_and_all_corruptions_are_rejected() {
        let dir = std::env::temp_dir().join("hdp_ckpt_trailer_test");
        std::fs::create_dir_all(&dir).unwrap();
        let ckpt = sample_ckpt();
        let p = dir.join("m.ckpt");
        ckpt.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        let n = bytes.len();
        // The file ends in the checksum trailer and the stored CRC
        // matches a recomputation over the payload.
        assert_eq!(&bytes[n - 4..], crate::durable::TRAILER_TAG);
        let stored = u32::from_le_bytes(bytes[n - 8..n - 4].try_into().unwrap());
        assert_eq!(stored, crate::durable::crc32(&bytes[..n - 8]));
        let bad_p = dir.join("bad.ckpt");
        // Every single-byte flip is rejected.
        for i in 0..n {
            let mut bad = bytes.clone();
            bad[i] ^= 0x40;
            std::fs::write(&bad_p, &bad).unwrap();
            assert!(Checkpoint::load(&bad_p).is_err(), "flip at byte {i} accepted");
        }
        // Every strict prefix is rejected — including the one that
        // cuts exactly the trailer (a payload-perfect torn write).
        for cut in 0..n {
            std::fs::write(&bad_p, &bytes[..cut]).unwrap();
            assert!(Checkpoint::load(&bad_p).is_err(), "prefix {cut} accepted");
        }
        // Extension is rejected too.
        let mut ext = bytes.clone();
        ext.push(0);
        std::fs::write(&bad_p, &ext).unwrap();
        assert!(Checkpoint::load(&bad_p).is_err(), "extended file accepted");
        // The v1 compat writer gets the same protection.
        let p1 = dir.join("m1.ckpt");
        ckpt.save_v1(&p1).unwrap();
        let bytes1 = std::fs::read(&p1).unwrap();
        assert_eq!(&bytes1[bytes1.len() - 4..], crate::durable::TRAILER_TAG);
        for cut in [bytes1.len() - 1, bytes1.len() - 8, bytes1.len() / 2] {
            std::fs::write(&bad_p, &bytes1[..cut]).unwrap();
            assert!(Checkpoint::load(&bad_p).is_err(), "v1 prefix {cut} accepted");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn latest_valid_picks_newest_and_skips_corrupt() {
        let dir = std::env::temp_dir().join("hdp_ckpt_latest_test");
        std::fs::remove_dir_all(&dir).ok();
        // Missing directory is a clean "nothing to resume".
        assert!(latest_valid(&dir).unwrap().is_none());
        std::fs::create_dir_all(&dir).unwrap();
        let mut c3 = sample_ckpt();
        c3.iteration = 3;
        c3.save(&dir.join(periodic_name(3))).unwrap();
        let mut c6 = sample_ckpt();
        c6.iteration = 6;
        c6.save(&dir.join(periodic_name(6))).unwrap();
        // Newest valid checkpoint wins.
        let (p, got) = latest_valid(&dir).unwrap().unwrap();
        assert_eq!(p.file_name().unwrap().to_str().unwrap(), periodic_name(6));
        assert_eq!(got, c6);
        // Tear the newest; the scan falls back to the previous one and
        // sweeps crash-debris temp partials.
        let bytes = std::fs::read(dir.join(periodic_name(6))).unwrap();
        std::fs::write(dir.join(periodic_name(6)), &bytes[..bytes.len() - 3]).unwrap();
        let tmp = dir.join(".ckpt-0000000009.ckpt.123-0.tmp");
        std::fs::write(&tmp, b"partial").unwrap();
        std::fs::write(dir.join("notes.txt"), b"ignored").unwrap();
        let (_, got) = latest_valid(&dir).unwrap().unwrap();
        assert_eq!(got, c3);
        assert!(!tmp.exists(), "temp partial not cleaned up");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_chain_restores_iteration_and_state() {
        let c = corpus();
        let cfg = HdpConfig { k_max: 32, ..Default::default() };
        let mut s = PcSampler::new(c.clone(), cfg, 1, 5).unwrap();
        for _ in 0..4 {
            s.step().unwrap();
        }
        let ckpt = s.checkpoint();
        let resumed = PcSampler::resume_chain(c.clone(), cfg, 1, 5, &ckpt).unwrap();
        assert_eq!(Trainer::iterations_done(&resumed), 4);
        assert_eq!(resumed.psi(), &ckpt.psi[..]);
        assert_eq!(resumed.z_nested(), ckpt.z_nested());
    }

    #[test]
    fn resume_chain_packed_is_bit_identical_to_nested() {
        // The packed-only resume (arena and file-backed z) must
        // continue the exact chain the nested resume continues.
        let c = corpus();
        let cfg = HdpConfig { k_max: 32, ..Default::default() };
        let mut s = PcSampler::new(c.clone(), cfg, 2, 11).unwrap();
        for _ in 0..4 {
            s.step().unwrap();
        }
        let ckpt = s.checkpoint();
        let packed = Arc::new(c.to_packed());
        let mut nested = PcSampler::resume_chain(c.clone(), cfg, 2, 11, &ckpt).unwrap();
        let mut arena =
            PcSampler::resume_chain_packed(packed.clone(), cfg, 2, 11, &ckpt, None)
                .unwrap();
        let dir = std::env::temp_dir().join("hdp_ckpt_packed_resume_test");
        let mut filed = PcSampler::resume_chain_packed(
            packed,
            cfg,
            2,
            11,
            &ckpt,
            Some(&dir.join("z.bin")),
        )
        .unwrap();
        assert_eq!(arena.z_mode(), "arena");
        assert_eq!(filed.z_mode(), "file");
        for _ in 0..3 {
            nested.step().unwrap();
            arena.step().unwrap();
            filed.step().unwrap();
        }
        assert_eq!(nested.z_nested(), arena.z_nested());
        assert_eq!(nested.z_nested(), filed.z_nested());
        assert_eq!(nested.psi(), arena.psi());
        assert_eq!(nested.psi(), filed.psi());
        assert_eq!(
            nested.diagnostics().log_likelihood.to_bits(),
            arena.diagnostics().log_likelihood.to_bits()
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn packed_layout_roundtrips_and_v1_still_loads() {
        let dir = std::env::temp_dir().join("hdp_ckpt_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let ckpt = sample_ckpt();
        // v2 (packed) roundtrip.
        let p2 = dir.join("v2.ckpt");
        ckpt.save(&p2).unwrap();
        assert_eq!(Checkpoint::load(&p2).unwrap(), ckpt);
        // The file really is the packed layout: magic + the z section
        // is offsets [0,4,4,6] followed by the flat arena.
        let bytes = std::fs::read(&p2).unwrap();
        assert_eq!(&bytes[..8], b"HDPCKPT2");
        // Legacy v1 (the public compat writer) loads to the same
        // snapshot.
        let p1 = dir.join("v1.ckpt");
        ckpt.save_v1(&p1).unwrap();
        let bytes1 = std::fs::read(&p1).unwrap();
        assert_eq!(&bytes1[..8], b"HDPCKPT1");
        assert_eq!(Checkpoint::load(&p1).unwrap(), ckpt);
        // Unknown version is rejected.
        let mut bad = bytes.clone();
        bad[7] = b'9';
        let pbad = dir.join("bad.ckpt");
        std::fs::write(&pbad, &bad).unwrap();
        assert!(Checkpoint::load(&pbad).is_err());
        // Truncations never panic.
        for cut in [0, 7, 8, 20, bytes.len() / 2, bytes.len() - 1] {
            std::fs::write(&pbad, &bytes[..cut]).unwrap();
            assert!(Checkpoint::load(&pbad).is_err(), "prefix {cut} accepted");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
