//! Model checkpointing: persist a trained sampler state and resume or
//! serve from it. The format is a compact little-endian binary holding
//! the assignments `z`, the global distribution `Ψ`, and run metadata;
//! sufficient statistics (`m`, `n`) are rebuilt on load, so the file
//! stays small and version-robust.

use crate::corpus::Corpus;
use crate::sparse::DocTopics;
use anyhow::{Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"HDPCKPT1";

/// A serializable snapshot of a trained topic-model state.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Iterations completed when the snapshot was taken.
    pub iteration: u64,
    /// Sampler name (informational).
    pub sampler: String,
    /// Global topic distribution (length = K* for the PC sampler).
    pub psi: Vec<f64>,
    /// Topic assignments per document.
    pub z: Vec<Vec<u32>>,
}

impl Checkpoint {
    /// Write to `path` (parent directories created).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        write_u64(&mut f, self.iteration)?;
        let name = self.sampler.as_bytes();
        write_u64(&mut f, name.len() as u64)?;
        f.write_all(name)?;
        write_u64(&mut f, self.psi.len() as u64)?;
        for &p in &self.psi {
            f.write_all(&p.to_le_bytes())?;
        }
        write_u64(&mut f, self.z.len() as u64)?;
        for zd in &self.z {
            write_u64(&mut f, zd.len() as u64)?;
            for &k in zd {
                f.write_all(&k.to_le_bytes())?;
            }
        }
        f.flush()?;
        Ok(())
    }

    /// Read from `path`.
    pub fn load(path: &Path) -> Result<Self> {
        let mut f = BufReader::new(
            std::fs::File::open(path)
                .with_context(|| format!("open {}", path.display()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not an hdp checkpoint: {}", path.display());
        let iteration = read_u64(&mut f)?;
        let name_len = read_u64(&mut f)? as usize;
        anyhow::ensure!(name_len < 1024, "corrupt sampler name");
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let psi_len = read_u64(&mut f)? as usize;
        let mut psi = Vec::with_capacity(psi_len);
        let mut b8 = [0u8; 8];
        for _ in 0..psi_len {
            f.read_exact(&mut b8)?;
            psi.push(f64::from_le_bytes(b8));
        }
        let docs = read_u64(&mut f)? as usize;
        let mut z = Vec::with_capacity(docs);
        for _ in 0..docs {
            let len = read_u64(&mut f)? as usize;
            let mut buf = vec![0u8; len * 4];
            f.read_exact(&mut buf)?;
            z.push(
                buf.chunks_exact(4)
                    .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            );
        }
        Ok(Self {
            iteration,
            sampler: String::from_utf8(name)?,
            psi,
            z,
        })
    }

    /// Validate the snapshot against a corpus (doc/token alignment and
    /// topic ids inside `psi`'s range).
    pub fn validate(&self, corpus: &Corpus) -> Result<()> {
        anyhow::ensure!(
            self.z.len() == corpus.num_docs(),
            "checkpoint docs {} != corpus docs {}",
            self.z.len(),
            corpus.num_docs()
        );
        let k = self.psi.len() as u32;
        for (d, (zd, doc)) in self.z.iter().zip(&corpus.docs).enumerate() {
            anyhow::ensure!(zd.len() == doc.len(), "doc {d}: token count mismatch");
            for &t in zd {
                anyhow::ensure!(t < k, "doc {d}: topic {t} out of range {k}");
            }
        }
        Ok(())
    }

    /// Rebuild the `Assignments` (z + m) for resuming a sampler.
    pub fn to_assignments(&self) -> super::state::Assignments {
        let m: Vec<DocTopics> =
            self.z.iter().map(|zd| zd.iter().copied().collect()).collect();
        super::state::Assignments { z: self.z.clone(), m }
    }
}

fn write_u64(f: &mut impl Write, x: u64) -> std::io::Result<()> {
    f.write_all(&x.to_le_bytes())
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

impl super::pc::PcSampler {
    /// Snapshot the current state.
    pub fn checkpoint(&self) -> Checkpoint {
        Checkpoint {
            iteration: crate::hdp::Trainer::iterations_done(self) as u64,
            sampler: "pc-hdp".to_string(),
            psi: self.psi().to_vec(),
            z: crate::hdp::Trainer::assignments(self).to_vec(),
        }
    }

    /// Resume from a snapshot: rebuilds `m`/`n` and reuses the stored
    /// `Ψ` implicitly through the next `l`/`Ψ` step (the chain is a
    /// valid continuation of the checkpointed posterior state).
    pub fn resume(
        corpus: std::sync::Arc<Corpus>,
        cfg: crate::config::HdpConfig,
        threads: usize,
        seed: u64,
        ckpt: &Checkpoint,
    ) -> Result<Self> {
        ckpt.validate(&corpus)?;
        anyhow::ensure!(
            ckpt.psi.len() == cfg.k_max,
            "checkpoint K* {} != cfg.k_max {}",
            ckpt.psi.len(),
            cfg.k_max
        );
        let mut s = Self::with_assignments(
            corpus,
            cfg,
            threads,
            seed ^ ckpt.iteration, // fresh stream offset past the old chain
            ckpt.to_assignments(),
        )?;
        s.set_psi(&ckpt.psi);
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HdpConfig;
    use crate::corpus::synthetic::HdpCorpusSpec;
    use crate::hdp::pc::PcSampler;
    use crate::hdp::Trainer;
    use std::sync::Arc;

    fn corpus() -> Arc<Corpus> {
        let (c, _) = HdpCorpusSpec {
            vocab: 150,
            topics: 4,
            gamma: 1.0,
            alpha: 1.0,
            topic_beta: 0.05,
            docs: 40,
            mean_doc_len: 25.0,
            len_sigma: 0.3,
            min_doc_len: 8,
        }
        .generate(71);
        Arc::new(c)
    }

    #[test]
    fn roundtrip_exact() {
        let c = corpus();
        let cfg = HdpConfig { k_max: 32, ..Default::default() };
        let mut s = PcSampler::new(c.clone(), cfg, 1, 1).unwrap();
        for _ in 0..8 {
            s.step().unwrap();
        }
        let ckpt = s.checkpoint();
        let path = std::env::temp_dir().join("hdp_ckpt_test/model.ckpt");
        ckpt.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ckpt);
        back.validate(&c).unwrap();
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn resume_continues_training() {
        let c = corpus();
        let cfg = HdpConfig { k_max: 32, ..Default::default() };
        let mut s = PcSampler::new(c.clone(), cfg, 1, 2).unwrap();
        for _ in 0..10 {
            s.step().unwrap();
        }
        let ll_before = s.diagnostics().log_likelihood;
        let ckpt = s.checkpoint();
        let mut resumed = PcSampler::resume(c.clone(), cfg, 2, 99, &ckpt).unwrap();
        // The resumed state reproduces the checkpoint exactly...
        assert_eq!(resumed.psi(), &ckpt.psi[..]);
        assert_eq!(Trainer::assignments(&resumed), &ckpt.z[..]);
        let d0 = resumed.diagnostics();
        assert!((d0.log_likelihood - ll_before).abs() < 1e-6);
        // ...and keeps training sanely.
        for _ in 0..5 {
            resumed.step().unwrap();
        }
        let d = resumed.diagnostics();
        assert_eq!(d.total_tokens, c.num_tokens());
        assert!(d.log_likelihood.is_finite());
    }

    #[test]
    fn rejects_mismatched_corpus() {
        let c = corpus();
        let cfg = HdpConfig { k_max: 32, ..Default::default() };
        let s = PcSampler::new(c, cfg, 1, 3).unwrap();
        let ckpt = s.checkpoint();
        let (other, _) = HdpCorpusSpec {
            vocab: 150,
            topics: 4,
            gamma: 1.0,
            alpha: 1.0,
            topic_beta: 0.05,
            docs: 10,
            mean_doc_len: 25.0,
            len_sigma: 0.3,
            min_doc_len: 8,
        }
        .generate(72);
        assert!(ckpt.validate(&other).is_err());
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join("hdp_ckpt_test2/garbage.ckpt");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, b"nope").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }
}
