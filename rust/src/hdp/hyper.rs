//! Hyperparameter inference — the paper's §4 extensions.
//!
//! * [`sample_alpha`] / [`sample_gamma`] — Gibbs updates for the
//!   document-level concentration `α` and the GEM concentration `γ`
//!   under Gamma priors, via the auxiliary-variable schemes of Teh et
//!   al. (2006, §A.1) / Escobar & West (1995). Both consume only the
//!   sufficient statistics the sparse sampler already maintains
//!   (per-document token counts and the table-count statistic `l`), so
//!   they add O(D + K) per iteration.
//! * [`super::pc::psi::sample_psi_general`] — the informative
//!   generalized-Dirichlet prior for `Ψ` suggested by §4 "one could
//!   consider an informative prior for Ψ in lieu of GEM(γ)".

use crate::rng::{dist, Pcg64};

/// Gamma(shape `a`, rate `b`) prior on a concentration parameter.
#[derive(Clone, Copy, Debug)]
pub struct GammaPrior {
    pub shape: f64,
    pub rate: f64,
}

impl Default for GammaPrior {
    /// A vague prior (shape 1, rate 1).
    fn default() -> Self {
        Self { shape: 1.0, rate: 1.0 }
    }
}

/// Resample the document-level DP concentration `α`.
///
/// `doc_tokens[j]` = `N_j` (tokens in document j), `total_tables` =
/// `Σ_k l_k` (the paper's auxiliary statistic: total number of draws
/// from Ψ). Teh et al. (2006) §A.1: per document draw
/// `w_j ~ Beta(α+1, N_j)`, `s_j ~ Ber(N_j / (N_j + α))`, then
/// `α ~ Gamma(a + T − Σs_j, b − Σ log w_j)`.
pub fn sample_alpha(
    rng: &mut Pcg64,
    alpha: f64,
    doc_tokens: &[u32],
    total_tables: u64,
    prior: GammaPrior,
) -> f64 {
    let mut sum_log_w = 0.0f64;
    let mut sum_s = 0u64;
    for &nj in doc_tokens {
        if nj == 0 {
            continue;
        }
        let nj = nj as f64;
        let w = dist::beta(rng, alpha + 1.0, nj);
        sum_log_w += w.max(1e-300).ln();
        if rng.bernoulli(nj / (nj + alpha)) {
            sum_s += 1;
        }
    }
    let shape = prior.shape + total_tables as f64 - sum_s as f64;
    let rate = prior.rate - sum_log_w;
    // Guard degenerate corners (empty corpus): fall back to the prior.
    if shape <= 0.0 || rate <= 0.0 {
        return dist::gamma_scaled(rng, prior.shape, 1.0 / prior.rate);
    }
    dist::gamma_scaled(rng, shape, 1.0 / rate)
}

/// Resample the GEM concentration `γ` (Escobar & West 1995).
///
/// `active_topics` = K (current number of represented topics),
/// `total_tables` = `Σ_k l_k`. Draw `η ~ Beta(γ+1, T)`, then γ from a
/// two-component Gamma mixture with odds
/// `(a + K − 1) / (T·(b − log η))`.
pub fn sample_gamma(
    rng: &mut Pcg64,
    gamma: f64,
    active_topics: usize,
    total_tables: u64,
    prior: GammaPrior,
) -> f64 {
    if total_tables == 0 || active_topics == 0 {
        return dist::gamma_scaled(rng, prior.shape, 1.0 / prior.rate);
    }
    let t = total_tables as f64;
    let k = active_topics as f64;
    let eta = dist::beta(rng, gamma + 1.0, t);
    let rate = prior.rate - eta.max(1e-300).ln();
    let odds = (prior.shape + k - 1.0) / (t * rate);
    let shape = if rng.bernoulli(odds / (1.0 + odds)) {
        prior.shape + k
    } else {
        prior.shape + k - 1.0
    };
    dist::gamma_scaled(rng, shape.max(1e-3), 1.0 / rate)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_stays_positive_and_stable() {
        let mut rng = Pcg64::new(1);
        let doc_tokens: Vec<u32> = (0..200).map(|i| 20 + (i % 50) as u32).collect();
        let mut alpha = 1.0;
        for _ in 0..200 {
            alpha = sample_alpha(&mut rng, alpha, &doc_tokens, 600, GammaPrior::default());
            assert!(alpha.is_finite() && alpha > 0.0, "alpha {alpha}");
            assert!(alpha < 100.0, "alpha runaway {alpha}");
        }
    }

    #[test]
    fn alpha_tracks_table_count() {
        // More tables (relative to the same token counts) must push α up.
        let doc_tokens: Vec<u32> = vec![50; 300];
        let run = |tables: u64, seed: u64| {
            let mut rng = Pcg64::new(seed);
            let mut a = 1.0;
            let mut acc = 0.0;
            for i in 0..400 {
                a = sample_alpha(&mut rng, a, &doc_tokens, tables, GammaPrior::default());
                if i >= 200 {
                    acc += a;
                }
            }
            acc / 200.0
        };
        let low = run(350, 2);
        let high = run(3000, 2);
        assert!(
            high > 2.0 * low,
            "α should grow with table count: {low} vs {high}"
        );
    }

    #[test]
    fn gamma_tracks_topic_count() {
        let run = |k: usize, seed: u64| {
            let mut rng = Pcg64::new(seed);
            let mut g = 1.0;
            let mut acc = 0.0;
            for i in 0..400 {
                g = sample_gamma(&mut rng, g, k, 5000, GammaPrior::default());
                assert!(g.is_finite() && g > 0.0);
                if i >= 200 {
                    acc += g;
                }
            }
            acc / 200.0
        };
        let few = run(5, 3);
        let many = run(200, 3);
        assert!(many > 3.0 * few, "γ should grow with K: {few} vs {many}");
    }

    #[test]
    fn degenerate_inputs_fall_back_to_prior() {
        let mut rng = Pcg64::new(4);
        let g = sample_gamma(&mut rng, 1.0, 0, 0, GammaPrior::default());
        assert!(g > 0.0 && g.is_finite());
        let a = sample_alpha(&mut rng, 1.0, &[], 0, GammaPrior::default());
        assert!(a > 0.0 && a.is_finite());
    }
}
