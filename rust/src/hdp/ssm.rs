//! A simplified **subcluster split-merge** HDP sampler in the style of
//! Chang & Fisher (2014) — the paper's large-scale baseline
//! (Fig 1 g–i).
//!
//! Substitution note (DESIGN.md): the reference implementation is a
//! sizeable C++ system; what the comparison in the paper needs are its
//! *structural* properties, which this implementation shares:
//!
//! * topics change **only** through split/merge Metropolis–Hastings
//!   moves, so the topic count grows by at most a few per iteration —
//!   vs the partially collapsed sampler which can create many topics
//!   per sweep;
//! * every live topic carries **two subclusters** that are resampled
//!   alongside `z` and act as split proposals;
//! * the z sweep is dense over all live topics (no sparsity
//!   exploitation), so per-iteration cost grows with K — the behaviour
//!   visible in Fig 1(i);
//! * split/merge acceptance uses the collapsed Dirichlet-multinomial
//!   marginal likelihood with a CRP(γ) prior factor (Jain & Neal 2004
//!   style), so its log-likelihood values are *not* directly comparable
//!   to the other samplers — matching the caveat in the paper's §3.

use crate::config::HdpConfig;
use crate::corpus::Corpus;
use crate::diagnostics::loglik;
use crate::rng::special::ln_gamma;
use crate::rng::{dist, Pcg64};
use crate::sparse::DocCountHist;

use super::pc::lstep;
use super::state::Assignments;
use super::{DiagSnapshot, Trainer, ZView};

/// The simplified subcluster split-merge sampler.
pub struct SsmSampler {
    corpus: std::sync::Arc<Corpus>,
    cfg: HdpConfig,
    rng: Pcg64,
    assign: Assignments,
    /// Subcluster flag per token (false = left, true = right).
    sub: Vec<Vec<bool>>,
    /// Dense per-slot topic-word counts.
    n: Vec<Vec<u32>>,
    nk: Vec<u64>,
    /// Subcluster counts: `nsub[slot][s][v]`.
    nsub: Vec<[Vec<u32>; 2]>,
    nsub_tot: Vec<[u64; 2]>,
    psi: Vec<f64>,
    weights: Vec<f64>,
    iteration: usize,
    /// Split/merge acceptance counters (diagnostics).
    pub splits_accepted: u64,
    pub merges_accepted: u64,
}

impl SsmSampler {
    /// Create with single-topic initialization and random subclusters.
    pub fn new(corpus: std::sync::Arc<Corpus>, cfg: HdpConfig, seed: u64) -> anyhow::Result<Self> {
        cfg.validate()?;
        let assign = Assignments::single_topic(&corpus);
        let mut rng = Pcg64::with_stream(seed, 0x55a);
        let sub: Vec<Vec<bool>> = corpus
            .docs
            .iter()
            .map(|d| d.iter().map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        let mut s = Self {
            corpus,
            cfg,
            rng,
            assign,
            sub,
            n: Vec::new(),
            nk: Vec::new(),
            nsub: Vec::new(),
            nsub_tot: Vec::new(),
            psi: vec![1.0],
            weights: Vec::with_capacity(64),
            iteration: 0,
            splits_accepted: 0,
            merges_accepted: 0,
        };
        s.rebuild();
        Ok(s)
    }

    /// Live topic count.
    pub fn active_topics(&self) -> usize {
        self.nk.iter().filter(|&&c| c > 0).count()
    }

    /// Rebuild all count structures from `z` and `sub` (called after
    /// structural split/merge rewrites).
    fn rebuild(&mut self) {
        let slots = self
            .assign
            .z
            .iter()
            .flatten()
            .map(|&k| k as usize + 1)
            .max()
            .unwrap_or(1);
        let v = self.corpus.vocab_size();
        self.n = vec![vec![0u32; v]; slots];
        self.nk = vec![0u64; slots];
        self.nsub = (0..slots).map(|_| [vec![0u32; v], vec![0u32; v]]).collect();
        self.nsub_tot = vec![[0u64; 2]; slots];
        for (d, doc) in self.corpus.docs.iter().enumerate() {
            for (i, &w) in doc.iter().enumerate() {
                let k = self.assign.z[d][i] as usize;
                let s = self.sub[d][i] as usize;
                self.n[k][w as usize] += 1;
                self.nk[k] += 1;
                self.nsub[k][s][w as usize] += 1;
                self.nsub_tot[k][s] += 1;
            }
        }
        // Rebuild m as well.
        for (d, zd) in self.assign.z.iter().enumerate() {
            self.assign.m[d] = zd.iter().copied().collect();
        }
        // Keep ψ aligned with the slot table (extra tail slots can only
        // be dead after a merge; missing ones appear after a split and
        // were pre-assigned by the proposer).
        self.psi.resize(slots, 0.0);
    }

    /// Dense restricted z + subcluster sweep.
    ///
    /// The subcluster conditional carries a *document-level* count term
    /// (`msub`): without it, sub assignments ignore document structure,
    /// proposed splits cut through documents, and the Pólya-urn side of
    /// the acceptance ratio vetoes every split.
    fn sweep(&mut self) {
        let vb = self.corpus.vocab_size() as f64 * self.cfg.beta;
        let half_gamma = self.cfg.gamma / 2.0;
        // Per-document sub counts for the current document:
        // msub[s] over topics.
        let mut msub: [crate::sparse::DocTopics; 2] = [
            crate::sparse::DocTopics::with_capacity(16),
            crate::sparse::DocTopics::with_capacity(16),
        ];
        for d in 0..self.corpus.docs.len() {
            msub[0].clear();
            msub[1].clear();
            for (i, &k) in self.assign.z[d].iter().enumerate() {
                msub[self.sub[d][i] as usize].inc(k);
            }
            for i in 0..self.corpus.docs[d].len() {
                let v = self.corpus.docs[d][i] as usize;
                let kold = self.assign.z[d][i] as usize;
                let sold = self.sub[d][i] as usize;
                // remove
                self.assign.m[d].dec(kold as u32);
                msub[sold].dec(kold as u32);
                self.n[kold][v] -= 1;
                self.nk[kold] -= 1;
                self.nsub[kold][sold][v] -= 1;
                self.nsub_tot[kold][sold] -= 1;
                // dense restricted conditional over live slots
                let slots = self.nk.len();
                self.weights.clear();
                self.weights.resize(slots, 0.0);
                for k in 0..slots {
                    if self.nk[k] == 0 && self.psi[k] <= 0.0 {
                        continue;
                    }
                    let doc_side = self.assign.m[d].get(k as u32) as f64
                        + self.cfg.alpha * self.psi[k];
                    let word_side = (self.n[k][v] as f64 + self.cfg.beta)
                        / (self.nk[k] as f64 + vb);
                    self.weights[k] = doc_side * word_side;
                }
                let knew = dist::categorical(&mut self.rng, &self.weights);
                // subcluster conditional within knew: document count ×
                // word likelihood (the doc term is what aligns splits
                // with document boundaries).
                let mut ws = [0.0f64; 2];
                for s in 0..2 {
                    ws[s] = (msub[s].get(knew as u32) as f64 + half_gamma)
                        * (self.nsub[knew][s][v] as f64 + self.cfg.beta)
                        / (self.nsub_tot[knew][s] as f64 + vb);
                }
                let snew = usize::from(self.rng.f64() * (ws[0] + ws[1]) >= ws[0]);
                // add
                self.assign.z[d][i] = knew as u32;
                self.sub[d][i] = snew == 1;
                self.assign.m[d].inc(knew as u32);
                msub[snew].inc(knew as u32);
                self.n[knew][v] += 1;
                self.nk[knew] += 1;
                self.nsub[knew][snew][v] += 1;
                self.nsub_tot[knew][snew] += 1;
            }
        }
    }

    /// Collapsed Dirichlet-multinomial log marginal of a count row.
    fn row_marginal(&self, row: &[u32], total: u64) -> f64 {
        let v = self.corpus.vocab_size() as f64;
        let beta = self.cfg.beta;
        let mut acc = ln_gamma(v * beta) - ln_gamma(v * beta + total as f64);
        let lb = ln_gamma(beta);
        for &c in row {
            if c > 0 {
                acc += ln_gamma(beta + c as f64) - lb;
            }
        }
        acc
    }

    /// CRP-side delta of splitting topic `k` along its subclusters:
    /// for every token with `z = k`, replace
    /// `ln(αΨ_k + m^{<i}_{d,k})` by `ln(αΨ_s + m^{<i}_{d,s})` with the
    /// proposed sub-weights `(ψ_l, ψ_r)`. Denominators `(α + i − 1)`
    /// and all other topics' terms cancel.
    fn split_crp_delta(&self, k: usize, psi_l: f64, psi_r: f64) -> f64 {
        let a = self.cfg.alpha;
        let mut delta = 0.0f64;
        for (d, zd) in self.assign.z.iter().enumerate() {
            if self.assign.m[d].get(k as u32) == 0 {
                continue;
            }
            let (mut seen_k, mut seen_l, mut seen_r) = (0u32, 0u32, 0u32);
            for (i, &z) in zd.iter().enumerate() {
                if z as usize != k {
                    continue;
                }
                delta -= (a * self.psi[k] + seen_k as f64).ln();
                if self.sub[d][i] {
                    delta += (a * psi_r + seen_r as f64).ln();
                    seen_r += 1;
                } else {
                    delta += (a * psi_l + seen_l as f64).ln();
                    seen_l += 1;
                }
                seen_k += 1;
            }
        }
        delta
    }

    /// Propose splitting every live topic along its subclusters; the
    /// Metropolis–Hastings target is the collapsed joint
    /// `p(w | z, β)·p(z | Ψ, α)` with the new topic taking a
    /// proportional share of `Ψ_k` (simplified Hastings — the
    /// deterministic-proposal q-ratio is dropped; see module docs).
    /// Accepted splits are applied in one corpus scan. Returns
    /// #accepted.
    fn propose_splits(&mut self) -> usize {
        let slots = self.nk.len();
        // slot -> (new slot id for the right subcluster, ψ_l, ψ_r)
        let mut split_to: Vec<Option<u32>> = vec![None; slots];
        let mut new_psi: Vec<(f64, f64)> = vec![(0.0, 0.0); slots];
        let mut next_slot = slots as u32;
        for k in 0..slots {
            let [nl, nr] = self.nsub_tot[k];
            if nl == 0 || nr == 0 || self.psi[k] <= 0.0 {
                continue;
            }
            let whole = self.row_marginal(&self.n[k], self.nk[k]);
            let left = self.row_marginal(&self.nsub[k][0], nl);
            let right = self.row_marginal(&self.nsub[k][1], nr);
            let frac = nl as f64 / (nl + nr) as f64;
            let psi_l = self.psi[k] * frac;
            let psi_r = self.psi[k] * (1.0 - frac);
            let crp = self.split_crp_delta(k, psi_l, psi_r);
            let log_accept = left + right - whole + crp;
            if std::env::var_os("HDP_SSM_DEBUG").is_some() {
                eprintln!(
                    "split k={k} nl={nl} nr={nr} word={:.1} crp={crp:.1} accept={log_accept:.1}",
                    left + right - whole
                );
            }
            if log_accept >= 0.0 || self.rng.f64_open().ln() < log_accept {
                split_to[k] = Some(next_slot);
                new_psi[k] = (psi_l, psi_r);
                next_slot += 1;
            }
        }
        let accepted = split_to.iter().filter(|s| s.is_some()).count();
        if accepted > 0 {
            // One scan: right-subcluster tokens move to the new slot;
            // subclusters of both halves re-randomized.
            for (zd, sd) in self.assign.z.iter_mut().zip(self.sub.iter_mut()) {
                for (z, s) in zd.iter_mut().zip(sd.iter_mut()) {
                    if let Some(new) = split_to[*z as usize] {
                        if *s {
                            *z = new;
                        }
                        *s = self.rng.bernoulli(0.5);
                    }
                }
            }
            self.psi.resize(next_slot as usize, 0.0);
            for k in 0..slots {
                if let Some(new) = split_to[k] {
                    let (pl, pr) = new_psi[k];
                    self.psi[k] = pl;
                    self.psi[new as usize] = pr;
                }
            }
            self.rebuild();
        }
        accepted
    }

    /// CRP-side delta of merging topic `b` into `a` with merged weight
    /// `ψ_a + ψ_b`: the merged topic's per-document sequences interleave
    /// the two originals' counts.
    fn merge_crp_delta(&self, a: usize, b: usize) -> f64 {
        let al = self.cfg.alpha;
        let psi_m = self.psi[a] + self.psi[b];
        let mut delta = 0.0f64;
        for (d, zd) in self.assign.z.iter().enumerate() {
            let (ma, mb) = (
                self.assign.m[d].get(a as u32),
                self.assign.m[d].get(b as u32),
            );
            if ma == 0 && mb == 0 {
                continue;
            }
            let (mut seen_a, mut seen_b, mut seen_m) = (0u32, 0u32, 0u32);
            for &z in zd.iter() {
                let z = z as usize;
                if z == a {
                    delta -= (al * self.psi[a] + seen_a as f64).ln();
                    delta += (al * psi_m + seen_m as f64).ln();
                    seen_a += 1;
                    seen_m += 1;
                } else if z == b {
                    delta -= (al * self.psi[b] + seen_b as f64).ln();
                    delta += (al * psi_m + seen_m as f64).ln();
                    seen_b += 1;
                    seen_m += 1;
                }
            }
        }
        delta
    }

    /// Propose merging random topic pairs under the same collapsed
    /// joint target; apply accepted merges. Returns #accepted.
    fn propose_merges(&mut self) -> usize {
        let live: Vec<usize> =
            (0..self.nk.len()).filter(|&k| self.nk[k] > 0).collect();
        if live.len() < 2 {
            return 0;
        }
        let pairs = (live.len() / 2).max(1).min(8);
        let mut remap: Vec<Option<u32>> = vec![None; self.nk.len()];
        let mut used = vec![false; self.nk.len()];
        let mut accepted = 0usize;
        for _ in 0..pairs {
            let a = live[self.rng.below_usize(live.len())];
            let b = live[self.rng.below_usize(live.len())];
            if a == b || used[a] || used[b] {
                continue;
            }
            let merged_row: Vec<u32> = self.n[a]
                .iter()
                .zip(&self.n[b])
                .map(|(&x, &y)| x + y)
                .collect();
            let whole =
                self.row_marginal(&merged_row, self.nk[a] + self.nk[b]);
            let parts = self.row_marginal(&self.n[a], self.nk[a])
                + self.row_marginal(&self.n[b], self.nk[b]);
            let crp = self.merge_crp_delta(a, b);
            let log_accept = whole - parts + crp;
            if log_accept >= 0.0 || self.rng.f64_open().ln() < log_accept {
                remap[b] = Some(a as u32);
                used[a] = true;
                used[b] = true;
                accepted += 1;
            }
        }
        if accepted > 0 {
            for k in 0..self.nk.len() {
                if let Some(to) = remap[k] {
                    self.psi[to as usize] += self.psi[k];
                    self.psi[k] = 0.0;
                }
            }
            for (zd, sd) in self.assign.z.iter_mut().zip(self.sub.iter_mut()) {
                for (z, s) in zd.iter_mut().zip(sd.iter_mut()) {
                    if let Some(to) = remap[*z as usize] {
                        *z = to;
                        *s = self.rng.bernoulli(0.5);
                    }
                }
            }
            self.rebuild();
        }
        accepted
    }

    fn resample_psi(&mut self) {
        let slots = self.nk.len();
        let mut hist = DocCountHist::new(slots);
        for m in &self.assign.m {
            hist.record_doc(m.entries());
        }
        hist.finish();
        let mut gammas = vec![0.0f64; slots];
        let mut total = 0.0;
        for k in 0..slots {
            if self.nk[k] == 0 {
                self.psi[k] = 0.0;
                continue;
            }
            let l = lstep::sample_l_topic(
                &mut self.rng,
                &hist,
                k,
                self.psi.get(k).copied().unwrap_or(1.0 / slots as f64).max(1e-6),
                self.cfg.alpha,
            );
            let g = dist::gamma(&mut self.rng, l as f64 + 1e-9);
            gammas[k] = g;
            total += g;
        }
        total += dist::gamma(&mut self.rng, self.cfg.gamma); // unrepresented
        if self.psi.len() != slots {
            self.psi.resize(slots, 0.0);
        }
        for k in 0..slots {
            self.psi[k] = gammas[k] / total.max(1e-300);
        }
    }
}

impl SsmSampler {
    /// Nested view of the assignments (tests).
    pub fn assignments(&self) -> &[Vec<u32>] {
        &self.assign.z
    }
}

impl Trainer for SsmSampler {
    fn name(&self) -> &'static str {
        "ssm-hdp"
    }

    fn step(&mut self) -> anyhow::Result<()> {
        self.sweep();
        self.splits_accepted += self.propose_splits() as u64;
        self.merges_accepted += self.propose_merges() as u64;
        self.resample_psi();
        self.iteration += 1;
        Ok(())
    }

    fn diagnostics(&self) -> DiagSnapshot {
        let rows = self.topic_word_rows();
        let ll = loglik::joint_loglik(
            &rows,
            &self.assign.z,
            &self.psi,
            self.cfg.alpha,
            self.cfg.beta,
            self.corpus.vocab_size(),
            1usize,
        );
        let mut tokens_per_topic: Vec<u64> =
            self.nk.iter().copied().filter(|&t| t > 0).collect();
        tokens_per_topic.sort_unstable_by(|a, b| b.cmp(a));
        DiagSnapshot {
            log_likelihood: ll,
            active_topics: self.active_topics(),
            flag_topic_tokens: 0,
            total_tokens: self.nk.iter().sum(),
            tokens_per_topic,
        }
    }

    fn z_view(&self) -> ZView<'_> {
        ZView::Nested(&self.assign.z)
    }

    fn topic_word_rows(&self) -> Vec<Vec<(u32, u32)>> {
        self.n
            .iter()
            .map(|row| {
                row.iter()
                    .enumerate()
                    .filter(|&(_, &c)| c > 0)
                    .map(|(v, &c)| (v as u32, c))
                    .collect()
            })
            .collect()
    }

    fn docs(&self) -> &dyn crate::corpus::CorpusView {
        &*self.corpus
    }

    fn iterations_done(&self) -> usize {
        self.iteration
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::HdpCorpusSpec;

    fn tiny() -> std::sync::Arc<Corpus> {
        let (c, _) = HdpCorpusSpec {
            vocab: 100,
            topics: 4,
            gamma: 1.0,
            alpha: 1.0,
            topic_beta: 0.05,
            docs: 50,
            mean_doc_len: 25.0,
            len_sigma: 0.3,
            min_doc_len: 8,
        }
        .generate(41);
        std::sync::Arc::new(c)
    }

    fn cfg() -> HdpConfig {
        HdpConfig { alpha: 1.0, beta: 0.1, gamma: 1.0, k_max: 100, init_topics: 1 }
    }

    #[test]
    fn conserves_tokens() {
        let corpus = tiny();
        let total = corpus.num_tokens();
        let mut s = SsmSampler::new(corpus.clone(), cfg(), 3).unwrap();
        for _ in 0..8 {
            s.step().unwrap();
            assert_eq!(s.diagnostics().total_tokens, total);
            s.assign.check_consistency(&corpus).unwrap();
        }
    }

    #[test]
    fn splits_create_topics_slowly() {
        let corpus = tiny();
        let mut s = SsmSampler::new(corpus, cfg(), 9).unwrap();
        let mut prev = 1usize;
        let mut max_jump = 0usize;
        for _ in 0..20 {
            s.step().unwrap();
            let now = s.active_topics();
            max_jump = max_jump.max(now.saturating_sub(prev));
            prev = now;
        }
        assert!(s.active_topics() > 1, "splits should fire");
        // Structural property: births only via splits — each topic can
        // split at most once per iteration, so growth per iteration is
        // bounded by the current topic count (vs PC creating topics
        // from thin air); on this tiny corpus that means small jumps.
        assert!(max_jump <= prev.max(8), "jump {max_jump} vs {prev}");
    }

    #[test]
    fn loglik_improves() {
        let corpus = tiny();
        let mut s = SsmSampler::new(corpus, cfg(), 5).unwrap();
        s.step().unwrap();
        let first = s.diagnostics().log_likelihood;
        for _ in 0..15 {
            s.step().unwrap();
        }
        let last = s.diagnostics().log_likelihood;
        assert!(last > first, "{first} -> {last}");
    }
}
