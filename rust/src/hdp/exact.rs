//! **Algorithm 1** with exact, dense conditional draws — the
//! correctness oracle.
//!
//! Same partially collapsed blocking as [`super::pc`] but with none of
//! the sparse machinery:
//!
//! * `Φ` rows are exact `Dir(β + n_k)` draws (dense);
//! * `z` conditionals are enumerated densely over all `K*` topics;
//! * the augmentation `b` is sampled *explicitly* per token (eq. 14 /
//!   appendix A) and `l` is read off it, instead of the binomial trick;
//! * `Ψ` uses the same FGEM stick-breaking step (it is already exact).
//!
//! O(N·K*) per iteration and sequential — usable only on tiny corpora,
//! which is exactly its role: integration tests compare its stationary
//! behaviour against the sparse sampler's.

use crate::config::HdpConfig;
use crate::corpus::Corpus;
use crate::diagnostics::loglik;
use crate::rng::{dist, Pcg64};
use crate::sparse::DocTopics;

use super::pc::psi::sample_psi;
use super::state::Assignments;
use super::{DiagSnapshot, Trainer, ZView};

/// The dense Algorithm-1 sampler.
pub struct ExactSampler {
    corpus: std::sync::Arc<Corpus>,
    cfg: HdpConfig,
    rng: Pcg64,
    assign: Assignments,
    /// Dense topic-word counts `n[k][v]`.
    n: Vec<Vec<u32>>,
    /// Per-topic totals.
    nk: Vec<u64>,
    psi: Vec<f64>,
    /// Dense `Φ` of the current iteration.
    phi: Vec<Vec<f64>>,
    l: Vec<u64>,
    iteration: usize,
}

impl ExactSampler {
    /// Create with single-topic initialization.
    pub fn new(corpus: std::sync::Arc<Corpus>, cfg: HdpConfig, seed: u64) -> anyhow::Result<Self> {
        cfg.validate()?;
        let assign = Assignments::single_topic(&corpus);
        let v = corpus.vocab_size();
        let mut n = vec![vec![0u32; v]; cfg.k_max];
        let mut nk = vec![0u64; cfg.k_max];
        for (doc, zd) in corpus.docs.iter().zip(&assign.z) {
            for (&w, &k) in doc.iter().zip(zd) {
                n[k as usize][w as usize] += 1;
                nk[k as usize] += 1;
            }
        }
        let mut rng = Pcg64::with_stream(seed, 0xe8ac7);
        // Initial Ψ from l = "one draw per document per topic present".
        let mut l = vec![0u64; cfg.k_max];
        for m in &assign.m {
            for (k, _) in m.iter() {
                l[k as usize] += 1;
            }
        }
        let mut psi = vec![0.0; cfg.k_max];
        sample_psi(&mut rng, &l, cfg.gamma, &mut psi);
        Ok(Self {
            corpus,
            cfg,
            rng,
            assign,
            n,
            nk,
            psi,
            phi: Vec::new(),
            l,
            iteration: 0,
        })
    }

    /// Current Ψ.
    pub fn psi(&self) -> &[f64] {
        &self.psi
    }

    fn sample_phi_exact(&mut self) {
        let v = self.corpus.vocab_size();
        let mut phi = vec![vec![0.0f64; v]; self.cfg.k_max];
        let mut alpha_buf = vec![0.0f64; v];
        for k in 0..self.cfg.k_max {
            for w in 0..v {
                alpha_buf[w] = self.cfg.beta + self.n[k][w] as f64;
            }
            dist::dirichlet_into(&mut self.rng, &alpha_buf, &mut phi[k]);
        }
        self.phi = phi;
    }

    fn sweep_z(&mut self) {
        let k_max = self.cfg.k_max;
        let mut weights = vec![0.0f64; k_max];
        for d in 0..self.corpus.docs.len() {
            let doc = &self.corpus.docs[d];
            for i in 0..doc.len() {
                let v = doc[i] as usize;
                let kold = self.assign.z[d][i] as usize;
                self.assign.m[d].dec(kold as u32);
                self.n[kold][v] -= 1;
                self.nk[kold] -= 1;
                for (k, w) in weights.iter_mut().enumerate() {
                    *w = self.phi[k][v]
                        * (self.cfg.alpha * self.psi[k]
                            + self.assign.m[d].get(k as u32) as f64);
                }
                let knew = dist::categorical(&mut self.rng, &weights);
                self.assign.z[d][i] = knew as u32;
                self.assign.m[d].inc(knew as u32);
                self.n[knew][v] += 1;
                self.nk[knew] += 1;
            }
        }
    }

    /// Explicit `b` sampling (appendix A): for each document, walk the
    /// topic sequence keeping per-topic counts of *previous* tokens;
    /// `P(b_i = 1) = αΨ_{z_i} / (αΨ_{z_i} + #prev same-topic)`; `l_k`
    /// accumulates the successes.
    fn sample_l_explicit(&mut self) {
        let mut l = vec![0u64; self.cfg.k_max];
        let mut prev = DocTopics::with_capacity(16);
        for zd in &self.assign.z {
            prev.clear();
            for &k in zd {
                let a = self.cfg.alpha * self.psi[k as usize];
                let seen = prev.get(k) as f64;
                let p = if seen == 0.0 { 1.0 } else { a / (a + seen) };
                if self.rng.bernoulli(p) {
                    l[k as usize] += 1;
                }
                prev.inc(k);
            }
        }
        self.l = l;
    }
}

impl ExactSampler {
    /// Nested view of the assignments (tests).
    pub fn assignments(&self) -> &[Vec<u32>] {
        &self.assign.z
    }
}

impl Trainer for ExactSampler {
    fn name(&self) -> &'static str {
        "exact-hdp"
    }

    fn step(&mut self) -> anyhow::Result<()> {
        self.sample_phi_exact();
        self.sweep_z();
        self.sample_l_explicit();
        let mut rng = self.rng.clone();
        sample_psi(&mut rng, &self.l, self.cfg.gamma, &mut self.psi);
        self.rng = rng;
        self.iteration += 1;
        Ok(())
    }

    fn diagnostics(&self) -> DiagSnapshot {
        let rows = self.topic_word_rows();
        let ll = loglik::joint_loglik(
            &rows,
            &self.assign.z,
            &self.psi,
            self.cfg.alpha,
            self.cfg.beta,
            self.corpus.vocab_size(),
            1usize,
        );
        let mut tokens_per_topic: Vec<u64> =
            self.nk.iter().copied().filter(|&t| t > 0).collect();
        tokens_per_topic.sort_unstable_by(|a, b| b.cmp(a));
        DiagSnapshot {
            log_likelihood: ll,
            active_topics: self.nk.iter().filter(|&&t| t > 0).count(),
            flag_topic_tokens: self.nk[self.cfg.k_max - 1],
            total_tokens: self.nk.iter().sum(),
            tokens_per_topic,
        }
    }

    fn z_view(&self) -> ZView<'_> {
        ZView::Nested(&self.assign.z)
    }

    fn topic_word_rows(&self) -> Vec<Vec<(u32, u32)>> {
        self.n
            .iter()
            .map(|row| {
                row.iter()
                    .enumerate()
                    .filter(|&(_, &c)| c > 0)
                    .map(|(v, &c)| (v as u32, c))
                    .collect()
            })
            .collect()
    }

    fn docs(&self) -> &dyn crate::corpus::CorpusView {
        &*self.corpus
    }

    fn iterations_done(&self) -> usize {
        self.iteration
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::HdpCorpusSpec;

    fn tiny() -> std::sync::Arc<Corpus> {
        let (c, _) = HdpCorpusSpec {
            vocab: 60,
            topics: 3,
            gamma: 1.0,
            alpha: 1.0,
            topic_beta: 0.1,
            docs: 25,
            mean_doc_len: 15.0,
            len_sigma: 0.3,
            min_doc_len: 5,
        }
        .generate(21);
        std::sync::Arc::new(c)
    }

    fn cfg() -> HdpConfig {
        HdpConfig { alpha: 0.5, beta: 0.1, gamma: 1.0, k_max: 12, init_topics: 1 }
    }

    #[test]
    fn conserves_and_stays_finite() {
        let corpus = tiny();
        let total = corpus.num_tokens();
        let mut s = ExactSampler::new(corpus.clone(), cfg(), 3).unwrap();
        let init = s.diagnostics();
        assert_eq!(init.total_tokens, total);
        for _ in 0..25 {
            s.step().unwrap();
        }
        let last = s.diagnostics();
        assert_eq!(last.total_tokens, total);
        assert!(last.log_likelihood.is_finite());
        // The stationary joint should be no worse than a few percent
        // below the single-topic init (exact chains fluctuate; gross
        // divergence means a conditional is wrong).
        assert!(
            last.log_likelihood > init.log_likelihood - 0.1 * init.log_likelihood.abs(),
            "{} -> {}",
            init.log_likelihood,
            last.log_likelihood
        );
        assert!(last.active_topics >= 1);
        s.assign.check_consistency(&corpus).unwrap();
    }

    #[test]
    fn l_bounded_by_tokens_and_docs() {
        let corpus = tiny();
        let mut s = ExactSampler::new(corpus.clone(), cfg(), 4).unwrap();
        for _ in 0..5 {
            s.step().unwrap();
        }
        for k in 0..s.cfg.k_max {
            assert!(s.l[k] <= s.nk[k], "l_k <= n_k");
        }
    }
}
