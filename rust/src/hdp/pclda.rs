//! Partially collapsed **Pólya urn LDA** (Terenin et al. 2019;
//! Magnusson et al. 2018) — the fixed-K ablation baseline.
//!
//! Structurally this is Algorithm 2 with the nonparametric machinery
//! removed: `Ψ` is pinned to the uniform distribution over K topics
//! (the implicit assumption LDA makes — paper §2.4) and the `l`/`Ψ`
//! steps are skipped. Everything else (PPU `Φ`, per-word alias tables,
//! doubly sparse z, document-parallel sweep) is shared with
//! [`super::pc`], which is exactly the paper's point: conditional on
//! `Ψ`, the HDP's z step *is* the LDA z step.

use crate::corpus::Corpus;
use crate::diagnostics::loglik;
use crate::metrics::PhaseTimers;
use crate::par::{Sharding, WorkerPool};
use crate::rng::Pcg64;
use crate::sparse::{TopicWordAcc, TopicWordRows};

use super::pc::{phi, zstep};
use super::state::Assignments;
use super::{DiagSnapshot, Trainer};

/// The fixed-K Pólya urn LDA sampler.
pub struct PcLdaSampler {
    corpus: std::sync::Arc<Corpus>,
    /// Number of topics K.
    k: usize,
    alpha: f64,
    beta: f64,
    threads: usize,
    root: Pcg64,
    assign: Assignments,
    psi: Vec<f64>, // uniform, fixed
    n: TopicWordRows,
    iteration: usize,
    /// Phase timers (comparable to the PC sampler's).
    pub timers: PhaseTimers,
    doc_plan: Sharding,
    /// Persistent fork-join pool shared by all phases.
    pool: WorkerPool,
    /// Per-pool-slot z-phase scratch, cleared and reused each sweep.
    scratch: Vec<zstep::ShardScratch>,
}

impl PcLdaSampler {
    /// Create with random topic initialization over `k` topics (the
    /// usual LDA initialization).
    pub fn new(
        corpus: std::sync::Arc<Corpus>,
        k: usize,
        alpha: f64,
        beta: f64,
        threads: usize,
        seed: u64,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(k >= 2, "LDA needs at least 2 topics");
        let mut rng = Pcg64::with_stream(seed, 0x1da);
        let assign = Assignments::random(&corpus, k, &mut rng);
        let mut acc = TopicWordAcc::with_capacity(corpus.num_tokens() as usize / 2 + 16);
        for (doc, zd) in corpus.docs.iter().zip(&assign.z) {
            for (&v, &kk) in doc.iter().zip(zd) {
                acc.add(kk, v, 1);
            }
        }
        let n = TopicWordRows::merge_from(k, &mut [acc]);
        let doc_plan = Sharding::weighted(&corpus.doc_weights(), threads);
        let pool = WorkerPool::new(threads);
        let scratch = (0..pool.slots())
            .map(|_| zstep::ShardScratch::new(k))
            .collect();
        Ok(Self {
            corpus,
            k,
            alpha,
            beta,
            threads,
            root: Pcg64::with_stream(seed, 0x1da2),
            assign,
            psi: vec![1.0 / k as f64; k],
            n,
            iteration: 0,
            timers: PhaseTimers::new(),
            doc_plan,
            pool,
            scratch,
        })
    }

    /// Topic-word statistic.
    pub fn n(&self) -> &TopicWordRows {
        &self.n
    }

    /// Thread count used by the parallel phases.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The sampler's persistent worker pool.
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }
}

impl Trainer for PcLdaSampler {
    fn name(&self) -> &'static str {
        "pclda"
    }

    fn step(&mut self) -> anyhow::Result<()> {
        use std::time::Instant;
        let iter = self.iteration as u64 + 1;
        let vocab = self.corpus.vocab_size();
        let root = self.root.clone();
        let t0 = Instant::now();
        let phi_m = phi::sample_phi(
            &root.stream(iter.wrapping_mul(0x9e37) ^ 0x1f1),
            &self.n,
            self.beta,
            vocab,
            &self.pool,
        );
        self.timers.add("phi", t0.elapsed());
        let t0 = Instant::now();
        // α·Ψ_k = α/K — the LDA symmetric document prior.
        let tables = zstep::WordTables::build(&phi_m, &self.psi, self.alpha, &self.pool);
        self.timers.add("alias", t0.elapsed());
        let sweep = zstep::ZSweep {
            phi: &phi_m,
            psi: &self.psi,
            tables: &tables,
            alpha: self.alpha,
            k_max: self.k,
            seed_root: &root,
            iteration: iter,
        };
        let t0 = Instant::now();
        sweep.run_with_scratch(
            &self.corpus.docs,
            &mut self.assign.z,
            &mut self.assign.m,
            &self.doc_plan,
            &self.pool,
            &mut self.scratch,
        );
        self.timers.add("z", t0.elapsed());
        let t0 = Instant::now();
        self.n = TopicWordRows::merge_from_iter(
            self.k,
            self.scratch.iter_mut().map(|s| &mut s.out.n_acc),
        );
        self.timers.add("merge", t0.elapsed());
        self.iteration += 1;
        Ok(())
    }

    fn diagnostics(&self) -> DiagSnapshot {
        let rows = self.topic_word_rows();
        let ll = loglik::joint_loglik(
            &rows,
            &self.assign.z,
            &self.psi,
            self.alpha,
            self.beta,
            self.corpus.vocab_size(),
            &self.pool,
        );
        let mut tokens_per_topic: Vec<u64> =
            self.n.row_totals().iter().copied().filter(|&t| t > 0).collect();
        tokens_per_topic.sort_unstable_by(|a, b| b.cmp(a));
        DiagSnapshot {
            log_likelihood: ll,
            active_topics: self.n.active_topics(),
            flag_topic_tokens: 0,
            total_tokens: self.n.total(),
            tokens_per_topic,
        }
    }

    fn assignments(&self) -> &[Vec<u32>] {
        &self.assign.z
    }

    fn topic_word_rows(&self) -> Vec<Vec<(u32, u32)>> {
        (0..self.k).map(|k| self.n.row(k).to_vec()).collect()
    }

    fn corpus(&self) -> &Corpus {
        &self.corpus
    }

    fn iterations_done(&self) -> usize {
        self.iteration
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::HdpCorpusSpec;

    fn tiny() -> std::sync::Arc<Corpus> {
        let (c, _) = HdpCorpusSpec {
            vocab: 150,
            topics: 5,
            gamma: 1.0,
            alpha: 1.0,
            topic_beta: 0.05,
            docs: 60,
            mean_doc_len: 30.0,
            len_sigma: 0.3,
            min_doc_len: 8,
        }
        .generate(51);
        std::sync::Arc::new(c)
    }

    #[test]
    fn runs_and_improves() {
        let corpus = tiny();
        let total = corpus.num_tokens();
        let mut s = PcLdaSampler::new(corpus.clone(), 10, 0.1, 0.05, 2, 3).unwrap();
        s.step().unwrap();
        let first = s.diagnostics();
        assert_eq!(first.total_tokens, total);
        for _ in 0..20 {
            s.step().unwrap();
        }
        let last = s.diagnostics();
        assert_eq!(last.total_tokens, total);
        assert!(last.log_likelihood > first.log_likelihood);
        assert!(last.active_topics <= 10);
        s.assign.check_consistency(&corpus).unwrap();
    }

    #[test]
    fn thread_invariant() {
        let corpus = tiny();
        let mut a = PcLdaSampler::new(corpus.clone(), 8, 0.1, 0.05, 1, 7).unwrap();
        let mut b = PcLdaSampler::new(corpus, 8, 0.1, 0.05, 3, 7).unwrap();
        for _ in 0..3 {
            a.step().unwrap();
            b.step().unwrap();
        }
        assert_eq!(a.assignments(), b.assignments());
    }
}
