//! Partially collapsed **Pólya urn LDA** (Terenin et al. 2019;
//! Magnusson et al. 2018) — the fixed-K ablation baseline.
//!
//! Structurally this is Algorithm 2 with the nonparametric machinery
//! removed: `Ψ` is pinned to the uniform distribution over K topics
//! (the implicit assumption LDA makes — paper §2.4) and the `l`/`Ψ`
//! steps are skipped. Everything else (PPU `Φ`, per-word alias tables,
//! doubly sparse z, document-parallel sweep, and the async phase
//! pipeline — `Φ_{t+1}` submitted right after the merge, joined at the
//! next step, overlapping any between-step diagnostics) is shared with
//! [`super::pc`], which is exactly the paper's point: conditional on
//! `Ψ`, the HDP's z step *is* the LDA z step.

use crate::corpus::{Corpus, PackedCorpus};
use crate::diagnostics::loglik;
use crate::metrics::PhaseTimers;
use crate::par::{self, Schedule, Sharding, WorkerPool};
use crate::rng::Pcg64;
use crate::simd::Kernels;
use crate::sparse::{MergeScratch, TopicWordAcc, TopicWordRows};
use std::sync::Arc;

use super::pc::{phi, zstep};
use super::state::Assignments;
use super::{DiagSnapshot, Trainer, ZView};

/// The fixed-K Pólya urn LDA sampler.
pub struct PcLdaSampler {
    /// The packed CSR corpus — the only corpus representation held
    /// (the nested form is packed and dropped at construction); z stays
    /// nested internally and is served through [`ZView::Nested`].
    packed: Arc<PackedCorpus>,
    /// Number of topics K.
    k: usize,
    alpha: f64,
    beta: f64,
    threads: usize,
    root: Pcg64,
    assign: Assignments,
    psi: Vec<f64>, // uniform, fixed
    /// Shared with the in-flight Φ job in pipelined mode.
    n: Arc<TopicWordRows>,
    iteration: usize,
    /// Phase timers (comparable to the PC sampler's).
    pub timers: PhaseTimers,
    doc_plan: Sharding,
    /// Persistent fork-join pool shared by all phases.
    pool: Arc<WorkerPool>,
    /// Per-pool-slot z-phase scratch, cleared and reused each sweep.
    scratch: Vec<zstep::ShardScratch>,
    /// Bucket-(a) alias tables, rebuilt in place every iteration.
    tables: zstep::WordTables,
    tables_scratch: zstep::WordTablesScratch,
    merge_scratch: MergeScratch,
    pipelined: bool,
    slot_affine: bool,
    /// Streamed z: max documents per block (None = resident sweep).
    stream_block_docs: Option<usize>,
    /// Block plan derived from `doc_plan.refine(stream_block_docs)`.
    block_plan: Option<Sharding>,
    /// Streamed z: double-buffered block prefetch (next block's I/O
    /// overlaps the current block's sweep).
    stream_prefetch: bool,
    /// Double-buffer slot for the in-flight Φ job.
    phi_pipe: phi::PhiPipeline,
    /// Kernel set for the hot loops (see
    /// [`super::pc::PcSampler::set_simd`]).
    kernels: Kernels,
    /// Resolved worker core pinning state.
    pinning: bool,
    /// Pólya-urn MH z sweep instead of the exact kernel (see
    /// [`super::pc::zstep`]'s module docs).
    ppu: bool,
}

impl PcLdaSampler {
    /// Create with random topic initialization over `k` topics (the
    /// usual LDA initialization).
    pub fn new(
        corpus: Arc<Corpus>,
        k: usize,
        alpha: f64,
        beta: f64,
        threads: usize,
        seed: u64,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(k >= 2, "LDA needs at least 2 topics");
        let mut rng = Pcg64::with_stream(seed, 0x1da);
        let assign = Assignments::random(&corpus, k, &mut rng);
        let mut acc = TopicWordAcc::with_capacity(corpus.num_tokens() as usize / 2 + 16);
        for (doc, zd) in corpus.docs.iter().zip(&assign.z) {
            for (&v, &kk) in doc.iter().zip(zd) {
                acc.add(kk, v, 1);
            }
        }
        let n = Arc::new(TopicWordRows::merge_from(k, &mut [acc]));
        let weights = corpus.doc_weights();
        let doc_plan = Sharding::weighted(&weights, threads);
        let pool = Arc::new(WorkerPool::new(threads));
        let packed = Arc::new(corpus.to_packed());
        drop(corpus);
        // Plan-derived accumulator pre-size (see `zstep::plan_pair_hint`).
        let pair_hint = zstep::plan_pair_hint(&doc_plan, &weights, pool.slots());
        let scratch = (0..pool.slots())
            .map(|_| zstep::ShardScratch::with_pair_hint(k, pair_hint))
            .collect();
        Ok(Self {
            packed,
            k,
            alpha,
            beta,
            threads,
            root: Pcg64::with_stream(seed, 0x1da2),
            assign,
            psi: vec![1.0 / k as f64; k],
            n,
            iteration: 0,
            timers: PhaseTimers::new(),
            doc_plan,
            pool,
            scratch,
            tables: zstep::WordTables::empty(),
            tables_scratch: zstep::WordTablesScratch::new(),
            merge_scratch: MergeScratch::new(),
            pipelined: true,
            slot_affine: false,
            stream_block_docs: None,
            block_plan: None,
            stream_prefetch: false,
            phi_pipe: phi::PhiPipeline::new(0x1f1),
            kernels: Kernels::scalar(),
            pinning: false,
            ppu: false,
        })
    }

    /// Topic-word statistic.
    pub fn n(&self) -> &TopicWordRows {
        &self.n
    }

    /// Thread count used by the parallel phases.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The sampler's persistent worker pool.
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// An owning handle to the sampler's pool (see
    /// [`super::pc::PcSampler::pool_handle`]).
    pub fn pool_handle(&self) -> Arc<WorkerPool> {
        self.pool.clone()
    }

    /// The fixed uniform `Ψ` over the K topics — the implicit prior
    /// assumption LDA makes (paper §2.4).
    pub fn psi(&self) -> &[f64] {
        &self.psi
    }

    /// Number of topics K.
    pub fn num_topics(&self) -> usize {
        self.k
    }

    /// Document-side concentration α.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Topic-word prior mass β.
    pub fn beta(&self) -> f64 {
        self.beta
    }

    /// Enable/disable the phase pipeline (default on); chains are
    /// bit-identical either way.
    pub fn set_pipelined(&mut self, pipelined: bool) {
        self.pipelined = pipelined;
        if !pipelined {
            self.phi_pipe.clear(); // join → discard
        }
    }

    /// Enable/disable slot-affine z scheduling (default off).
    pub fn set_slot_affine(&mut self, slot_affine: bool) {
        self.slot_affine = slot_affine;
    }

    /// Engage (or drop) the SIMD kernel set for the hot loops —
    /// bit-identical chains under every tier (see
    /// [`super::pc::PcSampler::set_simd`]).
    pub fn set_simd(&mut self, on: bool) {
        self.kernels = if on { Kernels::auto() } else { Kernels::scalar() };
        self.phi_pipe.set_kernels(self.kernels);
    }

    /// Whether an accelerated (non-scalar) kernel tier is active.
    pub fn simd_active(&self) -> bool {
        self.kernels.is_accelerated()
    }

    /// Request (or release) worker core pinning with first-touch
    /// scratch placement (see [`super::pc::PcSampler::set_pinning`]).
    /// Returns the resolved state — `false` when the OS denied
    /// `sched_setaffinity`.
    pub fn set_pinning(&mut self, on: bool) -> bool {
        self.pinning = self.pool.set_pinning(on);
        if self.pinning {
            self.first_touch_scratch();
        }
        self.pinning
    }

    /// Whether worker core pinning is engaged.
    pub fn pinning(&self) -> bool {
        self.pinning
    }

    /// Enable/disable the Pólya-urn MH z sweep (default off; changes
    /// the chain — see [`super::pc::PcSampler::set_ppu`]).
    pub fn set_ppu(&mut self, on: bool) {
        self.ppu = on;
    }

    /// Whether the Pólya-urn fast path is engaged.
    pub fn ppu(&self) -> bool {
        self.ppu
    }

    /// Reallocate the per-slot z scratch on the pinned workers
    /// (slot-affine job, one task per slot) so first-touch places its
    /// pages on each worker's NUMA node.
    fn first_touch_scratch(&mut self) {
        let slots = self.pool.slots();
        let plan = self.block_plan.as_ref().unwrap_or(&self.doc_plan);
        let weights = self.packed.doc_weights();
        let pair_hint = zstep::plan_pair_hint(plan, &weights, slots);
        let k = self.k;
        let slot_plan = Sharding::even(slots, slots);
        // Pool slot_bound == slots (one unit scratch per slot).
        let mut unit: Vec<()> = vec![(); slots];
        self.scratch = par::exec_shards_with_sched(
            &*self.pool,
            &slot_plan,
            &mut unit,
            Schedule::SlotAffine,
            |_, _, _| zstep::ShardScratch::with_pair_hint(k, pair_hint),
        );
    }

    /// Enable/disable the streamed z sweep (blocks of at most
    /// `block_docs` documents through per-slot buffers; `None` =
    /// resident). Chains are bit-identical under every setting — see
    /// [`super::pc::PcSampler::set_streaming`].
    pub fn set_streaming(&mut self, block_docs: Option<usize>) {
        self.stream_block_docs = block_docs.map(|b| b.max(1));
        self.block_plan = self.stream_block_docs.map(|b| self.doc_plan.refine(b));
        if self.pinning {
            // Keep the first-touch placement across plan swaps.
            self.first_touch_scratch();
            return;
        }
        let plan = self.block_plan.as_ref().unwrap_or(&self.doc_plan);
        let weights = self.packed.doc_weights();
        let pair_hint = zstep::plan_pair_hint(plan, &weights, self.pool.slots());
        self.scratch = (0..self.pool.slots())
            .map(|_| zstep::ShardScratch::with_pair_hint(self.k, pair_hint))
            .collect();
    }

    /// Nested view of the assignments (tests).
    pub fn assignments(&self) -> &[Vec<u32>] {
        &self.assign.z
    }

    /// Streamed-mode block size (documents), if streaming is enabled.
    pub fn streaming(&self) -> Option<usize> {
        self.stream_block_docs
    }

    /// The prefetch knob of [`PcLdaSampler::set_streaming`]: overlap
    /// block `t+1`'s token/z I/O with block `t`'s sweep (see
    /// [`super::pc::PcSampler::set_stream_prefetch`]). Bit-identical
    /// chains either way.
    pub fn set_stream_prefetch(&mut self, prefetch: bool) {
        self.stream_prefetch = prefetch;
    }

    /// Whether streamed sweeps prefetch the next block.
    pub fn stream_prefetch(&self) -> bool {
        self.stream_prefetch
    }
}

impl Trainer for PcLdaSampler {
    fn name(&self) -> &'static str {
        "pclda"
    }

    fn try_set_ppu(&mut self, on: bool) -> bool {
        self.set_ppu(on);
        true
    }

    fn step(&mut self) -> anyhow::Result<()> {
        use std::time::Instant;
        let step_t0 = Instant::now();
        let iter = self.iteration as u64 + 1;
        let vocab = self.packed.vocab_size();
        let root = self.root.clone();
        // Φ: join the prebuilt job (submitted by the previous step,
        // overlapping its merge tail and any between-step diagnostics)
        // or sample synchronously. Identical RNG streams either way.
        let t0 = Instant::now();
        let (phi_m, overlapped) =
            self.phi_pipe.resolve(iter, &root, &self.n, self.beta, vocab, &self.pool);
        match overlapped {
            Some(sampling) => {
                self.timers.add("phi", sampling);
                self.timers.add("phi_join", t0.elapsed());
            }
            None => self.timers.add("phi", t0.elapsed()),
        }
        let t0 = Instant::now();
        // α·Ψ_k = α/K — the LDA symmetric document prior.
        self.tables.build_into_with(
            &phi_m,
            &self.psi,
            self.alpha,
            &*self.pool,
            &mut self.tables_scratch,
            &self.kernels,
        );
        self.timers.add("alias", t0.elapsed());
        if self.kernels.is_accelerated() {
            self.timers.incr(PhaseTimers::KERNEL_ALIAS_ELEMS, phi_m.nnz() as u64);
            self.timers.incr(PhaseTimers::KERNEL_PHI_ELEMS, phi_m.nnz() as u64);
        }
        // PPU mode: dense Ψ alias (here uniform — the LDA prior) for
        // the doc proposal's global side, built inline off the pool.
        let psi_alias = self
            .ppu
            .then(|| crate::alias::AliasTable::new_with(&self.psi, &self.kernels));
        let sweep = zstep::ZSweep {
            phi: &phi_m,
            psi: &self.psi,
            tables: &self.tables,
            alpha: self.alpha,
            k_max: self.k,
            seed_root: &root,
            iteration: iter,
            kernels: self.kernels,
            ppu: psi_alias.as_ref(),
        };
        let schedule =
            if self.slot_affine { Schedule::SlotAffine } else { Schedule::Steal };
        let t0 = Instant::now();
        match &self.block_plan {
            Some(blocks) if self.stream_prefetch => sweep.run_streamed_prefetched(
                &*self.packed,
                &zstep::NestedZ::new(&mut self.assign.z),
                &mut self.assign.m,
                blocks,
                &self.pool,
                &mut self.scratch,
            ),
            Some(blocks) => sweep.run_streamed(
                &*self.packed,
                &zstep::NestedZ::new(&mut self.assign.z),
                &mut self.assign.m,
                blocks,
                &*self.pool,
                &mut self.scratch,
                schedule,
            ),
            None => sweep.run_with_scratch_sched(
                &*self.packed,
                &mut self.assign.z,
                &mut self.assign.m,
                &self.doc_plan,
                &*self.pool,
                &mut self.scratch,
                schedule,
            ),
        }
        self.timers.add("z", t0.elapsed());
        let (mut pf_hits, mut pf_stalls, mut pf_failures) = (0u64, 0u64, 0u64);
        let (mut kern_gather, mut kern_scan) = (0u64, 0u64);
        let (mut ppu_tokens, mut ppu_doc, mut ppu_word) = (0u64, 0u64, 0u64);
        for s in &self.scratch {
            pf_hits += s.out.prefetch_hits;
            pf_stalls += s.out.prefetch_stalls;
            pf_failures += s.out.prefetch_failures;
            kern_gather += s.out.kern_gather_elems;
            kern_scan += s.out.kern_scan_tokens;
            ppu_tokens += s.out.ppu_tokens;
            ppu_doc += s.out.ppu_doc_accepts;
            ppu_word += s.out.ppu_word_accepts;
        }
        if ppu_tokens > 0 {
            self.timers.incr(PhaseTimers::PPU_TOKENS, ppu_tokens);
            self.timers.incr(PhaseTimers::PPU_DOC_ACCEPTS, ppu_doc);
            self.timers.incr(PhaseTimers::PPU_WORD_ACCEPTS, ppu_word);
        }
        if pf_hits + pf_stalls > 0 {
            self.timers.incr(PhaseTimers::PREFETCH_HITS, pf_hits);
            self.timers.incr(PhaseTimers::PREFETCH_STALLS, pf_stalls);
        }
        if pf_failures > 0 {
            self.timers.incr(PhaseTimers::PREFETCH_FAILURES, pf_failures);
        }
        if kern_gather + kern_scan > 0 {
            self.timers.incr(PhaseTimers::KERNEL_GATHER_ELEMS, kern_gather);
            self.timers.incr(PhaseTimers::KERNEL_SCAN_TOKENS, kern_scan);
        }
        let t0 = Instant::now();
        self.n = Arc::new(TopicWordRows::merge_par(
            self.k,
            self.scratch.iter_mut().map(|s| &mut s.out.n_acc),
            &*self.pool,
            &mut self.merge_scratch,
        ));
        self.timers.add("merge", t0.elapsed());
        // Pipeline front: n_t is final — Φ_{t+1} cooks on the workers
        // while the driver does diagnostics/trace work between steps.
        if self.pipelined {
            self.phi_pipe
                .submit_next(iter + 1, &root, &self.n, self.beta, vocab, &self.pool);
        }
        self.timers.add("critical_path", step_t0.elapsed());
        self.iteration += 1;
        Ok(())
    }

    fn diagnostics(&self) -> DiagSnapshot {
        let rows = self.topic_word_rows();
        let ll = loglik::joint_loglik(
            &rows,
            &self.assign.z,
            &self.psi,
            self.alpha,
            self.beta,
            self.packed.vocab_size(),
            &*self.pool,
        );
        let mut tokens_per_topic: Vec<u64> =
            self.n.row_totals().iter().copied().filter(|&t| t > 0).collect();
        tokens_per_topic.sort_unstable_by(|a, b| b.cmp(a));
        DiagSnapshot {
            log_likelihood: ll,
            active_topics: self.n.active_topics(),
            flag_topic_tokens: 0,
            total_tokens: self.n.total(),
            tokens_per_topic,
        }
    }

    fn z_view(&self) -> ZView<'_> {
        ZView::Nested(&self.assign.z)
    }

    fn topic_word_rows(&self) -> Vec<Vec<(u32, u32)>> {
        (0..self.k).map(|k| self.n.row(k).to_vec()).collect()
    }

    fn docs(&self) -> &dyn crate::corpus::CorpusView {
        &*self.packed
    }

    fn iterations_done(&self) -> usize {
        self.iteration
    }

    fn checkpoint(&self) -> crate::hdp::checkpoint::Checkpoint {
        crate::hdp::checkpoint::Checkpoint::from_nested_z(
            self.iteration as u64,
            "pclda",
            self.psi.clone(),
            &self.assign.z,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::HdpCorpusSpec;

    fn tiny() -> std::sync::Arc<Corpus> {
        let (c, _) = HdpCorpusSpec {
            vocab: 150,
            topics: 5,
            gamma: 1.0,
            alpha: 1.0,
            topic_beta: 0.05,
            docs: 60,
            mean_doc_len: 30.0,
            len_sigma: 0.3,
            min_doc_len: 8,
        }
        .generate(51);
        std::sync::Arc::new(c)
    }

    #[test]
    fn runs_and_improves() {
        let corpus = tiny();
        let total = corpus.num_tokens();
        let mut s = PcLdaSampler::new(corpus.clone(), 10, 0.1, 0.05, 2, 3).unwrap();
        s.step().unwrap();
        let first = s.diagnostics();
        assert_eq!(first.total_tokens, total);
        for _ in 0..20 {
            s.step().unwrap();
        }
        let last = s.diagnostics();
        assert_eq!(last.total_tokens, total);
        assert!(last.log_likelihood > first.log_likelihood);
        assert!(last.active_topics <= 10);
        s.assign.check_consistency(&corpus).unwrap();
    }

    #[test]
    fn thread_invariant() {
        let corpus = tiny();
        let mut a = PcLdaSampler::new(corpus.clone(), 8, 0.1, 0.05, 1, 7).unwrap();
        let mut b = PcLdaSampler::new(corpus, 8, 0.1, 0.05, 3, 7).unwrap();
        for _ in 0..3 {
            a.step().unwrap();
            b.step().unwrap();
        }
        assert_eq!(a.assignments(), b.assignments());
    }

    #[test]
    fn pipelined_matches_sequential() {
        // Pipelining and slot-affine scheduling change only where/when
        // work runs — the chain (and its diagnostics) must be
        // bit-identical to the barriered loop.
        let corpus = tiny();
        let mut seq = PcLdaSampler::new(corpus.clone(), 8, 0.1, 0.05, 3, 9).unwrap();
        seq.set_pipelined(false);
        let mut pip = PcLdaSampler::new(corpus, 8, 0.1, 0.05, 3, 9).unwrap();
        pip.set_slot_affine(true);
        for it in 0..5 {
            seq.step().unwrap();
            pip.step().unwrap();
            assert_eq!(pip.assignments(), seq.assignments(), "iter={it}");
            let (ds, dp) = (seq.diagnostics(), pip.diagnostics());
            assert_eq!(dp.log_likelihood.to_bits(), ds.log_likelihood.to_bits());
        }
    }

    #[test]
    fn simd_and_pinning_chains_bit_identical() {
        // Kernel/pinning invariance for the LDA baseline: every
        // simd × pinning cell bit-identical to the scalar unpinned
        // chain (pinning may resolve to off under EPERM — the
        // graceful-degradation path).
        let corpus = tiny();
        let run = |simd: bool, pin: bool| {
            let mut s = PcLdaSampler::new(corpus.clone(), 8, 0.1, 0.05, 3, 29).unwrap();
            s.set_simd(simd);
            if pin {
                let engaged = s.set_pinning(true);
                assert_eq!(engaged, s.pinning());
            }
            for _ in 0..3 {
                s.step().unwrap();
            }
            let _ = s.set_pinning(false);
            s.assignments().to_vec()
        };
        let reference = run(false, false);
        for &(simd, pin) in &[(true, false), (false, true), (true, true)] {
            assert_eq!(run(simd, pin), reference, "simd={simd} pin={pin}");
        }
    }

    #[test]
    fn streamed_matches_resident() {
        // The LDA sampler shares the streamed z machinery: 2-doc
        // blocks, pipelined, with and without the block prefetcher,
        // must stay bit-identical to the resident sweep.
        let corpus = tiny();
        let mut res = PcLdaSampler::new(corpus.clone(), 8, 0.1, 0.05, 2, 13).unwrap();
        let mut str8 = PcLdaSampler::new(corpus.clone(), 8, 0.1, 0.05, 2, 13).unwrap();
        str8.set_streaming(Some(2));
        assert_eq!(str8.streaming(), Some(2));
        let mut pf = PcLdaSampler::new(corpus, 8, 0.1, 0.05, 2, 13).unwrap();
        pf.set_streaming(Some(2));
        pf.set_stream_prefetch(true);
        assert!(pf.stream_prefetch());
        for it in 0..4 {
            res.step().unwrap();
            str8.step().unwrap();
            pf.step().unwrap();
            assert_eq!(str8.assignments(), res.assignments(), "iter={it}");
            assert_eq!(pf.assignments(), res.assignments(), "prefetched iter={it}");
        }
        // Hit/stall accounting reached the timers.
        let accounted =
            pf.timers.counter("prefetch_hits") + pf.timers.counter("prefetch_stalls");
        assert!(accounted > 0, "prefetch counters must be recorded");
        assert_eq!(str8.timers.counter("prefetch_hits"), 0);
    }
}
