//! The fully collapsed **direct assignment** sampler of Teh et al.
//! (2006) — the paper's small-scale baseline (Fig 1 a–f).
//!
//! `Φ` is integrated out, so the z conditional couples every token to
//! the global topic-word counts:
//!
//! ```text
//! P(z_{i,d} = k) ∝ (m^{-i}_{d,k} + αΨ_k) · (n^{-i}_{k,v} + β) / (n^{-i}_{k,·} + Vβ)
//! P(new topic)  ∝ αΨ_u / V
//! ```
//!
//! which makes the sweep inherently *sequential* — the property the
//! paper's parallel sampler removes. Topics are born by splitting the
//! unrepresented mass `Ψ_u` with a `Beta(1, γ)` stick and die when
//! their last token is removed. After each sweep the auxiliary counts
//! `l` are drawn (using the same binomial trick — §2.6 notes it applies
//! to other HDP samplers) and `(Ψ_1..Ψ_K, Ψ_u) ~ Dir(l_1..l_K, γ)`.

use crate::config::HdpConfig;
use crate::corpus::Corpus;
use crate::diagnostics::loglik;
use crate::rng::{dist, Pcg64};
use crate::sparse::DocCountHist;

use super::pc::lstep;
use super::state::Assignments;
use super::{DiagSnapshot, Trainer, ZView};

/// The direct-assignment sampler.
pub struct DaSampler {
    corpus: std::sync::Arc<Corpus>,
    cfg: HdpConfig,
    rng: Pcg64,
    assign: Assignments,
    /// Dense per-slot topic-word counts.
    n: Vec<Vec<u32>>,
    /// Per-slot totals.
    nk: Vec<u64>,
    /// Per-slot global weights; slots of dead topics hold 0.
    psi: Vec<f64>,
    /// Unrepresented mass Ψ_u.
    psi_u: f64,
    /// Reusable dead slots.
    free_slots: Vec<usize>,
    /// Scratch for the per-token weight vector.
    weights: Vec<f64>,
    iteration: usize,
}

impl DaSampler {
    /// Create with single-topic initialization (all tokens in slot 0).
    pub fn new(corpus: std::sync::Arc<Corpus>, cfg: HdpConfig, seed: u64) -> anyhow::Result<Self> {
        cfg.validate()?;
        let assign = Assignments::single_topic(&corpus);
        let v = corpus.vocab_size();
        let mut n0 = vec![0u32; v];
        let mut total = 0u64;
        for doc in &corpus.docs {
            for &w in doc {
                n0[w as usize] += 1;
                total += 1;
            }
        }
        let mut rng = Pcg64::with_stream(seed, 0xda);
        // Initial Ψ: one represented topic plus the unrepresented rest.
        let s = dist::beta(&mut rng, 1.0 + corpus.num_docs() as f64, cfg.gamma);
        Ok(Self {
            corpus,
            cfg,
            rng,
            assign,
            n: vec![n0],
            nk: vec![total],
            psi: vec![s],
            psi_u: 1.0 - s,
            free_slots: Vec::new(),
            weights: Vec::with_capacity(64),
            iteration: 0,
        })
    }

    /// Number of live topics.
    pub fn active_topics(&self) -> usize {
        self.nk.iter().filter(|&&c| c > 0).count()
    }

    /// Per-slot Ψ (dead slots are 0) — excludes Ψ_u.
    pub fn psi(&self) -> &[f64] {
        &self.psi
    }

    /// Unrepresented mass.
    pub fn psi_u(&self) -> f64 {
        self.psi_u
    }

    fn remove_token(&mut self, d: usize, i: usize) {
        let k = self.assign.z[d][i] as usize;
        let v = self.corpus.docs[d][i] as usize;
        self.assign.m[d].dec(k as u32);
        self.n[k][v] -= 1;
        self.nk[k] -= 1;
        if self.nk[k] == 0 {
            // Topic dies: fold its stick back into Ψ_u.
            self.psi_u += self.psi[k];
            self.psi[k] = 0.0;
            self.free_slots.push(k);
        }
    }

    fn add_token(&mut self, d: usize, i: usize, k: usize) {
        let v = self.corpus.docs[d][i] as usize;
        self.assign.z[d][i] = k as u32;
        self.assign.m[d].inc(k as u32);
        self.n[k][v] += 1;
        self.nk[k] += 1;
    }

    fn spawn_topic(&mut self) -> usize {
        // Break the unrepresented stick.
        let b = dist::beta(&mut self.rng, 1.0, self.cfg.gamma);
        let slot = if let Some(s) = self.free_slots.pop() {
            self.n[s].fill(0);
            s
        } else {
            self.n.push(vec![0u32; self.corpus.vocab_size()]);
            self.nk.push(0);
            self.psi.push(0.0);
            self.nk.len() - 1
        };
        self.psi[slot] = b * self.psi_u;
        self.psi_u *= 1.0 - b;
        slot
    }

    fn sweep(&mut self) {
        let vb = self.corpus.vocab_size() as f64 * self.cfg.beta;
        for d in 0..self.corpus.docs.len() {
            for i in 0..self.corpus.docs[d].len() {
                self.remove_token(d, i);
                let v = self.corpus.docs[d][i] as usize;
                let slots = self.nk.len();
                self.weights.clear();
                self.weights.resize(slots + 1, 0.0);
                for k in 0..slots {
                    if self.nk[k] == 0 && self.psi[k] == 0.0 {
                        continue; // dead slot
                    }
                    let doc_side = self.assign.m[d].get(k as u32) as f64
                        + self.cfg.alpha * self.psi[k];
                    let word_side = (self.n[k][v] as f64 + self.cfg.beta)
                        / (self.nk[k] as f64 + vb);
                    self.weights[k] = doc_side * word_side;
                }
                // New-topic option.
                self.weights[slots] =
                    self.cfg.alpha * self.psi_u / self.corpus.vocab_size() as f64;
                let pick = dist::categorical(&mut self.rng, &self.weights);
                let k = if pick == slots { self.spawn_topic() } else { pick };
                self.add_token(d, i, k);
            }
        }
    }

    /// Resample `(Ψ, Ψ_u)` from `Dir(l_1.., γ)` via the binomial trick
    /// on the per-document counts.
    fn resample_psi(&mut self) {
        let slots = self.nk.len();
        let mut hist = DocCountHist::new(slots);
        for m in &self.assign.m {
            hist.record_doc(m.entries());
        }
        hist.finish();
        let mut gammas = vec![0.0f64; slots + 1];
        let mut total = 0.0;
        for k in 0..slots {
            if self.nk[k] == 0 {
                continue;
            }
            let l = lstep::sample_l_topic(&mut self.rng, &hist, k, self.psi[k], self.cfg.alpha);
            let g = dist::gamma(&mut self.rng, l as f64 + 1e-12);
            gammas[k] = g;
            total += g;
        }
        let gu = dist::gamma(&mut self.rng, self.cfg.gamma);
        gammas[slots] = gu;
        total += gu;
        for k in 0..slots {
            self.psi[k] = gammas[k] / total;
        }
        self.psi_u = gammas[slots] / total;
    }
}

impl DaSampler {
    /// Nested view of the assignments (tests).
    pub fn assignments(&self) -> &[Vec<u32>] {
        &self.assign.z
    }
}

impl Trainer for DaSampler {
    fn name(&self) -> &'static str {
        "da-hdp"
    }

    fn step(&mut self) -> anyhow::Result<()> {
        self.sweep();
        self.resample_psi();
        self.iteration += 1;
        Ok(())
    }

    fn diagnostics(&self) -> DiagSnapshot {
        let rows = self.topic_word_rows();
        let ll = loglik::joint_loglik(
            &rows,
            &self.assign.z,
            &self.psi,
            self.cfg.alpha,
            self.cfg.beta,
            self.corpus.vocab_size(),
            1usize,
        );
        let mut tokens_per_topic: Vec<u64> =
            self.nk.iter().copied().filter(|&t| t > 0).collect();
        tokens_per_topic.sort_unstable_by(|a, b| b.cmp(a));
        DiagSnapshot {
            log_likelihood: ll,
            active_topics: self.active_topics(),
            flag_topic_tokens: 0, // no truncation in direct assignment
            total_tokens: self.nk.iter().sum(),
            tokens_per_topic,
        }
    }

    fn z_view(&self) -> ZView<'_> {
        ZView::Nested(&self.assign.z)
    }

    fn topic_word_rows(&self) -> Vec<Vec<(u32, u32)>> {
        self.n
            .iter()
            .map(|row| {
                row.iter()
                    .enumerate()
                    .filter(|&(_, &c)| c > 0)
                    .map(|(v, &c)| (v as u32, c))
                    .collect()
            })
            .collect()
    }

    fn docs(&self) -> &dyn crate::corpus::CorpusView {
        &*self.corpus
    }

    fn iterations_done(&self) -> usize {
        self.iteration
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::HdpCorpusSpec;

    fn tiny() -> std::sync::Arc<Corpus> {
        let (c, _) = HdpCorpusSpec {
            vocab: 80,
            topics: 4,
            gamma: 1.0,
            alpha: 1.0,
            topic_beta: 0.08,
            docs: 40,
            mean_doc_len: 20.0,
            len_sigma: 0.3,
            min_doc_len: 5,
        }
        .generate(31);
        std::sync::Arc::new(c)
    }

    fn cfg() -> HdpConfig {
        HdpConfig { alpha: 0.5, beta: 0.1, gamma: 1.0, k_max: 100, init_topics: 1 }
    }

    #[test]
    fn conserves_tokens_and_simplex() {
        let corpus = tiny();
        let total = corpus.num_tokens();
        let mut s = DaSampler::new(corpus.clone(), cfg(), 3).unwrap();
        for _ in 0..10 {
            s.step().unwrap();
            let d = s.diagnostics();
            assert_eq!(d.total_tokens, total);
            let sum: f64 = s.psi().iter().sum::<f64>() + s.psi_u();
            assert!((sum - 1.0).abs() < 1e-9, "psi simplex: {sum}");
            s.assign.check_consistency(&corpus).unwrap();
        }
    }

    #[test]
    fn grows_topics_and_improves() {
        let corpus = tiny();
        let mut s = DaSampler::new(corpus, cfg(), 5).unwrap();
        s.step().unwrap();
        let first = s.diagnostics();
        for _ in 0..40 {
            s.step().unwrap();
        }
        let last = s.diagnostics();
        assert!(last.active_topics > 1, "topics grew: {}", last.active_topics);
        assert!(last.log_likelihood > first.log_likelihood);
    }

    #[test]
    fn dead_topics_recycle_slots() {
        let corpus = tiny();
        let mut s = DaSampler::new(corpus, cfg(), 7).unwrap();
        for _ in 0..30 {
            s.step().unwrap();
        }
        // Slots should stay bounded well below token count: deaths are
        // recycled rather than appended forever.
        assert!(s.nk.len() < 60, "slot count {} runaway", s.nk.len());
        // All dead slots have zero psi.
        for k in 0..s.nk.len() {
            if s.nk[k] == 0 {
                assert_eq!(s.psi[k], 0.0);
            }
        }
    }
}
