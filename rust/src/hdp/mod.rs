//! The HDP topic model and its samplers.
//!
//! * [`state`] — topic assignments + sufficient statistics shared by
//!   every sampler, and the single-topic initialization the paper uses.
//! * [`pc`] — **the paper's contribution**: Algorithm 2, the doubly
//!   sparse, data-parallel, partially collapsed Gibbs sampler.
//! * [`exact`] — Algorithm 1 with dense, exact conditional draws (no
//!   PPU, no alias tables): the slow correctness oracle the sparse
//!   implementation is validated against.
//! * [`da`] — the fully collapsed *direct assignment* sampler of Teh et
//!   al. (2006): the paper's small-scale baseline (Fig 1 a–f).
//! * [`ssm`] — a simplified *subcluster split-merge* sampler in the
//!   style of Chang & Fisher (2014): the paper's large-scale baseline
//!   (Fig 1 g–i).
//! * [`pclda`] — partially collapsed LDA (fixed K, uniform Ψ): the
//!   ablation showing what the learned global distribution Ψ buys.
//!
//! All samplers implement [`Trainer`], which is what the coordinator's
//! training loop and the experiment drivers consume.

pub mod checkpoint;
pub mod da;
pub mod exact;
pub mod hyper;
pub mod pc;
pub mod pclda;
pub mod ssm;
pub mod state;

use crate::corpus::CorpusView;

/// Borrowed view of a sampler's topic assignments, in whichever layout
/// the sampler actually keeps them — the `Trainer` API's replacement
/// for the old `assignments() -> &[Vec<u32>]` accessor that forced
/// every sampler to hold a resident nested `z`.
///
/// * [`ZView::Nested`] — per-document vectors (the reference samplers'
///   internal layout).
/// * [`ZView::Packed`] — one flat CSR arena plus `(D+1)` doc offsets
///   (the packed-only training path). The arena is a [`Cow`] so
///   resident arenas borrow and out-of-core stores
///   ([`pc::zstep::FileZ`]) can hand back an owned read without ever
///   materializing nested per-document vectors.
///
/// [`Cow`]: std::borrow::Cow
pub enum ZView<'a> {
    /// `z[d][i]` = topic of token `i` in document `d`.
    Nested(&'a [Vec<u32>]),
    /// Flat z arena + CSR doc offsets (layout of
    /// [`crate::corpus::PackedCorpus`] and checkpoint v2).
    Packed {
        /// The flat assignments, packed in document order.
        z: std::borrow::Cow<'a, [u32]>,
        /// Doc offsets into `z`, length `D + 1`, starting at 0.
        offsets: std::borrow::Cow<'a, [u64]>,
    },
}

impl ZView<'_> {
    /// Number of documents `D`.
    pub fn num_docs(&self) -> usize {
        match self {
            ZView::Nested(z) => z.len(),
            ZView::Packed { offsets, .. } => offsets.len().saturating_sub(1),
        }
    }

    /// Total assigned tokens.
    pub fn num_tokens(&self) -> u64 {
        match self {
            ZView::Nested(z) => z.iter().map(|d| d.len() as u64).sum(),
            ZView::Packed { z, .. } => z.len() as u64,
        }
    }

    /// Assignments of document `d`.
    pub fn doc(&self, d: usize) -> &[u32] {
        match self {
            ZView::Nested(z) => &z[d],
            ZView::Packed { z, offsets } => {
                &z[offsets[d] as usize..offsets[d + 1] as usize]
            }
        }
    }

    /// Per-document iterator over the assignments, in document order.
    pub fn iter_docs(&self) -> impl Iterator<Item = &[u32]> + '_ {
        (0..self.num_docs()).map(move |d| self.doc(d))
    }

    /// Materialize nested per-document vectors (tests and the nested
    /// resume path — the packed-only path never calls this).
    pub fn to_nested(&self) -> Vec<Vec<u32>> {
        self.iter_docs().map(<[u32]>::to_vec).collect()
    }

    /// Materialize the packed form: `(flat z, doc offsets)`.
    pub fn to_packed(&self) -> (Vec<u32>, Vec<u64>) {
        match self {
            ZView::Nested(z) => {
                let mut offsets = Vec::with_capacity(z.len() + 1);
                let mut off = 0u64;
                offsets.push(0);
                let mut flat = Vec::new();
                for zd in z.iter() {
                    off += zd.len() as u64;
                    offsets.push(off);
                    flat.extend_from_slice(zd);
                }
                (flat, offsets)
            }
            ZView::Packed { z, offsets } => (z.to_vec(), offsets.to_vec()),
        }
    }
}

/// Per-iteration diagnostic snapshot (the quantities of the paper's
/// Fig 1 traces).
#[derive(Clone, Debug)]
pub struct DiagSnapshot {
    /// Joint collapsed log-likelihood `log p(w | z, β) + log p(z | Ψ, α)`
    /// (see [`crate::diagnostics`]).
    pub log_likelihood: f64,
    /// Topics with at least one token assigned.
    pub active_topics: usize,
    /// Tokens on the flag topic K* (0 unless the truncation is too
    /// tight; §2.4).
    pub flag_topic_tokens: u64,
    /// Total assigned tokens (conservation invariant).
    pub total_tokens: u64,
    /// Tokens per active topic, descending (Fig 1 c,f).
    pub tokens_per_topic: Vec<u64>,
}

/// A trainable HDP/LDA sampler.
pub trait Trainer {
    /// Human-readable sampler name (used in traces and reports).
    fn name(&self) -> &'static str;

    /// Run one full Gibbs iteration.
    fn step(&mut self) -> anyhow::Result<()>;

    /// Compute the diagnostic snapshot for the current state.
    fn diagnostics(&self) -> DiagSnapshot;

    /// Topic assignments, in the sampler's own layout ([`ZView`]).
    /// Nested samplers borrow their per-document vectors; packed-only
    /// samplers hand out the flat CSR arena (or an owned read of the
    /// file-backed store) — no caller forces a nested materialization.
    fn z_view(&self) -> ZView<'_>;

    /// Sparse topic-word counts: sorted `(word, count)` rows per topic.
    /// Row indices are sampler-internal topic ids.
    fn topic_word_rows(&self) -> Vec<Vec<(u32, u32)>>;

    /// The corpus being trained on, as a layout-agnostic view. The
    /// packed-only samplers return the packed arena; the reference
    /// samplers return their nested corpus.
    fn docs(&self) -> &dyn CorpusView;

    /// Iterations completed so far.
    fn iterations_done(&self) -> usize;

    /// Request the Pólya-urn MH z-sweep fast path (see
    /// [`pc::zstep`]'s module docs). Returns `true` when the sampler
    /// supports and applied the request; the default implementation
    /// declines (`false`) so callers (e.g. `repro train --ppu`) can
    /// report an unsupported sampler instead of silently running the
    /// exact kernel.
    fn try_set_ppu(&mut self, _on: bool) -> bool {
        false
    }

    /// Snapshot the current state as a durable
    /// [`checkpoint::Checkpoint`] (save with
    /// [`checkpoint::Checkpoint::save`] — atomic and checksummed).
    ///
    /// The default implementation covers samplers without a learned
    /// global topic distribution: `Ψ` is recorded as uniform over the
    /// sampler's topic rows. Samplers that carry a real `Ψ` (the PC
    /// family) override this with the exact resumable state.
    fn checkpoint(&self) -> checkpoint::Checkpoint {
        let k = self.topic_word_rows().len().max(1);
        checkpoint::Checkpoint::from_z_view(
            self.iterations_done() as u64,
            self.name(),
            vec![1.0 / k as f64; k],
            &self.z_view(),
        )
    }
}

#[cfg(test)]
mod trait_tests {
    //! Cross-sampler behavioural tests live in `rust/tests/`; here we
    //! only assert the snapshot type is usable standalone.
    use super::*;

    #[test]
    fn snapshot_is_plain_data() {
        let s = DiagSnapshot {
            log_likelihood: -1.0,
            active_topics: 2,
            flag_topic_tokens: 0,
            total_tokens: 10,
            tokens_per_topic: vec![6, 4],
        };
        let s2 = s.clone();
        assert_eq!(s2.active_topics, 2);
        assert_eq!(s2.tokens_per_topic.iter().sum::<u64>(), s2.total_tokens);
    }
}
