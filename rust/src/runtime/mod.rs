//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them
//! from the rust hot path.
//!
//! Python (jax + pallas) runs once at build time (`make artifacts`);
//! this module makes its outputs callable at training/eval time with
//! no python in the process:
//!
//! 1. [`Engine::load`] — read `artifacts/manifest.txt`, parse each
//!    `*.hlo.txt` via `HloModuleProto::from_text_file`, and compile it
//!    once on the PJRT CPU client;
//! 2. [`Engine::loglik`] — stream zero-padded `(n, Φ)` f32 tiles of
//!    the model state through the compiled `loglik_tile` executable
//!    and sum the per-tile results (exactly what the L1 kernel's grid
//!    does on-chip, tiled here across executions instead);
//! 3. [`Engine::zscore`] / [`Engine::psi_stick`] — dense z-conditional
//!    scoring batches and the stick-breaking transform.
//!
//! Buffers are reused across tile executions; each `execute` call
//! copies one tile pair (H2D equivalent on CPU), so the runtime cost is
//! dominated by the tile fill, measured in `benches/runtime_xla.rs`.

use crate::sparse::{PhiMatrix, TopicWordRows};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled artifact and its declared dimensions.
struct Artifact {
    exe: xla::PjRtLoadedExecutable,
    dims: Vec<usize>,
}

/// The PJRT execution engine.
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    artifacts: HashMap<String, Artifact>,
    /// Reused tile staging buffers.
    tile_n: Vec<f32>,
    tile_phi: Vec<f32>,
}

impl Engine {
    /// Default artifact directory (overridable with `$HDP_ARTIFACTS`).
    pub fn default_dir() -> PathBuf {
        std::env::var("HDP_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Load and compile every artifact listed in `dir/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {} (run `make artifacts`)", manifest.display()))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut artifacts = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_ascii_whitespace();
            let name = parts.next().context("manifest: missing name")?.to_string();
            let dims: Vec<usize> = parts
                .map(|p| p.parse::<usize>().context("manifest: bad dim"))
                .collect::<Result<_>>()?;
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
            artifacts.insert(name, Artifact { exe, dims });
        }
        anyhow::ensure!(
            artifacts.contains_key("loglik_tile"),
            "manifest lacks loglik_tile"
        );
        let (tk, tv) = {
            let a = &artifacts["loglik_tile"];
            (a.dims[0], a.dims[1])
        };
        Ok(Self {
            client,
            artifacts,
            tile_n: vec![0.0; tk * tv],
            tile_phi: vec![0.0; tk * tv],
        })
    }

    /// Loglik tile shape `(K_T, V_T)`.
    pub fn loglik_tile_shape(&self) -> (usize, usize) {
        let d = &self.artifacts["loglik_tile"].dims;
        (d[0], d[1])
    }

    /// Names of loaded artifacts.
    pub fn artifact_names(&self) -> Vec<&str> {
        self.artifacts.keys().map(String::as_str).collect()
    }

    fn run1(&self, name: &str, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let art = self
            .artifacts
            .get(name)
            .with_context(|| format!("artifact {name} not loaded"))?;
        let result = art
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} result: {e:?}"))?;
        // Artifacts are lowered with return_tuple=True → 1-tuples.
        result
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untupling {name} result: {e:?}"))
    }

    /// Execute one raw loglik tile pair (row-major `K_T × V_T`).
    pub fn loglik_tile_raw(&self, n: &[f32], phi: &[f32]) -> Result<f32> {
        let (tk, tv) = self.loglik_tile_shape();
        anyhow::ensure!(n.len() == tk * tv && phi.len() == tk * tv, "tile size");
        let ln = xla::Literal::vec1(n)
            .reshape(&[tk as i64, tv as i64])
            .map_err(|e| anyhow::anyhow!("reshape n: {e:?}"))?;
        let lp = xla::Literal::vec1(phi)
            .reshape(&[tk as i64, tv as i64])
            .map_err(|e| anyhow::anyhow!("reshape phi: {e:?}"))?;
        let out = self.run1("loglik_tile", &[ln, lp])?;
        Ok(out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?[0])
    }

    /// Model log-likelihood `Σ_{k,v} n_{k,v}·log φ_{k,v}` of a full
    /// sparse state, streamed through the compiled tile executable.
    ///
    /// This is the dense cross-check of the sparse rust-native value
    /// ([`phi_loglik_sparse`]): integration tests assert they agree.
    pub fn loglik(&mut self, n: &TopicWordRows, phi: &PhiMatrix) -> Result<f64> {
        let (tk, tv) = self.loglik_tile_shape();
        let k_max = n.num_topics();
        let vocab = phi.vocab();
        let mut total = 0.0f64;
        let mut k0 = 0usize;
        while k0 < k_max {
            // Skip all-empty topic bands quickly.
            let band_has_tokens =
                (k0..(k0 + tk).min(k_max)).any(|k| n.row_total(k) > 0);
            if !band_has_tokens {
                k0 += tk;
                continue;
            }
            let mut v0 = 0usize;
            while v0 < vocab {
                self.fill_n_tile(n, k0, tk, v0, tv);
                let n_tile_empty = self.tile_n.iter().all(|&x| x == 0.0);
                if !n_tile_empty {
                    phi.fill_tile_f32(k0, tk, v0, tv, &mut self.tile_phi);
                    let ln = xla::Literal::vec1(&self.tile_n)
                        .reshape(&[tk as i64, tv as i64])
                        .map_err(|e| anyhow::anyhow!("{e:?}"))?;
                    let lp = xla::Literal::vec1(&self.tile_phi)
                        .reshape(&[tk as i64, tv as i64])
                        .map_err(|e| anyhow::anyhow!("{e:?}"))?;
                    let out = self.run1("loglik_tile", &[ln, lp])?;
                    total += out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?
                        [0] as f64;
                }
                v0 += tv;
            }
            k0 += tk;
        }
        Ok(total)
    }

    fn fill_n_tile(&mut self, n: &TopicWordRows, k0: usize, tk: usize, v0: usize, tv: usize) {
        self.tile_n.fill(0.0);
        for (dk, k) in (k0..(k0 + tk).min(n.num_topics())).enumerate() {
            let row = n.row(k);
            let start = row.partition_point(|&(v, _)| (v as usize) < v0);
            for &(v, c) in &row[start..] {
                let v = v as usize;
                if v >= v0 + tv {
                    break;
                }
                self.tile_n[dk * tv + (v - v0)] = c as f32;
            }
        }
    }

    /// Dense z-conditional scoring for a token batch: inputs shaped
    /// `(B, K)` row-major plus `psi[K]` and `alpha`; returns the
    /// normalized `(B, K)` probabilities. `B`/`K` must match the
    /// artifact (see manifest).
    pub fn zscore(
        &self,
        phi_cols: &[f32],
        m_rows: &[f32],
        psi: &[f32],
        alpha: f32,
    ) -> Result<Vec<f32>> {
        let d = &self.artifacts.get("zscore_tile").context("zscore_tile")?.dims;
        let (b, k) = (d[0], d[1]);
        anyhow::ensure!(phi_cols.len() == b * k, "phi_cols size");
        anyhow::ensure!(m_rows.len() == b * k, "m_rows size");
        anyhow::ensure!(psi.len() == k, "psi size");
        let lphi = xla::Literal::vec1(phi_cols)
            .reshape(&[b as i64, k as i64])
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let lm = xla::Literal::vec1(m_rows)
            .reshape(&[b as i64, k as i64])
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let lpsi = xla::Literal::vec1(psi);
        let lalpha = xla::Literal::from(alpha);
        let out = self.run1("zscore_tile", &[lphi, lm, lpsi, lalpha])?;
        out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))
    }

    /// Batch shape `(B, K)` of the zscore artifact.
    pub fn zscore_shape(&self) -> Option<(usize, usize)> {
        self.artifacts.get("zscore_tile").map(|a| (a.dims[0], a.dims[1]))
    }

    /// Stick-breaking transform via the compiled artifact; input length
    /// must match the manifest (pad extra sticks with 1.0 — they take
    /// the then-zero remainder).
    pub fn psi_stick(&self, sticks: &[f32]) -> Result<Vec<f32>> {
        let d = &self.artifacts.get("psi_stick").context("psi_stick")?.dims;
        anyhow::ensure!(
            sticks.len() == d[0],
            "sticks length {} != {}",
            sticks.len(),
            d[0]
        );
        let ls = xla::Literal::vec1(sticks);
        let out = self.run1("psi_stick", &[ls])?;
        out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))
    }
}

/// Rust-native sparse evaluation of the same quantity as
/// [`Engine::loglik`]: `Σ n·log φ` over the nonzeros of `n`.
pub fn phi_loglik_sparse(n: &TopicWordRows, phi: &PhiMatrix) -> f64 {
    let mut total = 0.0f64;
    for k in 0..n.num_topics() {
        for &(v, c) in n.row(k) {
            let p = phi.get(k as u32, v);
            if p > 0.0 {
                total += c as f64 * p.ln();
            }
            // p == 0 with c > 0 cannot happen for a Φ sampled from the
            // same z that produced n, except transiently for the PPU's
            // zero-mass words; those tokens are skipped in the sweep
            // and contribute nothing here either.
        }
    }
    total
}

#[cfg(test)]
mod tests {
    // Engine tests that need compiled artifacts live in
    // rust/tests/runtime.rs (they require `make artifacts` to have
    // run). Here: the sparse reference only.
    use super::*;
    use crate::sparse::TopicWordAcc;

    #[test]
    fn sparse_loglik_by_hand() {
        let mut acc = TopicWordAcc::with_capacity(8);
        acc.add(0, 1, 2); // n[0][1] = 2
        acc.add(1, 0, 3); // n[1][0] = 3
        let n = TopicWordRows::merge_from(2, &mut [acc]);
        // phi: k0 = {1: 1.0}, k1 = {0: 0.5, 2: 0.5}
        let phi = PhiMatrix::from_count_rows(3, &[vec![(1, 4)], vec![(0, 2), (2, 2)]]);
        let want = 2.0 * 1.0f64.ln() + 3.0 * 0.5f64.ln();
        let got = phi_loglik_sparse(&n, &phi);
        assert!((got - want).abs() < 1e-12);
    }
}
