//! MALLET-equivalent corpus preprocessing (paper §3): stop-word
//! removal, rare-word limit, and minimum document size, with vocabulary
//! compaction.
//!
//! The paper preprocesses with "default Mallet stop-word removal,
//! minimum document size of 10, and a rare word limit of 10"; the same
//! defaults are exposed here via [`PreprocessConfig::paper_defaults`].

use super::Corpus;
use std::collections::HashSet;

/// A trimmed version of MALLET's default English stoplist — enough to
/// strip the function words that dominate raw newswire; synthetic
/// corpora generate content words only, so the exact list is not
/// behaviour-critical.
pub const DEFAULT_STOPWORDS: &[&str] = &[
    "a", "about", "above", "after", "again", "against", "all", "am", "an", "and",
    "any", "are", "as", "at", "be", "because", "been", "before", "being", "below",
    "between", "both", "but", "by", "can", "cannot", "could", "did", "do", "does",
    "doing", "down", "during", "each", "few", "for", "from", "further", "had",
    "has", "have", "having", "he", "her", "here", "hers", "herself", "him",
    "himself", "his", "how", "i", "if", "in", "into", "is", "it", "its", "itself",
    "just", "me", "more", "most", "my", "myself", "no", "nor", "not", "now", "of",
    "off", "on", "once", "only", "or", "other", "our", "ours", "ourselves", "out",
    "over", "own", "same", "she", "should", "so", "some", "such", "than", "that",
    "the", "their", "theirs", "them", "themselves", "then", "there", "these",
    "they", "this", "those", "through", "to", "too", "under", "until", "up",
    "very", "was", "we", "were", "what", "when", "where", "which", "while", "who",
    "whom", "why", "will", "with", "would", "you", "your", "yours", "yourself",
    "yourselves",
];

/// Preprocessing parameters.
#[derive(Clone, Debug)]
pub struct PreprocessConfig {
    /// Remove these exact word strings.
    pub stopwords: HashSet<String>,
    /// Drop word types occurring fewer than this many times corpus-wide.
    pub rare_word_limit: u64,
    /// Drop documents with fewer than this many tokens *after* word
    /// filtering.
    pub min_doc_size: usize,
}

impl PreprocessConfig {
    /// The paper's settings: default stoplist, rare-word limit 10,
    /// minimum document size 10.
    pub fn paper_defaults() -> Self {
        Self {
            stopwords: DEFAULT_STOPWORDS.iter().map(|s| s.to_string()).collect(),
            rare_word_limit: 10,
            min_doc_size: 10,
        }
    }

    /// No-op preprocessing.
    pub fn none() -> Self {
        Self { stopwords: HashSet::new(), rare_word_limit: 0, min_doc_size: 0 }
    }
}

/// Report of what preprocessing removed.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PreprocessReport {
    pub docs_in: usize,
    pub docs_out: usize,
    pub vocab_in: usize,
    pub vocab_out: usize,
    pub tokens_in: u64,
    pub tokens_out: u64,
    pub stopword_types_removed: usize,
    pub rare_types_removed: usize,
}

/// Apply preprocessing, producing a compacted corpus (word ids
/// renumbered densely; empty/short documents dropped).
pub fn preprocess(corpus: &Corpus, cfg: &PreprocessConfig) -> (Corpus, PreprocessReport) {
    let mut report = PreprocessReport {
        docs_in: corpus.num_docs(),
        vocab_in: corpus.vocab_size(),
        tokens_in: corpus.num_tokens(),
        ..Default::default()
    };
    let counts = corpus.word_counts();
    // Decide which word types survive.
    let mut keep = vec![true; corpus.vocab_size()];
    for (w, word) in corpus.vocab.iter().enumerate() {
        if cfg.stopwords.contains(word.as_str()) {
            keep[w] = false;
            report.stopword_types_removed += 1;
        } else if counts[w] < cfg.rare_word_limit {
            keep[w] = false;
            if counts[w] > 0 {
                report.rare_types_removed += 1;
            }
        } else if counts[w] == 0 {
            // unused vocab entries are dropped silently
            keep[w] = false;
        }
    }
    // Dense renumbering.
    let mut remap = vec![u32::MAX; corpus.vocab_size()];
    let mut vocab = Vec::new();
    for (w, &k) in keep.iter().enumerate() {
        if k {
            remap[w] = vocab.len() as u32;
            vocab.push(corpus.vocab[w].clone());
        }
    }
    report.vocab_out = vocab.len();
    // Filter documents.
    let mut docs = Vec::new();
    for doc in &corpus.docs {
        let filtered: Vec<u32> = doc
            .iter()
            .filter_map(|&w| {
                let r = remap[w as usize];
                (r != u32::MAX).then_some(r)
            })
            .collect();
        if filtered.len() >= cfg.min_doc_size.max(1) {
            report.tokens_out += filtered.len() as u64;
            docs.push(filtered);
        }
    }
    report.docs_out = docs.len();
    (Corpus { docs, vocab }, report)
}

/// [`preprocess`] straight into the packed arena form the samplers
/// consume. Identical filtering/renumbering (it is the same pass),
/// identical report; only the output layout differs.
pub fn preprocess_packed(
    corpus: &Corpus,
    cfg: &PreprocessConfig,
) -> (super::PackedCorpus, PreprocessReport) {
    let (clean, report) = preprocess(corpus, cfg);
    (clean.to_packed(), report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        // "the" is a stopword; "rare" occurs once; "cat"/"dog" common.
        let vocab: Vec<String> =
            ["the", "cat", "dog", "rare", "unused"].iter().map(|s| s.to_string()).collect();
        let docs = vec![
            vec![0, 1, 1, 2, 2, 2], // the cat cat dog dog dog
            vec![0, 3],             // the rare -> too short after filtering
            vec![1, 2, 1, 2],       // cat dog cat dog
        ];
        Corpus { docs, vocab }
    }

    #[test]
    fn filters_and_compacts() {
        let cfg = PreprocessConfig {
            stopwords: ["the"].iter().map(|s| s.to_string()).collect(),
            rare_word_limit: 2,
            min_doc_size: 2,
        };
        let (out, report) = preprocess(&corpus(), &cfg);
        assert_eq!(out.vocab, vec!["cat".to_string(), "dog".to_string()]);
        assert_eq!(out.num_docs(), 2);
        assert_eq!(out.num_tokens(), 9);
        assert_eq!(report.stopword_types_removed, 1);
        assert_eq!(report.rare_types_removed, 1);
        assert_eq!(report.vocab_out, 2);
        assert_eq!(report.docs_out, 2);
        assert_eq!(report.tokens_out, 9);
        out.validate().unwrap();
        // ids are dense and remapped
        for doc in &out.docs {
            assert!(doc.iter().all(|&w| w < 2));
        }
    }

    #[test]
    fn none_config_keeps_used_words() {
        let (out, _) = preprocess(&corpus(), &PreprocessConfig::none());
        // "unused" dropped (zero count), everything else kept.
        assert_eq!(out.vocab.len(), 4);
        assert_eq!(out.num_tokens(), corpus().num_tokens());
    }

    #[test]
    fn paper_defaults_are_papers() {
        let cfg = PreprocessConfig::paper_defaults();
        assert_eq!(cfg.rare_word_limit, 10);
        assert_eq!(cfg.min_doc_size, 10);
        assert!(cfg.stopwords.contains("the"));
    }

    #[test]
    fn min_doc_size_drops_empty() {
        let cfg = PreprocessConfig::none();
        let c = Corpus {
            docs: vec![vec![], vec![0]],
            vocab: vec!["w".into()],
        };
        let (out, _) = preprocess(&c, &cfg);
        assert_eq!(out.num_docs(), 1); // empty doc dropped even with min 0
    }

    #[test]
    fn packed_conversion_preserves_preprocess_output() {
        // preprocess filters; conversion must then be lossless: doc and
        // token counts match the report, ids stay dense, token order
        // and per-doc boundaries survive the round-trip.
        let cfg = PreprocessConfig {
            stopwords: ["the"].iter().map(|s| s.to_string()).collect(),
            rare_word_limit: 2,
            min_doc_size: 2,
        };
        let (nested, report) = preprocess(&corpus(), &cfg);
        let (packed, report2) = preprocess_packed(&corpus(), &cfg);
        assert_eq!(report2, report);
        assert_eq!(packed.num_docs(), report.docs_out);
        assert_eq!(packed.num_tokens(), report.tokens_out);
        assert_eq!(packed.vocab_size(), report.vocab_out);
        assert_eq!(packed.to_nested().docs, nested.docs);
        assert_eq!(packed.vocab, nested.vocab);
        packed.validate().unwrap();
        // Unlike preprocessing, *conversion* retains empty documents —
        // the CSR layout represents them as zero-length ranges.
        let with_empty = Corpus {
            docs: vec![vec![], vec![0], vec![]],
            vocab: vec!["w".into()],
        };
        let p = with_empty.to_packed();
        assert_eq!(p.num_docs(), 3);
        assert_eq!(p.doc_len(0), 0);
        assert_eq!(p.doc_len(2), 0);
        assert_eq!(p.to_nested().docs, with_empty.docs);
    }
}
