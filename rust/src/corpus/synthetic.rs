//! Synthetic corpus generators.
//!
//! The paper's corpora (AP, CGCBIB, NeurIPS, PubMed) cannot be
//! downloaded in this environment, so two simulators stand in
//! (substitution documented in DESIGN.md):
//!
//! * [`ZipfCorpusSpec`] — tokens drawn i.i.d. from a Zipf(s) marginal
//!   over a large underlying vocabulary. The *observed* vocabulary then
//!   grows like Heaps' law `V ≈ ξ·N^ζ`, which is the assumption the
//!   paper's complexity analysis (§2.8) rests on. Used by the scaling
//!   benches.
//! * [`HdpCorpusSpec`] — documents drawn from the HDP generative model
//!   itself (truncated GEM Ψ, Dirichlet topics, θ_d ~ Dir(αΨ)), with
//!   the planted `Φ`/`Ψ` returned as ground truth. This produces the
//!   document-topic and topic-word sparsity the doubly sparse sampler
//!   exploits, and supports recovery tests.
//!
//! Word strings are deterministic pronounceable pseudo-words
//! ([`pseudo_word`]) so top-word tables in the experiment output read
//! like the paper's appendices.

use super::{Corpus, PackedCorpus};
use crate::alias::AliasTable;
use crate::rng::{dist, Pcg64};

/// Deterministic pronounceable pseudo-word for a word id ("zana",
/// "tiko", …). Ids map to distinct strings (base-(C·V) positional code
/// over consonant-vowel syllables, with a disambiguating suffix beyond
/// the code range).
pub fn pseudo_word(id: u32) -> String {
    const C: &[u8] = b"bcdfghjklmnprstvwz";
    const V: &[u8] = b"aeiou";
    let mut s = String::new();
    let mut x = id as u64;
    // at least two syllables for visual plausibility
    for _ in 0..2 {
        s.push(C[(x % C.len() as u64) as usize] as char);
        x /= C.len() as u64;
        s.push(V[(x % V.len() as u64) as usize] as char);
        x /= V.len() as u64;
    }
    while x > 0 {
        s.push(C[(x % C.len() as u64) as usize] as char);
        x /= C.len() as u64;
        if x > 0 {
            s.push(V[(x % V.len() as u64) as usize] as char);
            x /= V.len() as u64;
        }
    }
    s
}

/// Build a vocabulary of `n` distinct pseudo-words.
pub fn pseudo_vocab(n: usize) -> Vec<String> {
    (0..n as u32).map(pseudo_word).collect()
}

/// Zipf/Heaps corpus parameters.
#[derive(Clone, Debug)]
pub struct ZipfCorpusSpec {
    /// Underlying vocabulary size (observed vocabulary will be smaller
    /// for small N — Heaps' law).
    pub vocab: usize,
    /// Zipf exponent (≈1 for natural language).
    pub exponent: f64,
    /// Number of documents.
    pub docs: usize,
    /// Mean document length (lognormal with `len_sigma`).
    pub mean_doc_len: f64,
    /// Lognormal sigma of document length.
    pub len_sigma: f64,
    /// Minimum document length.
    pub min_doc_len: usize,
}

impl ZipfCorpusSpec {
    /// Generate the corpus.
    pub fn generate(&self, seed: u64) -> Corpus {
        let mut rng = Pcg64::new(seed);
        let weights: Vec<f64> =
            (1..=self.vocab).map(|r| 1.0 / (r as f64).powf(self.exponent)).collect();
        let zipf = AliasTable::new(&weights);
        // lognormal(mu, sigma) with mean = mean_doc_len
        let sigma = self.len_sigma;
        let mu = self.mean_doc_len.ln() - 0.5 * sigma * sigma;
        let mut docs = Vec::with_capacity(self.docs);
        for _ in 0..self.docs {
            let len = (mu + sigma * dist::std_normal(&mut rng)).exp().round() as usize;
            let len = len.max(self.min_doc_len);
            let mut doc = Vec::with_capacity(len);
            for _ in 0..len {
                doc.push(zipf.sample(&mut rng) as u32);
            }
            docs.push(doc);
        }
        Corpus { docs, vocab: pseudo_vocab(self.vocab) }
    }

    /// Generate straight into the packed arena — same RNG consumption
    /// as [`ZipfCorpusSpec::generate`], so the token stream is
    /// identical, but without the nested per-document vectors (the
    /// form the ingest benches use at scale).
    pub fn generate_packed(&self, seed: u64) -> PackedCorpus {
        let mut rng = Pcg64::new(seed);
        let weights: Vec<f64> =
            (1..=self.vocab).map(|r| 1.0 / (r as f64).powf(self.exponent)).collect();
        let zipf = AliasTable::new(&weights);
        let sigma = self.len_sigma;
        let mu = self.mean_doc_len.ln() - 0.5 * sigma * sigma;
        let mut tokens = Vec::new();
        let mut doc_offsets = Vec::with_capacity(self.docs + 1);
        doc_offsets.push(0u64);
        for _ in 0..self.docs {
            let len = (mu + sigma * dist::std_normal(&mut rng)).exp().round() as usize;
            let len = len.max(self.min_doc_len);
            tokens.reserve(len);
            for _ in 0..len {
                tokens.push(zipf.sample(&mut rng) as u32);
            }
            doc_offsets.push(tokens.len() as u64);
        }
        PackedCorpus::from_parts(tokens, doc_offsets, pseudo_vocab(self.vocab))
            .expect("generator preserves CSR invariants")
    }
}

/// HDP generative-model corpus parameters.
#[derive(Clone, Debug)]
pub struct HdpCorpusSpec {
    /// Vocabulary size.
    pub vocab: usize,
    /// Number of planted topics (Ψ is GEM(γ) truncated here).
    pub topics: usize,
    /// GEM concentration for the planted Ψ.
    pub gamma: f64,
    /// Document-level concentration: θ_d ~ Dir(α·Ψ).
    pub alpha: f64,
    /// Topic-word Dirichlet concentration (small → sparse, distinct
    /// topics).
    pub topic_beta: f64,
    /// Number of documents.
    pub docs: usize,
    /// Mean document length (lognormal with `len_sigma`).
    pub mean_doc_len: f64,
    /// Lognormal sigma of document length.
    pub len_sigma: f64,
    /// Minimum document length.
    pub min_doc_len: usize,
}

/// Planted ground truth of an HDP-generated corpus.
#[derive(Clone, Debug)]
pub struct HdpGroundTruth {
    /// Planted global topic distribution (length = spec.topics).
    pub psi: Vec<f64>,
    /// Planted topic-word distributions, `phi[k][v]`.
    pub phi: Vec<Vec<f64>>,
    /// True topic of every token, aligned with `corpus.docs`.
    pub z: Vec<Vec<u32>>,
}

impl HdpCorpusSpec {
    /// Generate corpus + ground truth.
    pub fn generate(&self, seed: u64) -> (Corpus, HdpGroundTruth) {
        let mut rng = Pcg64::new(seed);
        // Planted Ψ: truncated GEM(γ), renormalized.
        let mut psi = Vec::with_capacity(self.topics);
        let mut remaining = 1.0f64;
        for _ in 0..self.topics {
            let s = dist::beta(&mut rng, 1.0, self.gamma);
            psi.push(remaining * s);
            remaining *= 1.0 - s;
        }
        let total: f64 = psi.iter().sum();
        psi.iter_mut().for_each(|p| *p /= total);
        // Planted topics: sparse symmetric Dirichlet rows.
        let phi: Vec<Vec<f64>> = (0..self.topics)
            .map(|_| dist::symmetric_dirichlet(&mut rng, self.topic_beta, self.vocab))
            .collect();
        let phi_alias: Vec<AliasTable> =
            phi.iter().map(|row| AliasTable::new(row)).collect();
        let sigma = self.len_sigma;
        let mu = self.mean_doc_len.ln() - 0.5 * sigma * sigma;
        let alpha_psi: Vec<f64> = psi.iter().map(|p| self.alpha * p).collect();
        let mut docs = Vec::with_capacity(self.docs);
        let mut zs = Vec::with_capacity(self.docs);
        let mut theta = vec![0.0f64; self.topics];
        for _ in 0..self.docs {
            let len = (mu + sigma * dist::std_normal(&mut rng)).exp().round() as usize;
            let len = len.max(self.min_doc_len);
            dist::dirichlet_into(&mut rng, &alpha_psi, &mut theta);
            let theta_alias = AliasTable::new(&theta);
            let mut doc = Vec::with_capacity(len);
            let mut z = Vec::with_capacity(len);
            for _ in 0..len {
                let k = theta_alias.sample(&mut rng);
                z.push(k as u32);
                doc.push(phi_alias[k].sample(&mut rng) as u32);
            }
            docs.push(doc);
            zs.push(z);
        }
        (
            Corpus { docs, vocab: pseudo_vocab(self.vocab) },
            HdpGroundTruth { psi, phi, z: zs },
        )
    }

    /// Generate corpus + ground truth with the corpus in packed arena
    /// form (a conversion of [`HdpCorpusSpec::generate`], so the two
    /// always agree token-for-token).
    pub fn generate_packed(&self, seed: u64) -> (PackedCorpus, HdpGroundTruth) {
        let (c, truth) = self.generate(seed);
        (c.to_packed(), truth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pseudo_words_distinct() {
        let v = pseudo_vocab(5000);
        let set: std::collections::HashSet<&String> = v.iter().collect();
        assert_eq!(set.len(), 5000);
        assert!(v.iter().all(|w| w.len() >= 4));
    }

    #[test]
    fn zipf_corpus_shape() {
        let spec = ZipfCorpusSpec {
            vocab: 2000,
            exponent: 1.05,
            docs: 200,
            mean_doc_len: 60.0,
            len_sigma: 0.5,
            min_doc_len: 5,
        };
        let c = spec.generate(1);
        c.validate().unwrap();
        assert_eq!(c.num_docs(), 200);
        let mean = c.num_tokens() as f64 / 200.0;
        assert!((mean - 60.0).abs() < 12.0, "mean len {mean}");
        // Zipf head dominance: most frequent word should have far more
        // mass than rank ~100.
        let counts = c.word_counts();
        let mut sorted = counts.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        assert!(sorted[0] > 10 * sorted[100].max(1) / 2);
    }

    #[test]
    fn zipf_heaps_growth() {
        // Observed vocabulary grows sublinearly in N.
        let gen = |docs: usize| {
            ZipfCorpusSpec {
                vocab: 50_000,
                exponent: 1.1,
                docs,
                mean_doc_len: 50.0,
                len_sigma: 0.3,
                min_doc_len: 5,
            }
            .generate(7)
        };
        let small = gen(50);
        let big = gen(800);
        let (vs, ns) = (small.observed_vocab() as f64, small.num_tokens() as f64);
        let (vb, nb) = (big.observed_vocab() as f64, big.num_tokens() as f64);
        let zeta = (vb / vs).ln() / (nb / ns).ln();
        assert!(zeta > 0.3 && zeta < 0.95, "heaps exponent {zeta}");
    }

    #[test]
    fn packed_generators_match_nested() {
        let zspec = ZipfCorpusSpec {
            vocab: 800,
            exponent: 1.05,
            docs: 60,
            mean_doc_len: 30.0,
            len_sigma: 0.4,
            min_doc_len: 5,
        };
        let nested = zspec.generate(9);
        let packed = zspec.generate_packed(9);
        assert_eq!(packed.num_docs(), nested.num_docs());
        assert_eq!(packed.num_tokens(), nested.num_tokens());
        assert_eq!(packed.vocab, nested.vocab);
        for d in 0..nested.num_docs() {
            assert_eq!(packed.doc(d), &nested.docs[d][..], "zipf doc {d}");
        }
        let hspec = HdpCorpusSpec {
            vocab: 300,
            topics: 5,
            gamma: 2.0,
            alpha: 1.5,
            topic_beta: 0.05,
            docs: 40,
            mean_doc_len: 25.0,
            len_sigma: 0.3,
            min_doc_len: 5,
        };
        let (nested, t1) = hspec.generate(5);
        let (packed, t2) = hspec.generate_packed(5);
        assert_eq!(packed.to_nested().docs, nested.docs);
        assert_eq!(t1.z, t2.z);
    }

    #[test]
    fn hdp_corpus_ground_truth_consistent() {
        let spec = HdpCorpusSpec {
            vocab: 500,
            topics: 8,
            gamma: 2.0,
            alpha: 2.0,
            topic_beta: 0.05,
            docs: 100,
            mean_doc_len: 40.0,
            len_sigma: 0.3,
            min_doc_len: 5,
        };
        let (c, truth) = spec.generate(3);
        c.validate().unwrap();
        assert_eq!(truth.phi.len(), 8);
        assert_eq!(truth.z.len(), c.num_docs());
        // psi sums to 1 and is (stochastically) decreasing-ish in k
        assert!((truth.psi.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        for (d, doc) in c.docs.iter().enumerate() {
            assert_eq!(doc.len(), truth.z[d].len());
            assert!(truth.z[d].iter().all(|&k| (k as usize) < 8));
        }
        // Documents should be topic-sparse: mean distinct topics per doc
        // well below the planted topic count.
        let mean_distinct: f64 = truth
            .z
            .iter()
            .map(|z| {
                let set: std::collections::HashSet<&u32> = z.iter().collect();
                set.len() as f64
            })
            .sum::<f64>()
            / c.num_docs() as f64;
        assert!(mean_distinct < 7.0, "docs not sparse: {mean_distinct}");
    }

    #[test]
    fn hdp_tokens_match_planted_topics() {
        // Tokens assigned to topic k should be distributed ~ phi_k:
        // check the chi-square-ish agreement on the most common topic.
        let spec = HdpCorpusSpec {
            vocab: 50,
            topics: 3,
            gamma: 1.0,
            alpha: 5.0,
            topic_beta: 0.2,
            docs: 400,
            mean_doc_len: 80.0,
            len_sigma: 0.2,
            min_doc_len: 10,
        };
        let (c, truth) = spec.generate(11);
        let mut counts = vec![vec![0u64; 50]; 3];
        for (doc, z) in c.docs.iter().zip(&truth.z) {
            for (&w, &k) in doc.iter().zip(z) {
                counts[k as usize][w as usize] += 1;
            }
        }
        for k in 0..3 {
            let total: u64 = counts[k].iter().sum();
            if total < 2000 {
                continue;
            }
            let mut l1 = 0.0;
            for v in 0..50 {
                l1 += (counts[k][v] as f64 / total as f64 - truth.phi[k][v]).abs();
            }
            assert!(l1 < 0.15, "topic {k} l1 distance {l1}");
        }
    }
}
