//! Corpus I/O.
//!
//! * UCI "bag of words" format (the format of the paper's NeurIPS and
//!   PubMed downloads): `docword.txt` has a 3-line header `D`, `V`,
//!   `NNZ` followed by `docId wordId count` triples (both ids
//!   1-based); `vocab.txt` has one word per line.
//! * A compact little-endian binary cache (`.hdpc`) so synthetic corpora
//!   are generated once and reloaded quickly by benches and examples.

use super::Corpus;
use std::io::{BufRead, BufWriter, Read, Write};
use std::path::Path;

/// Read UCI bag-of-words (`docword` stream + `vocab` stream).
///
/// Expansion note: counts are expanded into individual tokens, grouped
/// by document, preserving word-id order within a document — the
/// sampler is exchangeable so any stable order is fine.
pub fn read_uci(docword: impl Read, vocab: impl Read) -> anyhow::Result<Corpus> {
    let mut lines = std::io::BufReader::new(docword).lines();
    let mut header = |name: &str| -> anyhow::Result<usize> {
        let line = lines
            .next()
            .ok_or_else(|| anyhow::anyhow!("missing {name} header"))??;
        Ok(line.trim().parse::<usize>()?)
    };
    let d = header("D")?;
    let v = header("V")?;
    let nnz = header("NNZ")?;
    let mut docs: Vec<Vec<u32>> = vec![Vec::new(); d];
    let mut seen = 0usize;
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() {
            continue;
        }
        let mut it = t.split_ascii_whitespace();
        let (Some(ds), Some(ws), Some(cs)) = (it.next(), it.next(), it.next()) else {
            anyhow::bail!("malformed triple: `{t}`");
        };
        let di: usize = ds.parse()?;
        let wi: usize = ws.parse()?;
        let c: usize = cs.parse()?;
        anyhow::ensure!(di >= 1 && di <= d, "doc id {di} out of range 1..={d}");
        anyhow::ensure!(wi >= 1 && wi <= v, "word id {wi} out of range 1..={v}");
        let doc = &mut docs[di - 1];
        doc.extend(std::iter::repeat((wi - 1) as u32).take(c));
        seen += 1;
    }
    anyhow::ensure!(seen == nnz, "expected {nnz} triples, read {seen}");
    let vocab: Vec<String> = std::io::BufReader::new(vocab)
        .lines()
        .collect::<Result<Vec<_>, _>>()?
        .into_iter()
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    anyhow::ensure!(
        vocab.len() == v,
        "vocab has {} entries, header says {v}",
        vocab.len()
    );
    Ok(Corpus { docs, vocab })
}

/// Read UCI bag-of-words from file paths.
pub fn read_uci_files(docword: &Path, vocab: &Path) -> anyhow::Result<Corpus> {
    Ok(read_uci(
        std::fs::File::open(docword)
            .map_err(|e| anyhow::anyhow!("open {}: {e}", docword.display()))?,
        std::fs::File::open(vocab)
            .map_err(|e| anyhow::anyhow!("open {}: {e}", vocab.display()))?,
    )?)
}

/// Write UCI bag-of-words files.
pub fn write_uci(corpus: &Corpus, docword: &Path, vocab: &Path) -> anyhow::Result<()> {
    let mut triples: Vec<(u32, u32, u32)> = Vec::new();
    for (d, doc) in corpus.docs.iter().enumerate() {
        let mut counts = std::collections::BTreeMap::new();
        for &w in doc {
            *counts.entry(w).or_insert(0u32) += 1;
        }
        for (w, c) in counts {
            triples.push((d as u32 + 1, w + 1, c));
        }
    }
    let mut f = BufWriter::new(std::fs::File::create(docword)?);
    writeln!(f, "{}", corpus.num_docs())?;
    writeln!(f, "{}", corpus.vocab_size())?;
    writeln!(f, "{}", triples.len())?;
    for (d, w, c) in triples {
        writeln!(f, "{d} {w} {c}")?;
    }
    f.flush()?;
    let mut f = BufWriter::new(std::fs::File::create(vocab)?);
    for w in &corpus.vocab {
        writeln!(f, "{w}")?;
    }
    f.flush()?;
    Ok(())
}

const MAGIC: &[u8; 8] = b"HDPCORP1";

/// Write the compact binary cache.
pub fn write_binary(corpus: &Corpus, path: &Path) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    write_u64(&mut f, corpus.docs.len() as u64)?;
    write_u64(&mut f, corpus.vocab.len() as u64)?;
    for doc in &corpus.docs {
        write_u64(&mut f, doc.len() as u64)?;
        for &w in doc {
            f.write_all(&w.to_le_bytes())?;
        }
    }
    for w in &corpus.vocab {
        let bytes = w.as_bytes();
        write_u64(&mut f, bytes.len() as u64)?;
        f.write_all(bytes)?;
    }
    f.flush()?;
    Ok(())
}

/// Read the compact binary cache.
pub fn read_binary(path: &Path) -> anyhow::Result<Corpus> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not an hdp corpus cache: {}", path.display());
    let d = read_u64(&mut f)? as usize;
    let v = read_u64(&mut f)? as usize;
    let mut docs = Vec::with_capacity(d);
    for _ in 0..d {
        let len = read_u64(&mut f)? as usize;
        let mut buf = vec![0u8; len * 4];
        f.read_exact(&mut buf)?;
        let doc: Vec<u32> = buf
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        docs.push(doc);
    }
    let mut vocab = Vec::with_capacity(v);
    for _ in 0..v {
        let len = read_u64(&mut f)? as usize;
        let mut buf = vec![0u8; len];
        f.read_exact(&mut buf)?;
        vocab.push(String::from_utf8(buf)?);
    }
    let corpus = Corpus { docs, vocab };
    corpus.validate()?;
    Ok(corpus)
}

fn write_u64(f: &mut impl Write, x: u64) -> std::io::Result<()> {
    f.write_all(&x.to_le_bytes())
}

fn read_u64(f: &mut impl Read) -> anyhow::Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Corpus {
        Corpus {
            docs: vec![vec![0, 0, 2], vec![1], vec![2, 1]],
            vocab: vec!["alpha".into(), "beta".into(), "gamma".into()],
        }
    }

    #[test]
    fn uci_roundtrip() {
        let c = sample();
        let dir = std::env::temp_dir().join("hdp_uci_test");
        std::fs::create_dir_all(&dir).unwrap();
        let dw = dir.join("docword.txt");
        let vc = dir.join("vocab.txt");
        write_uci(&c, &dw, &vc).unwrap();
        let back = read_uci_files(&dw, &vc).unwrap();
        assert_eq!(back.vocab, c.vocab);
        assert_eq!(back.num_tokens(), c.num_tokens());
        // Bag-of-words equality per document.
        for (a, b) in c.docs.iter().zip(&back.docs) {
            let mut a = a.clone();
            let mut b = b.clone();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn uci_parses_reference_text() {
        let docword = "2\n3\n3\n1 1 2\n1 3 1\n2 2 5\n";
        let vocab = "x\ny\nz\n";
        let c = read_uci(docword.as_bytes(), vocab.as_bytes()).unwrap();
        assert_eq!(c.num_docs(), 2);
        assert_eq!(c.docs[0], vec![0, 0, 2]);
        assert_eq!(c.docs[1], vec![1, 1, 1, 1, 1]);
    }

    #[test]
    fn uci_rejects_bad_input() {
        assert!(read_uci("2\n3\n".as_bytes(), "x\n".as_bytes()).is_err());
        // out-of-range word id
        let bad = "1\n2\n1\n1 9 1\n";
        assert!(read_uci(bad.as_bytes(), "x\ny\n".as_bytes()).is_err());
        // nnz mismatch
        let bad = "1\n2\n5\n1 1 1\n";
        assert!(read_uci(bad.as_bytes(), "x\ny\n".as_bytes()).is_err());
        // vocab length mismatch
        let bad = "1\n2\n1\n1 1 1\n";
        assert!(read_uci(bad.as_bytes(), "x\n".as_bytes()).is_err());
    }

    #[test]
    fn binary_roundtrip_exact() {
        let c = sample();
        let path = std::env::temp_dir().join("hdp_bin_test/corpus.hdpc");
        write_binary(&c, &path).unwrap();
        let back = read_binary(&path).unwrap();
        assert_eq!(back.docs, c.docs); // exact, including token order
        assert_eq!(back.vocab, c.vocab);
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn binary_rejects_garbage() {
        let path = std::env::temp_dir().join("hdp_bin_test2/garbage.hdpc");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, b"not a corpus").unwrap();
        assert!(read_binary(&path).is_err());
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }
}
