//! Corpus I/O.
//!
//! * UCI "bag of words" format (the format of the paper's NeurIPS and
//!   PubMed downloads): `docword.txt` has a 3-line header `D`, `V`,
//!   `NNZ` followed by `docId wordId count` triples (both ids
//!   1-based); `vocab.txt` has one word per line.
//! * A compact little-endian binary cache (`.hdpc`) so synthetic corpora
//!   are generated once and reloaded quickly by benches and examples.
//! * The **packed corpus format** (`.hdpp`) — the on-disk twin of
//!   [`PackedCorpus`], designed so the token arena can be memory-mapped
//!   or block-read without parsing.
//!
//! # Packed on-disk format (version 1)
//!
//! All integers are **little-endian**. The file is a fixed-size header
//! followed by three sections at alignment-friendly offsets (the
//! offsets section is 8-byte aligned, the token section 4-byte
//! aligned), so an mmap of the file can serve `doc_offsets` and
//! `tokens` in place:
//!
//! ```text
//! byte 0   magic       [u8; 8]  = b"HDPPACK\0"
//! byte 8   version     u32      = 1
//! byte 12  flags       u32      bit 0 = PACKED_FLAG_CRC (see below)
//! byte 16  D           u64      number of documents
//! byte 24  V           u64      number of vocabulary entries
//! byte 32  N           u64      number of tokens (== doc_offsets[D])
//! byte 40  doc_offsets (D+1) × u64   CSR offsets, doc_offsets[0] == 0
//! ...      tokens      N × u32       the flat token arena
//! ...      vocab       V × { len u64, len × u8 (UTF-8) }
//! [trailer [crc32 u32 LE][b"HSUM"]   iff PACKED_FLAG_CRC]
//! ```
//!
//! Document `d` occupies tokens `doc_offsets[d] .. doc_offsets[d+1]`;
//! a contiguous *document block* is therefore a contiguous *byte
//! range* of the token section, which is what
//! [`PackedCorpusFile::read_block`] exploits for out-of-core sweeps.
//! Readers return a clean `Err` (never panic) on truncated files, bad
//! magic, unsupported versions, unknown flag bits, or inconsistent
//! offsets; all claimed section sizes are checked against the file
//! length *before* any allocation.
//!
//! ## Crash-recovery contract
//!
//! [`write_packed`] writes **atomically** via
//! [`crate::durable::atomic_write`] — temp file in the same directory,
//! data fsync, rename, parent-directory fsync — so a crash mid-write
//! can never leave a half-written `.hdpp` at the final path, and sets
//! `PACKED_FLAG_CRC`: an IEEE CRC-32 over every byte before the
//! trailer, appended as the 8-byte trailer `[crc u32 LE][b"HSUM"]`
//! (see [`crate::durable`]). Verifying readers ([`read_packed`],
//! [`PackedCorpusFile::open`]) recompute the CRC over the whole
//! payload and fail closed (`Err`, never a panic or partial value) on
//! **any** truncation, extension, or single-bit flip. Files with
//! `flags == 0` (written before the trailer existed) still load, but
//! a flag-0 file that nonetheless ends in a `b"HSUM"` tag is rejected
//! as corrupt — that shape only arises from a damaged flags field.
//! Unknown flag bits are rejected.
//!
//! ## Failpoint sites
//!
//! With the `failpoints` feature on (see [`crate::fault`]), the write
//! pipeline checks the `packed.write` / `packed.sync` /
//! `packed.rename` / `packed.dirsync` sites, and every positioned
//! block read/write checks `corpus.pread` / `corpus.pwrite`
//! ([`PackedCorpusFile`]) or `filez.pread` / `filez.pwrite`
//! ([`crate::hdp::pc::zstep::FileZ`]). Positioned block I/O retries
//! transient errors with bounded backoff ([`IO_RETRIES`]); the atomic
//! write pipeline deliberately never retries — a failed save surfaces
//! as `Err` with the previous file intact.
//!
//! ## Positioned-I/O contract
//!
//! All three sections are written once and never mutated in place, and
//! the token bytes of documents `[d0, d1)` are the contiguous range
//!
//! ```text
//! [40 + (D+1)·8 + doc_offsets[d0]·4,  40 + (D+1)·8 + doc_offsets[d1]·4)
//! ```
//!
//! Because `doc_offsets` is monotone, **disjoint document blocks map to
//! disjoint byte ranges**: readers may issue concurrent positioned
//! reads (`pread`) against one shared descriptor with no locking and no
//! shared cursor. [`PackedCorpusFile::read_block`] does exactly that on
//! unix (a `Seek`-based mutex fallback covers other platforms), which
//! is what lets every streamed-sweep slot — and the block prefetcher's
//! async loads — serve blocks from a single open file in parallel. The
//! file-backed z arena ([`crate::hdp::pc::zstep::FileZ`]) stores raw
//! little-endian u32s at `doc_offsets[d]·4` with no header and honors
//! the same contract for both reads and writes.
//!
//! ## Memory-mapping contract
//!
//! [`PackedCorpusFile::open_mmap`] serves token blocks from a
//! read-only `MAP_SHARED` mapping instead of `pread` when the platform
//! allows it. The format was laid out for this:
//!
//! * the mapping starts at byte 0 of the file, so it is page-aligned;
//! * `doc_offsets` starts at byte 40 (8-aligned) and occupies
//!   `(D+1)·8` bytes, so the token section starts at
//!   `40 + (D+1)·8` — always a multiple of 8, hence 4-aligned within
//!   the page-aligned mapping: the token bytes may be reinterpreted as
//!   a `&[u32]` in place with no copy and no alignment fixup;
//! * integers are little-endian, so the in-place reinterpret is
//!   value-correct only on little-endian targets — big-endian hosts
//!   fall back to the positioned-read path (which byte-swaps);
//! * the mapping covers exactly the header + offsets + token sections
//!   (never the vocab tail), and that length is validated against the
//!   file size at open, so no access can fault past EOF;
//! * the file is written once and never mutated in place (see the
//!   positioned-I/O contract), so a shared mapping can never observe a
//!   torn update.
//!
//! The binding is vendored (direct `mmap`/`munmap` externs against the
//! libc the std binary already links — no new dependency), linux-only,
//! and **always optional**: any mapping failure (`EINVAL`, `ENOMEM`,
//! an unsupported platform, a big-endian host) degrades silently to
//! the positioned-read path, which serves bit-identical tokens.

use super::{Corpus, PackedCorpus};
use std::io::{BufRead, BufWriter, Read, Write};
use std::path::Path;
#[cfg(not(unix))]
use std::sync::Mutex;

/// Cap on the total expanded token count accepted from a UCI stream:
/// each token occupies 4 resident bytes, so 2³² tokens ≈ 16 GiB of
/// arena — beyond anything this in-memory loader should expand (the
/// paper's PubMed is 768M tokens) and low enough to reject a corrupt
/// count field *before* `repeat(..).take(c)` tries to materialize it.
const MAX_UCI_TOKENS: u64 = 1 << 32;

/// Read UCI bag-of-words (`docword` stream + `vocab` stream).
///
/// Expansion note: counts are expanded into individual tokens, grouped
/// by document, preserving word-id order within a document — the
/// sampler is exchangeable so any stable order is fine. Triples must
/// carry a positive count (`c == 0` would silently skew the `NNZ`
/// accounting) and the running token total is validated against
/// [`MAX_UCI_TOKENS`] before any expansion.
pub fn read_uci(docword: impl Read, vocab: impl Read) -> anyhow::Result<Corpus> {
    let mut lines = std::io::BufReader::new(docword).lines();
    let mut header = |name: &str| -> anyhow::Result<usize> {
        let line = lines
            .next()
            .ok_or_else(|| anyhow::anyhow!("missing {name} header"))??;
        Ok(line.trim().parse::<usize>()?)
    };
    let d = header("D")?;
    let v = header("V")?;
    let nnz = header("NNZ")?;
    let mut docs: Vec<Vec<u32>> = vec![Vec::new(); d];
    let mut seen = 0usize;
    let mut total_tokens = 0u64;
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() {
            continue;
        }
        let mut it = t.split_ascii_whitespace();
        let (Some(ds), Some(ws), Some(cs)) = (it.next(), it.next(), it.next()) else {
            anyhow::bail!("malformed triple: `{t}`");
        };
        let di: usize = ds.parse()?;
        let wi: usize = ws.parse()?;
        let c: usize = cs.parse()?;
        anyhow::ensure!(di >= 1 && di <= d, "doc id {di} out of range 1..={d}");
        anyhow::ensure!(wi >= 1 && wi <= v, "word id {wi} out of range 1..={v}");
        anyhow::ensure!(c >= 1, "zero-count triple: `{t}`");
        // checked_add: a count near 2^64 must hit this Err, not wrap
        // past the bound (release) or panic (debug).
        total_tokens = match total_tokens.checked_add(c as u64) {
            Some(tot) if tot <= MAX_UCI_TOKENS => tot,
            _ => anyhow::bail!(
                "token total exceeds the {MAX_UCI_TOKENS} sanity bound at `{t}`"
            ),
        };
        let doc = &mut docs[di - 1];
        doc.extend(std::iter::repeat((wi - 1) as u32).take(c));
        seen += 1;
    }
    anyhow::ensure!(seen == nnz, "expected {nnz} triples, read {seen}");
    let vocab: Vec<String> = std::io::BufReader::new(vocab)
        .lines()
        .collect::<Result<Vec<_>, _>>()?
        .into_iter()
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    anyhow::ensure!(
        vocab.len() == v,
        "vocab has {} entries, header says {v}",
        vocab.len()
    );
    Ok(Corpus { docs, vocab })
}

/// Read UCI bag-of-words from file paths.
pub fn read_uci_files(docword: &Path, vocab: &Path) -> anyhow::Result<Corpus> {
    Ok(read_uci(
        std::fs::File::open(docword)
            .map_err(|e| anyhow::anyhow!("open {}: {e}", docword.display()))?,
        std::fs::File::open(vocab)
            .map_err(|e| anyhow::anyhow!("open {}: {e}", vocab.display()))?,
    )?)
}

/// Write UCI bag-of-words files.
pub fn write_uci(corpus: &Corpus, docword: &Path, vocab: &Path) -> anyhow::Result<()> {
    let mut triples: Vec<(u32, u32, u32)> = Vec::new();
    for (d, doc) in corpus.docs.iter().enumerate() {
        let mut counts = std::collections::BTreeMap::new();
        for &w in doc {
            *counts.entry(w).or_insert(0u32) += 1;
        }
        for (w, c) in counts {
            triples.push((d as u32 + 1, w + 1, c));
        }
    }
    let mut f = BufWriter::new(std::fs::File::create(docword)?);
    writeln!(f, "{}", corpus.num_docs())?;
    writeln!(f, "{}", corpus.vocab_size())?;
    writeln!(f, "{}", triples.len())?;
    for (d, w, c) in triples {
        writeln!(f, "{d} {w} {c}")?;
    }
    f.flush()?;
    let mut f = BufWriter::new(std::fs::File::create(vocab)?);
    for w in &corpus.vocab {
        writeln!(f, "{w}")?;
    }
    f.flush()?;
    Ok(())
}

const MAGIC: &[u8; 8] = b"HDPCORP1";

/// Write the compact binary cache.
pub fn write_binary(corpus: &Corpus, path: &Path) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    write_u64(&mut f, corpus.docs.len() as u64)?;
    write_u64(&mut f, corpus.vocab.len() as u64)?;
    for doc in &corpus.docs {
        write_u64(&mut f, doc.len() as u64)?;
        for &w in doc {
            f.write_all(&w.to_le_bytes())?;
        }
    }
    for w in &corpus.vocab {
        let bytes = w.as_bytes();
        write_u64(&mut f, bytes.len() as u64)?;
        f.write_all(bytes)?;
    }
    f.flush()?;
    Ok(())
}

/// Read the compact binary cache.
pub fn read_binary(path: &Path) -> anyhow::Result<Corpus> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not an hdp corpus cache: {}", path.display());
    let d = read_u64(&mut f)? as usize;
    let v = read_u64(&mut f)? as usize;
    let mut docs = Vec::with_capacity(d);
    for _ in 0..d {
        let len = read_u64(&mut f)? as usize;
        let mut buf = vec![0u8; len * 4];
        f.read_exact(&mut buf)?;
        let doc: Vec<u32> = buf
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        docs.push(doc);
    }
    let mut vocab = Vec::with_capacity(v);
    for _ in 0..v {
        let len = read_u64(&mut f)? as usize;
        let mut buf = vec![0u8; len];
        f.read_exact(&mut buf)?;
        vocab.push(String::from_utf8(buf)?);
    }
    let corpus = Corpus { docs, vocab };
    corpus.validate()?;
    Ok(corpus)
}

fn write_u64<W: Write + ?Sized>(f: &mut W, x: u64) -> std::io::Result<()> {
    f.write_all(&x.to_le_bytes())
}

fn read_u64(f: &mut impl Read) -> anyhow::Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_u32(f: &mut impl Read) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Read `n` little-endian u64s.
fn read_u64s(f: &mut impl Read, n: usize) -> anyhow::Result<Vec<u64>> {
    let mut out = Vec::with_capacity(n);
    let mut bytes = [0u8; 4096];
    let mut left = n;
    while left > 0 {
        let take = (left * 8).min(bytes.len());
        f.read_exact(&mut bytes[..take])?;
        for c in bytes[..take].chunks_exact(8) {
            out.push(u64::from_le_bytes(c.try_into().unwrap()));
        }
        left -= take / 8;
    }
    Ok(out)
}

/// Read `n` little-endian u32s, appending to `out`.
pub(crate) fn read_u32s_into(
    f: &mut impl Read,
    n: usize,
    out: &mut Vec<u32>,
) -> std::io::Result<()> {
    out.reserve(n);
    let mut bytes = [0u8; 4096];
    let mut left = n;
    while left > 0 {
        let take = (left * 4).min(bytes.len());
        f.read_exact(&mut bytes[..take])?;
        for c in bytes[..take].chunks_exact(4) {
            out.push(u32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        left -= take / 4;
    }
    Ok(())
}

/// Write a u32 slice as little-endian bytes.
pub(crate) fn write_u32s<W: Write + ?Sized>(f: &mut W, xs: &[u32]) -> std::io::Result<()> {
    let mut bytes = [0u8; 4096];
    for chunk in xs.chunks(bytes.len() / 4) {
        for (i, &x) in chunk.iter().enumerate() {
            bytes[i * 4..i * 4 + 4].copy_from_slice(&x.to_le_bytes());
        }
        f.write_all(&bytes[..chunk.len() * 4])?;
    }
    Ok(())
}

/// Magic of the packed corpus format (see the module docs).
pub const PACKED_MAGIC: &[u8; 8] = b"HDPPACK\0";
/// Current packed format version.
pub const PACKED_VERSION: u32 = 1;
/// Fixed header size in bytes; `doc_offsets` starts here.
pub const PACKED_HEADER_BYTES: u64 = 40;
/// Flags bit 0: the file carries the CRC-32 checksum trailer
/// ([`crate::durable::TRAILER_TAG`]). Set by [`write_packed`];
/// verified by both readers.
pub const PACKED_FLAG_CRC: u32 = 1;

/// Write a [`PackedCorpus`] in the packed on-disk format — atomically
/// (temp + fsync + rename + dir-fsync) and with the checksum trailer
/// (`PACKED_FLAG_CRC`; parent directories created). A crash anywhere
/// during the write leaves any previous file at `path` intact.
pub fn write_packed(corpus: &PackedCorpus, path: &Path) -> anyhow::Result<()> {
    crate::durable::atomic_write(path, &crate::durable::PACKED_SITES, |f| {
        f.write_all(PACKED_MAGIC)?;
        f.write_all(&PACKED_VERSION.to_le_bytes())?;
        f.write_all(&PACKED_FLAG_CRC.to_le_bytes())?;
        write_u64(f, corpus.num_docs() as u64)?;
        write_u64(f, corpus.vocab.len() as u64)?;
        write_u64(f, corpus.num_tokens())?;
        for &o in corpus.doc_offsets() {
            write_u64(f, o)?;
        }
        write_u32s(f, corpus.tokens())?;
        for w in &corpus.vocab {
            let bytes = w.as_bytes();
            write_u64(f, bytes.len() as u64)?;
            f.write_all(bytes)?;
        }
        Ok(())
    })
}

/// Parsed packed header: `(D, V, N, flags)`. Checks magic, version,
/// flag bits, and that the fixed sections fit inside `file_len` before
/// anything allocates.
fn read_packed_header<R: Read + ?Sized>(
    f: &mut R,
    file_len: u64,
    path: &Path,
) -> anyhow::Result<(u64, u64, u64, u32)> {
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(
        &magic == PACKED_MAGIC,
        "not a packed hdp corpus: {}",
        path.display()
    );
    let version = read_u32(f)?;
    anyhow::ensure!(
        version == PACKED_VERSION,
        "unsupported packed corpus version {version} (expected {PACKED_VERSION}): {}",
        path.display()
    );
    let flags = read_u32(f)?;
    anyhow::ensure!(
        flags & !PACKED_FLAG_CRC == 0,
        "unknown packed corpus flag bits {flags:#x}: {}",
        path.display()
    );
    let d = read_u64(f)?;
    let v = read_u64(f)?;
    let n = read_u64(f)?;
    // Fixed-size sections must fit in the file — this bounds every
    // allocation below by the actual file size (a corrupt header can
    // not trigger an absurd reservation).
    let need: u128 = PACKED_HEADER_BYTES as u128 + (d as u128 + 1) * 8 + n as u128 * 4;
    anyhow::ensure!(
        need <= file_len as u128,
        "truncated packed corpus: header claims D={d} N={n} ({need} bytes) but file has {file_len}"
    );
    Ok((d, v, n, flags))
}

/// Read a packed corpus fully into memory, verifying the checksum
/// trailer when the file carries one (module docs).
pub fn read_packed(path: &Path) -> anyhow::Result<PackedCorpus> {
    let file = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?;
    let file_len = file.metadata()?.len();
    // Hash above the buffering so the digest covers exactly the bytes
    // the parser consumes (BufReader read-ahead must not pollute it).
    let mut f = crate::durable::HashingReader::new(std::io::BufReader::new(file));
    let (d, v, n, flags) = read_packed_header(&mut f, file_len, path)?;
    let doc_offsets = read_u64s(&mut f, d as usize + 1)?;
    let mut tokens = Vec::new();
    read_u32s_into(&mut f, n as usize, &mut tokens)?;
    let mut vocab = Vec::with_capacity((v as usize).min(file_len as usize / 8 + 1));
    for _ in 0..v {
        let len = read_u64(&mut f)? as usize;
        anyhow::ensure!(len as u64 <= file_len, "corrupt vocab entry length {len}");
        let mut buf = vec![0u8; len];
        f.read_exact(&mut buf)?;
        vocab.push(String::from_utf8(buf)?);
    }
    if flags & PACKED_FLAG_CRC != 0 {
        let payload = crate::durable::payload_len(file_len, "packed corpus")?;
        crate::durable::verify_trailer(&mut f, payload, "packed corpus")?;
    } else {
        anyhow::ensure!(
            f.consumed() == file_len,
            "corrupt packed corpus: {} trailing bytes after the vocab section",
            file_len - f.consumed()
        );
    }
    let corpus = PackedCorpus::from_parts(tokens, doc_offsets, vocab)?;
    corpus.validate()?;
    Ok(corpus)
}

/// Positioned block I/O over an open file.
///
/// On unix every call is a single lock-free `pread`/`pwrite`
/// ([`std::os::unix::fs::FileExt`]): concurrent callers serving
/// **disjoint** byte ranges never touch a shared cursor or a lock,
/// which is what lets every streamed-sweep slot (and the prefetcher's
/// async loads) hit one descriptor in parallel. Elsewhere a
/// `Seek`-based fallback serializes on an internal mutex with the same
/// semantics. Callers guarantee range disjointness (the positioned-I/O
/// contract in the module docs); overlapping concurrent writes would
/// race at the OS level exactly as they would with `pwrite`.
pub(crate) struct PositionedFile {
    #[cfg(unix)]
    file: std::fs::File,
    #[cfg(not(unix))]
    file: Mutex<std::fs::File>,
    /// Failpoint site names checked on every (read, write); also the
    /// label under which transient faults are injected in tests.
    #[cfg_attr(not(feature = "failpoints"), allow(dead_code))]
    sites: (&'static str, &'static str),
}

/// Bounded retry budget for positioned block I/O: transient errors
/// (interrupted syscalls, injected `fault` errors, out-of-resource
/// blips) are retried up to this many times with exponential backoff
/// before surfacing. Deterministic corruption signals (EOF, invalid
/// data, …) are never retried — see [`retryable`].
pub(crate) const IO_RETRIES: u32 = 3;

/// Whether an I/O error class can plausibly heal on retry. Structural
/// errors — the file is too short, the data is bad, the path is gone —
/// are final; retrying them would only mask corruption.
fn retryable(e: &std::io::Error) -> bool {
    !matches!(
        e.kind(),
        std::io::ErrorKind::UnexpectedEof
            | std::io::ErrorKind::NotFound
            | std::io::ErrorKind::PermissionDenied
            | std::io::ErrorKind::InvalidInput
            | std::io::ErrorKind::InvalidData
            | std::io::ErrorKind::WriteZero
            | std::io::ErrorKind::AlreadyExists
    )
}

/// Run `op` with up to [`IO_RETRIES`] retries on transient errors,
/// backing off 200/400/800 µs between attempts. `op` must be
/// idempotent — positioned reads/writes of a fixed range are.
fn with_io_retries(mut op: impl FnMut() -> std::io::Result<()>) -> std::io::Result<()> {
    let mut attempt = 0u32;
    loop {
        match op() {
            Ok(()) => return Ok(()),
            Err(e) if attempt < IO_RETRIES && retryable(&e) => {
                attempt += 1;
                std::thread::sleep(std::time::Duration::from_micros(100u64 << attempt));
            }
            Err(e) => return Err(e),
        }
    }
}

impl PositionedFile {
    /// Wrap an open file for positioned access (the current cursor
    /// position is irrelevant from here on). `sites` names the
    /// failpoint checked before each (read, write).
    pub(crate) fn new(file: std::fs::File, sites: (&'static str, &'static str)) -> Self {
        #[cfg(not(unix))]
        let file = Mutex::new(file);
        Self { file, sites }
    }

    /// Read exactly `n` little-endian u32s at byte `offset` into `out`
    /// (cleared first), as one positioned read.
    pub(crate) fn read_u32s_at(
        &self,
        offset: u64,
        n: usize,
        out: &mut Vec<u32>,
    ) -> std::io::Result<()> {
        out.clear();
        out.resize(n, 0);
        // SAFETY: `out` is an initialized, uniquely borrowed u32
        // buffer; u8 has no alignment requirement, and the
        // little-endian fixup below restores the value contract.
        let bytes =
            unsafe { std::slice::from_raw_parts_mut(out.as_mut_ptr().cast::<u8>(), n * 4) };
        self.read_exact_at(bytes, offset)?;
        if cfg!(target_endian = "big") {
            for x in out.iter_mut() {
                *x = u32::from_le(*x);
            }
        }
        Ok(())
    }

    /// Write `xs` as little-endian bytes at byte `offset`.
    pub(crate) fn write_u32s_at(&self, offset: u64, xs: &[u32]) -> std::io::Result<()> {
        if cfg!(target_endian = "little") {
            // In-memory layout == on-disk layout: one positioned
            // write of the whole block.
            // SAFETY: plain shared reinterpret of initialized u32s.
            let bytes =
                unsafe { std::slice::from_raw_parts(xs.as_ptr().cast::<u8>(), xs.len() * 4) };
            return self.write_all_at(bytes, offset);
        }
        // Big-endian fallback: convert through a stack chunk.
        let mut bytes = [0u8; 4096];
        let mut pos = offset;
        for chunk in xs.chunks(bytes.len() / 4) {
            for (i, &x) in chunk.iter().enumerate() {
                bytes[i * 4..i * 4 + 4].copy_from_slice(&x.to_le_bytes());
            }
            self.write_all_at(&bytes[..chunk.len() * 4], pos)?;
            pos += chunk.len() as u64 * 4;
        }
        Ok(())
    }

    /// Positioned exact read at `offset`: failpoint-checked, with
    /// bounded retry on transient errors. Retrying is safe because the
    /// read targets a fixed range and overwrites `bytes` from scratch.
    fn read_exact_at(&self, bytes: &mut [u8], offset: u64) -> std::io::Result<()> {
        with_io_retries(|| {
            crate::fault::check(self.sites.0)?;
            self.read_exact_at_raw(bytes, offset)
        })
    }

    /// Positioned `write_all` at `offset`: failpoint-checked, with
    /// bounded retry. Safe to retry because block writes target
    /// disjoint fixed ranges with the same data every attempt.
    fn write_all_at(&self, bytes: &[u8], offset: u64) -> std::io::Result<()> {
        with_io_retries(|| {
            crate::fault::check(self.sites.1)?;
            self.write_all_at_raw(bytes, offset)
        })
    }

    /// One positioned exact read at `offset` (lock-free `pread`).
    #[cfg(unix)]
    fn read_exact_at_raw(&self, bytes: &mut [u8], offset: u64) -> std::io::Result<()> {
        use std::os::unix::fs::FileExt;
        self.file.read_exact_at(bytes, offset)
    }

    /// One positioned exact read at `offset` (seek + read under the
    /// fallback mutex).
    #[cfg(not(unix))]
    fn read_exact_at_raw(&self, bytes: &mut [u8], offset: u64) -> std::io::Result<()> {
        let mut f = self.file.lock().unwrap();
        std::io::Seek::seek(&mut *f, std::io::SeekFrom::Start(offset))?;
        std::io::Read::read_exact(&mut *f, bytes)
    }

    /// One positioned `write_all` at `offset` (lock-free `pwrite`).
    #[cfg(unix)]
    fn write_all_at_raw(&self, bytes: &[u8], offset: u64) -> std::io::Result<()> {
        use std::os::unix::fs::FileExt;
        self.file.write_all_at(bytes, offset)
    }

    /// One positioned `write_all` at `offset` (seek + write under the
    /// fallback mutex).
    #[cfg(not(unix))]
    fn write_all_at_raw(&self, bytes: &[u8], offset: u64) -> std::io::Result<()> {
        let mut f = self.file.lock().unwrap();
        std::io::Seek::seek(&mut *f, std::io::SeekFrom::Start(offset))?;
        std::io::Write::write_all(&mut *f, bytes)
    }

    /// `fdatasync` the file — the durability point for stores whose
    /// block writes only hand data to the page cache.
    #[cfg(unix)]
    pub(crate) fn sync_data(&self) -> std::io::Result<()> {
        self.file.sync_data()
    }

    /// `fdatasync` the file (fallback-mutex form).
    #[cfg(not(unix))]
    pub(crate) fn sync_data(&self) -> std::io::Result<()> {
        self.file.lock().unwrap().sync_data()
    }
}

#[cfg(target_os = "linux")]
mod mmap_sys {
    // Vendored binding against the libc std already links (the
    // [`crate::par::affinity`] idiom) — no new dependency. `*mut u8`
    // is ABI-compatible with `void *`; `off_t` is i64 on 64-bit linux.
    extern "C" {
        pub fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        pub fn munmap(addr: *mut u8, len: usize) -> i32;
    }
    pub const PROT_READ: i32 = 1;
    pub const MAP_SHARED: i32 = 1;
}

/// A read-only `MAP_SHARED` memory mapping of the leading `len` bytes
/// of a file (the mapping survives the file descriptor it was created
/// from). Linux-only; everywhere else [`Mmap::map`] returns
/// `ErrorKind::Unsupported` and callers fall back to positioned reads.
pub(crate) struct Mmap {
    #[cfg_attr(not(target_os = "linux"), allow(dead_code))]
    ptr: std::ptr::NonNull<u8>,
    len: usize,
}

// SAFETY: the mapping is PROT_READ over a file this crate never
// mutates in place (positioned-I/O contract): shared references from
// any thread observe immutable bytes.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map the first `len` bytes of `file` read-only. `len` must be
    /// nonzero and no larger than the file (touching mapped pages past
    /// EOF is a hardware fault, not an `Err`) — callers validate the
    /// length against the file size first.
    #[cfg(target_os = "linux")]
    pub(crate) fn map(file: &std::fs::File, len: u64) -> std::io::Result<Self> {
        use std::os::unix::io::AsRawFd;
        if len == 0 {
            // mmap(len = 0) is EINVAL; make the failure deterministic.
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                "empty mapping",
            ));
        }
        let len = usize::try_from(len).map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::InvalidInput, "mapping too large")
        })?;
        // SAFETY: a fresh PROT_READ/MAP_SHARED mapping of an open fd;
        // the kernel validates the fd and length, and MAP_FAILED is
        // checked below.
        let ptr = unsafe {
            mmap_sys::mmap(
                std::ptr::null_mut(),
                len,
                mmap_sys::PROT_READ,
                mmap_sys::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as usize == usize::MAX {
            return Err(std::io::Error::last_os_error());
        }
        match std::ptr::NonNull::new(ptr) {
            Some(ptr) => Ok(Self { ptr, len }),
            None => Err(std::io::Error::new(
                std::io::ErrorKind::Other,
                "mmap returned null",
            )),
        }
    }

    /// Unsupported platform: callers fall back to positioned reads.
    #[cfg(not(target_os = "linux"))]
    pub(crate) fn map(_file: &std::fs::File, _len: u64) -> std::io::Result<Self> {
        Err(std::io::Error::new(
            std::io::ErrorKind::Unsupported,
            "mmap is only vendored on linux",
        ))
    }

    /// The mapped bytes.
    pub(crate) fn as_slice(&self) -> &[u8] {
        // SAFETY: `ptr` is a live PROT_READ mapping of exactly `len`
        // bytes (unmapped only in Drop, which requires `&mut self`).
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(target_os = "linux")]
        // SAFETY: `ptr`/`len` are exactly the values mmap returned;
        // the mapping is unmapped once, here.
        unsafe {
            mmap_sys::munmap(self.ptr.as_ptr(), self.len);
        }
    }
}

/// An opened packed corpus served **out of core**: only the header and
/// `doc_offsets` are resident (8 bytes per document); token blocks are
/// read on demand with [`PackedCorpusFile::read_block`]. This is the
/// token source of the streamed z sweep when the arena does not fit in
/// RAM (PubMed scale: 768M tokens ≈ 3 GB of arena vs 64 MB of
/// offsets).
///
/// Block reads are **positioned** ([`PositionedFile`]): on unix,
/// concurrent slots serving disjoint blocks issue lock-free `pread`s
/// against the shared descriptor, so disk latency lands only on the
/// requesting slot while the others compute (and the streamed sweep's
/// prefetcher can load the next block from another thread).
pub struct PackedCorpusFile {
    file: PositionedFile,
    doc_offsets: Vec<u64>,
    vocab_entries: u64,
    /// Read-only mapping of header + offsets + token sections (see the
    /// memory-mapping contract in the module docs). `None` when opened
    /// with [`PackedCorpusFile::open`] or when mapping is unavailable;
    /// block reads then go through positioned reads.
    map: Option<Mmap>,
}

impl PackedCorpusFile {
    /// Open and validate a packed corpus file: header + offsets, plus
    /// a full-file checksum scan when the file carries the trailer
    /// (`PACKED_FLAG_CRC`), so a bit-flipped arena fails at open, not
    /// as a silently wrong token mid-sweep.
    pub fn open(path: &Path) -> anyhow::Result<Self> {
        let file = std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?;
        let file_len = file.metadata()?.len();
        let mut f = std::io::BufReader::new(file);
        let (d, v, n, flags) = read_packed_header(&mut f, file_len, path)?;
        let doc_offsets = read_u64s(&mut f, d as usize + 1)?;
        anyhow::ensure!(
            doc_offsets[0] == 0
                && doc_offsets.windows(2).all(|w| w[0] <= w[1])
                && *doc_offsets.last().unwrap() == n,
            "corrupt doc_offsets in {}",
            path.display()
        );
        let mut file = f.into_inner();
        if flags & PACKED_FLAG_CRC != 0 {
            crate::durable::verify_file_crc(&mut file, file_len, "packed corpus")
                .map_err(|e| anyhow::anyhow!("{}: {e:#}", path.display()))?;
        } else if file_len >= crate::durable::TRAILER_LEN {
            // A flag-0 file whose last 4 bytes are the trailer tag can
            // only arise from a damaged flags field (the vocab section
            // never dangles extra bytes): fail closed rather than
            // serve a file whose checksum we were told not to check.
            use std::io::Seek;
            file.seek(std::io::SeekFrom::Start(file_len - 4))?;
            let mut tag = [0u8; 4];
            file.read_exact(&mut tag)?;
            anyhow::ensure!(
                &tag != crate::durable::TRAILER_TAG,
                "corrupt packed corpus {}: flags claim no checksum but the file ends in a checksum trailer tag",
                path.display()
            );
        }
        Ok(Self {
            file: PositionedFile::new(file, ("corpus.pread", "corpus.pwrite")),
            doc_offsets,
            vocab_entries: v,
            map: None,
        })
    }

    /// [`PackedCorpusFile::open`] plus a best-effort read-only
    /// `MAP_SHARED` mapping of the token section (module docs:
    /// memory-mapping contract). Validation — header, offsets,
    /// checksum — is identical to `open`; only the block-serving
    /// mechanism changes. Mapping failures of any kind (`EINVAL`,
    /// `ENOMEM`, non-linux platforms, big-endian hosts) are **not**
    /// errors: the file opens in positioned-read mode instead, which
    /// serves bit-identical tokens. Check [`PackedCorpusFile::mmap_active`]
    /// to see which mode is live.
    pub fn open_mmap(path: &Path) -> anyhow::Result<Self> {
        let mut s = Self::open(path)?;
        // The in-place &[u32] reinterpret is value-correct only on
        // little-endian targets; big-endian hosts keep the pread path
        // (which byte-swaps).
        if cfg!(target_endian = "little") {
            if let Ok(file) = std::fs::File::open(path) {
                let file_len = file.metadata().map(|m| m.len()).unwrap_or(0);
                let map_len = PACKED_HEADER_BYTES
                    + s.doc_offsets.len() as u64 * 8
                    + s.num_tokens() * 4;
                // `open` validated this, but never map past EOF: a
                // short file would fault on access, not Err.
                if map_len <= file_len {
                    s.map = Mmap::map(&file, map_len).ok();
                }
            }
        }
        Ok(s)
    }

    /// True when token blocks are served from a memory mapping
    /// (zero-copy) rather than positioned reads.
    pub fn mmap_active(&self) -> bool {
        self.map.is_some()
    }

    /// The mapped token arena as an in-place `&[u32]`, when mapped.
    pub(crate) fn mapped_tokens(&self) -> Option<&[u32]> {
        let map = self.map.as_ref()?;
        let off = (PACKED_HEADER_BYTES + self.doc_offsets.len() as u64 * 8) as usize;
        let n = self.num_tokens() as usize;
        let bytes = &map.as_slice()[off..off + n * 4];
        // SAFETY: `off` is a multiple of 8 inside a page-aligned
        // mapping, so the pointer is u32-aligned; the range holds
        // exactly `n` initialized little-endian u32s and the mapping
        // (borrowed here) is immutable for its lifetime. Mapping is
        // only established on little-endian targets (`open_mmap`), so
        // the reinterpret is value-correct.
        Some(unsafe { std::slice::from_raw_parts(bytes.as_ptr().cast::<u32>(), n) })
    }

    /// Number of documents `D`.
    pub fn num_docs(&self) -> usize {
        self.doc_offsets.len() - 1
    }

    /// Total token count `N`.
    pub fn num_tokens(&self) -> u64 {
        *self.doc_offsets.last().unwrap()
    }

    /// Vocabulary entries recorded in the header (strings stay on
    /// disk).
    pub fn vocab_entries(&self) -> u64 {
        self.vocab_entries
    }

    /// Document offsets (length `D + 1`), resident.
    pub fn doc_offsets(&self) -> &[u64] {
        &self.doc_offsets
    }

    /// Read the token block of documents `[start_doc, end_doc)` into
    /// `buf` (cleared first). One positioned read; safe to call from
    /// any number of threads concurrently (disjoint or not — reads
    /// never conflict).
    pub fn read_block(
        &self,
        start_doc: usize,
        end_doc: usize,
        buf: &mut Vec<u32>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            start_doc <= end_doc && end_doc < self.doc_offsets.len(),
            "doc block {start_doc}..{end_doc} out of range"
        );
        let t0 = self.doc_offsets[start_doc];
        let t1 = self.doc_offsets[end_doc];
        if let Some(tokens) = self.mapped_tokens() {
            buf.clear();
            buf.extend_from_slice(&tokens[t0 as usize..t1 as usize]);
            return Ok(());
        }
        let byte0 = PACKED_HEADER_BYTES + self.doc_offsets.len() as u64 * 8 + t0 * 4;
        self.file.read_u32s_at(byte0, (t1 - t0) as usize, buf)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Corpus {
        Corpus {
            docs: vec![vec![0, 0, 2], vec![1], vec![2, 1]],
            vocab: vec!["alpha".into(), "beta".into(), "gamma".into()],
        }
    }

    #[test]
    fn uci_roundtrip() {
        let c = sample();
        let dir = std::env::temp_dir().join("hdp_uci_test");
        std::fs::create_dir_all(&dir).unwrap();
        let dw = dir.join("docword.txt");
        let vc = dir.join("vocab.txt");
        write_uci(&c, &dw, &vc).unwrap();
        let back = read_uci_files(&dw, &vc).unwrap();
        assert_eq!(back.vocab, c.vocab);
        assert_eq!(back.num_tokens(), c.num_tokens());
        // Bag-of-words equality per document.
        for (a, b) in c.docs.iter().zip(&back.docs) {
            let mut a = a.clone();
            let mut b = b.clone();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn uci_parses_reference_text() {
        let docword = "2\n3\n3\n1 1 2\n1 3 1\n2 2 5\n";
        let vocab = "x\ny\nz\n";
        let c = read_uci(docword.as_bytes(), vocab.as_bytes()).unwrap();
        assert_eq!(c.num_docs(), 2);
        assert_eq!(c.docs[0], vec![0, 0, 2]);
        assert_eq!(c.docs[1], vec![1, 1, 1, 1, 1]);
    }

    #[test]
    fn uci_rejects_bad_input() {
        assert!(read_uci("2\n3\n".as_bytes(), "x\n".as_bytes()).is_err());
        // out-of-range word id
        let bad = "1\n2\n1\n1 9 1\n";
        assert!(read_uci(bad.as_bytes(), "x\ny\n".as_bytes()).is_err());
        // nnz mismatch
        let bad = "1\n2\n5\n1 1 1\n";
        assert!(read_uci(bad.as_bytes(), "x\ny\n".as_bytes()).is_err());
        // vocab length mismatch
        let bad = "1\n2\n1\n1 1 1\n";
        assert!(read_uci(bad.as_bytes(), "x\n".as_bytes()).is_err());
        // zero-count triple (would count toward NNZ but append nothing)
        let bad = "1\n2\n1\n1 1 0\n";
        let err = read_uci(bad.as_bytes(), "x\ny\n".as_bytes()).unwrap_err();
        assert!(err.to_string().contains("zero-count"), "{err}");
        // absurd count: rejected by the token-total bound BEFORE any
        // expansion is attempted (this must not try to allocate)
        let bad = "1\n2\n2\n1 1 1\n1 2 999999999999\n";
        let err = read_uci(bad.as_bytes(), "x\ny\n".as_bytes()).unwrap_err();
        assert!(err.to_string().contains("sanity bound"), "{err}");
        // a count near 2^64 must not wrap the running total past the
        // bound (release) or panic (debug) — clean Err either way
        let bad = "1\n2\n2\n1 1 100\n1 2 18446744073709551585\n";
        let err = read_uci(bad.as_bytes(), "x\ny\n".as_bytes()).unwrap_err();
        assert!(err.to_string().contains("sanity bound"), "{err}");
    }

    #[test]
    fn binary_roundtrip_exact() {
        let c = sample();
        let path = std::env::temp_dir().join("hdp_bin_test/corpus.hdpc");
        write_binary(&c, &path).unwrap();
        let back = read_binary(&path).unwrap();
        assert_eq!(back.docs, c.docs); // exact, including token order
        assert_eq!(back.vocab, c.vocab);
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn binary_rejects_garbage() {
        let path = std::env::temp_dir().join("hdp_bin_test2/garbage.hdpc");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, b"not a corpus").unwrap();
        assert!(read_binary(&path).is_err());
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    /// Packed corpus exercising the edge cases the format must honor:
    /// leading/trailing/interior empty docs and max-u32 word ids in a
    /// vocabless arena.
    fn packed_edge() -> PackedCorpus {
        PackedCorpus::from_parts(
            vec![0, u32::MAX, 7, 7, u32::MAX],
            vec![0, 0, 2, 2, 5, 5],
            vec![],
        )
        .unwrap()
    }

    #[test]
    fn packed_roundtrip_exact() {
        let dir = std::env::temp_dir().join("hdp_packed_test_rt");
        // Edge-case arena (empty docs, max ids, no vocab).
        let c = packed_edge();
        let p = dir.join("edge.hdpp");
        write_packed(&c, &p).unwrap();
        assert_eq!(read_packed(&p).unwrap(), c);
        // Regular corpus with vocab, via conversion.
        let c = sample().to_packed();
        let p = dir.join("sample.hdpp");
        write_packed(&c, &p).unwrap();
        let back = read_packed(&p).unwrap();
        assert_eq!(back, c);
        assert_eq!(back.to_nested().docs, sample().docs);
        // Empty corpus.
        let c = PackedCorpus::default();
        let p = dir.join("empty.hdpp");
        write_packed(&c, &p).unwrap();
        assert_eq!(read_packed(&p).unwrap(), c);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn packed_rejects_bad_magic_and_version() {
        let dir = std::env::temp_dir().join("hdp_packed_test_bad");
        let path = dir.join("c.hdpp");
        write_packed(&packed_edge(), &path).unwrap();
        let good = std::fs::read(&path).unwrap();
        // Bad magic.
        let mut bad = good.clone();
        bad[0] ^= 0xff;
        std::fs::write(&path, &bad).unwrap();
        let err = read_packed(&path).unwrap_err().to_string();
        assert!(err.contains("not a packed"), "{err}");
        assert!(PackedCorpusFile::open(&path).is_err());
        // Wrong version.
        let mut bad = good.clone();
        bad[8] = 99;
        std::fs::write(&path, &bad).unwrap();
        let err = read_packed(&path).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        assert!(PackedCorpusFile::open(&path).is_err());
        // Total garbage / too short for a header.
        std::fs::write(&path, b"HDP").unwrap();
        assert!(read_packed(&path).is_err());
        assert!(PackedCorpusFile::open(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn packed_rejects_truncation_cleanly() {
        // Every strict prefix of a valid file must yield Err, not a
        // panic, OOM, or silent short read.
        let dir = std::env::temp_dir().join("hdp_packed_test_trunc");
        let path = dir.join("c.hdpp");
        write_packed(&sample().to_packed(), &path).unwrap();
        let good = std::fs::read(&path).unwrap();
        let cut = dir.join("cut.hdpp");
        for len in [0, 4, 8, 12, 39, 40, 41, good.len() / 2, good.len() - 1] {
            std::fs::write(&cut, &good[..len.min(good.len())]).unwrap();
            assert!(read_packed(&cut).is_err(), "prefix of {len} bytes accepted");
        }
        // A header whose claimed N exceeds the file must not allocate
        // N tokens: corrupt the token count field (bytes 32..40).
        let mut bad = good.clone();
        bad[32..40].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&cut, &bad).unwrap();
        let err = read_packed(&cut).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn packed_trailer_layout_and_legacy_flag0() {
        let dir = std::env::temp_dir().join("hdp_packed_test_trailer");
        let path = dir.join("c.hdpp");
        let c = sample().to_packed();
        write_packed(&c, &path).unwrap();
        let good = std::fs::read(&path).unwrap();
        // Flags word carries exactly the CRC bit; the file ends in the
        // trailer whose stored CRC matches a recomputation.
        assert_eq!(
            u32::from_le_bytes(good[12..16].try_into().unwrap()),
            PACKED_FLAG_CRC
        );
        let n = good.len();
        assert_eq!(&good[n - 4..], crate::durable::TRAILER_TAG);
        let stored = u32::from_le_bytes(good[n - 8..n - 4].try_into().unwrap());
        assert_eq!(stored, crate::durable::crc32(&good[..n - 8]));
        // A legacy (pre-trailer) file — flags 0, no trailer — still
        // loads through both readers.
        let mut legacy = good[..n - 8].to_vec();
        legacy[12..16].copy_from_slice(&0u32.to_le_bytes());
        let lp = dir.join("legacy.hdpp");
        std::fs::write(&lp, &legacy).unwrap();
        assert_eq!(read_packed(&lp).unwrap(), c);
        assert_eq!(
            PackedCorpusFile::open(&lp).unwrap().doc_offsets(),
            c.doc_offsets()
        );
        // Legacy file with trailing garbage: rejected (the format has
        // no dangling bytes).
        let mut garbage = legacy.clone();
        garbage.extend_from_slice(b"xx");
        std::fs::write(&lp, &garbage).unwrap();
        let err = read_packed(&lp).unwrap_err().to_string();
        assert!(err.contains("trailing bytes"), "{err}");
        // Legacy flags but a trailer tag at the end — the shape a
        // flipped flags byte produces — is rejected by the open path
        // (read_packed catches it as trailing bytes).
        let mut flipped = good.clone();
        flipped[12..16].copy_from_slice(&0u32.to_le_bytes());
        std::fs::write(&lp, &flipped).unwrap();
        assert!(read_packed(&lp).is_err());
        let err = PackedCorpusFile::open(&lp).unwrap_err().to_string();
        assert!(err.contains("trailer tag"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn packed_rejects_every_single_byte_flip() {
        // With the checksum trailer, no single-byte corruption —
        // header, offsets, arena, vocab, or the trailer itself — can
        // load through either reader.
        let dir = std::env::temp_dir().join("hdp_packed_test_flip");
        let path = dir.join("c.hdpp");
        write_packed(&sample().to_packed(), &path).unwrap();
        let good = std::fs::read(&path).unwrap();
        let bad_path = dir.join("bad.hdpp");
        for i in 0..good.len() {
            let mut bad = good.clone();
            bad[i] ^= 0x40;
            std::fs::write(&bad_path, &bad).unwrap();
            assert!(read_packed(&bad_path).is_err(), "flip at byte {i} accepted");
            assert!(
                PackedCorpusFile::open(&bad_path).is_err(),
                "flip at byte {i} accepted by open"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn packed_file_blocks_match_resident_arena() {
        let dir = std::env::temp_dir().join("hdp_packed_test_blocks");
        let path = dir.join("c.hdpp");
        let c = sample().to_packed();
        write_packed(&c, &path).unwrap();
        let f = PackedCorpusFile::open(&path).unwrap();
        assert_eq!(f.num_docs(), c.num_docs());
        assert_eq!(f.num_tokens(), c.num_tokens());
        assert_eq!(f.vocab_entries(), c.vocab.len() as u64);
        assert_eq!(f.doc_offsets(), c.doc_offsets());
        let mut buf = Vec::new();
        // Every contiguous block agrees with the resident arena.
        for start in 0..=c.num_docs() {
            for end in start..=c.num_docs() {
                f.read_block(start, end, &mut buf).unwrap();
                assert_eq!(&buf[..], &c.tokens()[c.token_range(start, end)]);
            }
        }
        assert!(f.read_block(0, c.num_docs() + 1, &mut buf).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mmap_blocks_match_pread_exactly() {
        // The mapped reader and the positioned reader must serve
        // byte-identical blocks — that equality is what makes the
        // mmap × pread invariance cells of the statistical matrix
        // trivially true at the token level.
        let dir = std::env::temp_dir().join("hdp_packed_test_mmap");
        let path = dir.join("c.hdpp");
        let c = sample().to_packed();
        write_packed(&c, &path).unwrap();
        let pread = PackedCorpusFile::open(&path).unwrap();
        let mapped = PackedCorpusFile::open_mmap(&path).unwrap();
        assert!(!pread.mmap_active());
        #[cfg(target_os = "linux")]
        assert!(
            mapped.mmap_active(),
            "mmap must engage on linux little-endian hosts"
        );
        assert_eq!(mapped.doc_offsets(), pread.doc_offsets());
        let (mut a, mut b) = (Vec::new(), Vec::new());
        for start in 0..=c.num_docs() {
            for end in start..=c.num_docs() {
                pread.read_block(start, end, &mut a).unwrap();
                mapped.read_block(start, end, &mut b).unwrap();
                assert_eq!(a, b, "block {start}..{end}");
                assert_eq!(&a[..], &c.tokens()[c.token_range(start, end)]);
            }
        }
        if let Some(tokens) = mapped.mapped_tokens() {
            assert_eq!(tokens, c.tokens());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_mmap_rejects_truncation_and_corruption() {
        // open_mmap runs the full open-time validation: truncated or
        // bit-flipped files fail closed before any mapping exists.
        let dir = std::env::temp_dir().join("hdp_packed_test_mmap_bad");
        let path = dir.join("c.hdpp");
        write_packed(&sample().to_packed(), &path).unwrap();
        let good = std::fs::read(&path).unwrap();
        let bad = dir.join("bad.hdpp");
        for cut in [0, 8, 39, 40, good.len() / 2, good.len() - 1] {
            std::fs::write(&bad, &good[..cut]).unwrap();
            assert!(PackedCorpusFile::open_mmap(&bad).is_err(), "prefix {cut}");
        }
        let mut flip = good.clone();
        flip[good.len() / 2] ^= 0x10;
        std::fs::write(&bad, &flip).unwrap();
        assert!(PackedCorpusFile::open_mmap(&bad).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mmap_short_and_empty_maps_fail_or_fall_back() {
        // Mmap::map itself: zero-length mappings are a deterministic
        // Err (not EINVAL roulette), and a mapping is never longer
        // than the validated sections, so no access can fault past
        // EOF. On non-linux platforms map() is Unsupported and
        // open_mmap silently stays in positioned-read mode.
        let dir = std::env::temp_dir().join("hdp_packed_test_mmap_short");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("f.bin");
        std::fs::write(&p, [0u8; 64]).unwrap();
        let f = std::fs::File::open(&p).unwrap();
        assert!(Mmap::map(&f, 0).is_err(), "empty mapping must be Err");
        match Mmap::map(&f, 64) {
            Ok(m) => {
                assert_eq!(m.as_slice().len(), 64);
                assert!(m.as_slice().iter().all(|&b| b == 0));
            }
            Err(e) => {
                // Acceptable only where the binding is absent.
                assert_eq!(e.kind(), std::io::ErrorKind::Unsupported, "{e}");
            }
        }
        // An empty packed corpus still opens via open_mmap; its token
        // section is empty so block reads are trivially correct in
        // either mode.
        let path = dir.join("empty.hdpp");
        write_packed(&PackedCorpus::default(), &path).unwrap();
        let f = PackedCorpusFile::open_mmap(&path).unwrap();
        let mut buf = vec![7u32];
        f.read_block(0, 0, &mut buf).unwrap();
        assert!(buf.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn concurrent_block_reads_match_the_arena() {
        // The positioned-read path serves many threads from one shared
        // descriptor with no lock. Hammer disjoint (and overlapping)
        // blocks from 8 threads and require every read to match the
        // resident arena — pins the lock-free `pread` contract.
        let docs: Vec<Vec<u32>> = (0..64u32)
            .map(|d| (0..(d % 7 + 1)).map(|i| d * 100 + i).collect())
            .collect();
        let c = Corpus { docs, vocab: vec![] };
        let packed = c.to_packed();
        let dir = std::env::temp_dir().join("hdp_packed_test_conc");
        let path = dir.join("c.hdpp");
        write_packed(&packed, &path).unwrap();
        let f = PackedCorpusFile::open(&path).unwrap();
        std::thread::scope(|scope| {
            for t in 0..8usize {
                let f = &f;
                let packed = &packed;
                scope.spawn(move || {
                    let mut buf = Vec::new();
                    for round in 0..50 {
                        // A stride-8 stripe of disjoint 1-doc blocks,
                        // plus one deliberately overlapping wide read.
                        for start in (t..packed.num_docs()).step_by(8) {
                            f.read_block(start, start + 1, &mut buf).unwrap();
                            assert_eq!(
                                &buf[..],
                                &packed.tokens()[packed.token_range(start, start + 1)],
                                "thread {t} round {round} doc {start}"
                            );
                        }
                        f.read_block(0, packed.num_docs(), &mut buf).unwrap();
                        assert_eq!(&buf[..], packed.tokens());
                    }
                });
            }
        });
        std::fs::remove_dir_all(&dir).ok();
    }
}
