//! Corpus substrate: document storage, I/O, preprocessing, synthesis.
//!
//! Two in-memory document layouts, one logical corpus:
//!
//! * [`Corpus`] — the nested `Vec<Vec<u32>>` interchange form used by
//!   ingest, preprocessing, and synthesis (cheap to build a document at
//!   a time).
//! * [`PackedCorpus`] — the packed CSR token arena the samplers run on:
//!   one flat `tokens` vector plus `doc_offsets` (length `D + 1`), so a
//!   document is a contiguous slice of the arena, token storage is a
//!   single allocation, and contiguous *document blocks* are contiguous
//!   *token ranges* — the property the streamed/out-of-core z sweep
//!   ([`crate::hdp::pc::zstep::ZSweep::run_streamed`]) is built on. Its
//!   on-disk twin ([`io::write_packed`] / [`io::PackedCorpusFile`]) has
//!   the same layout, so blocks can be served straight from disk.
//!
//! The [`DocAccess`] trait abstracts "give me document `d`'s tokens"
//! over both layouts (and over `&[Vec<u32>]` directly), which is what
//! lets the sweep and diagnostics take either without copies.
//!
//! Sources:
//!
//! * [`io`] — the UCI "bag of words" interchange format used by the
//!   paper's NeurIPS/PubMed downloads (`docword.txt` + `vocab.txt`),
//!   plus a compact binary cache.
//! * [`preprocess`] — MALLET-equivalent preprocessing: stop-word
//!   removal, rare-word limit, minimum document size (paper §3 uses
//!   stoplist + min-doc-size 10 + rare-word limit 10).
//! * [`synthetic`] — the corpus *simulators* standing in for AP /
//!   CGCBIB / NeurIPS / PubMed (no network in this environment):
//!   a Zipf/Heaps generator matched to each corpus' (V, D, N) and an
//!   HDP generative-model generator with planted ground truth.
//! * [`registry`] — named corpus specs (`ap`, `cgcbib`, `neurips`,
//!   `pubmed-scaled`, …) with the paper's Table 2 statistics.

pub mod io;
pub mod preprocess;
pub mod registry;
pub mod synthetic;

/// A tokenized bag-of-words corpus.
///
/// Token order inside a document is meaningless to the model (bag of
/// words) but is kept stable so chains are reproducible.
#[derive(Clone, Debug, Default)]
pub struct Corpus {
    /// `docs[d]` = word ids of every token in document `d`.
    pub docs: Vec<Vec<u32>>,
    /// Word strings, indexed by word id.
    pub vocab: Vec<String>,
}

impl Corpus {
    /// Number of documents `D`.
    pub fn num_docs(&self) -> usize {
        self.docs.len()
    }

    /// Vocabulary size `V`.
    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    /// Total token count `N`.
    pub fn num_tokens(&self) -> u64 {
        self.docs.iter().map(|d| d.len() as u64).sum()
    }

    /// Longest document length `max_d N_d`.
    pub fn max_doc_len(&self) -> usize {
        self.docs.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Per-document lengths as weights for load-balanced sharding.
    pub fn doc_weights(&self) -> Vec<u64> {
        self.docs.iter().map(|d| d.len() as u64).collect()
    }

    /// Number of *distinct* word types that actually occur.
    pub fn observed_vocab(&self) -> usize {
        let mut seen = vec![false; self.vocab.len()];
        for doc in &self.docs {
            for &w in doc {
                seen[w as usize] = true;
            }
        }
        seen.iter().filter(|&&b| b).count()
    }

    /// Corpus-wide word frequencies.
    pub fn word_counts(&self) -> Vec<u64> {
        let mut counts = vec![0u64; self.vocab.len()];
        for doc in &self.docs {
            for &w in doc {
                counts[w as usize] += 1;
            }
        }
        counts
    }

    /// Validate internal consistency (word ids in range, nonempty vocab
    /// when there are tokens).
    pub fn validate(&self) -> anyhow::Result<()> {
        let v = self.vocab.len() as u32;
        for (d, doc) in self.docs.iter().enumerate() {
            for &w in doc {
                anyhow::ensure!(w < v, "doc {d}: word id {w} out of range (V={v})");
            }
        }
        Ok(())
    }

    /// One-line summary (Table-2 style).
    pub fn summary(&self) -> String {
        format!(
            "D={} V={} N={} max_Nd={}",
            self.num_docs(),
            self.vocab_size(),
            self.num_tokens(),
            self.max_doc_len()
        )
    }
}

/// Read access to per-document token slices, implemented by the nested
/// [`Corpus`] (and raw `Vec<Vec<u32>>` document lists) and by the
/// packed arena [`PackedCorpus`]. `Sync` so parallel sweeps can share
/// the source across shards.
pub trait DocAccess: Sync {
    /// Number of documents `D`.
    fn num_docs(&self) -> usize;
    /// Tokens of document `d`.
    fn doc(&self, d: usize) -> &[u32];
}

impl DocAccess for [Vec<u32>] {
    fn num_docs(&self) -> usize {
        self.len()
    }
    fn doc(&self, d: usize) -> &[u32] {
        &self[d]
    }
}

impl DocAccess for Vec<Vec<u32>> {
    fn num_docs(&self) -> usize {
        self.len()
    }
    fn doc(&self, d: usize) -> &[u32] {
        &self[d]
    }
}

impl DocAccess for Corpus {
    fn num_docs(&self) -> usize {
        self.docs.len()
    }
    fn doc(&self, d: usize) -> &[u32] {
        &self.docs[d]
    }
}

impl DocAccess for PackedCorpus {
    fn num_docs(&self) -> usize {
        PackedCorpus::num_docs(self)
    }
    fn doc(&self, d: usize) -> &[u32] {
        PackedCorpus::doc(self, d)
    }
}

impl<T: DocAccess + Send> DocAccess for std::sync::Arc<T> {
    fn num_docs(&self) -> usize {
        (**self).num_docs()
    }
    fn doc(&self, d: usize) -> &[u32] {
        (**self).doc(d)
    }
}

/// Whole-corpus statistics and vocabulary on top of [`DocAccess`] —
/// the trait-object view the [`crate::hdp::Trainer`] API exposes, so
/// training, diagnostics, and serving consume a corpus without caring
/// whether it is the nested interchange [`Corpus`] or the packed arena
/// [`PackedCorpus`]. The packed-only training path
/// ([`crate::hdp::pc::PcSampler::from_packed`]) never materializes a
/// nested `Corpus` at all; everything downstream sees `&dyn CorpusView`.
pub trait CorpusView: DocAccess {
    /// Total token count `N`.
    fn num_tokens(&self) -> u64;
    /// Vocabulary size `V`.
    fn vocab_size(&self) -> usize;
    /// Word strings, indexed by word id (may be empty for vocabless
    /// arenas).
    fn vocab(&self) -> &[String];
    /// Longest document length `max_d N_d`.
    fn max_doc_len(&self) -> usize {
        (0..DocAccess::num_docs(self)).map(|d| self.doc(d).len()).max().unwrap_or(0)
    }
    /// Per-document lengths as weights for load-balanced sharding.
    fn doc_weights(&self) -> Vec<u64> {
        (0..DocAccess::num_docs(self)).map(|d| self.doc(d).len() as u64).collect()
    }
}

impl CorpusView for Corpus {
    fn num_tokens(&self) -> u64 {
        Corpus::num_tokens(self)
    }
    fn vocab_size(&self) -> usize {
        Corpus::vocab_size(self)
    }
    fn vocab(&self) -> &[String] {
        &self.vocab
    }
    fn max_doc_len(&self) -> usize {
        Corpus::max_doc_len(self)
    }
    fn doc_weights(&self) -> Vec<u64> {
        Corpus::doc_weights(self)
    }
}

impl CorpusView for PackedCorpus {
    fn num_tokens(&self) -> u64 {
        PackedCorpus::num_tokens(self)
    }
    fn vocab_size(&self) -> usize {
        PackedCorpus::vocab_size(self)
    }
    fn vocab(&self) -> &[String] {
        &self.vocab
    }
    fn max_doc_len(&self) -> usize {
        PackedCorpus::max_doc_len(self)
    }
    fn doc_weights(&self) -> Vec<u64> {
        PackedCorpus::doc_weights(self)
    }
}

/// A bag-of-words corpus in packed CSR layout: one flat token arena
/// plus per-document offsets.
///
/// Invariants (enforced by [`PackedCorpus::from_parts`] and preserved
/// by every constructor):
///
/// * `doc_offsets.len() == num_docs + 1`, `doc_offsets[0] == 0`;
/// * `doc_offsets` is non-decreasing (empty documents are *retained*
///   as zero-length ranges — unlike preprocessing, conversion never
///   drops documents);
/// * `doc_offsets[num_docs] == tokens.len()`.
///
/// The vocabulary may be empty even when tokens exist: benches and
/// intermediate arenas are "vocabless", and [`PackedCorpus::validate`]
/// only range-checks word ids against a non-empty vocabulary.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedCorpus {
    tokens: Vec<u32>,
    doc_offsets: Vec<u64>,
    /// Word strings, indexed by word id (possibly empty; see above).
    pub vocab: Vec<String>,
}

impl Default for PackedCorpus {
    fn default() -> Self {
        Self { tokens: Vec::new(), doc_offsets: vec![0], vocab: Vec::new() }
    }
}

impl PackedCorpus {
    /// Assemble from raw parts, checking the CSR invariants.
    pub fn from_parts(
        tokens: Vec<u32>,
        doc_offsets: Vec<u64>,
        vocab: Vec<String>,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(!doc_offsets.is_empty(), "doc_offsets must have D+1 entries");
        anyhow::ensure!(doc_offsets[0] == 0, "doc_offsets must start at 0");
        anyhow::ensure!(
            doc_offsets.windows(2).all(|w| w[0] <= w[1]),
            "doc_offsets must be non-decreasing"
        );
        anyhow::ensure!(
            *doc_offsets.last().unwrap() == tokens.len() as u64,
            "doc_offsets end {} != token count {}",
            doc_offsets.last().unwrap(),
            tokens.len()
        );
        Ok(Self { tokens, doc_offsets, vocab })
    }

    /// Number of documents `D`.
    pub fn num_docs(&self) -> usize {
        self.doc_offsets.len() - 1
    }

    /// Vocabulary size `V`.
    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    /// Total token count `N`.
    pub fn num_tokens(&self) -> u64 {
        *self.doc_offsets.last().unwrap()
    }

    /// Length of document `d`.
    pub fn doc_len(&self, d: usize) -> usize {
        (self.doc_offsets[d + 1] - self.doc_offsets[d]) as usize
    }

    /// Tokens of document `d` (a slice of the arena).
    pub fn doc(&self, d: usize) -> &[u32] {
        &self.tokens[self.doc_offsets[d] as usize..self.doc_offsets[d + 1] as usize]
    }

    /// The whole token arena.
    pub fn tokens(&self) -> &[u32] {
        &self.tokens
    }

    /// Document offsets into the arena (length `D + 1`).
    pub fn doc_offsets(&self) -> &[u64] {
        &self.doc_offsets
    }

    /// Arena token range of the contiguous document block
    /// `[start_doc, end_doc)`.
    pub fn token_range(&self, start_doc: usize, end_doc: usize) -> std::ops::Range<usize> {
        self.doc_offsets[start_doc] as usize..self.doc_offsets[end_doc] as usize
    }

    /// Longest document length `max_d N_d`.
    pub fn max_doc_len(&self) -> usize {
        (0..self.num_docs()).map(|d| self.doc_len(d)).max().unwrap_or(0)
    }

    /// Per-document lengths as weights for load-balanced sharding.
    pub fn doc_weights(&self) -> Vec<u64> {
        self.doc_offsets.windows(2).map(|w| w[1] - w[0]).collect()
    }

    /// Validate internal consistency. Word ids are range-checked only
    /// against a non-empty vocabulary (vocabless arenas are legal).
    pub fn validate(&self) -> anyhow::Result<()> {
        if !self.vocab.is_empty() {
            let v = self.vocab.len() as u32;
            for (i, &w) in self.tokens.iter().enumerate() {
                anyhow::ensure!(w < v, "token {i}: word id {w} out of range (V={v})");
            }
        }
        Ok(())
    }

    /// Resident bytes of the arena itself: the flat token vector plus
    /// the `(D+1)` doc offsets (vocab strings excluded — they are
    /// shared by every layout). This is the denominator of the
    /// memory-accounting counters ([`crate::metrics::PhaseTimers`]).
    pub fn arena_bytes(&self) -> u64 {
        self.tokens.len() as u64 * 4 + self.doc_offsets.len() as u64 * 8
    }

    /// One-line summary (Table-2 style).
    pub fn summary(&self) -> String {
        format!(
            "D={} V={} N={} max_Nd={} (packed)",
            self.num_docs(),
            self.vocab_size(),
            self.num_tokens(),
            self.max_doc_len()
        )
    }

    /// Convert back to the nested interchange form (token order and
    /// empty documents preserved exactly).
    pub fn to_nested(&self) -> Corpus {
        Corpus {
            docs: (0..self.num_docs()).map(|d| self.doc(d).to_vec()).collect(),
            vocab: self.vocab.clone(),
        }
    }
}

impl Corpus {
    /// Convert to the packed CSR arena form. Token order and empty
    /// documents are preserved exactly, so the conversion round-trips
    /// ([`PackedCorpus::to_nested`]) bit-for-bit.
    pub fn to_packed(&self) -> PackedCorpus {
        let mut doc_offsets = Vec::with_capacity(self.docs.len() + 1);
        let mut off = 0u64;
        doc_offsets.push(0);
        for doc in &self.docs {
            off += doc.len() as u64;
            doc_offsets.push(off);
        }
        let mut tokens = Vec::with_capacity(off as usize);
        for doc in &self.docs {
            tokens.extend_from_slice(doc);
        }
        PackedCorpus { tokens, doc_offsets, vocab: self.vocab.clone() }
    }
}

impl From<&Corpus> for PackedCorpus {
    fn from(c: &Corpus) -> Self {
        c.to_packed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Corpus {
        Corpus {
            docs: vec![vec![0, 1, 1], vec![2], vec![]],
            vocab: vec!["a".into(), "b".into(), "c".into(), "unused".into()],
        }
    }

    #[test]
    fn stats() {
        let c = tiny();
        assert_eq!(c.num_docs(), 3);
        assert_eq!(c.vocab_size(), 4);
        assert_eq!(c.num_tokens(), 4);
        assert_eq!(c.max_doc_len(), 3);
        assert_eq!(c.observed_vocab(), 3);
        assert_eq!(c.word_counts(), vec![1, 2, 1, 0]);
        assert_eq!(c.doc_weights(), vec![3, 1, 0]);
        c.validate().unwrap();
    }

    #[test]
    fn validate_catches_out_of_range() {
        let c = Corpus { docs: vec![vec![5]], vocab: vec!["a".into()] };
        assert!(c.validate().is_err());
    }

    #[test]
    fn packed_conversion_roundtrips_and_matches_stats() {
        let c = tiny();
        let p = c.to_packed();
        assert_eq!(p.num_docs(), c.num_docs());
        assert_eq!(p.num_tokens(), c.num_tokens());
        assert_eq!(p.vocab_size(), c.vocab_size());
        assert_eq!(p.max_doc_len(), c.max_doc_len());
        assert_eq!(p.doc_weights(), c.doc_weights());
        for d in 0..c.num_docs() {
            assert_eq!(p.doc(d), &c.docs[d][..], "doc {d}");
        }
        // Empty docs retained as zero-length ranges.
        assert_eq!(p.doc_len(2), 0);
        assert_eq!(p.to_nested().docs, c.docs);
        assert_eq!(p.to_nested().vocab, c.vocab);
        p.validate().unwrap();
        // DocAccess agreement across all three layouts.
        fn via<D: DocAccess + ?Sized>(a: &D, d: usize) -> Vec<u32> {
            a.doc(d).to_vec()
        }
        for d in 0..c.num_docs() {
            assert_eq!(via(&c, d), via(&p, d));
            assert_eq!(via(&c.docs, d), via(&p, d));
        }
    }

    #[test]
    fn packed_token_ranges_are_contiguous_blocks() {
        let c = tiny();
        let p = c.to_packed();
        assert_eq!(p.token_range(0, 3), 0..4);
        assert_eq!(p.token_range(1, 2), 3..4);
        assert_eq!(p.token_range(2, 3), 4..4); // empty doc, empty range
        assert_eq!(&p.tokens()[p.token_range(0, 1)], &[0, 1, 1]);
        assert_eq!(p.doc_offsets(), &[0, 3, 4, 4]);
    }

    #[test]
    fn packed_from_parts_enforces_invariants() {
        // Valid, including a vocabless arena with max word ids.
        let p = PackedCorpus::from_parts(vec![u32::MAX], vec![0, 0, 1, 1], vec![]).unwrap();
        assert_eq!(p.num_docs(), 3);
        assert_eq!(p.num_tokens(), 1);
        p.validate().unwrap(); // empty vocab: no range check
        // Bad shapes are rejected, never panic.
        assert!(PackedCorpus::from_parts(vec![], vec![], vec![]).is_err());
        assert!(PackedCorpus::from_parts(vec![1], vec![1, 1], vec![]).is_err());
        assert!(PackedCorpus::from_parts(vec![1, 2], vec![0, 2, 1], vec![]).is_err());
        assert!(PackedCorpus::from_parts(vec![1, 2], vec![0, 1], vec![]).is_err());
        // Non-empty vocab does range-check.
        let p = PackedCorpus::from_parts(vec![3], vec![0, 1], vec!["a".into()]).unwrap();
        assert!(p.validate().is_err());
    }

    #[test]
    fn packed_default_is_empty() {
        let p = PackedCorpus::default();
        assert_eq!(p.num_docs(), 0);
        assert_eq!(p.num_tokens(), 0);
        assert_eq!(p.max_doc_len(), 0);
        p.validate().unwrap();
    }
}
