//! Corpus substrate: document storage, I/O, preprocessing, synthesis.
//!
//! The samplers see a [`Corpus`]: a bag-of-words token stream per
//! document over an integer vocabulary. Sources:
//!
//! * [`io`] — the UCI "bag of words" interchange format used by the
//!   paper's NeurIPS/PubMed downloads (`docword.txt` + `vocab.txt`),
//!   plus a compact binary cache.
//! * [`preprocess`] — MALLET-equivalent preprocessing: stop-word
//!   removal, rare-word limit, minimum document size (paper §3 uses
//!   stoplist + min-doc-size 10 + rare-word limit 10).
//! * [`synthetic`] — the corpus *simulators* standing in for AP /
//!   CGCBIB / NeurIPS / PubMed (no network in this environment):
//!   a Zipf/Heaps generator matched to each corpus' (V, D, N) and an
//!   HDP generative-model generator with planted ground truth.
//! * [`registry`] — named corpus specs (`ap`, `cgcbib`, `neurips`,
//!   `pubmed-scaled`, …) with the paper's Table 2 statistics.

pub mod io;
pub mod preprocess;
pub mod registry;
pub mod synthetic;

/// A tokenized bag-of-words corpus.
///
/// Token order inside a document is meaningless to the model (bag of
/// words) but is kept stable so chains are reproducible.
#[derive(Clone, Debug, Default)]
pub struct Corpus {
    /// `docs[d]` = word ids of every token in document `d`.
    pub docs: Vec<Vec<u32>>,
    /// Word strings, indexed by word id.
    pub vocab: Vec<String>,
}

impl Corpus {
    /// Number of documents `D`.
    pub fn num_docs(&self) -> usize {
        self.docs.len()
    }

    /// Vocabulary size `V`.
    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    /// Total token count `N`.
    pub fn num_tokens(&self) -> u64 {
        self.docs.iter().map(|d| d.len() as u64).sum()
    }

    /// Longest document length `max_d N_d`.
    pub fn max_doc_len(&self) -> usize {
        self.docs.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Per-document lengths as weights for load-balanced sharding.
    pub fn doc_weights(&self) -> Vec<u64> {
        self.docs.iter().map(|d| d.len() as u64).collect()
    }

    /// Number of *distinct* word types that actually occur.
    pub fn observed_vocab(&self) -> usize {
        let mut seen = vec![false; self.vocab.len()];
        for doc in &self.docs {
            for &w in doc {
                seen[w as usize] = true;
            }
        }
        seen.iter().filter(|&&b| b).count()
    }

    /// Corpus-wide word frequencies.
    pub fn word_counts(&self) -> Vec<u64> {
        let mut counts = vec![0u64; self.vocab.len()];
        for doc in &self.docs {
            for &w in doc {
                counts[w as usize] += 1;
            }
        }
        counts
    }

    /// Validate internal consistency (word ids in range, nonempty vocab
    /// when there are tokens).
    pub fn validate(&self) -> anyhow::Result<()> {
        let v = self.vocab.len() as u32;
        for (d, doc) in self.docs.iter().enumerate() {
            for &w in doc {
                anyhow::ensure!(w < v, "doc {d}: word id {w} out of range (V={v})");
            }
        }
        Ok(())
    }

    /// One-line summary (Table-2 style).
    pub fn summary(&self) -> String {
        format!(
            "D={} V={} N={} max_Nd={}",
            self.num_docs(),
            self.vocab_size(),
            self.num_tokens(),
            self.max_doc_len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Corpus {
        Corpus {
            docs: vec![vec![0, 1, 1], vec![2], vec![]],
            vocab: vec!["a".into(), "b".into(), "c".into(), "unused".into()],
        }
    }

    #[test]
    fn stats() {
        let c = tiny();
        assert_eq!(c.num_docs(), 3);
        assert_eq!(c.vocab_size(), 4);
        assert_eq!(c.num_tokens(), 4);
        assert_eq!(c.max_doc_len(), 3);
        assert_eq!(c.observed_vocab(), 3);
        assert_eq!(c.word_counts(), vec![1, 2, 1, 0]);
        assert_eq!(c.doc_weights(), vec![3, 1, 0]);
        c.validate().unwrap();
    }

    #[test]
    fn validate_catches_out_of_range() {
        let c = Corpus { docs: vec![vec![5]], vocab: vec!["a".into()] };
        assert!(c.validate().is_err());
    }
}
