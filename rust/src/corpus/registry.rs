//! Named corpus registry: the four corpora of the paper's Table 2,
//! reproduced as HDP-generative analogs matched to the published
//! (V, D, N) statistics, plus scaled variants sized for this testbed.
//!
//! | corpus  | paper V | paper D   | paper N     | analog default |
//! |---------|---------|-----------|-------------|----------------|
//! | ap      | 7 074   | 2 206     | 393 567     | full size      |
//! | cgcbib  | 6 079   | 5 940     | 570 370     | full size      |
//! | neurips | 12 419  | 1 499     | 1 894 051   | full size      |
//! | pubmed  | 89 987  | 8 199 999 | 768 434 972 | 1/200 scale    |
//!
//! Real UCI files are used instead when present under
//! `$HDP_CORPUS_DIR` (`<name>.docword.txt` + `<name>.vocab.txt`), so
//! the same registry serves both simulated and genuine data.

use super::io;
use super::synthetic::HdpCorpusSpec;
use super::Corpus;
use std::path::PathBuf;

/// A registered corpus: paper statistics + generator settings.
#[derive(Clone, Debug)]
pub struct CorpusEntry {
    /// Registry key ("ap", "cgcbib", "neurips", "pubmed", plus tiny
    /// variants).
    pub name: &'static str,
    /// Paper's Table 2 row (None for the extra test corpora).
    pub paper: Option<PaperStats>,
    /// Generator spec for the simulated analog.
    pub spec: HdpCorpusSpec,
    /// Default iteration count used by the Table-2 reproduction (scaled
    /// down from the paper's; see EXPERIMENTS.md).
    pub default_iterations: usize,
    /// Paper's thread count for the corpus (Table 2).
    pub paper_threads: usize,
}

/// Published Table 2 statistics.
#[derive(Clone, Copy, Debug)]
pub struct PaperStats {
    pub vocab: usize,
    pub docs: usize,
    pub tokens: u64,
    pub iterations: usize,
    pub threads: usize,
    pub runtime_hours: f64,
}

fn entry(
    name: &'static str,
    paper: Option<PaperStats>,
    spec: HdpCorpusSpec,
    default_iterations: usize,
    paper_threads: usize,
) -> CorpusEntry {
    CorpusEntry { name, paper, spec, default_iterations, paper_threads }
}

/// All registered corpora.
pub fn all() -> Vec<CorpusEntry> {
    vec![
        // Tiny corpora for tests/quickstart (no paper row).
        entry(
            "tiny",
            None,
            HdpCorpusSpec {
                vocab: 300,
                topics: 6,
                gamma: 1.5,
                alpha: 1.5,
                topic_beta: 0.08,
                docs: 120,
                mean_doc_len: 40.0,
                len_sigma: 0.4,
                min_doc_len: 10,
            },
            200,
            1,
        ),
        entry(
            "small",
            None,
            HdpCorpusSpec {
                vocab: 1500,
                topics: 15,
                gamma: 2.0,
                alpha: 1.0,
                topic_beta: 0.05,
                docs: 800,
                mean_doc_len: 80.0,
                len_sigma: 0.5,
                min_doc_len: 10,
            },
            300,
            2,
        ),
        // AP analog: newswire — short-ish docs, moderate vocabulary.
        entry(
            "ap",
            Some(PaperStats {
                vocab: 7_074,
                docs: 2_206,
                tokens: 393_567,
                iterations: 100_000,
                threads: 8,
                runtime_hours: 3.8,
            }),
            HdpCorpusSpec {
                vocab: 7_074,
                topics: 120,
                gamma: 8.0,
                alpha: 0.8,
                topic_beta: 0.02,
                docs: 2_206,
                mean_doc_len: 178.0,
                len_sigma: 0.6,
                min_doc_len: 10,
            },
            2_000,
            8,
        ),
        // CGCBIB analog: bibliographic abstracts — many short docs.
        entry(
            "cgcbib",
            Some(PaperStats {
                vocab: 6_079,
                docs: 5_940,
                tokens: 570_370,
                iterations: 100_000,
                threads: 12,
                runtime_hours: 2.7,
            }),
            HdpCorpusSpec {
                vocab: 6_079,
                topics: 150,
                gamma: 10.0,
                alpha: 0.7,
                topic_beta: 0.02,
                docs: 5_940,
                mean_doc_len: 96.0,
                len_sigma: 0.5,
                min_doc_len: 10,
            },
            2_000,
            12,
        ),
        // NeurIPS analog: long papers, bigger vocabulary.
        entry(
            "neurips",
            Some(PaperStats {
                vocab: 12_419,
                docs: 1_499,
                tokens: 1_894_051,
                iterations: 255_500,
                threads: 8,
                runtime_hours: 24.0,
            }),
            HdpCorpusSpec {
                vocab: 12_419,
                topics: 250,
                gamma: 15.0,
                alpha: 1.2,
                topic_beta: 0.015,
                docs: 1_499,
                mean_doc_len: 1_264.0,
                len_sigma: 0.4,
                min_doc_len: 50,
            },
            400,
            8,
        ),
        // PubMed analog, scaled 1/200 in documents (same per-doc shape):
        // the full 8.2m-doc corpus is reproduced by extrapolation in
        // EXPERIMENTS.md from measured per-token cost.
        entry(
            "pubmed",
            Some(PaperStats {
                vocab: 89_987,
                docs: 8_199_999,
                tokens: 768_434_972,
                iterations: 25_000,
                threads: 20,
                runtime_hours: 82.4,
            }),
            HdpCorpusSpec {
                vocab: 60_000,
                topics: 400,
                gamma: 20.0,
                alpha: 0.6,
                topic_beta: 0.01,
                docs: 41_000,
                mean_doc_len: 94.0,
                len_sigma: 0.5,
                min_doc_len: 10,
            },
            200,
            20,
        ),
    ]
}

/// Look up a corpus by name.
pub fn find(name: &str) -> Option<CorpusEntry> {
    all().into_iter().find(|e| e.name == name)
}

/// Resolve a corpus by name: real UCI files when available under
/// `$HDP_CORPUS_DIR`, otherwise the cached synthetic analog (generated
/// on first use into `cache_dir`, default `.corpus-cache/`).
pub fn load(name: &str, seed: u64) -> anyhow::Result<Corpus> {
    let entry = find(name)
        .ok_or_else(|| anyhow::anyhow!("unknown corpus `{name}` (try: {})", names().join(", ")))?;
    // Real data first.
    if let Ok(dir) = std::env::var("HDP_CORPUS_DIR") {
        let dw = PathBuf::from(&dir).join(format!("{name}.docword.txt"));
        let vc = PathBuf::from(&dir).join(format!("{name}.vocab.txt"));
        if dw.exists() && vc.exists() {
            let raw = io::read_uci_files(&dw, &vc)?;
            let (clean, _) = super::preprocess::preprocess(
                &raw,
                &super::preprocess::PreprocessConfig::paper_defaults(),
            );
            return Ok(clean);
        }
    }
    // Synthetic analog with binary cache.
    let cache_dir = std::env::var("HDP_CACHE_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(".corpus-cache"));
    let cache = cache_dir.join(format!("{name}-{seed}.hdpc"));
    if cache.exists() {
        if let Ok(c) = io::read_binary(&cache) {
            return Ok(c);
        }
    }
    let (corpus, _) = entry.spec.generate(seed ^ 0x5eed_c0de);
    io::write_binary(&corpus, &cache).ok(); // cache failure is non-fatal
    Ok(corpus)
}

/// [`load`] straight into the packed arena form (same resolution
/// order, same cache files — the packing is a conversion of the loaded
/// corpus, so nested and packed loads always agree).
pub fn load_packed(name: &str, seed: u64) -> anyhow::Result<super::PackedCorpus> {
    Ok(load(name, seed)?.to_packed())
}

/// Registered names.
pub fn names() -> Vec<&'static str> {
    all().into_iter().map(|e| e.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `HDP_CACHE_DIR` is process-global; every test that mutates it
    /// must hold this lock or they race under the parallel harness.
    static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn registry_contains_paper_corpora() {
        for name in ["ap", "cgcbib", "neurips", "pubmed"] {
            let e = find(name).unwrap();
            assert!(e.paper.is_some(), "{name} should carry paper stats");
        }
        assert!(find("tiny").is_some());
        assert!(find("nope").is_none());
    }

    #[test]
    fn paper_stats_match_table2() {
        let ap = find("ap").unwrap().paper.unwrap();
        assert_eq!(ap.vocab, 7074);
        assert_eq!(ap.docs, 2206);
        assert_eq!(ap.tokens, 393_567);
        let pm = find("pubmed").unwrap().paper.unwrap();
        assert_eq!(pm.tokens, 768_434_972);
        assert_eq!(pm.threads, 20);
    }

    #[test]
    fn tiny_loads_and_caches() {
        let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let dir = std::env::temp_dir().join("hdp_registry_test");
        std::env::set_var("HDP_CACHE_DIR", &dir);
        let c1 = load("tiny", 1).unwrap();
        let c2 = load("tiny", 1).unwrap(); // cache hit
        assert_eq!(c1.num_tokens(), c2.num_tokens());
        assert_eq!(c1.num_docs(), 120);
        std::env::remove_var("HDP_CACHE_DIR");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn packed_load_matches_registry_metadata() {
        // Corpus→PackedCorpus conversion must preserve the registry's
        // metadata-level counts exactly: D from the generator spec, and
        // N/V/doc boundaries from the nested load.
        let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let dir = std::env::temp_dir().join("hdp_registry_test3");
        std::env::set_var("HDP_CACHE_DIR", &dir);
        let entry = find("tiny").unwrap();
        let nested = load("tiny", 4).unwrap();
        let packed = load_packed("tiny", 4).unwrap();
        assert_eq!(packed.num_docs(), entry.spec.docs);
        assert_eq!(packed.num_docs(), nested.num_docs());
        assert_eq!(packed.num_tokens(), nested.num_tokens());
        assert_eq!(packed.vocab_size(), nested.vocab_size());
        assert_eq!(packed.max_doc_len(), nested.max_doc_len());
        assert_eq!(packed.doc_weights(), nested.doc_weights());
        for d in 0..nested.num_docs() {
            assert_eq!(packed.doc(d), &nested.docs[d][..], "doc {d}");
        }
        std::env::remove_var("HDP_CACHE_DIR");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn analog_statistics_close_to_paper() {
        // Mean doc length of the generator matches the paper's N/D
        // within 20% (stochastic).
        let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let dir = std::env::temp_dir().join("hdp_registry_test2");
        std::env::set_var("HDP_CACHE_DIR", &dir);
        let e = find("ap").unwrap();
        let paper = e.paper.unwrap();
        let want = paper.tokens as f64 / paper.docs as f64;
        assert!((e.spec.mean_doc_len - want).abs() / want < 0.2);
        std::env::remove_var("HDP_CACHE_DIR");
        std::fs::remove_dir_all(&dir).ok();
    }
}
