//! The training coordinator: drives any [`Trainer`] through its
//! iterations with wall-clock budgeting, periodic diagnostics, and
//! trace streaming — the L3 event loop.
//!
//! Fig 1's three x-axes come from here: per-iteration traces (AP,
//! CGCBIB, PubMed panels), real-time traces under a fixed wall-clock
//! budget (NeurIPS panels), and per-iteration runtime (panel i).

use crate::config::RunConfig;
use crate::hdp::Trainer;
use crate::metrics::{IterRecord, TraceWriter};
use std::time::Instant;

/// Outcome of a training run.
#[derive(Clone, Debug)]
pub struct TrainSummary {
    /// Iterations actually completed (≤ requested when a time budget
    /// fires).
    pub iterations: usize,
    /// Total wall-clock seconds.
    pub elapsed_secs: f64,
    /// Final evaluated log-likelihood.
    pub final_log_likelihood: f64,
    /// Final active topic count.
    pub final_active_topics: usize,
    /// Tokens per second over the whole run.
    pub tokens_per_sec: f64,
}

/// Options controlling the loop beyond [`RunConfig`].
#[derive(Clone, Debug, Default)]
pub struct LoopOptions {
    /// Print progress lines to stdout.
    pub verbose: bool,
    /// Evaluate diagnostics on iteration 1 regardless of `eval_every`.
    pub eval_first: bool,
}

/// Run `trainer` for `run.iterations` (or until `run.time_budget_secs`
/// elapses), pushing an [`IterRecord`] into `trace` every
/// `run.eval_every` iterations (plus the final one).
pub fn train(
    trainer: &mut dyn Trainer,
    run: &RunConfig,
    trace: &mut TraceWriter,
    opts: &LoopOptions,
) -> anyhow::Result<TrainSummary> {
    let start = Instant::now();
    let tokens = trainer.corpus().num_tokens();
    let mut completed = 0usize;
    let mut last_rec: Option<IterRecord> = None;
    for it in 1..=run.iterations {
        let iter_start = Instant::now();
        trainer.step()?;
        let iter_secs = iter_start.elapsed().as_secs_f64();
        completed = it;
        let budget_hit = run.time_budget_secs > 0
            && start.elapsed().as_secs() >= run.time_budget_secs;
        let eval_now = it % run.eval_every == 0
            || it == run.iterations
            || budget_hit
            || (opts.eval_first && it == 1);
        if eval_now {
            let d = trainer.diagnostics();
            let rec = IterRecord {
                iteration: it,
                elapsed_secs: start.elapsed().as_secs_f64(),
                iter_secs,
                log_likelihood: d.log_likelihood,
                active_topics: d.active_topics,
                flag_topic_tokens: d.flag_topic_tokens,
                total_tokens: d.total_tokens,
            };
            if opts.verbose {
                println!(
                    "[{}] iter {:>6}  ll {:>14.2}  topics {:>4}  {:>7.3}s/iter",
                    trainer.name(),
                    it,
                    rec.log_likelihood,
                    rec.active_topics,
                    rec.iter_secs
                );
            }
            trace.push(rec.clone())?;
            last_rec = Some(rec);
        }
        if budget_hit {
            break;
        }
    }
    trace.flush()?;
    let elapsed = start.elapsed().as_secs_f64();
    let last = last_rec.expect("at least one evaluation");
    Ok(TrainSummary {
        iterations: completed,
        elapsed_secs: elapsed,
        final_log_likelihood: last.log_likelihood,
        final_active_topics: last.active_topics,
        tokens_per_sec: tokens as f64 * completed as f64 / elapsed.max(1e-9),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HdpConfig, RunConfig};
    use crate::corpus::synthetic::HdpCorpusSpec;
    use crate::hdp::pc::PcSampler;

    fn corpus() -> std::sync::Arc<crate::corpus::Corpus> {
        let (c, _) = HdpCorpusSpec {
            vocab: 100,
            topics: 4,
            gamma: 1.0,
            alpha: 1.0,
            topic_beta: 0.1,
            docs: 30,
            mean_doc_len: 20.0,
            len_sigma: 0.3,
            min_doc_len: 5,
        }
        .generate(61);
        std::sync::Arc::new(c)
    }

    #[test]
    fn loop_runs_and_records() {
        let cfg = HdpConfig { k_max: 20, ..Default::default() };
        let mut t = PcSampler::new(corpus(), cfg, 1, 1).unwrap();
        let run = RunConfig { iterations: 7, eval_every: 3, ..Default::default() };
        let mut trace = TraceWriter::in_memory();
        let summary = train(&mut t, &run, &mut trace, &LoopOptions::default()).unwrap();
        assert_eq!(summary.iterations, 7);
        // evals at 3, 6, 7
        let iters: Vec<usize> = trace.records().iter().map(|r| r.iteration).collect();
        assert_eq!(iters, vec![3, 6, 7]);
        assert!(summary.tokens_per_sec > 0.0);
        assert_eq!(summary.final_active_topics, trace.records().last().unwrap().active_topics);
    }

    #[test]
    fn time_budget_stops_early() {
        let cfg = HdpConfig { k_max: 20, ..Default::default() };
        let mut t = PcSampler::new(corpus(), cfg, 1, 2).unwrap();
        // 1-second budget with an absurd iteration count: must stop on
        // budget, not run 10^8 iterations.
        let run = RunConfig {
            iterations: 100_000_000,
            eval_every: 1000,
            time_budget_secs: 1,
            ..Default::default()
        };
        let mut trace = TraceWriter::in_memory();
        let summary = train(&mut t, &run, &mut trace, &LoopOptions::default()).unwrap();
        assert!(summary.iterations < 100_000_000);
        assert!(summary.elapsed_secs < 30.0);
        assert!(!trace.records().is_empty());
    }

    #[test]
    fn eval_first_option() {
        let cfg = HdpConfig { k_max: 20, ..Default::default() };
        let mut t = PcSampler::new(corpus(), cfg, 1, 3).unwrap();
        let run = RunConfig { iterations: 5, eval_every: 100, ..Default::default() };
        let mut trace = TraceWriter::in_memory();
        train(
            &mut t,
            &run,
            &mut trace,
            &LoopOptions { eval_first: true, verbose: false },
        )
        .unwrap();
        let iters: Vec<usize> = trace.records().iter().map(|r| r.iteration).collect();
        assert_eq!(iters, vec![1, 5]);
    }
}
