//! The training coordinator: drives any [`Trainer`] through its
//! iterations with wall-clock budgeting, periodic diagnostics, and
//! trace streaming — the L3 event loop.
//!
//! Fig 1's three x-axes come from here: per-iteration traces (AP,
//! CGCBIB, PubMed panels), real-time traces under a fixed wall-clock
//! budget (NeurIPS panels), and per-iteration runtime (panel i).

use crate::config::RunConfig;
use crate::hdp::Trainer;
use crate::metrics::{IterRecord, TraceWriter};
use std::time::Instant;

/// Outcome of a training run.
#[derive(Clone, Debug)]
pub struct TrainSummary {
    /// Iterations actually completed (≤ requested when a time budget
    /// fires).
    pub iterations: usize,
    /// Total wall-clock seconds.
    pub elapsed_secs: f64,
    /// Final evaluated log-likelihood.
    pub final_log_likelihood: f64,
    /// Final active topic count.
    pub final_active_topics: usize,
    /// Tokens per second over the iterations this run performed.
    pub tokens_per_sec: f64,
    /// Periodic checkpoints written durably this run.
    pub checkpoints_written: usize,
    /// Periodic checkpoint attempts that failed (training continued —
    /// a checkpoint failure costs durability, never the chain).
    pub checkpoints_failed: usize,
}

/// Options controlling the loop beyond [`RunConfig`].
#[derive(Clone, Debug, Default)]
pub struct LoopOptions {
    /// Print progress lines to stdout.
    pub verbose: bool,
    /// Evaluate diagnostics on the first iteration this run performs
    /// regardless of `eval_every`.
    pub eval_first: bool,
    /// Directory for periodic checkpoints (`ckpt-NNNNNNNNNN.ckpt`,
    /// written atomically + checksummed every
    /// `run.checkpoint_every` iterations). `None` disables them even
    /// when `checkpoint_every > 0`.
    pub checkpoint_dir: Option<std::path::PathBuf>,
}

/// Run `trainer` from its current iteration up to `run.iterations` (or
/// until `run.time_budget_secs` elapses), pushing an [`IterRecord`]
/// into `trace` every `run.eval_every` iterations (plus the final one).
///
/// # Resume and crash safety
///
/// The loop starts at `trainer.iterations_done()`, so a sampler
/// restored via [`crate::hdp::pc::PcSampler::resume_chain`] simply
/// continues its chain — the combined trace covers
/// `start + 1 ..= run.iterations` and is **bit-identical** to the
/// uninterrupted run. With `run.checkpoint_every > 0` and
/// [`LoopOptions::checkpoint_dir`] set, a durable checkpoint
/// (atomic rename + checksum trailer) is written every
/// `checkpoint_every` iterations; a failed write is reported and
/// counted, never fatal. Pick the newest loadable snapshot back up
/// with [`crate::hdp::checkpoint::latest_valid`].
pub fn train(
    trainer: &mut dyn Trainer,
    run: &RunConfig,
    trace: &mut TraceWriter,
    opts: &LoopOptions,
) -> anyhow::Result<TrainSummary> {
    let start = Instant::now();
    let tokens = trainer.docs().num_tokens();
    let start_iter = trainer.iterations_done();
    let mut completed = start_iter;
    let mut last_rec: Option<IterRecord> = None;
    let mut checkpoints_written = 0usize;
    let mut checkpoints_failed = 0usize;
    for it in (start_iter + 1)..=run.iterations {
        let iter_start = Instant::now();
        trainer.step()?;
        let iter_secs = iter_start.elapsed().as_secs_f64();
        completed = it;
        let budget_hit = run.time_budget_secs > 0
            && start.elapsed().as_secs() >= run.time_budget_secs;
        let eval_now = it % run.eval_every == 0
            || it == run.iterations
            || budget_hit
            || (opts.eval_first && it == start_iter + 1);
        if eval_now {
            let d = trainer.diagnostics();
            let rec = IterRecord {
                iteration: it,
                elapsed_secs: start.elapsed().as_secs_f64(),
                iter_secs,
                log_likelihood: d.log_likelihood,
                active_topics: d.active_topics,
                flag_topic_tokens: d.flag_topic_tokens,
                total_tokens: d.total_tokens,
            };
            if opts.verbose {
                println!(
                    "[{}] iter {:>6}  ll {:>14.2}  topics {:>4}  {:>7.3}s/iter",
                    trainer.name(),
                    it,
                    rec.log_likelihood,
                    rec.active_topics,
                    rec.iter_secs
                );
            }
            trace.push(rec.clone())?;
            last_rec = Some(rec);
        }
        if run.checkpoint_every > 0 && it % run.checkpoint_every == 0 {
            if let Some(dir) = &opts.checkpoint_dir {
                let path = dir.join(crate::hdp::checkpoint::periodic_name(it as u64));
                match trainer.checkpoint().save(&path) {
                    Ok(()) => checkpoints_written += 1,
                    Err(e) => {
                        // Durability lost, chain intact: keep training.
                        checkpoints_failed += 1;
                        eprintln!(
                            "warning: checkpoint at iteration {it} failed: {e:#}"
                        );
                    }
                }
            }
        }
        if budget_hit {
            break;
        }
    }
    trace.flush()?;
    let elapsed = start.elapsed().as_secs_f64();
    let (final_log_likelihood, final_active_topics) = match &last_rec {
        Some(rec) => (rec.log_likelihood, rec.active_topics),
        // Zero iterations this run (already at or past the target —
        // e.g. resuming a finished chain): evaluate the state as-is.
        None => {
            let d = trainer.diagnostics();
            (d.log_likelihood, d.active_topics)
        }
    };
    Ok(TrainSummary {
        iterations: completed,
        elapsed_secs: elapsed,
        final_log_likelihood,
        final_active_topics,
        tokens_per_sec: tokens as f64 * (completed - start_iter) as f64
            / elapsed.max(1e-9),
        checkpoints_written,
        checkpoints_failed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HdpConfig, RunConfig};
    use crate::corpus::synthetic::HdpCorpusSpec;
    use crate::hdp::pc::PcSampler;

    fn corpus() -> std::sync::Arc<crate::corpus::Corpus> {
        let (c, _) = HdpCorpusSpec {
            vocab: 100,
            topics: 4,
            gamma: 1.0,
            alpha: 1.0,
            topic_beta: 0.1,
            docs: 30,
            mean_doc_len: 20.0,
            len_sigma: 0.3,
            min_doc_len: 5,
        }
        .generate(61);
        std::sync::Arc::new(c)
    }

    #[test]
    fn loop_runs_and_records() {
        let cfg = HdpConfig { k_max: 20, ..Default::default() };
        let mut t = PcSampler::new(corpus(), cfg, 1, 1).unwrap();
        let run = RunConfig { iterations: 7, eval_every: 3, ..Default::default() };
        let mut trace = TraceWriter::in_memory();
        let summary = train(&mut t, &run, &mut trace, &LoopOptions::default()).unwrap();
        assert_eq!(summary.iterations, 7);
        // evals at 3, 6, 7
        let iters: Vec<usize> = trace.records().iter().map(|r| r.iteration).collect();
        assert_eq!(iters, vec![3, 6, 7]);
        assert!(summary.tokens_per_sec > 0.0);
        assert_eq!(summary.final_active_topics, trace.records().last().unwrap().active_topics);
    }

    #[test]
    fn time_budget_stops_early() {
        let cfg = HdpConfig { k_max: 20, ..Default::default() };
        let mut t = PcSampler::new(corpus(), cfg, 1, 2).unwrap();
        // 1-second budget with an absurd iteration count: must stop on
        // budget, not run 10^8 iterations.
        let run = RunConfig {
            iterations: 100_000_000,
            eval_every: 1000,
            time_budget_secs: 1,
            ..Default::default()
        };
        let mut trace = TraceWriter::in_memory();
        let summary = train(&mut t, &run, &mut trace, &LoopOptions::default()).unwrap();
        assert!(summary.iterations < 100_000_000);
        assert!(summary.elapsed_secs < 30.0);
        assert!(!trace.records().is_empty());
    }

    #[test]
    fn eval_first_option() {
        let cfg = HdpConfig { k_max: 20, ..Default::default() };
        let mut t = PcSampler::new(corpus(), cfg, 1, 3).unwrap();
        let run = RunConfig { iterations: 5, eval_every: 100, ..Default::default() };
        let mut trace = TraceWriter::in_memory();
        train(
            &mut t,
            &run,
            &mut trace,
            &LoopOptions { eval_first: true, ..Default::default() },
        )
        .unwrap();
        let iters: Vec<usize> = trace.records().iter().map(|r| r.iteration).collect();
        assert_eq!(iters, vec![1, 5]);
    }
}
