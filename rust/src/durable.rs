//! Durable, checksummed file writes — the crash-safety primitives
//! shared by checkpoints ([`crate::hdp::checkpoint`]) and the packed
//! corpus format ([`crate::corpus::io`]).
//!
//! # Atomic write protocol
//!
//! [`atomic_write`] writes a unique temp file **in the same
//! directory** as the target, fsyncs the data (`fdatasync`), renames
//! it over the target, then fsyncs the parent directory so the rename
//! itself survives a crash. A failure at any point removes the temp
//! file and leaves the previous target contents untouched — a reader
//! can never observe a half-written file at the final path.
//!
//! # Checksum trailer
//!
//! Every payload gets an 8-byte trailer appended:
//!
//! ```text
//! [crc32(payload) as u32 LE][tag b"HSUM"]
//! ```
//!
//! where the CRC covers every payload byte (a vendored IEEE CRC-32;
//! no crates). Verifying readers stream the payload through
//! [`HashingReader`], require the consumed byte count to equal
//! `file_len - 8`, and match the trailer — so *any* truncation,
//! extension, or bit flip of the file fails closed with `Err`.

use anyhow::{Context, Result};
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};

/// Trailer size in bytes: u32 CRC + 4-byte tag.
pub const TRAILER_LEN: u64 = 8;
/// Trailer tag marking a checksummed file.
pub const TRAILER_TAG: &[u8; 4] = b"HSUM";

/// Failpoint site names for one atomic-write pipeline (see
/// [`crate::fault`] for the registry).
pub struct WriteSites {
    /// Payload byte stream (supports [`crate::fault::FaultKind::Torn`]).
    pub write: &'static str,
    /// Data fsync before the rename.
    pub sync: &'static str,
    /// Temp → final rename.
    pub rename: &'static str,
    /// Parent-directory fsync after the rename.
    pub dirsync: &'static str,
}

/// Checkpoint writes (`ckpt.*` sites).
pub const CKPT_SITES: WriteSites = WriteSites {
    write: "ckpt.write",
    sync: "ckpt.sync",
    rename: "ckpt.rename",
    dirsync: "ckpt.dirsync",
};

/// Packed corpus writes (`packed.*` sites).
pub const PACKED_SITES: WriteSites = WriteSites {
    write: "packed.write",
    sync: "packed.sync",
    rename: "packed.rename",
    dirsync: "packed.dirsync",
};

// ---------------------------------------------------------------------------
// CRC-32 (IEEE 802.3, reflected, poly 0xEDB88320) — vendored, no crates.

const CRC_TABLE: [u32; 256] = build_crc_table();

const fn build_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// Incremental IEEE CRC-32.
#[derive(Clone, Copy, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    /// Fresh digest.
    pub fn new() -> Self {
        Self { state: 0xFFFF_FFFF }
    }

    /// Absorb `bytes`.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut s = self.state;
        for &b in bytes {
            s = CRC_TABLE[((s ^ b as u32) & 0xFF) as usize] ^ (s >> 8);
        }
        self.state = s;
    }

    /// Final digest value (the digest may keep absorbing afterwards).
    pub fn value(&self) -> u32 {
        !self.state
    }
}

/// One-shot CRC of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.value()
}

// ---------------------------------------------------------------------------
// Hashing adapters.

/// A reader that hashes and counts exactly the bytes the caller
/// consumes.
///
/// It must wrap **above** any `BufReader` (hashing the buffered
/// source would absorb read-ahead bytes — including the trailer — that
/// the parser never consumed).
pub struct HashingReader<R> {
    inner: R,
    crc: Crc32,
    consumed: u64,
}

impl<R: Read> HashingReader<R> {
    /// Wrap `inner`.
    pub fn new(inner: R) -> Self {
        Self { inner, crc: Crc32::new(), consumed: 0 }
    }

    /// Bytes consumed through this reader so far.
    pub fn consumed(&self) -> u64 {
        self.consumed
    }

    /// CRC over the consumed bytes so far.
    pub fn crc(&self) -> u32 {
        self.crc.value()
    }

    /// Read exactly `buf.len()` bytes **without** hashing or counting
    /// them — for the trailer, which the CRC must not cover.
    pub fn read_exact_unhashed(&mut self, buf: &mut [u8]) -> std::io::Result<()> {
        self.inner.read_exact(buf)
    }
}

impl<R: Read> Read for HashingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.crc.update(&buf[..n]);
        self.consumed += n as u64;
        Ok(n)
    }
}

/// A writer that hashes everything written through it, with a raw
/// (unhashed) escape hatch for the trailer.
struct Crc32Writer<W> {
    inner: W,
    crc: Crc32,
}

impl<W: Write> Crc32Writer<W> {
    fn new(inner: W) -> Self {
        Self { inner, crc: Crc32::new() }
    }

    fn crc(&self) -> u32 {
        self.crc.value()
    }

    /// Write without updating the digest (trailer bytes).
    fn write_raw(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        self.inner.write_all(bytes)
    }
}

impl<W: Write> Write for Crc32Writer<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.crc.update(&buf[..n]);
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// A writer that consults a failpoint site per write, supporting exact
/// torn-at-byte-offset cuts. Transparent when the `failpoints` feature
/// is off or the site is unarmed.
struct FaultWriter<W> {
    inner: W,
    #[cfg_attr(not(feature = "failpoints"), allow(dead_code))]
    site: &'static str,
}

impl<W: Write> Write for FaultWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        #[cfg(feature = "failpoints")]
        {
            let allowed = crate::fault::check_write(self.site, buf.len() as u64)? as usize;
            if allowed < buf.len() {
                // Torn cut: land exactly the allowed prefix, then fail.
                self.inner.write_all(&buf[..allowed])?;
                self.inner.flush()?;
                return Err(crate::fault::injected_error(self.site));
            }
        }
        self.inner.write(buf)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

// ---------------------------------------------------------------------------
// Atomic checksummed writes.

fn tmp_sibling(path: &Path) -> PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    let name = path.file_name().map(|s| s.to_string_lossy()).unwrap_or_default();
    path.with_file_name(format!(".{name}.{}-{n}.tmp", std::process::id()))
}

/// Atomically replace `path` with `payload`'s output plus the checksum
/// trailer (module docs: temp in same dir → data fsync → rename →
/// parent-dir fsync). On error the temp file is removed and any
/// previous contents of `path` are untouched.
///
/// There is deliberately **no retry** anywhere in this pipeline: a
/// failed save must surface as `Err` with the old file intact, not be
/// papered over mid-protocol (retries for transient faults live in the
/// positioned block-I/O layer).
pub fn atomic_write(
    path: &Path,
    sites: &WriteSites,
    payload: impl FnOnce(&mut dyn Write) -> Result<()>,
) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("create {}", dir.display()))?;
        }
    }
    let tmp = tmp_sibling(path);
    let res = write_tmp(&tmp, sites, payload).and_then(|()| {
        crate::fault::check(sites.rename)?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("rename {} -> {}", tmp.display(), path.display()))?;
        crate::fault::check(sites.dirsync)?;
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                // Durable rename: fsync the directory entry too.
                std::fs::File::open(dir)?.sync_all()?;
            }
        }
        Ok(())
    });
    if res.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    res.with_context(|| format!("atomic write of {}", path.display()))
}

fn write_tmp(
    tmp: &Path,
    sites: &WriteSites,
    payload: impl FnOnce(&mut dyn Write) -> Result<()>,
) -> Result<()> {
    let file = std::fs::File::create(tmp)
        .with_context(|| format!("create {}", tmp.display()))?;
    {
        let fw = FaultWriter { inner: &file, site: sites.write };
        let mut w = Crc32Writer::new(BufWriter::with_capacity(1 << 16, fw));
        payload(&mut w)?;
        let crc = w.crc();
        w.write_raw(&crc.to_le_bytes())?;
        w.write_raw(TRAILER_TAG)?;
        w.flush()?;
    }
    crate::fault::check(sites.sync)?;
    // The data must be on disk before the rename publishes it.
    file.sync_data()?;
    Ok(())
}

/// Split a checksummed file's length into `payload_len`, rejecting
/// files too short to carry a trailer.
pub fn payload_len(file_len: u64, what: &str) -> Result<u64> {
    anyhow::ensure!(
        file_len >= TRAILER_LEN,
        "corrupt {what}: {file_len} bytes is too short for a checksum trailer"
    );
    Ok(file_len - TRAILER_LEN)
}

/// Finish a verified read: require the parser to have consumed exactly
/// the payload, then read the trailer via `r` and match tag + CRC.
pub fn verify_trailer<R: Read>(
    r: &mut HashingReader<R>,
    expected_payload: u64,
    what: &str,
) -> Result<()> {
    anyhow::ensure!(
        r.consumed() == expected_payload,
        "corrupt {what}: parsed {} payload bytes, expected {expected_payload}",
        r.consumed()
    );
    let crc = r.crc();
    let mut trailer = [0u8; TRAILER_LEN as usize];
    r.read_exact_unhashed(&mut trailer)
        .map_err(|e| anyhow::anyhow!("corrupt {what}: unreadable checksum trailer: {e}"))?;
    anyhow::ensure!(
        &trailer[4..8] == TRAILER_TAG,
        "corrupt {what}: missing checksum trailer tag"
    );
    let stored = u32::from_le_bytes(trailer[0..4].try_into().unwrap());
    anyhow::ensure!(
        stored == crc,
        "corrupt {what}: checksum mismatch (stored {stored:#010x}, computed {crc:#010x})"
    );
    Ok(())
}

/// Re-scan an already-open file from byte 0 and verify its checksum
/// trailer (the last [`TRAILER_LEN`] bytes) over everything before it.
/// Chunked 64 KiB reads; the cursor position afterwards is
/// unspecified. For readers that keep the file open for positioned
/// block I/O and therefore never stream the whole payload through a
/// [`HashingReader`].
pub fn verify_file_crc(
    f: &mut (impl Read + std::io::Seek),
    file_len: u64,
    what: &str,
) -> Result<()> {
    let payload = payload_len(file_len, what)?;
    f.seek(std::io::SeekFrom::Start(0))?;
    let mut crc = Crc32::new();
    let mut buf = vec![0u8; 64 * 1024];
    let mut left = payload;
    while left > 0 {
        let take = (buf.len() as u64).min(left) as usize;
        f.read_exact(&mut buf[..take])
            .map_err(|e| anyhow::anyhow!("corrupt {what}: short payload read: {e}"))?;
        crc.update(&buf[..take]);
        left -= take as u64;
    }
    let mut trailer = [0u8; TRAILER_LEN as usize];
    f.read_exact(&mut trailer)
        .map_err(|e| anyhow::anyhow!("corrupt {what}: unreadable checksum trailer: {e}"))?;
    anyhow::ensure!(
        &trailer[4..8] == TRAILER_TAG,
        "corrupt {what}: missing checksum trailer tag"
    );
    let stored = u32::from_le_bytes(trailer[0..4].try_into().unwrap());
    anyhow::ensure!(
        stored == crc.value(),
        "corrupt {what}: checksum mismatch (stored {stored:#010x}, computed {:#010x})",
        crc.value()
    );
    Ok(())
}

/// True if `name` looks like one of [`atomic_write`]'s temp files — a
/// partial left behind only if the process died mid-save.
pub fn is_tmp_partial(name: &str) -> bool {
    name.ends_with(".tmp") && name.starts_with('.')
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_answer() {
        // The classic IEEE CRC-32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // Incremental == one-shot.
        let mut c = Crc32::new();
        c.update(b"1234");
        c.update(b"56789");
        assert_eq!(c.value(), 0xCBF4_3926);
    }

    #[test]
    fn atomic_write_roundtrip_and_trailer() {
        let dir = std::env::temp_dir().join("hdp_durable_rt");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("blob.bin");
        atomic_write(&p, &CKPT_SITES, |w| {
            w.write_all(b"hello durable world")?;
            Ok(())
        })
        .unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..19], b"hello durable world");
        assert_eq!(bytes.len(), 19 + TRAILER_LEN as usize);
        assert_eq!(&bytes[23..27], TRAILER_TAG);
        let stored = u32::from_le_bytes(bytes[19..23].try_into().unwrap());
        assert_eq!(stored, crc32(b"hello durable world"));
        // No temp files left behind.
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| is_tmp_partial(&e.file_name().to_string_lossy()))
            .collect();
        assert!(leftovers.is_empty(), "temp files left: {leftovers:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failed_payload_leaves_previous_contents() {
        let dir = std::env::temp_dir().join("hdp_durable_fail");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("blob.bin");
        atomic_write(&p, &CKPT_SITES, |w| {
            w.write_all(b"version 1")?;
            Ok(())
        })
        .unwrap();
        let before = std::fs::read(&p).unwrap();
        let err = atomic_write(&p, &CKPT_SITES, |w| {
            w.write_all(b"version 2 partial")?;
            anyhow::bail!("simulated payload failure")
        });
        assert!(err.is_err());
        assert_eq!(std::fs::read(&p).unwrap(), before, "target was clobbered");
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| is_tmp_partial(&e.file_name().to_string_lossy()))
            .collect();
        assert!(leftovers.is_empty(), "temp files left: {leftovers:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn hashing_reader_verifies_and_rejects() {
        let payload = b"some payload bytes";
        let mut file = payload.to_vec();
        file.extend_from_slice(&crc32(payload).to_le_bytes());
        file.extend_from_slice(TRAILER_TAG);

        // Clean verify.
        let mut r = HashingReader::new(&file[..]);
        let mut buf = vec![0u8; payload.len()];
        r.read_exact(&mut buf).unwrap();
        verify_trailer(&mut r, payload.len() as u64, "blob").unwrap();

        // Under-consumed payload is rejected.
        let mut r = HashingReader::new(&file[..]);
        let mut buf = vec![0u8; payload.len() - 1];
        r.read_exact(&mut buf).unwrap();
        assert!(verify_trailer(&mut r, payload.len() as u64, "blob").is_err());

        // A flipped payload byte is rejected.
        let mut bad = file.clone();
        bad[3] ^= 0x40;
        let mut r = HashingReader::new(&bad[..]);
        let mut buf = vec![0u8; payload.len()];
        r.read_exact(&mut buf).unwrap();
        let err = verify_trailer(&mut r, payload.len() as u64, "blob").unwrap_err();
        assert!(err.to_string().contains("checksum mismatch"), "{err}");

        // A flipped tag byte is rejected.
        let mut bad = file.clone();
        let taglast = bad.len() - 1;
        bad[taglast] ^= 0xff;
        let mut r = HashingReader::new(&bad[..]);
        let mut buf = vec![0u8; payload.len()];
        r.read_exact(&mut buf).unwrap();
        let err = verify_trailer(&mut r, payload.len() as u64, "blob").unwrap_err();
        assert!(err.to_string().contains("trailer tag"), "{err}");
    }
}
