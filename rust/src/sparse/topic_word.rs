//! The topic-word sufficient statistic `n` (`K* × V`, sparse).
//!
//! In the partially collapsed sampler `Φ` is held fixed during the z
//! phase, so `n` does not need to be updated per token — it is *rebuilt*
//! once per iteration from the freshly sampled assignments. Each shard
//! accumulates its own [`TopicWordAcc`]; the coordinator merges them
//! into [`TopicWordRows`] (per-topic sorted `(word, count)` rows), which
//! is exactly the layout the Poisson Pólya urn `Φ` step consumes.
//!
//! Two merge paths produce bit-identical rows: the serial drain
//! ([`TopicWordRows::merge_from_iter`], the reference) and the
//! pool-parallel two-phase range merge ([`TopicWordRows::merge_par`])
//! the pipelined sampler uses — phase 1 drains each shard accumulator
//! into per-(shard, topic) buckets in parallel over shards, phase 2
//! sorts and combines each topic row in parallel over topics. The
//! merged `n` is what unblocks Φ for the *next* iteration, so its
//! latency sits directly on the pipeline's critical path.

/// Shard-local accumulator of `(topic, word) → count`.
///
/// Keyed by `(k << 32) | v` in an open-addressing map specialized for
/// u64 keys / u32 values — measured ~3× faster than `std::HashMap` with
/// SipHash on this access pattern, and the merge path gets sorted
/// output for free via radix bucketing by topic.
#[derive(Clone, Debug)]
pub struct TopicWordAcc {
    keys: Vec<u64>,
    vals: Vec<u32>,
    mask: usize,
    len: usize,
}

const EMPTY: u64 = u64::MAX;

#[inline]
fn hash_u64(x: u64) -> u64 {
    // Fibonacci/Murmur-style finalizer.
    let mut z = x.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z ^= z >> 29;
    z = z.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^ (z >> 32)
}

impl TopicWordAcc {
    /// New accumulator with capacity for ~`cap` distinct pairs.
    pub fn with_capacity(cap: usize) -> Self {
        let size = (cap * 2).next_power_of_two().max(64);
        Self { keys: vec![EMPTY; size], vals: vec![0; size], mask: size - 1, len: 0 }
    }

    /// Number of distinct `(topic, word)` pairs.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.len
    }

    /// Pairs this accumulator can hold before its table regrows (the
    /// open-addressing map doubles at 50% load).
    #[inline]
    pub fn capacity(&self) -> usize {
        self.keys.len() / 2
    }

    /// Add `c` to `n[k][v]`.
    #[inline]
    pub fn add(&mut self, k: u32, v: u32, c: u32) {
        if self.len * 2 >= self.keys.len() {
            self.grow();
        }
        let key = ((k as u64) << 32) | v as u64;
        let mut i = hash_u64(key) as usize & self.mask;
        loop {
            let slot = self.keys[i];
            if slot == key {
                self.vals[i] += c;
                return;
            }
            if slot == EMPTY {
                self.keys[i] = key;
                self.vals[i] = c;
                self.len += 1;
                return;
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Current count for `(k, v)` (0 when absent).
    pub fn get(&self, k: u32, v: u32) -> u32 {
        let key = ((k as u64) << 32) | v as u64;
        let mut i = hash_u64(key) as usize & self.mask;
        loop {
            let slot = self.keys[i];
            if slot == key {
                return self.vals[i];
            }
            if slot == EMPTY {
                return 0;
            }
            i = (i + 1) & self.mask;
        }
    }

    fn grow(&mut self) {
        crate::par::stats::note_scratch_alloc();
        let new_size = self.keys.len() * 2;
        let old_keys = std::mem::replace(&mut self.keys, vec![EMPTY; new_size]);
        let old_vals = std::mem::replace(&mut self.vals, vec![0; new_size]);
        self.mask = new_size - 1;
        self.len = 0;
        for (key, val) in old_keys.into_iter().zip(old_vals) {
            if key != EMPTY {
                let k = (key >> 32) as u32;
                let v = key as u32;
                self.add(k, v, val);
            }
        }
    }

    /// Reset to empty, keeping capacity.
    pub fn clear(&mut self) {
        self.keys.fill(EMPTY);
        self.vals.fill(0);
        self.len = 0;
    }

    /// Drain into `(k, v, c)` triples (unordered).
    pub fn drain_triples(&mut self) -> Vec<(u32, u32, u32)> {
        let mut out = Vec::with_capacity(self.len);
        self.drain_each(|k, v, c| out.push((k, v, c)));
        out
    }

    /// Visit every `(k, v, c)` entry (unordered), then clear the
    /// accumulator keeping its capacity — the allocation-free merge
    /// path the reusable shard scratch relies on.
    pub fn drain_each(&mut self, mut f: impl FnMut(u32, u32, u32)) {
        for (i, &key) in self.keys.iter().enumerate() {
            if key != EMPTY {
                f((key >> 32) as u32, key as u32, self.vals[i]);
            }
        }
        self.clear();
    }
}

/// Reusable buckets for [`TopicWordRows::merge_par`]: one `(word,
/// count)` list per (shard, topic) pair. Allocations persist across
/// iterations; growth events are counted via
/// [`crate::par::stats::note_scratch_alloc`] so warm-sweep regressions
/// show up in the substrate counters.
#[derive(Debug, Default)]
pub struct MergeScratch {
    /// `buckets[shard][topic]` — cleared, never shrunk, between merges.
    buckets: Vec<Vec<Vec<(u32, u32)>>>,
}

impl MergeScratch {
    /// Empty scratch; buffers are grown on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// Make `buckets[..shards][..topics]` available and empty, keeping
    /// every existing allocation.
    fn ensure(&mut self, shards: usize, topics: usize) {
        if self.buckets.len() < shards {
            crate::par::stats::note_scratch_alloc();
            self.buckets.resize_with(shards, Vec::new);
        }
        for per_shard in self.buckets[..shards].iter_mut() {
            if per_shard.len() < topics {
                crate::par::stats::note_scratch_alloc();
                per_shard.resize_with(topics, Vec::new);
            }
            for row in per_shard.iter_mut() {
                row.clear();
            }
        }
    }
}

/// Merged, per-topic sorted rows of the `n` statistic.
#[derive(Clone, Debug, Default)]
pub struct TopicWordRows {
    /// `rows[k]` = sorted `(word, count)` with count > 0.
    rows: Vec<Vec<(u32, u32)>>,
    /// `Σ_v n[k][v]` per topic.
    row_totals: Vec<u64>,
}

impl TopicWordRows {
    /// Empty statistic over `num_topics` rows.
    pub fn new(num_topics: usize) -> Self {
        Self { rows: vec![Vec::new(); num_topics], row_totals: vec![0; num_topics] }
    }

    /// Merge shard accumulators. Consumes their contents.
    pub fn merge_from(num_topics: usize, shards: &mut [TopicWordAcc]) -> Self {
        Self::merge_from_iter(num_topics, shards.iter_mut())
    }

    /// Merge any iterator of shard accumulators, draining each in place
    /// (their hash capacity survives for the next sweep). The result is
    /// independent of shard order: rows are sorted by word id and
    /// duplicate entries summed.
    pub fn merge_from_iter<'a>(
        num_topics: usize,
        shards: impl IntoIterator<Item = &'a mut TopicWordAcc>,
    ) -> Self {
        let mut out = Self::new(num_topics);
        // Bucket triples by topic, then sort each row by word id.
        for shard in shards {
            shard.drain_each(|k, v, c| {
                debug_assert!((k as usize) < num_topics);
                out.rows[k as usize].push((v, c));
                out.row_totals[k as usize] += c as u64;
            });
        }
        for row in out.rows.iter_mut() {
            row.sort_unstable_by_key(|&(v, _)| v);
            // Combine duplicates coming from different shards.
            let mut w = 0usize;
            for i in 0..row.len() {
                if w > 0 && row[w - 1].0 == row[i].0 {
                    row[w - 1].1 += row[i].1;
                } else {
                    row[w] = row[i];
                    w += 1;
                }
            }
            row.truncate(w);
        }
        out
    }

    /// Pool-parallel merge, bit-identical to
    /// [`TopicWordRows::merge_from_iter`] on the same shard sequence:
    /// phase 1 drains every accumulator into `scratch`'s per-(shard,
    /// topic) buckets (parallel over shards, allocations reused across
    /// calls), phase 2 concatenates each topic's buckets in shard
    /// order, sorts by word id and sums duplicates (parallel over
    /// topics). Identity holds because each topic sees the same entry
    /// sequence either way and `sort_unstable_by_key` + duplicate
    /// summation is deterministic in it.
    pub fn merge_par<'a, E: crate::par::Executor + Copy>(
        num_topics: usize,
        shards: impl IntoIterator<Item = &'a mut TopicWordAcc>,
        exec: E,
        scratch: &mut MergeScratch,
    ) -> Self {
        let mut accs: Vec<&'a mut TopicWordAcc> = shards.into_iter().collect();
        let nshards = accs.len();
        if nshards == 0 {
            return Self::new(num_topics);
        }
        scratch.ensure(nshards, num_topics);
        // Phase 1: drain shard s into scratch.buckets[s][k].
        {
            let abase = crate::par::pool::SendPtr(accs.as_mut_ptr());
            let bbase = crate::par::pool::SendPtr(scratch.buckets.as_mut_ptr());
            let task = move |_slot: usize, s: usize| {
                // SAFETY: task `s` is the only one touching index `s`
                // of either array (Executor task-uniqueness contract).
                let acc: &mut TopicWordAcc = unsafe { &mut *abase.0.add(s) };
                let buckets: &mut Vec<Vec<(u32, u32)>> = unsafe { &mut *bbase.0.add(s) };
                acc.drain_each(|k, v, c| buckets[k as usize].push((v, c)));
            };
            exec.run_tasks(nshards, &task);
        }
        // Phase 2: per-topic concatenate (shard order), sort, combine.
        let buckets = &scratch.buckets;
        let merged: Vec<(Vec<(u32, u32)>, u64)> =
            crate::par::exec_map(exec, num_topics, |k| {
                let nnz: usize = buckets[..nshards].iter().map(|b| b[k].len()).sum();
                let mut row: Vec<(u32, u32)> = Vec::with_capacity(nnz);
                for b in &buckets[..nshards] {
                    row.extend_from_slice(&b[k]);
                }
                row.sort_unstable_by_key(|&(v, _)| v);
                let mut total = 0u64;
                let mut w = 0usize;
                for i in 0..row.len() {
                    total += row[i].1 as u64;
                    if w > 0 && row[w - 1].0 == row[i].0 {
                        row[w - 1].1 += row[i].1;
                    } else {
                        row[w] = row[i];
                        w += 1;
                    }
                }
                row.truncate(w);
                (row, total)
            });
        let mut out = Self::new(num_topics);
        for (k, (row, total)) in merged.into_iter().enumerate() {
            out.rows[k] = row;
            out.row_totals[k] = total;
        }
        out
    }

    /// Number of topic rows.
    #[inline]
    pub fn num_topics(&self) -> usize {
        self.rows.len()
    }

    /// Sorted `(word, count)` row for topic `k`.
    #[inline]
    pub fn row(&self, k: usize) -> &[(u32, u32)] {
        &self.rows[k]
    }

    /// `Σ_v n[k][v]`.
    #[inline]
    pub fn row_total(&self, k: usize) -> u64 {
        self.row_totals[k]
    }

    /// Total token count `Σ_{k,v} n[k][v]` — must equal N.
    pub fn total(&self) -> u64 {
        self.row_totals.iter().sum()
    }

    /// Count for `(k, v)` via binary search. O(log nnz_k).
    pub fn get(&self, k: usize, v: u32) -> u32 {
        match self.rows[k].binary_search_by_key(&v, |&(w, _)| w) {
            Ok(i) => self.rows[k][i].1,
            Err(_) => 0,
        }
    }

    /// Number of topics with at least one token ("active topics").
    pub fn active_topics(&self) -> usize {
        self.row_totals.iter().filter(|&&t| t > 0).count()
    }

    /// Per-topic totals slice.
    pub fn row_totals(&self) -> &[u64] {
        &self.row_totals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acc_add_get() {
        let mut acc = TopicWordAcc::with_capacity(4);
        acc.add(1, 10, 2);
        acc.add(1, 10, 3);
        acc.add(2, 10, 1);
        acc.add(1, 11, 7);
        assert_eq!(acc.get(1, 10), 5);
        assert_eq!(acc.get(2, 10), 1);
        assert_eq!(acc.get(1, 11), 7);
        assert_eq!(acc.get(0, 0), 0);
        assert_eq!(acc.nnz(), 3);
    }

    #[test]
    fn acc_grows_past_capacity() {
        let mut acc = TopicWordAcc::with_capacity(2);
        for k in 0..50u32 {
            for v in 0..50u32 {
                acc.add(k, v, 1);
            }
        }
        assert_eq!(acc.nnz(), 2500);
        for k in 0..50u32 {
            for v in 0..50u32 {
                assert_eq!(acc.get(k, v), 1);
            }
        }
    }

    #[test]
    fn merge_combines_shards_sorted() {
        let mut a = TopicWordAcc::with_capacity(8);
        let mut b = TopicWordAcc::with_capacity(8);
        a.add(0, 5, 1);
        a.add(0, 2, 2);
        a.add(1, 9, 4);
        b.add(0, 5, 3);
        b.add(1, 1, 1);
        let rows = TopicWordRows::merge_from(3, &mut [a, b]);
        assert_eq!(rows.row(0), &[(2, 2), (5, 4)]);
        assert_eq!(rows.row(1), &[(1, 1), (9, 4)]);
        assert!(rows.row(2).is_empty());
        assert_eq!(rows.row_total(0), 6);
        assert_eq!(rows.row_total(1), 5);
        assert_eq!(rows.total(), 11);
        assert_eq!(rows.active_topics(), 2);
        assert_eq!(rows.get(0, 5), 4);
        assert_eq!(rows.get(0, 3), 0);
    }

    /// Shared fixture: `nshards` accumulators filled from a seeded
    /// assignment stream.
    fn random_shards(seed: u64, nshards: usize, pairs: usize) -> Vec<TopicWordAcc> {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::new(seed);
        let mut shards: Vec<TopicWordAcc> =
            (0..nshards).map(|_| TopicWordAcc::with_capacity(64)).collect();
        for _ in 0..pairs {
            let k = rng.below(20) as u32;
            let v = rng.below(100) as u32;
            let s = rng.below(nshards as u64) as usize;
            shards[s].add(k, v, 1 + (v % 3));
        }
        shards
    }

    #[test]
    fn parallel_merge_matches_serial() {
        use crate::par::WorkerPool;
        let pool = WorkerPool::new(3);
        let mut scratch = MergeScratch::new();
        for seed in [1u64, 2, 3] {
            let mut serial = random_shards(seed, 4, 5_000);
            let mut pooled = serial.clone();
            let mut scoped = serial.clone();
            let want = TopicWordRows::merge_from_iter(20, serial.iter_mut());
            // Twice on the pool to exercise scratch reuse.
            let got = TopicWordRows::merge_par(20, pooled.iter_mut(), &pool, &mut scratch);
            let got2 =
                TopicWordRows::merge_par(20, scoped.iter_mut(), 4usize, &mut scratch);
            assert_eq!(got.total(), want.total(), "seed {seed}");
            for k in 0..20 {
                assert_eq!(got.row(k), want.row(k), "seed {seed} topic {k} (pool)");
                assert_eq!(got2.row(k), want.row(k), "seed {seed} topic {k} (scoped)");
                assert_eq!(got.row_total(k), want.row_total(k), "seed {seed} topic {k}");
            }
        }
    }

    #[test]
    fn parallel_merge_drains_shards_and_handles_empty() {
        use crate::par::WorkerPool;
        let pool = WorkerPool::new(2);
        let mut scratch = MergeScratch::new();
        let mut shards = random_shards(9, 3, 500);
        let rows = TopicWordRows::merge_par(20, shards.iter_mut(), &pool, &mut scratch);
        assert!(rows.total() > 0);
        // Accumulators drained in place (capacity kept for the next
        // sweep), exactly like the serial path.
        assert!(shards.iter().all(|s| s.nnz() == 0));
        // Zero shards → empty statistic.
        let empty =
            TopicWordRows::merge_par(5, std::iter::empty(), &pool, &mut scratch);
        assert_eq!(empty.total(), 0);
        assert_eq!(empty.num_topics(), 5);
    }

    #[test]
    fn merge_matches_reference_counts() {
        // Random assignment stream accumulated both ways.
        use crate::rng::Pcg64;
        let mut rng = Pcg64::new(11);
        let mut shards: Vec<TopicWordAcc> =
            (0..4).map(|_| TopicWordAcc::with_capacity(64)).collect();
        let mut reference = std::collections::HashMap::new();
        for _ in 0..10_000 {
            let k = rng.below(20) as u32;
            let v = rng.below(100) as u32;
            let s = rng.below(4) as usize;
            shards[s].add(k, v, 1);
            *reference.entry((k, v)).or_insert(0u32) += 1;
        }
        let rows = TopicWordRows::merge_from(20, &mut shards);
        assert_eq!(rows.total(), 10_000);
        for ((k, v), c) in reference {
            assert_eq!(rows.get(k as usize, v), c, "({k},{v})");
        }
        // rows sorted
        for k in 0..20 {
            let row = rows.row(k);
            assert!(row.windows(2).all(|w| w[0].0 < w[1].0));
        }
    }
}
