//! The sparse topic-word probability matrix `Φ`.
//!
//! Under the Poisson Pólya urn step (paper §2.5, eq. 21) each row of `Φ`
//! is a normalized vector of *integer* Poisson draws, so most entries
//! are exactly zero. [`PhiMatrix`] stores the nonzeros in **both**
//! orientations:
//!
//! * rows (by topic) — used by diagnostics and the `Φ`-side of the
//!   log-likelihood;
//! * columns (by word type, CSC) — the hot layout: the per-word alias
//!   table over bucket (a) is built from column `v`, and bucket (b)
//!   needs `φ_{k,v}` for the topics in `m_d` (binary search in the
//!   column) or a merge over the column, whichever side is sparser.

use crate::simd::Kernels;

/// Sparse `K × V` probability matrix with row and column views.
#[derive(Clone, Debug)]
pub struct PhiMatrix {
    num_topics: usize,
    vocab: usize,
    /// Row view: `rows[k]` = sorted `(word, prob)`.
    rows: Vec<Vec<(u32, f64)>>,
    /// CSC: column pointers into `col_topics` / `col_probs`.
    col_ptr: Vec<usize>,
    col_topics: Vec<u32>,
    col_probs: Vec<f64>,
}

impl PhiMatrix {
    /// Build from integer count rows (the PPU draws `ϕ_{k,·}`): row `k`
    /// is a sorted `(word, count)` list; probabilities are
    /// `count / row_sum`. Rows with zero total stay empty (a dead topic
    /// has no word distribution — callers must not score against it).
    pub fn from_count_rows(vocab: usize, count_rows: &[Vec<(u32, u32)>]) -> Self {
        Self::from_count_rows_with(vocab, count_rows, &Kernels::scalar())
    }

    /// [`PhiMatrix::from_count_rows`] with an explicit kernel set: the
    /// row normalization (`count * (1/total)` per nonzero) runs through
    /// `kernels.scale_f64` — the same elementwise multiply, so the
    /// matrix is bit-identical across tiers.
    pub fn from_count_rows_with(
        vocab: usize,
        count_rows: &[Vec<(u32, u32)>],
        kernels: &Kernels,
    ) -> Self {
        let num_topics = count_rows.len();
        let mut rows: Vec<Vec<(u32, f64)>> = Vec::with_capacity(num_topics);
        let mut col_counts = vec![0usize; vocab + 1];
        let mut nnz = 0usize;
        let mut vals: Vec<f64> = Vec::new();
        for row in count_rows {
            let total: u64 = row.iter().map(|&(_, c)| c as u64).sum();
            if total == 0 {
                rows.push(Vec::new());
                continue;
            }
            let inv = 1.0 / total as f64;
            vals.clear();
            vals.extend(row.iter().map(|&(_, c)| c as f64));
            (kernels.scale_f64)(&mut vals, inv);
            let prow: Vec<(u32, f64)> = row
                .iter()
                .zip(&vals)
                .map(|(&(v, _), &p)| (v, p))
                .collect();
            for &(v, _) in &prow {
                debug_assert!((v as usize) < vocab);
                col_counts[v as usize + 1] += 1;
                nnz += 1;
            }
            rows.push(prow);
        }
        // prefix sums -> col_ptr
        let mut col_ptr = col_counts;
        for i in 1..col_ptr.len() {
            col_ptr[i] += col_ptr[i - 1];
        }
        let mut col_topics = vec![0u32; nnz];
        let mut col_probs = vec![0.0f64; nnz];
        let mut cursor = col_ptr.clone();
        for (k, row) in rows.iter().enumerate() {
            for &(v, p) in row {
                let at = cursor[v as usize];
                col_topics[at] = k as u32;
                col_probs[at] = p;
                cursor[v as usize] += 1;
            }
        }
        // Topics within a column arrive in increasing k (rows iterated in
        // order), so each column is sorted by topic id — required by the
        // binary-search lookup.
        Self { num_topics, vocab, rows, col_ptr, col_topics, col_probs }
    }

    /// Number of topic rows.
    #[inline]
    pub fn num_topics(&self) -> usize {
        self.num_topics
    }

    /// Vocabulary size.
    #[inline]
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Total number of stored nonzeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.col_topics.len()
    }

    /// Sorted `(word, prob)` row for topic `k`.
    #[inline]
    pub fn row(&self, k: usize) -> &[(u32, f64)] {
        &self.rows[k]
    }

    /// Column `v` as parallel `(topics, probs)` slices, sorted by topic.
    /// Its length is `K_v^{(Φ)}`, the topic-word sparsity term of the
    /// per-token complexity bound (eq. 29).
    #[inline]
    pub fn col(&self, v: u32) -> (&[u32], &[f64]) {
        let lo = self.col_ptr[v as usize];
        let hi = self.col_ptr[v as usize + 1];
        (&self.col_topics[lo..hi], &self.col_probs[lo..hi])
    }

    /// `φ_{k,v}` via binary search in column `v`. O(log K_v^{(Φ)}).
    pub fn get(&self, k: u32, v: u32) -> f64 {
        let (topics, probs) = self.col(v);
        match topics.binary_search(&k) {
            Ok(i) => probs[i],
            Err(_) => 0.0,
        }
    }

    /// Dense materialization (tests / tiny corpora only).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut out = vec![vec![0.0; self.vocab]; self.num_topics];
        for (k, row) in self.rows.iter().enumerate() {
            for &(v, p) in row {
                out[k][v as usize] = p;
            }
        }
        out
    }

    /// Rows as f32 tiles for the XLA evaluation path: writes the
    /// `[k0..k0+kt) × [v0..v0+vt)` block of `Φ` into `out` (row-major,
    /// `kt × vt`, zero-padded).
    pub fn fill_tile_f32(
        &self,
        k0: usize,
        kt: usize,
        v0: usize,
        vt: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), kt * vt);
        out.fill(0.0);
        for (dk, k) in (k0..(k0 + kt).min(self.num_topics)).enumerate() {
            let row = &self.rows[k];
            let start = row.partition_point(|&(v, _)| (v as usize) < v0);
            for &(v, p) in &row[start..] {
                let v = v as usize;
                if v >= v0 + vt {
                    break;
                }
                out[dk * vt + (v - v0)] = p as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_matrix() -> PhiMatrix {
        // K=3, V=5
        // k0: words 0(2), 2(2)     -> probs .5, .5
        // k1: words 2(1), 3(3)     -> probs .25, .75
        // k2: empty (dead topic)
        PhiMatrix::from_count_rows(
            5,
            &[vec![(0, 2), (2, 2)], vec![(2, 1), (3, 3)], vec![]],
        )
    }

    #[test]
    fn rows_normalized() {
        let phi = sample_matrix();
        assert_eq!(phi.row(0), &[(0, 0.5), (2, 0.5)]);
        assert_eq!(phi.row(1), &[(2, 0.25), (3, 0.75)]);
        assert!(phi.row(2).is_empty());
        for k in 0..2 {
            let s: f64 = phi.row(k).iter().map(|&(_, p)| p).sum();
            assert!((s - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn columns_match_rows() {
        let phi = sample_matrix();
        let (t, p) = phi.col(2);
        assert_eq!(t, &[0, 1]);
        assert_eq!(p, &[0.5, 0.25]);
        let (t, _) = phi.col(1);
        assert!(t.is_empty());
        let (t, p) = phi.col(3);
        assert_eq!(t, &[1]);
        assert_eq!(p, &[0.75]);
    }

    #[test]
    fn get_lookup() {
        let phi = sample_matrix();
        assert_eq!(phi.get(0, 0), 0.5);
        assert_eq!(phi.get(1, 3), 0.75);
        assert_eq!(phi.get(2, 0), 0.0);
        assert_eq!(phi.get(0, 4), 0.0);
    }

    #[test]
    fn dense_agrees() {
        let phi = sample_matrix();
        let dense = phi.to_dense();
        for k in 0..3u32 {
            for v in 0..5u32 {
                assert_eq!(dense[k as usize][v as usize], phi.get(k, v));
            }
        }
    }

    #[test]
    fn tile_fill() {
        let phi = sample_matrix();
        let mut tile = vec![0.0f32; 2 * 3];
        // block k in [1,3), v in [2,5)
        phi.fill_tile_f32(1, 2, 2, 3, &mut tile);
        assert_eq!(tile, vec![0.25, 0.75, 0.0, 0.0, 0.0, 0.0]);
        // block beyond matrix bounds zero-padded
        let mut tile = vec![1.0f32; 4];
        phi.fill_tile_f32(2, 2, 0, 2, &mut tile);
        assert_eq!(tile, vec![0.0; 4]);
    }

    #[test]
    fn nnz_counts() {
        let phi = sample_matrix();
        assert_eq!(phi.nnz(), 4);
        assert_eq!(phi.num_topics(), 3);
        assert_eq!(phi.vocab(), 5);
    }

    /// Kernel-built normalization must be bit-identical to scalar,
    /// whatever tier `auto()` resolves to.
    #[test]
    fn kernel_built_matrix_is_bit_identical() {
        let rows: Vec<Vec<(u32, u32)>> = (0..9)
            .map(|k| {
                (0..(k * 3 + 1) as u32)
                    .map(|v| (v * 2, (v * 7 + k as u32) % 13))
                    .collect()
            })
            .collect();
        let a = PhiMatrix::from_count_rows(64, &rows);
        let b = PhiMatrix::from_count_rows_with(64, &rows, &Kernels::auto());
        assert_eq!(a.col_ptr, b.col_ptr);
        assert_eq!(a.col_topics, b.col_topics);
        let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.col_probs), bits(&b.col_probs));
        for k in 0..a.num_topics() {
            assert_eq!(a.row(k).len(), b.row(k).len());
            for (x, y) in a.row(k).iter().zip(b.row(k)) {
                assert_eq!(x.0, y.0);
                assert_eq!(x.1.to_bits(), y.1.to_bits());
            }
        }
    }
}
