//! The per-document topic sufficient statistic `m_d`.
//!
//! Natural-language documents touch only a handful of topics, so `m_d`
//! is a small unordered `(topic, count)` vector with linear-scan access:
//! for realistic support sizes (a few dozen) this beats hash maps and
//! trees by a wide margin and is the layout the doubly sparse bucket-(b)
//! iteration wants anyway (paper §2.5: "iterate over whichever of `m`
//! and `Φ` has fewer non-zero entries").

/// Sparse per-document topic counts `m_{d,·}`.
#[derive(Clone, Debug, Default)]
pub struct DocTopics {
    entries: Vec<(u32, u32)>, // (topic, count), count > 0, unordered
    total: u32,
}

impl DocTopics {
    /// Empty statistic.
    pub fn new() -> Self {
        Self { entries: Vec::new(), total: 0 }
    }

    /// With preallocated capacity for `cap` distinct topics.
    pub fn with_capacity(cap: usize) -> Self {
        Self { entries: Vec::with_capacity(cap), total: 0 }
    }

    /// Number of distinct topics in the document (`K_d^{(m)}`).
    #[inline]
    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Total token count `Σ_k m_{d,k}` (= `N_d` when every token is
    /// assigned).
    #[inline]
    pub fn total(&self) -> u32 {
        self.total
    }

    /// Count for topic `k` (0 if absent). O(nnz).
    #[inline]
    pub fn get(&self, k: u32) -> u32 {
        self.entries
            .iter()
            .find(|&&(t, _)| t == k)
            .map(|&(_, c)| c)
            .unwrap_or(0)
    }

    /// Increment topic `k` by one.
    #[inline]
    pub fn inc(&mut self, k: u32) {
        self.total += 1;
        for e in self.entries.iter_mut() {
            if e.0 == k {
                e.1 += 1;
                return;
            }
        }
        self.entries.push((k, 1));
    }

    /// Decrement topic `k` by one; removes the entry when it reaches
    /// zero (swap-remove, order not preserved). Panics in debug builds
    /// if `k` is absent.
    #[inline]
    pub fn dec(&mut self, k: u32) {
        for i in 0..self.entries.len() {
            if self.entries[i].0 == k {
                self.total -= 1;
                self.entries[i].1 -= 1;
                if self.entries[i].1 == 0 {
                    self.entries.swap_remove(i);
                }
                return;
            }
        }
        debug_assert!(false, "dec on absent topic {k}");
    }

    /// Set topic `k` to `count > 0`, assuming `k` is not present
    /// (bulk rebuild path — the z sweep compacts its dense scratch back
    /// through this).
    #[inline]
    pub fn set(&mut self, k: u32, count: u32) {
        debug_assert!(count > 0);
        debug_assert!(self.get(k) == 0, "set on present topic {k}");
        self.entries.push((k, count));
        self.total += count;
    }

    /// Iterate `(topic, count)` pairs (unordered).
    #[inline]
    pub fn iter(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.entries.iter().copied()
    }

    /// Raw entries slice.
    #[inline]
    pub fn entries(&self) -> &[(u32, u32)] {
        &self.entries
    }

    /// Remove all entries.
    pub fn clear(&mut self) {
        self.entries.clear();
        self.total = 0;
    }

    /// Maximum per-topic count (`max_k m_{d,k}`), 0 when empty.
    pub fn max_count(&self) -> u32 {
        self.entries.iter().map(|&(_, c)| c).max().unwrap_or(0)
    }
}

impl FromIterator<u32> for DocTopics {
    /// Build from an iterator of topic assignments (one per token).
    fn from_iter<I: IntoIterator<Item = u32>>(iter: I) -> Self {
        let mut m = DocTopics::new();
        for k in iter {
            m.inc(k);
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inc_dec_roundtrip() {
        let mut m = DocTopics::new();
        m.inc(3);
        m.inc(3);
        m.inc(7);
        assert_eq!(m.get(3), 2);
        assert_eq!(m.get(7), 1);
        assert_eq!(m.get(5), 0);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.total(), 3);
        m.dec(3);
        assert_eq!(m.get(3), 1);
        m.dec(3);
        assert_eq!(m.get(3), 0);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.total(), 1);
    }

    #[test]
    fn from_assignments() {
        let m: DocTopics = [1u32, 1, 2, 9, 1].into_iter().collect();
        assert_eq!(m.get(1), 3);
        assert_eq!(m.get(2), 1);
        assert_eq!(m.get(9), 1);
        assert_eq!(m.total(), 5);
        assert_eq!(m.max_count(), 3);
    }

    #[test]
    fn total_conserved_under_moves() {
        // Simulates the z step: dec old topic, inc new topic.
        let mut m: DocTopics = [0u32, 0, 1, 2, 2, 2].into_iter().collect();
        let before = m.total();
        for (from, to) in [(0u32, 5u32), (2, 1), (2, 2)] {
            m.dec(from);
            m.inc(to);
        }
        assert_eq!(m.total(), before);
        assert_eq!(m.get(0), 1);
        assert_eq!(m.get(5), 1);
        assert_eq!(m.get(1), 2);
        assert_eq!(m.get(2), 2);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn dec_absent_panics_in_debug() {
        let mut m = DocTopics::new();
        m.dec(0);
    }
}
