//! Sparse count structures for the HDP sampler.
//!
//! The paper's complexity claims rest on never materializing dense
//! `D×K` or `K×V` objects:
//!
//! * [`doc_topics::DocTopics`] — the per-document topic statistic `m_d`
//!   as a small sparse vector (document-topic sparsity, paper §2.5).
//! * [`topic_word::TopicWordAcc`] / [`topic_word::TopicWordRows`] — the
//!   topic-word statistic `n` accumulated shard-locally during the z
//!   phase and merged into per-topic sorted rows (topic-word sparsity).
//! * [`phi::PhiMatrix`] — the PPU-sampled integer `Φ` in both row
//!   (topic) and column (word) layouts; columns drive the per-word
//!   alias tables and the bucket-(b) lookups.
//! * [`dmat::DocCountHist`] — the `d` matrix of §2.6 (`d[k][p]` = #docs
//!   with exactly `p` tokens in topic `k`) and its reverse cumulative
//!   sums `D_{k,j}` feeding the binomial trick.

pub mod dmat;
pub mod doc_topics;
pub mod phi;
pub mod topic_word;

pub use dmat::DocCountHist;
pub use doc_topics::DocTopics;
pub use phi::PhiMatrix;
pub use topic_word::{MergeScratch, TopicWordAcc, TopicWordRows};
