//! The `d` matrix of paper §2.6 and the `D_{k,j}` reverse cumulative
//! sums that feed the binomial trick.
//!
//! `d[k][p]` counts documents whose topic-`k` count `m_{d,k}` equals
//! exactly `p`; `D_{k,j} = Σ_{p ≥ j} d[k][p]` is the number of documents
//! with `m_{d,k} ≥ j`. The `l` step then draws
//! `l_k = Σ_j Bin(D_{k,j}, αΨ_k / (αΨ_k + j − 1))` — constant in the
//! number of documents.
//!
//! Rows are kept as sparse `(p, count)` lists: a topic's per-document
//! counts concentrate on few distinct values, so rows are short. Shard
//! accumulators merge the same way as the topic-word statistic.

/// Sparse per-topic histogram of per-document counts.
#[derive(Clone, Debug, Default)]
pub struct DocCountHist {
    /// `rows[k]` = sorted `(p, #docs with m_{d,k} == p)`, p ≥ 1.
    rows: Vec<Vec<(u32, u32)>>,
}

impl DocCountHist {
    /// Empty histogram over `num_topics` topics.
    pub fn new(num_topics: usize) -> Self {
        Self { rows: vec![Vec::new(); num_topics] }
    }

    /// Record one document's statistic `m_d`: for every `(k, p)` with
    /// `p = m_{d,k} > 0`, increment `d[k][p]`. Unsorted insert; rows are
    /// sorted at [`DocCountHist::finish`].
    pub fn record_doc(&mut self, m_entries: &[(u32, u32)]) {
        for &(k, p) in m_entries {
            debug_assert!(p > 0);
            self.rows[k as usize].push((p, 1));
        }
    }

    /// Sort + deduplicate all rows (sums duplicate `p` entries).
    pub fn finish(&mut self) {
        for row in self.rows.iter_mut() {
            row.sort_unstable_by_key(|&(p, _)| p);
            let mut w = 0usize;
            for i in 0..row.len() {
                if w > 0 && row[w - 1].0 == row[i].0 {
                    row[w - 1].1 += row[i].1;
                } else {
                    row[w] = row[i];
                    w += 1;
                }
            }
            row.truncate(w);
        }
    }

    /// Merge shard histograms into one finished histogram.
    pub fn merge(num_topics: usize, mut shards: Vec<DocCountHist>) -> Self {
        Self::merge_mut(num_topics, shards.iter_mut())
    }

    /// Merge any iterator of shard histograms, draining each in place —
    /// the shards keep their row capacity for the next sweep (the
    /// reusable-scratch merge path).
    pub fn merge_mut<'a>(
        num_topics: usize,
        shards: impl IntoIterator<Item = &'a mut DocCountHist>,
    ) -> Self {
        let mut out = Self::new(num_topics);
        for shard in shards {
            for (k, row) in shard.rows.iter_mut().enumerate() {
                debug_assert!(k < num_topics);
                out.rows[k].append(row);
            }
        }
        out.finish();
        out
    }

    /// Reset to an empty, unfinished histogram over `num_topics`
    /// topics, keeping every row's allocation.
    pub fn reset(&mut self, num_topics: usize) {
        if self.rows.len() != num_topics {
            self.rows.resize(num_topics, Vec::new());
        }
        for row in self.rows.iter_mut() {
            row.clear();
        }
    }

    /// Number of topic rows.
    pub fn num_topics(&self) -> usize {
        self.rows.len()
    }

    /// Sorted `(p, count)` row for topic `k` (valid after `finish`).
    pub fn row(&self, k: usize) -> &[(u32, u32)] {
        &self.rows[k]
    }

    /// Iterate `(j, D_{k,j})` for `j = 1 ..= max_p` **restricted to the
    /// distinct j-runs**: the reverse cumulative sum `D_{k,j}` is a step
    /// function, constant for `j` in `(p_{i-1}, p_i]`; the callback
    /// receives each maximal run `(j_lo, j_hi, D)` with `D = D_{k,j}`
    /// for all `j` in `[j_lo, j_hi]`.
    ///
    /// The binomial-trick consumer still needs a draw *per j* (the
    /// success probability depends on j), but run-length exposure lets
    /// it skip empty levels without scanning.
    pub fn for_runs(&self, k: usize, mut f: impl FnMut(u32, u32, u32)) {
        let row = &self.rows[k];
        if row.is_empty() {
            return;
        }
        // Suffix sums over the sorted distinct p values.
        // D_{k,j} for j in (p_{i-1}, p_i] equals sum of counts with p >= p_i.
        let mut suffix = 0u32;
        let mut suffixes = vec![0u32; row.len()];
        for (i, &(_, c)) in row.iter().enumerate().rev() {
            suffix += c;
            suffixes[i] = suffix;
        }
        let mut j_lo = 1u32;
        for (i, &(p, _)) in row.iter().enumerate() {
            f(j_lo, p, suffixes[i]);
            j_lo = p + 1;
        }
    }

    /// `D_{k,j}` for a single `(k, j)` — O(log nnz), used by tests and
    /// the reference (non-run) l sampler.
    pub fn docs_with_at_least(&self, k: usize, j: u32) -> u32 {
        let row = &self.rows[k];
        let start = row.partition_point(|&(p, _)| p < j);
        row[start..].iter().map(|&(_, c)| c).sum()
    }

    /// Largest per-document count recorded for topic `k` (0 if none).
    pub fn max_count(&self, k: usize) -> u32 {
        self.rows[k].last().map(|&(p, _)| p).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hist_from_docs(num_topics: usize, docs: &[&[(u32, u32)]]) -> DocCountHist {
        let mut h = DocCountHist::new(num_topics);
        for d in docs {
            h.record_doc(d);
        }
        h.finish();
        h
    }

    #[test]
    fn records_and_dedups() {
        // doc1: m = {k0: 2, k1: 1}; doc2: m = {k0: 2}; doc3: m = {k0: 5}
        let h = hist_from_docs(2, &[&[(0, 2), (1, 1)], &[(0, 2)], &[(0, 5)]]);
        assert_eq!(h.row(0), &[(2, 2), (5, 1)]);
        assert_eq!(h.row(1), &[(1, 1)]);
        assert_eq!(h.max_count(0), 5);
        assert_eq!(h.max_count(1), 1);
    }

    #[test]
    fn docs_with_at_least_matches_definition() {
        let h = hist_from_docs(1, &[&[(0, 2)], &[(0, 2)], &[(0, 5)], &[(0, 1)]]);
        // counts: 1×1, 2×2, 5×1
        assert_eq!(h.docs_with_at_least(0, 1), 4);
        assert_eq!(h.docs_with_at_least(0, 2), 3);
        assert_eq!(h.docs_with_at_least(0, 3), 1);
        assert_eq!(h.docs_with_at_least(0, 5), 1);
        assert_eq!(h.docs_with_at_least(0, 6), 0);
    }

    #[test]
    fn runs_cover_every_level() {
        let h = hist_from_docs(1, &[&[(0, 2)], &[(0, 2)], &[(0, 5)], &[(0, 1)]]);
        let mut levels = std::collections::HashMap::new();
        h.for_runs(0, |lo, hi, d| {
            for j in lo..=hi {
                levels.insert(j, d);
            }
        });
        // Explicit D values per level from the definition.
        for j in 1..=5u32 {
            assert_eq!(levels[&j], h.docs_with_at_least(0, j), "level {j}");
        }
        assert_eq!(levels.len(), 5);
    }

    #[test]
    fn merge_equals_single() {
        let mut a = DocCountHist::new(2);
        let mut b = DocCountHist::new(2);
        a.record_doc(&[(0, 2), (1, 3)]);
        b.record_doc(&[(0, 2)]);
        b.record_doc(&[(1, 1)]);
        let merged = DocCountHist::merge(2, vec![a, b]);
        let whole =
            hist_from_docs(2, &[&[(0, 2), (1, 3)], &[(0, 2)], &[(1, 1)]]);
        for k in 0..2 {
            assert_eq!(merged.row(k), whole.row(k));
        }
    }

    #[test]
    fn empty_topic_has_no_runs() {
        let h = hist_from_docs(2, &[&[(0, 1)]]);
        let mut called = false;
        h.for_runs(1, |_, _, _| called = true);
        assert!(!called);
        assert_eq!(h.docs_with_at_least(1, 1), 0);
    }
}
