//! # hdp-sparse
//!
//! Reproduction of *Sparse Parallel Training of Hierarchical Dirichlet
//! Process Topic Models* (Terenin, Magnusson & Jonsson, EMNLP 2020).
//!
//! The crate is the Layer-3 (rust) coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — the paper's contribution: the doubly sparse,
//!   data-parallel, partially collapsed Gibbs sampler for the HDP topic
//!   model ([`hdp::pc`]), its baselines (direct assignment [`hdp::da`],
//!   subcluster split-merge [`hdp::ssm`], partially collapsed LDA
//!   [`hdp::pclda`]), and every substrate they need: RNG and
//!   distribution samplers ([`rng`]), alias tables ([`alias`]), sparse
//!   count matrices ([`sparse`]), a thread pool ([`par`]), corpus
//!   ingestion and synthesis ([`corpus`]), config ([`config`]),
//!   diagnostics ([`diagnostics`]) and metrics ([`metrics`]).
//! * **L2/L1 (python, build-time only)** — dense evaluation graphs
//!   (model log-likelihood, dense z-conditional scoring) written in JAX
//!   with Pallas kernels, AOT-lowered to HLO text in `artifacts/`.
//! * **Runtime bridge** ([`runtime`]) — loads the HLO artifacts via the
//!   `xla` crate's PJRT CPU client and executes them tile-by-tile from
//!   the diagnostics path. Python never runs at training time.

pub mod alias;
pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod corpus;
pub mod diagnostics;
pub mod durable;
pub mod experiments;
/// Deterministic failpoint registry for crash/fault testing. The
/// checks are compiled to no-ops unless the off-by-default
/// `failpoints` feature is on; arming requires the feature.
pub mod fault;
pub mod hdp;
pub mod metrics;
pub mod par;
pub mod rng;
/// PJRT/XLA bridge — compiled only with the off-by-default `xla`
/// feature (requires the `xla` crate and an XLA toolchain; see
/// `Cargo.toml`). The default build is pure rust + std.
#[cfg(feature = "xla")]
pub mod runtime;
pub mod serve;
/// Runtime-dispatched SIMD kernels for the dense hot loops (scalar
/// reference tier always present; x86_64 AVX2/SSE2 tiers behind the
/// off-by-default `simd` feature). See the module docs for the
/// dispatch ladder and the bit-exactness policy.
pub mod simd;
pub mod sparse;
