//! Deterministic, seedable I/O fault injection (failpoints).
//!
//! A process-global registry maps **site names** to fault specs; the
//! I/O layers consult it at well-known points. Everything is behind the
//! `failpoints` cargo feature: in a default build every check compiles
//! to an inlined `Ok(())` and the registry does not exist, so the hot
//! paths pay nothing. With the feature on, the disarmed fast path is a
//! single relaxed atomic load.
//!
//! # Sites
//!
//! | site            | where                                             |
//! |-----------------|---------------------------------------------------|
//! | `ckpt.write`    | checkpoint payload bytes ([`crate::durable`])     |
//! | `ckpt.sync`     | checkpoint data fsync                             |
//! | `ckpt.rename`   | checkpoint temp → final rename                    |
//! | `ckpt.dirsync`  | checkpoint parent-directory fsync                 |
//! | `packed.*`      | same four points for `.hdpp` corpus writes        |
//! | `corpus.pread`  | [`PackedCorpusFile`] positioned block reads       |
//! | `filez.pread`   | [`FileZ`] positioned block reads                  |
//! | `filez.pwrite`  | [`FileZ`] positioned block writes                 |
//! | `prefetch.load` | the streamed sweep's async block-prefetch job     |
//!
//! [`PackedCorpusFile`]: crate::corpus::io::PackedCorpusFile
//! [`FileZ`]: crate::hdp::pc::zstep::FileZ
//!
//! # Determinism
//!
//! Counted specs ([`FaultSpec::after`]/[`FaultSpec::times`]) fire on an
//! exact check sequence; probabilistic specs draw from a private
//! [`crate::rng::Pcg64`] seeded per site, so a given (seed, check
//! sequence) always fires identically. [`FaultKind::Torn`] accounts
//! bytes through a write site and cuts at an exact byte offset — a
//! simulated crash/torn write. Nothing here consults wall-clock time
//! or ambient randomness.

use std::io;

/// What an armed failpoint does when it fires.
#[derive(Clone, Copy, Debug)]
pub enum FaultKind {
    /// Return an injected I/O error (EIO-like) from the site.
    Error,
    /// Write sites only: let exactly `at` bytes through the site in
    /// total, then fail persistently — the on-disk effect of a crash
    /// or torn write at byte offset `at`.
    Torn {
        /// Byte offset at which the write stream is cut.
        at: u64,
    },
    /// Abort the process at the trigger point (real `kill -9`
    /// semantics; subprocess harnesses only).
    Abort,
}

/// An armed fault: what fires, when, and how often.
#[derive(Clone, Copy, Debug)]
pub struct FaultSpec {
    /// The injected behavior.
    pub kind: FaultKind,
    /// Successful passes through the site before the fault arms
    /// (counted kinds).
    pub after: u64,
    /// Triggers before the site self-heals (`u64::MAX` = persistent).
    pub times: u64,
    /// Seeded coin instead of counting: `(p, seed)` fires each check
    /// with probability `p`, deterministically per (seed, sequence).
    pub probability: Option<(f64, u64)>,
}

impl FaultSpec {
    /// Persistent injected error from the first check on.
    pub fn error() -> Self {
        Self { kind: FaultKind::Error, after: 0, times: u64::MAX, probability: None }
    }

    /// Injected error on checks `after..after + times`, healed after.
    pub fn error_after(after: u64, times: u64) -> Self {
        Self { kind: FaultKind::Error, after, times, probability: None }
    }

    /// Torn write: cut the site's byte stream at offset `at`.
    pub fn torn(at: u64) -> Self {
        Self { kind: FaultKind::Torn { at }, after: 0, times: u64::MAX, probability: None }
    }

    /// Seeded probabilistic error: each check fails with probability
    /// `p` (deterministic for a fixed seed and check sequence).
    pub fn random_error(p: f64, seed: u64) -> Self {
        Self { kind: FaultKind::Error, after: 0, times: u64::MAX, probability: Some((p, seed)) }
    }
}

/// Marker payload carried inside every injected [`io::Error`], so
/// callers (and retry policies) can tell injected faults from real
/// ones.
#[derive(Debug)]
pub struct InjectedFault {
    /// The failpoint site that fired.
    pub site: String,
}

impl std::fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "injected fault at failpoint `{}`", self.site)
    }
}

impl std::error::Error for InjectedFault {}

/// Build the injected error for `site`.
pub fn injected_error(site: &str) -> io::Error {
    io::Error::new(io::ErrorKind::Other, InjectedFault { site: site.to_string() })
}

/// True iff `e` was manufactured by this module.
pub fn is_injected(e: &io::Error) -> bool {
    e.get_ref().is_some_and(|r| r.is::<InjectedFault>())
}

#[cfg(feature = "failpoints")]
mod registry {
    use super::*;
    use std::collections::HashMap;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Mutex, MutexGuard, OnceLock};

    /// Armed-site count: the fast path is one relaxed load of this.
    static ARMED: AtomicUsize = AtomicUsize::new(0);

    struct SiteState {
        spec: FaultSpec,
        rng: crate::rng::Pcg64,
        /// Successful passes so far (counted kinds, pre-arm).
        passes: u64,
        /// Times the fault has fired.
        triggered: u64,
        /// Bytes allowed through a write site ([`FaultKind::Torn`]).
        written: u64,
    }

    fn table() -> MutexGuard<'static, HashMap<String, SiteState>> {
        static TABLE: OnceLock<Mutex<HashMap<String, SiteState>>> = OnceLock::new();
        TABLE
            .get_or_init(|| Mutex::new(HashMap::new()))
            .lock()
            .unwrap_or_else(|e| e.into_inner())
    }

    /// Arm `site` with `spec`, replacing any previous arming (and its
    /// counters).
    pub fn arm(site: &str, spec: FaultSpec) {
        let mut t = table();
        let seed = spec.probability.map(|(_, s)| s).unwrap_or(0);
        let prev = t.insert(
            site.to_string(),
            SiteState {
                spec,
                rng: crate::rng::Pcg64::new(seed),
                passes: 0,
                triggered: 0,
                written: 0,
            },
        );
        if prev.is_none() {
            ARMED.fetch_add(1, Ordering::SeqCst);
        }
    }

    /// Disarm `site` (no-op if not armed).
    pub fn disarm(site: &str) {
        if table().remove(site).is_some() {
            ARMED.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Disarm everything.
    pub fn reset() {
        let mut t = table();
        let n = t.len();
        t.clear();
        ARMED.fetch_sub(n, Ordering::SeqCst);
    }

    /// How many times `site` has fired since arming.
    pub fn triggered(site: &str) -> u64 {
        table().get(site).map_or(0, |s| s.triggered)
    }

    /// Registry tests and fault-matrix tests share one process-global
    /// registry; serialize them on this.
    pub fn serial_guard() -> MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Should this check fire? Advances the per-site counters/RNG.
    fn decide(st: &mut SiteState) -> bool {
        if let Some((p, _)) = st.spec.probability {
            let fire = st.triggered < st.spec.times && st.rng.f64() < p;
            if fire {
                st.triggered += 1;
            }
            return fire;
        }
        if st.passes < st.spec.after {
            st.passes += 1;
            return false;
        }
        let fire = st.triggered < st.spec.times;
        if fire {
            st.triggered += 1;
        }
        fire
    }

    /// Generic (read/sync/rename) failpoint check.
    pub fn check(site: &str) -> io::Result<()> {
        if ARMED.load(Ordering::Relaxed) == 0 {
            return Ok(());
        }
        let mut t = table();
        let Some(st) = t.get_mut(site) else { return Ok(()) };
        match st.spec.kind {
            FaultKind::Error => {
                if decide(st) {
                    return Err(injected_error(site));
                }
            }
            // Torn is byte-accounted through write sites; a plain
            // check never advances the byte counter, so it only fires
            // once the companion write site has hit the cut.
            FaultKind::Torn { at } => {
                if st.written >= at {
                    st.triggered += 1;
                    return Err(injected_error(site));
                }
            }
            FaultKind::Abort => {
                if decide(st) {
                    std::process::abort();
                }
            }
        }
        Ok(())
    }

    /// Write-site check for a `len`-byte write. Returns how many bytes
    /// may pass (`== len` normally); a short return means the caller
    /// must write exactly that prefix and then fail with
    /// [`injected_error`].
    pub fn check_write(site: &str, len: u64) -> io::Result<u64> {
        if ARMED.load(Ordering::Relaxed) == 0 {
            return Ok(len);
        }
        let mut t = table();
        let Some(st) = t.get_mut(site) else { return Ok(len) };
        match st.spec.kind {
            FaultKind::Error => {
                if decide(st) {
                    return Err(injected_error(site));
                }
                Ok(len)
            }
            FaultKind::Torn { at } => {
                if st.written >= at {
                    st.triggered += 1;
                    return Err(injected_error(site));
                }
                let allowed = (at - st.written).min(len);
                st.written += allowed;
                if allowed < len {
                    st.triggered += 1;
                }
                Ok(allowed)
            }
            FaultKind::Abort => {
                if decide(st) {
                    std::process::abort();
                }
                Ok(len)
            }
        }
    }
}

#[cfg(feature = "failpoints")]
pub use registry::{arm, check, check_write, disarm, reset, serial_guard, triggered};

/// No-op check (feature off): compiles away entirely.
#[cfg(not(feature = "failpoints"))]
#[inline(always)]
pub fn check(_site: &str) -> io::Result<()> {
    Ok(())
}

/// No-op write check (feature off): all bytes pass.
#[cfg(not(feature = "failpoints"))]
#[inline(always)]
pub fn check_write(_site: &str, len: u64) -> io::Result<u64> {
    Ok(len)
}

/// Retries for a transient fault at an async prefetch site before the
/// job gives up and dies (its supervisor degrades to the inline path).
pub const PREFETCH_RETRIES: u32 = 3;

/// Check `site` with bounded backoff retries — the prefetch-job
/// policy. Panics when the fault persists past [`PREFETCH_RETRIES`];
/// the pool's panic capture plus the streamed sweep's inline fallback
/// take over from there, so a dead prefetch never aborts a sweep.
#[cfg(feature = "failpoints")]
pub fn check_or_die(site: &str) {
    for attempt in 0..=PREFETCH_RETRIES {
        match check(site) {
            Ok(()) => return,
            Err(_) if attempt < PREFETCH_RETRIES => {
                // 0, 1, 2 → 100 µs, 200 µs, 400 µs
                std::thread::sleep(std::time::Duration::from_micros(100 << attempt));
            }
            Err(e) => panic!("{e} ({attempt} retries exhausted)"),
        }
    }
}

/// No-op (feature off).
#[cfg(not(feature = "failpoints"))]
#[inline(always)]
pub fn check_or_die(_site: &str) {}

#[cfg(all(test, feature = "failpoints"))]
mod tests {
    use super::*;

    #[test]
    fn counted_error_fires_exact_window() {
        let _g = serial_guard();
        let site = "test.fault.counted";
        arm(site, FaultSpec::error_after(2, 3));
        let results: Vec<bool> = (0..8).map(|_| check(site).is_ok()).collect();
        // passes 0,1 succeed; checks 2,3,4 fail; healed after.
        assert_eq!(results, vec![true, true, false, false, false, true, true, true]);
        assert_eq!(triggered(site), 3);
        disarm(site);
        assert!(check(site).is_ok());
    }

    #[test]
    fn torn_write_accounts_bytes_exactly() {
        let _g = serial_guard();
        let site = "test.fault.torn";
        arm(site, FaultSpec::torn(10));
        assert_eq!(check_write(site, 4).unwrap(), 4);
        assert_eq!(check_write(site, 4).unwrap(), 4);
        // 8 bytes through; a 5-byte write passes only 2.
        assert_eq!(check_write(site, 5).unwrap(), 2);
        // Persistently dead afterwards.
        assert!(check_write(site, 1).is_err());
        assert!(check(site).is_err());
        assert!(triggered(site) >= 2);
        disarm(site);
    }

    #[test]
    fn torn_at_zero_cuts_immediately() {
        let _g = serial_guard();
        let site = "test.fault.torn0";
        arm(site, FaultSpec::torn(0));
        assert!(check_write(site, 1).is_err());
        disarm(site);
    }

    #[test]
    fn seeded_probability_is_deterministic() {
        let _g = serial_guard();
        let site = "test.fault.random";
        let fire_pattern = |seed: u64| -> Vec<bool> {
            arm(site, FaultSpec::random_error(0.5, seed));
            let v = (0..64).map(|_| check(site).is_err()).collect();
            disarm(site);
            v
        };
        let a = fire_pattern(7);
        let b = fire_pattern(7);
        let c = fire_pattern(8);
        assert_eq!(a, b, "same seed must fire identically");
        assert_ne!(a, c, "different seeds should differ");
        let fails = a.iter().filter(|&&f| f).count();
        assert!((10..=54).contains(&fails), "p=0.5 fired {fails}/64");
    }

    #[test]
    fn injected_errors_are_recognizable() {
        let e = injected_error("test.site");
        assert!(is_injected(&e));
        assert!(e.to_string().contains("test.site"));
        assert!(!is_injected(&io::Error::new(io::ErrorKind::Other, "plain")));
    }

    #[test]
    fn unarmed_sites_pass() {
        let _g = serial_guard();
        reset();
        assert!(check("test.fault.never-armed").is_ok());
        assert_eq!(check_write("test.fault.never-armed", 9).unwrap(), 9);
        assert_eq!(triggered("test.fault.never-armed"), 0);
    }
}
