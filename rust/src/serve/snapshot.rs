//! Immutable, self-contained model state for serving.
//!
//! A [`ModelSnapshot`] is one posterior draw of `Φ̂` (sampled from the
//! topic-word counts through the same PPU kernel training uses) plus
//! the `Ψ` vector, the prebuilt bucket-(a) alias tables, and enough
//! metadata to attribute responses. Once constructed it never changes,
//! which is what makes the serving layer's lock-free hot swap safe:
//! concurrency is handled entirely by `Arc` + the publish cell, never
//! by interior mutability here.

use crate::diagnostics::heldout::{
    fold_in_gibbs, score_completion, CompletionScore, FOLD_IN_STREAM,
};
use crate::hdp::checkpoint::Checkpoint;
use crate::hdp::pc::phi::sample_phi;
use crate::hdp::pc::zstep::WordTables;
use crate::hdp::pc::PcSampler;
use crate::hdp::pclda::PcLdaSampler;
use crate::hdp::Trainer;
use crate::par;
use crate::rng::Pcg64;
use crate::sparse::{PhiMatrix, TopicWordRows};

use super::{InferMode, InferRequest, InferResponse};

/// Stream id of the freeze-time `Φ̂` root. A *fresh* generator derived
/// from the caller's `phi_seed` — deliberately not the training chain's
/// RNG, so freezing a snapshot consumes nothing from the chain and two
/// freezes of the same counts with the same seed are bit-identical
/// regardless of where training has moved on to.
const PHI_FREEZE_STREAM: u64 = 0xf5ee;

/// A frozen model: everything needed to answer inference requests,
/// immutable after construction.
pub struct ModelSnapshot {
    /// Generation stamped at publish time (0 = never published).
    pub(crate) generation: u64,
    phi: PhiMatrix,
    psi: Vec<f64>,
    /// Bucket-(a) alias tables over `φ·α·Ψ`, prebuilt at freeze time
    /// for [`InferMode::SparseMixture`] requests.
    tables: WordTables,
    alpha: f64,
    beta: f64,
    vocab: usize,
    k_max: usize,
    /// Training iterations completed when the state was frozen.
    iteration: u64,
    /// Provenance label (`"pc-hdp"`, `"pclda"`, or a checkpoint's
    /// recorded sampler name).
    source: String,
}

impl ModelSnapshot {
    /// Freeze a snapshot from raw model state: sample `Φ̂ ~ PPU(β + n)`
    /// with a fresh root derived from `phi_seed`, normalize, and
    /// prebuild the alias tables. `psi.len()` fixes the topic capacity
    /// and must equal `n.num_topics()`.
    #[allow(clippy::too_many_arguments)]
    pub fn freeze<E: par::Executor + Copy>(
        n: &TopicWordRows,
        psi: &[f64],
        alpha: f64,
        beta: f64,
        vocab: usize,
        iteration: u64,
        source: &str,
        phi_seed: u64,
        exec: E,
    ) -> Self {
        assert_eq!(
            psi.len(),
            n.num_topics(),
            "psi length must match topic-word row count"
        );
        let root = Pcg64::with_stream(phi_seed, PHI_FREEZE_STREAM);
        let phi = sample_phi(&root, n, beta, vocab, exec);
        let tables = WordTables::build(&phi, psi, alpha, exec);
        Self {
            generation: 0,
            phi,
            psi: psi.to_vec(),
            tables,
            alpha,
            beta,
            vocab,
            k_max: n.num_topics(),
            iteration,
            source: source.to_string(),
        }
    }

    /// Freeze the live state of a PC-HDP sampler (no training RNG is
    /// consumed; the sampler is free to keep stepping afterwards).
    pub fn from_pc(s: &PcSampler, phi_seed: u64) -> Self {
        let cfg = *s.config();
        Self::freeze(
            s.n(),
            s.psi(),
            cfg.alpha,
            cfg.beta,
            Trainer::docs(s).vocab_size(),
            Trainer::iterations_done(s) as u64,
            "pc-hdp",
            phi_seed,
            s.pool(),
        )
    }

    /// Freeze the live state of the fixed-K Pólya urn LDA sampler.
    pub fn from_pclda(s: &PcLdaSampler, phi_seed: u64) -> Self {
        Self::freeze(
            s.n(),
            s.psi(),
            s.alpha(),
            s.beta(),
            Trainer::docs(s).vocab_size(),
            Trainer::iterations_done(s) as u64,
            "pclda",
            phi_seed,
            s.pool(),
        )
    }

    /// Rebuild a snapshot from a saved [`Checkpoint`] plus the corpus
    /// it was trained on — any [`crate::corpus::CorpusView`] layout,
    /// nested or packed, so the packed-only serving path never
    /// materializes a nested corpus. The topic-word counts recovered
    /// from `z` are canonical (identical to the live sampler's merged
    /// rows), so a checkpoint round trip freezes to bit-identical
    /// state as [`ModelSnapshot::from_pc`] on the live sampler — given
    /// the same `phi_seed`. `alpha`/`beta` are not stored in
    /// checkpoints and must be supplied by the caller.
    pub fn from_checkpoint<C, E>(
        ckpt: &Checkpoint,
        corpus: &C,
        alpha: f64,
        beta: f64,
        phi_seed: u64,
        exec: E,
    ) -> anyhow::Result<Self>
    where
        C: crate::corpus::CorpusView + ?Sized,
        E: par::Executor + Copy,
    {
        let n = ckpt.topic_word_rows(corpus)?;
        Ok(Self::freeze(
            &n,
            &ckpt.psi,
            alpha,
            beta,
            corpus.vocab_size(),
            ckpt.iteration,
            &ckpt.sampler,
            phi_seed,
            exec,
        ))
    }

    /// Generation stamped by the publish cell (0 if never published).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// The frozen `Φ̂`.
    pub fn phi(&self) -> &PhiMatrix {
        &self.phi
    }

    /// The frozen `Ψ`.
    pub fn psi(&self) -> &[f64] {
        &self.psi
    }

    /// Document-side concentration α used for fold-in.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Topic-word prior mass β used when `Φ̂` was sampled.
    pub fn beta(&self) -> f64 {
        self.beta
    }

    /// Vocabulary size the snapshot serves.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Topic capacity (length of `Ψ`).
    pub fn k_max(&self) -> usize {
        self.k_max
    }

    /// Training iterations completed at freeze time.
    pub fn iteration(&self) -> u64 {
        self.iteration
    }

    /// Provenance label.
    pub fn source(&self) -> &str {
        &self.source
    }

    /// One-line human-readable description.
    pub fn describe(&self) -> String {
        format!(
            "gen {} [{} @ iter {}] K={} V={} nnz(phi)={}",
            self.generation,
            self.source,
            self.iteration,
            self.k_max,
            self.vocab,
            self.phi.nnz()
        )
    }

    /// Answer one request against this snapshot. Pure: the response is
    /// a function of `(request, snapshot)` only — the RNG is a private
    /// stream derived via [`super::request_seed`] from the request id,
    /// seed, and this snapshot's generation.
    pub fn infer(&self, req: &InferRequest) -> InferResponse {
        let derived = super::request_seed(req.seed, req.id, self.generation);
        let mut rng = Pcg64::with_stream(derived, FOLD_IN_STREAM);
        // Completion mode mirrors `document_completion`: fold in the
        // first half, score the second; documents shorter than 2
        // tokens are skipped entirely (no randomness consumed).
        let (observed, held): (&[u32], &[u32]) = match req.mode {
            InferMode::Completion => {
                if req.tokens.len() < 2 {
                    (&[], &[])
                } else {
                    req.tokens.split_at(req.tokens.len() / 2)
                }
            }
            _ => (&req.tokens, &req.tokens),
        };
        let mut weights = vec![0.0f64; self.k_max];
        let mut m: Vec<u32> = Vec::new();
        match req.mode {
            InferMode::SparseMixture => self.fold_in_sparse(
                &mut rng,
                observed,
                req.passes,
                &mut m,
            ),
            _ => fold_in_gibbs(
                &mut rng,
                observed,
                &self.phi,
                &self.psi,
                self.alpha,
                req.passes,
                &mut weights,
                &mut m,
            ),
        }
        let denom = observed.len() as f64 + self.alpha;
        let mut acc = CompletionScore::default();
        score_completion(
            held, &self.phi, &self.psi, self.alpha, &m, denom, &mut acc,
        );
        let mut theta = Vec::new();
        let mut topic_counts = Vec::new();
        for (k, &c) in m.iter().enumerate() {
            if c > 0 {
                let th =
                    (c as f64 + self.alpha * self.psi[k]) / denom;
                theta.push((k as u32, th));
                topic_counts.push((k as u32, c));
            }
        }
        InferResponse {
            id: req.id,
            generation: self.generation,
            theta,
            topic_counts,
            log_likelihood: acc.log_p,
            tokens_scored: acc.scored,
            tokens_skipped: acc.skipped,
        }
    }

    /// Doubly sparse fold-in: the sampler's own two-bucket z draw
    /// (bucket (a) via the snapshot's prebuilt alias tables over
    /// `φ·α·Ψ`, bucket (b) via a linear walk over the document's
    /// nonzero `φ·m` terms). Same stationary conditional as
    /// [`fold_in_gibbs`], different randomness consumption — so it is
    /// *not* bit-compatible with the dense scan, only
    /// distribution-compatible (pinned statistically in
    /// `tests/statistical.rs`).
    fn fold_in_sparse(
        &self,
        rng: &mut Pcg64,
        tokens: &[u32],
        passes: usize,
        m: &mut Vec<u32>,
    ) {
        let k_max = self.k_max;
        m.clear();
        m.resize(k_max, 0);
        let mut z: Vec<u32> =
            tokens.iter().map(|_| rng.below(k_max as u64) as u32).collect();
        // Topics with m > 0, unordered, no duplicates.
        let mut active: Vec<u32> = Vec::new();
        for &k in &z {
            if m[k as usize] == 0 {
                active.push(k);
            }
            m[k as usize] += 1;
        }
        let mut partials: Vec<(u32, f64)> = Vec::new();
        for _ in 0..passes {
            for (i, &v) in tokens.iter().enumerate() {
                let kold = z[i] as usize;
                m[kold] -= 1;
                if m[kold] == 0 {
                    let pos = active
                        .iter()
                        .position(|&k| k as usize == kold)
                        .expect("active topic tracked");
                    active.swap_remove(pos);
                }
                // Bucket (b): cumulative φ_{k,v}·m_k over the doc's
                // active topics.
                partials.clear();
                let mut s_b = 0.0f64;
                for &k in &active {
                    let w = self.phi.get(k, v) * m[k as usize] as f64;
                    if w > 0.0 {
                        s_b += w;
                        partials.push((k, s_b));
                    }
                }
                // Bucket (a): prebuilt alias mass Σ_k φ_{k,v}·α·Ψ_k.
                let q_a = self.tables.mass(v);
                let total = s_b + q_a;
                let knew = if total <= 0.0 {
                    kold as u32
                } else {
                    let u = rng.f64() * total;
                    if u < s_b {
                        partials
                            .iter()
                            .find(|&&(_, cum)| u < cum)
                            .map(|&(k, _)| k)
                            .unwrap_or(partials.last().unwrap().0)
                    } else {
                        // `u ≥ s_b` can hold even when q_a == 0: the
                        // rounding in `rng.f64()·s_b` can land exactly
                        // on `s_b`. A zero-mass column has no alias
                        // table — fall back to the last bucket-(b)
                        // partial (`total > 0 ∧ q_a = 0 ⇒ s_b > 0`),
                        // or keep the old topic; a serving request
                        // must never panic a pool slot over an unseen
                        // vocabulary id.
                        self.tables.try_sample(v, rng).unwrap_or_else(|| {
                            partials.last().map(|&(k, _)| k).unwrap_or(kold as u32)
                        })
                    }
                };
                z[i] = knew;
                if m[knew as usize] == 0 {
                    active.push(knew);
                }
                m[knew as usize] += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HdpConfig;
    use crate::corpus::synthetic::HdpCorpusSpec;
    use std::sync::Arc;

    fn small_sampler() -> (Arc<crate::corpus::Corpus>, PcSampler) {
        let (c, _) = HdpCorpusSpec {
            vocab: 150,
            topics: 4,
            gamma: 2.0,
            alpha: 0.8,
            topic_beta: 0.05,
            docs: 50,
            mean_doc_len: 25.0,
            len_sigma: 0.3,
            min_doc_len: 8,
        }
        .generate(41);
        let corpus = Arc::new(c);
        let cfg = HdpConfig {
            alpha: 0.3,
            beta: 0.05,
            gamma: 1.0,
            k_max: 12,
            init_topics: 1,
        };
        let mut s = PcSampler::new(corpus.clone(), cfg, 1, 5).unwrap();
        for _ in 0..15 {
            s.step().unwrap();
        }
        (corpus, s)
    }

    #[test]
    fn freeze_is_deterministic_and_consumes_no_chain_rng() {
        let (_, mut s) = small_sampler();
        let a = ModelSnapshot::from_pc(&s, 99);
        let b = ModelSnapshot::from_pc(&s, 99);
        assert_eq!(a.phi().nnz(), b.phi().nnz());
        for k in 0..a.k_max() {
            assert_eq!(a.phi().row(k), b.phi().row(k), "topic {k}");
        }
        // Freezing must not perturb the chain: stepping after two
        // freezes matches stepping without them on a twin sampler.
        let (_, mut twin) = small_sampler();
        s.step().unwrap();
        twin.step().unwrap();
        assert_eq!(s.psi(), twin.psi());
        assert_eq!(s.z_nested(), twin.z_nested());
    }

    #[test]
    fn infer_modes_are_sane() {
        let (_, s) = small_sampler();
        let snap = ModelSnapshot::from_pc(&s, 7);
        let doc: Vec<u32> = (0..40u32).map(|i| (i * 3) % 150).collect();
        for mode in
            [InferMode::Mixture, InferMode::SparseMixture, InferMode::Completion]
        {
            let r = snap.infer(&InferRequest {
                id: 1,
                tokens: doc.clone(),
                seed: 11,
                passes: 4,
                mode,
            });
            assert_eq!(r.generation, 0);
            let mass: f64 = r.theta.iter().map(|&(_, t)| t).sum();
            assert!(mass > 0.0 && mass <= 1.0 + 1e-9, "{mode:?}: {mass}");
            let counts: u32 = r.topic_counts.iter().map(|&(_, c)| c).sum();
            let folded = match mode {
                InferMode::Completion => doc.len() / 2,
                _ => doc.len(),
            };
            assert_eq!(counts as usize, folded, "{mode:?}");
            assert!(r.log_likelihood <= 0.0, "{mode:?}");
            assert!(
                r.theta.windows(2).all(|w| w[0].0 < w[1].0),
                "theta sorted by topic"
            );
        }
    }

    #[test]
    fn short_completion_doc_scores_nothing() {
        let (_, s) = small_sampler();
        let snap = ModelSnapshot::from_pc(&s, 7);
        let r = snap.infer(&InferRequest {
            id: 2,
            tokens: vec![3],
            seed: 5,
            passes: 3,
            mode: InferMode::Completion,
        });
        assert_eq!(r.tokens_scored, 0);
        assert_eq!(r.log_likelihood, 0.0);
        assert!(r.topic_counts.is_empty());
    }
}
