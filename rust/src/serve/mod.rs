//! Frozen-snapshot topic-inference serving.
//!
//! Training samplers mutate `(Φ, Ψ, z)` in place every iteration —
//! useless for answering queries. This module freezes one posterior
//! draw into an immutable [`ModelSnapshot`] and answers per-document
//! inference requests against it, concurrently and reproducibly, while
//! training continues elsewhere.
//!
//! # Snapshot lifecycle: freeze → publish → retire
//!
//! 1. **Freeze.** [`ModelSnapshot::from_pc`] /
//!    [`ModelSnapshot::from_pclda`] /
//!    [`ModelSnapshot::from_checkpoint`] sample `Φ̂` from the current
//!    topic-word counts with a *fresh* RNG root (never the training
//!    chain's — see the bugfix note below), normalize it into a
//!    [`crate::sparse::PhiMatrix`], and prebuild the bucket-(a) alias
//!    tables (`φ·α·Ψ` per word, §2.5 of the paper). The snapshot owns
//!    everything it needs; the sampler can keep training or drop.
//! 2. **Publish.** [`Server::publish`] (backed by [`SnapshotCell`])
//!    swaps the served `Arc<ModelSnapshot>` atomically and stamps a
//!    monotonically increasing *generation*. Readers that loaded the
//!    previous snapshot finish on it — in-flight requests never observe
//!    a torn or mixed state, because a snapshot is immutable after
//!    construction and the swap replaces the whole `Arc`.
//! 3. **Retire.** When the last in-flight request drops its clone, the
//!    old snapshot's refcount hits zero and it frees itself. There is
//!    no epoch machinery to drive; `Arc` is the reclamation scheme.
//!
//! # Determinism contract
//!
//! Every response is a pure function of
//! `(request tokens, request seed, request id, snapshot)`:
//!
//! * The per-request generator is
//!   `Pcg64::with_stream(request_seed(seed, id, generation), FOLD_IN_STREAM)`
//!   — derived from the request *and the snapshot generation it ran
//!   against*, never shared with the training chain. Re-issuing the
//!   same `(request, seed)` against the same snapshot reproduces the
//!   response bit-for-bit; the same request against a different
//!   generation draws an unrelated stream.
//! * [`InferMode::Completion`] consumes randomness exactly like
//!   [`crate::diagnostics::heldout::document_completion`], so a served
//!   completion request and a direct heldout evaluation with the same
//!   derived seed agree to the bit (pinned in `tests/statistical.rs`).
//! * Serving never touches sampler state: snapshots are frozen copies
//!   and request RNGs are derived, so interleaving queries with
//!   training steps leaves the training chain bit-identical (pinned in
//!   `tests/serving.rs`).

pub mod server;
pub mod snapshot;

pub use server::{Server, SnapshotCell};
pub use snapshot::ModelSnapshot;

use crate::rng::SplitMix64;

/// How [`ModelSnapshot::infer`] turns tokens into a response.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InferMode {
    /// Fold in *all* tokens with the dense-column Gibbs scan and report
    /// the topic mixture `θ̂` (plus the full-document likelihood under
    /// it — observed and scored sets coincide).
    Mixture,
    /// Same posterior as [`InferMode::Mixture`], but the per-token draw
    /// uses the snapshot's prebuilt alias tables and the sparse
    /// bucket-(b) walk — the sampler's own doubly sparse z kernel
    /// shape. Different RNG consumption, same stationary conditional.
    SparseMixture,
    /// Document-completion protocol: fold in the first half, score the
    /// second. Bit-compatible with
    /// [`crate::diagnostics::heldout::document_completion`].
    Completion,
}

/// One independent inference job.
#[derive(Clone, Debug)]
pub struct InferRequest {
    /// Caller-chosen id; echoed in the response and mixed into the
    /// per-request RNG stream.
    pub id: u64,
    /// The document's word ids (must be `< snapshot.vocab()`).
    pub tokens: Vec<u32>,
    /// Base seed for this request's private randomness.
    pub seed: u64,
    /// Fold-in Gibbs sweeps over the observed tokens.
    pub passes: usize,
    /// Inference protocol.
    pub mode: InferMode,
}

/// Result of serving one [`InferRequest`].
#[derive(Clone, Debug)]
pub struct InferResponse {
    /// Echo of [`InferRequest::id`].
    pub id: u64,
    /// Generation of the snapshot that answered (attribution: exactly
    /// one published snapshot produced this response).
    pub generation: u64,
    /// Sparse posterior-mean mixture: `(k, (m_k + α Ψ_k) / denom)` for
    /// topics with `m_k > 0`, sorted by topic id.
    pub theta: Vec<(u32, f64)>,
    /// Raw fold-in counts `(k, m_k)` for topics with `m_k > 0`.
    pub topic_counts: Vec<(u32, u32)>,
    /// `Σ ln p(w)` over the scored tokens.
    pub log_likelihood: f64,
    /// Tokens scored.
    pub tokens_scored: u64,
    /// Tokens with zero mass under the snapshot (skipped).
    pub tokens_skipped: u64,
}

/// Derive the per-request RNG seed from `(base seed, request id,
/// snapshot generation)`.
///
/// Two SplitMix64 mixes so that id and generation each diffuse through
/// the full 64 bits independently: requests differing in any one of
/// the three inputs get unrelated `Pcg64` streams, and a request
/// re-run against a *new* generation re-draws rather than replaying.
/// Public so tests (and callers cross-checking against
/// [`crate::diagnostics::heldout::document_completion`]) can derive
/// the exact seed a server used.
pub fn request_seed(seed: u64, request_id: u64, generation: u64) -> u64 {
    let a = SplitMix64::new(seed ^ request_id.rotate_left(21)).next_u64();
    SplitMix64::new(a ^ generation.rotate_left(42)).next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_seed_sensitivity() {
        let base = request_seed(7, 11, 1);
        assert_ne!(base, request_seed(8, 11, 1), "seed must matter");
        assert_ne!(base, request_seed(7, 12, 1), "id must matter");
        assert_ne!(base, request_seed(7, 11, 2), "generation must matter");
        assert_eq!(base, request_seed(7, 11, 1), "pure function");
    }
}
