//! The publish cell and the request-serving front end.
//!
//! Hot-swap scheme (hand-rolled arc-swap): the served snapshot lives in
//! a [`SnapshotCell`] as an `Arc<ModelSnapshot>` behind a mutex that is
//! held only for the duration of an `Arc` clone or store — never while
//! inference runs. Readers `load()` a clone and work on it unlocked;
//! a publisher swaps in a new `Arc` and bumps the generation counter.
//! In-flight requests keep the snapshot they loaded alive through its
//! refcount and finish on it; the retired snapshot frees itself when
//! the last clone drops.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::par::{self, WorkerPool};

use super::{InferRequest, InferResponse, ModelSnapshot};

/// Atomically swappable `Arc<ModelSnapshot>` with a monotonically
/// increasing generation stamp.
///
/// The mutex is a publication primitive only: the critical section is
/// an `Arc` clone (load) or an `Arc` store (publish), both O(1) and
/// never blocking on inference work. The separate [`AtomicU64`] lets
/// callers poll the published generation without touching the lock.
pub struct SnapshotCell {
    current: Mutex<Arc<ModelSnapshot>>,
    generation: AtomicU64,
}

impl SnapshotCell {
    /// Wrap the first snapshot, stamping it generation 1.
    pub fn new(mut first: ModelSnapshot) -> Self {
        first.generation = 1;
        Self {
            current: Mutex::new(Arc::new(first)),
            generation: AtomicU64::new(1),
        }
    }

    /// Publish a new snapshot: stamp it with the next generation and
    /// swap it in. Readers that already loaded the previous `Arc` are
    /// unaffected; subsequent loads see the new one. Returns the
    /// generation assigned.
    pub fn publish(&self, mut snap: ModelSnapshot) -> u64 {
        let mut guard = self.current.lock().unwrap();
        let next = guard.generation + 1;
        snap.generation = next;
        *guard = Arc::new(snap);
        drop(guard);
        self.generation.store(next, Ordering::Release);
        next
    }

    /// Clone the current snapshot handle (short lock, no copying of
    /// model state). The returned snapshot is immutable and valid for
    /// as long as the caller holds it, regardless of later publishes.
    pub fn load(&self) -> Arc<ModelSnapshot> {
        self.current.lock().unwrap().clone()
    }

    /// The most recently published generation (lock-free).
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }
}

/// Batched topic-inference server over a [`SnapshotCell`].
///
/// Shares a [`WorkerPool`] with (or borrows one from) training: a batch
/// is dispatched as one pool job with one task per request — many small
/// independent jobs rather than one sweep-shaped job. Do not call
/// [`Server::serve_batch`] from *inside* a pool task (the pool's
/// dispatch gate would deadlock); concurrent batches from multiple
/// client threads are fine — dispatches serialize on the gate.
pub struct Server {
    pool: Arc<WorkerPool>,
    cell: SnapshotCell,
}

impl Server {
    /// Serve `first` (stamped generation 1) using `pool` for batches.
    pub fn new(pool: Arc<WorkerPool>, first: ModelSnapshot) -> Self {
        Self { pool, cell: SnapshotCell::new(first) }
    }

    /// Hot-swap the served model. See [`SnapshotCell::publish`].
    pub fn publish(&self, snap: ModelSnapshot) -> u64 {
        self.cell.publish(snap)
    }

    /// Handle on the currently served snapshot (e.g. to pin a sequence
    /// of requests to one generation, or to cross-check responses).
    pub fn snapshot(&self) -> Arc<ModelSnapshot> {
        self.cell.load()
    }

    /// The currently served generation.
    pub fn generation(&self) -> u64 {
        self.cell.generation()
    }

    /// Answer one request inline on the calling thread. Loads the
    /// current snapshot and runs on it to completion — a concurrent
    /// publish does not affect this response.
    pub fn serve_one(&self, req: &InferRequest) -> InferResponse {
        self.cell.load().infer(req)
    }

    /// Answer a batch on the worker pool, one task per request. The
    /// snapshot is loaded **once**, so every response in the batch
    /// carries the same generation even if a publish lands mid-batch.
    pub fn serve_batch(&self, reqs: &[InferRequest]) -> Vec<InferResponse> {
        let snap = self.cell.load();
        par::exec_each(&*self.pool, reqs.len(), |i| snap.infer(&reqs[i]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HdpConfig;
    use crate::corpus::synthetic::HdpCorpusSpec;
    use crate::hdp::pc::PcSampler;
    use crate::hdp::Trainer;
    use crate::serve::{InferMode, ModelSnapshot};

    fn sampler() -> PcSampler {
        let (c, _) = HdpCorpusSpec {
            vocab: 120,
            topics: 3,
            gamma: 2.0,
            alpha: 0.8,
            topic_beta: 0.05,
            docs: 40,
            mean_doc_len: 20.0,
            len_sigma: 0.3,
            min_doc_len: 6,
        }
        .generate(23);
        let cfg = HdpConfig {
            alpha: 0.3,
            beta: 0.05,
            gamma: 1.0,
            k_max: 10,
            init_topics: 1,
        };
        let mut s = PcSampler::new(Arc::new(c), cfg, 2, 9).unwrap();
        for _ in 0..10 {
            s.step().unwrap();
        }
        s
    }

    fn requests(n: u64) -> Vec<InferRequest> {
        (0..n)
            .map(|i| InferRequest {
                id: i,
                tokens: (0..30u32).map(|t| (t * 7 + i as u32) % 120).collect(),
                seed: 1000 + i,
                passes: 3,
                mode: InferMode::Mixture,
            })
            .collect()
    }

    #[test]
    fn publish_bumps_generation_and_old_handles_survive() {
        let s = sampler();
        let server = Server::new(s.pool_handle(), ModelSnapshot::from_pc(&s, 1));
        assert_eq!(server.generation(), 1);
        let old = server.snapshot();
        let g2 = server.publish(ModelSnapshot::from_pc(&s, 2));
        assert_eq!(g2, 2);
        assert_eq!(server.generation(), 2);
        // The retired handle still answers, attributed to generation 1.
        let r = old.infer(&requests(1)[0]);
        assert_eq!(r.generation, 1);
        assert_eq!(server.snapshot().generation(), 2);
    }

    #[test]
    fn serve_batch_matches_serial_and_is_single_generation() {
        let s = sampler();
        let server = Server::new(s.pool_handle(), ModelSnapshot::from_pc(&s, 4));
        let reqs = requests(24);
        let batch = server.serve_batch(&reqs);
        let snap = server.snapshot();
        assert_eq!(batch.len(), reqs.len());
        for (r, req) in batch.iter().zip(&reqs) {
            assert_eq!(r.generation, 1);
            let direct = snap.infer(req);
            assert_eq!(r.id, direct.id);
            assert_eq!(
                r.log_likelihood.to_bits(),
                direct.log_likelihood.to_bits()
            );
            assert_eq!(r.theta, direct.theta);
            assert_eq!(r.topic_counts, direct.topic_counts);
        }
    }
}
