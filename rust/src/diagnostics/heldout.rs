//! Held-out evaluation: document-completion perplexity.
//!
//! Standard protocol: split each held-out document's tokens in half;
//! estimate the document's topic mixture `θ̂_d` from the first half by
//! a few Gibbs passes against the *fixed* trained `Φ̂`, `Ψ`; score the
//! second half under `p(w) = Σ_k θ̂_{d,k} φ̂_{k,w}` and report
//! `exp(−Σ log p / N)`. The per-token estimation step is exactly the
//! sampler's z conditional (eq. 24), so this module doubles as a
//! consumer of the dense `zscore` XLA artifact for cross-validation.

use crate::corpus::DocAccess;
use crate::rng::{dist, Pcg64};
use crate::sparse::PhiMatrix;

/// Result of a held-out evaluation.
#[derive(Clone, Debug)]
pub struct HeldoutResult {
    /// Document-completion perplexity (lower = better).
    pub perplexity: f64,
    /// Tokens scored.
    pub tokens: u64,
    /// Tokens whose word had zero mass in every topic (skipped).
    pub skipped: u64,
}

/// Stream id of the fold-in RNG. Shared with the serving layer
/// ([`crate::serve`]): a server request and a direct
/// [`document_completion`] call with the same derived seed construct
/// the same generator and therefore consume identical randomness.
pub const FOLD_IN_STREAM: u64 = 0x4e1d;

/// Running accumulators of a completion-scoring pass. Kept as one
/// mutable value (rather than per-call returns) so multi-document
/// evaluations add `ln p` terms in exactly the caller's document
/// order — float summation order is part of the bit-reproducibility
/// contract.
#[derive(Clone, Copy, Debug, Default)]
pub struct CompletionScore {
    /// `Σ ln p(w)` over scored tokens.
    pub log_p: f64,
    /// Tokens scored.
    pub scored: u64,
    /// Tokens with zero mass under the model (skipped).
    pub skipped: u64,
}

/// Fold-in Gibbs: estimate the θ̂ count vector `m` for `tokens` by
/// `passes` sweeps of the sampler's z conditional (eq. 24) against the
/// *fixed* `(Φ, Ψ)`. `weights` is caller scratch of length ≥ `psi.len()`;
/// `m` is resized to `psi.len()` and overwritten.
///
/// RNG contract (the serving layer's determinism guarantee leans on
/// this): one `below(k_max)` draw per token for the uniform
/// initialization, then per pass per token either exactly one
/// categorical draw, or none when the word has zero mass in every
/// topic. Nothing else touches `rng`.
#[allow(clippy::too_many_arguments)]
pub fn fold_in_gibbs(
    rng: &mut Pcg64,
    tokens: &[u32],
    phi: &PhiMatrix,
    psi: &[f64],
    alpha: f64,
    passes: usize,
    weights: &mut [f64],
    m: &mut Vec<u32>,
) {
    let k_max = psi.len();
    debug_assert!(weights.len() >= k_max);
    m.clear();
    m.resize(k_max, 0);
    let mut z: Vec<u32> =
        tokens.iter().map(|_| rng.below(k_max as u64) as u32).collect();
    for &k in &z {
        m[k as usize] += 1;
    }
    for _ in 0..passes {
        for (i, &v) in tokens.iter().enumerate() {
            let kold = z[i] as usize;
            m[kold] -= 1;
            let (col_topics, col_probs) = phi.col(v);
            let mut total = 0.0;
            weights[..k_max].iter_mut().for_each(|w| *w = 0.0);
            for (&k, &p) in col_topics.iter().zip(col_probs) {
                let w = p * (alpha * psi[k as usize] + m[k as usize] as f64);
                weights[k as usize] = w;
                total += w;
            }
            let knew = if total <= 0.0 {
                kold
            } else {
                dist::categorical(rng, &weights[..k_max])
            };
            z[i] = knew as u32;
            m[knew] += 1;
        }
    }
}

/// Score `held` tokens under the θ̂ point estimate implied by `m`
/// (posterior mean given the folded-in assignments):
/// `p(w) = Σ_k θ̂_k φ_{k,w}` with `θ̂_k = (m_k + α Ψ_k) / denom`.
/// Accumulates into `acc` in token order.
pub fn score_completion(
    held: &[u32],
    phi: &PhiMatrix,
    psi: &[f64],
    alpha: f64,
    m: &[u32],
    denom: f64,
    acc: &mut CompletionScore,
) {
    for &v in held {
        let (col_topics, col_probs) = phi.col(v);
        if col_topics.is_empty() {
            acc.skipped += 1;
            continue;
        }
        let mut p = 0.0f64;
        for (&k, &pw) in col_topics.iter().zip(col_probs) {
            let theta =
                (m[k as usize] as f64 + alpha * psi[k as usize]) / denom;
            p += theta * pw;
        }
        if p > 0.0 {
            acc.log_p += p.ln();
            acc.scored += 1;
        } else {
            acc.skipped += 1;
        }
    }
}

/// Evaluate document-completion perplexity of `(phi, psi)` on held-out
/// documents. `gibbs_passes` sweeps estimate θ̂ from the observed half.
/// `corpus` is any [`DocAccess`] source (nested [`crate::corpus::Corpus`]
/// or packed [`crate::corpus::PackedCorpus`]) — the RNG consumption is
/// per-document, so the result is bit-identical across layouts.
///
/// Built on [`fold_in_gibbs`] + [`score_completion`], the same core the
/// serving layer answers requests with: one `Completion`-mode request
/// per document reproduces this evaluation bit-for-bit.
pub fn document_completion<C: DocAccess + ?Sized>(
    corpus: &C,
    docs: &[usize],
    phi: &PhiMatrix,
    psi: &[f64],
    alpha: f64,
    gibbs_passes: usize,
    seed: u64,
) -> HeldoutResult {
    let k_max = psi.len();
    let mut rng = Pcg64::with_stream(seed, FOLD_IN_STREAM);
    let mut acc = CompletionScore::default();
    let mut weights = vec![0.0f64; k_max];
    let mut m: Vec<u32> = Vec::new();
    for &d in docs {
        let doc = corpus.doc(d);
        if doc.len() < 2 {
            continue;
        }
        let half = doc.len() / 2;
        let (observed, held) = doc.split_at(half);
        // θ̂ estimation: collapsed Gibbs on the observed half with Φ, Ψ
        // fixed (the PC z conditional), then score the held-out half.
        fold_in_gibbs(
            &mut rng, observed, phi, psi, alpha, gibbs_passes, &mut weights,
            &mut m,
        );
        let denom = observed.len() as f64 + alpha;
        score_completion(held, phi, psi, alpha, &m, denom, &mut acc);
    }
    HeldoutResult {
        // Zero scored tokens (empty doc set / all docs too short) has
        // no defined perplexity: NaN, not a silently "perfect"
        // exp(0) = 1.0. Callers report "no tokens" on a NaN.
        perplexity: if acc.scored == 0 {
            f64::NAN
        } else {
            (-acc.log_p / acc.scored as f64).exp()
        },
        tokens: acc.scored,
        skipped: acc.skipped,
    }
}

/// Split a corpus index set into train/held-out document ids.
pub fn train_test_split(
    num_docs: usize,
    test_fraction: f64,
    seed: u64,
) -> (Vec<usize>, Vec<usize>) {
    let mut ids: Vec<usize> = (0..num_docs).collect();
    let mut rng = Pcg64::with_stream(seed, 0x5711);
    rng.shuffle(&mut ids);
    let n_test = ((num_docs as f64) * test_fraction).round() as usize;
    let test = ids[..n_test].to_vec();
    let train = ids[n_test..].to_vec();
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HdpConfig;
    use crate::corpus::synthetic::HdpCorpusSpec;
    use crate::hdp::pc::{phi::sample_phi, PcSampler};
    use crate::hdp::Trainer;
    use std::sync::Arc;

    #[test]
    fn packed_corpus_scores_bit_identically() {
        // Same model, same held-out ids, nested vs packed corpus: the
        // per-document RNG consumption is layout-independent, so the
        // perplexity must match to the bit.
        let (c, _) = HdpCorpusSpec {
            vocab: 200,
            topics: 4,
            gamma: 2.0,
            alpha: 0.8,
            topic_beta: 0.05,
            docs: 60,
            mean_doc_len: 30.0,
            len_sigma: 0.3,
            min_doc_len: 10,
        }
        .generate(91);
        let packed = c.to_packed();
        let cfg = HdpConfig { alpha: 0.3, beta: 0.05, gamma: 1.0, k_max: 16, init_topics: 1 };
        let mut s = PcSampler::new(Arc::new(c.clone()), cfg, 1, 3).unwrap();
        for _ in 0..20 {
            s.step().unwrap();
        }
        let root = crate::rng::Pcg64::new(8);
        let phi = sample_phi(&root, s.n(), cfg.beta, c.vocab_size(), 1usize);
        let (_, test) = train_test_split(c.num_docs(), 0.3, 5);
        let a = document_completion(&c, &test, &phi, s.psi(), cfg.alpha, 3, 17);
        let b = document_completion(&packed, &test, &phi, s.psi(), cfg.alpha, 3, 17);
        assert_eq!(a.perplexity.to_bits(), b.perplexity.to_bits());
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.skipped, b.skipped);
    }

    #[test]
    fn empty_heldout_set_scores_nan_not_one() {
        // Regression: an empty doc-id list, or one whose documents are
        // all too short to split (< 2 tokens), scores zero tokens —
        // the perplexity must be NaN, never the silently "perfect"
        // exp(0) = 1.0 the old `max(1)` denominator produced.
        let phi = PhiMatrix::from_count_rows(4, &[vec![(0u32, 3u32), (2, 1)]]);
        let psi = [1.0f64];
        let c = crate::corpus::Corpus {
            docs: vec![vec![0u32], vec![], vec![1]],
            vocab: (0..4).map(|v| format!("w{v}")).collect(),
        };
        for ids in [&[][..], &[0usize, 1, 2][..]] {
            let r = document_completion(&c, ids, &phi, &psi, 0.5, 3, 42);
            assert!(r.perplexity.is_nan(), "ids {ids:?}: {}", r.perplexity);
            assert_eq!(r.tokens, 0);
            assert_eq!(r.skipped, 0);
        }
    }

    #[test]
    fn split_partitions() {
        let (train, test) = train_test_split(100, 0.2, 1);
        assert_eq!(train.len(), 80);
        assert_eq!(test.len(), 20);
        let mut all: Vec<usize> = train.iter().chain(&test).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn trained_model_beats_shuffled_phi() {
        // A trained model must achieve lower held-out perplexity than
        // the same Φ with shuffled topic rows (destroying the learned
        // word structure while keeping the size distribution).
        let (c, _) = HdpCorpusSpec {
            vocab: 300,
            topics: 6,
            gamma: 2.0,
            alpha: 0.6,
            topic_beta: 0.02,
            docs: 150,
            mean_doc_len: 50.0,
            len_sigma: 0.3,
            min_doc_len: 20,
        }
        .generate(81);
        let corpus = Arc::new(c);
        let cfg = HdpConfig { alpha: 0.3, beta: 0.02, gamma: 1.0, k_max: 48, init_topics: 1 };
        let mut s = PcSampler::new(corpus.clone(), cfg, 1, 7).unwrap();
        for _ in 0..120 {
            s.step().unwrap();
        }
        let root = crate::rng::Pcg64::new(5);
        let phi = sample_phi(&root, s.n(), cfg.beta, corpus.vocab_size(), 1usize);
        let (_, test) = train_test_split(corpus.num_docs(), 0.2, 3);
        let good = document_completion(&corpus, &test, &phi, s.psi(), cfg.alpha, 5, 11);
        assert!(good.tokens > 100);
        assert!(good.perplexity.is_finite() && good.perplexity > 1.0);
        // Scrambled baseline: permute word ids inside each row.
        let mut rng = crate::rng::Pcg64::new(9);
        let scrambled_rows: Vec<Vec<(u32, u32)>> = (0..cfg.k_max)
            .map(|k| {
                let row = s.n().row(k);
                let mut out: Vec<(u32, u32)> = row
                    .iter()
                    .map(|&(_, cnt)| (rng.below(300) as u32, cnt))
                    .collect();
                out.sort_unstable_by_key(|&(v, _)| v);
                out.dedup_by(|a, b| {
                    if a.0 == b.0 {
                        b.1 += a.1;
                        true
                    } else {
                        false
                    }
                });
                out
            })
            .collect();
        let bad_phi = PhiMatrix::from_count_rows(300, &scrambled_rows);
        let bad = document_completion(&corpus, &test, &bad_phi, s.psi(), cfg.alpha, 5, 11);
        assert!(
            good.perplexity < 0.8 * bad.perplexity,
            "trained {} vs scrambled {}",
            good.perplexity,
            bad.perplexity
        );
    }
}
