//! Model diagnostics: log-likelihoods, topic summaries, coherence.
//!
//! * [`loglik`] — the Fig-1 trace metric: joint collapsed
//!   log-likelihood `log p(w | z, β) + log p(z | Ψ, α)`, computed
//!   sparsely from the sufficient statistics (and cross-checked against
//!   the XLA-compiled dense kernel via [`crate::runtime`]).
//! * [`topics`] — top-words extraction, the paper's quantile summary
//!   tables (Appendices C–F), and UMass topic coherence (discussed in
//!   the paper's §4).

pub mod heldout;
pub mod loglik;
pub mod topics;
