//! Topic summaries: top words, the paper's quantile tables
//! (Appendices C–F / Fig 2), and UMass coherence.

use crate::corpus::{CorpusView, DocAccess};

/// One summarized topic.
#[derive(Clone, Debug)]
pub struct TopicSummary {
    /// Sampler-internal topic id.
    pub topic: usize,
    /// Total tokens `n_{k,·}`.
    pub tokens: u64,
    /// Top words, most frequent first.
    pub top_words: Vec<String>,
}

/// Extract per-topic top-`w` words from sparse topic-word rows,
/// restricted to topics with at least `min_tokens` tokens, sorted by
/// token count descending (the paper ranks topics this way).
pub fn top_words<C: CorpusView + ?Sized>(
    rows: &[Vec<(u32, u32)>],
    corpus: &C,
    w: usize,
    min_tokens: u64,
) -> Vec<TopicSummary> {
    let mut out = Vec::new();
    for (k, row) in rows.iter().enumerate() {
        let tokens: u64 = row.iter().map(|&(_, c)| c as u64).sum();
        if tokens < min_tokens.max(1) {
            continue;
        }
        let mut sorted: Vec<(u32, u32)> = row.clone();
        sorted.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let top = sorted
            .iter()
            .take(w)
            .map(|&(v, _)| corpus.vocab()[v as usize].clone())
            .collect();
        out.push(TopicSummary { topic: k, tokens, top_words: top });
    }
    out.sort_by(|a, b| b.tokens.cmp(&a.tokens).then(a.topic.cmp(&b.topic)));
    out
}

/// The paper's quantile summary (Appendix C preamble): rank topics with
/// ≥ `min_tokens` tokens by size, pick the `per_quantile` topics closest
/// to each of the 100 / 75 / 50 / 25 / 5 % quantiles of the ranking,
/// and report their top words.
pub fn quantile_summary(
    summaries: &[TopicSummary],
    quantiles: &[f64],
    per_quantile: usize,
) -> Vec<(f64, Vec<TopicSummary>)> {
    let n = summaries.len();
    let mut out = Vec::new();
    if n == 0 {
        return quantiles.iter().map(|&q| (q, Vec::new())).collect();
    }
    for &q in quantiles {
        // rank 0 = largest topic = 100% quantile.
        let target = ((1.0 - q) * (n - 1) as f64).round() as usize;
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| (i as i64 - target as i64).abs());
        let mut picks: Vec<TopicSummary> =
            order.iter().take(per_quantile.min(n)).map(|&i| summaries[i].clone()).collect();
        picks.sort_by(|a, b| b.tokens.cmp(&a.tokens));
        out.push((q, picks));
    }
    out
}

/// Render a quantile summary as an aligned text table (the experiment
/// drivers write these next to the CSV traces).
pub fn render_quantile_table(groups: &[(f64, Vec<TopicSummary>)]) -> String {
    let mut s = String::new();
    for (q, topics) in groups {
        s.push_str(&format!("== quantile {:.0}% ==\n", q * 100.0));
        if topics.is_empty() {
            s.push_str("(no topics)\n");
            continue;
        }
        s.push_str(&format!(
            "{}\n",
            topics
                .iter()
                .map(|t| format!("topic {:>4} ({:>9})", t.topic, t.tokens))
                .collect::<Vec<_>>()
                .join("  ")
        ));
        let depth = topics.iter().map(|t| t.top_words.len()).max().unwrap_or(0);
        for r in 0..depth {
            let row: Vec<String> = topics
                .iter()
                .map(|t| {
                    format!("{:<21}", t.top_words.get(r).cloned().unwrap_or_default())
                })
                .collect();
            s.push_str(&row.join("  "));
            s.push('\n');
        }
        s.push('\n');
    }
    s
}

/// UMass topic coherence (Mimno et al. 2011) for one topic's top words:
/// `Σ_{i<j} log[(D(w_i, w_j) + 1) / D(w_j)]` over document
/// co-occurrence counts. The paper (§4) notes this score is strongly
/// K-dependent; it is reported for completeness.
pub fn umass_coherence<C: DocAccess + ?Sized>(corpus: &C, word_ids: &[u32]) -> f64 {
    // Document frequency and co-document frequency over the top words.
    let set: Vec<u32> = word_ids.to_vec();
    let idx_of = |w: u32| set.iter().position(|&x| x == w);
    let mut df = vec![0u64; set.len()];
    let mut codf = vec![vec![0u64; set.len()]; set.len()];
    let mut present = vec![false; set.len()];
    for d in 0..corpus.num_docs() {
        let doc = corpus.doc(d);
        present.fill(false);
        for &w in doc {
            if let Some(i) = idx_of(w) {
                present[i] = true;
            }
        }
        for i in 0..set.len() {
            if present[i] {
                df[i] += 1;
                for j in 0..i {
                    if present[j] {
                        codf[i][j] += 1;
                        codf[j][i] += 1;
                    }
                }
            }
        }
    }
    let mut score = 0.0;
    for i in 1..set.len() {
        for j in 0..i {
            if df[j] > 0 {
                score += ((codf[i][j] + 1) as f64 / df[j] as f64).ln();
            }
        }
    }
    score
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Corpus;

    fn corpus() -> Corpus {
        Corpus {
            docs: vec![vec![0, 1], vec![0, 2], vec![1, 2], vec![0]],
            vocab: vec!["apple".into(), "banana".into(), "cherry".into()],
        }
    }

    #[test]
    fn top_words_sorted_and_filtered() {
        let rows = vec![
            vec![(0u32, 5u32), (1, 2)],
            vec![(2, 1)],
            vec![], // dead
        ];
        let s = top_words(&rows, &corpus(), 2, 2);
        assert_eq!(s.len(), 1); // topic 1 below min_tokens, topic 2 dead
        assert_eq!(s[0].topic, 0);
        assert_eq!(s[0].tokens, 7);
        assert_eq!(s[0].top_words, vec!["apple".to_string(), "banana".to_string()]);
    }

    #[test]
    fn top_words_ranking_descending() {
        let rows = vec![vec![(0u32, 1u32)], vec![(1, 10)], vec![(2, 5)]];
        let s = top_words(&rows, &corpus(), 1, 1);
        let sizes: Vec<u64> = s.iter().map(|t| t.tokens).collect();
        assert_eq!(sizes, vec![10, 5, 1]);
    }

    #[test]
    fn quantile_summary_picks_extremes() {
        let summaries: Vec<TopicSummary> = (0..100)
            .map(|i| TopicSummary {
                topic: i,
                tokens: (1000 - i * 10) as u64,
                top_words: vec![],
            })
            .collect();
        let q = quantile_summary(&summaries, &[1.0, 0.05], 3);
        assert_eq!(q.len(), 2);
        // 100% quantile -> largest topics (ranks 0,1,2)
        let top_ids: Vec<usize> = q[0].1.iter().map(|t| t.topic).collect();
        assert!(top_ids.contains(&0) && top_ids.contains(&1));
        // 5% quantile -> near rank 94
        assert!(q[1].1.iter().all(|t| t.topic > 85));
    }

    #[test]
    fn quantile_summary_empty() {
        let q = quantile_summary(&[], &[1.0], 5);
        assert!(q[0].1.is_empty());
    }

    #[test]
    fn render_contains_words() {
        let groups = vec![(
            1.0,
            vec![TopicSummary {
                topic: 3,
                tokens: 42,
                top_words: vec!["apple".into(), "banana".into()],
            }],
        )];
        let text = render_quantile_table(&groups);
        assert!(text.contains("apple"));
        assert!(text.contains("topic    3"));
        assert!(text.contains("100%"));
    }

    #[test]
    fn coherence_prefers_cooccurring_words() {
        // Same document frequencies, different co-occurrence: UMass
        // must rank the co-occurring pair higher.
        let vocab: Vec<String> = vec!["a".into(), "b".into()];
        let together = Corpus {
            docs: vec![vec![0, 1], vec![0, 1]],
            vocab: vocab.clone(),
        };
        let apart = Corpus {
            docs: vec![vec![0], vec![1], vec![0], vec![1]],
            vocab,
        };
        let coherent = umass_coherence(&together, &[0, 1]);
        let incoherent = umass_coherence(&apart, &[0, 1]);
        // together: ln((2+1)/2) > 0; apart: ln((0+1)/2) < 0.
        assert!(coherent > 0.0 && incoherent < 0.0, "{coherent} vs {incoherent}");
    }
}
