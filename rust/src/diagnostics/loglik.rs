//! Log-likelihood computations (the Fig 1 trace metric).
//!
//! The joint collapsed log-likelihood used for all trace plots is
//!
//! ```text
//! log p(w, z | Ψ, α, β) = log p(w | z, β) + log p(z | Ψ, α)
//! ```
//!
//! * `log p(w | z, β)` integrates `Φ` out of the categorical likelihood
//!   against its symmetric Dirichlet prior:
//!   `Σ_k [ lnΓ(Vβ) − lnΓ(Vβ + n_k·) + Σ_{v: n_kv>0} (lnΓ(β + n_kv) − lnΓ(β)) ]`
//!   — sparse in the nonzeros of `n`.
//! * `log p(z | Ψ, α)` is the Pólya-sequence probability of each
//!   document's topic sequence under the document DP with base `Ψ`
//!   (eq. 30): `Σ_d Σ_i log[(αΨ_{z_i} + m^{<i}_{d,z_i}) / (α + i − 1)]`.
//!
//! Both terms are exact; neither depends on the PPU approximation, so
//! the same metric is comparable across the partially collapsed,
//! direct-assignment, and (with the caveat the paper notes) subcluster
//! samplers.

use crate::par;
use crate::rng::special::ln_gamma;
use crate::sparse::DocTopics;

/// `log p(w | z, β)` from sparse topic-word rows.
///
/// `rows[k]` = sorted `(word, count)`; topics with zero tokens
/// contribute 0 (their prior integrates to 1).
pub fn word_loglik(rows: &[Vec<(u32, u32)>], beta: f64, vocab: usize) -> f64 {
    let vb = vocab as f64 * beta;
    let ln_gamma_vb = ln_gamma(vb);
    let ln_gamma_b = ln_gamma(beta);
    let mut total = 0.0;
    for row in rows {
        if row.is_empty() {
            continue;
        }
        let nk: u64 = row.iter().map(|&(_, c)| c as u64).sum();
        total += ln_gamma_vb - ln_gamma(vb + nk as f64);
        for &(_, c) in row {
            total += ln_gamma(beta + c as f64) - ln_gamma_b;
        }
    }
    total
}

/// `log p(z | Ψ, α)`: Pólya-sequence probability of every document's
/// topic sequence. `psi[k]` must cover every topic id appearing in `z`.
/// Parallel over documents on any executor (`threads: usize` scoped or
/// a persistent [`&WorkerPool`](crate::par::WorkerPool)).
pub fn crp_loglik(z: &[Vec<u32>], psi: &[f64], alpha: f64, exec: impl par::Executor) -> f64 {
    let plan = par::Sharding::even(z.len(), exec.slots());
    let partials = par::exec_shards(exec, &plan, |_, shard| {
        let mut acc = 0.0f64;
        let mut m = DocTopics::with_capacity(16);
        for zd in &z[shard.start..shard.end] {
            m.clear();
            for (i, &k) in zd.iter().enumerate() {
                let num = alpha * psi[k as usize] + m.get(k) as f64;
                let den = alpha + i as f64;
                acc += (num / den).ln();
                m.inc(k);
            }
        }
        acc
    });
    partials.into_iter().sum()
}

/// Packed-arena form of [`crp_loglik`]: assignments as one flat `z`
/// arena with CSR `doc_offsets` (the layout of
/// [`crate::corpus::PackedCorpus`], checkpoint v2, and the streamed
/// sweep's z stores). Per-document math, iteration order, and the
/// shard plan are identical to the nested form, so the result is
/// **bit-identical** for equal content — out-of-core pipelines can
/// score a chain without materializing nested vectors.
pub fn crp_loglik_packed(
    z: &[u32],
    doc_offsets: &[u64],
    psi: &[f64],
    alpha: f64,
    exec: impl par::Executor,
) -> f64 {
    let num_docs = doc_offsets.len().saturating_sub(1);
    let plan = par::Sharding::even(num_docs, exec.slots());
    let partials = par::exec_shards(exec, &plan, |_, shard| {
        let mut acc = 0.0f64;
        let mut m = DocTopics::with_capacity(16);
        for d in shard.start..shard.end {
            m.clear();
            let zd = &z[doc_offsets[d] as usize..doc_offsets[d + 1] as usize];
            for (i, &k) in zd.iter().enumerate() {
                let num = alpha * psi[k as usize] + m.get(k) as f64;
                let den = alpha + i as f64;
                acc += (num / den).ln();
                m.inc(k);
            }
        }
        acc
    });
    partials.into_iter().sum()
}

/// Joint metric: `word_loglik + crp_loglik`.
pub fn joint_loglik(
    rows: &[Vec<(u32, u32)>],
    z: &[Vec<u32>],
    psi: &[f64],
    alpha: f64,
    beta: f64,
    vocab: usize,
    exec: impl par::Executor,
) -> f64 {
    word_loglik(rows, beta, vocab) + crp_loglik(z, psi, alpha, exec)
}

/// Dense reference for [`word_loglik`] (tests + the XLA cross-check):
/// `Σ_{k,v} n_{k,v}·log φ_{k,v}` for a *given* normalized `Φ` — the
/// quantity the L1 Pallas kernel computes on tiles.
pub fn dense_phi_loglik(n: &[Vec<f64>], phi: &[Vec<f64>]) -> f64 {
    let mut acc = 0.0;
    for (nrow, prow) in n.iter().zip(phi) {
        for (&c, &p) in nrow.iter().zip(prow) {
            if c > 0.0 {
                acc += c * p.max(1e-300).ln();
            }
        }
    }
    acc
}

/// Per-document held-out perplexity given point estimates `Φ̂`, `θ̂`
/// (used by the eval examples): `exp(−Σ log p(w) / N)`.
///
/// An empty held-out set (`N = 0`) has no defined perplexity and
/// returns `f64::NAN` — never a silently "perfect" `exp(0) = 1.0`.
/// Callers should report "no tokens" on a NaN.
pub fn perplexity(docs: &[Vec<u32>], phi: &[Vec<f64>], theta: &[Vec<f64>]) -> f64 {
    let mut ll = 0.0f64;
    let mut n = 0u64;
    for (d, doc) in docs.iter().enumerate() {
        for &w in doc {
            let mut p = 0.0;
            for (k, th) in theta[d].iter().enumerate() {
                p += th * phi[k][w as usize];
            }
            ll += p.max(1e-300).ln();
            n += 1;
        }
    }
    if n == 0 {
        return f64::NAN;
    }
    (-ll / n as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_loglik_matches_brute_force() {
        // K=2, V=3, counts: k0: {0:2, 1:1}, k1: {2:4}
        let rows = vec![vec![(0u32, 2u32), (1, 1)], vec![(2, 4)]];
        let beta = 0.5;
        let v = 3usize;
        // brute force with dense counts
        let dense = [[2u32, 1, 0], [0, 0, 4]];
        let mut want = 0.0;
        for row in dense {
            let nk: u32 = row.iter().sum();
            want += ln_gamma(v as f64 * beta) - ln_gamma(v as f64 * beta + nk as f64);
            for c in row {
                want += ln_gamma(beta + c as f64) - ln_gamma(beta);
            }
        }
        let got = word_loglik(&rows, beta, v);
        assert!((got - want).abs() < 1e-10, "{got} vs {want}");
    }

    #[test]
    fn word_loglik_zero_rows_no_contribution() {
        let rows = vec![vec![], vec![(0u32, 1u32)], vec![]];
        let with_empties = word_loglik(&rows, 0.1, 5);
        let without = word_loglik(&[vec![(0u32, 1u32)]], 0.1, 5);
        assert!((with_empties - without).abs() < 1e-12);
    }

    #[test]
    fn crp_loglik_single_token_doc() {
        // One doc, one token on topic 1: p = αΨ_1 / α  = Ψ_1.
        let z = vec![vec![1u32]];
        let psi = [0.3, 0.7];
        let got = crp_loglik(&z, &psi, 0.5, 1usize);
        assert!((got - 0.7f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn crp_loglik_sequence_by_hand() {
        // Doc [0, 0, 1], α=1, Ψ=(0.5, 0.5):
        // p1 = (0.5·1 + 0)/1 = 0.5
        // p2 = (0.5 + 1)/2 = 0.75
        // p3 = (0.5 + 0)/3 = 1/6
        let z = vec![vec![0u32, 0, 1]];
        let psi = [0.5, 0.5];
        let want = 0.5f64.ln() + 0.75f64.ln() + (1.0f64 / 6.0).ln();
        let got = crp_loglik(&z, &psi, 1.0, 1usize);
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }

    #[test]
    fn crp_loglik_thread_invariant() {
        let z: Vec<Vec<u32>> = (0..37)
            .map(|d| (0..50).map(|i| ((d + i) % 5) as u32).collect())
            .collect();
        let psi = [0.2; 5];
        let a = crp_loglik(&z, &psi, 0.7, 1usize);
        let b = crp_loglik(&z, &psi, 0.7, 4usize);
        assert!((a - b).abs() < 1e-9);
    }

    #[test]
    fn crp_loglik_packed_bit_identical_to_nested() {
        // Uneven docs, an empty doc, several thread counts: the packed
        // form must reproduce the nested result to the bit.
        let mut z: Vec<Vec<u32>> = (0..23)
            .map(|d| (0..(d * 7) % 19).map(|i| ((d + i) % 6) as u32).collect())
            .collect();
        z[4].clear();
        let flat: Vec<u32> = z.iter().flatten().copied().collect();
        let mut offsets = vec![0u64];
        for zd in &z {
            offsets.push(offsets.last().unwrap() + zd.len() as u64);
        }
        let psi = [0.3, 0.2, 0.2, 0.1, 0.1, 0.1];
        for threads in [1usize, 3, 5] {
            let nested = crp_loglik(&z, &psi, 0.7, threads);
            let packed = crp_loglik_packed(&flat, &offsets, &psi, 0.7, threads);
            assert_eq!(packed.to_bits(), nested.to_bits(), "threads={threads}");
        }
        // Degenerate: no documents.
        assert_eq!(crp_loglik_packed(&[], &[0], &psi, 0.7, 2usize), 0.0);
        assert_eq!(crp_loglik_packed(&[], &[], &psi, 0.7, 2usize), 0.0);
    }

    #[test]
    fn dense_phi_loglik_by_hand() {
        let n = vec![vec![2.0, 0.0], vec![0.0, 3.0]];
        let phi = vec![vec![0.5, 0.5], vec![0.25, 0.75]];
        let want = 2.0 * 0.5f64.ln() + 3.0 * 0.75f64.ln();
        assert!((dense_phi_loglik(&n, &phi) - want).abs() < 1e-12);
    }

    #[test]
    fn perplexity_uniform_model() {
        // Uniform phi over V=4 and any theta gives perplexity 4.
        let docs = vec![vec![0u32, 1, 2, 3]];
        let phi = vec![vec![0.25; 4]; 2];
        let theta = vec![vec![0.5, 0.5]];
        let p = perplexity(&docs, &phi, &theta);
        assert!((p - 4.0).abs() < 1e-9);
    }

    #[test]
    fn perplexity_of_empty_heldout_set_is_nan() {
        // Regression: zero scored tokens used to yield exp(-0/1) = 1.0
        // — a silently "perfect" score for an empty evaluation. It must
        // be NaN (undefined), for no documents and for all-empty docs.
        let phi = vec![vec![0.25; 4]; 2];
        assert!(perplexity(&[], &phi, &[]).is_nan());
        let docs = vec![Vec::new(), Vec::new()];
        let theta = vec![vec![0.5, 0.5]; 2];
        assert!(perplexity(&docs, &phi, &theta).is_nan());
    }
}
