//! Metrics, timers, and trace output.
//!
//! Every trainer emits one [`IterRecord`] per evaluated iteration; a
//! [`TraceWriter`] streams them as CSV (the format the experiment
//! drivers and plotting scripts consume). [`PhaseTimers`] accumulates
//! per-phase wall-clock so the perf pass and Fig 1(i) (time per
//! iteration) come from the same instrumentation.

use std::io::Write;
use std::time::{Duration, Instant};

/// Wall-clock accumulator for the sampler phases, plus named event
/// counters (thread spawns, pool jobs, scratch allocations, …) so the
/// perf pass can see substrate overheads next to phase times.
///
/// The reserved phase name [`PhaseTimers::CRITICAL_PATH`] holds the
/// per-iteration *wall* time (what the pipelined samplers record
/// around the whole step). Per-phase times, by contrast, attribute
/// *work* — including work that ran on pool workers concurrently with
/// other phases — so `sum-of-phases > critical path` is exactly the
/// overlap the pipeline bought ([`PhaseTimers::overlap_seconds`]).
#[derive(Clone, Debug, Default)]
pub struct PhaseTimers {
    /// (phase name, accumulated time, invocation count)
    entries: Vec<(&'static str, Duration, u64)>,
    /// (counter name, accumulated count)
    counters: Vec<(&'static str, u64)>,
}

impl PhaseTimers {
    /// Reserved phase name for per-iteration wall time (excluded from
    /// [`PhaseTimers::phase_seconds`]).
    pub const CRITICAL_PATH: &'static str = "critical_path";

    /// Counter name: streamed-sweep blocks whose prefetched token/z
    /// loads were already complete when the sweep joined them — the
    /// I/O the double buffer hid behind compute.
    pub const PREFETCH_HITS: &'static str = "prefetch_hits";

    /// Counter name: streamed-sweep blocks whose data was not ready at
    /// join time (the sweep waited or loaded inline; each slot
    /// stripe's cold first block lands here). `hits + stalls` equals
    /// the blocks swept with prefetch enabled.
    pub const PREFETCH_STALLS: &'static str = "prefetch_stalls";

    /// Counter name: streamed-sweep prefetch jobs that died (panicked
    /// after exhausting their I/O retries) and were degraded to an
    /// inline reload. Every failure is also counted as a stall.
    pub const PREFETCH_FAILURES: &'static str = "prefetch_failures";

    /// Counter name: elements fed through the SIMD gather kernel in
    /// the dense bucket-(b) z branch (0 under the scalar kernel set).
    pub const KERNEL_GATHER_ELEMS: &'static str = "kern_gather_elems";

    /// Counter name: tokens whose bucket-(b) selection scan ran the
    /// SIMD `find_first_gt` kernel.
    pub const KERNEL_SCAN_TOKENS: &'static str = "kern_scan_tokens";

    /// Counter name: Φ nonzeros pushed through the kernel-accelerated
    /// alias builds (weight gather + rescale + Vose partition).
    pub const KERNEL_ALIAS_ELEMS: &'static str = "kern_alias_elems";

    /// Counter name: Φ nonzeros normalized through the kernel
    /// `scale_f64` path when assembling the matrix.
    pub const KERNEL_PHI_ELEMS: &'static str = "kern_phi_elems";

    /// Counter name: tokens resampled by the Pólya-urn MH z fast path
    /// (0 for exact sweeps).
    pub const PPU_TOKENS: &'static str = "ppu_tokens";

    /// Counter name: PPU doc-proposal MH moves accepted (urn /
    /// `Ψ`-alias side). `ppu_doc_accepts / ppu_tokens` is the doc-side
    /// acceptance rate.
    pub const PPU_DOC_ACCEPTS: &'static str = "ppu_doc_accepts";

    /// Counter name: PPU word-proposal MH moves accepted (bucket-(a)
    /// alias side).
    pub const PPU_WORD_ACCEPTS: &'static str = "ppu_word_accepts";

    /// Gauge name: total resident sampler-state bytes — token arena +
    /// doc offsets + the z store in its live layout. Set (not
    /// accumulated) via [`PhaseTimers::set`].
    pub const RESIDENT_BYTES: &'static str = "resident_bytes";

    /// Gauge name: packed token-arena bytes (tokens + doc offsets) —
    /// the corpus side of [`PhaseTimers::RESIDENT_BYTES`].
    pub const ARENA_BYTES: &'static str = "arena_bytes";

    /// Gauge name: z-store resident bytes in the sampler's live layout
    /// (nested `Vec<Vec<u32>>` headers + payloads, a flat arena, or
    /// just the offsets of a file-backed store).
    pub const Z_BYTES: &'static str = "z_bytes";

    /// Create with no phases registered.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `elapsed` to `phase`.
    pub fn add(&mut self, phase: &'static str, elapsed: Duration) {
        for e in self.entries.iter_mut() {
            if e.0 == phase {
                e.1 += elapsed;
                e.2 += 1;
                return;
            }
        }
        self.entries.push((phase, elapsed, 1));
    }

    /// Time a closure and attribute it to `phase`.
    pub fn time<R>(&mut self, phase: &'static str, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.add(phase, t0.elapsed());
        r
    }

    /// Accumulated seconds for `phase` (0 when unknown).
    pub fn seconds(&self, phase: &str) -> f64 {
        self.entries
            .iter()
            .find(|e| e.0 == phase)
            .map(|e| e.1.as_secs_f64())
            .unwrap_or(0.0)
    }

    /// Total across phases.
    pub fn total_seconds(&self) -> f64 {
        self.entries.iter().map(|e| e.1.as_secs_f64()).sum()
    }

    /// Sum of per-phase seconds, excluding the reserved
    /// [`PhaseTimers::CRITICAL_PATH`] wall timer — the "work" side of
    /// the overlap comparison.
    pub fn phase_seconds(&self) -> f64 {
        self.entries
            .iter()
            .filter(|e| e.0 != Self::CRITICAL_PATH)
            .map(|e| e.1.as_secs_f64())
            .sum()
    }

    /// Overlap the pipeline bought: `sum-of-phases − critical path`,
    /// clamped at 0 (also 0 when no critical-path wall was recorded).
    /// A barriered loop reports ≈ 0; a pipelined loop reports the
    /// worker time hidden behind the serial tail.
    pub fn overlap_seconds(&self) -> f64 {
        let wall = self.seconds(Self::CRITICAL_PATH);
        if wall <= 0.0 {
            return 0.0;
        }
        (self.phase_seconds() - wall).max(0.0)
    }

    /// `(phase, seconds, calls)` rows, insertion order.
    pub fn rows(&self) -> Vec<(&'static str, f64, u64)> {
        self.entries.iter().map(|e| (e.0, e.1.as_secs_f64(), e.2)).collect()
    }

    /// Add `delta` to the named event counter.
    pub fn incr(&mut self, counter: &'static str, delta: u64) {
        for c in self.counters.iter_mut() {
            if c.0 == counter {
                c.1 += delta;
                return;
            }
        }
        self.counters.push((counter, delta));
    }

    /// Set the named counter to an absolute value — gauge semantics,
    /// last write wins. For measurements (byte footprints) where
    /// accumulating samples would be meaningless. Gauges share the
    /// counter namespace; [`PhaseTimers::merge`] *adds* counters, so
    /// set gauges after any merging.
    pub fn set(&mut self, counter: &'static str, value: u64) {
        for c in self.counters.iter_mut() {
            if c.0 == counter {
                c.1 = value;
                return;
            }
        }
        self.counters.push((counter, value));
    }

    /// Accumulated value of a counter (0 when unknown).
    pub fn counter(&self, counter: &str) -> u64 {
        self.counters
            .iter()
            .find(|c| c.0 == counter)
            .map(|c| c.1)
            .unwrap_or(0)
    }

    /// `(counter, count)` rows, insertion order.
    pub fn counter_rows(&self) -> Vec<(&'static str, u64)> {
        self.counters.clone()
    }

    /// Human-readable summary. Phase percentages are of the phase-work
    /// total (the wall timer is reported separately with the overlap).
    pub fn summary(&self) -> String {
        let total = self.phase_seconds().max(1e-12);
        let mut s = String::new();
        for (name, secs, calls) in self.rows() {
            if name == Self::CRITICAL_PATH {
                continue;
            }
            s.push_str(&format!(
                "{name:>12}: {secs:9.3}s ({:5.1}%) over {calls} calls\n",
                100.0 * secs / total
            ));
        }
        let wall = self.seconds(Self::CRITICAL_PATH);
        if wall > 0.0 {
            s.push_str(&format!(
                "{:>12}: {wall:9.3}s (overlap gained {:.3}s)\n",
                Self::CRITICAL_PATH,
                self.overlap_seconds()
            ));
        }
        for &(name, count) in &self.counters {
            s.push_str(&format!("{name:>16}: {count}\n"));
        }
        s
    }

    /// Merge another timer set into this one.
    pub fn merge(&mut self, other: &PhaseTimers) {
        for &(name, dur, count) in &other.entries {
            for e in self.entries.iter_mut() {
                if e.0 == name {
                    e.1 += dur;
                    e.2 += count;
                }
            }
            if !self.entries.iter().any(|e| e.0 == name) {
                self.entries.push((name, dur, count));
            }
        }
        for &(name, count) in &other.counters {
            self.incr(name, count);
        }
    }
}

/// One evaluated iteration of a trainer.
#[derive(Clone, Debug, PartialEq)]
pub struct IterRecord {
    /// Iteration index (1-based).
    pub iteration: usize,
    /// Wall-clock seconds since training started.
    pub elapsed_secs: f64,
    /// Seconds spent in this iteration alone.
    pub iter_secs: f64,
    /// Log marginal likelihood of z given Ψ, Φ (paper Fig 1 metric).
    pub log_likelihood: f64,
    /// Topics with ≥ 1 token.
    pub active_topics: usize,
    /// Tokens currently assigned to the flag topic K* (§2.4: should
    /// stay 0 when K* is large enough).
    pub flag_topic_tokens: u64,
    /// Total tokens (invariant check).
    pub total_tokens: u64,
}

impl IterRecord {
    /// CSV header matching [`IterRecord::to_csv_row`].
    pub const CSV_HEADER: &'static str =
        "iteration,elapsed_secs,iter_secs,log_likelihood,active_topics,flag_topic_tokens,total_tokens";

    /// Serialize as a CSV row.
    pub fn to_csv_row(&self) -> String {
        format!(
            "{},{:.6},{:.6},{:.6},{},{},{}",
            self.iteration,
            self.elapsed_secs,
            self.iter_secs,
            self.log_likelihood,
            self.active_topics,
            self.flag_topic_tokens,
            self.total_tokens
        )
    }

    /// Parse a CSV row produced by [`IterRecord::to_csv_row`].
    pub fn from_csv_row(row: &str) -> anyhow::Result<Self> {
        let f: Vec<&str> = row.split(',').collect();
        anyhow::ensure!(f.len() == 7, "expected 7 fields, got {}", f.len());
        Ok(Self {
            iteration: f[0].parse()?,
            elapsed_secs: f[1].parse()?,
            iter_secs: f[2].parse()?,
            log_likelihood: f[3].parse()?,
            active_topics: f[4].parse()?,
            flag_topic_tokens: f[5].parse()?,
            total_tokens: f[6].parse()?,
        })
    }
}

/// Streaming CSV trace writer. `None` path = in-memory only (tests and
/// library callers that want the records without I/O).
pub struct TraceWriter {
    out: Option<std::io::BufWriter<std::fs::File>>,
    records: Vec<IterRecord>,
}

impl TraceWriter {
    /// In-memory trace.
    pub fn in_memory() -> Self {
        Self { out: None, records: Vec::new() }
    }

    /// Trace streaming to a CSV file (header written immediately).
    pub fn to_file(path: &std::path::Path) -> anyhow::Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(out, "{}", IterRecord::CSV_HEADER)?;
        Ok(Self { out: Some(out), records: Vec::new() })
    }

    /// Append a record.
    pub fn push(&mut self, rec: IterRecord) -> anyhow::Result<()> {
        if let Some(out) = self.out.as_mut() {
            writeln!(out, "{}", rec.to_csv_row())?;
        }
        self.records.push(rec);
        Ok(())
    }

    /// Records so far.
    pub fn records(&self) -> &[IterRecord] {
        &self.records
    }

    /// Flush file output.
    pub fn flush(&mut self) -> anyhow::Result<()> {
        if let Some(out) = self.out.as_mut() {
            out.flush()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timers_accumulate() {
        let mut t = PhaseTimers::new();
        t.add("z", Duration::from_millis(10));
        t.add("z", Duration::from_millis(5));
        t.add("phi", Duration::from_millis(1));
        assert!((t.seconds("z") - 0.015).abs() < 1e-9);
        assert_eq!(t.rows()[0].2, 2);
        assert!(t.total_seconds() > 0.015);
        let r = t.time("l", || 42);
        assert_eq!(r, 42);
        assert!(t.seconds("l") >= 0.0);
        let summary = t.summary();
        assert!(summary.contains("z") && summary.contains("phi"));
    }

    #[test]
    fn timers_merge() {
        let mut a = PhaseTimers::new();
        a.add("z", Duration::from_millis(10));
        a.incr("pool_jobs", 3);
        let mut b = PhaseTimers::new();
        b.add("z", Duration::from_millis(10));
        b.add("phi", Duration::from_millis(2));
        b.incr("pool_jobs", 4);
        b.incr("thread_spawns", 1);
        a.merge(&b);
        assert!((a.seconds("z") - 0.02).abs() < 1e-9);
        assert!((a.seconds("phi") - 0.002).abs() < 1e-9);
        assert_eq!(a.counter("pool_jobs"), 7);
        assert_eq!(a.counter("thread_spawns"), 1);
    }

    #[test]
    fn critical_path_and_overlap() {
        let mut t = PhaseTimers::new();
        t.add("phi", Duration::from_millis(30));
        t.add("z", Duration::from_millis(50));
        t.add(PhaseTimers::CRITICAL_PATH, Duration::from_millis(60));
        // Work = 80 ms over a 60 ms wall → 20 ms of overlap.
        assert!((t.phase_seconds() - 0.080).abs() < 1e-9);
        assert!((t.overlap_seconds() - 0.020).abs() < 1e-9);
        let s = t.summary();
        assert!(s.contains("critical_path") && s.contains("overlap"));
        // A barriered loop (wall ≥ work) reports zero overlap.
        let mut t = PhaseTimers::new();
        t.add("z", Duration::from_millis(10));
        t.add(PhaseTimers::CRITICAL_PATH, Duration::from_millis(12));
        assert_eq!(t.overlap_seconds(), 0.0);
        // No wall recorded → overlap undefined → 0.
        let mut t = PhaseTimers::new();
        t.add("z", Duration::from_millis(10));
        assert_eq!(t.overlap_seconds(), 0.0);
    }

    #[test]
    fn counters_accumulate_and_report() {
        let mut t = PhaseTimers::new();
        assert_eq!(t.counter("pool_jobs"), 0);
        t.incr("pool_jobs", 5);
        t.incr("pool_jobs", 2);
        t.incr("scratch_allocs", 1);
        assert_eq!(t.counter("pool_jobs"), 7);
        assert_eq!(t.counter_rows(), vec![("pool_jobs", 7), ("scratch_allocs", 1)]);
        let s = t.summary();
        assert!(s.contains("pool_jobs") && s.contains("scratch_allocs"));
        // The streamed-prefetch counters flow through the same
        // machinery under their reserved names.
        t.incr(PhaseTimers::PREFETCH_HITS, 10);
        t.incr(PhaseTimers::PREFETCH_STALLS, 3);
        assert_eq!(t.counter("prefetch_hits"), 10);
        assert_eq!(t.counter("prefetch_stalls"), 3);
        assert!(t.summary().contains("prefetch_hits"));
    }

    #[test]
    fn gauges_overwrite_instead_of_accumulating() {
        let mut t = PhaseTimers::new();
        t.set(PhaseTimers::RESIDENT_BYTES, 1000);
        t.set(PhaseTimers::RESIDENT_BYTES, 800);
        assert_eq!(t.counter("resident_bytes"), 800);
        t.set(PhaseTimers::ARENA_BYTES, 600);
        t.set(PhaseTimers::Z_BYTES, 200);
        assert_eq!(
            t.counter_rows(),
            vec![("resident_bytes", 800), ("arena_bytes", 600), ("z_bytes", 200)]
        );
        assert!(t.summary().contains("resident_bytes"));
    }

    #[test]
    fn record_csv_roundtrip() {
        let rec = IterRecord {
            iteration: 12,
            elapsed_secs: 3.5,
            iter_secs: 0.25,
            log_likelihood: -12345.678,
            active_topics: 42,
            flag_topic_tokens: 0,
            total_tokens: 99999,
        };
        let parsed = IterRecord::from_csv_row(&rec.to_csv_row()).unwrap();
        assert_eq!(parsed, rec);
        assert!(IterRecord::from_csv_row("1,2,3").is_err());
    }

    #[test]
    fn trace_writer_file_and_memory() {
        let dir = std::env::temp_dir().join("hdp_sparse_trace_test");
        let path = dir.join("trace.csv");
        let mut w = TraceWriter::to_file(&path).unwrap();
        let rec = IterRecord {
            iteration: 1,
            elapsed_secs: 0.1,
            iter_secs: 0.1,
            log_likelihood: -1.0,
            active_topics: 3,
            flag_topic_tokens: 0,
            total_tokens: 10,
        };
        w.push(rec.clone()).unwrap();
        w.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        assert_eq!(lines.next().unwrap(), IterRecord::CSV_HEADER);
        assert_eq!(
            IterRecord::from_csv_row(lines.next().unwrap()).unwrap(),
            rec
        );
        assert_eq!(w.records().len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
