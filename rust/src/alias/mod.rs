//! Walker alias tables for O(1) categorical sampling.
//!
//! The doubly sparse `z` step (paper §2.5) splits the full conditional
//! into bucket *(a)* `φ_{k,v}·α·Ψ_k` — identical for every token of word
//! type `v` in every document — and bucket *(b)* `φ_{k,v}·m_{d,k}`.
//! Bucket (a) is materialized once per iteration as one alias table per
//! word type over the *nonzero support* of the `Φ` column (Walker 1977;
//! Li et al. 2014), turning each draw into two uniforms. Because `Φ` and
//! `Ψ` are held fixed throughout the z phase (partially collapsed
//! sampler), the table is exact — no Metropolis–Hastings correction is
//! needed, unlike alias methods for fully collapsed LDA.
//!
//! [`AliasTable`] is the dense variant (outcome = slot index);
//! [`SparseAlias`] carries an explicit support so outcomes map back to
//! topic ids.

use crate::rng::Pcg64;
use crate::simd::Kernels;

/// Dense Walker alias table over outcomes `0..n`, built with Vose's
/// O(n) construction. Stores the total input mass so callers can mix
/// table draws with other buckets.
#[derive(Clone, Debug)]
pub struct AliasTable {
    /// acceptance probability per slot, scaled to u64 for a branch-cheap
    /// compare against raw RNG output.
    prob: Vec<u64>,
    alias: Vec<u32>,
    total: f64,
}

const U64_SCALE: f64 = 1.844_674_407_370_955_2e19; // 2^64

impl AliasTable {
    /// Build from (unnormalized, nonnegative) weights. Zero-weight
    /// outcomes are valid and will never be drawn. Panics on an empty or
    /// all-zero input in debug builds; in release the table degenerates
    /// to always returning slot 0.
    pub fn new(weights: &[f64]) -> Self {
        Self::new_with(weights, &Kernels::scalar())
    }

    /// [`AliasTable::new`] with an explicit kernel set: the slot
    /// rescaling and the small/large partition run through `kernels`
    /// (both bit-exact vs scalar — elementwise multiply and `< 1.0`
    /// compare; see [`crate::simd`]'s policy), so the table is
    /// bit-identical however it was built. The Vose pairing walk is
    /// inherently serial and stays scalar.
    pub fn new_with(weights: &[f64], kernels: &Kernels) -> Self {
        let n = weights.len();
        debug_assert!(n > 0, "alias table needs at least one outcome");
        debug_assert!(n <= u32::MAX as usize);
        let total: f64 = weights.iter().sum();
        debug_assert!(
            total > 0.0 && weights.iter().all(|&w| w >= 0.0),
            "alias table needs nonnegative weights with positive total"
        );
        let mut prob = vec![0u64; n];
        let mut alias: Vec<u32> = (0..n as u32).collect();
        // Vose's algorithm with two stacks.
        let scale = n as f64 / total;
        let mut scaled: Vec<f64> = weights.to_vec();
        (kernels.scale_f64)(&mut scaled, scale);
        let mut small: Vec<u32> = Vec::with_capacity(n);
        let mut large: Vec<u32> = Vec::with_capacity(n);
        (kernels.partition_lt1)(&scaled, &mut small, &mut large);
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            // p(s) fills the remainder of slot s from l.
            prob[s as usize] = (scaled[s as usize].min(1.0) * U64_SCALE) as u64;
            alias[s as usize] = l;
            scaled[l as usize] -= 1.0 - scaled[s as usize];
            if scaled[l as usize] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // Leftovers are numerically ≈ 1: accept unconditionally.
        for &i in small.iter().chain(large.iter()) {
            prob[i as usize] = u64::MAX;
        }
        Self { prob, alias, total }
    }

    /// Number of outcomes.
    #[inline]
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    /// True when the table has no outcomes.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Total (unnormalized) mass the table was built from.
    #[inline]
    pub fn total(&self) -> f64 {
        self.total
    }

    /// Draw an outcome in `0..len()` — two uniforms, O(1).
    #[inline]
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let slot = rng.below(self.prob.len() as u64) as usize;
        if rng.next_u64() < self.prob[slot] {
            slot
        } else {
            self.alias[slot] as usize
        }
    }
}

/// Alias table over an explicit sparse support: draws return elements of
/// `support` (topic ids), not slot indices. This is the per-word-type
/// bucket-(a) table: support = topics with `φ_{k,v} > 0`.
#[derive(Clone, Debug)]
pub struct SparseAlias {
    table: AliasTable,
    support: Vec<u32>,
}

impl SparseAlias {
    /// Build from parallel `(support, weights)` arrays.
    pub fn new(support: Vec<u32>, weights: &[f64]) -> Self {
        debug_assert_eq!(support.len(), weights.len());
        Self { table: AliasTable::new(weights), support }
    }

    /// [`SparseAlias::new`] with an explicit kernel set (bit-identical
    /// result; see [`AliasTable::new_with`]).
    pub fn new_with(support: Vec<u32>, weights: &[f64], kernels: &Kernels) -> Self {
        debug_assert_eq!(support.len(), weights.len());
        Self { table: AliasTable::new_with(weights, kernels), support }
    }

    /// Total unnormalized mass.
    #[inline]
    pub fn total(&self) -> f64 {
        self.table.total()
    }

    /// Support size.
    #[inline]
    pub fn len(&self) -> usize {
        self.support.len()
    }

    /// True when the support is empty.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.support.is_empty()
    }

    /// Draw a topic id from the support.
    #[inline]
    pub fn sample(&self, rng: &mut Pcg64) -> u32 {
        self.support[self.table.sample(rng)]
    }

    /// The support slice (sorted order is whatever the builder passed).
    #[inline]
    pub fn support(&self) -> &[u32] {
        &self.support
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_table_matches(weights: &[f64], seed: u64, trials: usize, tol: f64) {
        let table = AliasTable::new(weights);
        let mut rng = Pcg64::new(seed);
        let mut counts = vec![0usize; weights.len()];
        for _ in 0..trials {
            counts[table.sample(&mut rng)] += 1;
        }
        let total: f64 = weights.iter().sum();
        for (i, &w) in weights.iter().enumerate() {
            let want = w / total;
            let got = counts[i] as f64 / trials as f64;
            assert!(
                (got - want).abs() < tol,
                "outcome {i}: got {got}, want {want}"
            );
        }
    }

    #[test]
    fn uniform_weights() {
        check_table_matches(&[1.0; 8], 1, 200_000, 0.005);
    }

    #[test]
    fn skewed_weights() {
        check_table_matches(&[0.001, 10.0, 0.5, 3.0, 0.0, 0.2], 2, 400_000, 0.005);
    }

    #[test]
    fn zero_weight_never_drawn() {
        let table = AliasTable::new(&[0.0, 1.0, 0.0, 2.0]);
        let mut rng = Pcg64::new(3);
        for _ in 0..50_000 {
            let k = table.sample(&mut rng);
            assert!(k == 1 || k == 3);
        }
    }

    #[test]
    fn single_outcome() {
        let table = AliasTable::new(&[3.7]);
        let mut rng = Pcg64::new(4);
        for _ in 0..100 {
            assert_eq!(table.sample(&mut rng), 0);
        }
        assert!((table.total() - 3.7).abs() < 1e-12);
    }

    #[test]
    fn total_preserved() {
        let w = [1.5, 2.5, 6.0];
        let table = AliasTable::new(&w);
        assert!((table.total() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn sparse_alias_maps_support() {
        let support = vec![5u32, 17, 900];
        let weights = [1.0, 2.0, 1.0];
        let sa = SparseAlias::new(support.clone(), &weights);
        let mut rng = Pcg64::new(5);
        let mut counts = std::collections::HashMap::new();
        for _ in 0..100_000 {
            *counts.entry(sa.sample(&mut rng)).or_insert(0usize) += 1;
        }
        assert_eq!(counts.len(), 3);
        assert!((counts[&17] as f64 / 100_000.0 - 0.5).abs() < 0.01);
        assert!(counts.keys().all(|k| support.contains(k)));
    }

    /// Whatever kernel tier `auto()` resolves to, the table it builds
    /// must be bit-identical to the scalar-built one (the rescale and
    /// partition kernels are bit-exact by policy, and the pairing walk
    /// is shared).
    #[test]
    fn kernel_built_table_is_bit_identical() {
        let weights: Vec<f64> = (1..=257).map(|i| ((i * 37) % 101) as f64 * 0.13).collect();
        let a = AliasTable::new(&weights);
        let b = AliasTable::new_with(&weights, &Kernels::auto());
        assert_eq!(a.prob, b.prob);
        assert_eq!(a.alias, b.alias);
        assert_eq!(a.total.to_bits(), b.total.to_bits());
    }

    #[test]
    fn many_outcomes_chi2() {
        // 1000-outcome Zipf-ish weights, χ² sanity.
        let weights: Vec<f64> = (1..=1000).map(|i| 1.0 / i as f64).collect();
        let table = AliasTable::new(&weights);
        let mut rng = Pcg64::new(6);
        let trials = 2_000_000usize;
        let mut counts = vec![0usize; 1000];
        for _ in 0..trials {
            counts[table.sample(&mut rng)] += 1;
        }
        let total: f64 = weights.iter().sum();
        let mut chi2 = 0.0;
        for (c, w) in counts.iter().zip(&weights) {
            let e = trials as f64 * w / total;
            chi2 += (*c as f64 - e).powi(2) / e;
        }
        // 999 dof: mean 999, sd ~44.7; allow 5 sigma.
        assert!(chi2 < 999.0 + 5.0 * 44.7, "chi2={chi2}");
    }
}
