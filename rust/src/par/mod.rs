//! Minimal data-parallel execution substrate (no external crates).
//!
//! The sampler's phases are bulk-synchronous: *z phase* parallel over
//! document shards, *Φ/l phases* parallel over topic ranges, followed by
//! a merge. Two substrates implement the [`pool::Executor`] contract:
//!
//! * [`pool::WorkerPool`] — a persistent fork-join pool created once
//!   per sampler and reused across all iterations (no per-phase thread
//!   spawns, reusable per-slot scratch); this is what the samplers run
//!   on. Beyond the blocking phase dispatch it supports *asynchronous*
//!   submission ([`pool::WorkerPool::submit_map`] → [`pool::MapJob`]),
//!   which is what lets the sampler overlap Φ sampling for iteration
//!   t+1 with the serial merge/l/Ψ tail of iteration t, and a
//!   [`pool::Schedule::SlotAffine`] mode that pins shard `i` to slot
//!   `i % slots` every sweep (cache/NUMA affinity).
//! * `usize` — the original scoped-thread-per-task strategy
//!   ([`scope_shards`], [`parallel_for_ranges`], [`parallel_map`] are
//!   thin wrappers over it), kept for one-shot callers and as the
//!   baseline `benches/pool_overhead.rs` measures the pool against.
//!
//! [`Sharding`] computes balanced contiguous shards; for documents it
//! can balance by *token count* rather than document count, which is the
//! load-balancing fix the paper inherits from Magnusson et al. (2018).

pub mod affinity;
pub mod pool;

pub use pool::{
    exec_each, exec_for, exec_map, exec_shards, exec_shards_with,
    exec_shards_with_sched, stats, Executor, JobHandle, MapJob, Schedule,
    WorkerPool,
};

/// A contiguous shard `[start, end)` of some index space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shard {
    pub start: usize,
    pub end: usize,
}

impl Shard {
    /// Number of items in the shard.
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    /// True when the shard is empty.
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// Balanced sharding plans.
#[derive(Clone, Debug)]
pub struct Sharding {
    shards: Vec<Shard>,
}

impl Sharding {
    /// Split `0..n` into at most `parts` near-equal contiguous shards
    /// (every shard non-empty; fewer shards when `n < parts`).
    pub fn even(n: usize, parts: usize) -> Self {
        let parts = parts.max(1).min(n.max(1));
        let mut shards = Vec::with_capacity(parts);
        if n == 0 {
            return Self { shards };
        }
        let base = n / parts;
        let extra = n % parts;
        let mut start = 0;
        for i in 0..parts {
            let len = base + usize::from(i < extra);
            shards.push(Shard { start, end: start + len });
            start += len;
        }
        Self { shards }
    }

    /// Split `0..weights.len()` into at most `parts` contiguous shards
    /// with near-equal total weight (greedy cut at the running-average
    /// boundary). Used to shard documents by token count so that long
    /// documents don't serialize a shard.
    pub fn weighted(weights: &[u64], parts: usize) -> Self {
        let n = weights.len();
        if n == 0 || parts <= 1 {
            return Self::even(n, parts);
        }
        let total: u64 = weights.iter().sum();
        let parts = parts.min(n);
        let target = total as f64 / parts as f64;
        let mut shards = Vec::with_capacity(parts);
        let mut start = 0usize;
        let mut acc = 0u64;
        let mut cut = target;
        for (i, &w) in weights.iter().enumerate() {
            acc += w;
            let remaining_shards = parts - shards.len();
            let remaining_items = n - i - 1;
            // Cut when we pass the running target, but never leave more
            // shards to make than items remaining.
            if (acc as f64 >= cut && shards.len() + 1 < parts)
                || remaining_items + 1 == remaining_shards
            {
                shards.push(Shard { start, end: i + 1 });
                start = i + 1;
                cut += target;
            }
        }
        if start < n {
            shards.push(Shard { start, end: n });
        }
        Self { shards }
    }

    /// Refine every shard into contiguous blocks of at most
    /// `max_items` items. Block boundaries nest inside the original
    /// shard boundaries, so the refined plan covers exactly the same
    /// index space in the same order — any consumer whose per-item work
    /// is keyed by item id (like the z sweep's per-document RNG
    /// streams) computes a bit-identical result on the refined plan.
    /// This is how the streamed z phase derives its block plan from the
    /// document shard plan.
    pub fn refine(&self, max_items: usize) -> Sharding {
        let max_items = max_items.max(1);
        let mut blocks = Vec::new();
        for s in &self.shards {
            let mut start = s.start;
            while start < s.end {
                let end = start.saturating_add(max_items).min(s.end);
                blocks.push(Shard { start, end });
                start = end;
            }
        }
        Sharding { shards: blocks }
    }

    /// Largest total weight any executor slot receives when shard `i`
    /// runs on slot `i % slots` — the [`Schedule::SlotAffine`] stripe
    /// bound, and the expected per-slot share under balanced stealing.
    /// Used to pre-size per-slot sweep accumulators from the plan
    /// actually in effect instead of whole-corpus totals (which
    /// over-allocate streamed sweeps whose plans are block-refined).
    pub fn max_stripe_weight(&self, weights: &[u64], slots: usize) -> u64 {
        let slots = slots.max(1);
        let mut per = vec![0u64; slots];
        for (i, s) in self.shards.iter().enumerate() {
            let w: u64 = weights[s.start..s.end].iter().sum();
            per[i % slots] += w;
        }
        per.into_iter().max().unwrap_or(0)
    }

    /// The shards.
    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    /// Number of shards.
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    /// True when there are no shards (empty index space).
    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }
}

/// Run `f(shard_index, shard)` on every shard, one OS thread per shard
/// (plus the caller's thread for shard 0), and collect the results in
/// shard order. Single-shard plans run inline with zero spawns.
pub fn scope_shards<R: Send>(
    sharding: &Sharding,
    f: impl Fn(usize, Shard) -> R + Sync,
) -> Vec<R> {
    pool::exec_shards(sharding.len(), sharding, f)
}

/// Parallel-for over `0..n` in `threads` contiguous ranges; `f` receives
/// each index. Scoped-thread convenience wrapper over [`pool::exec_for`].
pub fn parallel_for_ranges(n: usize, threads: usize, f: impl Fn(usize) + Sync) {
    pool::exec_for(threads, n, f)
}

/// Parallel map over `0..n` producing a `Vec<R>` in index order.
pub fn parallel_map<R: Send>(
    n: usize,
    threads: usize,
    f: impl Fn(usize) -> R + Sync,
) -> Vec<R> {
    pool::exec_map(threads, n, f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn even_sharding_covers_everything() {
        for n in [0usize, 1, 7, 100] {
            for parts in [1usize, 2, 3, 8, 200] {
                let plan = Sharding::even(n, parts);
                let mut seen = vec![false; n];
                for s in plan.shards() {
                    for i in s.start..s.end {
                        assert!(!seen[i]);
                        seen[i] = true;
                    }
                    assert!(!s.is_empty() || n == 0);
                }
                assert!(seen.iter().all(|&b| b), "n={n} parts={parts}");
                if n > 0 {
                    assert!(plan.len() <= parts.max(1));
                    let lens: Vec<usize> =
                        plan.shards().iter().map(|s| s.len()).collect();
                    let min = lens.iter().min().unwrap();
                    let max = lens.iter().max().unwrap();
                    assert!(max - min <= 1, "balanced: {lens:?}");
                }
            }
        }
    }

    #[test]
    fn weighted_sharding_balances_mass() {
        // One huge doc + many small: even split by count would put the
        // huge doc plus half the small ones in shard 0.
        let mut weights = vec![10u64; 100];
        weights[0] = 500;
        let plan = Sharding::weighted(&weights, 4);
        assert_eq!(plan.len(), 4);
        let mass: Vec<u64> = plan
            .shards()
            .iter()
            .map(|s| weights[s.start..s.end].iter().sum())
            .collect();
        let total: u64 = weights.iter().sum();
        // every shard within 2x of ideal
        for m in &mass {
            assert!(*m <= total / 2, "mass {mass:?}");
        }
        // coverage
        assert_eq!(mass.iter().sum::<u64>(), total);
    }

    #[test]
    fn weighted_handles_degenerate() {
        assert_eq!(Sharding::weighted(&[], 4).len(), 0);
        let plan = Sharding::weighted(&[5, 5], 8);
        assert_eq!(plan.shards().iter().map(|s| s.len()).sum::<usize>(), 2);
    }

    /// Property check for adversarial weight vectors: every plan must
    /// consist of non-empty contiguous shards covering `0..n` exactly
    /// once, with at most `min(parts, n)` shards.
    fn assert_weighted_plan_valid(weights: &[u64], parts: usize) {
        let plan = Sharding::weighted(weights, parts);
        let n = weights.len();
        if n == 0 {
            assert!(plan.is_empty(), "empty input yields empty plan");
            return;
        }
        assert!(!plan.is_empty());
        assert!(
            plan.len() <= parts.max(1).min(n),
            "n={n} parts={parts}: got {} shards",
            plan.len()
        );
        let mut next = 0usize;
        for s in plan.shards() {
            assert!(!s.is_empty(), "empty shard in {:?}", plan.shards());
            assert_eq!(s.start, next, "gap/overlap at {}", s.start);
            next = s.end;
        }
        assert_eq!(next, n, "plan must cover all items");
    }

    #[test]
    fn weighted_sharding_adversarial_weights() {
        // All-zero weights (zero total mass must not divide-by-zero or
        // produce empty shards).
        assert_weighted_plan_valid(&[0u64; 50], 8);
        assert_weighted_plan_valid(&[0u64; 3], 3);
        // One giant document dwarfing everything else, in every
        // position.
        for pos in [0usize, 17, 49] {
            let mut w = vec![1u64; 50];
            w[pos] = 1_000_000_000;
            assert_weighted_plan_valid(&w, 4);
        }
        // Fewer items than parts.
        assert_weighted_plan_valid(&[7, 2, 9], 16);
        assert_weighted_plan_valid(&[7], 16);
        // Single part, and huge part counts.
        assert_weighted_plan_valid(&[1, 2, 3, 4, 5], 1);
        assert_weighted_plan_valid(&(0..200u64).collect::<Vec<_>>(), 200);
        // Pseudo-random fuzz over sizes and skews.
        let mut state = 0x9e37u64;
        for case in 0..50 {
            let n = 1 + (case * 13) % 120;
            let parts = 1 + (case * 7) % 16;
            let w: Vec<u64> = (0..n)
                .map(|_| {
                    state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                    if state % 11 == 0 {
                        0
                    } else {
                        state % 1000
                    }
                })
                .collect();
            assert_weighted_plan_valid(&w, parts);
        }
    }

    #[test]
    fn refine_nests_inside_shards_and_covers() {
        for n in [0usize, 1, 9, 100] {
            for parts in [1usize, 3, 7] {
                for max_items in [1usize, 2, 5, 1000, usize::MAX] {
                    let plan = Sharding::even(n, parts);
                    let blocks = plan.refine(max_items);
                    // Coverage: contiguous from 0..n, in order.
                    let mut next = 0usize;
                    for b in blocks.shards() {
                        assert_eq!(b.start, next);
                        assert!(!b.is_empty());
                        assert!(b.len() <= max_items);
                        next = b.end;
                    }
                    assert_eq!(next, n);
                    // Nesting: every block lies inside exactly one shard.
                    for b in blocks.shards() {
                        assert!(
                            plan.shards()
                                .iter()
                                .any(|s| s.start <= b.start && b.end <= s.end),
                            "block {b:?} crosses a shard boundary"
                        );
                    }
                }
            }
        }
        // max_items = 0 is clamped to 1-doc blocks, not a panic.
        let plan = Sharding::even(5, 2);
        assert_eq!(plan.refine(0).len(), 5);
    }

    #[test]
    fn max_stripe_weight_matches_manual_striping() {
        let weights: Vec<u64> = vec![5, 1, 1, 1, 10, 1, 1, 1, 1, 1];
        let plan = Sharding::even(10, 5); // shards of 2 docs each
        // shard weights: [6, 2, 11, 2, 2]; stripes over 2 slots:
        // slot0 = 6 + 11 + 2 = 19, slot1 = 2 + 2 = 4.
        assert_eq!(plan.max_stripe_weight(&weights, 2), 19);
        // One slot gets everything.
        assert_eq!(plan.max_stripe_weight(&weights, 1), 23);
        // More slots than shards: max single shard weight.
        assert_eq!(plan.max_stripe_weight(&weights, 16), 11);
        // Empty plan.
        assert_eq!(Sharding::even(0, 4).max_stripe_weight(&[], 4), 0);
    }

    #[test]
    fn scope_shards_returns_in_order() {
        let plan = Sharding::even(10, 3);
        let results = scope_shards(&plan, |i, s| (i, s.len()));
        assert_eq!(results.len(), 3);
        for (i, (idx, _)) in results.iter().enumerate() {
            assert_eq!(i, *idx);
        }
        assert_eq!(results.iter().map(|r| r.1).sum::<usize>(), 10);
    }

    #[test]
    fn parallel_for_touches_every_index() {
        let counter = AtomicUsize::new(0);
        parallel_for_ranges(1000, 4, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(100, 7, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }
}
