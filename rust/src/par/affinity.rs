//! Vendored CPU-affinity bindings (no crates).
//!
//! The pool's NUMA story is *pin + first-touch*: each worker thread is
//! pinned to one CPU ([`pin_current_thread`]), and the per-slot scratch
//! it owns is allocated/initialized **on that thread** afterwards, so
//! Linux's default first-touch page placement lands the pages on the
//! worker's node. Combined with [`Schedule::SlotAffine`] (shard `i` →
//! slot `i % slots` every sweep) a slot's working set stays node-local
//! across iterations. `sched_setaffinity(2)` is declared here directly
//! against the libc that `std` already links — no `libc` crate.
//!
//! Everything degrades gracefully: in containers/sandboxes that deny
//! `sched_setaffinity` the functions return `Err` (typically `EPERM`)
//! and callers fall back to unpinned operation; on non-Linux targets
//! they return [`std::io::ErrorKind::Unsupported`]. Tests skip, not
//! fail, on either.
//!
//! [`Schedule::SlotAffine`]: crate::par::Schedule::SlotAffine

use std::io;

/// Fixed-size CPU mask: 1024 CPUs, matching glibc's `cpu_set_t`.
pub const CPU_SET_WORDS: usize = 16;

/// A `cpu_set_t`-compatible bitmask (bit `c` of word `c / 64` = CPU c).
pub type CpuSet = [u64; CPU_SET_WORDS];

#[cfg(target_os = "linux")]
extern "C" {
    // glibc/musl wrappers; pid 0 = the calling thread.
    fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut u64) -> i32;
}

/// An empty CPU set.
pub fn empty_set() -> CpuSet {
    [0u64; CPU_SET_WORDS]
}

/// Set bit `cpu` in `set` (ignored beyond the 1024-CPU mask).
pub fn set_cpu(set: &mut CpuSet, cpu: usize) {
    if cpu < CPU_SET_WORDS * 64 {
        set[cpu / 64] |= 1u64 << (cpu % 64);
    }
}

/// The CPUs present in `set`, ascending.
pub fn cpus_in(set: &CpuSet) -> Vec<usize> {
    let mut out = Vec::new();
    for (w, &bits) in set.iter().enumerate() {
        let mut b = bits;
        while b != 0 {
            let t = b.trailing_zeros() as usize;
            out.push(w * 64 + t);
            b &= b - 1;
        }
    }
    out
}

/// Restrict the calling thread to the CPUs in `set`.
#[cfg(target_os = "linux")]
pub fn set_current_affinity(set: &CpuSet) -> io::Result<()> {
    // SAFETY: `set` is a valid, live [u64; 16] = 128 bytes, the size we
    // pass; pid 0 addresses only the calling thread.
    let rc = unsafe {
        sched_setaffinity(0, std::mem::size_of::<CpuSet>(), set.as_ptr())
    };
    if rc == 0 {
        Ok(())
    } else {
        Err(io::Error::last_os_error())
    }
}

#[cfg(not(target_os = "linux"))]
pub fn set_current_affinity(_set: &CpuSet) -> io::Result<()> {
    Err(io::Error::new(
        io::ErrorKind::Unsupported,
        "sched_setaffinity: not linux",
    ))
}

/// The calling thread's current affinity mask.
#[cfg(target_os = "linux")]
pub fn current_affinity() -> io::Result<CpuSet> {
    let mut set = empty_set();
    // SAFETY: `set` is a valid, writable 128-byte buffer; pid 0
    // addresses only the calling thread.
    let rc = unsafe {
        sched_getaffinity(0, std::mem::size_of::<CpuSet>(), set.as_mut_ptr())
    };
    if rc == 0 {
        Ok(set)
    } else {
        Err(io::Error::last_os_error())
    }
}

#[cfg(not(target_os = "linux"))]
pub fn current_affinity() -> io::Result<CpuSet> {
    Err(io::Error::new(
        io::ErrorKind::Unsupported,
        "sched_getaffinity: not linux",
    ))
}

/// Pin the calling thread to a single CPU.
pub fn pin_current_thread(cpu: usize) -> io::Result<()> {
    let mut set = empty_set();
    set_cpu(&mut set, cpu);
    set_current_affinity(&set)
}

/// The CPUs this process may run on, ascending — the topology the pool
/// lines its `SlotAffine` slot→CPU map up with. Honors cgroup/taskset
/// restrictions (it reads the *allowed* mask, not the machine size);
/// falls back to `0..available_parallelism()` where the syscall is
/// unavailable.
pub fn available_cpus() -> Vec<usize> {
    match current_affinity() {
        Ok(set) => {
            let cpus = cpus_in(&set);
            if !cpus.is_empty() {
                return cpus;
            }
            fallback_cpus()
        }
        Err(_) => fallback_cpus(),
    }
}

fn fallback_cpus() -> Vec<usize> {
    let n = std::thread::available_parallelism().map_or(1, |p| p.get());
    (0..n).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_set_bit_roundtrip() {
        let mut set = empty_set();
        for c in [0usize, 1, 63, 64, 65, 127, 1000, 1023] {
            set_cpu(&mut set, c);
        }
        set_cpu(&mut set, 5000); // out of mask range: ignored
        assert_eq!(cpus_in(&set), vec![0, 1, 63, 64, 65, 127, 1000, 1023]);
    }

    #[test]
    fn available_cpus_nonempty_and_sorted() {
        let cpus = available_cpus();
        assert!(!cpus.is_empty());
        assert!(cpus.windows(2).all(|w| w[0] < w[1]));
    }

    /// Pin to the first allowed CPU and restore. Containers may deny
    /// `sched_setaffinity` entirely — skip (don't fail) on any error,
    /// per the graceful-degradation contract.
    #[test]
    fn pin_and_restore_smoke() {
        let baseline = match current_affinity() {
            Ok(b) => b,
            Err(e) => {
                eprintln!("skipping pin smoke test: getaffinity: {e}");
                return;
            }
        };
        let cpus = cpus_in(&baseline);
        let target = match cpus.first() {
            Some(&c) => c,
            None => return,
        };
        match pin_current_thread(target) {
            Ok(()) => {
                let now = current_affinity().expect("getaffinity after pin");
                assert_eq!(cpus_in(&now), vec![target]);
                set_current_affinity(&baseline).expect("restore affinity");
            }
            Err(e) => {
                eprintln!("skipping pin smoke test: setaffinity denied: {e}");
            }
        }
    }
}
